"""Kernel correctness: jnp compact impl and Pallas kernel vs dense-masked
oracle, swept over shapes/variants/seeds with hypothesis."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import assume, given, settings, strategies as st

from compile import configs
from compile.kernels import bigbird, jnp_impl, pattern as pat, ref

VARIANTS = [
    "random",
    "window",
    "random_window",
    "window_global",
    "bigbird_itc",
    "bigbird_etc",
]


def make_cfg(variant, nb, block, g, w, r, heads, head_dim, seed):
    return configs.Config(
        variant=variant,
        seq_len=nb * block,
        block=block,
        global_blocks=g,
        window_blocks=w,
        random_blocks=r,
        layers=1,
        heads=heads,
        hidden=heads * head_dim,
        ffn=4 * heads * head_dim,
        vocab=64,
        batch=1,
        attn_seed=seed,
    )


def rand_qkv(rng, b, h, n, d):
    q = rng.normal(size=(b, h, n, d)).astype(np.float32)
    k = rng.normal(size=(b, h, n, d)).astype(np.float32)
    v = rng.normal(size=(b, h, n, d)).astype(np.float32)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


def rand_kv_valid(rng, b, n):
    """Random key-padding mask; always keeps a prefix so no row is empty."""
    keep = rng.integers(n // 2, n + 1, size=b)
    m = np.zeros((b, n), np.float32)
    for i, k in enumerate(keep):
        m[i, :k] = 1.0
    return jnp.asarray(m)


def assert_close_valid(got, want, kv_valid, atol=2e-5, rtol=2e-5):
    """Compare only at valid query positions: rows whose every attended
    key is padding produce unspecified (degenerate-softmax) values in
    both implementations, and the model never reads them."""
    g = np.asarray(got) * np.asarray(kv_valid)[:, None, :, None]
    w = np.asarray(want) * np.asarray(kv_valid)[:, None, :, None]
    np.testing.assert_allclose(g, w, atol=atol, rtol=rtol)


shape_strategy = st.tuples(
    st.sampled_from(VARIANTS),
    st.integers(min_value=6, max_value=12),   # nb
    st.sampled_from([4, 8]),                  # block
    st.integers(min_value=1, max_value=2),    # g
    st.sampled_from([1, 3]),                  # w
    st.integers(min_value=1, max_value=2),    # r
    st.integers(min_value=1, max_value=2),    # heads
    st.sampled_from([4, 16]),                 # head_dim
    st.integers(min_value=0, max_value=10_000),  # pattern seed
    st.integers(min_value=0, max_value=10_000),  # data seed
)


@settings(max_examples=60, deadline=None)
@given(shape_strategy)
def test_jnp_impl_matches_ref(t):
    variant, nb, block, g, w, r, heads, head_dim, pseed, dseed = t
    assume(g + w + r <= nb)
    cfg = make_cfg(variant, nb, block, g, w, r, heads, head_dim, pseed)
    rng = np.random.default_rng(dseed)
    q, k, v = rand_qkv(rng, 2, heads, cfg.seq_len, head_dim)
    kv = rand_kv_valid(rng, 2, cfg.seq_len)
    got = jnp_impl.attention(q, k, v, cfg, kv, impl="jnp")
    want = ref.bigbird_attention_ref(q, k, v, cfg, kv)
    assert_close_valid(got, want, kv)


@settings(max_examples=12, deadline=None)
@given(shape_strategy)
def test_pallas_matches_ref(t):
    """Pallas interpret mode is slow — fewer examples, same oracle."""
    variant, nb, block, g, w, r, heads, head_dim, pseed, dseed = t
    assume(g + w + r <= nb)
    cfg = make_cfg(variant, nb, block, g, w, r, heads, head_dim, pseed)
    rng = np.random.default_rng(dseed)
    q, k, v = rand_qkv(rng, 1, heads, cfg.seq_len, head_dim)
    kv = rand_kv_valid(rng, 1, cfg.seq_len)
    got = jnp_impl.attention(q, k, v, cfg, kv, impl="pallas")
    want = ref.bigbird_attention_ref(q, k, v, cfg, kv)
    assert_close_valid(got, want, kv)


@pytest.mark.parametrize("variant", VARIANTS)
def test_pallas_matches_jnp_no_padding(variant):
    """Pallas vs jnp impl without kv mask (exercise the default path)."""
    cfg = make_cfg(variant, 8, 8, 1, 3, 1, 2, 8, 5)
    rng = np.random.default_rng(0)
    q, k, v = rand_qkv(rng, 2, 2, cfg.seq_len, 8)
    a = jnp_impl.attention(q, k, v, cfg, impl="jnp")
    b = jnp_impl.attention(q, k, v, cfg, impl="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


def test_dense_matches_plain_softmax():
    rng = np.random.default_rng(1)
    q, k, v = rand_qkv(rng, 2, 2, 32, 8)
    got = jnp_impl.dense_attention(q, k, v)
    d = 8
    s = np.einsum("bhnd,bhmd->bhnm", q, k) / np.sqrt(d)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = np.einsum("bhnm,bhmd->bhnd", p, v)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5, rtol=1e-5)


def test_fully_padded_keys_are_ignored():
    """Output for valid queries must not depend on padded key content."""
    cfg = make_cfg("bigbird_itc", 8, 8, 1, 3, 1, 2, 8, 0)
    n = cfg.seq_len
    rng = np.random.default_rng(2)
    q, k, v = rand_qkv(rng, 1, 2, n, 8)
    kv = np.ones((1, n), np.float32)
    kv[0, n // 2 :] = 0.0
    kv = jnp.asarray(kv)
    out1 = jnp_impl.attention(q, k, v, cfg, kv, impl="jnp")
    # perturb padded keys/values wildly
    k2 = np.asarray(k).copy()
    v2 = np.asarray(v).copy()
    k2[:, :, n // 2 :, :] += 100.0
    v2[:, :, n // 2 :, :] -= 50.0
    out2 = jnp_impl.attention(q, jnp.asarray(k2), jnp.asarray(v2), cfg, kv, impl="jnp")
    np.testing.assert_allclose(
        np.asarray(out1)[:, :, : n // 2], np.asarray(out2)[:, :, : n // 2], atol=1e-5
    )


def test_rows_sum_to_one_property():
    """Attention output of constant V must be that constant (softmax rows
    normalise over exactly the attended set)."""
    cfg = make_cfg("bigbird_itc", 8, 8, 1, 3, 1, 1, 8, 3)
    n = cfg.seq_len
    rng = np.random.default_rng(3)
    q, k, _ = rand_qkv(rng, 1, 1, n, 8)
    v = jnp.full((1, 1, n, 8), 2.5, jnp.float32)
    out = jnp_impl.attention(q, k, v, cfg, impl="jnp")
    np.testing.assert_allclose(np.asarray(out), 2.5, atol=1e-5)


def test_vmem_estimate_matches_paper_scale():
    """At the paper's config (b=64, A=8 blocks, d=64) the working set must
    fit comfortably in a TPU core's ~16 MiB VMEM."""
    b, a, d = 64, 8, 64
    assert bigbird.vmem_bytes(b, a, d) < 16 * 2**20
    # and utilisation estimate is a sane fraction
    u = bigbird.mxu_utilization_estimate(b, a, d)
    assert 0.0 < u <= 1.0


def test_plan_pads_nonuniform_rows():
    cfg = make_cfg("window_global", 8, 4, 2, 3, 1, 1, 4, 0)
    idx, valid, g_eff = jnp_impl.plan(cfg)
    assert g_eff == 2
    assert idx.shape == valid.shape
    # rows whose window overlaps the global prefix have padding
    assert (valid == 0.0).any()
    # padded entries point at a legal block
    assert idx.min() >= 0 and idx.max() < cfg.num_blocks
