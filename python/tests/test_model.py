"""Layer-2 model tests: shapes, masking invariance, ETC handling, losses."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import configs, layers, model


CFG = configs.tiny()


def params_for(task, cfg=CFG):
    return model.init_task_params(jax.random.PRNGKey(0), cfg, task)


def rand_batch(cfg=CFG, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(6, cfg.vocab, size=(cfg.batch, cfg.seq_len)), jnp.int32)
    kv = jnp.ones((cfg.batch, cfg.seq_len), jnp.float32)
    return tokens, kv


@pytest.mark.parametrize("task,shape", [
    ("mlm", (CFG.batch, CFG.seq_len, CFG.vocab)),
    ("cls", (CFG.batch, CFG.num_classes)),
    ("qa", (CFG.batch, CFG.seq_len, 2)),
    ("multilabel", (CFG.batch, CFG.num_profiles)),
])
def test_forward_shapes(task, shape):
    params = params_for(task)
    tokens, kv = rand_batch()
    logits = model.forward(params, tokens, kv, CFG, task)
    assert logits.shape == shape
    assert bool(jnp.isfinite(logits).all())


def test_etc_prepends_and_strips_global_tokens():
    cfg = CFG.replace(variant="bigbird_etc")
    params = params_for("mlm", cfg)
    assert "global_emb" in params["encoder"]
    tokens, kv = rand_batch(cfg)
    h = layers.encoder(params["encoder"], tokens, kv, cfg)
    # output is on the *task* sequence, global prefix stripped
    assert h.shape == (cfg.batch, cfg.seq_len, cfg.hidden)


def test_padding_does_not_leak_into_valid_positions():
    params = params_for("mlm")
    rng = np.random.default_rng(1)
    tokens = rng.integers(6, CFG.vocab, size=(2, CFG.seq_len)).astype(np.int32)
    kv = np.ones((2, CFG.seq_len), np.float32)
    half = CFG.seq_len // 2
    kv[:, half:] = 0.0
    l1 = model.forward(params, jnp.asarray(tokens), jnp.asarray(kv), CFG, "mlm")
    tokens2 = tokens.copy()
    tokens2[:, half:] = 17  # change padded content
    l2 = model.forward(params, jnp.asarray(tokens2), jnp.asarray(kv), CFG, "mlm")
    np.testing.assert_allclose(
        np.asarray(l1)[:, :half], np.asarray(l2)[:, :half], atol=2e-4
    )


def test_mlm_loss_decreases_under_adam():
    from compile import train_step

    cfg = configs.tiny(seq_len=64, batch=2, layers=1, block=8)
    step_fn, n = train_step.make_train_step(cfg, "mlm", base_lr=1e-2, warmup=5)
    init_fn, _ = train_step.make_init(cfg, "mlm")
    flat = jax.jit(init_fn)()
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(6, cfg.vocab, size=(2, 64)), jnp.int32)
    kv = jnp.ones((2, 64), jnp.float32)
    weights = jnp.asarray((rng.random((2, 64)) < 0.15).astype(np.float32))
    sj = jax.jit(step_fn)
    losses = []
    for i in range(12):
        flat, m, v, loss = sj(flat, m, v, jnp.int32(i), tokens, kv, tokens, weights)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(losses))


def test_qa_loss_and_head_mask_padding():
    params = params_for("qa")
    tokens, kv = rand_batch()
    kv = kv.at[:, 100:].set(0.0)
    logits = model.forward(params, tokens, kv, CFG, "qa")
    assert bool((np.asarray(logits)[:, 100:, :] < -1e8).all()), "padding must be masked"


def test_raveler_roundtrip():
    params, unravel, n = model.raveler(CFG, "mlm")
    flat, _ = jax.flatten_util.ravel_pytree(params)
    assert flat.shape == (n,)
    back = unravel(flat)
    leaves_a = jax.tree_util.tree_leaves(params)
    leaves_b = jax.tree_util.tree_leaves(back)
    for a, b in zip(leaves_a, leaves_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_batch_specs_agree_with_loss_fn():
    for task in model.TASKS:
        args, names = model.batch_specs(CFG, task)
        assert len(args) == len(names)
        params = params_for(task)
        batch = []
        rng = np.random.default_rng(0)
        for a, name in zip(args, names):
            if a.dtype == jnp.int32:
                if len(a.shape) == 2:
                    hi = CFG.vocab
                elif name.startswith("label"):
                    hi = CFG.num_classes
                else:  # qa starts/ends
                    hi = CFG.seq_len
                batch.append(jnp.asarray(rng.integers(0, hi, size=a.shape), jnp.int32))
            else:
                batch.append(jnp.ones(a.shape, jnp.float32))
        loss = model.loss_fn(params, tuple(batch), CFG, task)
        assert np.isfinite(float(loss)), task


def test_lr_schedule_warmup_then_decay():
    from compile.train_step import lr_schedule

    lrs = [float(lr_schedule(jnp.int32(s), base_lr=1e-3, warmup=100)) for s in [0, 50, 98, 99, 400]]
    assert lrs[0] < lrs[1] < lrs[2]               # warmup rising
    assert abs(lrs[3] - 1e-3) < 1e-9              # peak at end of warmup
    assert lrs[4] < lrs[3]                        # decay after
