"""Dtype sweeps for the L1 kernel: the paper's TPU kernels run bf16 on
the MXU; the CPU artifacts use f32. Verify the kernel math is stable in
bf16/f16 too (python-side only — the 0.5.1 runtime is f32/i32)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import configs
from compile.kernels import bigbird, jnp_impl, ref


CFG = configs.tiny(heads=2, hidden=32)


def qkv(dtype, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda: jnp.asarray(rng.normal(size=(1, 2, CFG.seq_len, 16)).astype(np.float32)).astype(dtype)
    return mk(), mk(), mk()


@pytest.mark.parametrize("dtype,atol", [
    (jnp.bfloat16, 5e-2),
    (jnp.float16, 2e-2),
    (jnp.float32, 2e-5),
])
def test_jnp_impl_low_precision_close_to_f32_oracle(dtype, atol):
    q, k, v = qkv(dtype)
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    want = np.asarray(ref.bigbird_attention_ref(qf, kf, vf, CFG))
    got = np.asarray(
        jnp_impl.attention(q, k, v, CFG, impl="jnp").astype(jnp.float32)
    )
    np.testing.assert_allclose(got, want, atol=atol, rtol=atol)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_pallas_kernel_runs_in_low_precision(dtype):
    """The pallas kernel must trace + execute in bf16 (TPU's MXU dtype)."""
    q, k, v = qkv(dtype, seed=1)
    attend_idx, pad_valid, g_eff = jnp_impl.plan(CFG)
    out = bigbird.block_sparse_attention_pallas(
        q.astype(jnp.float32),  # compact gather happens in f32
        k.astype(jnp.float32),
        v.astype(jnp.float32),
        jnp.asarray(attend_idx),
        jnp.asarray(pad_valid),
        g_eff,
        CFG.block,
    )
    assert out.shape == (1, 2, CFG.seq_len, 16)
    assert bool(jnp.isfinite(out).all())


def test_softmax_stability_with_large_scores():
    """Max-subtraction must keep the kernel finite under extreme logits."""
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, 1, CFG.seq_len, 16)).astype(np.float32)) * 100.0
    k = jnp.asarray(rng.normal(size=(1, 1, CFG.seq_len, 16)).astype(np.float32)) * 100.0
    v = jnp.asarray(rng.normal(size=(1, 1, CFG.seq_len, 16)).astype(np.float32))
    c = CFG.replace(heads=1, hidden=16)
    for impl in ("jnp", "pallas"):
        out = jnp_impl.attention(q, k, v, c, impl=impl)
        assert bool(jnp.isfinite(out).all()), impl


def test_vmem_budget_across_block_sizes():
    """§Perf L1: the paper-scale kernel working set must fit VMEM for all
    block sizes we might tile with; utilization improves with block size."""
    a, d = 8, 64
    prev_u = 0.0
    for b in (16, 32, 64, 128):
        assert bigbird.vmem_bytes(b, a, d) < 16 * 2**20, b
        u = bigbird.mxu_utilization_estimate(b, a, d)
        assert u >= prev_u - 1e-9, f"utilization should not drop: b={b}"
        prev_u = u
    # at b=128 the matmuls are MXU-aligned
    assert bigbird.mxu_utilization_estimate(128, a, 128) == 1.0
