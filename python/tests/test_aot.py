"""AOT exporter tests: HLO text properties, manifest emission, build plan."""

import re

import jax
import jax.numpy as jnp
import pytest

from compile import aot, configs


def test_build_plan_names_unique_and_large():
    arts = aot.build_plan()
    names = [a.name for a in arts]
    assert len(names) == len(set(names))
    assert len(names) >= 90


def test_lowered_text_has_full_constants_and_no_metadata():
    # a function with a large embedded constant — the bug class we fixed:
    # default printing elides large constants and the 0.5.1 parser reads
    # garbage silently.
    big = jnp.asarray((jnp.arange(640) % 7).reshape(64, 10), jnp.int32)

    def fn(x):
        return (big + x,)

    text = aot.lower_to_hlo_text(fn, [jax.ShapeDtypeStruct((), jnp.int32)])
    assert "..." not in text, "large constant was elided"
    assert "source_end_line" not in text, "new metadata attrs break the 0.5.1 parser"
    # the constant payload must be printed
    assert text.count("constant(") >= 1
    assert "{ 0, 1, 2, 3, 4, 5, 6, 0" in text.replace("\n", " ")


def test_manifest_entry_format():
    arts = [a for a in aot.build_plan() if a.name == "train_mlm_bigbird_itc_s512_b4"]
    assert len(arts) == 1
    a = arts[0]
    out_shapes = jax.eval_shape(a.fn, *a.args)
    entry = aot.manifest_entry(a, out_shapes)
    assert entry.startswith("[artifact]\nname=train_mlm_bigbird_itc_s512_b4\n")
    assert "input=params:f32[" in entry
    assert "input=step:i32\n" in entry
    assert "output=loss:f32\n" in entry
    assert "meta=attn:bigbird_itc" in entry
    assert "meta=pattern:pattern_bigbird_itc_" in entry


def test_pattern_key_matches_dump_regex():
    cfg = configs.exp(batch=4)
    key = aot.pattern_key(cfg)
    m = re.match(r"pattern_(\w+)_nb(\d+)_g(\d+)_w(\d+)_r(\d+)_seed(\d+)\.txt", key)
    assert m, key
    assert m.group(1) == "bigbird_itc"
    assert int(m.group(2)) == cfg.num_blocks


def test_pattern_key_uses_internal_length_for_etc():
    cfg = configs.exp(batch=4, variant="bigbird_etc")
    key = aot.pattern_key(cfg)
    m = re.match(r"pattern_\w+_nb(\d+)_", key)
    # ETC grows the internal sequence by global_blocks blocks
    assert int(m.group(1)) == cfg.num_blocks + cfg.global_blocks


def test_hlo_stats_histogram():
    def fn(x):
        return (jnp.tanh(x) @ jnp.ones((4, 4), jnp.float32),)

    text = aot.lower_to_hlo_text(fn, [jax.ShapeDtypeStruct((4, 4), jnp.float32)])
    ops = aot.hlo_stats(text)
    assert ops.get("tanh", 0) >= 1
    assert ops.get("dot", 0) >= 1


def test_task1_artifacts_mask_is_sparse():
    arts = aot.task1_artifacts()  # default n=256, d=32 (block 16 ⇒ 16 blocks)
    assert [a.name for a in arts] == ["task1_dense", "task1_sparse"]
    # run both in python: sparse output should differ from dense output
    import numpy as np

    rng = np.random.default_rng(0)
    u = rng.normal(size=(1, 256, 32)).astype(np.float32)
    u /= np.linalg.norm(u, axis=-1, keepdims=True)
    dense_out = np.asarray(arts[0].fn(jnp.asarray(u))[0])
    sparse_out = np.asarray(arts[1].fn(jnp.asarray(u))[0])
    assert not np.allclose(dense_out, sparse_out, atol=1e-3)
