"""Seq2seq (summarization) model tests."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import configs, seq2seq


CFG = configs.tiny(seq_len=64, batch=2, layers=1, block=8)
DEC = 16


def test_forward_shape_and_finite():
    params = seq2seq.init_seq2seq(jax.random.PRNGKey(0), CFG, DEC)
    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.integers(6, CFG.vocab, size=(2, 64)), jnp.int32)
    valid = jnp.ones((2, 64), jnp.float32)
    dec = jnp.asarray(rng.integers(6, CFG.vocab, size=(2, DEC)), jnp.int32)
    logits = seq2seq.s2s_forward(params, src, valid, dec, CFG)
    assert logits.shape == (2, DEC, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_decoder_is_causal():
    """Changing decoder token t must not change logits at positions < t."""
    params = seq2seq.init_seq2seq(jax.random.PRNGKey(0), CFG, DEC)
    rng = np.random.default_rng(1)
    src = jnp.asarray(rng.integers(6, CFG.vocab, size=(2, 64)), jnp.int32)
    valid = jnp.ones((2, 64), jnp.float32)
    dec = np.asarray(rng.integers(6, CFG.vocab, size=(2, DEC)), np.int32)
    l1 = seq2seq.s2s_forward(params, src, valid, jnp.asarray(dec), CFG)
    dec2 = dec.copy()
    dec2[:, 10] = 9
    l2 = seq2seq.s2s_forward(params, src, valid, jnp.asarray(dec2), CFG)
    np.testing.assert_allclose(
        np.asarray(l1)[:, :10], np.asarray(l2)[:, :10], atol=2e-5
    )
    # ...and DOES change at ≥ t (sanity that the perturbation matters)
    assert not np.allclose(np.asarray(l1)[:, 10:], np.asarray(l2)[:, 10:], atol=1e-3)


def test_s2s_train_step_decreases_loss():
    step_fn, n = seq2seq.make_s2s_train_step(CFG, DEC, base_lr=1e-2, warmup=5)
    init_fn = seq2seq.make_s2s_init(CFG, DEC)
    flat = jax.jit(init_fn)()
    assert flat.shape == (n,)
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.integers(6, CFG.vocab, size=(2, 64)), jnp.int32)
    valid = jnp.ones((2, 64), jnp.float32)
    dec_in = jnp.asarray(rng.integers(6, CFG.vocab, size=(2, DEC)), jnp.int32)
    dec_out = jnp.asarray(rng.integers(6, CFG.vocab, size=(2, DEC)), jnp.int32)
    w = jnp.ones((2, DEC), jnp.float32)
    sj = jax.jit(step_fn)
    losses = []
    for i in range(10):
        flat, m, v, loss = sj(flat, m, v, jnp.int32(i), src, valid, dec_in, dec_out, w)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_cross_attention_ignores_padded_source():
    params = seq2seq.init_seq2seq(jax.random.PRNGKey(0), CFG, DEC)
    rng = np.random.default_rng(2)
    src = np.asarray(rng.integers(6, CFG.vocab, size=(2, 64)), np.int32)
    valid = np.ones((2, 64), np.float32)
    valid[:, 32:] = 0.0
    dec = jnp.asarray(rng.integers(6, CFG.vocab, size=(2, DEC)), jnp.int32)
    l1 = seq2seq.s2s_forward(params, jnp.asarray(src), jnp.asarray(valid), dec, CFG)
    src2 = src.copy()
    src2[:, 32:] = 11
    l2 = seq2seq.s2s_forward(params, jnp.asarray(src2), jnp.asarray(valid), dec, CFG)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-4)
