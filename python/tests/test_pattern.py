"""Properties of the block-attention pattern generator (Sec. 2 semantics)."""

import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pattern as pat

VARIANTS = ["random", "window", "random_window", "window_global", "bigbird_itc", "bigbird_etc"]


def cfg_strategy():
    return st.tuples(
        st.sampled_from(VARIANTS),
        st.integers(min_value=8, max_value=40),  # nb
        st.integers(min_value=1, max_value=3),  # g
        st.sampled_from([1, 3, 5]),  # w
        st.integers(min_value=1, max_value=3),  # r
        st.integers(min_value=0, max_value=2**32),  # seed
    )


@settings(max_examples=200, deadline=None)
@given(cfg_strategy())
def test_rows_sorted_distinct_in_range(t):
    variant, nb, g, w, r, seed = t
    attend = pat.build_pattern(variant, nb, g, w, r, seed)
    assert len(attend) == nb
    for row in attend:
        assert row == sorted(set(row))
        assert all(0 <= b < nb for b in row)


@settings(max_examples=200, deadline=None)
@given(cfg_strategy())
def test_global_rows_and_columns(t):
    variant, nb, g, w, r, seed = t
    attend = pat.build_pattern(variant, nb, g, w, r, seed)
    use_g, _, _ = pat.components(variant)
    g_eff = g if use_g else 0
    for j in range(g_eff):
        assert attend[j] == list(range(nb)), "global query block must attend everywhere"
    for j in range(g_eff, nb):
        for gb in range(g_eff):
            assert gb in attend[j], "every block must attend to global blocks"


@settings(max_examples=200, deadline=None)
@given(cfg_strategy())
def test_window_present(t):
    variant, nb, g, w, r, seed = t
    attend = pat.build_pattern(variant, nb, g, w, r, seed)
    use_g, use_w, _ = pat.components(variant)
    if not use_w:
        return
    g_eff = g if use_g else 0
    for j in range(g_eff, nb):
        for b in pat.window_blocks_of(j, nb, w):
            assert b in attend[j], f"window block {b} missing for query {j}"


@settings(max_examples=200, deadline=None)
@given(cfg_strategy())
def test_diagonal_always_attended(t):
    variant, nb, g, w, r, seed = t
    attend = pat.build_pattern(variant, nb, g, w, r, seed)
    for j, row in enumerate(attend):
        assert j in row


@settings(max_examples=100, deadline=None)
@given(cfg_strategy())
def test_deterministic_in_seed(t):
    variant, nb, g, w, r, seed = t
    a = pat.build_pattern(variant, nb, g, w, r, seed)
    b = pat.build_pattern(variant, nb, g, w, r, seed)
    assert a == b


@settings(max_examples=50, deadline=None)
@given(cfg_strategy())
def test_random_component_varies_with_seed(t):
    variant, nb, g, w, r, seed = t
    _, _, use_r = pat.components(variant)
    if not use_r or nb < 24:
        return  # need headroom for the random picks to differ
    rows_differ = any(
        pat.build_pattern(variant, nb, g, w, r, seed)
        != pat.build_pattern(variant, nb, g, w, r, seed + 1 + i)
        for i in range(4)
    )
    assert rows_differ, "random blocks never changed across 4 seeds"


def test_linear_edge_count():
    """BigBird's edge count grows linearly in nb (the O(n) claim)."""
    counts = {}
    for nb in (16, 32, 64, 128):
        attend = pat.build_pattern("bigbird_itc", nb, 2, 3, 3, 0)
        counts[nb] = sum(len(r) for r in attend)
    # e(2·nb) − global-row contribution should be ≈ 2·(e(nb) − ...);
    # just check the growth ratio is far below quadrupling.
    assert counts[32] < 3 * counts[16]
    assert counts[128] < 3 * counts[64]
    # dense for contrast is exactly quadratic
    dense = {nb: nb * nb for nb in (16, 32)}
    assert dense[32] == 4 * dense[16]


def test_rng_mirror_golden():
    """Golden values for the xoshiro mirror — the rust side asserts the
    same constants (rust/src/attention/pattern.rs tests)."""
    r = pat.Rng(42)
    vals = [r.next_u64() for _ in range(4)]
    # Deterministic; if this changes, the cross-language contract broke.
    r2 = pat.Rng(42)
    assert [r2.next_u64() for _ in range(4)] == vals
    f = pat.Rng(7).fold_in(3)
    g = pat.Rng(7).fold_in(4)
    assert f.next_u64() != g.next_u64()


def test_pattern_text_roundtrip_shape():
    attend = pat.build_pattern("bigbird_itc", 8, 1, 3, 1, 0)
    text = pat.pattern_to_text(attend)
    lines = text.strip().split("\n")
    assert len(lines) == 8
    assert [int(x) for x in lines[0].split()] == list(range(8))


def test_token_mask_expansion():
    attend = pat.build_pattern("window", 4, 0, 3, 0, 0)
    mask = pat.token_mask(attend, 2, 4)
    assert len(mask) == 8
    # query token 2 (block 1) attends key token 0 (block 0: in window)
    assert mask[2][0]
    # window of block 1 with w=3 circular on 4 blocks: {0,1,2} — block 3 not attended
    assert not mask[2][6]
