"""Layer-2 task models: encoder + head, loss functions, parameter ravel.

Every entry here is a pure function of (flat_params, batch...) so the AOT
exporter can lower it directly; ``jax.flatten_util.ravel_pytree`` gives a
single f32 parameter vector, which is what the Rust training driver owns
and checkpoints.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from . import layers

TASKS = ("mlm", "cls", "qa", "multilabel")


def init_task_params(key, cfg, task: str):
    """Nested param dict for encoder + task head."""
    k_enc, k_head = jax.random.split(key)
    params = {"encoder": layers.init_encoder(k_enc, cfg)}
    if task == "mlm":
        params["head"] = layers.init_mlm_head(k_head, cfg)
    elif task == "cls":
        params["head"] = layers.init_cls_head(k_head, cfg)
    elif task == "qa":
        params["head"] = layers.init_qa_head(k_head, cfg)
    elif task == "multilabel":
        params["head"] = layers.init_multilabel_head(k_head, cfg)
    else:
        raise ValueError(task)
    return params


def raveler(cfg, task: str):
    """(example_params, unravel_fn, param_count) for a config+task."""
    params = init_task_params(jax.random.PRNGKey(0), cfg, task)
    flat, unravel = ravel_pytree(params)
    return params, unravel, flat.shape[0]


def forward(params, tokens, kv_valid, cfg, task: str, impl="jnp"):
    """Task logits.

    mlm → (B, S, V); cls → (B, C); qa → (B, S, 2); multilabel → (B, P).
    """
    h = layers.encoder(params["encoder"], tokens, kv_valid, cfg, impl=impl)
    if task == "mlm":
        return layers.mlm_logits(params["head"], h)
    if task == "cls":
        return layers.cls_logits(params["head"], h)
    if task == "qa":
        return layers.qa_logits(params["head"], h, kv_valid)
    if task == "multilabel":
        return layers.multilabel_logits(params["head"], h)
    raise ValueError(task)


def loss_fn(params, batch, cfg, task: str, impl="jnp"):
    """Scalar training loss for one batch.

    Batch layouts (all i32 unless noted):
      mlm:        (tokens, kv_valid f32, labels, weights f32)
      cls:        (tokens, kv_valid f32, label (B,))
      qa:         (tokens, kv_valid f32, starts (B,), ends (B,))
      multilabel: (tokens, kv_valid f32, labels f32 (B, P))
    """
    tokens, kv_valid = batch[0], batch[1]
    logits = forward(params, tokens, kv_valid, cfg, task, impl=impl)
    if task == "mlm":
        labels, weights = batch[2], batch[3]
        return layers.softmax_xent(logits, labels, weights)
    if task == "cls":
        return layers.cls_xent(logits, batch[2])
    if task == "qa":
        return layers.qa_span_loss(logits, batch[2], batch[3])
    if task == "multilabel":
        return layers.bce_multilabel(logits, batch[2], pos_weight=8.0)
    raise ValueError(task)


def batch_specs(cfg, task: str):
    """jax.ShapeDtypeStruct list describing one batch, and manifest type
    strings — shared by the exporter and (via the manifest) the Rust
    data pipeline."""
    B, S = cfg.batch, cfg.seq_len
    i32, f32 = jnp.int32, jnp.float32
    sds = jax.ShapeDtypeStruct
    if task == "mlm":
        return (
            [sds((B, S), i32), sds((B, S), f32), sds((B, S), i32), sds((B, S), f32)],
            ["tokens:i32", "kv_valid:f32", "labels:i32", "weights:f32"],
        )
    if task == "cls":
        return (
            [sds((B, S), i32), sds((B, S), f32), sds((B,), i32)],
            ["tokens:i32", "kv_valid:f32", "label:i32"],
        )
    if task == "qa":
        return (
            [sds((B, S), i32), sds((B, S), f32), sds((B,), i32), sds((B,), i32)],
            ["tokens:i32", "kv_valid:f32", "starts:i32", "ends:i32"],
        )
    if task == "multilabel":
        return (
            [
                sds((B, S), i32),
                sds((B, S), f32),
                sds((B, cfg.num_profiles), f32),
            ],
            ["tokens:i32", "kv_valid:f32", "labels:f32"],
        )
    raise ValueError(task)


def logits_spec(cfg, task: str):
    """Output logits shape for the fwd artifact manifest entry."""
    B, S = cfg.batch, cfg.seq_len
    if task == "mlm":
        return (B, S, cfg.vocab)
    if task == "cls":
        return (B, cfg.num_classes)
    if task == "qa":
        return (B, S, 2)
    if task == "multilabel":
        return (B, cfg.num_profiles)
    raise ValueError(task)
