"""Model configurations shared with the Rust side.

The canonical hyperparameters mirror ``rust/src/config/mod.rs``; the
artifact manifest is the enforcement mechanism (rust integration tests
check that the artifacts it finds match these configs).
"""

from __future__ import annotations

import dataclasses

ATTN_VARIANTS = (
    "dense",
    "random",
    "window",
    "random_window",
    "window_global",  # ≈ Longformer's pattern (App. E.3 comparison rows)
    "bigbird_itc",
    "bigbird_etc",
)


@dataclasses.dataclass(frozen=True)
class Config:
    """BigBird hyperparameters (paper App. E.1 Tab. 8, scaled down)."""

    variant: str = "bigbird_itc"
    seq_len: int = 512
    block: int = 16
    global_blocks: int = 2
    window_blocks: int = 3  # odd; paper uses 3
    random_blocks: int = 3
    layers: int = 4
    heads: int = 4
    hidden: int = 128
    ffn: int = 512
    vocab: int = 2048
    batch: int = 8
    attn_seed: int = 0
    # number of output classes / labels for the task heads
    num_classes: int = 4
    num_profiles: int = 16

    def __post_init__(self):
        assert self.variant in ATTN_VARIANTS, self.variant
        assert self.seq_len % self.block == 0, (self.seq_len, self.block)
        assert self.window_blocks % 2 == 1, self.window_blocks
        assert self.hidden % self.heads == 0, (self.hidden, self.heads)
        nb = self.num_blocks
        assert self.global_blocks + self.window_blocks + self.random_blocks <= nb, (
            "attention pattern larger than sequence",
            self,
        )

    @property
    def num_blocks(self) -> int:
        return self.seq_len // self.block

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    def artifact_name(self, kind: str) -> str:
        """Matches ModelConfig::artifact_name on the rust side."""
        return f"{kind}_{self.variant}_s{self.seq_len}_b{self.batch}"

    def replace(self, **kw) -> "Config":
        return dataclasses.replace(self, **kw)


def tiny(**kw) -> Config:
    """Unit-test scale. Mirrors ModelConfig::tiny()."""
    base = Config(
        variant="bigbird_itc",
        seq_len=128,
        block=16,
        global_blocks=1,
        window_blocks=3,
        random_blocks=1,
        layers=2,
        heads=2,
        hidden=64,
        ffn=128,
        vocab=512,
        batch=4,
    )
    return base.replace(**kw)


def exp(**kw) -> Config:
    """Experiment-table scale: small model, long sequences."""
    base = Config(
        variant="bigbird_itc",
        seq_len=512,
        block=16,
        global_blocks=2,
        window_blocks=3,
        random_blocks=3,
        layers=2,
        heads=2,
        hidden=64,
        ffn=256,
        vocab=512,
        batch=8,
    )
    return base.replace(**kw)


def base(**kw) -> Config:
    """End-to-end example scale. Mirrors ModelConfig::base()."""
    b = Config()
    return b.replace(**kw)
