"""L1 kernel §Perf report: VMEM footprint + MXU utilization estimates.

``python -m compile.kernel_report``

interpret=True gives CPU-numpy timings only (not a TPU proxy), so the
TPU-facing performance story is *structural*: per-program VMEM working
set and MXU-lane utilization as a function of the tile geometry. This
report generates the numbers recorded in EXPERIMENTS.md §Perf (L1).
"""

from __future__ import annotations

from .kernels.bigbird import mxu_utilization_estimate, vmem_bytes


def report():
    rows = []
    # (label, block, attended blocks A = g+w+r, head_dim)
    cases = [
        ("ours tiny (b=16, A=5, d=32)", 16, 5, 32),
        ("ours exp (b=16, A=8, d=32)", 16, 8, 32),
        ("ours bench (b=32, A=8, d=32)", 32, 8, 32),
        ("paper base (b=64, A=8, d=64)", 64, 8, 64),
        ("paper ETC-large (b=169, A=8, d=64)", 169, 8, 64),
        ("MXU-aligned (b=128, A=8, d=128)", 128, 8, 128),
    ]
    print(f"{'config':<36}{'VMEM/program':>14}{'of 16MiB':>10}{'MXU util':>10}")
    for label, b, a, d in cases:
        vm = vmem_bytes(b, a, d)
        u = mxu_utilization_estimate(b, a, d)
        rows.append((label, vm, u))
        print(f"{label:<36}{vm/1024:>11.1f}KiB{100*vm/(16*2**20):>9.2f}%{100*u:>9.1f}%")
    print()
    print("roofline note: at the paper's base geometry the two kernel matmuls")
    print("are (64×64)·(64×512) and (64×512)·(512×64) — K and N pad cleanly")
    print("onto the 128×128 systolic array; the M=64 query-block dimension is")
    print("the only under-filled axis (50%), which the TPU pipelines across")
    print("the (head, query-block) grid. Structural ceiling ≈ the estimate.")
    return rows


if __name__ == "__main__":
    report()
