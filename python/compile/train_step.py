"""Adam training step, lowered as a single AOT program.

The Rust driver owns three flat f32 vectors (params, m, v) plus an i32
step counter; one call to the exported program performs forward, backward
and the optimizer update and returns the new state plus the scalar loss.
Nothing about optimisation lives in Rust — it only moves host tensors.

Learning-rate schedule: linear warmup then inverse-sqrt decay (the
paper's pretraining recipe, App. E.1), baked into the program as a
function of the step input.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from . import model


def lr_schedule(step, base_lr=1e-3, warmup=100):
    """Linear warmup → inverse-sqrt decay (paper App. E.1 shape)."""
    step = step.astype(jnp.float32) + 1.0
    w = jnp.float32(warmup)
    return base_lr * jnp.minimum(step / w, jnp.sqrt(w / step))


def make_train_step(cfg, task: str, impl="jnp", base_lr=1e-3, warmup=100):
    """Returns ``(train_step, n_params)``.

    ``train_step(flat_params, m, v, step, *batch)``
      → ``(flat_params', m', v', loss)`` with Adam(β1=.9, β2=.999, ε=1e-8)
    """
    _, unravel, n = model.raveler(cfg, task)
    b1, b2, eps = 0.9, 0.999, 1e-8

    def loss_flat(flat, *batch):
        return model.loss_fn(unravel(flat), batch, cfg, task, impl=impl)

    def train_step(flat, m, v, step, *batch):
        loss, g = jax.value_and_grad(loss_flat)(flat, *batch)
        m2 = b1 * m + (1.0 - b1) * g
        v2 = b2 * v + (1.0 - b2) * g * g
        t = step.astype(jnp.float32) + 1.0
        mhat = m2 / (1.0 - b1**t)
        vhat = v2 / (1.0 - b2**t)
        lr = lr_schedule(step, base_lr, warmup)
        flat2 = flat - lr * mhat / (jnp.sqrt(vhat) + eps)
        return flat2, m2, v2, loss

    return train_step, n


def make_eval_loss(cfg, task: str, impl="jnp"):
    """``eval_loss(flat_params, *batch) -> loss`` (no update)."""
    _, unravel, n = model.raveler(cfg, task)

    def eval_loss(flat, *batch):
        return model.loss_fn(unravel(flat), batch, cfg, task, impl=impl)

    return eval_loss, n


def make_forward(cfg, task: str, impl="jnp"):
    """``fwd(flat_params, tokens, kv_valid) -> logits``."""
    _, unravel, n = model.raveler(cfg, task)

    def fwd(flat, tokens, kv_valid):
        return model.forward(unravel(flat), tokens, kv_valid, cfg, task, impl=impl)

    return fwd, n


def make_init(cfg, task: str, seed: int = 0):
    """``init() -> flat_params`` with the seed baked in."""
    _, unravel, n = model.raveler(cfg, task)

    def init():
        params = model.init_task_params(jax.random.PRNGKey(seed), cfg, task)
        flat, _ = ravel_pytree(params)
        return flat

    return init, n
