"""Encoder-decoder model for long-document summarization (Sec. 4.1).

Exactly the paper's arrangement: **sparse BigBird attention on the
encoder only**, full attention on the (short) decoder — "the length of
output sequence is typically small as compared to the input". The decoder
is a standard causal transformer with cross-attention to the encoder
states.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from . import layers

NEG_INF = -1e9


def init_decoder_layer(key, cfg):
    ks = jax.random.split(key, 9)
    h = cfg.hidden
    return {
        "wq": layers._dense_init(ks[0], h, h),
        "wk": layers._dense_init(ks[1], h, h),
        "wv": layers._dense_init(ks[2], h, h),
        "wo": layers._dense_init(ks[3], h, h),
        "cq": layers._dense_init(ks[4], h, h),
        "ck": layers._dense_init(ks[5], h, h),
        "cv": layers._dense_init(ks[6], h, h),
        "co": layers._dense_init(ks[7], h, h),
        "w1": layers._dense_init(ks[8], h, cfg.ffn),
        "b1": jnp.zeros((cfg.ffn,), jnp.float32),
        "w2": layers._dense_init(jax.random.fold_in(key, 99), cfg.ffn, h),
        "b2": jnp.zeros((h,), jnp.float32),
        "ln1_g": jnp.ones((h,), jnp.float32),
        "ln1_b": jnp.zeros((h,), jnp.float32),
        "ln2_g": jnp.ones((h,), jnp.float32),
        "ln2_b": jnp.zeros((h,), jnp.float32),
        "ln3_g": jnp.ones((h,), jnp.float32),
        "ln3_b": jnp.zeros((h,), jnp.float32),
    }


def init_seq2seq(key, cfg, dec_len: int):
    k_enc, k_dec, k_emb, k_out = jax.random.split(key, 4)
    dec_keys = jax.random.split(k_dec, cfg.layers)
    return {
        "encoder": layers.init_encoder(k_enc, cfg),
        "dec_pos": jax.random.normal(k_emb, (dec_len, cfg.hidden), jnp.float32) * 0.02,
        "dec_layers": [init_decoder_layer(k, cfg) for k in dec_keys],
        "out_w": layers._dense_init(k_out, cfg.hidden, cfg.vocab),
        "out_b": jnp.zeros((cfg.vocab,), jnp.float32),
        "ln_f_g": jnp.ones((cfg.hidden,), jnp.float32),
        "ln_f_b": jnp.zeros((cfg.hidden,), jnp.float32),
    }


def _mha(q, k, v, heads, mask=None):
    """(B, Nq, H) x (B, Nk, H) dense multi-head attention."""
    bsz, nq, h = q.shape
    nk = k.shape[1]
    d = h // heads
    qh = q.reshape(bsz, nq, heads, d).transpose(0, 2, 1, 3)
    kh = k.reshape(bsz, nk, heads, d).transpose(0, 2, 1, 3)
    vh = v.reshape(bsz, nk, heads, d).transpose(0, 2, 1, 3)
    s = jnp.einsum("bhnd,bhmd->bhnm", qh, kh) / jnp.sqrt(jnp.float32(d))
    if mask is not None:
        s = s + mask
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhnm,bhmd->bhnd", p, vh)
    return o.transpose(0, 2, 1, 3).reshape(bsz, nq, h)


def decoder(params, enc_h, enc_valid, dec_tokens, cfg):
    """Teacher-forced decoder. dec_tokens (B, T) → logits (B, T, V).

    Token embeddings are shared with the encoder's table.
    """
    tok_emb = params["encoder"]["tok_emb"]
    x = tok_emb[dec_tokens] + params["dec_pos"][None, : dec_tokens.shape[1], :]
    t = x.shape[1]
    causal = jnp.where(
        jnp.arange(t)[:, None] >= jnp.arange(t)[None, :], 0.0, NEG_INF
    )[None, None, :, :]
    cross_mask = ((1.0 - enc_valid) * NEG_INF)[:, None, None, :]
    for p in params["dec_layers"]:
        a = _mha(x @ p["wq"], x @ p["wk"], x @ p["wv"], cfg.heads, causal)
        x = layers.layer_norm(x + a @ p["wo"], p["ln1_g"], p["ln1_b"])
        c = _mha(x @ p["cq"], enc_h @ p["ck"], enc_h @ p["cv"], cfg.heads, cross_mask)
        x = layers.layer_norm(x + c @ p["co"], p["ln2_g"], p["ln2_b"])
        f = layers.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]
        x = layers.layer_norm(x + f, p["ln3_g"], p["ln3_b"])
    x = layers.layer_norm(x, params["ln_f_g"], params["ln_f_b"])
    return x @ params["out_w"] + params["out_b"]


def s2s_forward(params, src_tokens, src_valid, dec_tokens, cfg, impl="jnp"):
    enc_h = layers.encoder(params["encoder"], src_tokens, src_valid, cfg, impl=impl)
    return decoder(params, enc_h, src_valid, dec_tokens, cfg)


def s2s_loss(params, batch, cfg, impl="jnp"):
    """Teacher forcing: predict dec_out from dec_in.

    batch = (src_tokens, src_valid, dec_in, dec_out, dec_weights)
    """
    src, valid, dec_in, dec_out, w = batch
    logits = s2s_forward(params, src, valid, dec_in, cfg, impl=impl)
    return layers.softmax_xent(logits, dec_out, w)


def make_s2s_train_step(cfg, dec_len: int, impl="jnp", base_lr=1e-3, warmup=100):
    """Adam step over the seq2seq params; same contract as
    train_step.make_train_step."""
    params0 = init_seq2seq(jax.random.PRNGKey(0), cfg, dec_len)
    flat0, unravel = ravel_pytree(params0)
    n = flat0.shape[0]
    b1, b2, eps = 0.9, 0.999, 1e-8

    def loss_flat(flat, *batch):
        return s2s_loss(unravel(flat), batch, cfg, impl=impl)

    def step_fn(flat, m, v, step, *batch):
        loss, g = jax.value_and_grad(loss_flat)(flat, *batch)
        m2 = b1 * m + (1.0 - b1) * g
        v2 = b2 * v + (1.0 - b2) * g * g
        t = step.astype(jnp.float32) + 1.0
        mhat = m2 / (1.0 - b1**t)
        vhat = v2 / (1.0 - b2**t)
        sf = step.astype(jnp.float32) + 1.0
        w = jnp.float32(warmup)
        lr = base_lr * jnp.minimum(sf / w, jnp.sqrt(w / sf))
        flat2 = flat - lr * mhat / (jnp.sqrt(vhat) + eps)
        return flat2, m2, v2, loss

    return step_fn, n


def make_s2s_decode(cfg, dec_len: int, impl="jnp"):
    """``decode(flat, src, valid, dec_tokens) -> logits (B, T, V)``.

    Greedy decoding lives in Rust: it feeds the partial hypothesis back in
    (positions ≥ current step are padding id 0) and reads the next-token
    logits from the returned full-sequence logits.
    """
    params0 = init_seq2seq(jax.random.PRNGKey(0), cfg, dec_len)
    _, unravel = ravel_pytree(params0)

    def decode(flat, src, valid, dec_tokens):
        return s2s_forward(unravel(flat), src, valid, dec_tokens, cfg, impl=impl)

    return decode


def make_s2s_init(cfg, dec_len: int, seed: int = 0):
    params0 = init_seq2seq(jax.random.PRNGKey(0), cfg, dec_len)
    _, unravel = ravel_pytree(params0)

    def init():
        params = init_seq2seq(jax.random.PRNGKey(seed), cfg, dec_len)
        flat, _ = ravel_pytree(params)
        return flat

    return init
