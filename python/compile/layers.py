"""Layer-2 building blocks: embeddings, BigBird encoder layers, heads.

Functional JAX (no flax): parameters are nested dicts of jnp arrays,
initialised by ``init_*`` functions and threaded explicitly. Every
attention call routes through ``kernels.jnp_impl.attention`` which
dispatches to the Pallas kernel (L1) or its jnp formulation.

ETC handling: for ``bigbird_etc`` the model *prepends* ``g·b`` learned
global tokens to the sequence before blockification (App. D / Sec. 2
"extended transformer construction") and strips them before the heads, so
task code never sees them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import jnp_impl

NEG_INF = -1e9


# --------------------------------------------------------------------------
# initialisation
# --------------------------------------------------------------------------


def _dense_init(key, fan_in, fan_out):
    scale = (2.0 / (fan_in + fan_out)) ** 0.5
    return jax.random.normal(key, (fan_in, fan_out), jnp.float32) * scale


def init_layer(key, cfg):
    """One transformer layer's parameters."""
    ks = jax.random.split(key, 6)
    h = cfg.hidden
    return {
        "wq": _dense_init(ks[0], h, h),
        "wk": _dense_init(ks[1], h, h),
        "wv": _dense_init(ks[2], h, h),
        "wo": _dense_init(ks[3], h, h),
        "w1": _dense_init(ks[4], h, cfg.ffn),
        "b1": jnp.zeros((cfg.ffn,), jnp.float32),
        "w2": _dense_init(ks[5], cfg.ffn, h),
        "b2": jnp.zeros((h,), jnp.float32),
        "ln1_g": jnp.ones((h,), jnp.float32),
        "ln1_b": jnp.zeros((h,), jnp.float32),
        "ln2_g": jnp.ones((h,), jnp.float32),
        "ln2_b": jnp.zeros((h,), jnp.float32),
    }


def init_encoder(key, cfg):
    """Embeddings + all layers (+ ETC global token embeddings)."""
    keys = jax.random.split(key, cfg.layers + 3)
    params = {
        "tok_emb": jax.random.normal(keys[0], (cfg.vocab, cfg.hidden), jnp.float32) * 0.02,
        "pos_emb": jax.random.normal(keys[1], (internal_len(cfg), cfg.hidden), jnp.float32)
        * 0.02,
        "layers": [init_layer(keys[2 + i], cfg) for i in range(cfg.layers)],
        "ln_f_g": jnp.ones((cfg.hidden,), jnp.float32),
        "ln_f_b": jnp.zeros((cfg.hidden,), jnp.float32),
    }
    if cfg.variant == "bigbird_etc":
        params["global_emb"] = (
            jax.random.normal(keys[-1], (cfg.global_blocks * cfg.block, cfg.hidden), jnp.float32)
            * 0.02
        )
    return params


def internal_len(cfg) -> int:
    """Sequence length inside the encoder (ETC prepends global tokens)."""
    if cfg.variant == "bigbird_etc":
        return cfg.seq_len + cfg.global_blocks * cfg.block
    return cfg.seq_len


def internal_cfg(cfg):
    """Attention config on the internal sequence (ETC grows nb by g)."""
    if cfg.variant == "bigbird_etc":
        return cfg.replace(seq_len=internal_len(cfg))
    return cfg


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


def layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


def encoder_layer(p, x, kv_valid, cfg, impl):
    """Post-LN transformer layer with BigBird attention."""
    bsz, n, h = x.shape
    heads, d = cfg.heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(bsz, n, heads, d).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(bsz, n, heads, d).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(bsz, n, heads, d).transpose(0, 2, 1, 3)
    a = jnp_impl.attention(q, k, v, cfg, kv_valid, impl=impl)
    a = a.transpose(0, 2, 1, 3).reshape(bsz, n, h)
    x = layer_norm(x + a @ p["wo"], p["ln1_g"], p["ln1_b"])
    f = gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]
    return layer_norm(x + f, p["ln2_g"], p["ln2_b"])


def encoder(params, tokens, kv_valid, cfg, impl="jnp"):
    """Full encoder: embeddings → L layers → final LN.

    Args:
      tokens: (B, S) int32
      kv_valid: (B, S) float 1/0 padding mask (1 = real token)
    Returns: (B, S, H) hidden states on the *task* sequence (ETC global
      prefix stripped).
    """
    icfg = internal_cfg(cfg)
    x = params["tok_emb"][tokens]  # (B, S, H)
    bsz = x.shape[0]
    if cfg.variant == "bigbird_etc":
        gtok = jnp.broadcast_to(
            params["global_emb"][None, :, :],
            (bsz,) + params["global_emb"].shape,
        )
        x = jnp.concatenate([gtok, x], axis=1)
        kv_valid = jnp.concatenate(
            [jnp.ones((bsz, gtok.shape[1]), jnp.float32), kv_valid], axis=1
        )
    x = x + params["pos_emb"][None, : x.shape[1], :]
    for p in params["layers"]:
        x = encoder_layer(p, x, kv_valid, icfg, impl)
    x = layer_norm(x, params["ln_f_g"], params["ln_f_b"])
    if cfg.variant == "bigbird_etc":
        x = x[:, cfg.global_blocks * cfg.block :, :]
    return x


# --------------------------------------------------------------------------
# task heads
# --------------------------------------------------------------------------


def init_mlm_head(key, cfg):
    return {
        "w": _dense_init(key, cfg.hidden, cfg.vocab),
        "b": jnp.zeros((cfg.vocab,), jnp.float32),
    }


def mlm_logits(head, hidden):
    return hidden @ head["w"] + head["b"]


def init_cls_head(key, cfg, num_classes=None):
    k1, k2 = jax.random.split(key)
    n = num_classes or cfg.num_classes
    return {
        "wp": _dense_init(k1, cfg.hidden, cfg.hidden),
        "bp": jnp.zeros((cfg.hidden,), jnp.float32),
        "wc": _dense_init(k2, cfg.hidden, n),
        "bc": jnp.zeros((n,), jnp.float32),
    }


def cls_logits(head, hidden):
    """BERT-style: tanh pooling on the first ([CLS]) token."""
    pooled = jnp.tanh(hidden[:, 0, :] @ head["wp"] + head["bp"])
    return pooled @ head["wc"] + head["bc"]


def init_qa_head(key, cfg):
    return {
        "w": _dense_init(key, cfg.hidden, 2),
        "b": jnp.zeros((2,), jnp.float32),
    }


def qa_logits(head, hidden, kv_valid):
    """Span start/end logits, padding masked to −∞. Returns (B, S, 2)."""
    logits = hidden @ head["w"] + head["b"]
    return logits + (1.0 - kv_valid)[:, :, None] * NEG_INF


def init_multilabel_head(key, cfg, num_profiles=None):
    k1, k2 = jax.random.split(key)
    n = num_profiles or cfg.num_profiles
    return {
        "wp": _dense_init(k1, cfg.hidden, cfg.hidden),
        "bp": jnp.zeros((cfg.hidden,), jnp.float32),
        "wc": _dense_init(k2, cfg.hidden, n),
        "bc": jnp.zeros((n,), jnp.float32),
    }


def multilabel_logits(head, hidden):
    """919-profile-style multi-label head on the CLS token (App. F.3)."""
    pooled = jnp.tanh(hidden[:, 0, :] @ head["wp"] + head["bp"])
    return pooled @ head["wc"] + head["bc"]


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------


def softmax_xent(logits, labels, weights):
    """Weighted token-level cross entropy.

    logits (B, S, V), labels (B, S) int32, weights (B, S) float.
    Returns scalar mean over weighted positions.
    """
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - ll) * weights
    return nll.sum() / jnp.maximum(weights.sum(), 1.0)


def cls_xent(logits, labels):
    """(B, C) logits vs (B,) int labels."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return (logz - ll).mean()


def qa_span_loss(logits, starts, ends):
    """Sum of start and end cross entropies; logits (B, S, 2)."""
    return cls_xent(logits[:, :, 0], starts) + cls_xent(logits[:, :, 1], ends)


def bce_multilabel(logits, labels, pos_weight=1.0):
    """Binary cross entropy with positive upweighting (App. F.3 uses 8×)."""
    logp = jax.nn.log_sigmoid(logits)
    lognp = jax.nn.log_sigmoid(-logits)
    loss = -(pos_weight * labels * logp + (1.0 - labels) * lognp)
    return loss.mean()
