"""BigBird block-sparse attention as compact dense tensor algebra.

This is the paper's App.-D formulation, verbatim in jnp:

1. blockify Q, K, V into ``(nb, b, d)``,
2. gather each query block's attended key blocks into a compact
   ``K'' : (nb, A·b, d)`` (window blocks come from the rolled-copy trick,
   random + global blocks from a gather — all folded into one take here),
3. one dense batched matmul ``(nb, b, d) × (nb, d, A·b)`` for the scores,
   masked softmax, and a second batched matmul for the output,
4. global *query* blocks are overwritten with direct full attention
   ("the first row-block is computed by direct multiplication").

Cost: O(n · A·b · d) = O(n) for fixed (g, w, r, b) — the linear-attention
claim. The Pallas kernel (``bigbird.py``) implements step 3 as an explicit
tiled kernel over the same compact tensors; both are verified against
``ref.py``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import pattern as pat

NEG_INF = -1e9


def plan(cfg):
    """Static gather plan for a config.

    Returns ``(attend_idx, pad_valid, g_eff)``:

    * ``attend_idx`` — int32 (nb, A): attended key-block indices per
      query block, rows right-padded with block 0 to the max row length
      A (rows < g_eff are placeholders — those take the dense path),
    * ``pad_valid`` — float32 (nb, A): 1.0 for real entries, 0.0 for
      padding (padding entries are masked to −∞ in the score),
    * ``g_eff`` — number of leading global query blocks.
    """
    attend = pat.build_pattern(
        cfg.variant,
        cfg.num_blocks,
        cfg.global_blocks,
        cfg.window_blocks,
        cfg.random_blocks,
        cfg.attn_seed,
    )
    use_g, _, _ = pat.components(cfg.variant)
    g_eff = cfg.global_blocks if use_g else 0
    sparse_rows = [attend[j] for j in range(g_eff, cfg.num_blocks)]
    a = max((len(r) for r in sparse_rows), default=cfg.num_blocks)
    idx = np.zeros((cfg.num_blocks, a), dtype=np.int32)
    valid = np.zeros((cfg.num_blocks, a), dtype=np.float32)
    for j in range(cfg.num_blocks):
        if j < g_eff:
            # dense path; keep a harmless in-range placeholder row
            idx[j, :] = np.arange(a) % cfg.num_blocks
            valid[j, :] = 1.0
        else:
            row = attend[j]
            idx[j, : len(row)] = row
            valid[j, : len(row)] = 1.0
    return idx, valid, g_eff


def block_sparse_attention(q, k, v, attend_idx, pad_valid, g_eff, block, kv_valid=None):
    """Compact block-sparse attention.

    Args:
      q, k, v: (B, H, N, D) float32
      attend_idx: (nb, A) int32 gather plan from ``plan``
      pad_valid: (nb, A) float32 1/0 row-padding validity from ``plan``
      g_eff: number of leading global query blocks (dense path)
      block: block size b
      kv_valid: optional (B, N) 1/0 key-padding mask
    Returns: (B, H, N, D)
    """
    bsz, h, n, d = q.shape
    nb = n // block
    a = attend_idx.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    qb = q.reshape(bsz, h, nb, block, d)
    kb = k.reshape(bsz, h, nb, block, d)
    vb = v.reshape(bsz, h, nb, block, d)

    # compact key/value: (B, H, nb, A*b, d)
    kk = jnp.take(kb, attend_idx, axis=2).reshape(bsz, h, nb, a * block, d)
    vv = jnp.take(vb, attend_idx, axis=2).reshape(bsz, h, nb, a * block, d)

    scores = jnp.einsum("bhnqd,bhnkd->bhnqk", qb, kk) * scale
    # pattern-padding mask: (nb, A) -> (nb, A*b)
    pv = jnp.repeat(pad_valid, block, axis=1)
    scores = scores + (1.0 - pv)[None, None, :, None, :] * NEG_INF
    if kv_valid is not None:
        mb = kv_valid.reshape(bsz, nb, block)
        mm = jnp.take(mb, attend_idx, axis=1).reshape(bsz, nb, a * block)
        scores = scores + (1.0 - mm)[:, None, :, None, :] * NEG_INF
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhnqk,bhnkd->bhnqd", p, vv).reshape(bsz, h, n, d)

    if g_eff > 0:
        # Global query rows: direct dense attention over the full keys.
        gq = q[:, :, : g_eff * block, :]
        gs = jnp.einsum("bhnd,bhmd->bhnm", gq, k) * scale
        if kv_valid is not None:
            gs = gs + (1.0 - kv_valid)[:, None, None, :] * NEG_INF
        gp = jnp.exp(gs - gs.max(axis=-1, keepdims=True))
        gp = gp / gp.sum(axis=-1, keepdims=True)
        gout = jnp.einsum("bhnm,bhmd->bhnd", gp, v)
        out = jnp.concatenate([gout, out[:, :, g_eff * block :, :]], axis=2)
    return out


def dense_attention(q, k, v, kv_valid=None):
    """Full quadratic attention (the BERT baseline)."""
    d = q.shape[-1]
    scores = jnp.einsum("bhnd,bhmd->bhnm", q, k) / jnp.sqrt(jnp.float32(d))
    if kv_valid is not None:
        scores = scores + (1.0 - kv_valid)[:, None, None, :] * NEG_INF
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhnm,bhmd->bhnd", p, v)


def attention(q, k, v, cfg, kv_valid=None, impl="jnp"):
    """Dispatch on variant/impl. ``impl``: "jnp" | "pallas"."""
    if cfg.variant == "dense":
        return dense_attention(q, k, v, kv_valid)
    attend_idx, pad_valid, g_eff = plan(cfg)
    if impl == "pallas":
        from . import bigbird as bb

        return bb.block_sparse_attention_pallas(
            q, k, v, jnp.asarray(attend_idx), jnp.asarray(pad_valid), g_eff,
            cfg.block, kv_valid,
        )
    return block_sparse_attention(
        q, k, v, jnp.asarray(attend_idx), jnp.asarray(pad_valid), g_eff,
        cfg.block, kv_valid,
    )
