"""Pure-jnp correctness oracle for BigBird attention.

Computes attention the *obvious* O(n²) way — dense scores with an additive
mask built from the block pattern — so every optimised implementation
(``jnp_impl`` compact gather/roll path, ``bigbird`` Pallas kernel) can be
checked against it bit-for-bit (up to fp error) by pytest.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import pattern as pat

NEG_INF = -1e9


def mask_from_pattern(attend, block: int) -> np.ndarray:
    """(n, n) float mask: 0 where attended, NEG_INF where not."""
    nb = len(attend)
    n = nb * block
    m = np.full((n, n), NEG_INF, dtype=np.float32)
    for qb, keys in enumerate(attend):
        rows = slice(qb * block, (qb + 1) * block)
        for kb in keys:
            m[rows, kb * block : (kb + 1) * block] = 0.0
    return m


def attention_ref(q, k, v, mask, kv_valid=None):
    """Masked multi-head attention, dense reference.

    Args:
      q, k, v: (B, H, N, D)
      mask: (N, N) additive mask (0 / NEG_INF) from ``mask_from_pattern``
      kv_valid: optional (B, N) 1.0/0.0 key-padding mask
    Returns:
      (B, H, N, D)
    """
    d = q.shape[-1]
    scores = jnp.einsum("bhnd,bhmd->bhnm", q, k) / jnp.sqrt(jnp.float32(d))
    scores = scores + mask[None, None, :, :]
    if kv_valid is not None:
        scores = scores + (1.0 - kv_valid)[:, None, None, :] * NEG_INF
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhnm,bhmd->bhnd", p, v)


def bigbird_attention_ref(q, k, v, cfg, kv_valid=None):
    """Oracle wired to a Config: builds the pattern and applies it."""
    attend = pat.build_pattern(
        cfg.variant,
        cfg.num_blocks,
        cfg.global_blocks,
        cfg.window_blocks,
        cfg.random_blocks,
        cfg.attn_seed,
    )
    mask = jnp.asarray(mask_from_pattern(attend, cfg.block))
    return attention_ref(q, k, v, mask, kv_valid)
