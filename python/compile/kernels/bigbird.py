"""Layer-1: the BigBird block-sparse attention **Pallas kernel**.

The hot spot of the paper is the compact blocked attention of App. D:
after the (cheap, one-off) gather that builds the compact key/value
tensors, all the FLOPs are in

    scores  = Q_block @ K''_blockᵀ   (b × d) × (d × A·b)
    probs   = masked softmax(scores)
    output  = probs @ V''_block      (b × A·b) × (A·b × d)

This kernel tiles exactly that computation: grid over (batch·head,
query-block); each program holds one (b, d) query tile and its (A·b, d)
compact key/value tiles in VMEM and performs the two MXU matmuls plus an
in-register softmax.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation):
* the (b, A·b) score tile and the three input tiles are the kernel's VMEM
  working set: (2·A·b·d + b·d + b·A·b) · 4 bytes — reported per config by
  ``vmem_bytes`` and used for the §Perf roofline estimate;
* ``interpret=True`` is mandatory on the CPU PJRT plugin (real TPU
  lowering emits Mosaic custom-calls the CPU client cannot execute); the
  kernel still lowers into plain HLO embedded in the same program as the
  surrounding JAX model.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9


def _attn_kernel(q_ref, kk_ref, vv_ref, mm_ref, o_ref, *, scale):
    """One (batch·head, query-block) program.

    q_ref:  (1, 1, b, d)    query tile
    kk_ref: (1, 1, A·b, d)  compact (gathered) key tile
    vv_ref: (1, 1, A·b, d)  compact value tile
    mm_ref: (1, 1, 1, A·b)  additive mask row (key padding)
    o_ref:  (1, 1, b, d)    output tile
    """
    q = q_ref[0, 0]
    kk = kk_ref[0, 0]
    vv = vv_ref[0, 0]
    mm = mm_ref[0, 0]
    scores = jnp.dot(q, kk.T) * scale + mm  # (b, A·b)
    scores = scores - scores.max(axis=-1, keepdims=True)
    p = jnp.exp(scores)
    p = p / p.sum(axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.dot(p, vv)


def _dense_kernel(q_ref, k_ref, v_ref, m_ref, o_ref, *, scale):
    """Dense fallback program for the global query rows (paper: "the
    first row-block is computed by direct multiplication").

    Shapes (leading grid dim of 1 indexed away): q (1, gb, d),
    k/v (1, N, d), m (1, 1, N), o (1, gb, d).
    """
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    m = m_ref[0]
    scores = jnp.dot(q, k.T) * scale + m  # (gb, N)
    scores = scores - scores.max(axis=-1, keepdims=True)
    p = jnp.exp(scores)
    p = p / p.sum(axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v)


def block_sparse_attention_pallas(
    q, k, v, attend_idx, pad_valid, g_eff, block, kv_valid=None
):
    """BigBird attention with the Pallas kernel on the compact tensors.

    Args mirror ``jnp_impl.block_sparse_attention``:
      q, k, v: (B, H, N, D) float32
      attend_idx: (nb, A) int32
      pad_valid: (nb, A) float32 1/0 pattern-padding validity
      g_eff: leading global query blocks handled by the dense program
      block: block size b
      kv_valid: optional (B, N) 1/0 key-padding mask
    """
    bsz, h, n, d = q.shape
    nb = n // block
    a = attend_idx.shape[1]
    scale = float(1.0 / (d ** 0.5))  # python float: pallas kernels cannot capture traced constants

    # ---- gather (one-off data movement, outside the FLOP kernel) ----
    kb = k.reshape(bsz, h, nb, block, d)
    vb = v.reshape(bsz, h, nb, block, d)
    kk = jnp.take(kb, attend_idx, axis=2).reshape(bsz, h, nb, a * block, d)
    vv = jnp.take(vb, attend_idx, axis=2).reshape(bsz, h, nb, a * block, d)
    if kv_valid is None:
        kv_valid = jnp.ones((bsz, n), jnp.float32)
    mb = kv_valid.reshape(bsz, nb, block)
    gathered_valid = jnp.take(mb, attend_idx, axis=1).reshape(bsz, nb, a * block)
    # combine key padding with pattern-row padding into one additive mask
    pv = jnp.repeat(pad_valid, block, axis=1)[None, :, :]  # (1, nb, A*b)
    mm = (1.0 - gathered_valid * pv) * NEG_INF

    # ---- flatten (B, H) into one grid axis ----
    bh = bsz * h
    qf = q.reshape(bh, nb, block, d)
    kkf = jnp.broadcast_to(kk.reshape(bh, nb, a * block, d), (bh, nb, a * block, d))
    vvf = jnp.broadcast_to(vv.reshape(bh, nb, a * block, d), (bh, nb, a * block, d))
    mmf = jnp.broadcast_to(mm[:, None, :, :], (bsz, h, nb, a * block)).reshape(
        bh, nb, 1, a * block
    )

    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale),
        grid=(bh, nb),
        in_specs=[
            pl.BlockSpec((1, 1, block, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, a * block, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, a * block, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, a * block), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block, d), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nb, block, d), jnp.float32),
        interpret=True,
    )(qf, kkf, vvf, mmf)
    out = out.reshape(bsz, h, n, d)

    if g_eff > 0:
        gb = g_eff * block
        gmask = ((1.0 - kv_valid) * NEG_INF)[:, None, None, :]  # (B,1,1,N)
        gq = q[:, :, :gb, :].reshape(bh, gb, d)
        kf = k.reshape(bh, n, d)
        vf = v.reshape(bh, n, d)
        gm = jnp.broadcast_to(gmask, (bsz, h, 1, n)).reshape(bh, 1, n)
        gout = pl.pallas_call(
            functools.partial(_dense_kernel, scale=scale),
            grid=(bh,),
            in_specs=[
                pl.BlockSpec((1, gb, d), lambda i: (i, 0, 0)),
                pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
                pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
                pl.BlockSpec((1, 1, n), lambda i: (i, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, gb, d), lambda i: (i, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((bh, gb, d), jnp.float32),
            interpret=True,
        )(gq, kf, vf, gm)
        gout = gout.reshape(bsz, h, gb, d)
        out = jnp.concatenate([gout, out[:, :, gb:, :]], axis=2)
    return out


def vmem_bytes(block: int, a: int, d: int) -> int:
    """VMEM working set of one sparse program (bytes, f32): q + kk + vv +
    scores + out. Used for the §Perf TPU-roofline estimate."""
    q = block * d
    kv = 2 * a * block * d
    scores = block * a * block
    out = block * d
    return 4 * (q + kv + scores + out)


def mxu_utilization_estimate(block: int, a: int, d: int, mxu: int = 128) -> float:
    """Fraction of MXU lanes a (b×d)·(d×A·b) matmul keeps busy if tiles
    are padded to the mxu×mxu systolic array (structural estimate)."""
    def eff(m, k, n):
        pad = lambda x: ((x + mxu - 1) // mxu) * mxu
        return (m * k * n) / (pad(m) * pad(k) * pad(n))

    f1 = eff(block, d, a * block)
    f2 = eff(block, a * block, d)
    return (f1 + f2) / 2.0
