"""Deterministic BigBird block-attention pattern.

This module is the Python half of a **cross-language contract**: the exact
same integer algorithm is implemented in ``rust/src/attention/pattern.rs``
(splitmix64 seeding + xoshiro256** stream + Lemire bounded sampling +
partial Fisher–Yates). ``aot.py`` dumps the pattern next to each artifact
and a rust test regenerates and diffs it, so any drift between the two
implementations fails the build.

Pattern semantics (Sec. 2 + App. D of the paper), on ``nb`` blocks:

* the first ``g`` blocks are **global**: they attend to every block and
  every block attends to them (ITC; ETC reaches the same shape by
  prepending extra tokens before blockification),
* every query block attends to its **window**: ``w`` blocks centred on
  itself, circular (the rolled-key implementation of App. D wraps),
* every non-global query block attends to ``r`` **random** blocks drawn
  without replacement from the blocks it does not already attend to.

Variant ablations (Table 1) toggle the components; the diagonal block is
always attended (the rolled window always covers it; for the R-only
ablation it prevents degenerate softmax rows).
"""

from __future__ import annotations

MASK64 = (1 << 64) - 1


def _rotl(x: int, k: int) -> int:
    return ((x << k) | (x >> (64 - k))) & MASK64


def _splitmix64(state: int):
    state = (state + 0x9E3779B97F4A7C15) & MASK64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return state, z ^ (z >> 31)


class Rng:
    """xoshiro256** — bit-exact mirror of ``rust/src/util/rng.rs``."""

    def __init__(self, seed: int):
        sm = seed & MASK64
        s = []
        for _ in range(4):
            sm, v = _splitmix64(sm)
            s.append(v)
        self.s = s

    def fold_in(self, label: int) -> "Rng":
        sm = (
            self.s[0]
            ^ _rotl(self.s[2], 17)
            ^ ((label * 0x9E3779B97F4A7C15) & MASK64)
        ) & MASK64
        out = Rng.__new__(Rng)
        s = []
        for _ in range(4):
            sm, v = _splitmix64(sm)
            s.append(v)
        out.s = s
        return out

    def next_u64(self) -> int:
        s = self.s
        result = (_rotl((s[1] * 5) & MASK64, 7) * 9) & MASK64
        t = (s[1] << 17) & MASK64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def below(self, n: int) -> int:
        """Lemire multiply-shift bounded sampling — mirrors rust exactly."""
        assert n > 0
        while True:
            x = self.next_u64()
            m = x * n  # 128-bit in python
            lo = m & MASK64
            if lo >= n:
                return m >> 64
            t = ((-n) & MASK64) % n
            if lo >= t:
                return m >> 64

    def range(self, lo: int, hi: int) -> int:
        return lo + self.below(hi - lo)

    def sample_distinct(self, n: int, k: int):
        """Partial Fisher–Yates, identical to rust ``sample_distinct``."""
        assert k <= n
        idx = list(range(n))
        for i in range(k):
            j = self.range(i, n)
            idx[i], idx[j] = idx[j], idx[i]
        return idx[:k]


def components(variant: str):
    """(use_global, use_window, use_random) per attention variant."""
    return {
        "dense": (False, False, False),  # dense bypasses the pattern
        "random": (False, False, True),
        "window": (False, True, False),
        "random_window": (False, True, True),
        "window_global": (True, True, False),  # ≈ Longformer (App. E.3)
        "bigbird_itc": (True, True, True),
        "bigbird_etc": (True, True, True),
    }[variant]


def window_blocks_of(j: int, nb: int, w: int):
    """Circular window of w blocks centred on j (always contains j)."""
    half = w // 2
    return [(j + o) % nb for o in range(-half, half + 1)]


def build_pattern(
    variant: str,
    nb: int,
    g: int,
    w: int,
    r: int,
    seed: int,
):
    """Attended key blocks per query block.

    Returns ``attend``: a list of ``nb`` sorted lists of key-block
    indices. For ``dense`` every block attends to every block. Global
    *query* blocks attend to everything (App. D: "the first row-block is
    computed by direct multiplication").
    """
    use_g, use_w, use_r = components(variant)
    g_eff = g if use_g else 0
    attend = []
    for j in range(nb):
        if variant == "dense" or j < g_eff:
            attend.append(list(range(nb)))
            continue
        base = set()
        if use_g:
            base.update(range(g_eff))
        if use_w:
            base.update(window_blocks_of(j, nb, w))
        else:
            base.add(j)  # diagonal always attended
        picks = []
        if use_r:
            candidates = [b for b in range(nb) if b not in base]
            rng = Rng(seed).fold_in(j)
            chosen = rng.sample_distinct(len(candidates), min(r, len(candidates)))
            picks = [candidates[c] for c in chosen]
        attend.append(sorted(base | set(picks)))
    # Rows may have slightly different lengths (window/global overlap near
    # the edges with the circular roll); the compact kernel pads every row
    # to the max length with mask-invalid entries (see jnp_impl.plan).
    return attend


def pattern_to_text(attend) -> str:
    """Serialise for the cross-language golden test: one line per query
    block, space-separated key blocks."""
    return "\n".join(" ".join(str(b) for b in row) for row in attend) + "\n"


def token_mask(attend, block: int, nb: int):
    """Expand a block pattern to a token-level boolean mask (n, n) as a
    nested list (numpy-free so the rust mirror test can share goldens)."""
    n = nb * block
    mask = [[False] * n for _ in range(n)]
    for qb, keys in enumerate(attend):
        for kb in keys:
            for qi in range(qb * block, (qb + 1) * block):
                row = mask[qi]
                for ki in range(kb * block, (kb + 1) * block):
                    row[ki] = True
    return mask
