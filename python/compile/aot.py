"""AOT exporter: lower every model variant ONCE to HLO text + manifest.

Usage (from ``python/``):

    python -m compile.aot --out ../artifacts [--only REGEX] [--list]
    python -m compile.aot --out ../artifacts --dump-stats

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Alongside each sparse artifact we dump its attention pattern
(``pattern_*.txt``); the Rust side regenerates the pattern from the same
seed with its mirrored generator and diffs it (cross-language contract).
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model, seq2seq, train_step
from .kernels import jnp_impl, pattern as pat

NEG_INF = -1e9


# --------------------------------------------------------------------------
# lowering
# --------------------------------------------------------------------------


def lower_to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: default HLO printing ELIDES large constants ("{...}") and
    # the 0.5.1 text parser silently reads the elision as garbage (an
    # iota-like fill) — attention gather indices came back corrupted and
    # produced NaN oceans. Print with full constants.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # the 0.5.1 parser rejects newer metadata attrs (source_end_line, ...)
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def _dtype_str(dt) -> str:
    if dt == jnp.int32 or str(dt) == "int32":
        return "i32"
    if dt == jnp.float32 or str(dt) == "float32":
        return "f32"
    raise ValueError(f"unsupported dtype {dt}")


def _spec_str(name_dtype: str, shape) -> str:
    """'tokens:i32' + shape -> 'tokens:i32[8,512]'."""
    dims = ",".join(str(d) for d in shape)
    return f"{name_dtype}[{dims}]" if dims else name_dtype


@dataclasses.dataclass
class Artifact:
    name: str
    fn: object
    args: list  # ShapeDtypeStruct per input
    input_names: list  # "tokens:i32" style (dims appended from args)
    output_names: list  # same style, dims appended from eval_shape
    meta: dict


def sds(shape, dt):
    return jax.ShapeDtypeStruct(shape, dt)


# --------------------------------------------------------------------------
# build plan
# --------------------------------------------------------------------------


def model_artifacts(cfg, task: str, lr=3e-3, warmup=20, seed=0, impl="jnp", tag=""):
    """init + train_step + fwd artifacts for one (config, task)."""
    batch_args, batch_names = model.batch_specs(cfg, task)
    step_fn, n = train_step.make_train_step(cfg, task, impl=impl, base_lr=lr, warmup=warmup)
    fwd_fn, _ = train_step.make_forward(cfg, task, impl=impl)
    init_fn, _ = train_step.make_init(cfg, task, seed=seed)
    pvec = sds((n,), jnp.float32)
    step_s = sds((), jnp.int32)
    meta = {
        "task": task,
        "attn": cfg.variant,
        "impl": impl,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
        "block": cfg.block,
        "global_blocks": cfg.global_blocks,
        "window_blocks": cfg.window_blocks,
        "random_blocks": cfg.random_blocks,
        "layers": cfg.layers,
        "heads": cfg.heads,
        "hidden": cfg.hidden,
        "vocab": cfg.vocab,
        "params": n,
        "attn_seed": cfg.attn_seed,
        "pattern": pattern_key(cfg),
    }
    suffix = f"_{tag}" if tag else ""
    out = []
    out.append(
        Artifact(
            name=cfg.artifact_name(f"init_{task}") + suffix,
            fn=lambda: (init_fn(),),
            args=[],
            input_names=[],
            output_names=["params:f32"],
            meta={**meta, "kind": "init", "seed": seed},
        )
    )
    out.append(
        Artifact(
            name=cfg.artifact_name(f"train_{task}") + suffix,
            fn=lambda p, m, v, s, *b: step_fn(p, m, v, s, *b),
            args=[pvec, pvec, pvec, step_s] + batch_args,
            input_names=["params:f32", "m:f32", "v:f32", "step:i32"] + batch_names,
            output_names=["params:f32", "m:f32", "v:f32", "loss:f32"],
            meta={**meta, "kind": "train", "lr": lr, "warmup": warmup},
        )
    )
    out.append(
        Artifact(
            name=cfg.artifact_name(f"fwd_{task}") + suffix,
            fn=lambda p, t, k: (fwd_fn(p, t, k),),
            args=[pvec, batch_args[0], batch_args[1]],
            input_names=["params:f32", "tokens:i32", "kv_valid:f32"],
            output_names=["logits:f32"],
            meta={**meta, "kind": "fwd"},
        )
    )
    return out


def attnbench_artifacts():
    """Microbenchmark artifacts for the scaling figure: pure attention
    forward at several sequence lengths, dense vs BigBird, jnp vs pallas."""
    arts = []
    heads, d, block = 2, 32, 32
    for n in (256, 512, 1024, 2048, 4096):
        cfg = configs.Config(
            variant="bigbird_itc",
            seq_len=n,
            block=block,
            global_blocks=2,
            window_blocks=3,
            random_blocks=3,
            layers=1,
            heads=heads,
            hidden=heads * d,
            ffn=4 * heads * d,
            vocab=64,
            batch=1,
        )
        q = sds((1, heads, n, d), jnp.float32)
        for variant, impls in (
            ("dense", ("jnp",)),
            ("bigbird_itc", ("jnp", "pallas")),
        ):
            c = cfg.replace(variant=variant)
            for impl in impls:
                def make_fn(c=c, impl=impl):
                    def fn(qq, kk, vv):
                        return (jnp_impl.attention(qq, kk, vv, c, None, impl=impl),)

                    return fn

                arts.append(
                    Artifact(
                        name=f"attnbench_{variant}_{impl}_n{n}",
                        fn=make_fn(),
                        args=[q, q, q],
                        input_names=["q:f32", "k:f32", "v:f32"],
                        output_names=["o:f32"],
                        meta={
                            "kind": "attnbench",
                            "attn": variant,
                            "impl": impl,
                            "seq_len": n,
                            "block": block,
                            "heads": heads,
                            "head_dim": d,
                            "global_blocks": c.global_blocks,
                            "window_blocks": c.window_blocks,
                            "random_blocks": c.random_blocks,
                            "attn_seed": c.attn_seed,
                            "pattern": pattern_key(c) if variant != "dense" else "",
                        },
                    )
                )
    return arts


def task1_artifacts(n=256, d=32, tau=200.0):
    """Prop. 1 / Task 1 (§3.4): furthest-vector retrieval.

    The dense program is the paper's *analytic* single-layer construction
    (App. C): Q = −u, K = u, hardmax ≈ softmax at temperature τ. The
    sparse program applies the identical construction restricted to the
    BigBird pattern — which provably cannot see most pairs.
    """
    block = 16
    cfg = configs.Config(
        variant="bigbird_itc",
        seq_len=n,
        block=block,
        global_blocks=1,
        window_blocks=3,
        random_blocks=2,
        layers=1,
        heads=1,
        hidden=d,
        ffn=d,
        vocab=8,
        batch=1,
    )
    u_spec = sds((1, n, d), jnp.float32)

    def dense_fn(u):
        s = -tau * jnp.einsum("bnd,bmd->bnm", u, u)
        p = jax.nn.softmax(s, axis=-1)
        return (jnp.einsum("bnm,bmd->bnd", p, u),)

    attend_idx, pad_valid, g_eff = jnp_impl.plan(cfg)
    from .kernels import ref

    mask = jnp.asarray(
        ref.mask_from_pattern(
            pat.build_pattern(
                cfg.variant,
                cfg.num_blocks,
                cfg.global_blocks,
                cfg.window_blocks,
                cfg.random_blocks,
                cfg.attn_seed,
            ),
            cfg.block,
        )
    )

    def sparse_fn(u):
        s = -tau * jnp.einsum("bnd,bmd->bnm", u, u) + mask[None]
        p = jax.nn.softmax(s, axis=-1)
        return (jnp.einsum("bnm,bmd->bnd", p, u),)

    meta = {"kind": "task1", "seq_len": n, "head_dim": d, "tau": tau}
    return [
        Artifact("task1_dense", dense_fn, [u_spec], ["u:f32"], ["out:f32"],
                 {**meta, "attn": "dense"}),
        Artifact("task1_sparse", sparse_fn, [u_spec], ["u:f32"], ["out:f32"],
                 {**meta, "attn": "bigbird_itc", "pattern": pattern_key(cfg)}),
    ]


def s2s_artifacts(cfg, dec_len: int, lr=3e-3, warmup=20, seed=0):
    step_fn, n = seq2seq.make_s2s_train_step(cfg, dec_len, base_lr=lr, warmup=warmup)
    decode_fn = seq2seq.make_s2s_decode(cfg, dec_len)
    init_fn = seq2seq.make_s2s_init(cfg, dec_len, seed=seed)
    B, S, T = cfg.batch, cfg.seq_len, dec_len
    pvec = sds((n,), jnp.float32)
    batch_args = [
        sds((B, S), jnp.int32),
        sds((B, S), jnp.float32),
        sds((B, T), jnp.int32),
        sds((B, T), jnp.int32),
        sds((B, T), jnp.float32),
    ]
    batch_names = ["src:i32", "src_valid:f32", "dec_in:i32", "dec_out:i32", "dec_w:f32"]
    meta = {
        "task": "s2s",
        "attn": cfg.variant,
        "impl": "jnp",
        "seq_len": cfg.seq_len,
        "dec_len": dec_len,
        "batch": cfg.batch,
        "vocab": cfg.vocab,
        "params": n,
        "pattern": pattern_key(cfg) if cfg.variant != "dense" else "",
    }
    return [
        Artifact(
            cfg.artifact_name("init_s2s"),
            lambda: (init_fn(),),
            [],
            [],
            ["params:f32"],
            {**meta, "kind": "init", "seed": seed},
        ),
        Artifact(
            cfg.artifact_name("train_s2s"),
            lambda p, m, v, s, *b: step_fn(p, m, v, s, *b),
            [pvec, pvec, pvec, sds((), jnp.int32)] + batch_args,
            ["params:f32", "m:f32", "v:f32", "step:i32"] + batch_names,
            ["params:f32", "m:f32", "v:f32", "loss:f32"],
            {**meta, "kind": "train", "lr": lr, "warmup": warmup},
        ),
        Artifact(
            cfg.artifact_name("decode_s2s"),
            lambda p, s, va, d: (decode_fn(p, s, va, d),),
            [pvec, batch_args[0], batch_args[1], batch_args[2]],
            ["params:f32", "src:i32", "src_valid:f32", "dec_in:i32"],
            ["logits:f32"],
            {**meta, "kind": "decode"},
        ),
    ]


def pattern_key(cfg) -> str:
    """Filename of the dumped pattern for this attention config."""
    from .layers import internal_cfg

    c = internal_cfg(cfg)
    return (
        f"pattern_{c.variant}_nb{c.num_blocks}_g{c.global_blocks}"
        f"_w{c.window_blocks}_r{c.random_blocks}_seed{c.attn_seed}.txt"
    )


def build_plan():
    """The full artifact list (DESIGN.md §6 experiment index)."""
    arts = []

    # -- scaling figure microbench --
    arts += attnbench_artifacts()

    # -- Table 1: building blocks @512 (7 variants, MLM) --
    for variant in configs.ATTN_VARIANTS:
        cfg = configs.exp(batch=4, variant=variant)
        arts += model_artifacts(cfg, "mlm")

    # -- Pallas-in-model proof artifact --
    arts += [
        a
        for a in model_artifacts(configs.exp(batch=4), "mlm", impl="pallas", tag="pallas")
        if a.meta["kind"] == "fwd"
    ]

    # -- Tab. 10 / Fig. 8: MLM across context lengths --
    for s, b in ((128, 8), (256, 8), (1024, 2), (2048, 1)):
        arts += model_artifacts(configs.exp(seq_len=s, batch=b), "mlm")
    arts += model_artifacts(configs.exp(seq_len=2048, batch=1, variant="window_global"), "mlm")
    arts += model_artifacts(configs.exp(seq_len=2048, batch=1, variant="bigbird_etc"), "mlm")

    # -- Tab. 2/3: QA (long evidence @1024; dense truncated @512) --
    for variant in ("bigbird_itc", "bigbird_etc", "window_global"):
        arts += model_artifacts(configs.exp(seq_len=1024, batch=2, variant=variant), "qa")
    arts += model_artifacts(configs.exp(seq_len=512, batch=4, variant="dense"), "qa")

    # -- Tab. 15/16: classification long + short --
    for variant in ("bigbird_itc", "dense"):
        arts += model_artifacts(configs.exp(seq_len=512, batch=4, variant=variant), "cls")
        arts += model_artifacts(configs.exp(seq_len=128, batch=8, variant=variant), "cls")
    arts += model_artifacts(configs.exp(seq_len=1024, batch=2), "cls")

    # -- Tab. 7: chromatin multi-label @1024 (window = local-only baseline) --
    for variant in ("bigbird_itc", "window"):
        arts += model_artifacts(
            configs.exp(seq_len=1024, batch=2, variant=variant), "multilabel"
        )

    # -- Tab. 4/20: summarization seq2seq --
    for variant in ("bigbird_itc", "dense"):
        arts += s2s_artifacts(configs.exp(batch=4, variant=variant), dec_len=64)

    # -- Prop. 1 / Task 1 --
    arts += task1_artifacts()

    names = [a.name for a in arts]
    dup = {n for n in names if names.count(n) > 1}
    assert not dup, f"duplicate artifact names: {dup}"
    return arts


# --------------------------------------------------------------------------
# manifest + pattern dumps
# --------------------------------------------------------------------------


def manifest_entry(a: Artifact, out_shapes) -> str:
    lines = ["[artifact]", f"name={a.name}", f"file={a.name}.hlo.txt"]
    for nd, spec in zip(a.input_names, a.args):
        lines.append(f"input={_spec_str(nd, spec.shape)}")
    for nd, sh in zip(a.output_names, out_shapes):
        lines.append(f"output={_spec_str(nd, sh.shape)}")
    for k, v in sorted(a.meta.items()):
        lines.append(f"meta={k}:{v}")
    return "\n".join(lines) + "\n"


def dump_patterns(arts, out_dir):
    done = set()
    for a in arts:
        key = a.meta.get("pattern", "")
        if not key or key in done:
            continue
        m = re.match(
            r"pattern_(\w+)_nb(\d+)_g(\d+)_w(\d+)_r(\d+)_seed(\d+)\.txt", key
        )
        variant, nb, g, w, r, seed = m.group(1), *map(int, m.groups()[1:])
        attend = pat.build_pattern(variant, nb, g, w, r, seed)
        with open(os.path.join(out_dir, key), "w") as f:
            f.write(pat.pattern_to_text(attend))
        done.add(key)
    return len(done)


def hlo_stats(text: str) -> dict:
    """Cheap HLO profile: op histogram + fusion count, for §Perf L2."""
    ops = {}
    for mm in re.finditer(r"=\s+\S+\s+(\w+)\(", text):
        ops[mm.group(1)] = ops.get(mm.group(1), 0) + 1
    return ops


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default="", help="regex filter on artifact names")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--dump-stats", action="store_true")
    args = ap.parse_args(argv)

    arts = build_plan()
    if args.only:
        rx = re.compile(args.only)
        arts = [a for a in arts if rx.search(a.name)]
    if args.list:
        for a in arts:
            print(a.name)
        print(f"{len(arts)} artifacts")
        return

    os.makedirs(args.out, exist_ok=True)
    manifest_parts = ["# bigbird artifact manifest (generated by compile.aot)\n"]
    t_all = time.time()
    for i, a in enumerate(arts):
        t0 = time.time()
        out_shapes = jax.eval_shape(a.fn, *a.args)
        if not isinstance(out_shapes, (tuple, list)):
            out_shapes = (out_shapes,)
        text = lower_to_hlo_text(a.fn, a.args)
        path = os.path.join(args.out, f"{a.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest_parts.append(manifest_entry(a, out_shapes))
        msg = f"[{i + 1}/{len(arts)}] {a.name}: {len(text) / 1024:.0f} KiB in {time.time() - t0:.1f}s"
        if args.dump_stats:
            ops = hlo_stats(text)
            top = sorted(ops.items(), key=lambda kv: -kv[1])[:6]
            msg += "  ops: " + ", ".join(f"{k}×{v}" for k, v in top)
        print(msg, flush=True)

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_parts))
    n_pat = dump_patterns(arts, args.out)
    print(
        f"wrote {len(arts)} artifacts + manifest + {n_pat} patterns "
        f"in {time.time() - t_all:.1f}s -> {args.out}"
    )


if __name__ == "__main__":
    main()
