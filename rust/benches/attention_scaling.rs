//! `cargo bench --bench attention_scaling` — the scaling figure bench:
//! dense vs BigBird attention forward latency across sequence lengths,
//! with log-log exponent fits (hand-rolled harness; criterion is not
//! available offline).

use std::time::Instant;

use bigbird::runtime::{ExecutablePool, HostTensor, Manifest, Runtime};
use bigbird::util::stats::{linear_fit, median};

const LENGTHS: [usize; 5] = [256, 512, 1024, 2048, 4096];

fn bench_artifact(pool: &ExecutablePool, name: &str, n: usize, reps: usize) -> Vec<f64> {
    let exe = pool.get(name).expect(name);
    let vol = 2 * n * 32;
    let q = HostTensor::F32 {
        shape: vec![1, 2, n, 32],
        data: (0..vol).map(|i| ((i % 97) as f32) * 0.01).collect(),
    };
    exe.run(&[q.clone(), q.clone(), q.clone()]).unwrap(); // warmup
    (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            exe.run(&[q.clone(), q.clone(), q.clone()]).unwrap();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

fn main() {
    let pool = ExecutablePool::new(
        Runtime::cpu().unwrap(),
        Manifest::load("artifacts").expect("run `make artifacts`"),
    );
    println!("attention_scaling bench (median of 5 reps):\n");
    println!("{:<14}{:<9}{:>9}{:>14}", "variant", "impl", "seq_len", "median ms");
    for (variant, impl_) in [("dense", "jnp"), ("bigbird_itc", "jnp"), ("bigbird_itc", "pallas")] {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for &n in &LENGTHS {
            let samples = bench_artifact(&pool, &format!("attnbench_{variant}_{impl_}_n{n}"), n, 5);
            let med = median(&samples);
            println!("{variant:<14}{impl_:<9}{n:>9}{:>14.2}", med * 1000.0);
            xs.push((n as f64).ln());
            ys.push(med.ln());
        }
        let (_, k, r2) = linear_fit(&xs, &ys);
        println!("{variant:<14}{impl_:<9}  t ∝ n^{k:.2} (r²={r2:.3})\n");
    }
}
