//! `cargo bench --bench attention_scaling` — the scaling figure bench:
//! dense vs BigBird block-sparse attention forward latency across
//! sequence lengths, with log-log exponent fits (hand-rolled harness;
//! criterion is not available offline).
//!
//! Two tiers:
//! 1. **native kernels** (always runs, zero artifacts): the pure-Rust
//!    dense masked reference vs the streaming-softmax sparse kernel
//!    from `bigbird::kernel` — the measurable linear-vs-quadratic
//!    claim, expected ≥ 2× sparse speedup at the largest length;
//! 2. **PJRT artifacts** (skips when `artifacts/manifest.txt` is
//!    absent): the AOT-compiled jnp/pallas attention programs.
//!
//! `-- --json <path>` writes a flat JSON report in the same format as
//! `benches/coordinator.rs` (the CI `BENCH_attention.json` artifact).

use std::time::Instant;

use bigbird::attention::{PatternSource, PatternSpec};
use bigbird::config::{AttnVariant, ModelConfig, Precision};
use bigbird::kernel::{dense_reference, sparse_forward, HeadViews, NativeModel, SparseScratch};
use bigbird::runtime::{ExecutablePool, HostTensor, Manifest, Runtime};
use bigbird::util::stats::{linear_fit, median};
use bigbird::util::{BenchReport, Rng};

const LENGTHS: [usize; 5] = [256, 512, 1024, 2048, 4096];
/// Native kernel tier lengths: the dense O(n²) reference is the
/// bottleneck, so the ladder stops at 2048.
const NATIVE_LENGTHS: [usize; 4] = [256, 512, 1024, 2048];
const NATIVE_BLOCK: usize = 16;
const NATIVE_HEAD_DIM: usize = 32;
const NATIVE_REPS: usize = 3;

fn median_ms(samples: &[f64]) -> f64 {
    median(samples) * 1000.0
}

/// Dense-vs-sparse scaling of the native kernels (no PJRT, no
/// artifacts): one head; the sparse tier runs the paper-shaped pattern
/// (g=2, w=3, r=3), the dense tier a truly dense (all-attended) layout.
fn bench_native(report: &mut BenchReport) {
    println!("native kernel scaling (median of {NATIVE_REPS} reps):\n");
    println!("{:<14}{:>9}{:>14}", "kernel", "seq_len", "median ms");
    let mut rng = Rng::new(17);
    let mut log_n = Vec::new();
    let mut dense_log_t = Vec::new();
    let mut sparse_log_t = Vec::new();
    let mut dense_at_max = 0.0f64;
    let mut sparse_at_max = 0.0f64;
    for &n in &NATIVE_LENGTHS {
        let sparse_spec = PatternSpec {
            variant: AttnVariant::BigBirdItc,
            nb: n / NATIVE_BLOCK,
            global_blocks: 2,
            window_blocks: 3,
            random_blocks: 3,
            seed: 0,
        };
        let sparse_pattern = PatternSource::Static(sparse_spec).compile(NATIVE_BLOCK);
        let sparse_layout = sparse_pattern.head(0);
        // the dense baseline needs a genuinely dense layout: with the
        // sparse layout, dense_reference would mask to the same
        // attended blocks and do the same FLOPs as the sparse kernel
        let dense_spec = PatternSpec {
            variant: AttnVariant::Dense,
            nb: n / NATIVE_BLOCK,
            global_blocks: 0,
            window_blocks: 1,
            random_blocks: 0,
            seed: 0,
        };
        let dense_pattern = PatternSource::Static(dense_spec).compile(NATIVE_BLOCK);
        let dense_layout = dense_pattern.head(0);
        let d = NATIVE_HEAD_DIM;
        let q: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let x = HeadViews { q: &q, k: &k, v: &v, key_valid: None };
        let mut out = vec![0.0f32; n * d];
        let mut scratch = SparseScratch::new();

        // warmup once, then time
        dense_reference(&x, d, dense_layout, &mut out);
        let dense_samples: Vec<f64> = (0..NATIVE_REPS)
            .map(|_| {
                let t0 = Instant::now();
                dense_reference(&x, d, dense_layout, &mut out);
                t0.elapsed().as_secs_f64()
            })
            .collect();
        sparse_forward(&x, d, sparse_layout, &mut scratch, &mut out);
        let sparse_samples: Vec<f64> = (0..NATIVE_REPS)
            .map(|_| {
                let t0 = Instant::now();
                sparse_forward(&x, d, sparse_layout, &mut scratch, &mut out);
                t0.elapsed().as_secs_f64()
            })
            .collect();

        let dense_ms = median_ms(&dense_samples);
        let sparse_ms = median_ms(&sparse_samples);
        println!("{:<14}{n:>9}{dense_ms:>14.3}", "dense");
        println!("{:<14}{n:>9}{sparse_ms:>14.3}", "sparse");
        report.push(&format!("attn_native_dense_n{n}_ms"), dense_ms);
        report.push(&format!("attn_native_sparse_n{n}_ms"), sparse_ms);
        // tokens/sec of the sparse kernel at this length — feeds the
        // CI step-summary table only (the bench-check gate tracks the
        // latency keys; this is their exact reciprocal)
        if sparse_ms > 0.0 {
            let tps = n as f64 / (sparse_ms / 1000.0);
            report.push(&format!("attn_native_sparse_n{n}_tokens_per_sec"), tps);
        }
        log_n.push((n as f64).ln());
        dense_log_t.push(median(&dense_samples).max(1e-9).ln());
        sparse_log_t.push(median(&sparse_samples).max(1e-9).ln());
        if n == *NATIVE_LENGTHS.last().expect("nonempty") {
            dense_at_max = dense_ms;
            sparse_at_max = sparse_ms;
        }
    }
    for (name, log_t) in [("dense", &dense_log_t), ("sparse", &sparse_log_t)] {
        let (_, exponent, r2) = linear_fit(&log_n, log_t);
        println!("{name:<14}  t ∝ n^{exponent:.2} (r²={r2:.3})");
        report.push(&format!("attn_native_{name}_exponent"), exponent);
    }
    let n_max = NATIVE_LENGTHS.last().expect("nonempty");
    let speedup = if sparse_at_max > 0.0 { dense_at_max / sparse_at_max } else { 0.0 };
    println!("sparse speedup over dense at n={n_max}: x{speedup:.1}\n");
    report.push(&format!("attn_native_sparse_speedup_n{n_max}"), speedup);
}

/// Serve-path precision ablation: the full native model forward
/// (projections + FFN + tied logits, all through the packed GEMM layer)
/// at each `--precision` policy, batch 1 per serving bucket length.
/// **Informational only** — bench-check gates the latency keys above;
/// these `*_tokens_per_sec` keys feed the step-summary precision column.
fn bench_precision(report: &mut BenchReport) {
    println!("native serve-path precision ablation (median of {NATIVE_REPS} reps):\n");
    println!("{:<10}{:>9}{:>14}{:>16}", "precision", "seq_len", "median ms", "tokens/sec");
    for p in Precision::all() {
        for &n in &NATIVE_LENGTHS {
            let mut cfg = ModelConfig::native_serving();
            cfg.seq_len = n;
            cfg.precision = p;
            let vocab = cfg.vocab;
            let mut model = NativeModel::new(cfg).expect("native serving config");
            let tokens: Vec<i32> = (0..n).map(|i| (i % vocab) as i32).collect();
            model.forward(&tokens, None, 1, n).expect("warmup forward"); // warmup (packs weights)
            let samples: Vec<f64> = (0..NATIVE_REPS)
                .map(|_| {
                    let t0 = Instant::now();
                    model.forward(&tokens, None, 1, n).expect("timed forward");
                    t0.elapsed().as_secs_f64()
                })
                .collect();
            let ms = median_ms(&samples);
            let tps = if ms > 0.0 { n as f64 / (ms / 1000.0) } else { 0.0 };
            println!("{:<10}{n:>9}{ms:>14.3}{tps:>16.0}", p.as_str());
            report.push(&format!("model_native_{}_n{n}_ms", p.as_str()), ms);
            report.push(&format!("model_native_{}_n{n}_tokens_per_sec", p.as_str()), tps);
        }
    }
    println!();
}

// ---------------------------------------------------------------------
// PJRT artifact tier (optional)
// ---------------------------------------------------------------------

/// AOT artifact dir, or `None` when artifacts haven't been generated
/// (bare checkout / CI) — the PJRT tier skips rather than panics.
fn artifacts() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        Some("artifacts")
    } else {
        eprintln!(
            "(skipping PJRT attention benches: no artifacts; generate via python/compile/aot.py)"
        );
        None
    }
}

fn bench_artifact(pool: &ExecutablePool, name: &str, n: usize, reps: usize) -> Vec<f64> {
    let exe = pool.get(name).expect(name);
    let vol = 2 * n * 32;
    let q = HostTensor::F32 {
        shape: vec![1, 2, n, 32],
        data: (0..vol).map(|i| ((i % 97) as f32) * 0.01).collect(),
    };
    exe.run(&[q.clone(), q.clone(), q.clone()]).unwrap(); // warmup
    (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            exe.run(&[q.clone(), q.clone(), q.clone()]).unwrap();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

fn bench_pjrt(dir: &str, report: &mut BenchReport) {
    let pool = ExecutablePool::new(Runtime::cpu().unwrap(), Manifest::load(dir).expect(dir));
    println!("PJRT artifact scaling (median of 5 reps):\n");
    println!("{:<14}{:<9}{:>9}{:>14}", "variant", "impl", "seq_len", "median ms");
    for (variant, impl_) in [("dense", "jnp"), ("bigbird_itc", "jnp"), ("bigbird_itc", "pallas")] {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for &n in &LENGTHS {
            let samples = bench_artifact(&pool, &format!("attnbench_{variant}_{impl_}_n{n}"), n, 5);
            let med = median(&samples);
            println!("{variant:<14}{impl_:<9}{n:>9}{:>14.2}", med * 1000.0);
            report.push(&format!("attn_pjrt_{variant}_{impl_}_n{n}_ms"), med * 1000.0);
            xs.push((n as f64).ln());
            ys.push(med.ln());
        }
        let (_, k, r2) = linear_fit(&xs, &ys);
        println!("{variant:<14}{impl_:<9}  t ∝ n^{k:.2} (r²={r2:.3})\n");
        report.push(&format!("attn_pjrt_{variant}_{impl_}_exponent"), k);
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json_path = BenchReport::json_path(&args).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });

    let mut report = BenchReport::new();
    bench_native(&mut report);
    bench_precision(&mut report);
    if let Some(dir) = artifacts() {
        bench_pjrt(dir, &mut report);
    }
    if let Some(path) = json_path {
        report.write(&path).expect("writing bench JSON");
        println!("(bench JSON written to {path})");
    }
}
