//! `cargo bench --bench coordinator` — L3 hot-path benches:
//! 1. batcher routing/forming micro-bench (pure logic, no PJRT),
//! 2. end-to-end serving throughput + latency percentiles under a
//!    mixed-length fill-mask workload,
//! 3. throughput scaling curve vs engine-pool worker count on mixed
//!    512/2048 traffic (the pipelined-dispatch payoff: ≥1.5× at 4
//!    workers, and a 1-worker pool reproduces the single-inflight
//!    baseline).

use std::time::{Duration, Instant};

use bigbird::config::ServingConfig;
use bigbird::coordinator::{
    trace, Batcher, BatcherConfig, Bucket, PendingRequest, Server, ServerConfig,
};
use bigbird::tokenizer::special;
use bigbird::util::Rng;

fn bench_batcher() {
    let buckets = vec![
        Bucket { artifact: "a".into(), seq_len: 128, batch: 8 },
        Bucket { artifact: "b".into(), seq_len: 512, batch: 4 },
        Bucket { artifact: "c".into(), seq_len: 2048, batch: 1 },
    ];
    let mut rng = Rng::new(1);
    let n = 100_000;
    let reqs: Vec<PendingRequest> = (0..n)
        .map(|i| PendingRequest {
            id: i as u64,
            tokens: vec![7; rng.range(16, 2048)],
            enqueued: Instant::now(),
        })
        .collect();
    let mut b =
        Batcher::new(buckets, BatcherConfig { max_wait: Duration::ZERO, ..Default::default() });
    let t0 = Instant::now();
    for r in reqs {
        b.push(r);
    }
    let mut formed = 0usize;
    let deadline = Instant::now() + Duration::from_millis(1);
    while let Some(fb) = b.poll(deadline) {
        formed += fb.requests.len();
    }
    let dt = t0.elapsed();
    println!(
        "batcher: {n} requests routed+formed in {:.1} ms ({:.1} M req/s), {formed} drained",
        dt.as_secs_f64() * 1000.0,
        n as f64 / dt.as_secs_f64() / 1e6
    );
}

/// Fill-mask tokens of length `len` with three masked positions.
fn masked_request(rng: &mut Rng, len: usize) -> Vec<i32> {
    let mut toks: Vec<i32> = (0..len).map(|_| 6 + rng.below(500) as i32).collect();
    for _ in 0..3 {
        let p = rng.below(len);
        toks[p] = special::MASK;
    }
    toks
}

fn bench_serving() {
    let mut cfg = ServerConfig::mlm_default("artifacts");
    cfg.batcher = BatcherConfig { max_wait: Duration::from_millis(5), ..Default::default() };
    let server = Server::start(cfg).expect("run `make artifacts`");
    let mut rng = Rng::new(2);
    let n = 48;
    // warm every bucket (compile + param init), then reset metrics
    server.warmup(&[128, 256, 512, 1024, 2048]).unwrap();
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for _ in 0..n {
        let len = match rng.below(10) {
            0..=4 => rng.range(64, 512),
            5..=7 => rng.range(512, 1024),
            _ => rng.range(1024, 2048),
        };
        rxs.push(server.submit(masked_request(&mut rng, len)).unwrap());
    }
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(600)).unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.metrics();
    println!(
        "serving: {n} reqs in {wall:.2}s = {:.1} req/s | p50 {:.0}ms p95 {:.0}ms p99 {:.0}ms | fill {:.2} batches {}",
        n as f64 / wall,
        m.p50_ms,
        m.p95_ms,
        m.p99_ms,
        m.fill_ratio,
        m.batches
    );
    server.shutdown();
}

/// Throughput scaling vs engine workers: the same mixed 512/2048-bucket
/// closed workload replayed against pools of 1/2/4 workers.
fn bench_scaling() {
    println!("\nscaling: mixed 512/2048 traffic vs engine workers");
    // lens 400 → 512 bucket, 1800 → 2048 bucket; 40% long requests
    let events = trace::bimodal(32, trace::Arrival::Closed, 400, 1800, 0.4, 5);
    let mut base_rps = 0.0f64;
    for workers in [1usize, 2, 4] {
        let mut cfg = ServerConfig::mlm_default("artifacts");
        cfg.batcher = BatcherConfig { max_wait: Duration::from_millis(5), ..Default::default() };
        cfg.serving = ServingConfig { engine_workers: workers, max_inflight: 4 };
        let server = Server::start(cfg).expect("run `make artifacts`");
        server.warmup(&[512, 2048]).unwrap();
        let mut rng = Rng::new(7);
        let t0 = Instant::now();
        let rxs: Vec<_> = events
            .iter()
            .map(|e| server.submit(masked_request(&mut rng, e.len)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(600)).unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let rps = events.len() as f64 / wall;
        if workers == 1 {
            base_rps = rps;
        }
        let m = server.metrics();
        let utils = m.worker_utilization(wall);
        let mean_util = 100.0 * utils.iter().sum::<f64>() / utils.len().max(1) as f64;
        println!(
            "  {workers} worker(s): {rps:5.2} req/s  speedup x{:.2} | queue-wait {:.0}ms exec {:.0}ms | peak inflight {} | mean util {:.0}%",
            rps / base_rps,
            m.mean_queue_wait_ms,
            m.mean_exec_ms,
            m.peak_inflight,
            mean_util
        );
        server.shutdown();
    }
}

fn main() {
    println!("coordinator benches:\n");
    bench_batcher();
    bench_serving();
    bench_scaling();
}
