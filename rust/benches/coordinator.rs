//! `cargo bench --bench coordinator` — L3 hot-path benches:
//! 1. batcher routing/forming micro-bench (pure logic, no PJRT),
//! 2. heterogeneous-pool dispatch simulation over cost-skewed backends
//!    (pure logic): weighted expected-completion-time routing vs a
//!    homogeneous pool on bimodal 512/2048 traffic,
//! 3. end-to-end serving throughput + latency percentiles under a
//!    mixed-length fill-mask workload,
//! 4. throughput scaling curve vs engine-pool worker count on mixed
//!    512/2048 traffic (the pipelined-dispatch payoff: ≥1.5× at 4
//!    workers, and a 1-worker pool reproduces the single-inflight
//!    baseline),
//! 5. telemetry-sampler overhead A/B on a native pool (informational
//!    keys; no committed baseline).
//!
//! Benches 3 and 4 need AOT artifacts (`make artifacts`) and skip with
//! a note when they are absent, so the artifact-free path (1, 2 and 5)
//! runs anywhere — including the CI smoke job, which passes
//! `--json <path>` to capture the numbers as a workflow artifact.

use std::time::{Duration, Instant};

use bigbird::config::ServingConfig;
use bigbird::coordinator::{
    replay, trace, Batcher, BatcherConfig, Bucket, PendingRequest, Request, Server, ServerConfig,
    WeightedPolicy,
};
use bigbird::runtime::{Backend, BackendKind, JobShape, Roofline};
use bigbird::tokenizer::special;
use bigbird::util::{BenchReport, Rng};

/// AOT artifact dir, or `None` when artifacts haven't been generated
/// (bare checkout / CI) — PJRT-backed benches skip rather than panic.
fn artifacts() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        Some("artifacts")
    } else {
        eprintln!("(skipping PJRT benches: no artifacts; generate them via python/compile/aot.py)");
        None
    }
}

fn bench_batcher(report: &mut BenchReport) {
    let buckets = vec![
        Bucket { artifact: "a".into(), seq_len: 128, batch: 8 },
        Bucket { artifact: "b".into(), seq_len: 512, batch: 4 },
        Bucket { artifact: "c".into(), seq_len: 2048, batch: 1 },
    ];
    let mut rng = Rng::new(1);
    let n = 100_000;
    let reqs: Vec<PendingRequest> = (0..n)
        .map(|i| PendingRequest {
            id: i as u64,
            tokens: vec![7; rng.range(16, 2048)],
            enqueued: Instant::now(),
            deadline: None,
        })
        .collect();
    let mut b =
        Batcher::new(buckets, BatcherConfig { max_wait: Duration::ZERO, ..Default::default() });
    let t0 = Instant::now();
    for r in reqs {
        b.push(r);
    }
    let mut formed = 0usize;
    let deadline = Instant::now() + Duration::from_millis(1);
    while let Some(fb) = b.poll(deadline) {
        formed += fb.requests.len();
    }
    let dt = t0.elapsed();
    let mreq_s = n as f64 / dt.as_secs_f64() / 1e6;
    println!(
        "batcher: {n} requests routed+formed in {:.1} ms ({:.1} M req/s), {formed} drained",
        dt.as_secs_f64() * 1000.0,
        mreq_s
    );
    report.push("batcher_mreq_per_s", mreq_s);
}

/// Heterogeneous-pool dispatch simulation (pure logic, no PJRT): replay
/// a bimodal 512/2048 trace through the weighted policy over (a) two
/// identical simulated CPUs and (b) a CPU + a simulated
/// high-throughput/high-overhead accelerator, comparing modelled
/// makespan and reporting where the long bucket landed.
fn bench_hetero(report: &mut BenchReport) {
    let cpu = || Backend::simulated(BackendKind::Cpu, Roofline::for_kind(BackendKind::Cpu));
    let accel = || {
        Backend::simulated(
            BackendKind::Gpu,
            Roofline { gflops: 5000.0, gbps: 1000.0, overhead_ms: 25.0 },
        )
    };
    // lens 400 → 512 bucket (batch 4), 1800 → 2048 bucket (batch 2)
    let events = trace::bimodal(256, trace::Arrival::Closed, 400, 1800, 0.4, 5);
    let shapes: Vec<JobShape> = events
        .iter()
        .map(|e| {
            if e.len <= 512 {
                JobShape { seq_len: 512, batch: 4 }
            } else {
                JobShape { seq_len: 2048, batch: 2 }
            }
        })
        .collect();

    // replay with up to 8 batches in flight; completions observe the
    // backend's true (modelled) cost, refining the policy's EWMAs
    let run = |backends: Vec<Backend>| -> (f64, Vec<usize>) {
        let rooflines: Vec<Roofline> = backends.iter().map(|b| b.roofline).collect();
        let mut policy = WeightedPolicy::new(backends);
        let picks = replay(&mut policy, &shapes, 8, |w, s| rooflines[w].cost_ms(s));
        let mut busy_ms = vec![0.0f64; rooflines.len()];
        for (&w, &shape) in picks.iter().zip(&shapes) {
            busy_ms[w] += rooflines[w].cost_ms(shape);
        }
        let makespan = busy_ms.iter().copied().fold(0.0, f64::max);
        (makespan, picks)
    };

    let (homo_ms, _) = run(vec![cpu(), cpu()]);
    let (hetero_ms, picks) = run(vec![cpu(), accel()]);
    let long_total = shapes.iter().filter(|s| s.seq_len == 2048).count();
    let long_on_accel = shapes
        .iter()
        .zip(&picks)
        .filter(|(s, &w)| s.seq_len == 2048 && w == 1)
        .count();
    let frac = long_on_accel as f64 / long_total.max(1) as f64;
    let speedup = homo_ms / hetero_ms;
    println!(
        "hetero: modelled makespan cpu:2 = {homo_ms:.0} ms, cpu:1+accel:1 = {hetero_ms:.0} ms \
         (x{speedup:.2}); {long_on_accel}/{long_total} long batches on the accelerator"
    );
    report.push("hetero_speedup_modelled", speedup);
    report.push("hetero_long_frac_on_accel", frac);
}

/// Fill-mask tokens of length `len` with three masked positions.
fn masked_request(rng: &mut Rng, len: usize) -> Vec<i32> {
    let mut toks: Vec<i32> = (0..len).map(|_| 6 + rng.below(500) as i32).collect();
    for _ in 0..3 {
        let p = rng.below(len);
        toks[p] = special::MASK;
    }
    toks
}

/// Telemetry-sampler overhead A/B (native pool, artifact-free): the
/// same closed fill-mask workload with the time-series sampler off vs
/// sampling every 50 ms. The keys have no committed baseline, so the
/// bench-check gate reports them as informational rows — CI tracks the
/// delta without gating on it.
fn bench_sampler_overhead(report: &mut BenchReport) {
    println!("\nsampler overhead: native pool, telemetry off vs 50 ms cadence");
    let n = 24usize;
    let mut rps = [0.0f64; 2];
    for (i, interval_ms) in [0u64, 50].into_iter().enumerate() {
        let mut cfg = ServerConfig::mlm_default("artifacts");
        cfg.batcher = BatcherConfig { max_wait: Duration::from_millis(5), ..Default::default() };
        cfg.serving = ServingConfig::native(2, 4);
        cfg.obs.sampler_interval_ms = interval_ms;
        let server = Server::start(cfg).expect("native pool needs no artifacts");
        server.warmup(&[512]).unwrap();
        let mut rng = Rng::new(11);
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n)
            .map(|_| {
                let len = rng.range(64, 500);
                server.submit(Request::new(masked_request(&mut rng, len))).unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(600)).unwrap();
        }
        rps[i] = n as f64 / t0.elapsed().as_secs_f64();
        server.shutdown();
    }
    println!(
        "  sampler off {:.2} req/s, on(50ms) {:.2} req/s ({:+.1}% delta)",
        rps[0],
        rps[1],
        100.0 * (rps[1] / rps[0] - 1.0)
    );
    report.push("serving_sampler_off_req_per_s", rps[0]);
    report.push("serving_sampler_on_req_per_s", rps[1]);
}

fn bench_serving(artifacts: &str, report: &mut BenchReport) {
    let mut cfg = ServerConfig::mlm_default(artifacts);
    cfg.batcher = BatcherConfig { max_wait: Duration::from_millis(5), ..Default::default() };
    let server = Server::start(cfg).expect("run `make artifacts`");
    let mut rng = Rng::new(2);
    let n = 48;
    // warm every bucket (compile + param init), then reset metrics
    server.warmup(&[128, 256, 512, 1024, 2048]).unwrap();
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for _ in 0..n {
        let len = match rng.below(10) {
            0..=4 => rng.range(64, 512),
            5..=7 => rng.range(512, 1024),
            _ => rng.range(1024, 2048),
        };
        rxs.push(server.submit(Request::new(masked_request(&mut rng, len))).unwrap());
    }
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(600)).unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.metrics();
    println!(
        "serving: {n} reqs in {wall:.2}s = {:.1} req/s | p50 {:.0}ms p95 {:.0}ms p99 {:.0}ms | fill {:.2} batches {}",
        n as f64 / wall,
        m.p50_ms,
        m.p95_ms,
        m.p99_ms,
        m.fill_ratio,
        m.batches
    );
    report.push("serving_req_per_s", n as f64 / wall);
    report.push("serving_p50_ms", m.p50_ms);
    report.push("serving_p95_ms", m.p95_ms);
    server.shutdown();
}

/// Throughput scaling vs engine workers: the same mixed 512/2048-bucket
/// closed workload replayed against pools of 1/2/4 workers.
fn bench_scaling(artifacts: &str, report: &mut BenchReport) {
    println!("\nscaling: mixed 512/2048 traffic vs engine workers");
    // lens 400 → 512 bucket, 1800 → 2048 bucket; 40% long requests
    let events = trace::bimodal(32, trace::Arrival::Closed, 400, 1800, 0.4, 5);
    let mut base_rps = 0.0f64;
    for workers in [1usize, 2, 4] {
        let mut cfg = ServerConfig::mlm_default(artifacts);
        cfg.batcher = BatcherConfig { max_wait: Duration::from_millis(5), ..Default::default() };
        cfg.serving = ServingConfig::cpu(workers, 4);
        let server = Server::start(cfg).expect("run `make artifacts`");
        server.warmup(&[512, 2048]).unwrap();
        let mut rng = Rng::new(7);
        let t0 = Instant::now();
        let rxs: Vec<_> = events
            .iter()
            .map(|e| server.submit(Request::new(masked_request(&mut rng, e.len))).unwrap())
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(600)).unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let rps = events.len() as f64 / wall;
        if workers == 1 {
            base_rps = rps;
        }
        let m = server.metrics();
        let utils = m.worker_utilization(wall);
        let mean_util = 100.0 * utils.iter().sum::<f64>() / utils.len().max(1) as f64;
        println!(
            "  {workers} worker(s): {rps:5.2} req/s  speedup x{:.2} | queue-wait {:.0}ms exec {:.0}ms | peak inflight {} | mean util {:.0}%",
            rps / base_rps,
            m.mean_queue_wait_ms,
            m.mean_exec_ms,
            m.peak_inflight,
            mean_util
        );
        report.push(&format!("scaling_{workers}w_req_per_s"), rps);
        server.shutdown();
    }
}

fn main() {
    // `cargo bench --bench coordinator -- --json <path>` writes the
    // numbers as a flat JSON object (the CI smoke job's artifact); the
    // format is shared with benches/attention_scaling.rs via BenchReport
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json_path = BenchReport::json_path(&args).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });

    println!("coordinator benches:\n");
    let mut report = BenchReport::new();
    bench_batcher(&mut report);
    bench_hetero(&mut report);
    bench_sampler_overhead(&mut report);
    if let Some(dir) = artifacts() {
        bench_serving(dir, &mut report);
        bench_scaling(dir, &mut report);
    }
    if let Some(path) = json_path {
        report.write(&path).expect("writing bench JSON");
        println!("(bench JSON written to {path})");
    }
}
