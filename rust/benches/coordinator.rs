//! `cargo bench --bench coordinator` — L3 hot-path benches:
//! 1. batcher routing/forming micro-bench (pure logic, no PJRT),
//! 2. end-to-end serving throughput + latency percentiles under a
//!    mixed-length fill-mask workload.

use std::time::{Duration, Instant};

use bigbird::coordinator::{Batcher, BatcherConfig, Bucket, PendingRequest, Server, ServerConfig};
use bigbird::tokenizer::special;
use bigbird::util::Rng;

fn bench_batcher() {
    let buckets = vec![
        Bucket { artifact: "a".into(), seq_len: 128, batch: 8 },
        Bucket { artifact: "b".into(), seq_len: 512, batch: 4 },
        Bucket { artifact: "c".into(), seq_len: 2048, batch: 1 },
    ];
    let mut rng = Rng::new(1);
    let n = 100_000;
    let reqs: Vec<PendingRequest> = (0..n)
        .map(|i| PendingRequest {
            id: i as u64,
            tokens: vec![7; rng.range(16, 2048)],
            enqueued: Instant::now(),
        })
        .collect();
    let mut b = Batcher::new(buckets, BatcherConfig { max_wait: Duration::ZERO });
    let t0 = Instant::now();
    for r in reqs {
        b.push(r);
    }
    let mut formed = 0usize;
    let deadline = Instant::now() + Duration::from_millis(1);
    while let Some(fb) = b.poll(deadline) {
        formed += fb.requests.len();
    }
    let dt = t0.elapsed();
    println!(
        "batcher: {n} requests routed+formed in {:.1} ms ({:.1} M req/s), {formed} drained",
        dt.as_secs_f64() * 1000.0,
        n as f64 / dt.as_secs_f64() / 1e6
    );
}

fn bench_serving() {
    let mut cfg = ServerConfig::mlm_default("artifacts");
    cfg.batcher = BatcherConfig { max_wait: Duration::from_millis(5) };
    let server = Server::start(cfg).expect("run `make artifacts`");
    let mut rng = Rng::new(2);
    let n = 48;
    // warm every bucket (compile + param init), then reset metrics
    server.warmup(&[128, 256, 512, 1024, 2048]).unwrap();
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for _ in 0..n {
        let len = match rng.below(10) {
            0..=4 => rng.range(64, 512),
            5..=7 => rng.range(512, 1024),
            _ => rng.range(1024, 2048),
        };
        let mut toks: Vec<i32> = (0..len).map(|_| 6 + rng.below(500) as i32).collect();
        for _ in 0..3 {
            let p = rng.below(len);
            toks[p] = special::MASK;
        }
        rxs.push(server.submit(toks).unwrap());
    }
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(600)).unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.metrics();
    println!(
        "serving: {n} reqs in {wall:.2}s = {:.1} req/s | p50 {:.0}ms p95 {:.0}ms p99 {:.0}ms | fill {:.2} batches {}",
        n as f64 / wall,
        m.p50_ms,
        m.p95_ms,
        m.p99_ms,
        m.fill_ratio,
        m.batches
    );
    server.shutdown();
}

fn main() {
    println!("coordinator benches:\n");
    bench_batcher();
    bench_serving();
}
