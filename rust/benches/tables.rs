//! `cargo bench --bench tables` — timed regeneration entry points for
//! the paper tables (short-budget versions of the `experiment` CLI):
//! each row of this bench IS the harness that regenerates a table, run
//! with a reduced step budget so the bench finishes in minutes. Use
//! `bigbird experiment <id> --steps N` for full-budget runs.

use std::time::Instant;

use bigbird::cli::Flags;

fn timed(name: &str, f: impl FnOnce() -> anyhow::Result<()>) {
    let t0 = Instant::now();
    match f() {
        Ok(()) => println!("[tables] {name}: {:.1}s", t0.elapsed().as_secs_f64()),
        Err(e) => println!("[tables] {name}: FAILED: {e:#}"),
    }
}

fn flags(steps: usize) -> Flags {
    Flags {
        artifacts: "artifacts".to_string(),
        config: String::new(),
        seed: 0,
        steps,
        positional: vec![],
    }
}

fn main() {
    println!("table regeneration benches (reduced budgets):\n");
    // keep full-budget run files intact
    std::env::set_var("BB_RUN_SUFFIX", "_bench40");
    let quick = flags(40);
    timed("patterns (Fig. 1/3)", || bigbird::experiments::patterns::run(&quick));
    timed("graph report (Sec. 2)", || bigbird::experiments::graph_report::run(&quick));
    timed("scaling (headline fig)", || bigbird::experiments::scaling::run(&quick));
    timed("task1 (Prop. 1)", || bigbird::experiments::task1::run(&quick));
    timed("turing (App. B)", || bigbird::experiments::turing::run(&quick));
    timed("table1 @40 steps", || bigbird::experiments::table1::run(&quick));
    timed("classification @40 steps", || {
        bigbird::experiments::classification::run(&quick)
    });
}
