//! `cargo bench --bench train_step` — native training-step throughput:
//! tokens/sec per optimizer step and the forward/backward/optimizer
//! wall-clock split, measured on the artifact-free `kernel::grad`
//! pipeline (hand-rolled harness; criterion is not available offline).
//!
//! `-- --json <path>` writes a flat JSON report in the shared
//! `util::BenchReport` format (the CI `BENCH_train.json` artifact).

use std::time::Instant;

use bigbird::config::{ModelConfig, Precision};
use bigbird::kernel::grad::AdamWConfig;
use bigbird::train::{synthetic_docs, synthetic_mlm_batch, NativeTrainer};
use bigbird::util::{BenchReport, Rng};

const WARMUP_STEPS: usize = 2;
const TIMED_STEPS: usize = 10;
/// Timed steps for the per-precision ablation tier (informational
/// keys only, so a shorter run keeps the bench cheap).
const ABLATION_STEPS: usize = 5;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json_path = BenchReport::json_path(&args).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let mut report = BenchReport::new();

    let cfg = ModelConfig::tiny();
    let tokens_per_step = (cfg.batch * cfg.seq_len) as f64;
    let mut trainer =
        NativeTrainer::new(cfg.clone(), AdamWConfig::default()).expect("building native trainer");
    println!(
        "native train-step bench: {} params, batch {} × seq {} ({} warmup + {} timed steps)\n",
        trainer.model().param_count(),
        cfg.batch,
        cfg.seq_len,
        WARMUP_STEPS,
        TIMED_STEPS
    );
    let docs = synthetic_docs(cfg.vocab, 32, 2048, 11);
    let mut rng = Rng::new(11).fold_in(0x17);

    let mut first_loss = f32::NAN;
    let mut last_loss = f32::NAN;
    for _ in 0..WARMUP_STEPS {
        let batch = synthetic_mlm_batch(&docs, &cfg, &mut rng);
        trainer.train_step(&batch).expect("warmup step");
    }
    let (mut fwd_ms, mut bwd_ms, mut opt_ms) = (0.0f64, 0.0f64, 0.0f64);
    let t0 = Instant::now();
    for i in 0..TIMED_STEPS {
        let batch = synthetic_mlm_batch(&docs, &cfg, &mut rng);
        let loss = trainer.train_step(&batch).expect("timed step");
        if i == 0 {
            first_loss = loss;
        }
        last_loss = loss;
        fwd_ms += trainer.timings.fwd_ms;
        bwd_ms += trainer.timings.bwd_ms;
        opt_ms += trainer.timings.opt_ms;
    }
    let wall = t0.elapsed().as_secs_f64();
    let step_ms = wall * 1000.0 / TIMED_STEPS as f64;
    let tokens_per_sec = tokens_per_step * TIMED_STEPS as f64 / wall;
    let (fwd, bwd, opt) = (
        fwd_ms / TIMED_STEPS as f64,
        bwd_ms / TIMED_STEPS as f64,
        opt_ms / TIMED_STEPS as f64,
    );

    println!("{:<26}{:>12}", "metric", "value");
    println!("{:<26}{tokens_per_sec:>12.0}", "tokens/sec");
    println!("{:<26}{step_ms:>12.2}", "ms/step");
    println!("{:<26}{fwd:>12.2}", "fwd ms/step");
    println!("{:<26}{bwd:>12.2}", "bwd ms/step");
    println!("{:<26}{opt:>12.2}", "optimizer ms/step");
    println!("{:<26}{first_loss:>12.4}", "loss (first timed)");
    println!("{:<26}{last_loss:>12.4}", "loss (last timed)");

    report.push("train_native_tokens_per_sec", tokens_per_sec);
    report.push("train_native_step_ms", step_ms);
    report.push("train_native_fwd_ms", fwd);
    report.push("train_native_bwd_ms", bwd);
    report.push("train_native_opt_ms", opt);
    report.push("train_native_first_loss", first_loss as f64);
    report.push("train_native_last_loss", last_loss as f64);
    // alias of the gated key above, named so the step-summary precision
    // column can line f32 up against the ablation tiers below
    report.push("train_native_f32_tokens_per_sec", tokens_per_sec);

    // precision ablation tier (informational, never gated): the same
    // step with the forward GEMMs at f16/int8 — master weights, the
    // whole backward pass, and AdamW stay f32 (quantize-on-pack)
    for p in [Precision::F16, Precision::Int8] {
        let mut pcfg = ModelConfig::tiny();
        pcfg.precision = p;
        let mut ptrainer = NativeTrainer::new(pcfg.clone(), AdamWConfig::default())
            .expect("building ablation trainer");
        let mut prng = Rng::new(11).fold_in(0x17);
        for _ in 0..WARMUP_STEPS {
            let batch = synthetic_mlm_batch(&docs, &pcfg, &mut prng);
            ptrainer.train_step(&batch).expect("ablation warmup step");
        }
        let t0 = Instant::now();
        for _ in 0..ABLATION_STEPS {
            let batch = synthetic_mlm_batch(&docs, &pcfg, &mut prng);
            ptrainer.train_step(&batch).expect("ablation timed step");
        }
        let wall = t0.elapsed().as_secs_f64();
        let tps = tokens_per_step * ABLATION_STEPS as f64 / wall;
        println!("{:<26}{tps:>12.0}", format!("tokens/sec ({})", p.as_str()));
        report.push(&format!("train_native_{}_tokens_per_sec", p.as_str()), tps);
    }

    if let Some(path) = json_path {
        report.write(&path).expect("writing bench JSON");
        println!("\n(bench JSON written to {path})");
    }
}
