//! Synthetic long-range language (stand-in for Books/CC-News/Stories/
//! Wikipedia, App. E.1 Tab. 9).
//!
//! Structure planted per document:
//! * a **topic** latent selecting a topic-specific vocabulary slice
//!   (documents are lexically coherent end-to-end),
//! * a set of **entities** introduced early and re-mentioned at long,
//!   controlled distances (coreference-style long-range dependency),
//! * a **copy channel**: with probability `copy_p` a token repeats the
//!   token `copy_dist` positions back (the long-range correlation
//!   structure Buldyrev et al. observed in text and DNA — paper [12]).
//!
//! A model with a context window shorter than the re-mention distance
//! cannot predict masked entity mentions; a long-context model can.
//! That is exactly the effect Tab. 10 / Fig. 8 measure.

use crate::tokenizer::special;
use crate::util::Rng;

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct CorpusConfig {
    /// Total vocabulary (ids < vocab; first `special::FIRST_FREE` reserved).
    pub vocab: usize,
    /// Number of latent topics.
    pub topics: usize,
    /// Tokens reserved per topic slice.
    pub topic_slice: usize,
    /// Entities introduced per document.
    pub entities: usize,
    /// Mean distance between entity re-mentions.
    pub mention_stride: usize,
    /// Copy channels: (distance, probability) — a position repeats the
    /// token `distance` back with the given probability. Multiple scales
    /// let experiments control exactly which context lengths pay off.
    pub copy_channels: Vec<(usize, f64)>,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            vocab: 512,
            topics: 8,
            topic_slice: 24,
            entities: 12,
            mention_stride: 96,
            copy_channels: vec![(192, 0.12), (384, 0.15)],
        }
    }
}

/// Seeded document generator.
#[derive(Clone, Debug)]
pub struct CorpusGen {
    pub cfg: CorpusConfig,
    rng: Rng,
}

impl CorpusGen {
    pub fn new(cfg: CorpusConfig, seed: u64) -> Self {
        CorpusGen { cfg, rng: Rng::new(seed).fold_in(0xC0FFEE) }
    }

    /// id range reserved for entity tokens (topic slices come first).
    fn entity_base(&self) -> i32 {
        special::FIRST_FREE + (self.cfg.topics * self.cfg.topic_slice) as i32
    }

    /// Generate one document of exactly `len` tokens.
    pub fn document(&mut self, len: usize) -> Vec<i32> {
        let cfg = &self.cfg;
        let topic = self.rng.below(cfg.topics);
        let topic_lo = special::FIRST_FREE + (topic * cfg.topic_slice) as i32;
        // entity ids for this document, drawn from the entity range
        let ent_lo = self.entity_base();
        let ent_hi = cfg.vocab as i32;
        let n_ent_ids = (ent_hi - ent_lo).max(1) as usize;
        let ents: Vec<i32> = (0..cfg.entities)
            .map(|_| ent_lo + self.rng.below(n_ent_ids) as i32)
            .collect();

        let mut doc = Vec::with_capacity(len);
        'pos: for i in 0..len {
            // copy channels first: long-range verbatim dependencies
            for &(dist, p) in &cfg.copy_channels {
                if i >= dist && self.rng.coin(p) {
                    doc.push(doc[i - dist]);
                    continue 'pos;
                }
            }
            // entity re-mention on a jittered stride
            if !ents.is_empty() && self.rng.coin(1.0 / cfg.mention_stride as f64 * 4.0) {
                doc.push(*self.rng.choose(&ents));
                continue;
            }
            // topic token (Zipf-ish within the slice)
            let r = self.rng.f64();
            let z = (r * r * cfg.topic_slice as f64) as usize; // quadratic skew
            doc.push(topic_lo + z.min(cfg.topic_slice - 1) as i32);
        }
        doc
    }

    /// Corpus statistics in Tab.-9 style (token count, avg doc length).
    pub fn stats(&mut self, docs: usize, len: usize) -> (usize, f64) {
        let total: usize = (0..docs).map(|_| self.document(len).len()).sum();
        (total, total as f64 / docs as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn documents_have_exact_length_and_valid_ids() {
        let mut g = CorpusGen::new(CorpusConfig::default(), 1);
        let d = g.document(777);
        assert_eq!(d.len(), 777);
        for &t in &d {
            assert!(t >= special::FIRST_FREE && (t as usize) < g.cfg.vocab, "bad id {t}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = CorpusGen::new(CorpusConfig::default(), 5);
        let mut b = CorpusGen::new(CorpusConfig::default(), 5);
        assert_eq!(a.document(256), b.document(256));
        let mut c = CorpusGen::new(CorpusConfig::default(), 6);
        assert_ne!(a.document(256), c.document(256));
    }

    #[test]
    fn copy_channel_creates_long_range_matches() {
        let cfg = CorpusConfig { copy_channels: vec![(100, 0.3)], ..Default::default() };
        let mut g = CorpusGen::new(cfg, 2);
        let d = g.document(2000);
        let matches = (100..2000).filter(|&i| d[i] == d[i - 100]).count();
        // ≥ copy_p of positions match at the copy distance (plus chance)
        assert!(matches as f64 / 1900.0 > 0.25, "copy rate too low: {matches}");
    }

    #[test]
    fn topical_coherence_within_document() {
        let mut g = CorpusGen::new(CorpusConfig::default(), 3);
        let d = g.document(1000);
        // most tokens should fall in ONE topic slice
        let mut counts = vec![0usize; g.cfg.topics];
        for &t in &d {
            let off = (t - special::FIRST_FREE) as usize;
            if off < g.cfg.topics * g.cfg.topic_slice {
                counts[off / g.cfg.topic_slice] += 1;
            }
        }
        let max = *counts.iter().max().unwrap();
        let sum: usize = counts.iter().sum();
        assert!(max as f64 / sum as f64 > 0.9, "document not topically coherent");
    }
}
