//! Synthetic long-document summarization (stand-in for Arxiv / PubMed /
//! BigPatent, Tab. 4; and the short-doc check of Tab. 20).
//!
//! A document is a sequence of "sentences". A few sentences are *salient*
//! — they open with a salience marker and carry distinctive content
//! tokens. The reference summary is the concatenation of the salient
//! sentences' content heads, in document order, terminated by `<eos>`.
//!
//! Salient sentences are placed uniformly over the document (BigPatent's
//! by-design property: "salient content can be evenly distributed in the
//! long document"), so Lead-k and truncated-input baselines miss
//! late-document salience — the Tab. 4 effect.

use crate::tokenizer::special;
use crate::util::Rng;

use super::corpus::{CorpusConfig, CorpusGen};

/// One (document, reference summary) pair.
#[derive(Clone, Debug)]
pub struct SummarizeExample {
    /// source document tokens (no CLS — encoder consumes raw)
    pub src: Vec<i32>,
    /// reference summary: `<bos> …content… <eos>`
    pub summary: Vec<i32>,
    /// sentence boundaries of the source (for Lead/oracle baselines)
    pub sentences: Vec<(usize, usize)>,
    /// indices of salient sentences
    pub salient: Vec<usize>,
}

pub struct SummarizeGen {
    corpus: CorpusGen,
    rng: Rng,
    pub sentence_len: usize,
    pub salient_count: usize,
    /// content head tokens copied into the summary per salient sentence
    pub head_len: usize,
}

/// Marker token opening a salient sentence.
const SALIENT_MARK: i32 = special::FIRST_FREE + 5;

impl SummarizeGen {
    pub fn new(vocab: usize, seed: u64) -> Self {
        let cfg = CorpusConfig { vocab, ..Default::default() };
        SummarizeGen {
            corpus: CorpusGen::new(cfg, seed),
            rng: Rng::new(seed).fold_in(0x50),
            sentence_len: 24,
            salient_count: 4,
            head_len: 6,
        }
    }

    /// Generate one example with `n_sentences` sentences.
    pub fn example(&mut self, n_sentences: usize) -> SummarizeExample {
        assert!(n_sentences > self.salient_count);
        let mut salient: Vec<usize> =
            self.rng.sample_distinct(n_sentences, self.salient_count);
        salient.sort_unstable();

        let mut src = Vec::with_capacity(n_sentences * self.sentence_len);
        let mut sentences = Vec::with_capacity(n_sentences);
        let mut summary = vec![special::BOS];
        for si in 0..n_sentences {
            let start = src.len();
            let mut body = self.corpus.document(self.sentence_len);
            // scrub the marker id from filler
            for t in body.iter_mut() {
                if *t == SALIENT_MARK {
                    *t = SALIENT_MARK + 1;
                }
            }
            if salient.binary_search(&si).is_ok() {
                body[0] = SALIENT_MARK;
                // distinctive head content (upper-vocab "content" ids)
                for k in 0..self.head_len {
                    let id = (self.corpus.cfg.vocab / 2
                        + self.rng.below(self.corpus.cfg.vocab / 2))
                        as i32;
                    body[1 + k] = id;
                }
                summary.extend_from_slice(&body[1..1 + self.head_len]);
            }
            src.extend_from_slice(&body);
            sentences.push((start, src.len()));
        }
        summary.push(special::EOS);
        SummarizeExample { src, summary, sentences, salient }
    }
}

/// Lead baseline: first `k` sentences' tokens (Tab. 20's "Lead" row).
pub fn lead_baseline(ex: &SummarizeExample, k: usize) -> Vec<i32> {
    let mut out = Vec::new();
    for &(s, e) in ex.sentences.iter().take(k) {
        out.extend_from_slice(&ex.src[s..e]);
    }
    out
}

/// Frequency baseline (SumBasic-like): sentences ranked by mean token
/// frequency, take top k (prior-art row for Tab. 4).
pub fn frequency_baseline(ex: &SummarizeExample, k: usize) -> Vec<i32> {
    let mut freq = std::collections::HashMap::new();
    for &t in &ex.src {
        *freq.entry(t).or_insert(0usize) += 1;
    }
    let mut scored: Vec<(f64, usize)> = ex
        .sentences
        .iter()
        .enumerate()
        .map(|(i, &(s, e))| {
            let mean = ex.src[s..e].iter().map(|t| freq[t] as f64).sum::<f64>()
                / (e - s).max(1) as f64;
            (mean, i)
        })
        .collect();
    scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut chosen: Vec<usize> = scored.iter().take(k).map(|&(_, i)| i).collect();
    chosen.sort_unstable();
    let mut out = Vec::new();
    for i in chosen {
        let (s, e) = ex.sentences[i];
        out.extend_from_slice(&ex.src[s..e]);
    }
    out
}

/// Oracle extractive baseline: the salient sentences themselves (upper
/// bound for extractive systems).
pub fn oracle_baseline(ex: &SummarizeExample) -> Vec<i32> {
    let mut out = Vec::new();
    for &i in &ex.salient {
        let (s, e) = ex.sentences[i];
        out.extend_from_slice(&ex.src[s..e]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::rouge_n;

    #[test]
    fn summary_heads_come_from_salient_sentences() {
        let mut g = SummarizeGen::new(512, 1);
        let ex = g.example(20);
        assert_eq!(ex.summary[0], special::BOS);
        assert_eq!(*ex.summary.last().unwrap(), special::EOS);
        assert_eq!(ex.summary.len(), 2 + g.salient_count * g.head_len);
        // every summary content token appears in the source
        for &t in &ex.summary[1..ex.summary.len() - 1] {
            assert!(ex.src.contains(&t));
        }
    }

    #[test]
    fn oracle_beats_lead_on_rouge() {
        let mut g = SummarizeGen::new(512, 2);
        let mut lead_f1 = 0.0;
        let mut oracle_f1 = 0.0;
        for _ in 0..20 {
            let ex = g.example(24);
            let gold = &ex.summary[1..ex.summary.len() - 1];
            lead_f1 += rouge_n(&lead_baseline(&ex, 4), gold, 1).f1;
            oracle_f1 += rouge_n(&oracle_baseline(&ex), gold, 1).f1;
        }
        assert!(
            oracle_f1 > lead_f1 * 1.5,
            "oracle {oracle_f1} should beat lead {lead_f1}"
        );
    }

    #[test]
    fn salient_sentences_are_spread_out() {
        let mut g = SummarizeGen::new(512, 3);
        let mut late = 0;
        for _ in 0..50 {
            let ex = g.example(30);
            if ex.salient.iter().any(|&s| s >= 15) {
                late += 1;
            }
        }
        assert!(late > 35, "salience never lands late: {late}/50");
    }

    #[test]
    fn sentence_boundaries_cover_source() {
        let mut g = SummarizeGen::new(512, 4);
        let ex = g.example(10);
        assert_eq!(ex.sentences.len(), 10);
        assert_eq!(ex.sentences[0].0, 0);
        assert_eq!(ex.sentences.last().unwrap().1, ex.src.len());
        for w in ex.sentences.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }
}
