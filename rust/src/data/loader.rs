//! Deterministic epoch-based data loader.
//!
//! The experiment harnesses sample batches i.i.d. from a doc pool; for
//! reproducible *training runs* (the `train` CLI / train_mlm example) we
//! want proper epochs: every example visited once per epoch, shuffled
//! deterministically per (seed, epoch), with a held-out split carved off
//! before training ever sees it.

use crate::util::Rng;

/// Deterministic train/held-out split + epoch shuffling over an owned
/// example pool.
#[derive(Clone, Debug)]
pub struct Loader<T> {
    train: Vec<T>,
    heldout: Vec<T>,
    seed: u64,
    epoch: usize,
    order: Vec<usize>,
    cursor: usize,
}

impl<T: Clone> Loader<T> {
    /// Split `examples` into train/held-out (`heldout_frac` of the pool,
    /// at least 1 example when the pool is non-trivial) and prepare
    /// epoch 0. The split is a deterministic function of `seed` only.
    pub fn new(mut examples: Vec<T>, heldout_frac: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed).fold_in(0x10AD);
        rng.shuffle(&mut examples);
        let n_held = ((examples.len() as f64 * heldout_frac) as usize)
            .min(examples.len().saturating_sub(1))
            .max(usize::from(examples.len() > 1));
        let heldout = examples.split_off(examples.len() - n_held);
        let mut loader = Loader {
            train: examples,
            heldout,
            seed,
            epoch: 0,
            order: Vec::new(),
            cursor: 0,
        };
        loader.reshuffle();
        loader
    }

    fn reshuffle(&mut self) {
        self.order = (0..self.train.len()).collect();
        let mut rng = Rng::new(self.seed).fold_in(0xE0 + self.epoch as u64);
        rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Next training example; rolls into the next epoch transparently.
    pub fn next_example(&mut self) -> &T {
        if self.cursor >= self.order.len() {
            self.epoch += 1;
            self.reshuffle();
        }
        let idx = self.order[self.cursor];
        self.cursor += 1;
        &self.train[idx]
    }

    /// Fill a batch of `n` examples (clones).
    pub fn next_batch(&mut self, n: usize) -> Vec<T> {
        (0..n).map(|_| self.next_example().clone()).collect()
    }

    /// The held-out split (never returned by `next_example`).
    pub fn heldout(&self) -> &[T] {
        &self.heldout
    }

    /// Completed epochs.
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Training-pool size.
    pub fn train_len(&self) -> usize {
        self.train.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_res;

    #[test]
    fn split_is_disjoint_and_complete() {
        let loader = Loader::new((0..100).collect::<Vec<i32>>(), 0.2, 7);
        assert_eq!(loader.train_len() + loader.heldout().len(), 100);
        assert_eq!(loader.heldout().len(), 20);
        let held: std::collections::HashSet<i32> =
            loader.heldout().iter().copied().collect();
        let mut l = loader.clone();
        for _ in 0..l.train_len() {
            assert!(!held.contains(l.next_example()));
        }
    }

    #[test]
    fn epoch_visits_every_example_once() {
        let mut loader = Loader::new((0..37).collect::<Vec<i32>>(), 0.0, 3);
        let n = loader.train_len();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..n {
            seen.insert(*loader.next_example());
        }
        assert_eq!(seen.len(), n, "epoch must be a permutation");
        assert_eq!(loader.epoch(), 0);
        loader.next_example();
        assert_eq!(loader.epoch(), 1);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Loader::new((0..50).collect::<Vec<i32>>(), 0.1, 9);
        let mut b = Loader::new((0..50).collect::<Vec<i32>>(), 0.1, 9);
        for _ in 0..120 {
            assert_eq!(a.next_example(), b.next_example());
        }
    }

    #[test]
    fn epochs_reshuffle_differently() {
        let mut loader = Loader::new((0..64).collect::<Vec<i32>>(), 0.0, 5);
        let n = loader.train_len();
        let e0: Vec<i32> = (0..n).map(|_| *loader.next_example()).collect();
        let e1: Vec<i32> = (0..n).map(|_| *loader.next_example()).collect();
        assert_ne!(e0, e1, "epoch orders should differ");
        let mut s0 = e0.clone();
        let mut s1 = e1.clone();
        s0.sort_unstable();
        s1.sort_unstable();
        assert_eq!(s0, s1, "same multiset each epoch");
    }

    #[test]
    fn prop_loader_invariants() {
        check_res(
            21,
            60,
            |rng| (rng.range(2, 80), rng.f64() * 0.4, rng.next_u64()),
            |&(n, frac, seed)| {
                let mut l = Loader::new((0..n as i32).collect::<Vec<_>>(), frac, seed);
                if l.train_len() == 0 {
                    return Err("empty train split".into());
                }
                if l.train_len() + l.heldout().len() != n {
                    return Err("split not a partition".into());
                }
                // two epochs worth of draws never touch held-out items
                let held: std::collections::HashSet<i32> =
                    l.heldout().iter().copied().collect();
                for _ in 0..2 * l.train_len() {
                    if held.contains(l.next_example()) {
                        return Err("held-out example leaked into training".into());
                    }
                }
                Ok(())
            },
        );
    }
}
