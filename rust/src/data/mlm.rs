//! BERT-style MLM masking (App. F.1 / Devlin et al.): of the 15% selected
//! positions, 80% → `<mask>`, 10% → random token, 10% → unchanged.

use crate::tokenizer::special;
use crate::util::Rng;

/// Masking hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct MlmMasking {
    pub mask_prob: f64,
    pub mask_token_frac: f64,
    pub random_frac: f64,
    /// Content vocabulary size for the "random token" replacement.
    pub vocab: usize,
}

impl Default for MlmMasking {
    fn default() -> Self {
        MlmMasking { mask_prob: 0.15, mask_token_frac: 0.8, random_frac: 0.1, vocab: 512 }
    }
}

/// One fully-assembled MLM training batch.
#[derive(Clone, Debug)]
pub struct MlmBatch {
    /// (B, S) masked input tokens.
    pub tokens: Vec<i32>,
    /// (B, S) validity.
    pub kv_valid: Vec<f32>,
    /// (B, S) original tokens (loss targets).
    pub labels: Vec<i32>,
    /// (B, S) 1.0 at predicted positions.
    pub weights: Vec<f32>,
}

/// Apply MLM masking to a padded token matrix.
///
/// `kv_valid` marks real tokens; specials (< FIRST_FREE) are never masked.
pub fn mask_tokens(
    tokens: &[i32],
    kv_valid: &[f32],
    m: &MlmMasking,
    rng: &mut Rng,
) -> MlmBatch {
    assert_eq!(tokens.len(), kv_valid.len());
    let labels = tokens.to_vec();
    let mut out = tokens.to_vec();
    let mut weights = vec![0f32; tokens.len()];
    for i in 0..tokens.len() {
        if kv_valid[i] == 0.0 || tokens[i] < special::FIRST_FREE {
            continue;
        }
        if !rng.coin(m.mask_prob) {
            continue;
        }
        weights[i] = 1.0;
        let u = rng.f64();
        if u < m.mask_token_frac {
            out[i] = special::MASK;
        } else if u < m.mask_token_frac + m.random_frac {
            let lo = special::FIRST_FREE as usize;
            out[i] = rng.range(lo, m.vocab) as i32;
        } // else: keep original, still predicted
    }
    MlmBatch { tokens: out, kv_valid: kv_valid.to_vec(), labels, weights }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(n: usize) -> (Vec<i32>, Vec<f32>) {
        let toks: Vec<i32> = (0..n).map(|i| special::FIRST_FREE + (i % 100) as i32).collect();
        let valid = vec![1f32; n];
        (toks, valid)
    }

    #[test]
    fn mask_rate_is_near_15_percent() {
        let (t, v) = setup(20_000);
        let mut rng = Rng::new(1);
        let b = mask_tokens(&t, &v, &MlmMasking::default(), &mut rng);
        let rate = b.weights.iter().sum::<f32>() / 20_000.0;
        assert!((rate - 0.15).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn masked_positions_follow_80_10_10() {
        let (t, v) = setup(50_000);
        let mut rng = Rng::new(2);
        let m = MlmMasking::default();
        let b = mask_tokens(&t, &v, &m, &mut rng);
        let (mut masked, mut random, mut kept) = (0.0f64, 0.0f64, 0.0f64);
        for i in 0..t.len() {
            if b.weights[i] == 0.0 {
                continue;
            }
            if b.tokens[i] == special::MASK {
                masked += 1.0;
            } else if b.tokens[i] == t[i] {
                kept += 1.0;
            } else {
                random += 1.0;
            }
        }
        let total = masked + random + kept;
        assert!((masked / total - 0.8).abs() < 0.03);
        // random replacements can coincide with the original id (1/vocab),
        // slightly inflating `kept`; tolerances cover it
        assert!((random / total - 0.1).abs() < 0.02);
        assert!((kept / total - 0.1).abs() < 0.02);
    }

    #[test]
    fn labels_preserve_originals_and_pads_untouched() {
        let (mut t, mut v) = setup(100);
        t[50] = special::PAD;
        v[50] = 0.0;
        let mut rng = Rng::new(3);
        let b = mask_tokens(&t, &v, &MlmMasking::default(), &mut rng);
        assert_eq!(b.labels, t);
        assert_eq!(b.tokens[50], special::PAD);
        assert_eq!(b.weights[50], 0.0);
    }

    #[test]
    fn specials_never_masked() {
        let t = vec![special::CLS; 1000];
        let v = vec![1f32; 1000];
        let mut rng = Rng::new(4);
        let b = mask_tokens(&t, &v, &MlmMasking::default(), &mut rng);
        assert_eq!(b.weights.iter().sum::<f32>(), 0.0);
    }
}
