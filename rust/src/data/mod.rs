//! Synthetic data substrates (DESIGN.md §Substitutions).
//!
//! Every generator is seeded and produces token sequences in the shared
//! id space (`tokenizer::special` + content ids). The generators plant
//! *controlled long-range structure* so that the paper's qualitative
//! claims (longer context ⇒ better MLM/QA/classification/summarization)
//! are properties of the data, not accidents.

pub mod classify;
mod corpus;
mod dna;
mod loader;
mod mlm;
mod qa;
pub mod summarize;

pub use classify::{ClassifyExample, ClassifyGen, EvidenceSpread};
pub use corpus::{CorpusConfig, CorpusGen};
pub use dna::{ChromatinExample, DnaGen, PromoterExample};
pub use loader::Loader;
pub use mlm::{mask_tokens, MlmBatch, MlmMasking};
pub use qa::{QaExample, QaGen};
pub use summarize::{SummarizeExample, SummarizeGen};

/// A generic padded batch of token sequences.
#[derive(Clone, Debug)]
pub struct TokenBatch {
    /// (B, S) row-major token ids.
    pub tokens: Vec<i32>,
    /// (B, S) 1.0/0.0 validity.
    pub kv_valid: Vec<f32>,
    pub batch: usize,
    pub seq_len: usize,
}

impl TokenBatch {
    /// Pad/truncate `seqs` to `seq_len` and stack. Panics if
    /// `seqs.len() != batch`.
    pub fn from_seqs(seqs: &[Vec<i32>], batch: usize, seq_len: usize) -> Self {
        assert_eq!(seqs.len(), batch, "batch size mismatch");
        let mut tokens = vec![crate::tokenizer::special::PAD; batch * seq_len];
        let mut kv_valid = vec![0f32; batch * seq_len];
        for (i, s) in seqs.iter().enumerate() {
            let n = s.len().min(seq_len);
            tokens[i * seq_len..i * seq_len + n].copy_from_slice(&s[..n]);
            for v in kv_valid[i * seq_len..i * seq_len + n].iter_mut() {
                *v = 1.0;
            }
        }
        TokenBatch { tokens, kv_valid, batch, seq_len }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_seqs_pads_and_truncates() {
        let seqs = vec![vec![7, 8, 9], vec![1; 20]];
        let b = TokenBatch::from_seqs(&seqs, 2, 8);
        assert_eq!(&b.tokens[0..4], &[7, 8, 9, 0]);
        assert_eq!(b.kv_valid[2], 1.0);
        assert_eq!(b.kv_valid[3], 0.0);
        assert_eq!(&b.tokens[8..16], &[1; 8]);
    }
}
