//! Synthetic document classification (stand-in for Arxiv / IMDb /
//! Hyperpartisan / Patents, Tab. 15, and the short-sequence "GLUE" check,
//! Tab. 16).
//!
//! The label is the majority topic of *signature tokens* sprinkled
//! uniformly over the document. With `spread = Late`, the discriminative
//! tokens appear only after position 512 — reproducing Tab. 15's
//! "discriminating information may not be located in the first 512
//! tokens".

use crate::tokenizer::special;
use crate::util::Rng;

use super::corpus::{CorpusConfig, CorpusGen};

/// Where the label evidence lives in the document.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvidenceSpread {
    /// Uniform over the whole document (Arxiv-like).
    Uniform,
    /// Only in the first 25% (IMDb-like short reviews — truncation safe).
    Early,
    /// Only after token 512 (worst case for truncated baselines).
    Late,
}

/// One labelled document, laid out `[CLS] doc…`.
#[derive(Clone, Debug)]
pub struct ClassifyExample {
    pub tokens: Vec<i32>,
    pub label: i32,
}

/// Generator.
pub struct ClassifyGen {
    corpus: CorpusGen,
    rng: Rng,
    pub classes: usize,
    pub spread: EvidenceSpread,
    /// signature tokens planted per document
    pub signal_tokens: usize,
}

impl ClassifyGen {
    pub fn new(vocab: usize, classes: usize, spread: EvidenceSpread, seed: u64) -> Self {
        let cfg = CorpusConfig { vocab, ..Default::default() };
        ClassifyGen {
            corpus: CorpusGen::new(cfg, seed),
            rng: Rng::new(seed).fold_in(0xC1),
            classes,
            spread,
            signal_tokens: 12,
        }
    }

    /// Signature token id for class c, slot k — distinct from corpus ids
    /// by construction (uses a dedicated low range after REL).
    fn signature(&self, c: usize, k: usize) -> i32 {
        special::FIRST_FREE + 8 + (c * 4 + (k % 4)) as i32
    }

    pub fn example(&mut self, doc_len: usize) -> ClassifyExample {
        let label = self.rng.below(self.classes);
        let mut doc = self.corpus.document(doc_len);
        // scrub signature range from filler
        let sig_lo = self.signature(0, 0);
        let sig_hi = self.signature(self.classes - 1, 3) + 1;
        for t in doc.iter_mut() {
            if *t >= sig_lo && *t < sig_hi {
                *t = special::FIRST_FREE + 1;
            }
        }
        let (lo, hi) = match self.spread {
            EvidenceSpread::Uniform => (0, doc_len),
            EvidenceSpread::Early => (0, (doc_len / 4).max(self.signal_tokens + 1)),
            EvidenceSpread::Late => {
                let lo = 512.min(doc_len.saturating_sub(self.signal_tokens + 1));
                (lo, doc_len)
            }
        };
        for k in 0..self.signal_tokens {
            let pos = self.rng.range(lo, hi);
            doc[pos] = self.signature(label, k);
        }
        let mut tokens = vec![special::CLS];
        tokens.extend_from_slice(&doc);
        ClassifyExample { tokens, label: label as i32 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_in_range_and_signatures_present() {
        let mut g = ClassifyGen::new(512, 4, EvidenceSpread::Uniform, 1);
        let ex = g.example(600);
        assert!((0..4).contains(&ex.label));
        let sig0 = g.signature(ex.label as usize, 0);
        let present = ex.tokens.iter().filter(|&&t| t >= sig0 && t < sig0 + 4).count();
        assert!(present >= g.signal_tokens / 2, "signatures missing");
    }

    #[test]
    fn late_spread_puts_evidence_beyond_512() {
        let mut g = ClassifyGen::new(512, 4, EvidenceSpread::Late, 2);
        let ex = g.example(1000);
        let sig_lo = g.signature(0, 0);
        let sig_hi = g.signature(3, 3) + 1;
        for (i, &t) in ex.tokens.iter().enumerate() {
            if t >= sig_lo && t < sig_hi {
                assert!(i > 512, "evidence at {i} <= 512");
            }
        }
    }

    #[test]
    fn early_spread_is_truncation_safe() {
        let mut g = ClassifyGen::new(512, 4, EvidenceSpread::Early, 3);
        let ex = g.example(1000);
        let sig_lo = g.signature(0, 0);
        let sig_hi = g.signature(3, 3) + 1;
        for (i, &t) in ex.tokens.iter().enumerate() {
            if t >= sig_lo && t < sig_hi {
                assert!(i <= 256, "early evidence at {i}");
            }
        }
    }

    #[test]
    fn signature_ids_do_not_collide_across_classes() {
        let g = ClassifyGen::new(512, 4, EvidenceSpread::Uniform, 4);
        let mut seen = std::collections::HashSet::new();
        for c in 0..4 {
            for k in 0..4 {
                assert!(seen.insert(g.signature(c, k)), "collision at ({c},{k})");
            }
        }
    }
}
