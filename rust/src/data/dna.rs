//! Synthetic genomics (Sec. 5 / App. F), replacing GRCh37 + EPDnew +
//! DeepSea with a controlled generator (DESIGN.md §Substitutions):
//!
//! * **genome**: order-2 Markov chain over {A,C,G,T} with rare N, giving
//!   realistic local statistics plus a long-range copy channel (paper
//!   [12]: long-range correlations in non-coding DNA),
//! * **promoters** (Tab. 6): positives plant a TATA-like motif cluster
//!   upstream of the TSS; negatives follow the exact Oubounyt et al.
//!   protocol — split the positive into 20 subsequences, randomly
//!   substitute 12, conserve 8,
//! * **chromatin profiles** (Tab. 7): 16 binary profiles in three groups;
//!   TF/DHS profiles depend on single local motifs, HM profiles require a
//!   *pair* of motifs at long distance — reproducing "HM is known to have
//!   longer-range correlations" as a property of the data.

use crate::tokenizer::special;
use crate::util::Rng;

const BASES: [char; 4] = ['A', 'C', 'G', 'T'];

/// One promoter-classification example (raw base string + label).
#[derive(Clone, Debug)]
pub struct PromoterExample {
    pub seq: String,
    pub label: bool,
}

/// One chromatin-profile example: raw bases + per-profile binary labels.
#[derive(Clone, Debug)]
pub struct ChromatinExample {
    pub seq: String,
    pub labels: Vec<bool>,
}

/// Seeded genome generator.
pub struct DnaGen {
    rng: Rng,
    /// order-2 transition temperature: larger = more structured
    skew: f64,
    pub n_profiles: usize,
}

impl DnaGen {
    pub fn new(seed: u64) -> Self {
        DnaGen { rng: Rng::new(seed).fold_in(0xD0A), skew: 2.0, n_profiles: 16 }
    }

    /// Order-2 Markov base sampler: P(b | prev2) from a deterministic
    /// per-context weight table (hash-derived, so the "genome" has real
    /// 2nd-order structure a language model can learn).
    fn next_base(&mut self, c1: usize, c2: usize) -> usize {
        let mut w = [0.0f64; 4];
        for (b, wb) in w.iter_mut().enumerate() {
            // deterministic context-dependent weights
            let h = (c1 * 31 + c2 * 7 + b * 13) % 11;
            *wb = (h as f64 / 10.0 * self.skew).exp();
        }
        self.rng.categorical(&w)
    }

    /// Generate `len` bases of genome.
    pub fn genome(&mut self, len: usize) -> String {
        let mut out = String::with_capacity(len);
        let (mut c1, mut c2) = (0usize, 1usize);
        for _ in 0..len {
            if self.rng.coin(0.001) {
                out.push('N'); // missing base (App. F: 5-char alphabet)
                continue;
            }
            let b = self.next_base(c1, c2);
            out.push(BASES[b]);
            c1 = c2;
            c2 = b;
        }
        out
    }

    // ---------------- promoters (Tab. 6) ----------------

    /// TATA-like promoter motif cluster.
    fn promoter_motif(&mut self) -> String {
        // canonical TATA box + downstream GC-rich element with light noise
        let mut m = String::from("TATAAAA");
        for _ in 0..6 {
            m.push(if self.rng.coin(0.8) { 'G' } else { 'C' });
        }
        m
    }

    /// A positive promoter sequence of length `len`: motif planted in the
    /// "upstream" third of the fragment (paper: −5000..+3000 around TSS).
    pub fn promoter_positive(&mut self, len: usize) -> String {
        let mut seq: Vec<char> = self.genome(len).chars().collect();
        let motif: Vec<char> = self.promoter_motif().chars().collect();
        let lo = len / 6;
        let hi = len / 3;
        let pos = self.rng.range(lo, hi - motif.len());
        seq[pos..pos + motif.len()].copy_from_slice(&motif);
        seq.into_iter().collect()
    }

    /// Oubounyt et al. negative: split into 20 subsequences, substitute
    /// 12 random ones with random sequence, conserve 8.
    pub fn promoter_negative_from(&mut self, positive: &str) -> String {
        let chars: Vec<char> = positive.chars().collect();
        let n = chars.len();
        let k = 20;
        let sub = n / k;
        let replace_idx = self.rng.sample_distinct(k, 12);
        let mut out = chars.clone();
        for &i in &replace_idx {
            let start = i * sub;
            let end = if i == k - 1 { n } else { (i + 1) * sub };
            for c in out.iter_mut().take(end).skip(start) {
                *c = BASES[self.rng.below(4)];
            }
        }
        out.into_iter().collect()
    }

    /// Balanced promoter dataset.
    pub fn promoter_dataset(&mut self, count: usize, len: usize) -> Vec<PromoterExample> {
        let mut out = Vec::with_capacity(count);
        for i in 0..count {
            if i % 2 == 0 {
                out.push(PromoterExample { seq: self.promoter_positive(len), label: true });
            } else {
                let pos = self.promoter_positive(len);
                out.push(PromoterExample {
                    seq: self.promoter_negative_from(&pos),
                    label: false,
                });
            }
        }
        out
    }

    // ---------------- chromatin profiles (Tab. 7) ----------------

    /// Profile-specific motif (8 bases, deterministic per profile).
    fn profile_motif(&self, p: usize) -> String {
        let mut rng = Rng::new(0xBEEF).fold_in(p as u64);
        (0..8).map(|_| BASES[rng.below(4)]).collect()
    }

    /// Group of profile `p`: 0..8 = TF, 8..12 = DHS, 12..16 = HM.
    pub fn profile_group(&self, p: usize) -> &'static str {
        match p {
            x if x < 8 => "TF",
            x if x < 12 => "DHS",
            _ => "HM",
        }
    }

    /// One chromatin example of length `len`; each profile is active with
    /// ~25% probability. TF/DHS plant one motif anywhere; HM plants a
    /// *pair* of motifs separated by at least `len/2` (long-range).
    /// Plants never overlap (an occupied-interval tracker guarantees the
    /// labels stay faithful to the sequence).
    pub fn chromatin_example(&mut self, len: usize) -> ChromatinExample {
        let mut seq: Vec<char> = self.genome(len).chars().collect();
        let mut labels = vec![false; self.n_profiles];
        let mut occupied: Vec<(usize, usize)> = Vec::new();
        let place = |rng: &mut Rng, lo: usize, hi: usize, l: usize,
                         occupied: &mut Vec<(usize, usize)>|
         -> Option<usize> {
            for _ in 0..64 {
                let pos = rng.range(lo, hi - l);
                if occupied.iter().all(|&(s, e)| pos + l <= s || pos >= e) {
                    occupied.push((pos, pos + l));
                    return Some(pos);
                }
            }
            None
        };
        for p in 0..self.n_profiles {
            if !self.rng.coin(0.25) {
                continue;
            }
            let motif: Vec<char> = self.profile_motif(p).chars().collect();
            let l = motif.len();
            if self.profile_group(p) == "HM" {
                // paired long-range plant: first half + second half
                let (Some(p1), Some(p2)) = (
                    place(&mut self.rng, 0, len / 2, l, &mut occupied),
                    place(&mut self.rng, len / 2, len, l, &mut occupied),
                ) else {
                    continue;
                };
                seq[p1..p1 + l].copy_from_slice(&motif);
                seq[p2..p2 + l].copy_from_slice(&motif);
            } else {
                let Some(pos) = place(&mut self.rng, 0, len, l, &mut occupied) else {
                    continue;
                };
                seq[pos..pos + l].copy_from_slice(&motif);
            }
            labels[p] = true;
        }
        ChromatinExample { seq: seq.into_iter().collect(), labels }
    }
}

/// Encode a base string to token ids with a fixed 5-symbol vocabulary
/// (used before BPE training, and by tests).
pub fn encode_bases(seq: &str) -> Vec<i32> {
    seq.chars()
        .map(|c| match c {
            'A' => special::FIRST_FREE,
            'C' => special::FIRST_FREE + 1,
            'G' => special::FIRST_FREE + 2,
            'T' => special::FIRST_FREE + 3,
            _ => special::FIRST_FREE + 4,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn genome_is_acgt_with_rare_n() {
        let mut g = DnaGen::new(1);
        let s = g.genome(10_000);
        assert_eq!(s.len(), 10_000);
        let n_count = s.chars().filter(|&c| c == 'N').count();
        assert!(n_count < 50, "too many N: {n_count}");
        assert!(s.chars().all(|c| "ACGTN".contains(c)));
    }

    #[test]
    fn genome_has_second_order_structure() {
        // the Markov chain must NOT be uniform: some trigrams much more
        // frequent than others
        let mut g = DnaGen::new(2);
        let s: Vec<usize> = g
            .genome(50_000)
            .chars()
            .filter(|&c| c != 'N')
            .map(|c| BASES.iter().position(|&b| b == c).unwrap())
            .collect();
        let mut tri = [0usize; 64];
        for w in s.windows(3) {
            tri[w[0] * 16 + w[1] * 4 + w[2]] += 1;
        }
        let max = *tri.iter().max().unwrap() as f64;
        let min = *tri.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) > 3.0, "genome looks uniform");
    }

    #[test]
    fn promoter_positive_contains_tata() {
        let mut g = DnaGen::new(3);
        let p = g.promoter_positive(1000);
        assert!(p.contains("TATAAAA"), "motif missing");
    }

    #[test]
    fn negative_conserves_40_percent() {
        let mut g = DnaGen::new(4);
        let pos = g.promoter_positive(1000);
        let neg = g.promoter_negative_from(&pos);
        let same = pos
            .chars()
            .zip(neg.chars())
            .filter(|(a, b)| a == b)
            .count();
        // 8/20 conserved exactly + ~25% chance agreement on the rest
        let frac = same as f64 / 1000.0;
        assert!(frac > 0.45 && frac < 0.75, "conservation {frac}");
    }

    #[test]
    fn hm_profiles_have_long_range_motif_pairs() {
        let mut g = DnaGen::new(5);
        for _ in 0..40 {
            let ex = g.chromatin_example(2000);
            for p in 12..16 {
                if ex.labels[p] {
                    let motif = g.profile_motif(p);
                    let first = ex.seq.find(&motif);
                    let last = ex.seq.rfind(&motif);
                    let (Some(a), Some(b)) = (first, last) else { continue };
                    assert!(b >= 1000 && a < 1000, "HM pair not long-range: {a},{b}");
                }
            }
        }
    }

    #[test]
    fn encode_bases_maps_correctly() {
        let ids = encode_bases("ACGTN");
        assert_eq!(
            ids,
            vec![
                special::FIRST_FREE,
                special::FIRST_FREE + 1,
                special::FIRST_FREE + 2,
                special::FIRST_FREE + 3,
                special::FIRST_FREE + 4
            ]
        );
    }
}
