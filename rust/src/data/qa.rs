//! Synthetic multi-hop span QA over long evidence (stand-in for
//! HotpotQA / Natural Questions / TriviaQA, Sec. 4).
//!
//! Construction: a document of filler text contains planted *facts*
//! `[e_a REL e_b]`. The question names a head entity `e_q`; answering
//! requires following `e_q → e_m → e_ans` across TWO facts planted at
//! independent random positions (multi-hop, HotpotQA-style), then
//! returning the span of `e_ans`'s *definition phrase*.
//!
//! The second fact is planted uniformly over the whole document, so a
//! model truncated to 512 tokens loses it for long documents — exactly
//! the mechanism behind Tab. 2/3's "longer context wins" rows.

use crate::tokenizer::special;
use crate::util::Rng;

use super::corpus::{CorpusConfig, CorpusGen};

/// One QA example, already laid out as `[CLS] q [SEP] doc [SEP]`.
#[derive(Clone, Debug)]
pub struct QaExample {
    pub tokens: Vec<i32>,
    /// gold answer span in token coordinates, half-open.
    pub span: (usize, usize),
}

/// Generator.
pub struct QaGen {
    corpus: CorpusGen,
    rng: Rng,
    vocab: usize,
    /// definition phrase length (the answer span length)
    pub def_len: usize,
}

const REL: i32 = special::FIRST_FREE; // reserve one content id as "REL"

impl QaGen {
    pub fn new(vocab: usize, seed: u64) -> Self {
        let cfg = CorpusConfig { vocab, ..Default::default() };
        QaGen {
            corpus: CorpusGen::new(cfg, seed),
            rng: Rng::new(seed).fold_in(0x9A),
            vocab,
            def_len: 4,
        }
    }

    fn entity(&mut self) -> i32 {
        // entities drawn from the upper half of the vocab
        let lo = self.vocab / 2;
        self.rng.range(lo, self.vocab) as i32
    }

    /// Generate one example whose document fills `doc_len` tokens and
    /// whose final sequence is exactly `seq_len` (padded by caller).
    ///
    /// Layout: `[CLS] e_q <sep> filler… [e_q REL e_m] … [e_m REL e_ans]
    /// … e_ans def-phrase … <sep>`.
    pub fn example(&mut self, seq_len: usize, doc_len: usize) -> QaExample {
        assert!(doc_len + 8 <= seq_len + doc_len); // sanity
        let e_q = self.entity();
        let mut e_m = self.entity();
        while e_m == e_q {
            e_m = self.entity();
        }
        let mut e_ans = self.entity();
        while e_ans == e_q || e_ans == e_m {
            e_ans = self.entity();
        }

        let mut doc = self.corpus.document(doc_len);
        // scrub accidental occurrences of the three entities from filler
        for t in doc.iter_mut() {
            if *t == e_q || *t == e_m || *t == e_ans || *t == REL {
                *t = special::FIRST_FREE + 1;
            }
        }

        // plant fact1 [e_q REL e_m], fact2 [e_m REL e_ans], and the answer
        // definition "e_ans d d d d" at three non-overlapping positions
        let fact_len = 3;
        let def_total = 1 + self.def_len;
        let slots = self.place_nonoverlapping(
            doc_len,
            &[fact_len, fact_len, def_total],
        );
        let (p1, p2, pd) = (slots[0], slots[1], slots[2]);
        doc[p1] = e_q;
        doc[p1 + 1] = REL;
        doc[p1 + 2] = e_m;
        doc[p2] = e_m;
        doc[p2 + 1] = REL;
        doc[p2 + 2] = e_ans;
        doc[pd] = e_ans;
        for i in 0..self.def_len {
            // definition phrase: distinctive low-range tokens
            doc[pd + 1 + i] = special::FIRST_FREE + 2 + (i as i32);
        }

        // final layout
        let mut tokens = vec![special::CLS, e_q, special::SEP];
        let off = tokens.len();
        tokens.extend_from_slice(&doc);
        tokens.push(special::SEP);
        // the gold span is the definition phrase (incl. the entity mention)
        let span = (off + pd, off + pd + def_total);
        QaExample { tokens, span }
    }

    /// Choose non-overlapping slot starts for pieces of given lengths.
    fn place_nonoverlapping(&mut self, doc_len: usize, lens: &[usize]) -> Vec<usize> {
        loop {
            let starts: Vec<usize> = lens
                .iter()
                .map(|&l| self.rng.below(doc_len - l))
                .collect();
            let mut ivs: Vec<(usize, usize)> = starts
                .iter()
                .zip(lens)
                .map(|(&s, &l)| (s, s + l))
                .collect();
            ivs.sort_unstable();
            if ivs.windows(2).all(|w| w[0].1 <= w[1].0) {
                return starts;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example_has_consistent_span() {
        let mut g = QaGen::new(512, 1);
        for _ in 0..20 {
            let ex = g.example(1024, 900);
            let (s, e) = ex.span;
            assert!(e <= ex.tokens.len());
            assert!(e - s == 1 + g.def_len);
            // span begins with an entity (upper vocab half)
            assert!(ex.tokens[s] >= 256);
            // definition phrase follows
            assert_eq!(ex.tokens[s + 1], special::FIRST_FREE + 2);
        }
    }

    #[test]
    fn multihop_chain_present_exactly_once() {
        let mut g = QaGen::new(512, 2);
        let ex = g.example(1024, 900);
        let e_q = ex.tokens[1];
        // count occurrences of e_q in the doc: exactly 1 (the fact)
        let n = ex.tokens[3..].iter().filter(|&&t| t == e_q).count();
        assert_eq!(n, 1, "head entity must appear exactly once in evidence");
    }

    #[test]
    fn answers_land_beyond_512_often_for_long_docs() {
        let mut g = QaGen::new(512, 3);
        let beyond = (0..200)
            .filter(|_| g.example(1024, 900).span.0 >= 512)
            .count();
        // uniform placement ⇒ ~43% beyond 512 for doc_len 900
        assert!(beyond > 50, "only {beyond}/200 spans beyond 512");
    }

    #[test]
    fn deterministic() {
        let mut a = QaGen::new(512, 9);
        let mut b = QaGen::new(512, 9);
        let (x, y) = (a.example(512, 400), b.example(512, 400));
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.span, y.span);
    }
}
