//! Configuration for model variants, serving, and training runs.
//!
//! The Rust side never builds models itself — shapes are fixed at AOT
//! time — but the coordinator, data pipeline, and experiment harnesses all
//! need to agree with the Python compile path on hyperparameters. The
//! canonical config values live here and in `python/compile/configs.py`;
//! `tests/manifest_contract.rs` checks the two stay in sync through the
//! artifact manifest.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::runtime::BackendSpec;

/// Which attention pattern a model variant uses. Mirrors
/// `python/compile/configs.py::ATTN_VARIANTS` and Sec. 2 / Table 1 of the
/// paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AttnVariant {
    /// Full quadratic attention (BERT baseline).
    Dense,
    /// Random block attention only (Table 1 "R").
    Random,
    /// Sliding-window block attention only (Table 1 "W").
    Window,
    /// Random + window (Table 1 "R + W").
    RandomWindow,
    /// Window + global, no random — ≈ Longformer's pattern (App. E.3).
    WindowGlobal,
    /// BigBird-ITC: global tokens are existing tokens (first g blocks).
    BigBirdItc,
    /// BigBird-ETC: extra global tokens prepended to the sequence.
    BigBirdEtc,
}

impl AttnVariant {
    /// Manifest string, matching the Python side.
    pub fn as_str(self) -> &'static str {
        match self {
            AttnVariant::Dense => "dense",
            AttnVariant::Random => "random",
            AttnVariant::Window => "window",
            AttnVariant::RandomWindow => "random_window",
            AttnVariant::WindowGlobal => "window_global",
            AttnVariant::BigBirdItc => "bigbird_itc",
            AttnVariant::BigBirdEtc => "bigbird_etc",
        }
    }

    /// Parse a manifest string.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "dense" => AttnVariant::Dense,
            "random" => AttnVariant::Random,
            "window" => AttnVariant::Window,
            "random_window" => AttnVariant::RandomWindow,
            "window_global" => AttnVariant::WindowGlobal,
            "bigbird_itc" => AttnVariant::BigBirdItc,
            "bigbird_etc" => AttnVariant::BigBirdEtc,
            other => bail!("unknown attention variant {other:?}"),
        })
    }

    /// All variants, in Table 1 presentation order.
    pub fn all() -> [AttnVariant; 7] {
        [
            AttnVariant::Dense,
            AttnVariant::Random,
            AttnVariant::Window,
            AttnVariant::RandomWindow,
            AttnVariant::WindowGlobal,
            AttnVariant::BigBirdItc,
            AttnVariant::BigBirdEtc,
        ]
    }

    /// Is this a sparse (linear-complexity) pattern?
    pub fn is_sparse(self) -> bool {
        !matches!(self, AttnVariant::Dense)
    }
}

/// Numeric policy of the model GEMM layer (projections, FFN, tied
/// logits): the storage/compute precision `kernel::microkernel`'s
/// packed tiles run at. Master weights and every gradient stay f32
/// regardless (quantize-on-pack), so `BBCKPT1` checkpoints are
/// precision-agnostic and the fingerprint deliberately excludes this
/// field. See rust/README.md "Precision policy" for the error budgets.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Full f32 storage and compute — bit-identical to the retired
    /// naive matmul path (the parity tests pin this).
    #[default]
    F32,
    /// f16 **storage** of the packed weight operand, f32 compute:
    /// halves weight memory traffic on the bandwidth-bound FFN/logits
    /// GEMMs at ~2⁻¹⁰ relative element error.
    F16,
    /// Symmetric int8: per-row activation scales (quantized at call
    /// time) × per-column weight scales (quantized at pack time),
    /// i8×i8→i32 dot tiles, f32 dequant epilogue.
    Int8,
}

impl Precision {
    /// CLI / override string.
    pub fn as_str(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F16 => "f16",
            Precision::Int8 => "int8",
        }
    }

    /// Parse a CLI / override string.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => Precision::F32,
            "f16" => Precision::F16,
            "int8" => Precision::Int8,
            other => bail!("unknown precision {other:?} (expected f32|f16|int8)"),
        })
    }

    /// All modes, from full precision down to the coarsest error budget.
    pub fn all() -> [Precision; 3] {
        [Precision::F32, Precision::F16, Precision::Int8]
    }
}

/// How the model picks its sparse attention pattern — the config-level
/// face of `attention::select::PatternSource` (`--pattern` CLI flag).
///
/// `k` is the per-query-block selection budget of the adaptive/learned
/// kinds; `0` means "inherit `random_blocks`", which keeps the block
/// budget identical to the static pattern (the selected blocks replace
/// the seeded-random ones, never add to them). The selection kind and
/// resolved `k` are part of the checkpoint fingerprint: a `Learned`
/// model carries extra per-head score parameters, so its checkpoints
/// must not silently load into a `Static` architecture.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum PatternSelect {
    /// The paper's fixed band + global + seeded-random pattern.
    #[default]
    Static,
    /// Content-adaptive: block-mean-pooled Q/K proxy scores pick the
    /// top-k key blocks per head, unioned with band + global.
    Adaptive {
        /// Selected blocks per query row (0 = `random_blocks`).
        k: usize,
    },
    /// Learned: trainable per-head relative-offset block scores pick
    /// the top-k, unioned with band + global.
    Learned {
        /// Selected blocks per query row (0 = `random_blocks`).
        k: usize,
    },
}

impl PatternSelect {
    /// CLI / override string: `static`, `adaptive`, `learned`, each
    /// optionally suffixed `:k=<n>`.
    pub fn parse(s: &str) -> Result<Self> {
        let (kind, k) = match s.split_once(':') {
            None => (s, 0usize),
            Some((kind, rest)) => {
                let k = rest
                    .strip_prefix("k=")
                    .with_context(|| {
                        format!("pattern argument {rest:?} must be k=<n> (e.g. adaptive:k=3)")
                    })?
                    .parse::<usize>()
                    .with_context(|| format!("pattern k in {s:?} is not a number"))?;
                (kind, k)
            }
        };
        Ok(match kind {
            "static" => {
                if k != 0 {
                    bail!("the static pattern takes no k (it keeps random_blocks)");
                }
                PatternSelect::Static
            }
            "adaptive" => PatternSelect::Adaptive { k },
            "learned" => PatternSelect::Learned { k },
            other => bail!("unknown pattern kind {other:?} (expected static|adaptive|learned[:k=..])"),
        })
    }

    /// Render back to the CLI syntax (`parse` round-trips it).
    pub fn label(self) -> String {
        match self {
            PatternSelect::Static => "static".to_string(),
            PatternSelect::Adaptive { k: 0 } => "adaptive".to_string(),
            PatternSelect::Adaptive { k } => format!("adaptive:k={k}"),
            PatternSelect::Learned { k: 0 } => "learned".to_string(),
            PatternSelect::Learned { k } => format!("learned:k={k}"),
        }
    }

    /// Fingerprint-stable kind index (0 static, 1 adaptive, 2 learned).
    pub fn kind_index(self) -> usize {
        match self {
            PatternSelect::Static => 0,
            PatternSelect::Adaptive { .. } => 1,
            PatternSelect::Learned { .. } => 2,
        }
    }

    /// The per-row selection budget, with `k = 0` resolved to
    /// `random_blocks` (equal block budget vs the static pattern).
    pub fn budget(self, random_blocks: usize) -> usize {
        match self {
            PatternSelect::Static => 0,
            PatternSelect::Adaptive { k } | PatternSelect::Learned { k } => {
                if k == 0 {
                    random_blocks
                } else {
                    k
                }
            }
        }
    }

    /// Does this kind carry trainable selection parameters?
    pub fn is_learned(self) -> bool {
        matches!(self, PatternSelect::Learned { .. })
    }
}

/// BigBird model hyperparameters (App. E.1, Tab. 8, scaled down for the
/// CPU testbed — see DESIGN.md §Substitutions).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Attention pattern.
    pub variant: AttnVariant,
    /// Sequence length (multiple of `block`).
    pub seq_len: usize,
    /// Attention block size `b` (paper: 64; we default to 16 at small scale).
    pub block: usize,
    /// Number of global blocks `g/b`.
    pub global_blocks: usize,
    /// Window size in blocks `w/b` (odd; paper: 3).
    pub window_blocks: usize,
    /// Number of random blocks `r/b` per query block (paper: 3).
    pub random_blocks: usize,
    /// Transformer depth.
    pub layers: usize,
    /// Attention heads.
    pub heads: usize,
    /// Model width.
    pub hidden: usize,
    /// FFN width.
    pub ffn: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Batch size baked into the artifact.
    pub batch: usize,
    /// Seed for the random-attention pattern (shared with Python).
    pub attn_seed: u64,
    /// GEMM precision policy for the model-math hot paths (`--precision`).
    /// Runtime-only: excluded from the checkpoint fingerprint, so any
    /// mode serves/trains against the same `BBCKPT1` checkpoints.
    pub precision: Precision,
    /// How the sparse attention pattern is chosen (`--pattern`). The
    /// `Static` default keeps the paper's fixed pattern and the Python
    /// cross-language contract bit-exact; adaptive/learned kinds change
    /// the architecture fingerprint (learned adds parameters), so they
    /// need matching checkpoints.
    pub pattern: PatternSelect,
}

impl ModelConfig {
    /// The "tiny" configuration used by fast unit/integration tests.
    pub fn tiny() -> Self {
        ModelConfig {
            variant: AttnVariant::BigBirdItc,
            seq_len: 128,
            block: 16,
            global_blocks: 1,
            window_blocks: 3,
            random_blocks: 1,
            layers: 2,
            heads: 2,
            hidden: 64,
            ffn: 128,
            vocab: 512,
            batch: 4,
            attn_seed: 0,
            precision: Precision::F32,
            pattern: PatternSelect::Static,
        }
    }

    /// The "base" configuration used by the end-to-end training example
    /// and most experiment tables (a scaled-down BigBird-base).
    pub fn base() -> Self {
        ModelConfig {
            variant: AttnVariant::BigBirdItc,
            seq_len: 512,
            block: 16,
            global_blocks: 2,
            window_blocks: 3,
            random_blocks: 3,
            layers: 4,
            heads: 4,
            hidden: 128,
            ffn: 512,
            vocab: 2048,
            batch: 8,
            attn_seed: 0,
            precision: Precision::F32,
            pattern: PatternSelect::Static,
        }
    }

    /// The configuration the **native kernel backend** serves
    /// (`--backends native:N`): the tiny BigBird-ITC family, sized so a
    /// pure-Rust forward pass stays interactive on a CPU-only machine.
    /// `seq_len`/`batch` here are the largest bucket — the native engine
    /// runs each serving bucket's own `(batch, seq_len)` against the
    /// same parameters.
    pub fn native_serving() -> Self {
        ModelConfig { seq_len: 2048, batch: 1, ..Self::tiny() }
    }

    /// The configuration `train --backends native` pretrains
    /// (overridable with `--config`): the same parameter family as
    /// [`ModelConfig::native_serving`] — identical architecture
    /// fingerprint, so its checkpoints install directly into the native
    /// serving pool — at the tiny training shape
    /// (`batch × seq_len = 4 × 128` per step).
    pub fn native_train() -> Self {
        Self::tiny()
    }

    /// Number of blocks in the sequence.
    pub fn num_blocks(&self) -> usize {
        self.seq_len / self.block
    }

    /// Per-head dimension.
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Validate invariants shared with the Python compile path.
    pub fn validate(&self) -> Result<()> {
        if self.seq_len % self.block != 0 {
            bail!("seq_len {} not a multiple of block {}", self.seq_len, self.block);
        }
        if self.window_blocks % 2 == 0 {
            bail!("window_blocks {} must be odd", self.window_blocks);
        }
        if self.hidden % self.heads != 0 {
            bail!("hidden {} not divisible by heads {}", self.hidden, self.heads);
        }
        let nb = self.num_blocks();
        if self.global_blocks + self.window_blocks + self.random_blocks > nb {
            bail!(
                "pattern ({} g + {} w + {} r blocks) exceeds {} sequence blocks",
                self.global_blocks,
                self.window_blocks,
                self.random_blocks,
                nb
            );
        }
        Ok(())
    }

    /// Attended key blocks per query block (g + w + r) — the linear factor
    /// in BigBird's O(n) complexity.
    pub fn attended_blocks(&self) -> usize {
        self.global_blocks + self.window_blocks + self.random_blocks
    }

    /// FLOPs estimate of one attention layer forward pass, for roofline
    /// accounting (2·n·k·d per score + weighted sum, across heads).
    pub fn attention_flops(&self) -> u64 {
        let n = self.seq_len as u64;
        let d = self.head_dim() as u64;
        let h = self.heads as u64;
        let keys_per_query = match self.variant {
            AttnVariant::Dense => n,
            _ => (self.attended_blocks() * self.block) as u64,
        };
        // QK^T (2ndk) + softmax(V) (2ndk), per head
        4 * h * n * keys_per_query * d
    }

    /// Name of the artifact for a given program kind, matching aot.py's
    /// naming scheme: `{kind}_{variant}_s{seq}_b{batch}`.
    pub fn artifact_name(&self, kind: &str) -> String {
        format!("{kind}_{}_s{}_b{}", self.variant.as_str(), self.seq_len, self.batch)
    }
}

/// Engine-pool shape for the serving coordinator: the backend of every
/// PJRT worker thread that executes batches (one [`BackendSpec`] per
/// worker — mix kinds for a heterogeneous pool), and how many batches
/// per bucket may be in flight at once (the pipelining depth). Mirrors
/// the `--backends` / `--engine-workers` / `--max-inflight` CLI flags;
/// flows into `ServerConfig`. With one CPU worker and `max_inflight: 1`
/// the coordinator degenerates to the original single-inflight loop.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServingConfig {
    /// Backend of each engine worker thread (each owns its own PJRT
    /// runtime). `BackendSpec::cpu_workers(n)` reproduces the PR 1
    /// homogeneous `engine_workers: n` shape.
    pub backends: Vec<BackendSpec>,
    /// Per-bucket cap on dispatched-but-incomplete batches.
    pub max_inflight: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig { backends: vec![BackendSpec::cpu()], max_inflight: 2 }
    }
}

impl ServingConfig {
    /// A homogeneous pool of `n` CPU workers (the PR 1 shape).
    pub fn cpu(engine_workers: usize, max_inflight: usize) -> Self {
        ServingConfig { backends: BackendSpec::cpu_workers(engine_workers), max_inflight }
    }

    /// A homogeneous pool of `n` native-kernel workers — real in-process
    /// compute, zero PJRT artifacts required.
    pub fn native(engine_workers: usize, max_inflight: usize) -> Self {
        ServingConfig { backends: BackendSpec::native_workers(engine_workers), max_inflight }
    }

    /// Number of engine workers the config spawns.
    pub fn n_workers(&self) -> usize {
        self.backends.len()
    }

    /// Validate invariants (at least one worker, inflight cap ≥ 1).
    pub fn validate(&self) -> Result<()> {
        if self.backends.is_empty() {
            bail!("serving config names no engine workers (need at least one backend)");
        }
        if self.max_inflight == 0 {
            bail!("max_inflight must be >= 1");
        }
        Ok(())
    }
}

/// Admission-control knobs for the serving front end: the policy every
/// request — wire or in-process — passes through before it may enter
/// the batcher. Mirrors the `serve --latency-budget-ms` / `--max-queue`
/// CLI flags; flows into `ServerConfig`. The hard queue bound is what
/// keeps server memory flat under overload: past it, requests are shed
/// with a typed `Overloaded`-family response instead of queued.
#[derive(Clone, Debug, PartialEq)]
pub struct AdmissionConfig {
    /// Soft latency budget: when the observed request queue-wait EWMA
    /// exceeds this many milliseconds (and at least `pressure_floor`
    /// requests are outstanding), `Normal`/`Low`-priority requests are
    /// shed as `Overloaded`. `None` disables budget shedding — the hard
    /// queue bound below still applies.
    pub latency_budget_ms: Option<f64>,
    /// Hard bound on admitted-but-unanswered requests across all
    /// clients. Admission past it sheds `QueueFull` regardless of
    /// priority, so queue memory stays bounded no matter the offered
    /// load.
    pub max_queue: usize,
    /// Per-client bound on admitted-but-unanswered requests: one greedy
    /// pipelining client is shed `ClientLimit` past it instead of
    /// crowding every other client out of the shared queue budget.
    pub max_client_inflight: usize,
    /// Minimum outstanding requests before budget/deadline shedding may
    /// fire, so a stale (post-spike) queue-wait EWMA never sheds on an
    /// otherwise idle server.
    pub pressure_floor: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            latency_budget_ms: None,
            max_queue: 1024,
            max_client_inflight: 128,
            pressure_floor: 8,
        }
    }
}

impl AdmissionConfig {
    /// Validate invariants (nonzero bounds, a finite positive budget).
    pub fn validate(&self) -> Result<()> {
        if self.max_queue == 0 {
            bail!("max_queue must be >= 1");
        }
        if self.max_client_inflight == 0 {
            bail!("max_client_inflight must be >= 1");
        }
        if let Some(b) = self.latency_budget_ms {
            if !b.is_finite() || b < 0.0 {
                bail!("latency budget must be a finite, non-negative ms value (got {b})");
            }
        }
        Ok(())
    }
}

/// Observability knobs for the serving coordinator: request-span
/// tracing (`serve --trace-out`), kernel-phase profiling, and the
/// continuous-telemetry layer (sampler thread, watchdog, flight
/// recorder). Everything is off by default so timing-sensitive paths
/// (benches, tests) pay one relaxed atomic load per instrumentation
/// site; flows into `ServerConfig`.
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// Record request spans (submission → response, with per-stage
    /// children) for export as Chrome trace-event JSON.
    pub trace: bool,
    /// Per-thread span ring capacity, in spans (~64 B each). Fixed at
    /// the first enable of the process.
    pub trace_ring: usize,
    /// Accumulate per-phase kernel counters (pack/QKᵀ/softmax/AV/
    /// backward/GEMM) so metrics can report achieved-vs-roofline
    /// utilization.
    pub phase_profile: bool,
    /// Telemetry sampler interval in milliseconds; 0 disables the
    /// sampler thread (and with it the series ring, the watchdog, and
    /// window metrics in the Prometheus exposition). `serve` defaults
    /// this to `obs::timeseries::DEFAULT_INTERVAL_MS` (1 s).
    pub sampler_interval_ms: u64,
    /// Time-series ring retention, in samples (min 2 when sampling).
    pub series_capacity: usize,
    /// Arms the watchdog's SLO-burn detector: sustained window p99
    /// above this many ms is an anomaly. `None` leaves it unarmed.
    pub slo_p99_ms: Option<f64>,
    /// Directory for watchdog flight-recorder bundles; `None` disables
    /// dumping (detectors still flip `/healthz`).
    pub flight_dir: Option<String>,
    /// Fault injection for tests/CI: the router stops dispatching
    /// batches, so admitted requests queue forever — a genuine worker
    /// stall for the watchdog to catch. Never set in production.
    pub fault_stall: bool,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            trace: false,
            trace_ring: crate::obs::trace::DEFAULT_RING_CAPACITY,
            phase_profile: false,
            sampler_interval_ms: 0,
            series_capacity: crate::obs::timeseries::DEFAULT_CAPACITY,
            slo_p99_ms: None,
            flight_dir: None,
            fault_stall: false,
        }
    }
}

impl ObsConfig {
    /// Validate invariants (a non-empty span ring, sane sampler knobs).
    pub fn validate(&self) -> Result<()> {
        if self.trace && self.trace_ring == 0 {
            bail!("trace_ring must be >= 1 when tracing is enabled");
        }
        if self.sampler_interval_ms > 0 && self.series_capacity < 2 {
            bail!("series_capacity must be >= 2 when the sampler is enabled");
        }
        if let Some(slo) = self.slo_p99_ms {
            if !slo.is_finite() || slo <= 0.0 {
                bail!("slo_p99_ms must be a finite, positive ms value (got {slo})");
            }
        }
        Ok(())
    }
}

/// Parse a `key=value,key=value` override string onto a base config (CLI
/// `--config` flag).
pub fn apply_overrides(mut cfg: ModelConfig, overrides: &str) -> Result<ModelConfig> {
    let mut map = BTreeMap::new();
    for pair in overrides.split(',').filter(|s| !s.is_empty()) {
        let (k, v) = pair
            .split_once('=')
            .with_context(|| format!("override {pair:?} is not key=value"))?;
        map.insert(k.trim().to_string(), v.trim().to_string());
    }
    for (k, v) in map {
        match k.as_str() {
            "variant" => cfg.variant = AttnVariant::parse(&v)?,
            "seq_len" => cfg.seq_len = v.parse()?,
            "block" => cfg.block = v.parse()?,
            "global_blocks" => cfg.global_blocks = v.parse()?,
            "window_blocks" => cfg.window_blocks = v.parse()?,
            "random_blocks" => cfg.random_blocks = v.parse()?,
            "layers" => cfg.layers = v.parse()?,
            "heads" => cfg.heads = v.parse()?,
            "hidden" => cfg.hidden = v.parse()?,
            "ffn" => cfg.ffn = v.parse()?,
            "vocab" => cfg.vocab = v.parse()?,
            "batch" => cfg.batch = v.parse()?,
            "attn_seed" => cfg.attn_seed = v.parse()?,
            "precision" => cfg.precision = Precision::parse(&v)?,
            "pattern" => cfg.pattern = PatternSelect::parse(&v)?,
            other => bail!("unknown config key {other:?}"),
        }
    }
    cfg.validate()?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_and_tiny_validate() {
        ModelConfig::tiny().validate().unwrap();
        ModelConfig::base().validate().unwrap();
    }

    #[test]
    fn variant_roundtrip() {
        for v in AttnVariant::all() {
            assert_eq!(AttnVariant::parse(v.as_str()).unwrap(), v);
        }
        assert!(AttnVariant::parse("bogus").is_err());
    }

    #[test]
    fn flops_linear_vs_quadratic() {
        let mut sparse = ModelConfig::base();
        let mut dense = ModelConfig::base();
        dense.variant = AttnVariant::Dense;
        // doubling seq_len doubles sparse flops but quadruples dense flops
        let f1s = sparse.attention_flops();
        let f1d = dense.attention_flops();
        sparse.seq_len *= 2;
        dense.seq_len *= 2;
        assert_eq!(sparse.attention_flops(), 2 * f1s);
        assert_eq!(dense.attention_flops(), 4 * f1d);
    }

    #[test]
    fn overrides_apply_and_validate() {
        let cfg = apply_overrides(ModelConfig::base(), "seq_len=1024,layers=2").unwrap();
        assert_eq!(cfg.seq_len, 1024);
        assert_eq!(cfg.layers, 2);
        assert!(apply_overrides(ModelConfig::base(), "seq_len=100").is_err()); // not mult of block
        assert!(apply_overrides(ModelConfig::base(), "nope=1").is_err());
    }

    #[test]
    fn precision_roundtrip_and_override() {
        for p in Precision::all() {
            assert_eq!(Precision::parse(p.as_str()).unwrap(), p);
        }
        assert!(Precision::parse("fp64").is_err());
        assert_eq!(Precision::default(), Precision::F32);
        let cfg = apply_overrides(ModelConfig::tiny(), "precision=int8").unwrap();
        assert_eq!(cfg.precision, Precision::Int8);
        assert!(apply_overrides(ModelConfig::tiny(), "precision=bf16").is_err());
        // runtime-only: any precision shares one checkpoint fingerprint
        let mut f16 = ModelConfig::tiny();
        f16.precision = Precision::F16;
        assert_eq!(
            crate::kernel::config_fingerprint(&ModelConfig::tiny()),
            crate::kernel::config_fingerprint(&f16)
        );
    }

    #[test]
    fn pattern_select_roundtrip_and_override() {
        for s in ["static", "adaptive", "learned", "adaptive:k=3", "learned:k=2"] {
            let p = PatternSelect::parse(s).unwrap();
            assert_eq!(p.label(), s, "parse/label round-trip for {s:?}");
        }
        assert_eq!(PatternSelect::default(), PatternSelect::Static);
        assert_eq!(PatternSelect::parse("adaptive").unwrap(), PatternSelect::Adaptive { k: 0 });
        assert!(PatternSelect::parse("bogus").is_err());
        assert!(PatternSelect::parse("adaptive:3").is_err()); // missing k=
        assert!(PatternSelect::parse("learned:k=two").is_err());
        assert!(PatternSelect::parse("static:k=1").is_err()); // static takes no k
        // k = 0 inherits random_blocks (equal block budget vs static)
        assert_eq!(PatternSelect::Adaptive { k: 0 }.budget(3), 3);
        assert_eq!(PatternSelect::Learned { k: 2 }.budget(3), 2);
        assert_eq!(PatternSelect::Static.budget(3), 0);
        let cfg = apply_overrides(ModelConfig::tiny(), "pattern=adaptive:k=2").unwrap();
        assert_eq!(cfg.pattern, PatternSelect::Adaptive { k: 2 });
        assert!(apply_overrides(ModelConfig::tiny(), "pattern=fancy").is_err());
    }

    #[test]
    fn admission_config_validates() {
        AdmissionConfig::default().validate().unwrap();
        let ok = AdmissionConfig { latency_budget_ms: Some(25.0), ..Default::default() };
        ok.validate().unwrap();
        assert!(AdmissionConfig { max_queue: 0, ..Default::default() }.validate().is_err());
        assert!(
            AdmissionConfig { max_client_inflight: 0, ..Default::default() }.validate().is_err()
        );
        assert!(AdmissionConfig { latency_budget_ms: Some(-1.0), ..Default::default() }
            .validate()
            .is_err());
        assert!(AdmissionConfig { latency_budget_ms: Some(f64::NAN), ..Default::default() }
            .validate()
            .is_err());
    }

    #[test]
    fn obs_config_validates() {
        let off = ObsConfig::default();
        off.validate().unwrap();
        assert!(!off.trace && !off.phase_profile, "observability must default off");
        assert_eq!(off.sampler_interval_ms, 0, "continuous telemetry must default off");
        assert!(off.slo_p99_ms.is_none() && off.flight_dir.is_none() && !off.fault_stall);
        assert!(ObsConfig { trace: true, trace_ring: 0, ..Default::default() }
            .validate()
            .is_err());
        // sampler knobs
        ObsConfig { sampler_interval_ms: 1000, ..Default::default() }.validate().unwrap();
        assert!(ObsConfig { sampler_interval_ms: 1000, series_capacity: 1, ..Default::default() }
            .validate()
            .is_err());
        assert!(ObsConfig { slo_p99_ms: Some(0.0), ..Default::default() }.validate().is_err());
        assert!(ObsConfig { slo_p99_ms: Some(f64::NAN), ..Default::default() }
            .validate()
            .is_err());
        ObsConfig { slo_p99_ms: Some(250.0), ..Default::default() }.validate().unwrap();
    }

    #[test]
    fn serving_config_validates() {
        ServingConfig::default().validate().unwrap();
        assert!(ServingConfig::cpu(0, 1).validate().is_err());
        assert!(ServingConfig::cpu(1, 0).validate().is_err());
        let cfg = ServingConfig::cpu(3, 2);
        assert_eq!(cfg.n_workers(), 3);
        assert!(cfg.backends.iter().all(|b| *b == BackendSpec::cpu()));
        let native = ServingConfig::native(2, 2);
        native.validate().unwrap();
        assert!(native.backends.iter().all(|b| *b == BackendSpec::native()));
    }

    #[test]
    fn native_serving_config_is_valid_at_every_bucket_length() {
        let mut cfg = ModelConfig::native_serving();
        cfg.validate().unwrap();
        for seq in [128usize, 256, 512, 1024, 2048] {
            cfg.seq_len = seq;
            cfg.validate().unwrap();
        }
    }

    #[test]
    fn native_train_shares_the_serving_parameter_family() {
        let train = ModelConfig::native_train();
        train.validate().unwrap();
        let serve = ModelConfig::native_serving();
        // identical architecture fingerprint ⇒ train checkpoints load
        // into the serving pool (seq_len/batch are runtime shapes)
        assert_eq!(
            crate::kernel::config_fingerprint(&train),
            crate::kernel::config_fingerprint(&serve)
        );
    }

    #[test]
    fn artifact_name_scheme() {
        let cfg = ModelConfig::base();
        assert_eq!(cfg.artifact_name("mlm_fwd"), "mlm_fwd_bigbird_itc_s512_b8");
    }
}
