//! Artifact manifest: the contract between the Python compile path and the
//! Rust runtime.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt` alongside the
//! HLO text files. The format is deliberately trivial (no serde/JSON in
//! this offline environment) — a sequence of `[artifact]` sections of
//! `key=value` lines:
//!
//! ```text
//! [artifact]
//! name=mlm_fwd_s512
//! file=mlm_fwd_s512.hlo.txt
//! input=tokens:i32[8,512]
//! input=params:f32[1234]
//! output=logits:f32[8,512,1024]
//! meta=seq_len:512
//! meta=attn:bigbird
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::executable::{IoSpec, TensorSpec};

/// One artifact entry: a compiled-program name, its HLO file, and its
/// typed I/O signature.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    /// Unique artifact name, e.g. `mlm_train_step_s512_bigbird`.
    pub name: String,
    /// HLO text file, relative to the manifest's directory.
    pub file: String,
    /// Ordered input/output tensor specs.
    pub io: IoSpec,
    /// Free-form metadata (seq_len, variant, param counts, ...).
    pub meta: BTreeMap<String, String>,
}

impl ManifestEntry {
    /// Integer metadata accessor.
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.parse().ok())
    }
}

/// The parsed manifest: every artifact the Python compile path produced.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// Directory the manifest was loaded from (HLO files live here).
    pub dir: PathBuf,
    entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let mut m = Self::parse(&text)?;
        m.dir = dir;
        Ok(m)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries: Vec<ManifestEntry> = Vec::new();
        let mut cur: Option<ManifestEntry> = None;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if line == "[artifact]" {
                if let Some(e) = cur.take() {
                    entries.push(Self::validated(e, lineno)?);
                }
                cur = Some(ManifestEntry {
                    name: String::new(),
                    file: String::new(),
                    io: IoSpec::default(),
                    meta: BTreeMap::new(),
                });
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                bail!("manifest line {} is not key=value: {raw:?}", lineno + 1);
            };
            let e = cur
                .as_mut()
                .with_context(|| format!("line {}: key before any [artifact]", lineno + 1))?;
            match key {
                "name" => e.name = value.to_string(),
                "file" => e.file = value.to_string(),
                "input" => e.io.inputs.push(TensorSpec::parse(value)?),
                "output" => e.io.outputs.push(TensorSpec::parse(value)?),
                "meta" => {
                    let Some((k, v)) = value.split_once(':') else {
                        bail!("line {}: meta must be key:value", lineno + 1);
                    };
                    e.meta.insert(k.to_string(), v.to_string());
                }
                other => bail!("line {}: unknown manifest key {other:?}", lineno + 1),
            }
        }
        if let Some(e) = cur.take() {
            entries.push(Self::validated(e, 0)?);
        }
        Ok(Manifest { dir: PathBuf::new(), entries })
    }

    fn validated(e: ManifestEntry, lineno: usize) -> Result<ManifestEntry> {
        if e.name.is_empty() {
            bail!("artifact ending at line {lineno} has no name");
        }
        if e.file.is_empty() {
            bail!("artifact {:?} has no file", e.name);
        }
        if e.io.outputs.is_empty() {
            bail!("artifact {:?} declares no outputs", e.name);
        }
        Ok(e)
    }

    /// All entries in declaration order.
    pub fn entries(&self) -> &[ManifestEntry] {
        &self.entries
    }

    /// Look up an artifact by exact name.
    pub fn get(&self, name: &str) -> Result<&ManifestEntry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .with_context(|| {
                let names: Vec<&str> = self.entries.iter().map(|e| e.name.as_str()).collect();
                format!("artifact {name:?} not in manifest (have: {names:?})")
            })
    }

    /// Entries whose metadata matches all given `(key, value)` pairs.
    pub fn select(&self, filters: &[(&str, &str)]) -> Vec<&ManifestEntry> {
        self.entries
            .iter()
            .filter(|e| {
                filters
                    .iter()
                    .all(|(k, v)| e.meta.get(*k).map(|x| x == v).unwrap_or(false))
            })
            .collect()
    }

    /// Absolute path to an entry's HLO file.
    pub fn hlo_path(&self, e: &ManifestEntry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
[artifact]
name=attn_s512
file=attn_s512.hlo.txt
input=x:f32[1,512,128]
output=y:f32[1,512,128]
meta=seq_len:512
meta=attn:bigbird

[artifact]
name=attn_s1024
file=attn_s1024.hlo.txt
input=x:f32[1,1024,128]
output=y:f32[1,1024,128]
meta=seq_len:1024
meta=attn:dense
";

    #[test]
    fn parses_two_entries() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries().len(), 2);
        let e = m.get("attn_s512").unwrap();
        assert_eq!(e.file, "attn_s512.hlo.txt");
        assert_eq!(e.io.inputs.len(), 1);
        assert_eq!(e.io.inputs[0].dims, vec![1, 512, 128]);
        assert_eq!(e.meta_usize("seq_len"), Some(512));
    }

    #[test]
    fn select_filters_by_meta() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let hits = m.select(&[("attn", "bigbird")]);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].name, "attn_s512");
        assert!(m.select(&[("attn", "bigbird"), ("seq_len", "1024")]).is_empty());
    }

    #[test]
    fn missing_name_is_error() {
        let bad = "[artifact]\nfile=x.hlo\noutput=y:f32[1]\n";
        assert!(Manifest::parse(bad).is_err());
    }

    #[test]
    fn unknown_key_is_error() {
        let bad = "[artifact]\nname=a\nfile=x\nwibble=1\noutput=y:f32[1]\n";
        assert!(Manifest::parse(bad).is_err());
    }

    #[test]
    fn get_unknown_artifact_errors_with_names() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let err = m.get("nope").unwrap_err().to_string();
        assert!(err.contains("attn_s512"), "{err}");
    }
}
