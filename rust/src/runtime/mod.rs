//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! Rust hot path.
//!
//! The Python side (`python/compile/aot.py`) lowers each model variant
//! once to **HLO text** (not a serialized `HloModuleProto` — jax ≥ 0.5
//! emits 64-bit instruction ids which xla_extension 0.5.1 rejects; the
//! text parser reassigns ids). This module compiles those artifacts on a
//! shared [`PjRtClient`] and exposes typed, shape-checked entry points.

pub mod backend;
mod client;
mod executable;
pub mod hlo_stats;
mod literal_util;
mod manifest;
mod pool;

pub use backend::{
    format_backend_specs, parse_backend_specs, Backend, BackendKind, BackendSpec, JobShape,
    Roofline,
};
pub use client::Runtime;
pub use executable::{ArtifactExecutable, IoSpec, TensorSpec};
pub use literal_util::{literal_f32, literal_i32, to_vec_f32, to_vec_i32, HostTensor};
pub use manifest::{Manifest, ManifestEntry};
pub use pool::ExecutablePool;
