//! Lightweight HLO-text analysis for the Layer-2 performance pass:
//! op histograms, fusion counts, and a FLOP estimate from `dot` shapes.
//!
//! The 0.5.1 runtime exposes no cost-analysis API over the C boundary,
//! so we parse the HLO text we already ship. Good enough to find
//! redundant recomputation and fusion regressions between exports.

use std::collections::BTreeMap;

use anyhow::Result;

/// Parsed per-module statistics.
#[derive(Clone, Debug, Default)]
pub struct HloStats {
    /// op name → count across all computations in the module
    pub ops: BTreeMap<String, usize>,
    /// estimated FLOPs from dot ops (2·M·N·K per dot)
    pub dot_flops: u64,
    /// total instruction count
    pub instructions: usize,
    /// bytes of constant data embedded in the module (4 B/elem estimate)
    pub constant_bytes: u64,
}

impl HloStats {
    /// Top-k ops by count.
    pub fn top_ops(&self, k: usize) -> Vec<(String, usize)> {
        let mut v: Vec<(String, usize)> = self
            .ops
            .iter()
            .map(|(a, b)| (a.clone(), *b))
            .collect();
        v.sort_by(|a, b| b.1.cmp(&a.1));
        v.truncate(k);
        v
    }
}

/// Shape volume of an HLO type string like `f32[4,512,64]`.
fn shape_volume(ty: &str) -> Option<u64> {
    let open = ty.find('[')?;
    let close = ty.find(']')?;
    let dims = &ty[open + 1..close];
    if dims.is_empty() {
        return Some(1);
    }
    let mut vol = 1u64;
    for d in dims.split(',') {
        vol = vol.checked_mul(d.trim().parse().ok()?)?;
    }
    Some(vol)
}

/// Analyse one HLO text module.
pub fn analyze(text: &str) -> HloStats {
    let mut st = HloStats::default();
    for line in text.lines() {
        let line = line.trim_start();
        // instruction lines look like: `%name = TYPE opcode(...)` or
        // `name.N = TYPE opcode(...)`
        let Some(eq) = line.find(" = ") else { continue };
        let rest = &line[eq + 3..];
        // rest = "f32[4,512]{1,0} add(...)" — take type token then opcode
        let mut parts = rest.splitn(2, ' ');
        let ty = parts.next().unwrap_or("");
        let Some(tail) = parts.next() else { continue };
        let opcode: String = tail.chars().take_while(|c| c.is_alphanumeric() || *c == '-').collect();
        if opcode.is_empty() {
            continue;
        }
        *st.ops.entry(opcode.clone()).or_insert(0) += 1;
        st.instructions += 1;
        match opcode.as_str() {
            "dot" => {
                // output volume × K × 2; K is unknown from the line alone,
                // approximate with output volume × 2 × contracted dim by
                // parsing the first operand's type if present
                if let Some(vol) = shape_volume(ty) {
                    // find first operand type inside parens for K
                    let k = tail
                        .find("f32[")
                        .and_then(|i| shape_volume(&tail[i + 3..]))
                        .unwrap_or(1);
                    // upper-bound-ish estimate: 2 * out_vol * (operand_vol / out_vol)
                    let kdim = (k / vol.max(1)).max(1);
                    st.dot_flops += 2 * vol * kdim;
                }
            }
            "constant" => {
                if let Some(vol) = shape_volume(ty) {
                    st.constant_bytes += vol * 4;
                }
            }
            _ => {}
        }
    }
    st
}

/// Analyse an HLO file on disk.
pub fn analyze_file(path: &std::path::Path) -> Result<HloStats> {
    Ok(analyze(&std::fs::read_to_string(path)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
ENTRY %main (p0: f32[4,8]) -> f32[4,8] {
  %p0 = f32[4,8]{1,0} parameter(0)
  %c = f32[8,8]{1,0} constant({ ... })
  %d = f32[4,8]{1,0} dot(%p0, %c), lhs_contracting_dims={1}, rhs_contracting_dims={0}, f32[4,8]
  %t = f32[4,8]{1,0} tanh(%d)
  ROOT %a = f32[4,8]{1,0} add(%d, %t)
}
";

    #[test]
    fn counts_ops() {
        let st = analyze(SAMPLE);
        assert_eq!(st.ops.get("dot"), Some(&1));
        assert_eq!(st.ops.get("tanh"), Some(&1));
        assert_eq!(st.ops.get("add"), Some(&1));
        assert_eq!(st.ops.get("parameter"), Some(&1));
        assert!(st.instructions >= 5);
    }

    #[test]
    fn shape_volume_parses() {
        assert_eq!(shape_volume("f32[4,512,64]"), Some(4 * 512 * 64));
        assert_eq!(shape_volume("f32[]"), Some(1));
        assert_eq!(shape_volume("f32"), None);
    }

    #[test]
    fn constant_bytes_counted() {
        let st = analyze(SAMPLE);
        assert_eq!(st.constant_bytes, 8 * 8 * 4);
    }

    #[test]
    fn top_ops_sorted() {
        let st = analyze(SAMPLE);
        let top = st.top_ops(2);
        assert_eq!(top.len(), 2);
        assert!(top[0].1 >= top[1].1);
    }
}
