//! Lazily-compiled executable pool.
//!
//! The serving coordinator buckets requests by padded sequence length and
//! batch size; each bucket maps to one AOT artifact. The pool compiles an
//! artifact the first time its bucket is hit and caches it for the rest of
//! the process lifetime (one compiled executable per model variant, as the
//! architecture prescribes).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::Result;

use super::client::Runtime;
use super::executable::ArtifactExecutable;
use super::manifest::Manifest;

/// Pool keyed by artifact name. Engine-thread only (interior mutability
/// via `RefCell`, `Rc` handles shared within the thread). The manifest
/// is held behind an `Arc` so an engine *pool* of N workers can share
/// one parsed copy instead of re-parsing it N times.
pub struct ExecutablePool {
    runtime: Runtime,
    manifest: Arc<Manifest>,
    cache: RefCell<HashMap<String, Rc<ArtifactExecutable>>>,
    /// Number of cache misses (compiles) — exposed for metrics.
    compiles: RefCell<usize>,
}

impl ExecutablePool {
    /// New pool over a loaded manifest — owned (`Manifest`) or shared
    /// (`Arc<Manifest>`, zero-copy across workers).
    pub fn new(runtime: Runtime, manifest: impl Into<Arc<Manifest>>) -> Self {
        ExecutablePool {
            runtime,
            manifest: manifest.into(),
            cache: RefCell::new(HashMap::new()),
            compiles: RefCell::new(0),
        }
    }

    /// The manifest backing this pool.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Underlying runtime.
    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Get (compiling if needed) the executable for `name`.
    pub fn get(&self, name: &str) -> Result<Rc<ArtifactExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let exe = Rc::new(self.runtime.compile_named(&self.manifest, name)?);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        *self.compiles.borrow_mut() += 1;
        Ok(exe)
    }

    /// Eagerly compile every artifact whose metadata matches the filters.
    pub fn warmup(&self, filters: &[(&str, &str)]) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .select(filters)
            .into_iter()
            .map(|e| e.name.clone())
            .collect();
        for n in &names {
            self.get(n)?;
        }
        Ok(names.len())
    }

    /// Number of artifacts compiled so far.
    pub fn compiled_count(&self) -> usize {
        *self.compiles.borrow()
    }
}
