//! Typed wrapper around a compiled PJRT executable.

use anyhow::{bail, Context, Result};

use super::literal_util::HostTensor;
use super::manifest::ManifestEntry;

/// Shape+dtype of one program input or output, e.g. `tokens:i32[8,512]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    /// Logical name from the manifest (documentation only; PJRT inputs
    /// are positional).
    pub name: String,
    /// "f32" or "i32".
    pub dtype: String,
    /// Dimensions; empty for scalars.
    pub dims: Vec<usize>,
}

impl TensorSpec {
    /// Parse `name:dtype[d0,d1,...]`.
    pub fn parse(s: &str) -> Result<Self> {
        let (name, rest) = s.split_once(':').context("tensor spec needs name:")?;
        let (dtype, dims_s) = match rest.split_once('[') {
            Some((d, t)) => (d, t.trim_end_matches(']')),
            None => (rest, ""),
        };
        if dtype != "f32" && dtype != "i32" {
            bail!("unsupported dtype {dtype:?} in spec {s:?}");
        }
        let dims = if dims_s.is_empty() {
            vec![]
        } else {
            dims_s
                .split(',')
                .map(|d| d.trim().parse::<usize>().with_context(|| format!("bad dim in {s:?}")))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorSpec { name: name.to_string(), dtype: dtype.to_string(), dims })
    }

    /// Number of elements.
    pub fn volume(&self) -> usize {
        self.dims.iter().product()
    }

    /// Does a host tensor match this spec?
    pub fn matches(&self, t: &HostTensor) -> bool {
        t.dtype() == self.dtype && t.shape() == self.dims.as_slice()
    }
}

/// Ordered input/output signature of an artifact.
#[derive(Clone, Debug, Default)]
pub struct IoSpec {
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// A compiled artifact: PJRT executable + manifest signature.
///
/// Not `Send` (wraps PJRT pointers) — lives on the engine thread.
pub struct ArtifactExecutable {
    /// Artifact name from the manifest.
    pub name: String,
    /// Typed I/O signature.
    pub io: IoSpec,
    /// Metadata copied from the manifest entry.
    pub meta: std::collections::BTreeMap<String, String>,
    exe: xla::PjRtLoadedExecutable,
}

impl ArtifactExecutable {
    pub(crate) fn new(entry: &ManifestEntry, exe: xla::PjRtLoadedExecutable) -> Self {
        ArtifactExecutable {
            name: entry.name.clone(),
            io: entry.io.clone(),
            meta: entry.meta.clone(),
            exe,
        }
    }

    /// Execute with shape-checked host tensors; returns host outputs.
    ///
    /// All jax programs are lowered with `return_tuple=True`, so the
    /// single device output is a tuple literal which we decompose into
    /// one `HostTensor` per declared output.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.io.inputs.len() {
            bail!(
                "{}: got {} inputs, signature has {}",
                self.name,
                inputs.len(),
                self.io.inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&self.io.inputs).enumerate() {
            if !spec.matches(t) {
                bail!(
                    "{}: input #{i} ({}) expects {}[{:?}], got {}[{:?}]",
                    self.name,
                    spec.name,
                    spec.dtype,
                    spec.dims,
                    t.dtype(),
                    t.shape()
                );
            }
        }
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != self.io.outputs.len() {
            bail!(
                "{}: program returned {} outputs, manifest declares {}",
                self.name,
                parts.len(),
                self.io.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (i, (lit, spec)) in parts.iter().zip(&self.io.outputs).enumerate() {
            let t = HostTensor::from_literal(lit)
                .with_context(|| format!("{}: output #{i} ({})", self.name, spec.name))?;
            if !spec.matches(&t) {
                bail!(
                    "{}: output #{i} ({}) expected {}[{:?}], got {}[{:?}]",
                    self.name,
                    spec.name,
                    spec.dtype,
                    spec.dims,
                    t.dtype(),
                    t.shape()
                );
            }
            out.push(t);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_parse_roundtrip() {
        let s = TensorSpec::parse("tokens:i32[8,512]").unwrap();
        assert_eq!(s.name, "tokens");
        assert_eq!(s.dtype, "i32");
        assert_eq!(s.dims, vec![8, 512]);
        assert_eq!(s.volume(), 4096);
    }

    #[test]
    fn tensor_spec_scalar() {
        let s = TensorSpec::parse("lr:f32").unwrap();
        assert!(s.dims.is_empty());
        assert_eq!(s.volume(), 1);
    }

    #[test]
    fn tensor_spec_rejects_bad_dtype() {
        assert!(TensorSpec::parse("x:f64[2]").is_err());
        assert!(TensorSpec::parse("no_colon").is_err());
    }

    #[test]
    fn spec_matches_host_tensor() {
        let s = TensorSpec::parse("x:f32[2,3]").unwrap();
        let good = HostTensor::f32(&[2, 3], vec![0.0; 6]).unwrap();
        let wrong_shape = HostTensor::f32(&[3, 2], vec![0.0; 6]).unwrap();
        let wrong_dtype = HostTensor::i32(&[2, 3], vec![0; 6]).unwrap();
        assert!(s.matches(&good));
        assert!(!s.matches(&wrong_shape));
        assert!(!s.matches(&wrong_dtype));
    }
}
