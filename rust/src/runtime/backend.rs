//! Backend abstraction: which device family a PJRT runtime targets, and
//! a per-bucket roofline cost model for device-aware dispatch.
//!
//! BigBird's block-sparse attention is the reason a *heterogeneous* pool
//! makes sense: the pattern is bandwidth/latency-bound at short sequence
//! buckets and compute-bound at long ones, so the optimal device depends
//! on the bucket. Each engine worker is assigned a [`BackendSpec`]; the
//! dispatcher scores every (bucket, backend) pair with [`Roofline`] —
//! seeded statically per platform here, refined online from observed
//! execution times — and routes each batch to the worker with the
//! minimum expected completion time.
//!
//! The spec grammar (the `--backends` CLI flag) is
//! `kind[:count][,kind[:count]...]`, e.g. `cpu:2,gpu:1` for two CPU
//! workers plus one GPU worker. When a GPU/TPU PJRT plugin is absent the
//! worker falls back to CPU with a warning (see
//! [`super::Runtime::for_backend`]), so the same flag works on CPU-only
//! machines and CI runners.
//!
//! The `native` kind is different in nature: it is **not** a PJRT
//! device at all but the in-process block-sparse kernel subsystem
//! ([`crate::kernel`]) — real Rust compute that needs no AOT artifacts
//! and no plugin, so `--backends native:2` serves real forward passes
//! on a bare checkout. Its roofline is seeded from a self-calibration
//! micro-probe ([`crate::kernel::calibrate`]) rather than hardcoded
//! platform guesses.

use anyhow::{bail, Result};

/// Device family a worker's PJRT client targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum BackendKind {
    /// Host CPU (always available; the fallback for every other kind).
    Cpu,
    /// CUDA/ROCm device behind a PJRT GPU plugin.
    Gpu,
    /// TPU device behind a PJRT TPU plugin.
    Tpu,
    /// The in-process native kernel subsystem ([`crate::kernel`]): no
    /// PJRT client, no AOT artifacts — always available, like CPU, but
    /// executing the block-sparse kernels directly.
    Native,
}

impl BackendKind {
    /// Spec-grammar name (also used as the metrics label).
    pub fn as_str(self) -> &'static str {
        match self {
            BackendKind::Cpu => "cpu",
            BackendKind::Gpu => "gpu",
            BackendKind::Tpu => "tpu",
            BackendKind::Native => "native",
        }
    }

    /// Parse a spec-grammar name.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "cpu" => BackendKind::Cpu,
            "gpu" => BackendKind::Gpu,
            "tpu" => BackendKind::Tpu,
            "native" => BackendKind::Native,
            other => bail!("unknown backend kind {other:?} (expected cpu|gpu|tpu|native)"),
        })
    }
}

/// Requested backend for one engine worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BackendSpec {
    /// Requested device family. The *realized* backend may differ (CPU
    /// fallback when the plugin is absent).
    pub kind: BackendKind,
}

impl BackendSpec {
    /// A CPU worker spec.
    pub fn cpu() -> Self {
        BackendSpec { kind: BackendKind::Cpu }
    }

    /// A native (in-process kernel) worker spec.
    pub fn native() -> Self {
        BackendSpec { kind: BackendKind::Native }
    }

    /// `n` identical CPU worker specs — the PR 1-compatible homogeneous
    /// pool shape.
    pub fn cpu_workers(n: usize) -> Vec<Self> {
        vec![BackendSpec::cpu(); n]
    }

    /// `n` identical native worker specs.
    pub fn native_workers(n: usize) -> Vec<Self> {
        vec![BackendSpec::native(); n]
    }
}

/// Parse the `--backends` spec grammar into one [`BackendSpec`] per
/// worker, preserving declaration order: `cpu:2,gpu:1` →
/// `[cpu, cpu, gpu]`. A bare kind means count 1; counts must be ≥ 1.
pub fn parse_backend_specs(s: &str) -> Result<Vec<BackendSpec>> {
    let mut specs = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            bail!("empty backend entry in spec {s:?}");
        }
        let (kind, count) = match part.split_once(':') {
            Some((k, c)) => {
                let n: usize = c
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("backend count {c:?} is not a number"))?;
                (BackendKind::parse(k.trim())?, n)
            }
            None => (BackendKind::parse(part)?, 1),
        };
        if count == 0 {
            bail!("backend {part:?} has count 0 (must be >= 1)");
        }
        specs.extend(std::iter::repeat(BackendSpec { kind }).take(count));
    }
    if specs.is_empty() {
        bail!("backend spec {s:?} names no workers");
    }
    Ok(specs)
}

/// Render worker specs back into the compact spec grammar (adjacent runs
/// of one kind are collapsed): `[cpu, cpu, gpu]` → `"cpu:2,gpu:1"`.
pub fn format_backend_specs(specs: &[BackendSpec]) -> String {
    let mut out: Vec<(BackendKind, usize)> = Vec::new();
    for s in specs {
        if let Some(last) = out.last_mut() {
            if last.0 == s.kind {
                last.1 += 1;
                continue;
            }
        }
        out.push((s.kind, 1));
    }
    out.iter()
        .map(|(k, n)| format!("{}:{n}", k.as_str()))
        .collect::<Vec<_>>()
        .join(",")
}

/// The work shape of one dispatched batch, as the cost model sees it:
/// everything else about the artifact is folded into the per-token
/// constants and the observed-time refinement.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct JobShape {
    /// Padded sequence length of the bucket.
    pub seq_len: usize,
    /// Batch capacity baked into the bucket's artifact.
    pub batch: usize,
}

impl JobShape {
    /// Padded tokens the batch carries (the linear factor in BigBird's
    /// O(n) attention cost).
    pub fn tokens(&self) -> usize {
        self.seq_len * self.batch
    }
}

/// Model FLOPs per padded token (scaled-down BigBird-base forward pass;
/// order-of-magnitude seed — observed-time EWMAs refine it online).
const FLOPS_PER_TOKEN: f64 = 1.0e6;
/// Bytes moved per padded token (activations in + logits out, crossing
/// the host↔device link on accelerators).
const BYTES_PER_TOKEN: f64 = 4.0e3;

/// Roofline cost model for one backend: a batch costs
/// `overhead + max(compute time, memory time)` where compute time is
/// `flops / peak flops` and memory time is `bytes / peak bandwidth`.
///
/// The numbers are *seeds*, not measurements: they only need to rank
/// backends sensibly per bucket until real execution times arrive. The
/// defaults (see [`Roofline::for_kind`]) are deliberately conservative
/// public figures and are documented in `rust/README.md`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Roofline {
    /// Peak sustained compute, GFLOP/s.
    pub gflops: f64,
    /// Peak effective memory/link bandwidth, GB/s (for accelerators this
    /// is the host↔device link the batch must cross, not HBM).
    pub gbps: f64,
    /// Fixed per-batch overhead in ms (dispatch, kernel launch,
    /// host↔device round-trip setup) — what keeps short buckets on
    /// low-latency backends.
    pub overhead_ms: f64,
}

impl Roofline {
    /// Per-kind seed model. PJRT kinds use static platform seeds; the
    /// native kind is **measured** — a once-per-process self-calibration
    /// micro-probe ([`crate::kernel::calibrate::native_roofline`]) times
    /// the actual kernels on this machine, so the native backend's cost
    /// model starts from reality instead of a hardcoded guess.
    pub fn for_kind(kind: BackendKind) -> Self {
        match kind {
            // multithreaded host CPU: low latency, modest throughput
            BackendKind::Cpu => Roofline { gflops: 80.0, gbps: 40.0, overhead_ms: 0.05 },
            // data-center GPU behind PCIe: huge throughput, launch +
            // transfer overhead per batch
            BackendKind::Gpu => Roofline { gflops: 9000.0, gbps: 16.0, overhead_ms: 1.5 },
            // TPU via PJRT plugin: highest throughput, highest dispatch
            // overhead
            BackendKind::Tpu => Roofline { gflops: 45000.0, gbps: 30.0, overhead_ms: 3.0 },
            // in-process kernels: self-calibrated, cached per process
            BackendKind::Native => crate::kernel::calibrate::native_roofline(),
        }
    }

    /// Predicted execution cost of one batch of `shape`, in ms.
    pub fn cost_ms(&self, shape: JobShape) -> f64 {
        let tokens = shape.tokens() as f64;
        let compute_s = tokens * FLOPS_PER_TOKEN / (self.gflops * 1e9);
        let memory_s = tokens * BYTES_PER_TOKEN / (self.gbps * 1e9);
        self.overhead_ms + compute_s.max(memory_s) * 1e3
    }
}

/// The realized backend of a spawned engine worker: what the worker
/// actually got (after any CPU fallback), plus its cost model.
#[derive(Clone, Debug, PartialEq)]
pub struct Backend {
    /// Realized device family (== requested, or [`BackendKind::Cpu`]
    /// after a fallback).
    pub kind: BackendKind,
    /// Device family the spec asked for.
    pub requested: BackendKind,
    /// PJRT platform name reported by the client (e.g. `"cpu"`).
    pub platform: String,
    /// Cost model used to score buckets on this backend.
    pub roofline: Roofline,
}

impl Backend {
    /// Backend for a realized kind with the static roofline seed.
    pub fn of_kind(kind: BackendKind, requested: BackendKind, platform: String) -> Self {
        Backend { kind, requested, platform, roofline: Roofline::for_kind(kind) }
    }

    /// A synthetic backend with an explicit cost model — used by the
    /// dispatch-policy tests and the heterogeneous-pool bench to
    /// simulate cost-skewed devices without any PJRT plugin.
    pub fn simulated(kind: BackendKind, roofline: Roofline) -> Self {
        Backend { kind, requested: kind, platform: format!("sim-{}", kind.as_str()), roofline }
    }

    /// Metrics label: the realized kind, annotated when it differs from
    /// the request (e.g. `"cpu(gpu-fallback)"`).
    pub fn label(&self) -> String {
        if self.kind == self.requested {
            self.kind.as_str().to_string()
        } else {
            format!("{}({}-fallback)", self.kind.as_str(), self.requested.as_str())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_grammar_roundtrip() {
        let specs = parse_backend_specs("cpu:2,gpu:1").unwrap();
        assert_eq!(
            specs,
            vec![BackendSpec::cpu(), BackendSpec::cpu(), BackendSpec { kind: BackendKind::Gpu }]
        );
        assert_eq!(format_backend_specs(&specs), "cpu:2,gpu:1");
        // bare kind means count 1
        assert_eq!(parse_backend_specs("tpu").unwrap().len(), 1);
        // whitespace tolerated
        assert_eq!(parse_backend_specs(" cpu : 2 , gpu ").unwrap().len(), 3);
    }

    #[test]
    fn spec_grammar_rejects_malformed() {
        assert!(parse_backend_specs("").is_err());
        assert!(parse_backend_specs("cpu:0").is_err());
        assert!(parse_backend_specs("cpu:two").is_err());
        assert!(parse_backend_specs("npu:1").is_err());
        assert!(parse_backend_specs("cpu:1,,gpu:1").is_err());
    }

    #[test]
    fn kind_roundtrip() {
        for k in [BackendKind::Cpu, BackendKind::Gpu, BackendKind::Tpu, BackendKind::Native] {
            assert_eq!(BackendKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(BackendKind::parse("cuda").is_err());
    }

    #[test]
    fn native_specs_parse_and_format() {
        let specs = parse_backend_specs("native:2,cpu:1").unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0], BackendSpec::native());
        assert_eq!(specs[1].kind, BackendKind::Native);
        assert_eq!(specs[2], BackendSpec::cpu());
        assert_eq!(format_backend_specs(&specs), "native:2,cpu:1");
        assert_eq!(BackendSpec::native_workers(4).len(), 4);
    }

    #[test]
    fn native_roofline_is_measured_and_positive() {
        let r = Roofline::for_kind(BackendKind::Native);
        assert!(r.gflops > 0.0 && r.gflops.is_finite(), "{r:?}");
        assert!(r.gbps > 0.0 && r.gbps.is_finite(), "{r:?}");
        assert!(r.overhead_ms > 0.0 && r.overhead_ms.is_finite(), "{r:?}");
        // cached probe: stable across calls, and costs grow with tokens
        assert_eq!(r, Roofline::for_kind(BackendKind::Native));
        let small = JobShape { seq_len: 128, batch: 1 };
        let large = JobShape { seq_len: 2048, batch: 4 };
        assert!(r.cost_ms(small) < r.cost_ms(large));
    }

    #[test]
    fn roofline_orders_backends_by_bucket() {
        let cpu = Roofline::for_kind(BackendKind::Cpu);
        let gpu = Roofline::for_kind(BackendKind::Gpu);
        let long = JobShape { seq_len: 2048, batch: 4 };
        // long buckets are compute-bound: the throughput backend wins
        assert!(gpu.cost_ms(long) < cpu.cost_ms(long), "gpu should win the long bucket");
        // cost grows monotonically with tokens on every backend
        let short = JobShape { seq_len: 128, batch: 4 };
        assert!(cpu.cost_ms(short) < cpu.cost_ms(long));
        assert!(gpu.cost_ms(short) < gpu.cost_ms(long));
        // tiny batches are dominated by overhead, where cpu is cheapest
        let tiny = JobShape { seq_len: 16, batch: 1 };
        assert!(cpu.cost_ms(tiny) < gpu.cost_ms(tiny), "cpu should win the tiny bucket");
    }

    #[test]
    fn fallback_label_names_the_request() {
        let b = Backend::of_kind(BackendKind::Cpu, BackendKind::Gpu, "cpu".into());
        assert_eq!(b.label(), "cpu(gpu-fallback)");
        let b = Backend::of_kind(BackendKind::Cpu, BackendKind::Cpu, "cpu".into());
        assert_eq!(b.label(), "cpu");
    }
}
