//! Host-side tensors and conversions to/from `xla::Literal`.
//!
//! `xla::Literal` wraps a raw C pointer and is **not `Send`**, so it can
//! never cross a thread boundary. The coordinator therefore moves
//! [`HostTensor`]s (plain `Vec`-backed arrays) between threads and only
//! materialises `Literal`s on the engine thread that owns the PJRT client.

use anyhow::{bail, Context, Result};

/// A plain host-memory tensor: row-major data + shape. `Send + Sync`,
/// cheap to move through channels, convertible to/from `xla::Literal`.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    /// New f32 tensor; checks that `data.len()` matches the shape volume.
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let vol: usize = shape.iter().product();
        if vol != data.len() {
            bail!("shape {shape:?} (volume {vol}) != data len {}", data.len());
        }
        Ok(HostTensor::F32 { shape: shape.to_vec(), data })
    }

    /// New i32 tensor; checks that `data.len()` matches the shape volume.
    pub fn i32(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        let vol: usize = shape.iter().product();
        if vol != data.len() {
            bail!("shape {shape:?} (volume {vol}) != data len {}", data.len());
        }
        Ok(HostTensor::I32 { shape: shape.to_vec(), data })
    }

    /// Tensor filled with zeros.
    pub fn zeros_f32(shape: &[usize]) -> Self {
        let vol: usize = shape.iter().product();
        HostTensor::F32 { shape: shape.to_vec(), data: vec![0.0; vol] }
    }

    /// Shape accessor.
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } => shape,
            HostTensor::I32 { shape, .. } => shape,
        }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    /// True if the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// dtype name as used in the artifact manifest ("f32" / "i32").
    pub fn dtype(&self) -> &'static str {
        match self {
            HostTensor::F32 { .. } => "f32",
            HostTensor::I32 { .. } => "i32",
        }
    }

    /// Borrow f32 data (error if i32).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => bail!("tensor is i32, expected f32"),
        }
    }

    /// Borrow i32 data (error if f32).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            HostTensor::F32 { .. } => bail!("tensor is f32, expected i32"),
        }
    }

    /// Convert to an `xla::Literal` (engine-thread only).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            HostTensor::F32 { shape, data } => literal_f32(shape, data),
            HostTensor::I32 { shape, data } => literal_i32(shape, data),
        }
    }

    /// Convert from an `xla::Literal` (engine-thread only).
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

/// Build an f32 `Literal` of the given shape from row-major data.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let lit = xla::Literal::vec1(data);
    Ok(lit.reshape(&dims)?)
}

/// Build an i32 `Literal` of the given shape from row-major data.
pub fn literal_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let lit = xla::Literal::vec1(data);
    Ok(lit.reshape(&dims)?)
}

/// Extract f32 data from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract i32 data from a literal.
pub fn to_vec_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checks() {
        assert!(HostTensor::f32(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::f32(&[2, 3], vec![0.0; 5]).is_err());
        assert!(HostTensor::i32(&[4], vec![1, 2, 3, 4]).is_ok());
    }

    #[test]
    fn zeros_has_right_volume() {
        let t = HostTensor::zeros_f32(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.shape(), &[2, 3, 4]);
        assert_eq!(t.dtype(), "f32");
    }

    #[test]
    fn roundtrip_f32_literal() {
        let t = HostTensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn roundtrip_i32_literal() {
        let t = HostTensor::i32(&[3], vec![7, -1, 0]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }
}
