//! The PJRT runtime owner: one per-backend client + artifact compilation.

use std::collections::HashSet;
use std::path::Path;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::backend::{BackendKind, BackendSpec};
use super::executable::ArtifactExecutable;
use super::manifest::{Manifest, ManifestEntry};

/// Requested kinds whose CPU-fallback warning has already been printed.
/// A `gpu:8` spec spawns eight workers that all fall back — the warning
/// is per *spec kind*, not per worker, so it logs once.
static FALLBACK_WARNED: OnceLock<Mutex<HashSet<BackendKind>>> = OnceLock::new();

fn warn_fallback_once(requested: BackendKind, message: impl FnOnce() -> String) {
    let first = FALLBACK_WARNED
        .get_or_init(|| Mutex::new(HashSet::new()))
        .lock()
        .expect("fallback-warning set poisoned")
        .insert(requested);
    if first {
        crate::log!(crate::obs::log::Level::Warn, "runtime", "{}", message());
    }
}

/// Owns the PJRT client. Not `Send` — construct on the engine thread.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// Create a client for the requested backend, returning the runtime
    /// together with the *realized* [`BackendKind`].
    ///
    /// GPU/TPU clients require a PJRT device plugin (advertised via
    /// `PJRT_GPU_PLUGIN_PATH` / `PJRT_TPU_PLUGIN_PATH`); the vendored
    /// `xla_extension` in this build links only the CPU client, so a
    /// missing — or presently unloadable — plugin degrades to a CPU
    /// client with a warning rather than failing the worker. The warning
    /// is deduplicated per requested kind (a `gpu:8` pool logs once, not
    /// eight times). Callers use the realized kind to pick the matching
    /// roofline cost model, so a fallen-back "gpu" worker is costed (and
    /// dispatched to) as the CPU it actually is.
    ///
    /// `native` specs never reach PJRT: the engine pool executes them
    /// in-process via [`crate::kernel::NativeEngine`], so asking this
    /// constructor for one is a caller bug and errors out.
    pub fn for_backend(spec: &BackendSpec) -> Result<(Self, BackendKind)> {
        match spec.kind {
            BackendKind::Cpu => Ok((Self::cpu()?, BackendKind::Cpu)),
            BackendKind::Native => bail!(
                "the native backend runs in-process (crate::kernel) and has no PJRT runtime"
            ),
            requested => {
                let var = format!("PJRT_{}_PLUGIN_PATH", requested.as_str().to_uppercase());
                warn_fallback_once(requested, || match std::env::var_os(&var) {
                    Some(path) => format!(
                        "[runtime] {} plugin at {} cannot be loaded by this CPU-only \
                         xla_extension build; falling back to CPU",
                        requested.as_str(),
                        Path::new(&path).display()
                    ),
                    None => format!(
                        "[runtime] no {} PJRT plugin ({var} unset); falling back to CPU",
                        requested.as_str()
                    ),
                });
                Ok((Self::cpu()?, BackendKind::Cpu))
            }
        }
    }

    /// Backend platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one manifest entry's HLO text into an executable.
    ///
    /// HLO **text** is the interchange format: jax ≥ 0.5 serialized protos
    /// use 64-bit instruction ids which xla_extension 0.5.1 rejects; the
    /// text parser reassigns ids and round-trips cleanly.
    pub fn compile_entry(
        &self,
        manifest: &Manifest,
        entry: &ManifestEntry,
    ) -> Result<ArtifactExecutable> {
        let path = manifest.hlo_path(entry);
        self.compile_hlo_file(entry, &path)
    }

    /// Compile an HLO text file with an explicit entry signature.
    pub fn compile_hlo_file(
        &self,
        entry: &ManifestEntry,
        path: &Path,
    ) -> Result<ArtifactExecutable> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {}", entry.name))?;
        let dt = t0.elapsed();
        crate::log!(
            crate::obs::log::Level::Info,
            "runtime",
            "compiled {} ({:.1} KiB HLO) in {:.2}s",
            entry.name,
            std::fs::metadata(path).map(|m| m.len() as f64 / 1024.0).unwrap_or(0.0),
            dt.as_secs_f64()
        );
        Ok(ArtifactExecutable::new(entry, exe))
    }

    /// Compile by artifact name.
    pub fn compile_named(&self, manifest: &Manifest, name: &str) -> Result<ArtifactExecutable> {
        let entry = manifest.get(name)?;
        self.compile_entry(manifest, entry)
    }
}
