//! Hand-rolled CLI (no `clap` in this offline environment).
//!
//! Subcommands:
//! * `smoke`              — compile + run every artifact once (pipeline check)
//! * `serve`              — start the long-document serving coordinator
//!                          (add `--listen <addr>` to serve over TCP)
//! * `train`              — run the MLM training driver
//! * `experiment <id>`    — regenerate one paper table/figure
//! * `graph`              — attention-graph theory report (Sec. 2 claims)
//! * `list`               — list artifacts in the manifest
//! * `bench-check`        — gate bench JSONs against committed perf baselines
//! * `kernel-probe`       — print the GEMM tile-tuner table and SIMD probe;
//!                          `--assert-simd` turns it into a CI vectorization gate
//!
//! **Argument structs.** `serve`, `train`, `bench-check`, and
//! `kernel-probe` each parse into their own typed struct
//! ([`ServeArgs`], [`TrainArgs`], [`BenchCheckArgs`],
//! [`KernelProbeArgs`]) and accept **only their own flags** — a
//! misplaced flag produces an error naming the subcommand it belongs
//! to. The experiment harnesses (`experiment <id>`, `smoke`, `graph`,
//! `list`) still share the legacy [`Flags`] grab-bag, since dozens of
//! harnesses draw different subsets from it.

use anyhow::{bail, Context, Result};

use crate::config::{AdmissionConfig, ObsConfig, PatternSelect, Precision, ServingConfig};
use crate::runtime::{parse_backend_specs, BackendSpec};

// ---------------------------------------------------------------------
// per-subcommand flag registry (drives misplaced-flag diagnostics)
// ---------------------------------------------------------------------

const SERVE_FLAGS: &[&str] = &[
    "--artifacts",
    "--seed",
    "--backends",
    "--engine-workers",
    "--max-inflight",
    "--checkpoint",
    "--precision",
    "--pattern",
    "--listen",
    "--latency-budget-ms",
    "--max-queue",
    "--trace-out",
    "--sampler-interval-ms",
    "--flight-dir",
    "--slo-p99-ms",
    "--fault",
];

const WATCH_FLAGS: &[&str] = &["--connect", "--interval-ms", "--frames", "--http"];

const TRAIN_FLAGS: &[&str] = &[
    "--artifacts",
    "--config",
    "--seed",
    "--steps",
    "--backends",
    "--checkpoint",
    "--precision",
    "--pattern",
];

const BENCH_CHECK_FLAGS: &[&str] = &[
    "--attention-json",
    "--train-json",
    "--patterns-json",
    "--baselines",
    "--update-baselines",
    "--summary",
];

const KERNEL_PROBE_FLAGS: &[&str] = &["--assert-simd"];

const SUBCOMMAND_FLAGS: &[(&str, &[&str])] = &[
    ("serve", SERVE_FLAGS),
    ("watch", WATCH_FLAGS),
    ("train", TRAIN_FLAGS),
    ("bench-check", BENCH_CHECK_FLAGS),
    ("kernel-probe", KERNEL_PROBE_FLAGS),
];

/// Diagnostic for a flag the subcommand does not take: names the
/// subcommand(s) the flag actually belongs to, then lists the valid set.
fn unknown_flag(cmd: &str, flag: &str, valid: &[&str]) -> anyhow::Error {
    let owners: Vec<&str> = SUBCOMMAND_FLAGS
        .iter()
        .filter(|(c, fl)| *c != cmd && fl.contains(&flag))
        .map(|(c, _)| *c)
        .collect();
    if owners.is_empty() {
        anyhow::anyhow!("unknown flag {flag} for `{cmd}`; valid flags: {}", valid.join(", "))
    } else {
        anyhow::anyhow!(
            "flag {flag} belongs to `{}`, not `{cmd}`; valid `{cmd}` flags: {}",
            owners.join("`/`"),
            valid.join(", ")
        )
    }
}

/// Pull the value after a `--flag` or fail naming flag and subcommand.
fn flag_value<'a>(
    it: &mut std::slice::Iter<'a, String>,
    flag: &str,
    cmd: &str,
) -> Result<&'a str> {
    match it.next() {
        Some(v) => Ok(v.as_str()),
        None => bail!("{flag} needs a value (`{cmd}`)"),
    }
}

// ---------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------

/// Arguments of `bigbird serve`: the engine-pool shape, the admission
/// policy, and (optionally) a TCP listen address for the wire ingress.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeArgs {
    /// `--artifacts <dir>` (default "artifacts"; unused by `--backends
    /// native:N`, which needs no artifacts).
    pub artifacts: String,
    /// `--seed <u64>` workload RNG seed.
    pub seed: u64,
    /// `--backends <spec>` / `--engine-workers <n>` engine pool shape.
    pub backends: Vec<BackendSpec>,
    /// `--max-inflight <n>` per-bucket inflight batch cap.
    pub max_inflight: usize,
    /// `--checkpoint <path>` native BBCKPT1 checkpoint to serve.
    pub checkpoint: Option<String>,
    /// `--precision f32|f16|int8` native GEMM precision policy.
    pub precision: Precision,
    /// `--pattern static|adaptive|learned[:k=N]` — how the native
    /// backend picks its block-sparse attention pattern.
    pub pattern: PatternSelect,
    /// `--listen <addr>`: bind the length-prefixed TCP wire ingress
    /// (e.g. `127.0.0.1:9090`; port 0 picks an ephemeral port) and
    /// drive the demo workload over real sockets. `None` keeps the
    /// in-process demo — both paths submit the same typed requests.
    pub listen: Option<String>,
    /// `--latency-budget-ms <ms>`: shed `Normal`/`Low` requests as
    /// `overloaded` while the queue-wait EWMA exceeds this budget.
    pub latency_budget_ms: Option<f64>,
    /// `--max-queue <n>`: hard cap on admitted-but-unanswered requests;
    /// past it requests shed `queue_full` so memory stays bounded.
    pub max_queue: usize,
    /// `--trace-out <path>`: enable request-span tracing and kernel
    /// phase profiling, and write the Chrome trace-event JSON
    /// (Perfetto-loadable) of the demo workload here on exit.
    pub trace_out: Option<String>,
    /// `--sampler-interval-ms <ms>`: telemetry sampler period (default
    /// 1000; 0 disables the sampler thread and the series ring stays
    /// empty).
    pub sampler_interval_ms: u64,
    /// `--flight-dir <dir>`: where the watchdog dumps flight-recorder
    /// bundles on alert edges (default: no dumps).
    pub flight_dir: Option<String>,
    /// `--slo-p99-ms <ms>`: arm the watchdog's SLO-burn detector with
    /// this windowed-p99 target.
    pub slo_p99_ms: Option<f64>,
    /// `--fault stall`: fault injection — accept and admit requests but
    /// never dispatch them (exercises the watchdog + flight recorder;
    /// never use outside tests/demos).
    pub fault_stall: bool,
}

impl Default for ServeArgs {
    fn default() -> Self {
        let sd = ServingConfig::default();
        let ad = AdmissionConfig::default();
        ServeArgs {
            artifacts: "artifacts".to_string(),
            seed: 0,
            backends: sd.backends,
            max_inflight: sd.max_inflight,
            checkpoint: None,
            precision: Precision::default(),
            pattern: PatternSelect::default(),
            listen: None,
            latency_budget_ms: ad.latency_budget_ms,
            max_queue: ad.max_queue,
            trace_out: None,
            sampler_interval_ms: crate::obs::timeseries::DEFAULT_INTERVAL_MS,
            flight_dir: None,
            slo_p99_ms: None,
            fault_stall: false,
        }
    }
}

impl ServeArgs {
    /// The serving-pool shape selected on the command line.
    pub fn serving(&self) -> ServingConfig {
        ServingConfig { backends: self.backends.clone(), max_inflight: self.max_inflight }
    }

    /// The admission policy selected on the command line (per-client
    /// cap and pressure floor keep their defaults).
    pub fn admission(&self) -> AdmissionConfig {
        AdmissionConfig {
            latency_budget_ms: self.latency_budget_ms,
            max_queue: self.max_queue,
            ..AdmissionConfig::default()
        }
    }

    /// The continuous-telemetry knobs selected on the command line
    /// (`--trace-out` additionally flips the tracing/profiling switches
    /// in `serve_demo`).
    pub fn obs(&self) -> ObsConfig {
        ObsConfig {
            sampler_interval_ms: self.sampler_interval_ms,
            slo_p99_ms: self.slo_p99_ms,
            flight_dir: self.flight_dir.clone(),
            fault_stall: self.fault_stall,
            ..ObsConfig::default()
        }
    }
}

/// Parse `serve` arguments; rejects flags of other subcommands by name.
pub fn parse_serve(args: &[String]) -> Result<ServeArgs> {
    const CMD: &str = "serve";
    let mut a = ServeArgs::default();
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--artifacts" => a.artifacts = flag_value(&mut it, "--artifacts", CMD)?.to_string(),
            "--seed" => {
                let v = flag_value(&mut it, "--seed", CMD)?;
                a.seed = v.parse().with_context(|| format!("--seed expects a u64, got {v:?}"))?;
            }
            "--backends" => a.backends = parse_backend_specs(flag_value(&mut it, "--backends", CMD)?)?,
            "--engine-workers" => {
                let v = flag_value(&mut it, "--engine-workers", CMD)?;
                let n: usize =
                    v.parse().with_context(|| format!("--engine-workers expects a count, got {v:?}"))?;
                a.backends = BackendSpec::cpu_workers(n);
            }
            "--max-inflight" => {
                let v = flag_value(&mut it, "--max-inflight", CMD)?;
                a.max_inflight =
                    v.parse().with_context(|| format!("--max-inflight expects a count, got {v:?}"))?;
            }
            "--checkpoint" => {
                a.checkpoint = Some(flag_value(&mut it, "--checkpoint", CMD)?.to_string())
            }
            "--precision" => a.precision = Precision::parse(flag_value(&mut it, "--precision", CMD)?)?,
            "--pattern" => {
                a.pattern = PatternSelect::parse(flag_value(&mut it, "--pattern", CMD)?)?
            }
            "--listen" => a.listen = Some(flag_value(&mut it, "--listen", CMD)?.to_string()),
            "--latency-budget-ms" => {
                let v = flag_value(&mut it, "--latency-budget-ms", CMD)?;
                let ms: f64 = v
                    .parse()
                    .with_context(|| format!("--latency-budget-ms expects a number, got {v:?}"))?;
                a.latency_budget_ms = Some(ms);
            }
            "--max-queue" => {
                let v = flag_value(&mut it, "--max-queue", CMD)?;
                a.max_queue =
                    v.parse().with_context(|| format!("--max-queue expects a count, got {v:?}"))?;
            }
            "--trace-out" => {
                a.trace_out = Some(flag_value(&mut it, "--trace-out", CMD)?.to_string())
            }
            "--sampler-interval-ms" => {
                let v = flag_value(&mut it, "--sampler-interval-ms", CMD)?;
                a.sampler_interval_ms = v
                    .parse()
                    .with_context(|| format!("--sampler-interval-ms expects millis, got {v:?}"))?;
            }
            "--flight-dir" => {
                a.flight_dir = Some(flag_value(&mut it, "--flight-dir", CMD)?.to_string())
            }
            "--slo-p99-ms" => {
                let v = flag_value(&mut it, "--slo-p99-ms", CMD)?;
                let ms: f64 = v
                    .parse()
                    .with_context(|| format!("--slo-p99-ms expects a number, got {v:?}"))?;
                a.slo_p99_ms = Some(ms);
            }
            "--fault" => {
                let v = flag_value(&mut it, "--fault", CMD)?;
                match v {
                    "stall" => a.fault_stall = true,
                    other => bail!("--fault supports only `stall`, got {other:?}"),
                }
            }
            other if other.starts_with("--") => return Err(unknown_flag(CMD, other, SERVE_FLAGS)),
            other => bail!("`serve` takes no positional arguments (got {other:?})"),
        }
    }
    a.serving().validate()?;
    a.admission().validate()?;
    a.obs().validate()?;
    Ok(a)
}

// ---------------------------------------------------------------------
// watch
// ---------------------------------------------------------------------

/// Arguments of `bigbird watch`: the live terminal dashboard that polls
/// a running server's Prometheus exposition.
#[derive(Clone, Debug, PartialEq)]
pub struct WatchArgs {
    /// `--connect <addr>` server address (default 127.0.0.1:9090).
    pub connect: String,
    /// `--interval-ms <ms>` poll period (default 1000).
    pub interval_ms: u64,
    /// `--frames <n>`: render n frames then exit (0 = run until ^C).
    pub frames: usize,
    /// `--http`: scrape `GET /metrics` over HTTP/1.1 instead of wire
    /// frame 7 (both hit the same ingress port).
    pub http: bool,
}

impl Default for WatchArgs {
    fn default() -> Self {
        WatchArgs {
            connect: "127.0.0.1:9090".to_string(),
            interval_ms: crate::obs::timeseries::DEFAULT_INTERVAL_MS,
            frames: 0,
            http: false,
        }
    }
}

/// Parse `watch` arguments; rejects flags of other subcommands by name.
pub fn parse_watch(args: &[String]) -> Result<WatchArgs> {
    const CMD: &str = "watch";
    let mut a = WatchArgs::default();
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--connect" => a.connect = flag_value(&mut it, "--connect", CMD)?.to_string(),
            "--interval-ms" => {
                let v = flag_value(&mut it, "--interval-ms", CMD)?;
                a.interval_ms = v
                    .parse()
                    .with_context(|| format!("--interval-ms expects millis, got {v:?}"))?;
                if a.interval_ms == 0 {
                    bail!("--interval-ms must be positive");
                }
            }
            "--frames" => {
                let v = flag_value(&mut it, "--frames", CMD)?;
                a.frames =
                    v.parse().with_context(|| format!("--frames expects a count, got {v:?}"))?;
            }
            "--http" => a.http = true,
            other if other.starts_with("--") => return Err(unknown_flag(CMD, other, WATCH_FLAGS)),
            other => bail!("`watch` takes no positional arguments (got {other:?})"),
        }
    }
    Ok(a)
}

// ---------------------------------------------------------------------
// train
// ---------------------------------------------------------------------

/// Arguments of `bigbird train`.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainArgs {
    /// `--artifacts <dir>` (PJRT path only).
    pub artifacts: String,
    /// `--config k=v,...` model config overrides (native path).
    pub config: String,
    /// `--seed <u64>`.
    pub seed: u64,
    /// `--steps <n>` (default 200).
    pub steps: usize,
    /// `--backends <spec>`: `native` selects the artifact-free trainer.
    pub backends: Vec<BackendSpec>,
    /// `--checkpoint <path>` where the native trainer writes BBCKPT1.
    pub checkpoint: Option<String>,
    /// `--precision f32|f16|int8` forward-GEMM precision (native path).
    pub precision: Precision,
    /// `--pattern static|adaptive|learned[:k=N]` — block-sparse pattern
    /// selection for the native trainer.
    pub pattern: PatternSelect,
    /// Optional positional model key (PJRT path; default
    /// `mlm_bigbird_itc_s512_b4`).
    pub model: Option<String>,
}

impl Default for TrainArgs {
    fn default() -> Self {
        TrainArgs {
            artifacts: "artifacts".to_string(),
            config: String::new(),
            seed: 0,
            steps: 200,
            backends: ServingConfig::default().backends,
            checkpoint: None,
            precision: Precision::default(),
            pattern: PatternSelect::default(),
            model: None,
        }
    }
}

/// Parse `train` arguments; rejects flags of other subcommands by name.
pub fn parse_train(args: &[String]) -> Result<TrainArgs> {
    const CMD: &str = "train";
    let mut a = TrainArgs::default();
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--artifacts" => a.artifacts = flag_value(&mut it, "--artifacts", CMD)?.to_string(),
            "--config" => a.config = flag_value(&mut it, "--config", CMD)?.to_string(),
            "--seed" => {
                let v = flag_value(&mut it, "--seed", CMD)?;
                a.seed = v.parse().with_context(|| format!("--seed expects a u64, got {v:?}"))?;
            }
            "--steps" => {
                let v = flag_value(&mut it, "--steps", CMD)?;
                a.steps = v.parse().with_context(|| format!("--steps expects a count, got {v:?}"))?;
            }
            "--backends" => a.backends = parse_backend_specs(flag_value(&mut it, "--backends", CMD)?)?,
            "--checkpoint" => {
                a.checkpoint = Some(flag_value(&mut it, "--checkpoint", CMD)?.to_string())
            }
            "--precision" => a.precision = Precision::parse(flag_value(&mut it, "--precision", CMD)?)?,
            "--pattern" => {
                a.pattern = PatternSelect::parse(flag_value(&mut it, "--pattern", CMD)?)?
            }
            other if other.starts_with("--") => return Err(unknown_flag(CMD, other, TRAIN_FLAGS)),
            other => {
                if a.model.is_some() {
                    bail!("`train` takes at most one positional model key (got extra {other:?})");
                }
                a.model = Some(other.to_string());
            }
        }
    }
    Ok(a)
}

// ---------------------------------------------------------------------
// bench-check
// ---------------------------------------------------------------------

/// Arguments of `bigbird bench-check`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BenchCheckArgs {
    /// `--attention-json <path>` (default BENCH_attention.json).
    pub attention_json: String,
    /// `--train-json <path>` (default BENCH_train.json).
    pub train_json: String,
    /// `--patterns-json <path>` (default BENCH_patterns.json; missing
    /// file is fine — the pattern-ablation section is informational).
    pub patterns_json: String,
    /// `--baselines <path>` (default bench_baselines.json).
    pub baselines: String,
    /// `--update-baselines`: rewrite baselines instead of gating.
    pub update_baselines: bool,
    /// `--summary <path>`: append the markdown report here.
    pub summary: Option<String>,
}

impl Default for BenchCheckArgs {
    fn default() -> Self {
        BenchCheckArgs {
            attention_json: "BENCH_attention.json".to_string(),
            train_json: "BENCH_train.json".to_string(),
            patterns_json: "BENCH_patterns.json".to_string(),
            baselines: "bench_baselines.json".to_string(),
            update_baselines: false,
            summary: None,
        }
    }
}

/// Parse `bench-check` arguments.
pub fn parse_bench_check(args: &[String]) -> Result<BenchCheckArgs> {
    const CMD: &str = "bench-check";
    let mut a = BenchCheckArgs::default();
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--attention-json" => {
                a.attention_json = flag_value(&mut it, "--attention-json", CMD)?.to_string()
            }
            "--train-json" => a.train_json = flag_value(&mut it, "--train-json", CMD)?.to_string(),
            "--patterns-json" => {
                a.patterns_json = flag_value(&mut it, "--patterns-json", CMD)?.to_string()
            }
            "--baselines" => a.baselines = flag_value(&mut it, "--baselines", CMD)?.to_string(),
            "--update-baselines" => a.update_baselines = true,
            "--summary" => a.summary = Some(flag_value(&mut it, "--summary", CMD)?.to_string()),
            other if other.starts_with("--") => {
                return Err(unknown_flag(CMD, other, BENCH_CHECK_FLAGS))
            }
            other => bail!("`bench-check` takes no positional arguments (got {other:?})"),
        }
    }
    Ok(a)
}

// ---------------------------------------------------------------------
// kernel-probe
// ---------------------------------------------------------------------

/// Arguments of `bigbird kernel-probe`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct KernelProbeArgs {
    /// `--assert-simd`: exit nonzero when the tiled f32 GEMM misses the
    /// vectorization floor.
    pub assert_simd: bool,
}

/// Parse `kernel-probe` arguments.
pub fn parse_kernel_probe(args: &[String]) -> Result<KernelProbeArgs> {
    const CMD: &str = "kernel-probe";
    let mut a = KernelProbeArgs::default();
    for arg in args {
        match arg.as_str() {
            "--assert-simd" => a.assert_simd = true,
            other if other.starts_with("--") => {
                return Err(unknown_flag(CMD, other, KERNEL_PROBE_FLAGS))
            }
            other => bail!("`kernel-probe` takes no positional arguments (got {other:?})"),
        }
    }
    Ok(a)
}

// ---------------------------------------------------------------------
// legacy shared flags (experiment harnesses)
// ---------------------------------------------------------------------

/// Parsed shared flags for the experiment harnesses (`experiment <id>`,
/// `smoke`, `graph`, `list`). The serving/training entrypoints use the
/// typed per-subcommand structs above instead.
#[derive(Debug, Default)]
pub struct Flags {
    /// `--artifacts <dir>` (default "artifacts").
    pub artifacts: String,
    /// `--config k=v,k=v` model config overrides.
    pub config: String,
    /// `--seed <u64>`.
    pub seed: u64,
    /// `--steps <n>` for training.
    pub steps: usize,
    /// Engine-pool worker backends: `--backends cpu:2,gpu:1`, or
    /// `--engine-workers <n>` as shorthand for `cpu:n`.
    pub backends: Vec<BackendSpec>,
    /// `--max-inflight <n>` per-bucket inflight batch cap.
    pub max_inflight: usize,
    /// `--checkpoint <path>` native checkpoint: written by
    /// `train --backends native`, loaded by `serve --backends native:N`.
    pub checkpoint: Option<String>,
    /// `--attention-json <path>`: attention bench JSON for `bench-check`.
    pub attention_json: String,
    /// `--train-json <path>`: train-step bench JSON for `bench-check`.
    pub train_json: String,
    /// `--baselines <path>`: committed perf baselines for `bench-check`.
    pub baselines: String,
    /// `--update-baselines`: rewrite the baselines from the current
    /// bench JSONs instead of gating against them.
    pub update_baselines: bool,
    /// `--summary <path>`: append the `bench-check` markdown report
    /// (pointed at `$GITHUB_STEP_SUMMARY` in CI).
    pub summary: Option<String>,
    /// `--precision f32|f16|int8`: native GEMM precision policy
    /// (default f32).
    pub precision: Precision,
    /// `--assert-simd`: make `kernel-probe` fail (exit nonzero) when the
    /// tiled f32 GEMM does not beat the scalar-chain floor.
    pub assert_simd: bool,
    /// Remaining positional args.
    pub positional: Vec<String>,
}

impl Flags {
    /// The serving-pool shape selected on the command line.
    pub fn serving(&self) -> ServingConfig {
        ServingConfig { backends: self.backends.clone(), max_inflight: self.max_inflight }
    }
}

/// Parse the legacy shared flag set out of an argument list.
pub fn parse_flags(args: &[String]) -> Result<Flags> {
    let serving_defaults = ServingConfig::default();
    let mut f = Flags {
        artifacts: "artifacts".to_string(),
        seed: 0,
        steps: 200,
        backends: serving_defaults.backends,
        max_inflight: serving_defaults.max_inflight,
        attention_json: "BENCH_attention.json".to_string(),
        train_json: "BENCH_train.json".to_string(),
        baselines: "bench_baselines.json".to_string(),
        ..Default::default()
    };
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--artifacts" => f.artifacts = it.next().context("--artifacts needs a value")?.clone(),
            "--config" => f.config = it.next().context("--config needs a value")?.clone(),
            "--seed" => f.seed = it.next().context("--seed needs a value")?.parse()?,
            "--steps" => f.steps = it.next().context("--steps needs a value")?.parse()?,
            "--backends" => {
                f.backends = parse_backend_specs(it.next().context("--backends needs a value")?)?
            }
            "--engine-workers" => {
                let n: usize = it.next().context("--engine-workers needs a value")?.parse()?;
                f.backends = BackendSpec::cpu_workers(n);
            }
            "--max-inflight" => {
                f.max_inflight = it.next().context("--max-inflight needs a value")?.parse()?
            }
            "--checkpoint" => {
                f.checkpoint = Some(it.next().context("--checkpoint needs a value")?.clone())
            }
            "--attention-json" => {
                f.attention_json = it.next().context("--attention-json needs a value")?.clone()
            }
            "--train-json" => {
                f.train_json = it.next().context("--train-json needs a value")?.clone()
            }
            "--baselines" => {
                f.baselines = it.next().context("--baselines needs a value")?.clone()
            }
            "--update-baselines" => f.update_baselines = true,
            "--precision" => {
                f.precision = Precision::parse(it.next().context("--precision needs a value")?)?
            }
            "--assert-simd" => f.assert_simd = true,
            "--summary" => {
                f.summary = Some(it.next().context("--summary needs a value")?.clone())
            }
            other if other.starts_with("--") => bail!("unknown flag {other}"),
            other => f.positional.push(other.to_string()),
        }
    }
    f.serving().validate()?;
    Ok(f)
}

const USAGE: &str = "\
bigbird — BigBird (NeurIPS 2020) reproduction leader

USAGE: bigbird <command> [flags]

Each subcommand accepts only its own flags; a misplaced flag produces an
error naming the subcommand it belongs to.

COMMANDS:
  smoke                  compile + run every artifact once
  list                   list artifacts in the manifest
  serve                  run the long-document serving demo workload;
                         with --listen, serve it over the TCP wire protocol
  watch                  live terminal dashboard: poll a serving ingress's
                         Prometheus exposition and render rates/latency/health
  train                  run the MLM training driver
  graph                  attention-graph theory report (Sec. 2)
  bench-check            gate bench JSONs against the committed perf baselines
  kernel-probe           print the per-precision GEMM tile-tuner table and the
                         SIMD vectorization probe
  experiment <id>        regenerate a paper table/figure; <id> one of:
                         table1 | mlm_bpc | qa | classification | summarization |
                         genomics | fig_ctxlen | scaling | task1 | patterns |
                         turing | ablation_global | ablate | hotpath |
                         hlo_report | all

SERVE FLAGS:
  --artifacts <dir>      artifact directory (default: artifacts; not needed
                         with --backends native:N)
  --seed <u64>           workload RNG seed (default 0)
  --backends <spec>      engine pool backends, kind[:count] comma-list
                         (e.g. cpu:2,gpu:1 or native:2; default cpu:1;
                         native runs the in-process block-sparse kernels —
                         real compute, no artifacts needed)
  --engine-workers <n>   shorthand for --backends cpu:<n>
  --max-inflight <n>     per-bucket inflight batch cap (default 2)
  --checkpoint <path>    native BBCKPT1 checkpoint to serve
  --precision <p>        native GEMM precision policy: f32 | f16 | int8
  --pattern <p>          block-sparse pattern selection for the native
                         backend: static | adaptive | learned, optionally
                         :k=N extra key blocks per query block (default:
                         static, the paper's fixed band+global+random;
                         adaptive picks top-k blocks from content,
                         learned from trained per-head scores — both keep
                         the band+global guarantee blocks)
  --listen <addr>        bind the length-prefixed TCP ingress (e.g.
                         127.0.0.1:9090; port 0 picks an ephemeral port) and
                         drive the demo over real sockets; clients speak the
                         versioned wire protocol (see rust/README.md)
  --latency-budget-ms <ms>
                         admission control: shed Normal/Low-priority requests
                         as `overloaded` while the queue-wait EWMA exceeds
                         this budget (default: no budget shedding)
  --max-queue <n>        admission control: hard cap on admitted-but-
                         unanswered requests; past it requests shed
                         `queue_full` (default 1024)
  --trace-out <path>     enable request-span tracing + kernel phase
                         profiling and write the demo's Chrome
                         trace-event JSON here (load at ui.perfetto.dev)
  --sampler-interval-ms <ms>
                         telemetry sampler period (default 1000; 0 turns the
                         sampler off — scrapes then see no window series)
  --flight-dir <dir>     dump flight-recorder bundles (trace.json +
                         series.json + snapshot.json) here when a watchdog
                         detector fires
  --slo-p99-ms <ms>      arm the SLO-burn detector: alert when the windowed
                         p99 latency stays above this target
  --fault <mode>         fault injection; `stall` admits but never dispatches,
                         turning `serve` into a self-checking watchdog drill:
                         it waits for degraded health, validates /healthz and
                         the flight bundle, then exits (non-zero on failure)

WATCH FLAGS:
  --connect <addr>       serving ingress to poll (default 127.0.0.1:9090)
  --interval-ms <ms>     poll period (default 1000)
  --frames <n>           render n frames then exit (default: until ^C)
  --http                 scrape HTTP GET /metrics instead of wire frame 7

TRAIN FLAGS:
  --artifacts <dir>      artifact directory (PJRT path)
  --config k=v,...       model config overrides (native path)
  --seed <u64>           RNG seed (default 0)
  --steps <n>            training steps (default 200)
  --backends <spec>      `native` selects the artifact-free trainer
  --checkpoint <path>    where the native trainer writes BBCKPT1
                         (default runs/native_mlm.ckpt)
  --precision <p>        forward-GEMM precision: f32 | f16 | int8
  --pattern <p>          static | adaptive | learned[:k=N] pattern
                         selection (native path; learned adds trainable
                         per-head block scores to the checkpoint)
  [model]                positional model key (PJRT path)

BENCH-CHECK FLAGS:
  --attention-json <p>   attention bench JSON (default BENCH_attention.json)
  --train-json <p>       train-step bench JSON (default BENCH_train.json)
  --patterns-json <p>    pattern-ablation bench JSON from
                         `experiment ablate` (default BENCH_patterns.json;
                         informational — never gated, missing is fine)
  --baselines <p>        committed perf baselines (default bench_baselines.json)
  --update-baselines     rewrite the baselines instead of gating
  --summary <p>          append the markdown perf report here
                         ($GITHUB_STEP_SUMMARY in CI)

KERNEL-PROBE FLAGS:
  --assert-simd          fail loudly when the tiled f32 GEMM does not clear
                         the scalar-chain vectorization floor

EXPERIMENT/SMOKE/GRAPH/LIST FLAGS (shared legacy set):
  --artifacts, --config, --seed, --steps, --backends, --engine-workers,
  --max-inflight, --checkpoint, --precision
";

/// CLI entrypoint used by `main.rs`.
pub fn run(args: &[String]) -> Result<()> {
    if args.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = args[0].as_str();
    let rest = &args[1..];
    match cmd {
        "serve" => crate::experiments::serve_demo::run(&parse_serve(rest)?),
        "watch" => crate::experiments::watch::run(&parse_watch(rest)?),
        "train" => crate::experiments::train_demo::run(&parse_train(rest)?),
        "bench-check" => {
            let a = parse_bench_check(rest)?;
            crate::bench_check::run(&crate::bench_check::BenchCheck {
                attention: &a.attention_json,
                train: &a.train_json,
                patterns: &a.patterns_json,
                baselines: &a.baselines,
                update: a.update_baselines,
                summary: a.summary.as_deref(),
            })
        }
        "kernel-probe" => run_kernel_probe(&parse_kernel_probe(rest)?),
        "smoke" => crate::experiments::smoke::run(&parse_flags(rest)?),
        "list" => {
            let flags = parse_flags(rest)?;
            let manifest = crate::runtime::Manifest::load(&flags.artifacts)?;
            for e in manifest.entries() {
                println!(
                    "{:40} {:28} in={} out={} meta={:?}",
                    e.name,
                    e.file,
                    e.io.inputs.len(),
                    e.io.outputs.len(),
                    e.meta
                );
            }
            Ok(())
        }
        "graph" => crate::experiments::graph_report::run(&parse_flags(rest)?),
        "experiment" => {
            let flags = parse_flags(rest)?;
            let id = flags
                .positional
                .first()
                .context("experiment needs an id; see `bigbird` for the list")?
                .clone();
            crate::experiments::dispatch(&id, &flags)
        }
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}; run `bigbird help`"),
    }
}

/// Run a fixed block-sparse forward + backward + model-GEMM workload
/// with phase profiling on, and return the per-phase achieved
/// flop/byte profile. Printed by `kernel-probe` so a SIMD-floor
/// failure shows *which* phase degraded, not just that the aggregate
/// ratio fell.
fn phase_profile_stats() -> Vec<crate::obs::phase::PhaseStat> {
    use crate::attention::{PatternSource, PatternSpec};
    use crate::config::AttnVariant;
    use crate::kernel::{
        model_gemm, sparse_backward_batch_heads, sparse_forward_batch_training_heads, HeadViews,
        PackedMat,
    };
    use crate::obs::phase;
    let was = phase::enabled();
    phase::set_enabled(true);
    phase::reset();
    let spec = PatternSpec {
        variant: AttnVariant::BigBirdItc,
        nb: 16,
        global_blocks: 1,
        window_blocks: 3,
        random_blocks: 1,
        seed: 7,
    };
    let pattern = PatternSource::Static(spec).compile(16);
    let (batch, heads, d) = (2usize, 4usize, 32usize);
    let n = pattern.seq_len();
    let vol = batch * heads * n * d;
    let mut rng = crate::util::Rng::new(17);
    let q: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
    let k: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
    let v: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
    let x = HeadViews { q: &q, k: &k, v: &v, key_valid: None };
    let mut o = vec![0.0f32; vol];
    let mut m = vec![0.0f32; batch * heads * n];
    let mut l = vec![0.0f32; batch * heads * n];
    sparse_forward_batch_training_heads(&x, batch, heads, d, &pattern, &mut o, &mut m, &mut l);
    let (mut dq, mut dk, mut dv) =
        (vec![0.0f32; vol], vec![0.0f32; vol], vec![0.0f32; vol]);
    sparse_backward_batch_heads(
        &x, &o, &o, &m, &l, batch, heads, d, &pattern, &mut dq, &mut dk, &mut dv,
    );
    let (gm, gk, gn) = (128usize, 128usize, 128usize);
    let a: Vec<f32> = (0..gm * gk).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..gk * gn).map(|_| rng.normal() as f32).collect();
    let packed = PackedMat::pack(&b, gk, gn, Precision::F32);
    let mut out = vec![0.0f32; gm * gn];
    model_gemm(&a, &packed, gm, &mut out);
    let stats = phase::snapshot();
    phase::set_enabled(was);
    stats
}

/// `kernel-probe`: report the per-precision GEMM tile-tuner winners,
/// the SIMD vectorization probe, and the per-phase flop/byte profile
/// of a fixed kernel workload. With `--assert-simd` it becomes the CI
/// vectorization gate: exit nonzero (remediation steps on stderr via the
/// error) when the tiled f32 kernel fails [`crate::kernel::MIN_SIMD_RATIO`]
/// — the phase table is still printed first, so the failing run names
/// the degraded phase.
fn run_kernel_probe(args: &KernelProbeArgs) -> Result<()> {
    let tiles = crate::kernel::tuned_tiles();
    println!("GEMM tile auto-tuner (winning MRxNR shape per precision):");
    for (name, choice) in [("f32", &tiles.f32), ("f16", &tiles.f16), ("int8", &tiles.int8)] {
        println!("  {name:<5} {:>5}  {:8.2} GFLOP/s", choice.shape.as_str(), choice.gflops);
    }
    let phases = phase_profile_stats();
    let print_phases = || {
        println!("kernel phase profile (fixed forward+backward+GEMM workload):");
        println!(
            "  {:<9} {:>7} {:>10} {:>9} {:>9} {:>10} {:>9}",
            "phase", "calls", "busy_ms", "GFLOP", "GB", "GFLOP/s", "GB/s"
        );
        for s in &phases {
            println!(
                "  {:<9} {:>7} {:>10.3} {:>9.4} {:>9.4} {:>10.2} {:>9.2}",
                s.phase,
                s.calls,
                s.busy_ms,
                s.gflop,
                s.gbyte,
                s.achieved_gflops(),
                s.achieved_gbps()
            );
        }
    };
    let report = |p: &crate::kernel::SimdProbe| {
        println!("SIMD probe (96x96x96 packed GEMM vs scalar dependency chain):");
        println!("  scalar chain {:8.2} GFLOP/s", p.scalar_gflops);
        println!("  tiled f32    {:8.2} GFLOP/s  ({:.2}x scalar)", p.f32_gflops, p.ratio());
        println!("  tiled f16    {:8.2} GFLOP/s", p.f16_gflops);
        println!("  tiled int8   {:8.2} GFLOP/s", p.int8_gflops);
    };
    if args.assert_simd {
        match crate::kernel::assert_simd_floor() {
            Ok(probe) => {
                report(&probe);
                print_phases();
                println!(
                    "vectorization floor OK: {:.2}x >= required {:.1}x",
                    probe.ratio(),
                    crate::kernel::MIN_SIMD_RATIO
                );
            }
            Err(msg) => {
                print_phases();
                return Err(anyhow::Error::msg(msg));
            }
        }
    } else {
        let probe = crate::kernel::simd_probe();
        report(&probe);
        print_phases();
        println!(
            "(informational; pass --assert-simd to enforce the {:.1}x floor)",
            crate::kernel::MIN_SIMD_RATIO
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    // -- per-subcommand parsers -----------------------------------------

    #[test]
    fn serve_defaults_match_configs() {
        let a = parse_serve(&s(&[])).unwrap();
        assert_eq!(a.serving(), ServingConfig::default());
        assert_eq!(a.admission(), AdmissionConfig::default());
        assert_eq!(a.listen, None);
    }

    #[test]
    fn serve_parses_ingress_and_admission_flags() {
        let a = parse_serve(&s(&[
            "--backends",
            "native:2",
            "--listen",
            "127.0.0.1:0",
            "--latency-budget-ms",
            "25",
            "--max-queue",
            "64",
            "--trace-out",
            "trace.json",
        ]))
        .unwrap();
        assert_eq!(a.backends, BackendSpec::native_workers(2));
        assert_eq!(a.listen.as_deref(), Some("127.0.0.1:0"));
        assert_eq!(a.trace_out.as_deref(), Some("trace.json"));
        // --trace-out is off by default and needs a value
        assert_eq!(parse_serve(&s(&[])).unwrap().trace_out, None);
        assert!(parse_serve(&s(&["--trace-out"])).is_err());
        let adm = a.admission();
        assert_eq!(adm.latency_budget_ms, Some(25.0));
        assert_eq!(adm.max_queue, 64);
        // untouched knobs keep their defaults
        assert_eq!(adm.max_client_inflight, AdmissionConfig::default().max_client_inflight);
        // invalid admission values are rejected at parse time
        assert!(parse_serve(&s(&["--max-queue", "0"])).is_err());
        assert!(parse_serve(&s(&["--latency-budget-ms", "-3"])).is_err());
        assert!(parse_serve(&s(&["--engine-workers", "0"])).is_err());
        assert!(parse_serve(&s(&["--max-inflight", "0"])).is_err());
    }

    #[test]
    fn serve_parses_observability_flags() {
        let a = parse_serve(&s(&[])).unwrap();
        assert_eq!(a.sampler_interval_ms, crate::obs::timeseries::DEFAULT_INTERVAL_MS);
        assert_eq!(a.flight_dir, None);
        assert_eq!(a.slo_p99_ms, None);
        assert!(!a.fault_stall);
        let a = parse_serve(&s(&[
            "--sampler-interval-ms",
            "250",
            "--flight-dir",
            "runs/flight",
            "--slo-p99-ms",
            "80",
            "--fault",
            "stall",
        ]))
        .unwrap();
        assert_eq!(a.sampler_interval_ms, 250);
        assert_eq!(a.flight_dir.as_deref(), Some("runs/flight"));
        assert_eq!(a.slo_p99_ms, Some(80.0));
        assert!(a.fault_stall);
        let obs = a.obs();
        assert_eq!(obs.sampler_interval_ms, 250);
        assert!(obs.fault_stall);
        // sampler off is allowed; bad SLO targets and fault modes are not
        let zero = parse_serve(&s(&["--sampler-interval-ms", "0"])).unwrap();
        assert_eq!(zero.sampler_interval_ms, 0);
        assert!(parse_serve(&s(&["--slo-p99-ms", "0"])).is_err());
        assert!(parse_serve(&s(&["--slo-p99-ms", "-5"])).is_err());
        assert!(parse_serve(&s(&["--fault", "jitter"])).is_err());
    }

    #[test]
    fn watch_parses_own_flags() {
        let a = parse_watch(&s(&[])).unwrap();
        assert_eq!(a, WatchArgs::default());
        let a = parse_watch(&s(&[
            "--connect",
            "127.0.0.1:9191",
            "--interval-ms",
            "200",
            "--frames",
            "3",
            "--http",
        ]))
        .unwrap();
        assert_eq!(a.connect, "127.0.0.1:9191");
        assert_eq!(a.interval_ms, 200);
        assert_eq!(a.frames, 3);
        assert!(a.http);
        assert!(parse_watch(&s(&["--interval-ms", "0"])).is_err());
        // foreign flags name their owner; positionals are rejected
        let e = parse_watch(&s(&["--listen", ":0"])).unwrap_err().to_string();
        assert!(e.contains("`serve`"), "missing owner in: {e}");
        assert!(parse_watch(&s(&["stray"])).is_err());
    }

    #[test]
    fn serve_and_train_parse_pattern_flag() {
        // default is the paper's static pattern on both subcommands
        assert_eq!(parse_serve(&s(&[])).unwrap().pattern, PatternSelect::Static);
        assert_eq!(parse_train(&s(&[])).unwrap().pattern, PatternSelect::Static);
        let a = parse_serve(&s(&["--pattern", "adaptive"])).unwrap();
        assert_eq!(a.pattern, PatternSelect::Adaptive { k: 0 });
        let a = parse_serve(&s(&["--pattern", "learned:k=2"])).unwrap();
        assert_eq!(a.pattern, PatternSelect::Learned { k: 2 });
        let a = parse_train(&s(&["--pattern", "adaptive:k=3"])).unwrap();
        assert_eq!(a.pattern, PatternSelect::Adaptive { k: 3 });
        // bad kinds/values are rejected with the parse error, a missing
        // value names the owning subcommand
        assert!(parse_serve(&s(&["--pattern", "bogus"])).is_err());
        assert!(parse_train(&s(&["--pattern", "static:k=1"])).is_err());
        let e = parse_train(&s(&["--pattern"])).unwrap_err().to_string();
        assert!(e.contains("`train`"), "missing subcommand in: {e}");
        // --pattern is not a watch/bench-check/kernel-probe flag: the
        // error names its owners
        let e = parse_watch(&s(&["--pattern", "adaptive"])).unwrap_err().to_string();
        assert!(e.contains("`serve`") && e.contains("`train`"), "missing owners in: {e}");
    }

    #[test]
    fn serve_rejects_foreign_and_unknown_flags() {
        // --steps belongs to train: the error names both subcommands
        let e = parse_serve(&s(&["--steps", "50"])).unwrap_err().to_string();
        assert!(e.contains("`train`"), "missing owner in: {e}");
        assert!(e.contains("`serve`"), "missing subcommand in: {e}");
        // --assert-simd belongs to kernel-probe
        let e = parse_serve(&s(&["--assert-simd"])).unwrap_err().to_string();
        assert!(e.contains("`kernel-probe`"), "missing owner in: {e}");
        // a flag nobody owns lists the valid serve set
        let e = parse_serve(&s(&["--bogus"])).unwrap_err().to_string();
        assert!(e.contains("unknown flag --bogus"), "bad message: {e}");
        assert!(e.contains("--listen"), "valid-flag list missing in: {e}");
        // serve takes no positionals
        assert!(parse_serve(&s(&["table1"])).is_err());
    }

    #[test]
    fn train_parses_own_flags_and_model_positional() {
        let a = parse_train(&s(&["--steps", "50", "--seed", "7", "my_model"])).unwrap();
        assert_eq!(a.steps, 50);
        assert_eq!(a.seed, 7);
        assert_eq!(a.model.as_deref(), Some("my_model"));
        let a = parse_train(&s(&["--backends", "native", "--checkpoint", "runs/x.ckpt"])).unwrap();
        assert_eq!(a.backends[0].kind, crate::runtime::BackendKind::Native);
        assert_eq!(a.checkpoint.as_deref(), Some("runs/x.ckpt"));
        assert_eq!(a.model, None);
        // serve-only flags are named as such
        let e = parse_train(&s(&["--listen", ":0"])).unwrap_err().to_string();
        assert!(e.contains("`serve`"), "missing owner in: {e}");
        let e = parse_train(&s(&["--max-queue", "9"])).unwrap_err().to_string();
        assert!(e.contains("`serve`"), "missing owner in: {e}");
        // at most one positional
        assert!(parse_train(&s(&["a", "b"])).is_err());
    }

    #[test]
    fn bench_check_and_kernel_probe_parse() {
        let a = parse_bench_check(&s(&[])).unwrap();
        assert_eq!(a, BenchCheckArgs::default());
        let a = parse_bench_check(&s(&[
            "--attention-json",
            "a.json",
            "--train-json",
            "t.json",
            "--baselines",
            "b.json",
            "--update-baselines",
            "--summary",
            "s.md",
        ]))
        .unwrap();
        assert_eq!(a.attention_json, "a.json");
        assert_eq!(a.train_json, "t.json");
        assert_eq!(a.baselines, "b.json");
        assert_eq!(a.patterns_json, "BENCH_patterns.json");
        let a = parse_bench_check(&s(&["--patterns-json", "p.json"])).unwrap();
        assert_eq!(a.patterns_json, "p.json");
        assert!(a.update_baselines);
        assert_eq!(a.summary.as_deref(), Some("s.md"));
        assert!(parse_bench_check(&s(&["--summary"])).is_err());
        let e = parse_bench_check(&s(&["--seed", "1"])).unwrap_err().to_string();
        assert!(e.contains("`bench-check`"), "missing subcommand in: {e}");

        assert!(!parse_kernel_probe(&s(&[])).unwrap().assert_simd);
        assert!(parse_kernel_probe(&s(&["--assert-simd"])).unwrap().assert_simd);
        let e = parse_kernel_probe(&s(&["--summary", "s.md"])).unwrap_err().to_string();
        assert!(e.contains("`bench-check`"), "missing owner in: {e}");
        assert!(parse_kernel_probe(&s(&["stray"])).is_err());
    }

    // -- legacy shared parser -------------------------------------------

    #[test]
    fn parse_defaults() {
        let f = parse_flags(&s(&[])).unwrap();
        assert_eq!(f.artifacts, "artifacts");
        assert_eq!(f.steps, 200);
        assert_eq!(f.serving(), ServingConfig::default());
    }

    #[test]
    fn parse_flags_and_positionals() {
        let f = parse_flags(&s(&["table1", "--seed", "7", "--steps", "50"])).unwrap();
        assert_eq!(f.positional, vec!["table1"]);
        assert_eq!(f.seed, 7);
        assert_eq!(f.steps, 50);
    }

    #[test]
    fn parse_serving_flags() {
        let f = parse_flags(&s(&["--engine-workers", "4", "--max-inflight", "8"])).unwrap();
        assert_eq!(f.backends, BackendSpec::cpu_workers(4));
        assert_eq!(f.max_inflight, 8);
        // zero workers is rejected at parse time
        assert!(parse_flags(&s(&["--engine-workers", "0"])).is_err());
        assert!(parse_flags(&s(&["--max-inflight", "0"])).is_err());
    }

    #[test]
    fn parse_backends_flag() {
        use crate::runtime::BackendKind;
        let f = parse_flags(&s(&["--backends", "cpu:2,gpu:1"])).unwrap();
        assert_eq!(f.backends.len(), 3);
        assert_eq!(f.backends[2].kind, BackendKind::Gpu);
        assert_eq!(f.serving().n_workers(), 3);
        // the last of --backends / --engine-workers wins
        let f = parse_flags(&s(&["--backends", "gpu:2", "--engine-workers", "1"])).unwrap();
        assert_eq!(f.backends, BackendSpec::cpu_workers(1));
        // malformed specs are rejected at parse time
        assert!(parse_flags(&s(&["--backends", "npu:1"])).is_err());
        assert!(parse_flags(&s(&["--backends", "cpu:0"])).is_err());
        assert!(parse_flags(&s(&["--backends", ""])).is_err());
    }

    #[test]
    fn parse_native_backends() {
        use crate::runtime::BackendKind;
        let f = parse_flags(&s(&["--backends", "native:2,cpu:1"])).unwrap();
        assert_eq!(f.backends.len(), 3);
        assert_eq!(f.backends[0].kind, BackendKind::Native);
        assert_eq!(f.backends[1].kind, BackendKind::Native);
        assert_eq!(f.backends[2].kind, BackendKind::Cpu);
    }

    #[test]
    fn parse_precision_and_simd_flags() {
        let f = parse_flags(&s(&[])).unwrap();
        assert_eq!(f.precision, Precision::F32);
        assert!(!f.assert_simd);
        let f = parse_flags(&s(&["--precision", "int8", "--assert-simd"])).unwrap();
        assert_eq!(f.precision, Precision::Int8);
        assert!(f.assert_simd);
        assert_eq!(parse_flags(&s(&["--precision", "f16"])).unwrap().precision, Precision::F16);
        // unknown modes and a missing value are rejected at parse time
        assert!(parse_flags(&s(&["--precision", "bf16"])).is_err());
        assert!(parse_flags(&s(&["--precision"])).is_err());
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(parse_flags(&s(&["--bogus"])).is_err());
    }
}
