//! Hand-rolled CLI (no `clap` in this offline environment).
//!
//! Subcommands:
//! * `smoke`              — compile + run every artifact once (pipeline check)
//! * `serve`              — start the long-document serving coordinator
//! * `train`              — run the MLM training driver
//! * `experiment <id>`    — regenerate one paper table/figure
//! * `graph`              — attention-graph theory report (Sec. 2 claims)
//! * `list`               — list artifacts in the manifest
//! * `bench-check`        — gate bench JSONs against committed perf baselines
//! * `kernel-probe`       — print the GEMM tile-tuner table and SIMD probe;
//!                          `--assert-simd` turns it into a CI vectorization gate

use anyhow::{bail, Context, Result};

use crate::config::Precision;
use crate::runtime::{parse_backend_specs, BackendSpec};

/// Parsed global flags.
#[derive(Debug, Default)]
pub struct Flags {
    /// `--artifacts <dir>` (default "artifacts").
    pub artifacts: String,
    /// `--config k=v,k=v` model config overrides.
    pub config: String,
    /// `--seed <u64>`.
    pub seed: u64,
    /// `--steps <n>` for training.
    pub steps: usize,
    /// Engine-pool worker backends: `--backends cpu:2,gpu:1`, or
    /// `--engine-workers <n>` as shorthand for `cpu:n`.
    pub backends: Vec<BackendSpec>,
    /// `--max-inflight <n>` per-bucket inflight batch cap.
    pub max_inflight: usize,
    /// `--checkpoint <path>` native checkpoint: written by
    /// `train --backends native`, loaded by `serve --backends native:N`.
    pub checkpoint: Option<String>,
    /// `--attention-json <path>`: attention bench JSON for `bench-check`.
    pub attention_json: String,
    /// `--train-json <path>`: train-step bench JSON for `bench-check`.
    pub train_json: String,
    /// `--baselines <path>`: committed perf baselines for `bench-check`.
    pub baselines: String,
    /// `--update-baselines`: rewrite the baselines from the current
    /// bench JSONs instead of gating against them.
    pub update_baselines: bool,
    /// `--summary <path>`: append the `bench-check` markdown report
    /// (pointed at `$GITHUB_STEP_SUMMARY` in CI).
    pub summary: Option<String>,
    /// `--precision f32|f16|int8`: native GEMM precision policy for
    /// `serve` and `train` (default f32; training keeps master weights
    /// f32 and quantizes on pack, so checkpoints stay `BBCKPT1`).
    pub precision: Precision,
    /// `--assert-simd`: make `kernel-probe` fail (exit nonzero) when the
    /// tiled f32 GEMM does not beat the scalar-chain floor.
    pub assert_simd: bool,
    /// Remaining positional args.
    pub positional: Vec<String>,
}

impl Flags {
    /// The serving-pool shape selected on the command line.
    pub fn serving(&self) -> crate::config::ServingConfig {
        crate::config::ServingConfig {
            backends: self.backends.clone(),
            max_inflight: self.max_inflight,
        }
    }
}

/// Parse flags out of an argument list.
pub fn parse_flags(args: &[String]) -> Result<Flags> {
    let serving_defaults = crate::config::ServingConfig::default();
    let mut f = Flags {
        artifacts: "artifacts".to_string(),
        seed: 0,
        steps: 200,
        backends: serving_defaults.backends,
        max_inflight: serving_defaults.max_inflight,
        attention_json: "BENCH_attention.json".to_string(),
        train_json: "BENCH_train.json".to_string(),
        baselines: "bench_baselines.json".to_string(),
        ..Default::default()
    };
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--artifacts" => f.artifacts = it.next().context("--artifacts needs a value")?.clone(),
            "--config" => f.config = it.next().context("--config needs a value")?.clone(),
            "--seed" => f.seed = it.next().context("--seed needs a value")?.parse()?,
            "--steps" => f.steps = it.next().context("--steps needs a value")?.parse()?,
            "--backends" => {
                f.backends = parse_backend_specs(it.next().context("--backends needs a value")?)?
            }
            "--engine-workers" => {
                let n: usize = it.next().context("--engine-workers needs a value")?.parse()?;
                f.backends = BackendSpec::cpu_workers(n);
            }
            "--max-inflight" => {
                f.max_inflight = it.next().context("--max-inflight needs a value")?.parse()?
            }
            "--checkpoint" => {
                f.checkpoint = Some(it.next().context("--checkpoint needs a value")?.clone())
            }
            "--attention-json" => {
                f.attention_json = it.next().context("--attention-json needs a value")?.clone()
            }
            "--train-json" => {
                f.train_json = it.next().context("--train-json needs a value")?.clone()
            }
            "--baselines" => {
                f.baselines = it.next().context("--baselines needs a value")?.clone()
            }
            "--update-baselines" => f.update_baselines = true,
            "--precision" => {
                f.precision = Precision::parse(it.next().context("--precision needs a value")?)?
            }
            "--assert-simd" => f.assert_simd = true,
            "--summary" => {
                f.summary = Some(it.next().context("--summary needs a value")?.clone())
            }
            other if other.starts_with("--") => bail!("unknown flag {other}"),
            other => f.positional.push(other.to_string()),
        }
    }
    f.serving().validate()?;
    Ok(f)
}

const USAGE: &str = "\
bigbird — BigBird (NeurIPS 2020) reproduction leader

USAGE: bigbird <command> [flags]

COMMANDS:
  smoke                  compile + run every artifact once
  list                   list artifacts in the manifest
  serve                  run the long-document serving demo workload
  train                  run the MLM training driver
  graph                  attention-graph theory report (Sec. 2)
  bench-check            gate BENCH_attention.json / BENCH_train.json against
                         the committed perf baselines (bench_baselines.json);
                         --update-baselines refreshes them, --summary <path>
                         appends a markdown report ($GITHUB_STEP_SUMMARY)
  kernel-probe           print the per-precision GEMM tile-tuner table and the
                         SIMD vectorization probe; with --assert-simd, exit
                         nonzero (with remediation steps) when the tiled f32
                         kernel fails the vectorization floor — run on the
                         release binary in CI
  experiment <id>        regenerate a paper table/figure; <id> one of:
                         table1 | mlm_bpc | qa | classification | summarization |
                         genomics | fig_ctxlen | scaling | task1 | patterns |
                         turing | ablation_global | hotpath | hlo_report | all

FLAGS:
  --artifacts <dir>      artifact directory (default: artifacts)
  --config k=v,...       model config overrides
  --seed <u64>           RNG seed (default 0)
  --steps <n>            training steps (default 200)
  --backends <spec>      engine pool backends, kind[:count] comma-list
                         (e.g. cpu:2,gpu:1 or native:2; default cpu:1;
                         gpu/tpu fall back to cpu when no PJRT plugin is
                         present; native runs the in-process block-sparse
                         kernels — real compute, no artifacts needed)
  --engine-workers <n>   shorthand for --backends cpu:<n>
  --max-inflight <n>     per-bucket inflight batch cap (default 2)
  --checkpoint <path>    native BBCKPT1 checkpoint: train --backends native
                         writes it (default runs/native_mlm.ckpt), serve
                         --backends native:N loads it and serves the trained
                         weights
  --attention-json <p>   bench-check: attention bench JSON
                         (default BENCH_attention.json)
  --train-json <p>       bench-check: train-step bench JSON
                         (default BENCH_train.json)
  --baselines <p>        bench-check: committed perf baselines
                         (default bench_baselines.json)
  --update-baselines     bench-check: rewrite the baselines from the
                         current bench JSONs instead of gating
  --summary <p>          bench-check: append the markdown perf report here
  --precision <p>        native GEMM precision policy: f32 | f16 | int8
                         (default f32; serve quantizes the packed weights,
                         train keeps f32 master weights and quantizes on
                         pack — checkpoints stay BBCKPT1 either way)
  --assert-simd          kernel-probe: fail loudly when the tiled f32 GEMM
                         does not clear the scalar-chain vectorization floor
";

/// CLI entrypoint used by `main.rs`.
pub fn run(args: &[String]) -> Result<()> {
    if args.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = args[0].as_str();
    let flags = parse_flags(&args[1..])?;
    match cmd {
        "smoke" => crate::experiments::smoke::run(&flags),
        "list" => {
            let manifest = crate::runtime::Manifest::load(&flags.artifacts)?;
            for e in manifest.entries() {
                println!(
                    "{:40} {:28} in={} out={} meta={:?}",
                    e.name,
                    e.file,
                    e.io.inputs.len(),
                    e.io.outputs.len(),
                    e.meta
                );
            }
            Ok(())
        }
        "serve" => crate::experiments::serve_demo::run(&flags),
        "train" => crate::experiments::train_demo::run(&flags),
        "graph" => crate::experiments::graph_report::run(&flags),
        "kernel-probe" => run_kernel_probe(&flags),
        "bench-check" => crate::bench_check::run(&crate::bench_check::BenchCheck {
            attention: &flags.attention_json,
            train: &flags.train_json,
            baselines: &flags.baselines,
            update: flags.update_baselines,
            summary: flags.summary.as_deref(),
        }),
        "experiment" => {
            let id = flags
                .positional
                .first()
                .context("experiment needs an id; see `bigbird` for the list")?
                .clone();
            crate::experiments::dispatch(&id, &flags)
        }
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}; run `bigbird help`"),
    }
}

/// `kernel-probe`: report the per-precision GEMM tile-tuner winners and
/// the SIMD vectorization probe. With `--assert-simd` it becomes the CI
/// vectorization gate: exit nonzero (remediation steps on stderr via the
/// error) when the tiled f32 kernel fails [`crate::kernel::MIN_SIMD_RATIO`].
fn run_kernel_probe(flags: &Flags) -> Result<()> {
    let tiles = crate::kernel::tuned_tiles();
    println!("GEMM tile auto-tuner (winning MRxNR shape per precision):");
    for (name, choice) in [("f32", &tiles.f32), ("f16", &tiles.f16), ("int8", &tiles.int8)] {
        println!("  {name:<5} {:>5}  {:8.2} GFLOP/s", choice.shape.as_str(), choice.gflops);
    }
    let report = |p: &crate::kernel::SimdProbe| {
        println!("SIMD probe (96x96x96 packed GEMM vs scalar dependency chain):");
        println!("  scalar chain {:8.2} GFLOP/s", p.scalar_gflops);
        println!("  tiled f32    {:8.2} GFLOP/s  ({:.2}x scalar)", p.f32_gflops, p.ratio());
        println!("  tiled f16    {:8.2} GFLOP/s", p.f16_gflops);
        println!("  tiled int8   {:8.2} GFLOP/s", p.int8_gflops);
    };
    if flags.assert_simd {
        let probe = crate::kernel::assert_simd_floor().map_err(anyhow::Error::msg)?;
        report(&probe);
        println!(
            "vectorization floor OK: {:.2}x >= required {:.1}x",
            probe.ratio(),
            crate::kernel::MIN_SIMD_RATIO
        );
    } else {
        let probe = crate::kernel::simd_probe();
        report(&probe);
        println!(
            "(informational; pass --assert-simd to enforce the {:.1}x floor)",
            crate::kernel::MIN_SIMD_RATIO
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_defaults() {
        let f = parse_flags(&s(&[])).unwrap();
        assert_eq!(f.artifacts, "artifacts");
        assert_eq!(f.steps, 200);
        assert_eq!(f.serving(), crate::config::ServingConfig::default());
    }

    #[test]
    fn parse_flags_and_positionals() {
        let f = parse_flags(&s(&["table1", "--seed", "7", "--steps", "50"])).unwrap();
        assert_eq!(f.positional, vec!["table1"]);
        assert_eq!(f.seed, 7);
        assert_eq!(f.steps, 50);
    }

    #[test]
    fn parse_serving_flags() {
        let f = parse_flags(&s(&["--engine-workers", "4", "--max-inflight", "8"])).unwrap();
        assert_eq!(f.backends, BackendSpec::cpu_workers(4));
        assert_eq!(f.max_inflight, 8);
        // zero workers is rejected at parse time
        assert!(parse_flags(&s(&["--engine-workers", "0"])).is_err());
        assert!(parse_flags(&s(&["--max-inflight", "0"])).is_err());
    }

    #[test]
    fn parse_backends_flag() {
        use crate::runtime::BackendKind;
        let f = parse_flags(&s(&["--backends", "cpu:2,gpu:1"])).unwrap();
        assert_eq!(f.backends.len(), 3);
        assert_eq!(f.backends[2].kind, BackendKind::Gpu);
        assert_eq!(f.serving().n_workers(), 3);
        // the last of --backends / --engine-workers wins
        let f = parse_flags(&s(&["--backends", "gpu:2", "--engine-workers", "1"])).unwrap();
        assert_eq!(f.backends, BackendSpec::cpu_workers(1));
        // malformed specs are rejected at parse time
        assert!(parse_flags(&s(&["--backends", "npu:1"])).is_err());
        assert!(parse_flags(&s(&["--backends", "cpu:0"])).is_err());
        assert!(parse_flags(&s(&["--backends", ""])).is_err());
    }

    #[test]
    fn parse_native_backends() {
        use crate::runtime::BackendKind;
        let f = parse_flags(&s(&["--backends", "native:2,cpu:1"])).unwrap();
        assert_eq!(f.backends.len(), 3);
        assert_eq!(f.backends[0].kind, BackendKind::Native);
        assert_eq!(f.backends[1].kind, BackendKind::Native);
        assert_eq!(f.backends[2].kind, BackendKind::Cpu);
    }

    #[test]
    fn parse_checkpoint_flag() {
        let f = parse_flags(&s(&["--checkpoint", "runs/x.ckpt"])).unwrap();
        assert_eq!(f.checkpoint.as_deref(), Some("runs/x.ckpt"));
        assert_eq!(parse_flags(&s(&[])).unwrap().checkpoint, None);
        assert!(parse_flags(&s(&["--checkpoint"])).is_err());
    }

    #[test]
    fn parse_bench_check_flags() {
        let f = parse_flags(&s(&[])).unwrap();
        assert_eq!(f.attention_json, "BENCH_attention.json");
        assert_eq!(f.train_json, "BENCH_train.json");
        assert_eq!(f.baselines, "bench_baselines.json");
        assert!(!f.update_baselines);
        assert_eq!(f.summary, None);
        let f = parse_flags(&s(&[
            "--attention-json",
            "a.json",
            "--train-json",
            "t.json",
            "--baselines",
            "b.json",
            "--update-baselines",
            "--summary",
            "s.md",
        ]))
        .unwrap();
        assert_eq!(f.attention_json, "a.json");
        assert_eq!(f.train_json, "t.json");
        assert_eq!(f.baselines, "b.json");
        assert!(f.update_baselines);
        assert_eq!(f.summary.as_deref(), Some("s.md"));
        assert!(parse_flags(&s(&["--summary"])).is_err());
    }

    #[test]
    fn parse_precision_and_simd_flags() {
        let f = parse_flags(&s(&[])).unwrap();
        assert_eq!(f.precision, Precision::F32);
        assert!(!f.assert_simd);
        let f = parse_flags(&s(&["--precision", "int8", "--assert-simd"])).unwrap();
        assert_eq!(f.precision, Precision::Int8);
        assert!(f.assert_simd);
        assert_eq!(parse_flags(&s(&["--precision", "f16"])).unwrap().precision, Precision::F16);
        // unknown modes and a missing value are rejected at parse time
        assert!(parse_flags(&s(&["--precision", "bf16"])).is_err());
        assert!(parse_flags(&s(&["--precision"])).is_err());
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(parse_flags(&s(&["--bogus"])).is_err());
    }
}
