//! Continuous telemetry: a fixed-capacity ring of periodic metric
//! samples, each describing **one sampler window** (default 1 s).
//!
//! PR 8 made any single moment observable as a cumulative
//! `MetricsSnapshot`; this module adds the time axis. A server-owned
//! sampler thread captures [`CumulativeStats`] every window and
//! [`SamplerState::sample`] turns consecutive captures into a
//! [`SeriesSample`]: counter **deltas** (admitted/completed/shed/error
//! counts in the window, exposed as rates), point-in-time gauges
//! (outstanding, queue-wait EWMA), and the window's **exact latency
//! histogram delta** — because `obs::hist` buckets have fixed
//! boundaries, `counts_now − counts_prev` is itself an exact histogram
//! of just the window's samples, so per-window percentiles carry no
//! approximation beyond bucket resolution (and none vs. a histogram
//! recorded fresh in the window).
//!
//! Samples are **mergeable**: [`SeriesSample::merge_all`] folds any
//! contiguous run of windows into one wider window, summing counts and
//! histogram deltas, so merged percentiles are exactly the percentiles
//! of the concatenated windows. They are **queryable by window** via
//! [`SeriesRing::last`] / [`SeriesRing::merged`].
//!
//! The series exports as JSON (`render_series_json`) with a strict
//! self-parser ([`parse_series_json`]) in the mold of
//! `obs::trace::parse_chrome_trace`: the flight recorder writes this
//! document into every bundle, and validation round-trips it.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::hist::{Histogram, BUCKETS};

/// Default sampler interval in milliseconds.
pub const DEFAULT_INTERVAL_MS: u64 = 1000;

/// Default ring capacity in samples (10 min of history at 1 s).
pub const DEFAULT_CAPACITY: usize = 600;

/// Cumulative counters captured at one instant — the sampler's input.
/// Produced by `ServingMetrics::cumulative`; consecutive captures are
/// differenced into a [`SeriesSample`].
#[derive(Clone, Debug, Default)]
pub struct CumulativeStats {
    /// Requests admitted since the metrics window started.
    pub admitted: u64,
    /// Sheds per reason, in wire-code order
    /// (queue_full, overloaded, client_limit, expired).
    pub shed: [u64; 4],
    /// Request errors.
    pub errors: u64,
    /// End-to-end latency histogram (count() = completed requests).
    pub latency: Histogram,
    /// Per-sequence-bucket latency histograms, sorted by seq_len.
    pub bucket_latency: Vec<(usize, Histogram)>,
    /// Batch queue-wait histogram.
    pub queue_wait: Histogram,
    /// Batch execute-time histogram.
    pub exec: Histogram,
    /// Completed batch jobs per worker.
    pub worker_jobs: Vec<u64>,
    /// Total execute time per worker (ms).
    pub worker_busy_ms: Vec<f64>,
    /// Total kernel-phase GFLOP executed (from `obs::phase`).
    pub phase_gflop: f64,
    /// Pool-wide roofline peak GFLOP/s (sum of each worker's backend
    /// peak; 0 when no backend declared one).
    pub peak_gflops: f64,
}

/// Sparse per-bucket counts of one window's histogram delta: only the
/// occupied `(bucket index, count)` pairs, ascending by index.
pub type SparseHist = Vec<(u32, u64)>;

fn sparse_delta(now: &Histogram, prev: &Histogram) -> SparseHist {
    now.counts()
        .iter()
        .zip(prev.counts())
        .enumerate()
        .filter_map(|(i, (a, b))| {
            let d = a.saturating_sub(*b);
            (d > 0).then_some((i as u32, d))
        })
        .collect()
}

fn expand(sparse: &SparseHist) -> [u64; BUCKETS] {
    let mut counts = [0u64; BUCKETS];
    for &(i, c) in sparse {
        if let Some(slot) = counts.get_mut(i as usize) {
            *slot += c;
        }
    }
    counts
}

fn sparse_count(sparse: &SparseHist) -> u64 {
    sparse.iter().map(|&(_, c)| c).sum()
}

fn sparse_percentile(sparse: &SparseHist, p: f64) -> f64 {
    Histogram::from_counts(expand(sparse)).percentile(p)
}

/// One sequence bucket's share of a window: its exact latency
/// histogram delta.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BucketWindow {
    /// Bucket sequence length.
    pub seq_len: u64,
    /// Sparse latency histogram of requests this bucket completed in
    /// the window.
    pub hist: SparseHist,
}

impl BucketWindow {
    /// Requests this bucket completed in the window.
    pub fn completed(&self) -> u64 {
        sparse_count(&self.hist)
    }

    /// Exact nearest-rank percentile of the bucket's window latencies.
    pub fn percentile(&self, p: f64) -> f64 {
        sparse_percentile(&self.hist, p)
    }
}

/// One sampler window: counter deltas, gauges, and exact histogram
/// deltas. All counts are *this window only*, never cumulative.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SeriesSample {
    /// Server uptime when the window closed (seconds).
    pub at_s: f64,
    /// Window width (seconds since the previous sample).
    pub window_s: f64,
    /// Requests admitted in the window.
    pub admitted: u64,
    /// Requests completed in the window.
    pub completed: u64,
    /// Sheds per reason in the window, wire-code order.
    pub shed: [u64; 4],
    /// Request errors in the window.
    pub errors: u64,
    /// Admitted-but-unanswered requests when the window closed (gauge).
    pub outstanding: u64,
    /// Queue-wait EWMA when the window closed (ms, gauge).
    pub queue_ewma_ms: f64,
    /// Sparse latency histogram of the window's completions.
    pub hist: SparseHist,
    /// Per-sequence-bucket window histograms, sorted by seq_len.
    pub buckets: Vec<BucketWindow>,
    /// Batch completions per worker in the window.
    pub worker_jobs: Vec<u64>,
    /// Per-worker busy fraction of the window (0..=1).
    pub worker_busy: Vec<f64>,
    /// Kernel GFLOP/s achieved over the window.
    pub achieved_gflops: f64,
    /// Pool roofline peak GFLOP/s (gauge).
    pub peak_gflops: f64,
}

impl SeriesSample {
    /// Admitted requests per second over the window.
    pub fn admitted_per_s(&self) -> f64 {
        self.admitted as f64 / self.window_s.max(1e-9)
    }

    /// Completed requests per second over the window.
    pub fn completed_per_s(&self) -> f64 {
        self.completed as f64 / self.window_s.max(1e-9)
    }

    /// Sheds per second over the window (all reasons).
    pub fn shed_per_s(&self) -> f64 {
        self.shed.iter().sum::<u64>() as f64 / self.window_s.max(1e-9)
    }

    /// Exact nearest-rank latency percentile of the window (0.0 when
    /// no request completed).
    pub fn percentile(&self, p: f64) -> f64 {
        sparse_percentile(&self.hist, p)
    }

    /// Fold a run of windows into one wider window. Counter deltas and
    /// histogram deltas are summed (so merged percentiles are exactly
    /// the percentiles of the concatenated windows); gauges
    /// (`outstanding`, `queue_ewma_ms`, `peak_gflops`) take the most
    /// recent sample's value. Returns `None` on an empty slice.
    pub fn merge_all(samples: &[SeriesSample]) -> Option<SeriesSample> {
        let last = samples.last()?;
        let mut out = SeriesSample {
            at_s: last.at_s,
            outstanding: last.outstanding,
            queue_ewma_ms: last.queue_ewma_ms,
            peak_gflops: last.peak_gflops,
            ..SeriesSample::default()
        };
        let mut hist = [0u64; BUCKETS];
        let mut buckets: Vec<(u64, [u64; BUCKETS])> = Vec::new();
        let mut gflop = 0.0;
        for s in samples {
            out.window_s += s.window_s;
            out.admitted += s.admitted;
            out.completed += s.completed;
            for (a, b) in out.shed.iter_mut().zip(s.shed) {
                *a += b;
            }
            out.errors += s.errors;
            for (a, b) in hist.iter_mut().zip(expand(&s.hist)) {
                *a += b;
            }
            for b in &s.buckets {
                let counts = expand(&b.hist);
                match buckets.iter_mut().find(|(seq, _)| *seq == b.seq_len) {
                    Some((_, acc)) => {
                        for (a, c) in acc.iter_mut().zip(counts) {
                            *a += c;
                        }
                    }
                    None => buckets.push((b.seq_len, counts)),
                }
            }
            if s.worker_jobs.len() > out.worker_jobs.len() {
                out.worker_jobs.resize(s.worker_jobs.len(), 0);
                out.worker_busy.resize(s.worker_jobs.len(), 0.0);
            }
            for (a, b) in out.worker_jobs.iter_mut().zip(&s.worker_jobs) {
                *a += b;
            }
            // busy fractions recombine weighted by window width
            for (a, b) in out.worker_busy.iter_mut().zip(&s.worker_busy) {
                *a += b * s.window_s;
            }
            gflop += s.achieved_gflops * s.window_s;
        }
        let w = out.window_s.max(1e-9);
        for b in &mut out.worker_busy {
            *b = (*b / w).clamp(0.0, 1.0);
        }
        out.achieved_gflops = gflop / w;
        out.hist = sparse_delta(&Histogram::from_counts(hist), &Histogram::new());
        buckets.sort_by_key(|&(seq, _)| seq);
        out.buckets = buckets
            .into_iter()
            .map(|(seq_len, counts)| BucketWindow {
                seq_len,
                hist: sparse_delta(&Histogram::from_counts(counts), &Histogram::new()),
            })
            .collect();
        Some(out)
    }
}

/// The delta state machine between consecutive cumulative captures.
/// Pure and clock-free: the caller supplies the uptime stamp, so tests
/// drive windows deterministically.
#[derive(Debug, Default)]
pub struct SamplerState {
    prev: Option<(f64, CumulativeStats, u64)>,
}

impl SamplerState {
    pub fn new() -> Self {
        SamplerState { prev: None }
    }

    /// Close one window: difference `cur` against the previous capture
    /// (an all-zero baseline for the first window) into a
    /// [`SeriesSample`]. `outstanding` and `queue_ewma_ms` are gauges
    /// read at the same instant as `cur`.
    pub fn sample(
        &mut self,
        at_s: f64,
        cur: CumulativeStats,
        outstanding: u64,
        queue_ewma_ms: f64,
    ) -> SeriesSample {
        let (prev_at, prev, _) = self
            .prev
            .take()
            .unwrap_or((0.0, CumulativeStats::default(), 0));
        let window_s = (at_s - prev_at).max(1e-9);
        let empty = Histogram::new();
        let prev_bucket = |seq: usize| -> &Histogram {
            prev.bucket_latency
                .iter()
                .find(|(s, _)| *s == seq)
                .map(|(_, h)| h)
                .unwrap_or(&empty)
        };
        let mut shed = [0u64; 4];
        for (d, (a, b)) in shed.iter_mut().zip(cur.shed.iter().zip(prev.shed)) {
            *d = a.saturating_sub(b);
        }
        let sample = SeriesSample {
            at_s,
            window_s,
            admitted: cur.admitted.saturating_sub(prev.admitted),
            completed: cur.latency.count().saturating_sub(prev.latency.count()),
            shed,
            errors: cur.errors.saturating_sub(prev.errors),
            outstanding,
            queue_ewma_ms,
            hist: sparse_delta(&cur.latency, &prev.latency),
            buckets: cur
                .bucket_latency
                .iter()
                .map(|(seq, h)| BucketWindow {
                    seq_len: *seq as u64,
                    hist: sparse_delta(h, prev_bucket(*seq)),
                })
                .collect(),
            worker_jobs: cur
                .worker_jobs
                .iter()
                .enumerate()
                .map(|(w, &j)| j.saturating_sub(prev.worker_jobs.get(w).copied().unwrap_or(0)))
                .collect(),
            worker_busy: cur
                .worker_busy_ms
                .iter()
                .enumerate()
                .map(|(w, &ms)| {
                    let d = ms - prev.worker_busy_ms.get(w).copied().unwrap_or(0.0);
                    (d / (window_s * 1e3)).clamp(0.0, 1.0)
                })
                .collect(),
            achieved_gflops: ((cur.phase_gflop - prev.phase_gflop) / window_s).max(0.0),
            peak_gflops: cur.peak_gflops,
        };
        self.prev = Some((at_s, cur, 0));
        sample
    }
}

/// Fixed-capacity ring of the most recent [`SeriesSample`]s, shared
/// between the sampler (producer), the watchdog, the Prometheus
/// exposition, and the flight recorder (readers). One short mutex per
/// push/query — never on the request hot path.
#[derive(Debug)]
pub struct SeriesRing {
    cap: usize,
    samples: Mutex<VecDeque<SeriesSample>>,
    pushed: AtomicU64,
}

impl SeriesRing {
    /// An empty ring retaining at most `capacity` samples (min 2, so a
    /// window delta always has a predecessor to merge against).
    pub fn new(capacity: usize) -> Self {
        SeriesRing {
            cap: capacity.max(2),
            samples: Mutex::new(VecDeque::new()),
            pushed: AtomicU64::new(0),
        }
    }

    /// Retention capacity in samples.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Samples currently retained.
    pub fn len(&self) -> usize {
        self.samples.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total samples ever pushed (including evicted ones).
    pub fn total_pushed(&self) -> u64 {
        self.pushed.load(Ordering::Acquire)
    }

    /// Append one window, evicting the oldest at capacity.
    pub fn push(&self, sample: SeriesSample) {
        let mut q = self.samples.lock().unwrap();
        if q.len() == self.cap {
            q.pop_front();
        }
        q.push_back(sample);
        self.pushed.fetch_add(1, Ordering::AcqRel);
    }

    /// The `k` most recent windows, oldest first (fewer when the ring
    /// holds fewer).
    pub fn last(&self, k: usize) -> Vec<SeriesSample> {
        let q = self.samples.lock().unwrap();
        q.iter().skip(q.len().saturating_sub(k)).cloned().collect()
    }

    /// The `k` most recent windows merged into one
    /// ([`SeriesSample::merge_all`]); `None` while empty.
    pub fn merged(&self, k: usize) -> Option<SeriesSample> {
        SeriesSample::merge_all(&self.last(k))
    }
}

// ---------------------------------------------------------------------------
// JSON export + strict self-parser (flight-recorder bundle format)
// ---------------------------------------------------------------------------

fn push_f64(out: &mut String, v: f64) {
    if v.is_finite() {
        out.push_str(&format!("{v}"));
    } else {
        out.push('0');
    }
}

fn push_sparse(out: &mut String, h: &SparseHist) {
    out.push('[');
    for (i, (b, c)) in h.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("[{b},{c}]"));
    }
    out.push(']');
}

/// Render a run of samples as the series JSON document the flight
/// recorder dumps. Key order is fixed; [`parse_series_json`] requires
/// exactly this shape.
pub fn render_series_json(samples: &[SeriesSample]) -> String {
    let mut out = String::with_capacity(64 + samples.len() * 256);
    out.push_str("{\"schema\":1,\"samples\":[");
    for (i, s) in samples.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str("{\"at_s\":");
        push_f64(&mut out, s.at_s);
        out.push_str(",\"window_s\":");
        push_f64(&mut out, s.window_s);
        out.push_str(&format!(",\"admitted\":{}", s.admitted));
        out.push_str(&format!(",\"completed\":{}", s.completed));
        out.push_str(&format!(
            ",\"shed\":[{},{},{},{}]",
            s.shed[0], s.shed[1], s.shed[2], s.shed[3]
        ));
        out.push_str(&format!(",\"errors\":{}", s.errors));
        out.push_str(&format!(",\"outstanding\":{}", s.outstanding));
        out.push_str(",\"queue_ewma_ms\":");
        push_f64(&mut out, s.queue_ewma_ms);
        out.push_str(",\"hist\":");
        push_sparse(&mut out, &s.hist);
        out.push_str(",\"buckets\":[");
        for (j, b) in s.buckets.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            out.push_str(&format!("{{\"seq_len\":{},\"hist\":", b.seq_len));
            push_sparse(&mut out, &b.hist);
            out.push('}');
        }
        out.push_str("],\"worker_jobs\":[");
        for (j, v) in s.worker_jobs.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            out.push_str(&v.to_string());
        }
        out.push_str("],\"worker_busy\":[");
        for (j, v) in s.worker_busy.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            push_f64(&mut out, *v);
        }
        out.push_str("],\"achieved_gflops\":");
        push_f64(&mut out, s.achieved_gflops);
        out.push_str(",\"peak_gflops\":");
        push_f64(&mut out, s.peak_gflops);
        out.push('}');
    }
    out.push_str("]}");
    out
}

/// Strict parser for [`render_series_json`] documents: the exact key
/// order and set, finite numbers, non-negative integer counts, no
/// trailing input. Like `parse_chrome_trace`, this is the validation
/// path for flight-recorder bundles — leniency would hide export bugs.
pub fn parse_series_json(src: &str) -> Result<Vec<SeriesSample>, String> {
    let mut p = Scan { bytes: src.as_bytes(), pos: 0 };
    p.lit("{\"schema\":1,\"samples\":[")?;
    let mut samples = Vec::new();
    if p.peek() == Some(b']') {
        p.pos += 1;
    } else {
        loop {
            samples.push(parse_sample(&mut p)?);
            match p.next()? {
                b',' => continue,
                b']' => break,
                _ => return p.err("expected ',' or ']' after sample"),
            }
        }
    }
    p.lit("}")?;
    if p.pos != p.bytes.len() {
        return p.err("trailing input after document");
    }
    Ok(samples)
}

struct Scan<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Scan<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, String> {
        Err(format!("series JSON invalid at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn next(&mut self) -> Result<u8, String> {
        let b = self.peek().ok_or("series JSON invalid: unexpected end of input")?;
        self.pos += 1;
        Ok(b)
    }

    fn lit(&mut self, s: &str) -> Result<(), String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(())
        } else {
            self.err(&format!("expected {s:?}"))
        }
    }

    fn f64(&mut self) -> Result<f64, String> {
        let start = self.pos;
        while matches!(self.peek(), Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')) {
            self.pos += 1;
        }
        if start == self.pos {
            return self.err("expected number");
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .filter(|v| v.is_finite())
            .ok_or_else(|| format!("series JSON invalid at byte {start}: bad number"))
    }

    fn u64(&mut self) -> Result<u64, String> {
        let v = self.f64()?;
        if v < 0.0 || v.fract() != 0.0 || v > 2f64.powi(53) {
            return self.err("expected a non-negative integer");
        }
        Ok(v as u64)
    }

    fn sparse(&mut self) -> Result<SparseHist, String> {
        self.lit("[")?;
        let mut out = Vec::new();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(out);
        }
        loop {
            self.lit("[")?;
            let i = self.u64()?;
            if i >= BUCKETS as u64 {
                return self.err("histogram bucket index out of range");
            }
            self.lit(",")?;
            let c = self.u64()?;
            self.lit("]")?;
            if let Some(&(last, _)) = out.last() {
                if i as u32 <= last {
                    return self.err("histogram bucket indices must ascend");
                }
            }
            out.push((i as u32, c));
            match self.next()? {
                b',' => continue,
                b']' => return Ok(out),
                _ => return self.err("expected ',' or ']' in histogram"),
            }
        }
    }
}

fn parse_sample(p: &mut Scan<'_>) -> Result<SeriesSample, String> {
    let mut s = SeriesSample::default();
    p.lit("{\"at_s\":")?;
    s.at_s = p.f64()?;
    p.lit(",\"window_s\":")?;
    s.window_s = p.f64()?;
    p.lit(",\"admitted\":")?;
    s.admitted = p.u64()?;
    p.lit(",\"completed\":")?;
    s.completed = p.u64()?;
    p.lit(",\"shed\":[")?;
    for (i, slot) in s.shed.iter_mut().enumerate() {
        if i > 0 {
            p.lit(",")?;
        }
        *slot = p.u64()?;
    }
    p.lit("],\"errors\":")?;
    s.errors = p.u64()?;
    p.lit(",\"outstanding\":")?;
    s.outstanding = p.u64()?;
    p.lit(",\"queue_ewma_ms\":")?;
    s.queue_ewma_ms = p.f64()?;
    p.lit(",\"hist\":")?;
    s.hist = p.sparse()?;
    p.lit(",\"buckets\":[")?;
    if p.peek() == Some(b'}') {
        return p.err("unterminated buckets array");
    }
    if p.peek() == Some(b']') {
        p.pos += 1;
    } else {
        loop {
            p.lit("{\"seq_len\":")?;
            let seq_len = p.u64()?;
            p.lit(",\"hist\":")?;
            let hist = p.sparse()?;
            p.lit("}")?;
            s.buckets.push(BucketWindow { seq_len, hist });
            match p.next()? {
                b',' => continue,
                b']' => break,
                _ => return p.err("expected ',' or ']' in buckets"),
            }
        }
    }
    p.lit(",\"worker_jobs\":[")?;
    if p.peek() == Some(b']') {
        p.pos += 1;
    } else {
        loop {
            s.worker_jobs.push(p.u64()?);
            match p.next()? {
                b',' => continue,
                b']' => break,
                _ => return p.err("expected ',' or ']' in worker_jobs"),
            }
        }
    }
    p.lit(",\"worker_busy\":[")?;
    if p.peek() == Some(b']') {
        p.pos += 1;
    } else {
        loop {
            s.worker_busy.push(p.f64()?);
            match p.next()? {
                b',' => continue,
                b']' => break,
                _ => return p.err("expected ',' or ']' in worker_busy"),
            }
        }
    }
    p.lit(",\"achieved_gflops\":")?;
    s.achieved_gflops = p.f64()?;
    p.lit(",\"peak_gflops\":")?;
    s.peak_gflops = p.f64()?;
    p.lit("}")?;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cum(admitted: u64, lat: &[f64], jobs: &[u64]) -> CumulativeStats {
        let mut latency = Histogram::new();
        let mut b512 = Histogram::new();
        for &v in lat {
            latency.record(v);
            b512.record(v);
        }
        CumulativeStats {
            admitted,
            latency,
            bucket_latency: vec![(512, b512)],
            worker_jobs: jobs.to_vec(),
            worker_busy_ms: jobs.iter().map(|&j| j as f64 * 10.0).collect(),
            phase_gflop: admitted as f64 * 2.0,
            peak_gflops: 100.0,
            ..CumulativeStats::default()
        }
    }

    #[test]
    fn window_deltas_are_exact() {
        let mut st = SamplerState::new();
        let first = st.sample(1.0, cum(10, &[5.0, 7.0], &[2]), 3, 4.0);
        assert_eq!(first.admitted, 10);
        assert_eq!(first.completed, 2);
        assert_eq!(first.outstanding, 3);
        assert!((first.window_s - 1.0).abs() < 1e-9);

        // second window adds 5 admissions, 3 completions at ~20ms
        let second =
            st.sample(2.0, cum(15, &[5.0, 7.0, 20.0, 20.0, 21.0], &[2, 4]), 1, 6.0);
        assert_eq!(second.admitted, 5);
        assert_eq!(second.completed, 3);
        assert_eq!(second.worker_jobs, vec![0, 4], "new worker slots appear as deltas");
        // the window percentile reflects only the window's samples
        let mut oracle = Histogram::new();
        for v in [20.0, 20.0, 21.0] {
            oracle.record(v);
        }
        assert_eq!(second.percentile(99.0), oracle.percentile(99.0));
        assert_eq!(second.buckets.len(), 1);
        assert_eq!(second.buckets[0].completed(), 3);
        // achieved GFLOP/s = ΔGFLOP / window
        assert!((second.achieved_gflops - 10.0).abs() < 1e-9);
    }

    #[test]
    fn merge_is_exact_over_windows() {
        let mut st = SamplerState::new();
        let a = st.sample(1.0, cum(4, &[1.0, 2.0], &[1]), 2, 1.0);
        let b = st.sample(3.0, cum(9, &[1.0, 2.0, 50.0, 60.0, 70.0], &[3]), 0, 2.0);
        let m = SeriesSample::merge_all(&[a, b]).unwrap();
        assert_eq!(m.admitted, 9);
        assert_eq!(m.completed, 5);
        assert!((m.window_s - 3.0).abs() < 1e-9);
        assert_eq!(m.outstanding, 0, "gauges take the latest sample");
        let mut oracle = Histogram::new();
        for v in [1.0, 2.0, 50.0, 60.0, 70.0] {
            oracle.record(v);
        }
        for p in [50.0, 95.0, 99.0] {
            assert_eq!(m.percentile(p), oracle.percentile(p), "merged p{p} must be exact");
        }
        assert_eq!(m.worker_jobs, vec![3]);
        assert!(SeriesSample::merge_all(&[]).is_none());
    }

    #[test]
    fn ring_retains_most_recent_and_counts_evictions() {
        let ring = SeriesRing::new(3);
        assert!(ring.is_empty());
        for i in 0..5 {
            ring.push(SeriesSample {
                at_s: i as f64,
                window_s: 1.0,
                ..SeriesSample::default()
            });
        }
        assert_eq!(ring.len(), 3);
        assert_eq!(ring.total_pushed(), 5);
        let last2 = ring.last(2);
        assert_eq!(last2.len(), 2);
        assert_eq!(last2[0].at_s, 3.0, "oldest first");
        assert_eq!(last2[1].at_s, 4.0);
        assert!(ring.merged(10).is_some());
    }

    #[test]
    fn series_json_round_trips_and_parser_is_strict() {
        let mut st = SamplerState::new();
        let a = st.sample(1.0, cum(4, &[1.0, 2.0], &[1, 0]), 2, 1.5);
        let b = st.sample(2.5, cum(9, &[1.0, 2.0, 50.0], &[2, 1]), 0, 2.25);
        let samples = vec![a, b];
        let json = render_series_json(&samples);
        let parsed = parse_series_json(&json).unwrap();
        assert_eq!(parsed, samples);
        // re-render is byte-identical
        assert_eq!(render_series_json(&parsed), json);
        // empty documents round-trip
        assert_eq!(parse_series_json(&render_series_json(&[])).unwrap(), vec![]);

        // strictness
        assert!(parse_series_json(&format!("{json} ")).is_err(), "trailing bytes rejected");
        assert!(parse_series_json(&json.replace("\"admitted\"", "\"admited\"")).is_err());
        assert!(parse_series_json(&json.replace("{\"schema\":1", "{\"schema\":2")).is_err());
        assert!(parse_series_json("").is_err());
        assert!(parse_series_json("{}").is_err());
        // negative counts rejected
        let neg = json.replacen("\"completed\":2", "\"completed\":-2", 1);
        assert!(parse_series_json(&neg).is_err());
    }
}
