//! Prometheus text-format exposition (format 0.0.4) of the serving
//! metrics, with a **strict self-parser gating every export** — the
//! same discipline as `obs::trace`: `render_validated` re-parses the
//! document it just rendered and refuses to serve anything that does
//! not round-trip, so an exposition bug fails a scrape loudly instead
//! of feeding a dashboard garbage.
//!
//! Sources folded into one scrape:
//!
//! * [`CumulativeStats`] — counters and the exact log-bucket
//!   histograms. Histogram `_bucket` series use the fixed
//!   `obs::hist` boundaries as `le` upper edges (last = `+Inf`), and
//!   each cumulative `_bucket` count is the exact prefix sum of
//!   [`crate::obs::hist::Histogram::counts`]: no re-bucketing, no
//!   approximation.
//! * [`ExportMeta`] — point-in-time gauges the server reads at scrape
//!   time (uptime, outstanding, queue EWMA, per-backend roofline) plus
//!   the self-describing identity (sampler interval, serving
//!   `ModelConfig` fingerprint).
//! * the most recent [`SeriesSample`] — last-window rates and exact
//!   window percentiles as `bigbird_window_*` gauges.
//! * [`HealthReport`] — `bigbird_healthy` and per-detector alert
//!   totals, mirroring `/healthz`.
//!
//! Every metric is prefixed `bigbird_`; see the README "Observability"
//! section for the full name/type table.

use std::fmt::Write as _;

use super::hist::{Histogram, BUCKETS};
use super::timeseries::{CumulativeStats, SeriesSample};
use super::watchdog::{HealthReport, DETECTORS};

/// Scrape-time gauges and identity that live outside the cumulative
/// counters. Assembled by the server at each scrape.
#[derive(Clone, Debug, Default)]
pub struct ExportMeta {
    /// Seconds since the metrics window started.
    pub uptime_s: f64,
    /// Sampler interval in seconds (0 when the sampler is off).
    pub sampler_interval_s: f64,
    /// Serving `ModelConfig` fingerprint (dotted integers).
    pub fingerprint: String,
    /// Admitted-but-unanswered requests right now.
    pub outstanding: u64,
    /// Queue-wait EWMA gauge (ms).
    pub queue_ewma_ms: f64,
    /// Batches formed so far.
    pub batches: u64,
    /// Per-backend `(label, achieved GFLOP/s, peak GFLOP/s)` roofline
    /// rows, sorted by label.
    pub backend_roofline: Vec<(String, f64, f64)>,
    /// Time-series samples taken so far (including evicted ones).
    pub samples_total: u64,
}

/// Render the exposition **and** gate it through [`parse_prometheus`];
/// the text is only returned if it round-trips the strict parser and
/// every histogram invariant holds. This is what `/metrics` and wire
/// frame 7 serve.
pub fn render_validated(
    cum: &CumulativeStats,
    meta: &ExportMeta,
    last: Option<&SeriesSample>,
    health: &HealthReport,
) -> Result<String, String> {
    let text = render_prometheus(cum, meta, last, health);
    parse_prometheus(&text).map_err(|e| format!("exposition failed self-validation: {e}"))?;
    Ok(text)
}

/// Render the Prometheus text document (unvalidated; prefer
/// [`render_validated`]).
pub fn render_prometheus(
    cum: &CumulativeStats,
    meta: &ExportMeta,
    last: Option<&SeriesSample>,
    health: &HealthReport,
) -> String {
    let mut w = Writer { out: String::with_capacity(16 * 1024) };

    w.family("bigbird_uptime_seconds", "gauge", "Seconds since the metrics window started.");
    w.sample("bigbird_uptime_seconds", &[], meta.uptime_s);
    w.family("bigbird_sampler_interval_seconds", "gauge", "Telemetry sampler interval (0 = off).");
    w.sample("bigbird_sampler_interval_seconds", &[], meta.sampler_interval_s);
    w.family("bigbird_model_info", "gauge", "Serving model identity (value is always 1).");
    w.sample("bigbird_model_info", &[("fingerprint", meta.fingerprint.as_str())], 1.0);

    w.family("bigbird_requests_admitted_total", "counter", "Requests that passed admission.");
    w.sample("bigbird_requests_admitted_total", &[], cum.admitted as f64);
    w.family("bigbird_requests_completed_total", "counter", "Requests answered with predictions.");
    w.sample("bigbird_requests_completed_total", &[], cum.latency.count() as f64);
    w.family("bigbird_requests_shed_total", "counter", "Requests shed, by typed reason.");
    let shed_reasons = ["queue_full", "overloaded", "client_limit", "expired"];
    for (i, reason) in shed_reasons.into_iter().enumerate() {
        w.sample("bigbird_requests_shed_total", &[("reason", reason)], cum.shed[i] as f64);
    }
    w.family("bigbird_errors_total", "counter", "Requests that failed with an error.");
    w.sample("bigbird_errors_total", &[], cum.errors as f64);
    w.family("bigbird_batches_total", "counter", "Batches formed by the router.");
    w.sample("bigbird_batches_total", &[], meta.batches as f64);

    w.family("bigbird_outstanding_requests", "gauge", "Admitted-but-unanswered requests.");
    w.sample("bigbird_outstanding_requests", &[], meta.outstanding as f64);
    w.family("bigbird_queue_wait_ewma_ms", "gauge", "Admission queue-wait EWMA.");
    w.sample("bigbird_queue_wait_ewma_ms", &[], meta.queue_ewma_ms);

    w.histogram("bigbird_request_latency_ms", "End-to-end request latency.", &[], &cum.latency);
    if !cum.bucket_latency.is_empty() {
        w.family("bigbird_bucket_latency_ms", "histogram", "Request latency per sequence bucket.");
        for (seq, h) in &cum.bucket_latency {
            let seq = seq.to_string();
            w.histogram_samples("bigbird_bucket_latency_ms", &[("bucket", seq.as_str())], h);
        }
    }
    w.histogram("bigbird_batch_queue_wait_ms", "Batch wait in queues.", &[], &cum.queue_wait);
    w.histogram("bigbird_batch_exec_ms", "Batch execution time on workers.", &[], &cum.exec);

    if !cum.worker_jobs.is_empty() {
        w.family("bigbird_worker_jobs_total", "counter", "Completed batch jobs per worker.");
        for (i, &j) in cum.worker_jobs.iter().enumerate() {
            let worker = i.to_string();
            w.sample("bigbird_worker_jobs_total", &[("worker", worker.as_str())], j as f64);
        }
        w.family("bigbird_worker_busy_ms_total", "counter", "Execute time per worker.");
        for (i, &ms) in cum.worker_busy_ms.iter().enumerate() {
            let worker = i.to_string();
            w.sample("bigbird_worker_busy_ms_total", &[("worker", worker.as_str())], ms.max(0.0));
        }
    }
    if !meta.backend_roofline.is_empty() {
        w.family("bigbird_backend_achieved_gflops", "gauge", "Achieved GFLOP/s per backend.");
        for (label, achieved, _) in &meta.backend_roofline {
            w.sample("bigbird_backend_achieved_gflops", &[("backend", label.as_str())], *achieved);
        }
        w.family("bigbird_backend_peak_gflops", "gauge", "Roofline peak GFLOP/s per backend.");
        for (label, _, peak) in &meta.backend_roofline {
            w.sample("bigbird_backend_peak_gflops", &[("backend", label.as_str())], *peak);
        }
    }

    w.family("bigbird_samples_total", "counter", "Telemetry windows sampled.");
    w.sample("bigbird_samples_total", &[], meta.samples_total as f64);
    if let Some(s) = last {
        w.family("bigbird_window_seconds", "gauge", "Width of the most recent sampler window.");
        w.sample("bigbird_window_seconds", &[], s.window_s);
        w.family("bigbird_window_admitted_per_s", "gauge", "Admission rate over the last window.");
        w.sample("bigbird_window_admitted_per_s", &[], s.admitted_per_s());
        w.family("bigbird_window_completed_per_s", "gauge", "Completion rate, last window.");
        w.sample("bigbird_window_completed_per_s", &[], s.completed_per_s());
        w.family("bigbird_window_shed_per_s", "gauge", "Shed rate over the last window.");
        w.sample("bigbird_window_shed_per_s", &[], s.shed_per_s());
        w.family(
            "bigbird_window_latency_quantile_ms",
            "gauge",
            "Exact latency quantiles of the last window.",
        );
        for (q, p) in [("p50", 50.0), ("p95", 95.0), ("p99", 99.0)] {
            w.sample("bigbird_window_latency_quantile_ms", &[("q", q)], s.percentile(p));
        }
        if !s.buckets.is_empty() {
            w.family(
                "bigbird_window_bucket_quantile_ms",
                "gauge",
                "Exact last-window latency quantiles per sequence bucket.",
            );
            for b in &s.buckets {
                let seq = b.seq_len.to_string();
                for (q, p) in [("p50", 50.0), ("p95", 95.0), ("p99", 99.0)] {
                    w.sample(
                        "bigbird_window_bucket_quantile_ms",
                        &[("bucket", seq.as_str()), ("q", q)],
                        b.percentile(p),
                    );
                }
            }
        }
    }

    w.family("bigbird_healthy", "gauge", "1 while no watchdog detector is active, else 0.");
    w.sample("bigbird_healthy", &[], if health.healthy { 1.0 } else { 0.0 });
    w.family("bigbird_health_info", "gauge", "Watchdog diagnosis (value is always 1).");
    w.sample("bigbird_health_info", &[("reason", health.reason.as_str())], 1.0);
    w.family("bigbird_alerts_total", "counter", "Detector-active windows, by detector.");
    for (i, d) in DETECTORS.iter().enumerate() {
        w.sample(
            "bigbird_alerts_total",
            &[("detector", d.as_str())],
            health.alerts_by_detector[i] as f64,
        );
    }
    w.out
}

struct Writer {
    out: String,
}

impl Writer {
    fn family(&mut self, name: &str, kind: &str, help: &str) {
        let _ = writeln!(self.out, "# HELP {name} {help}");
        let _ = writeln!(self.out, "# TYPE {name} {kind}");
    }

    fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(k);
                self.out.push_str("=\"");
                for ch in v.chars() {
                    match ch {
                        '\\' => self.out.push_str("\\\\"),
                        '"' => self.out.push_str("\\\""),
                        '\n' => self.out.push_str("\\n"),
                        c => self.out.push(c),
                    }
                }
                self.out.push('"');
            }
            self.out.push('}');
        }
        self.out.push(' ');
        if value.is_finite() {
            let _ = write!(self.out, "{value}");
        } else {
            self.out.push('0');
        }
        self.out.push('\n');
    }

    fn histogram(&mut self, name: &str, help: &str, labels: &[(&str, &str)], h: &Histogram) {
        self.family(name, "histogram", help);
        self.histogram_samples(name, labels, h);
    }

    /// `_bucket`/`_sum`/`_count` series for one histogram: `le` edges
    /// are the fixed `obs::hist` upper bounds, cumulative counts are
    /// exact prefix sums of [`Histogram::counts`].
    fn histogram_samples(&mut self, name: &str, labels: &[(&str, &str)], h: &Histogram) {
        let bucket = format!("{name}_bucket");
        let mut cumulative = 0u64;
        for (i, &c) in h.counts().iter().enumerate() {
            cumulative += c;
            let (_, hi) = Histogram::bucket_bounds(i);
            let le = if hi.is_finite() { format!("{hi}") } else { "+Inf".to_string() };
            let mut ls: Vec<(&str, &str)> = labels.to_vec();
            ls.push(("le", le.as_str()));
            self.sample(&bucket, &ls, cumulative as f64);
        }
        self.sample(&format!("{name}_sum"), labels, h.sum());
        self.sample(&format!("{name}_count"), labels, h.count() as f64);
    }
}

// ---------------------------------------------------------------------------
// Strict parser
// ---------------------------------------------------------------------------

/// Metric kinds the exposition uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

/// One sample line, post-parse. For histograms the `name` keeps its
/// `_bucket`/`_sum`/`_count` suffix.
#[derive(Clone, Debug, PartialEq)]
pub struct PromSample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

/// One metric family: `# HELP` + `# TYPE` + its samples.
#[derive(Clone, Debug, PartialEq)]
pub struct PromFamily {
    pub name: String,
    pub kind: MetricKind,
    pub help: String,
    pub samples: Vec<PromSample>,
}

/// A parsed exposition document.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PromDoc {
    pub families: Vec<PromFamily>,
}

impl PromDoc {
    /// The family declared as `name`, if present.
    pub fn family(&self, name: &str) -> Option<&PromFamily> {
        self.families.iter().find(|f| f.name == name)
    }

    /// Value of the sample with exactly this name (histogram
    /// `_bucket`/`_sum`/`_count` sample names included) and exactly
    /// this label set, across all families.
    pub fn value(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        self.families
            .iter()
            .flat_map(|f| &f.samples)
            .find(|s| {
                s.name == name
                    && s.labels.len() == labels.len()
                    && labels
                        .iter()
                        .all(|(k, v)| s.labels.iter().any(|(sk, sv)| sk == k && sv == v))
            })
            .map(|s| s.value)
    }

    /// All samples of a family, in document order.
    pub fn samples(&self, family: &str) -> &[PromSample] {
        self.family(family).map(|f| f.samples.as_slice()).unwrap_or(&[])
    }
}

fn valid_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Strictly parse a text exposition produced by [`render_prometheus`]:
/// every family must declare `# HELP` then `# TYPE` before its samples,
/// sample names must belong to the declared family (histograms: the
/// `_bucket`/`_sum`/`_count` triplet), values must be finite (counters
/// additionally non-negative), and histogram invariants must hold —
/// `le` edges strictly ascending and ending at `+Inf`, cumulative
/// bucket counts non-decreasing, the `+Inf` bucket equal to `_count`.
/// Unknown comment forms, blank lines, duplicate families, and
/// trailing garbage are all errors.
pub fn parse_prometheus(text: &str) -> Result<PromDoc, String> {
    let mut doc = PromDoc::default();
    let mut pending_help: Option<(String, String)> = None;
    for (ln, line) in text.lines().enumerate() {
        let err = |msg: &str| Err(format!("exposition line {}: {msg}", ln + 1));
        if line.is_empty() {
            return err("blank line");
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let (name, help) = rest
                .split_once(' ')
                .ok_or(format!("exposition line {}: HELP without text", ln + 1))?;
            if !valid_name(name) {
                return err("invalid metric name in HELP");
            }
            if pending_help.is_some() {
                return err("HELP without a following TYPE");
            }
            if doc.family(name).is_some() {
                return err("duplicate family");
            }
            pending_help = Some((name.to_string(), help.to_string()));
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, kind) = rest
                .split_once(' ')
                .ok_or(format!("exposition line {}: TYPE without kind", ln + 1))?;
            let Some((help_name, help)) = pending_help.take() else {
                return err("TYPE without a preceding HELP");
            };
            if help_name != name {
                return err("TYPE name does not match its HELP");
            }
            let kind = match kind {
                "counter" => MetricKind::Counter,
                "gauge" => MetricKind::Gauge,
                "histogram" => MetricKind::Histogram,
                _ => return err("unsupported metric kind"),
            };
            doc.families.push(PromFamily {
                name: name.to_string(),
                kind,
                help,
                samples: Vec::new(),
            });
            continue;
        }
        if line.starts_with('#') {
            return err("unknown comment form");
        }
        if pending_help.is_some() {
            return err("sample between HELP and TYPE");
        }
        let sample =
            parse_sample_line(line).map_err(|m| format!("exposition line {}: {m}", ln + 1))?;
        let family = doc
            .families
            .last_mut()
            .ok_or(format!("exposition line {}: sample before any TYPE", ln + 1))?;
        let base_ok = match family.kind {
            MetricKind::Histogram => {
                let n = &sample.name;
                n == &format!("{}_bucket", family.name)
                    || n == &format!("{}_sum", family.name)
                    || n == &format!("{}_count", family.name)
            }
            _ => sample.name == family.name,
        };
        if !base_ok {
            return err("sample name does not belong to the current family");
        }
        if family.kind == MetricKind::Counter && sample.value < 0.0 {
            return err("negative counter");
        }
        family.samples.push(sample);
    }
    if pending_help.is_some() {
        return Err("exposition ends with HELP but no TYPE".to_string());
    }
    if !text.is_empty() && !text.ends_with('\n') {
        return Err("exposition must end with a newline".to_string());
    }
    for f in &doc.families {
        if f.samples.is_empty() {
            return Err(format!("family {} declares no samples", f.name));
        }
        if f.kind == MetricKind::Histogram {
            validate_histogram(f)?;
        }
    }
    Ok(doc)
}

fn parse_sample_line(line: &str) -> Result<PromSample, String> {
    let bytes = line.as_bytes();
    let mut pos = 0;
    while pos < bytes.len() && bytes[pos] != b'{' && bytes[pos] != b' ' {
        pos += 1;
    }
    let name = &line[..pos];
    if !valid_name(name) {
        return Err(format!("invalid sample name {name:?}"));
    }
    let mut labels = Vec::new();
    if pos < bytes.len() && bytes[pos] == b'{' {
        pos += 1;
        loop {
            let key_start = pos;
            while pos < bytes.len() && bytes[pos] != b'=' {
                pos += 1;
            }
            let key = &line[key_start..pos];
            if !valid_name(key) {
                return Err(format!("invalid label name {key:?}"));
            }
            pos += 1; // '='
            if bytes.get(pos) != Some(&b'"') {
                return Err("label value must be quoted".to_string());
            }
            pos += 1;
            let mut value = String::new();
            loop {
                match bytes.get(pos) {
                    None => return Err("unterminated label value".to_string()),
                    Some(b'"') => {
                        pos += 1;
                        break;
                    }
                    Some(b'\\') => {
                        pos += 1;
                        match bytes.get(pos) {
                            Some(b'\\') => value.push('\\'),
                            Some(b'"') => value.push('"'),
                            Some(b'n') => value.push('\n'),
                            _ => return Err("unsupported label escape".to_string()),
                        }
                        pos += 1;
                    }
                    Some(_) => {
                        let ch = line[pos..].chars().next().unwrap();
                        value.push(ch);
                        pos += ch.len_utf8();
                    }
                }
            }
            labels.push((key.to_string(), value));
            match bytes.get(pos) {
                Some(b',') => {
                    pos += 1;
                    continue;
                }
                Some(b'}') => {
                    pos += 1;
                    break;
                }
                _ => return Err("expected ',' or '}' in labels".to_string()),
            }
        }
    }
    if bytes.get(pos) != Some(&b' ') {
        return Err("expected a space before the value".to_string());
    }
    let value_str = &line[pos + 1..];
    let value = value_str
        .parse::<f64>()
        .ok()
        .filter(|v| v.is_finite())
        .ok_or_else(|| format!("bad sample value {value_str:?}"))?;
    Ok(PromSample { name: name.to_string(), labels, value })
}

fn validate_histogram(f: &PromFamily) -> Result<(), String> {
    use std::collections::BTreeMap;
    let bucket_name = format!("{}_bucket", f.name);
    // group by the non-`le` label set
    let mut groups: BTreeMap<String, (Vec<(f64, f64)>, Option<f64>, Option<f64>)> = BTreeMap::new();
    let group_key = |labels: &[(String, String)]| {
        let mut ls: Vec<String> = labels
            .iter()
            .filter(|(k, _)| k != "le")
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        ls.sort();
        ls.join(",")
    };
    for s in &f.samples {
        let entry = groups.entry(group_key(&s.labels)).or_default();
        if s.name == bucket_name {
            let le = s
                .labels
                .iter()
                .find(|(k, _)| k == "le")
                .ok_or(format!("{}: _bucket without le label", f.name))?;
            let edge = if le.1 == "+Inf" {
                f64::INFINITY
            } else {
                le.1.parse::<f64>().map_err(|_| format!("{}: bad le {:?}", f.name, le.1))?
            };
            entry.0.push((edge, s.value));
        } else if s.name.ends_with("_sum") {
            entry.1 = Some(s.value);
        } else {
            entry.2 = Some(s.value);
        }
    }
    for (key, (buckets, sum, count)) in groups {
        let at = |m: &str| format!("{}{{{key}}}: {m}", f.name);
        if buckets.is_empty() {
            return Err(at("no _bucket series"));
        }
        for w in buckets.windows(2) {
            if w[1].0 <= w[0].0 {
                return Err(at("le edges must ascend strictly"));
            }
            if w[1].1 < w[0].1 {
                return Err(at("cumulative bucket counts must be non-decreasing"));
            }
        }
        let (last_le, last_count) = *buckets.last().unwrap();
        if !last_le.is_infinite() {
            return Err(at("last bucket must be le=\"+Inf\""));
        }
        let count = count.ok_or_else(|| at("missing _count"))?;
        if sum.is_none() {
            return Err(at("missing _sum"));
        }
        if (last_count - count).abs() > 1e-9 {
            return Err(at("+Inf bucket must equal _count"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::watchdog::Health;

    fn fixture() -> (CumulativeStats, ExportMeta, SeriesSample, HealthReport) {
        let mut latency = Histogram::new();
        let mut b512 = Histogram::new();
        let mut queue = Histogram::new();
        let mut exec = Histogram::new();
        for i in 0..500 {
            let v = 0.05 + (i as f64 * 7.31) % 240.0;
            latency.record(v);
            b512.record(v * 0.5);
            queue.record(v * 0.1);
            exec.record(v * 0.3);
        }
        let cum = CumulativeStats {
            admitted: 520,
            shed: [3, 2, 1, 0],
            errors: 1,
            latency,
            bucket_latency: vec![(512, b512)],
            queue_wait: queue,
            exec,
            worker_jobs: vec![40, 60],
            worker_busy_ms: vec![120.0, 260.0],
            phase_gflop: 4.0,
            peak_gflops: 80.0,
        };
        let meta = ExportMeta {
            uptime_s: 12.5,
            sampler_interval_s: 1.0,
            fingerprint: "1.8.512.64".to_string(),
            outstanding: 4,
            queue_ewma_ms: 2.25,
            batches: 33,
            backend_roofline: vec![("native".to_string(), 12.0, 80.0)],
            samples_total: 12,
        };
        let mut st = crate::obs::timeseries::SamplerState::new();
        let last = st.sample(1.0, cum.clone(), 4, 2.25);
        (cum, meta, last, Health::new().report())
    }

    #[test]
    fn exposition_round_trips_the_strict_parser() {
        let (cum, meta, last, health) = fixture();
        let text = render_validated(&cum, &meta, Some(&last), &health).unwrap();
        let doc = parse_prometheus(&text).unwrap();
        assert_eq!(doc.value("bigbird_requests_admitted_total", &[]), Some(520.0));
        assert_eq!(
            doc.value("bigbird_requests_shed_total", &[("reason", "queue_full")]),
            Some(3.0)
        );
        assert_eq!(doc.value("bigbird_healthy", &[]), Some(1.0));
        assert_eq!(doc.value("bigbird_worker_jobs_total", &[("worker", "1")]), Some(60.0));
        assert_eq!(
            doc.value("bigbird_model_info", &[("fingerprint", "1.8.512.64")]),
            Some(1.0)
        );
        assert_eq!(doc.value("bigbird_request_latency_ms_count", &[]), Some(500.0));
        // empty-series / empty-pool exports validate too
        let bare = render_validated(
            &CumulativeStats::default(),
            &ExportMeta::default(),
            None,
            &health,
        )
        .unwrap();
        assert!(parse_prometheus(&bare).is_ok());
    }

    #[test]
    fn histogram_buckets_match_hist_counts_exactly() {
        let (cum, meta, last, health) = fixture();
        let text = render_validated(&cum, &meta, Some(&last), &health).unwrap();
        let doc = parse_prometheus(&text).unwrap();
        let f = doc.family("bigbird_request_latency_ms").unwrap();
        assert_eq!(f.kind, MetricKind::Histogram);
        let buckets: Vec<&PromSample> =
            f.samples.iter().filter(|s| s.name.ends_with("_bucket")).collect();
        assert_eq!(buckets.len(), BUCKETS, "one le edge per hist bucket");
        let mut cumulative = 0u64;
        for (i, s) in buckets.iter().enumerate() {
            cumulative += cum.latency.counts()[i];
            assert_eq!(s.value, cumulative as f64, "bucket {i} cumulative count");
            let le = &s.labels.iter().find(|(k, _)| k == "le").unwrap().1;
            let (_, hi) = Histogram::bucket_bounds(i);
            if hi.is_finite() {
                assert_eq!(le.parse::<f64>().unwrap(), hi, "bucket {i} le edge");
            } else {
                assert_eq!(le, "+Inf");
            }
        }
        assert_eq!(doc.value("bigbird_request_latency_ms_count", &[]), Some(500.0));
        let sum = doc.value("bigbird_request_latency_ms_sum", &[]).unwrap();
        assert!((sum - cum.latency.sum()).abs() < 1e-6);
    }

    #[test]
    fn parser_rejects_malformed_expositions() {
        let (cum, meta, last, health) = fixture();
        let good = render_prometheus(&cum, &meta, Some(&last), &health);
        assert!(parse_prometheus(&good).is_ok());
        // samples before any TYPE
        assert!(parse_prometheus("bigbird_x 1\n").is_err());
        // TYPE without HELP
        assert!(parse_prometheus("# TYPE bigbird_x counter\nbigbird_x 1\n").is_err());
        // unknown kind
        assert!(
            parse_prometheus("# HELP bigbird_x x\n# TYPE bigbird_x summary\nbigbird_x 1\n")
                .is_err()
        );
        // sample from a foreign family
        assert!(parse_prometheus("# HELP a x\n# TYPE a counter\nb 1\n").is_err());
        // negative counter
        assert!(parse_prometheus("# HELP a x\n# TYPE a counter\na -1\n").is_err());
        // blank lines and unknown comments
        assert!(parse_prometheus(&good.replacen("# TYPE", "\n# TYPE", 1)).is_err());
        assert!(parse_prometheus(&format!("# EOF\n{good}")).is_err());
        // duplicate family
        let extra = "# HELP bigbird_healthy x\n# TYPE bigbird_healthy gauge\nbigbird_healthy 1\n";
        assert!(parse_prometheus(&format!("{good}{extra}")).is_err());
        // histogram invariants: breaking one cumulative count must fail
        let f = parse_prometheus(&good).unwrap();
        let count = f.value("bigbird_request_latency_ms_count", &[]).unwrap();
        let broken = good.replacen(
            &format!("bigbird_request_latency_ms_count {count}"),
            &format!("bigbird_request_latency_ms_count {}", count + 1.0),
            1,
        );
        let err = parse_prometheus(&broken).unwrap_err();
        assert!(err.contains("+Inf bucket must equal _count"), "{err}");
    }
}
