//! Request-span tracing: wall-clock intervals with parent/child links,
//! recorded into lock-free per-thread ring buffers and exported as
//! Chrome trace-event JSON (loadable in Perfetto / `chrome://tracing`).
//!
//! **Span model.** Every request is one *trace*, keyed by the server's
//! internal request id. The root `request` span covers submission →
//! response; its children cover the serving stages:
//!
//! ```text
//! request ──────────────────────────────────────────────────┐ (root)
//!   ingress     wire frame decode → submit return           │
//!   admission   the admission verdict                       │
//!   queue       batcher wait (admitted → dispatched)        │
//!   dispatch    batch formation + worker pick + enqueue     │
//!   worker_queue  worker job-queue wait                     │
//!   kernel      batch execution on the worker               │
//!   write       response handoff to the reply channel       │
//! ```
//!
//! Span ids are deterministic — root = `trace·16`, child =
//! `trace·16 + kind` — and every child's interval is contained in its
//! root's interval (the property test pins child ⊆ parent and
//! no-orphans). Batch-level stages (dispatch, worker queue, kernel)
//! are recorded once per request in the batch, so each trace is a
//! complete, self-contained timeline.
//!
//! **Recording.** Each thread owns a fixed-capacity ring of atomic
//! slots guarded by a seqlock counter; producers never block or
//! allocate after the ring exists, and the exporter snapshots slots
//! without stopping writers (a torn slot is simply skipped). When
//! tracing is disabled — the default — recording is one relaxed
//! atomic load.
//!
//! **Export.** [`export_chrome_json`] renders complete (`"ph":"X"`)
//! events with microsecond timestamps; `pid` is always 1 and `tid` is
//! the trace id, so Perfetto shows one lane per request. The exact
//! nanosecond interval and the span/parent links ride in `args`, which
//! is what [`parse_chrome_trace`] (a strict, zero-dependency parser)
//! and [`validate_trace`] check.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Default per-thread ring capacity, in spans (~64 B per slot).
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// The stage a span describes. Discriminants are stable wire/JSON ids.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// Root: submission → response, one per request.
    Request = 0,
    /// Wire frame decode + submit on the connection thread.
    Ingress = 1,
    /// Admission verdict inside `submit`.
    Admission = 2,
    /// Batcher queue wait (admitted → dispatched).
    Queue = 3,
    /// Batch formation, worker pick, and job enqueue.
    Dispatch = 4,
    /// Worker job-queue wait (dispatched → picked up).
    WorkerQueue = 5,
    /// Batch execution on the engine worker.
    Kernel = 6,
    /// Response handoff to the reply channel.
    Write = 7,
}

/// All span kinds, in pipeline order.
pub const SPAN_KINDS: [SpanKind; 8] = [
    SpanKind::Request,
    SpanKind::Ingress,
    SpanKind::Admission,
    SpanKind::Queue,
    SpanKind::Dispatch,
    SpanKind::WorkerQueue,
    SpanKind::Kernel,
    SpanKind::Write,
];

impl SpanKind {
    /// Stable event name in the exported trace.
    pub fn as_str(self) -> &'static str {
        match self {
            SpanKind::Request => "request",
            SpanKind::Ingress => "ingress",
            SpanKind::Admission => "admission",
            SpanKind::Queue => "queue",
            SpanKind::Dispatch => "dispatch",
            SpanKind::WorkerQueue => "worker_queue",
            SpanKind::Kernel => "kernel",
            SpanKind::Write => "write",
        }
    }

    /// Inverse of [`SpanKind::as_str`].
    pub fn parse(s: &str) -> Option<SpanKind> {
        SPAN_KINDS.iter().copied().find(|k| k.as_str() == s)
    }
}

/// One recorded span, as stored in the rings and round-tripped
/// through the Chrome JSON.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct SpanRecord {
    /// Trace id (the server's internal request id).
    pub trace: u64,
    /// This span's id (`trace·16 + kind`).
    pub span: u64,
    /// Parent span id (0 for the root).
    pub parent: u64,
    /// Stage.
    pub kind: SpanKind,
    /// Start, nanoseconds since the tracer epoch.
    pub start_ns: u64,
    /// Duration in nanoseconds.
    pub dur_ns: u64,
    /// Stage-specific argument (worker index for dispatch/kernel).
    pub arg: u64,
}

const SLOT_WORDS: usize = 7;

struct Slot {
    seq: AtomicU64,
    data: [AtomicU64; SLOT_WORDS],
}

struct ThreadRing {
    slots: Box<[Slot]>,
    head: AtomicU64,
}

impl ThreadRing {
    fn new(capacity: usize) -> Self {
        let slots = (0..capacity.max(1))
            .map(|_| Slot {
                seq: AtomicU64::new(0),
                data: std::array::from_fn(|_| AtomicU64::new(0)),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        ThreadRing { slots, head: AtomicU64::new(0) }
    }

    /// Single-producer push (the owning thread) under a seqlock: the
    /// slot is odd while mid-write, and readers retry/skip torn slots.
    fn push(&self, words: [u64; SLOT_WORDS]) {
        let i = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(i % self.slots.len() as u64) as usize];
        slot.seq.fetch_add(1, Ordering::Release); // now odd: write in progress
        for (d, w) in slot.data.iter().zip(words) {
            d.store(w, Ordering::Relaxed);
        }
        slot.seq.fetch_add(1, Ordering::Release); // even again: stable
    }

    fn snapshot(&self, out: &mut Vec<SpanRecord>) {
        let head = self.head.load(Ordering::Acquire);
        let filled = (head as usize).min(self.slots.len());
        for slot in &self.slots[..filled] {
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 & 1 == 1 {
                continue; // mid-write; skip rather than block the producer
            }
            let mut words = [0u64; SLOT_WORDS];
            for (w, d) in words.iter_mut().zip(&slot.data) {
                *w = d.load(Ordering::Relaxed);
            }
            std::sync::atomic::fence(Ordering::Acquire);
            if slot.seq.load(Ordering::Relaxed) != s1 {
                continue; // torn: overwritten while reading
            }
            let Some(kind) = SPAN_KINDS.get(words[3] as usize).copied() else {
                continue;
            };
            out.push(SpanRecord {
                trace: words[0],
                span: words[1],
                parent: words[2],
                kind,
                start_ns: words[4],
                dur_ns: words[5],
                arg: words[6],
            });
        }
    }
}

struct Registry {
    epoch: Instant,
    capacity: usize,
    rings: Mutex<Vec<Arc<ThreadRing>>>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static REGISTRY: OnceLock<Registry> = OnceLock::new();

fn registry(capacity: usize) -> &'static Registry {
    REGISTRY.get_or_init(|| Registry {
        epoch: Instant::now(),
        capacity: capacity.max(1),
        rings: Mutex::new(Vec::new()),
    })
}

thread_local! {
    static RING: std::cell::OnceCell<Arc<ThreadRing>> = const { std::cell::OnceCell::new() };
}

/// Turn span recording on. The per-thread ring capacity is fixed by
/// the first `enable` call of the process; later calls just flip the
/// gate back on.
pub fn enable(ring_capacity: usize) {
    registry(ring_capacity);
    ENABLED.store(true, Ordering::Release);
}

/// Stop recording (already-recorded spans remain exportable).
pub fn disable() {
    ENABLED.store(false, Ordering::Release);
}

/// Is span recording on? One relaxed load — check before touching any
/// clock on a hot path.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Deterministic span id for `kind` within `trace`.
pub fn span_id(trace: u64, kind: SpanKind) -> u64 {
    trace.wrapping_mul(16) + kind as u64
}

/// Record one span of `kind` for `trace` covering `[start, end]`.
/// No-op when tracing is disabled. The parent link is implied by the
/// kind: roots have parent 0, every other kind links to the trace's
/// root span.
pub fn span(kind: SpanKind, trace: u64, start: Instant, end: Instant, arg: u64) {
    if !enabled() {
        return;
    }
    let reg = registry(DEFAULT_RING_CAPACITY);
    let start_ns = start.saturating_duration_since(reg.epoch).as_nanos() as u64;
    let dur_ns = end.saturating_duration_since(start).as_nanos() as u64;
    let parent = if kind == SpanKind::Request { 0 } else { span_id(trace, SpanKind::Request) };
    let words = [trace, span_id(trace, kind), parent, kind as u64, start_ns, dur_ns, arg];
    RING.with(|cell| {
        let ring = cell.get_or_init(|| {
            let ring = Arc::new(ThreadRing::new(reg.capacity));
            match reg.rings.lock() {
                Ok(mut all) => all.push(ring.clone()),
                Err(mut p) => p.get_mut().push(ring.clone()),
            }
            ring
        });
        ring.push(words);
    });
}

/// Snapshot every thread's ring into one list, sorted by
/// `(trace, start, span)` for a stable export.
pub fn collect() -> Vec<SpanRecord> {
    let mut out = Vec::new();
    if let Some(reg) = REGISTRY.get() {
        let rings: Vec<Arc<ThreadRing>> = match reg.rings.lock() {
            Ok(g) => g.clone(),
            Err(p) => p.into_inner().clone(),
        };
        for ring in rings {
            ring.snapshot(&mut out);
        }
    }
    out.sort_by_key(|s| (s.trace, s.start_ns, s.span));
    out.dedup();
    out
}

// ---------------------------------------------------------------------------
// Chrome trace-event JSON export
// ---------------------------------------------------------------------------

fn push_f64(out: &mut String, v: f64) {
    if v.is_finite() {
        out.push_str(&format!("{v}"));
    } else {
        out.push('0');
    }
}

/// Render spans as a Chrome trace-event JSON document (complete `X`
/// events, µs timestamps, one `tid` lane per trace). The exact
/// nanosecond interval and span/parent links ride in `args`.
pub fn render_chrome_json(spans: &[SpanRecord]) -> String {
    let mut out = String::with_capacity(64 + spans.len() * 160);
    out.push_str("{\"traceEvents\":[");
    for (i, s) in spans.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str("{\"name\":\"");
        out.push_str(s.kind.as_str());
        out.push_str("\",\"cat\":\"bigbird\",\"ph\":\"X\",\"ts\":");
        push_f64(&mut out, s.start_ns as f64 / 1e3);
        out.push_str(",\"dur\":");
        push_f64(&mut out, s.dur_ns as f64 / 1e3);
        out.push_str(",\"pid\":1,\"tid\":");
        out.push_str(&s.trace.to_string());
        out.push_str(",\"args\":{\"trace\":");
        out.push_str(&s.trace.to_string());
        out.push_str(",\"span\":");
        out.push_str(&s.span.to_string());
        out.push_str(",\"parent\":");
        out.push_str(&s.parent.to_string());
        out.push_str(",\"start_ns\":");
        out.push_str(&s.start_ns.to_string());
        out.push_str(",\"dur_ns\":");
        out.push_str(&s.dur_ns.to_string());
        out.push_str(",\"arg\":");
        out.push_str(&s.arg.to_string());
        out.push_str("}}");
    }
    out.push_str("],\"displayTimeUnit\":\"ms\"}");
    out
}

/// [`collect`] + [`render_chrome_json`]: the document the `trace`
/// wire frame and `--trace-out` write.
pub fn export_chrome_json() -> String {
    render_chrome_json(&collect())
}

// ---------------------------------------------------------------------------
// Strict parser (round-trip checking; no serde anywhere in the crate)
// ---------------------------------------------------------------------------

struct Parser<'a> {
    src: &'a str,
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn new(s: &'a str) -> Self {
        Parser { src: s, bytes: s.as_bytes(), pos: 0 }
    }

    fn err<T>(&self, msg: &str) -> Result<T, String> {
        Err(format!("trace JSON invalid at byte {}: {msg}", self.pos))
    }

    fn skip_ws(&mut self) {
        while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, ch: u8) -> Result<(), String> {
        self.skip_ws();
        if self.bytes.get(self.pos) == Some(&ch) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected {:?}", ch as char))
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.bytes.get(self.pos).copied()
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bytes.get(self.pos) {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.bytes.get(self.pos) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        _ => return self.err("unsupported escape"),
                    }
                    self.pos += 1;
                }
                Some(&c) if c < 0x20 => return self.err("raw control byte in string"),
                Some(_) => {
                    // `pos` only ever lands on char boundaries, so this
                    // slice-and-next is safe for multi-byte UTF-8
                    let ch = self.src[self.pos..].chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<f64, String> {
        self.skip_ws();
        let start = self.pos;
        while matches!(
            self.bytes.get(self.pos),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        if start == self.pos {
            return self.err("expected number");
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .filter(|v| v.is_finite())
            .ok_or_else(|| format!("trace JSON invalid at byte {start}: bad number"))
    }

    fn u64_field(&mut self) -> Result<u64, String> {
        let v = self.number()?;
        if v < 0.0 || v.fract() != 0.0 || v > 2f64.powi(53) {
            return self.err("expected a non-negative integer");
        }
        Ok(v as u64)
    }
}

/// Strictly parse a Chrome trace-event document produced by
/// [`render_chrome_json`]: the exact key set, `"ph":"X"` only,
/// integer args, no trailing input. Anything else is an error — this
/// is the CI validation path, so leniency would hide export bugs.
pub fn parse_chrome_trace(json: &str) -> Result<Vec<SpanRecord>, String> {
    let mut p = Parser::new(json);
    let mut spans = Vec::new();
    p.expect(b'{')?;
    if p.string()? != "traceEvents" {
        return p.err("expected \"traceEvents\"");
    }
    p.expect(b':')?;
    p.expect(b'[')?;
    if p.peek() == Some(b']') {
        p.pos += 1;
    } else {
        loop {
            spans.push(parse_event(&mut p)?);
            match p.peek() {
                Some(b',') => p.pos += 1,
                Some(b']') => {
                    p.pos += 1;
                    break;
                }
                _ => return p.err("expected ',' or ']'"),
            }
        }
    }
    // optional trailing displayTimeUnit
    if p.peek() == Some(b',') {
        p.pos += 1;
        if p.string()? != "displayTimeUnit" {
            return p.err("unknown top-level key");
        }
        p.expect(b':')?;
        if p.string()? != "ms" {
            return p.err("unsupported displayTimeUnit");
        }
    }
    p.expect(b'}')?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing input after document");
    }
    Ok(spans)
}

fn parse_event(p: &mut Parser<'_>) -> Result<SpanRecord, String> {
    p.expect(b'{')?;
    let (mut name, mut trace, mut span, mut parent) = (None, None, None, None);
    let (mut start_ns, mut dur_ns, mut arg, mut tid) = (None, None, None, None);
    let (mut saw_ts, mut saw_dur, mut saw_pid, mut saw_cat, mut saw_ph) =
        (false, false, false, false, false);
    loop {
        let key = p.string()?;
        p.expect(b':')?;
        match key.as_str() {
            "name" => name = Some(p.string()?),
            "cat" => {
                if p.string()? != "bigbird" {
                    return p.err("unexpected event category");
                }
                saw_cat = true;
            }
            "ph" => {
                if p.string()? != "X" {
                    return p.err("only complete (\"X\") events are valid");
                }
                saw_ph = true;
            }
            "ts" => {
                p.number()?;
                saw_ts = true;
            }
            "dur" => {
                p.number()?;
                saw_dur = true;
            }
            "pid" => {
                p.u64_field()?;
                saw_pid = true;
            }
            "tid" => tid = Some(p.u64_field()?),
            "args" => {
                p.expect(b'{')?;
                loop {
                    let akey = p.string()?;
                    p.expect(b':')?;
                    let v = p.u64_field()?;
                    match akey.as_str() {
                        "trace" => trace = Some(v),
                        "span" => span = Some(v),
                        "parent" => parent = Some(v),
                        "start_ns" => start_ns = Some(v),
                        "dur_ns" => dur_ns = Some(v),
                        "arg" => arg = Some(v),
                        _ => return p.err("unknown args key"),
                    }
                    match p.peek() {
                        Some(b',') => p.pos += 1,
                        Some(b'}') => {
                            p.pos += 1;
                            break;
                        }
                        _ => return p.err("expected ',' or '}' in args"),
                    }
                }
            }
            _ => return p.err("unknown event key"),
        }
        match p.peek() {
            Some(b',') => p.pos += 1,
            Some(b'}') => {
                p.pos += 1;
                break;
            }
            _ => return p.err("expected ',' or '}' in event"),
        }
    }
    if !(saw_ts && saw_dur && saw_pid && saw_cat && saw_ph) {
        return p.err("event is missing a required key");
    }
    let name = name.ok_or("event missing name")?;
    let kind = SpanKind::parse(&name).ok_or_else(|| format!("unknown span name {name:?}"))?;
    let rec = SpanRecord {
        trace: trace.ok_or("args missing trace")?,
        span: span.ok_or("args missing span")?,
        parent: parent.ok_or("args missing parent")?,
        kind,
        start_ns: start_ns.ok_or("args missing start_ns")?,
        dur_ns: dur_ns.ok_or("args missing dur_ns")?,
        arg: arg.ok_or("args missing arg")?,
    };
    if tid != Some(rec.trace) {
        return p.err("tid must equal the trace id");
    }
    Ok(rec)
}

// ---------------------------------------------------------------------------
// Validation
// ---------------------------------------------------------------------------

/// What [`validate_trace`] found.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TraceSummary {
    /// Total spans checked.
    pub spans: usize,
    /// Distinct trace ids.
    pub traces: usize,
    /// Traces with the full admission→queue→dispatch→worker-queue→
    /// kernel chain under one root.
    pub full_chains: usize,
    /// Full-chain traces that also carry an ingress span (came over
    /// the wire).
    pub wire_chains: usize,
}

/// Check structural invariants over a parsed span set: span ids are
/// unique per trace, every non-root span's parent exists (no
/// orphans), and every child interval is contained in its parent's.
/// Returns per-kind coverage counts on success.
pub fn validate_trace(spans: &[SpanRecord]) -> Result<TraceSummary, String> {
    use std::collections::BTreeMap;
    let mut by_trace: BTreeMap<u64, Vec<&SpanRecord>> = BTreeMap::new();
    for s in spans {
        by_trace.entry(s.trace).or_default().push(s);
    }
    let mut summary =
        TraceSummary { spans: spans.len(), traces: by_trace.len(), ..Default::default() };
    for (trace, group) in &by_trace {
        let mut ids = BTreeMap::new();
        for s in group {
            if ids.insert(s.span, *s).is_some() {
                return Err(format!("trace {trace}: duplicate span id {}", s.span));
            }
        }
        for s in group {
            if s.parent == 0 {
                continue;
            }
            let parent = ids.get(&s.parent).ok_or_else(|| {
                format!("trace {trace}: span {} is an orphan (parent {} missing)", s.span, s.parent)
            })?;
            let (cs, ce) = (s.start_ns, s.start_ns + s.dur_ns);
            let (ps, pe) = (parent.start_ns, parent.start_ns + parent.dur_ns);
            if cs < ps || ce > pe {
                return Err(format!(
                    "trace {trace}: {} span [{cs},{ce}]ns escapes its parent {} [{ps},{pe}]ns",
                    s.kind.as_str(),
                    parent.kind.as_str()
                ));
            }
        }
        let has = |k: SpanKind| group.iter().any(|s| s.kind == k);
        if has(SpanKind::Request)
            && has(SpanKind::Admission)
            && has(SpanKind::Queue)
            && has(SpanKind::Dispatch)
            && has(SpanKind::WorkerQueue)
            && has(SpanKind::Kernel)
        {
            summary.full_chains += 1;
            if has(SpanKind::Ingress) {
                summary.wire_chains += 1;
            }
        }
    }
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(trace: u64, kind: SpanKind, start_ns: u64, dur_ns: u64) -> SpanRecord {
        SpanRecord {
            trace,
            span: span_id(trace, kind),
            parent: if kind == SpanKind::Request { 0 } else { span_id(trace, SpanKind::Request) },
            kind,
            start_ns,
            dur_ns,
            arg: 0,
        }
    }

    fn full_trace(trace: u64) -> Vec<SpanRecord> {
        vec![
            rec(trace, SpanKind::Request, 100, 1000),
            rec(trace, SpanKind::Ingress, 100, 50),
            rec(trace, SpanKind::Admission, 110, 20),
            rec(trace, SpanKind::Queue, 150, 200),
            rec(trace, SpanKind::Dispatch, 350, 40),
            rec(trace, SpanKind::WorkerQueue, 390, 60),
            rec(trace, SpanKind::Kernel, 450, 500),
            rec(trace, SpanKind::Write, 1050, 50),
        ]
    }

    #[test]
    fn json_round_trips_exactly() {
        let spans: Vec<SpanRecord> = (1u64..=3).flat_map(full_trace).collect();
        let json = render_chrome_json(&spans);
        let parsed = parse_chrome_trace(&json).unwrap();
        assert_eq!(parsed, spans);
        // and re-rendering the parse is byte-identical
        assert_eq!(render_chrome_json(&parsed), json);
        // empty documents round-trip too
        assert_eq!(parse_chrome_trace(&render_chrome_json(&[])).unwrap(), vec![]);
    }

    #[test]
    fn parser_is_strict() {
        let good = render_chrome_json(&full_trace(1));
        assert!(parse_chrome_trace(&good).is_ok());
        // trailing garbage
        assert!(parse_chrome_trace(&format!("{good} ")).is_ok(), "trailing ws is fine");
        assert!(parse_chrome_trace(&format!("{good}x")).is_err());
        // wrong phase marker
        assert!(parse_chrome_trace(&good.replace("\"ph\":\"X\"", "\"ph\":\"B\"")).is_err());
        // unknown span name
        assert!(parse_chrome_trace(&good.replace("\"request\"", "\"mystery\"")).is_err());
        // unknown key
        assert!(parse_chrome_trace(&good.replace("\"cat\"", "\"dog\"")).is_err());
        // tid must match the trace id
        assert!(parse_chrome_trace(&good.replace("\"tid\":1,", "\"tid\":9,")).is_err());
        // non-integer args
        assert!(parse_chrome_trace(&good.replace("\"arg\":0", "\"arg\":0.5")).is_err());
        assert!(parse_chrome_trace("{}").is_err());
        assert!(parse_chrome_trace("").is_err());
    }

    #[test]
    fn validation_accepts_nesting_and_rejects_violations() {
        let spans = full_trace(7);
        let s = validate_trace(&spans).unwrap();
        assert_eq!(s.traces, 1);
        assert_eq!(s.full_chains, 1);
        assert_eq!(s.wire_chains, 1);

        // child escaping its parent interval
        let mut bad = full_trace(7);
        bad[6].dur_ns = 10_000_000;
        assert!(validate_trace(&bad).unwrap_err().contains("escapes"));

        // orphan: child without its root
        let orphan = vec![rec(9, SpanKind::Kernel, 0, 10)];
        assert!(validate_trace(&orphan).unwrap_err().contains("orphan"));

        // duplicate span ids
        let mut dup = full_trace(7);
        dup.push(dup[0].clone());
        assert!(validate_trace(&dup).unwrap_err().contains("duplicate"));
    }

    #[test]
    fn ring_snapshot_sees_pushed_spans_and_survives_wrap() {
        let ring = ThreadRing::new(8);
        for i in 0..20u64 {
            ring.push([
                1,
                span_id(1, SpanKind::Kernel),
                span_id(1, SpanKind::Request),
                SpanKind::Kernel as u64,
                i,
                1,
                0,
            ]);
        }
        let mut out = Vec::new();
        ring.snapshot(&mut out);
        assert_eq!(out.len(), 8, "ring keeps the most recent capacity spans");
        assert!(out.iter().all(|s| s.kind == SpanKind::Kernel));
    }
}
