//! Structured logging: the `log!(level, target, ...)` facade behind
//! the scattered `eprintln!` calls the serving stack used to have.
//!
//! * **Filtering** — the `BB_LOG` environment variable selects what
//!   prints: a default level (`error|warn|info|debug|off`) optionally
//!   followed by per-target overrides, e.g.
//!   `BB_LOG=warn,ingress=debug,server=off`. Unset means `info`.
//!   Malformed clauses never take the process down: each is ignored
//!   with one warning line at first use naming the clause and why.
//! * **Format** — `[<seconds-since-start> LEVEL target] message` on
//!   stderr, one line per event, so logs stay greppable by target.
//! * **Rate limiting** — at most [`MAX_PER_WINDOW`] lines per target
//!   per second; excess lines are dropped and summarized with one
//!   `suppressed N line(s)` note when the window rolls, so a hot
//!   shed/error loop cannot flood stderr.
//!
//! The filter is parsed once per process; [`enabled`] is a cheap
//! lookup the macro checks before formatting anything, so disabled
//! log sites cost one branch.

use std::collections::HashMap;
use std::fmt;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Log severity, ordered from most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
}

impl Level {
    /// Fixed-width display name.
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }

    fn parse(s: &str) -> Option<Option<Level>> {
        Some(match s {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "off" => None,
            _ => return None,
        })
    }
}

/// A parsed `BB_LOG` filter: a default threshold plus per-target
/// overrides. `None` thresholds mean "off".
#[derive(Clone, Debug, PartialEq)]
pub struct Filter {
    default: Option<Level>,
    targets: Vec<(String, Option<Level>)>,
}

impl Filter {
    /// Parse a `BB_LOG` spec. Unknown level names and malformed
    /// clauses are ignored (logging must never take the server down),
    /// falling back to the `info` default for that clause.
    pub fn parse(spec: &str) -> Filter {
        Filter::parse_with_diagnostics(spec).0
    }

    /// [`Filter::parse`], additionally returning one human-readable
    /// diagnostic per ignored clause. The process-wide filter prints
    /// these once at first use, so a typo like `BB_LOG=nfo` degrades
    /// loudly instead of silently reverting to the defaults.
    pub fn parse_with_diagnostics(spec: &str) -> (Filter, Vec<String>) {
        let mut default = Some(Level::Info);
        let mut targets = Vec::new();
        let mut diagnostics = Vec::new();
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            match clause.split_once('=') {
                Some((target, level)) => match Level::parse(level.trim()) {
                    Some(lv) if !target.trim().is_empty() => {
                        targets.push((target.trim().to_string(), lv))
                    }
                    Some(_) => {
                        diagnostics.push(format!("ignoring BB_LOG clause {clause:?}: empty target"))
                    }
                    None => diagnostics.push(format!(
                        "ignoring BB_LOG clause {clause:?}: unknown level {:?} \
                         (use error|warn|info|debug|off)",
                        level.trim()
                    )),
                },
                None => match Level::parse(clause) {
                    Some(lv) => default = lv,
                    None => diagnostics.push(format!(
                        "ignoring BB_LOG clause {clause:?}: not a level or target=level \
                         (use error|warn|info|debug|off)"
                    )),
                },
            }
        }
        (Filter { default, targets }, diagnostics)
    }

    /// Would a `level` event for `target` print under this filter?
    pub fn enabled(&self, level: Level, target: &str) -> bool {
        let threshold = self
            .targets
            .iter()
            .find(|(t, _)| t == target)
            .map(|(_, lv)| *lv)
            .unwrap_or(self.default);
        matches!(threshold, Some(t) if level <= t)
    }
}

/// Max lines one target may print within one rate-limit window (1 s).
pub const MAX_PER_WINDOW: u32 = 32;

struct RateCell {
    window_start: Instant,
    printed: u32,
    suppressed: u64,
}

struct State {
    epoch: Instant,
    rate: Mutex<HashMap<String, RateCell>>,
}

fn state() -> &'static State {
    static STATE: OnceLock<State> = OnceLock::new();
    STATE.get_or_init(|| State { epoch: Instant::now(), rate: Mutex::new(HashMap::new()) })
}

fn filter() -> &'static Filter {
    static FILTER: OnceLock<Filter> = OnceLock::new();
    FILTER.get_or_init(|| {
        let spec = std::env::var("BB_LOG").unwrap_or_default();
        let (f, diagnostics) = Filter::parse_with_diagnostics(&spec);
        // warn once per process, directly through `write` — the filter
        // cell is mid-initialization here, so routing through `log!`
        // (which calls `enabled` → this function) would re-enter
        for d in diagnostics {
            write(Level::Warn, "log", format_args!("{d}"));
        }
        f
    })
}

/// Is a `level` event for `target` enabled under the process filter?
/// The `log!` macro checks this before formatting its arguments.
pub fn enabled(level: Level, target: &str) -> bool {
    filter().enabled(level, target)
}

/// Emit one already-filtered log line (called by the `log!` macro).
/// Applies the per-target rate limit.
pub fn write(level: Level, target: &str, args: fmt::Arguments<'_>) {
    let st = state();
    let now = Instant::now();
    let mut rate = match st.rate.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    let cell = rate
        .entry(target.to_string())
        .or_insert(RateCell { window_start: now, printed: 0, suppressed: 0 });
    if now.duration_since(cell.window_start).as_secs_f64() >= 1.0 {
        if cell.suppressed > 0 {
            eprintln!(
                "[{:9.3}s {:5} {}] suppressed {} line(s) (rate limit {MAX_PER_WINDOW}/s)",
                now.duration_since(st.epoch).as_secs_f64(),
                Level::Warn.as_str(),
                target,
                cell.suppressed
            );
        }
        cell.window_start = now;
        cell.printed = 0;
        cell.suppressed = 0;
    }
    if cell.printed >= MAX_PER_WINDOW {
        cell.suppressed += 1;
        return;
    }
    cell.printed += 1;
    drop(rate);
    eprintln!(
        "[{:9.3}s {:5} {}] {}",
        now.duration_since(st.epoch).as_secs_f64(),
        level.as_str(),
        target,
        args
    );
}

/// The `log!(level, target, format...)` facade. Levels are the
/// variants of [`crate::obs::Level`]; the target is a short static
/// subsystem name (`"server"`, `"ingress"`, `"admission"`, ...).
/// Filtered by the `BB_LOG` environment variable (see
/// [`crate::obs::log`]) and rate-limited per target.
#[macro_export]
macro_rules! log {
    ($lvl:expr, $target:expr, $($arg:tt)*) => {{
        let lvl = $lvl;
        if $crate::obs::log::enabled(lvl, $target) {
            $crate::obs::log::write(lvl, $target, format_args!($($arg)*));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filter_parses_default_and_overrides() {
        let f = Filter::parse("warn,ingress=debug,server=off");
        assert!(f.enabled(Level::Warn, "dispatch"));
        assert!(!f.enabled(Level::Info, "dispatch"));
        assert!(f.enabled(Level::Debug, "ingress"));
        assert!(!f.enabled(Level::Error, "server"), "off silences even errors");
    }

    #[test]
    fn filter_defaults_to_info_and_survives_garbage() {
        let f = Filter::parse("");
        assert!(f.enabled(Level::Info, "anything"));
        assert!(!f.enabled(Level::Debug, "anything"));
        // malformed clauses are ignored, not fatal
        let g = Filter::parse("bogus,=,x=notalevel,debug");
        assert!(g.enabled(Level::Debug, "anything"), "last valid default wins");
        assert!(!Filter::parse("off").enabled(Level::Error, "t"));
    }

    #[test]
    fn malformed_clauses_produce_diagnostics() {
        let (f, diags) = Filter::parse_with_diagnostics("bogus,x=notalevel,debug,ingress=warn");
        assert_eq!(diags.len(), 2, "one diagnostic per ignored clause: {diags:?}");
        assert!(diags[0].contains("\"bogus\""), "{}", diags[0]);
        assert!(diags[1].contains("\"x=notalevel\""), "{}", diags[1]);
        assert!(diags[1].contains("\"notalevel\""), "names the bad level: {}", diags[1]);
        // the valid clauses of a partly-bad spec still apply
        assert!(f.enabled(Level::Debug, "other"));
        assert!(!f.enabled(Level::Info, "ingress"));
        // clean specs produce no diagnostics
        assert!(Filter::parse_with_diagnostics("warn,server=off").1.is_empty());
        assert!(Filter::parse_with_diagnostics("").1.is_empty());
        // an empty target is ignored, with a diagnostic saying why
        let (g, d) = Filter::parse_with_diagnostics("=debug");
        assert_eq!(d.len(), 1);
        assert!(d[0].contains("empty target"), "{}", d[0]);
        assert!(!g.enabled(Level::Debug, "anything"), "ignored clause must not apply");
    }

    #[test]
    fn levels_order_most_severe_first() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn macro_compiles_against_the_facade() {
        // goes through the real filter; default info ⇒ debug is a no-op
        crate::log!(Level::Debug, "obs-test", "invisible {}", 1);
    }
}
