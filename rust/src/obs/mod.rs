//! Observability: zero-dependency tracing, profiling, and telemetry
//! primitives threaded through the serving stack.
//!
//! Four pieces, each independently gated so the disabled cost on hot
//! paths is one relaxed atomic load (the bench gate pins this):
//!
//! * [`trace`] — request spans with parent/child links, recorded into
//!   lock-free per-thread ring buffers and exported as Chrome
//!   trace-event JSON (Perfetto-loadable) via the `trace` wire frame
//!   and `serve --trace-out <path>`.
//! * [`phase`] — per-phase kernel accumulators (pack, QKᵀ, softmax,
//!   AV, backward, GEMM) with analytic flop/byte counts, feeding
//!   achieved-vs-roofline utilization in `MetricsSnapshot` and the
//!   `kernel-probe` profile table.
//! * [`hist`] — fixed-boundary log-bucket latency histograms that
//!   merge exactly across workers; the deterministic SLO percentiles
//!   in `MetricsSnapshot` (per `native_mlm_s{n}` sequence bucket).
//! * [`log`] — the `log!(level, target, ...)` facade with the
//!   `BB_LOG` env filter and per-target rate limiting, replacing the
//!   scattered `eprintln!` calls.
//!
//! See rust/README.md "Observability" for the span model, frame
//! layout, filter syntax, and bucket boundaries.

pub mod hist;
pub mod log;
pub mod phase;
pub mod trace;

pub use hist::Histogram;
pub use log::Level;
pub use phase::{Phase, PhaseStat};
pub use trace::{SpanKind, SpanRecord, TraceSummary};
