//! Observability: zero-dependency tracing, profiling, and telemetry
//! primitives threaded through the serving stack.
//!
//! Point-in-time pieces, each independently gated so the disabled cost
//! on hot paths is one relaxed atomic load (the bench gate pins this):
//!
//! * [`trace`] — request spans with parent/child links, recorded into
//!   lock-free per-thread ring buffers and exported as Chrome
//!   trace-event JSON (Perfetto-loadable) via the `trace` wire frame
//!   and `serve --trace-out <path>`.
//! * [`phase`] — per-phase kernel accumulators (pack, QKᵀ, softmax,
//!   AV, backward, GEMM) with analytic flop/byte counts, feeding
//!   achieved-vs-roofline utilization in `MetricsSnapshot` and the
//!   `kernel-probe` profile table.
//! * [`hist`] — fixed-boundary log-bucket latency histograms that
//!   merge exactly across workers; the deterministic SLO percentiles
//!   in `MetricsSnapshot` (per `native_mlm_s{n}` sequence bucket).
//! * [`log`] — the `log!(level, target, ...)` facade with the
//!   `BB_LOG` env filter and per-target rate limiting, replacing the
//!   scattered `eprintln!` calls.
//!
//! And the continuous layer built on top of them:
//!
//! * [`timeseries`] — a fixed-capacity ring of periodic samples from a
//!   server-owned sampler thread: counter deltas as rates plus exact
//!   histogram-delta percentiles per window, mergeable across windows.
//! * [`export`] — Prometheus text exposition of the live counters and
//!   the most recent window, gated by a strict self-parser; served over
//!   wire frames 7/8 and the ingress `GET /metrics` HTTP adapter.
//! * [`watchdog`] — anomaly detectors over the series (worker stall,
//!   shed spike, utilization collapse, SLO burn) driving `/healthz`
//!   and a flight recorder that dumps timestamped bundles.
//!
//! See rust/README.md "Observability" for the span model, frame
//! layout, filter syntax, bucket boundaries, metric names, and
//! watchdog thresholds.

pub mod export;
pub mod hist;
pub mod log;
pub mod phase;
pub mod timeseries;
pub mod trace;
pub mod watchdog;

pub use export::{parse_prometheus, ExportMeta, PromDoc};
pub use hist::Histogram;
pub use log::Level;
pub use phase::{Phase, PhaseStat};
pub use timeseries::{CumulativeStats, SeriesRing, SeriesSample};
pub use trace::{SpanKind, SpanRecord, TraceSummary};
pub use watchdog::{FlightRecorder, Health, HealthReport};
