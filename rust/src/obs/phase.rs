//! Kernel-phase profiling: process-wide accumulators for the native
//! kernel's hot phases — transpose **pack**, **QKᵀ** tiles, streaming
//! **softmax**, **AV** tiles, the attention **backward** pass, and the
//! model **GEMM** layer.
//!
//! Each phase accumulates call count, busy nanoseconds, and analytic
//! flop/byte totals (computed from the shapes actually executed, not
//! measured), so dividing gives the achieved GFLOP/s per phase —
//! comparable against the calibrated roofline
//! ([`crate::kernel::native_roofline`]) to answer "is this phase
//! compute-bound and efficient, or did it degrade?". `kernel-probe`
//! prints the table; `MetricsSnapshot` folds the same numbers into
//! per-backend achieved-vs-roofline utilization.
//!
//! Profiling is **off by default** and gated behind one relaxed
//! atomic load per instrumentation site, so the disabled cost is a
//! predictable branch (~0; the bench gate pins this). When enabled,
//! the forward tile loop samples timing on a subset of query-block
//! rows and scales by the exact tile ratio, keeping enabled overhead
//! under 1% even at small block sizes — flop/byte counts are always
//! exact because they are analytic.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// One instrumented kernel phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// `pack_transposed`: K/V block transpose-pack before the tiles.
    Pack,
    /// `qk_tile`: the QKᵀ score tiles.
    QkT,
    /// Streaming-softmax row pass between QKᵀ and AV.
    Softmax,
    /// `av_tile`: the probability × V accumulation tiles.
    Av,
    /// The attention backward pass (per-head, whole-call granularity).
    Backward,
    /// The packed model GEMM layer (projections, FFN, logits).
    Gemm,
}

/// Number of instrumented phases.
pub const PHASE_COUNT: usize = 6;

/// All phases, in pipeline order.
pub const PHASES: [Phase; PHASE_COUNT] =
    [Phase::Pack, Phase::QkT, Phase::Softmax, Phase::Av, Phase::Backward, Phase::Gemm];

impl Phase {
    /// Stable lowercase name (used in JSON and the probe table).
    pub fn as_str(self) -> &'static str {
        match self {
            Phase::Pack => "pack",
            Phase::QkT => "qk_t",
            Phase::Softmax => "softmax",
            Phase::Av => "av",
            Phase::Backward => "backward",
            Phase::Gemm => "gemm",
        }
    }

    fn index(self) -> usize {
        match self {
            Phase::Pack => 0,
            Phase::QkT => 1,
            Phase::Softmax => 2,
            Phase::Av => 3,
            Phase::Backward => 4,
            Phase::Gemm => 5,
        }
    }
}

struct Acc {
    calls: AtomicU64,
    nanos: AtomicU64,
    flops: AtomicU64,
    bytes: AtomicU64,
}

impl Acc {
    const fn new() -> Self {
        Acc {
            calls: AtomicU64::new(0),
            nanos: AtomicU64::new(0),
            flops: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
        }
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static ACCS: [Acc; PHASE_COUNT] =
    [Acc::new(), Acc::new(), Acc::new(), Acc::new(), Acc::new(), Acc::new()];

/// Turn phase accumulation on or off (off by default).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Release);
}

/// Is phase accumulation on? One relaxed load — instrumentation sites
/// check this before touching any clock.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Fold a batch of completed phase work into the accumulators:
/// `calls` executions totalling `nanos` busy time, `flops` floating
/// ops, and `bytes` memory traffic. Callers aggregate locally and
/// flush once per kernel call, so the atomics stay off the tile loop.
pub fn record(phase: Phase, calls: u64, nanos: u64, flops: u64, bytes: u64) {
    let acc = &ACCS[phase.index()];
    acc.calls.fetch_add(calls, Ordering::Relaxed);
    acc.nanos.fetch_add(nanos, Ordering::Relaxed);
    acc.flops.fetch_add(flops, Ordering::Relaxed);
    acc.bytes.fetch_add(bytes, Ordering::Relaxed);
}

/// Zero all accumulators (probe harnesses and tests).
pub fn reset() {
    for acc in &ACCS {
        acc.calls.store(0, Ordering::Relaxed);
        acc.nanos.store(0, Ordering::Relaxed);
        acc.flops.store(0, Ordering::Relaxed);
        acc.bytes.store(0, Ordering::Relaxed);
    }
}

/// One phase's accumulated totals, as reported by [`snapshot`].
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseStat {
    /// Phase name ([`Phase::as_str`]).
    pub phase: &'static str,
    /// Number of recorded executions (tiles for the forward phases,
    /// whole calls for backward/GEMM).
    pub calls: u64,
    /// Busy wall-clock summed across kernel threads, ms (timing is
    /// sampled on the forward tile loop and scaled by the exact tile
    /// ratio).
    pub busy_ms: f64,
    /// Analytic floating-op total, in GFLOP.
    pub gflop: f64,
    /// Analytic memory-traffic total, in GB.
    pub gbyte: f64,
}

impl PhaseStat {
    /// Achieved compute rate while busy (GFLOP/s; 0 when idle).
    pub fn achieved_gflops(&self) -> f64 {
        if self.busy_ms > 0.0 {
            self.gflop / (self.busy_ms / 1e3)
        } else {
            0.0
        }
    }

    /// Achieved memory bandwidth while busy (GB/s; 0 when idle).
    pub fn achieved_gbps(&self) -> f64 {
        if self.busy_ms > 0.0 {
            self.gbyte / (self.busy_ms / 1e3)
        } else {
            0.0
        }
    }
}

/// Snapshot all phase accumulators, in pipeline order. Phases that
/// never ran report zeros.
pub fn snapshot() -> Vec<PhaseStat> {
    PHASES
        .iter()
        .map(|&p| {
            let acc = &ACCS[p.index()];
            PhaseStat {
                phase: p.as_str(),
                calls: acc.calls.load(Ordering::Relaxed),
                busy_ms: acc.nanos.load(Ordering::Relaxed) as f64 / 1e6,
                gflop: acc.flops.load(Ordering::Relaxed) as f64 / 1e9,
                gbyte: acc.bytes.load(Ordering::Relaxed) as f64 / 1e9,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_accumulates_and_reset_clears() {
        // Serialize against other tests touching the global accumulators.
        reset();
        record(Phase::Gemm, 3, 2_000_000, 4_000_000_000, 1_000_000_000);
        let stat = snapshot().into_iter().find(|s| s.phase == "gemm").unwrap();
        assert_eq!(stat.calls, 3);
        assert!((stat.busy_ms - 2.0).abs() < 1e-9);
        assert!((stat.gflop - 4.0).abs() < 1e-9);
        assert!((stat.achieved_gflops() - 2000.0).abs() < 1e-6);
        assert!((stat.achieved_gbps() - 500.0).abs() < 1e-6);
        reset();
        assert!(snapshot().iter().all(|s| s.calls == 0 && s.busy_ms == 0.0));
    }

    #[test]
    fn phase_names_are_stable_and_ordered() {
        let names: Vec<_> = PHASES.iter().map(|p| p.as_str()).collect();
        assert_eq!(names, ["pack", "qk_t", "softmax", "av", "backward", "gemm"]);
        for (i, p) in PHASES.iter().enumerate() {
            assert_eq!(p.index(), i);
        }
    }
}
