//! Anomaly watchdog over the telemetry series, plus the health state
//! `/healthz` serves and the flight recorder that preserves evidence.
//!
//! Four detectors run after every sampler window, each a **pure
//! function of the recent [`SeriesSample`]s** (so tests drive them
//! with synthetic windows, no clocks or threads):
//!
//! * **worker stall** — the queue is non-empty (`outstanding > 0`) but
//!   nothing completed, across [`STALL_WINDOWS`] consecutive windows.
//!   The alert names the workers whose heartbeat (per-window job
//!   delta) is flat.
//! * **shed spike** — more than [`SPIKE_SHED_FRAC`] of the window's
//!   submissions were shed, with at least [`SPIKE_MIN_EVENTS`]
//!   submissions in the window (so an idle server's single shed never
//!   pages).
//! * **utilization collapse** — the pool's achieved GFLOP/s falls
//!   under [`COLLAPSE_UTIL_FRAC`] of the declared roofline peak for
//!   [`COLLAPSE_WINDOWS`] windows while real backlog is sustained
//!   (`outstanding ≥` [`COLLAPSE_MIN_BACKLOG`] and completions are
//!   still happening — a *total* stop is the stall detector's case).
//! * **SLO burn** — the window p99 exceeds the configured target for
//!   [`BURN_WINDOWS`] consecutive windows with completions in each.
//!   Off unless a target is set (`serve --slo-p99-ms`).
//!
//! Firing **edges** (a detector newly active) emit one rate-limited
//! `log!` alert and trigger one [`FlightRecorder`] dump; while a
//! condition stays active the health report stays degraded but no new
//! bundles are written. Health recovers automatically when a window
//! closes with no detector active.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::timeseries::SeriesSample;

/// Windows of queue-non-empty-with-no-completions before a stall fires.
pub const STALL_WINDOWS: usize = 3;
/// Minimum submissions in a window before the shed fraction is judged.
pub const SPIKE_MIN_EVENTS: u64 = 16;
/// Shed fraction of a window's submissions that counts as a spike.
pub const SPIKE_SHED_FRAC: f64 = 0.5;
/// Windows of collapsed utilization before the detector fires.
pub const COLLAPSE_WINDOWS: usize = 3;
/// Achieved/peak ratio under which utilization counts as collapsed.
pub const COLLAPSE_UTIL_FRAC: f64 = 0.02;
/// Outstanding requests that count as sustained backlog for collapse.
pub const COLLAPSE_MIN_BACKLOG: u64 = 8;
/// Consecutive over-target windows before the SLO burn fires.
pub const BURN_WINDOWS: usize = 3;

/// Which detector fired.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Detector {
    WorkerStall = 0,
    ShedSpike = 1,
    UtilCollapse = 2,
    SloBurn = 3,
}

/// All detectors, in stable index order.
pub const DETECTORS: [Detector; 4] =
    [Detector::WorkerStall, Detector::ShedSpike, Detector::UtilCollapse, Detector::SloBurn];

impl Detector {
    /// Stable label (Prometheus `detector` label, bundle tag).
    pub fn as_str(self) -> &'static str {
        match self {
            Detector::WorkerStall => "worker_stall",
            Detector::ShedSpike => "shed_spike",
            Detector::UtilCollapse => "util_collapse",
            Detector::SloBurn => "slo_burn",
        }
    }
}

/// One detector firing, with a human-readable diagnosis.
#[derive(Clone, Debug, PartialEq)]
pub struct Alert {
    pub detector: Detector,
    pub reason: String,
}

/// Run every detector over the most recent windows (oldest first, as
/// [`crate::obs::timeseries::SeriesRing::last`] returns them).
/// `slo_p99_ms` arms the SLO-burn detector. Pure: no clocks, no state.
pub fn detect(recent: &[SeriesSample], slo_p99_ms: Option<f64>) -> Vec<Alert> {
    let mut alerts = Vec::new();
    if let Some(a) = detect_stall(recent) {
        alerts.push(a);
    }
    if let Some(a) = detect_shed_spike(recent) {
        alerts.push(a);
    }
    if let Some(a) = detect_util_collapse(recent) {
        alerts.push(a);
    }
    if let Some(slo) = slo_p99_ms {
        if let Some(a) = detect_slo_burn(recent, slo) {
            alerts.push(a);
        }
    }
    alerts
}

fn tail(recent: &[SeriesSample], n: usize) -> Option<&[SeriesSample]> {
    (recent.len() >= n).then(|| &recent[recent.len() - n..])
}

fn detect_stall(recent: &[SeriesSample]) -> Option<Alert> {
    let w = tail(recent, STALL_WINDOWS)?;
    let stalled = w
        .iter()
        .all(|s| s.outstanding > 0 && s.completed == 0 && s.worker_jobs.iter().sum::<u64>() == 0);
    if !stalled {
        return None;
    }
    let last = w.last().unwrap();
    let flat: Vec<String> = last
        .worker_jobs
        .iter()
        .enumerate()
        .filter(|(_, &j)| j == 0)
        .map(|(i, _)| i.to_string())
        .collect();
    let who = if flat.is_empty() { "all".to_string() } else { flat.join(",") };
    Some(Alert {
        detector: Detector::WorkerStall,
        reason: format!(
            "queue non-empty ({} outstanding) with no completions for {STALL_WINDOWS} \
             windows; flat worker heartbeats: [{who}]",
            last.outstanding
        ),
    })
}

fn detect_shed_spike(recent: &[SeriesSample]) -> Option<Alert> {
    let s = recent.last()?;
    let shed: u64 = s.shed.iter().sum();
    let submitted = s.admitted + shed;
    if submitted < SPIKE_MIN_EVENTS {
        return None;
    }
    let frac = shed as f64 / submitted as f64;
    (frac > SPIKE_SHED_FRAC).then(|| Alert {
        detector: Detector::ShedSpike,
        reason: format!(
            "{shed}/{submitted} submissions shed this window ({:.0}% > {:.0}% threshold)",
            frac * 100.0,
            SPIKE_SHED_FRAC * 100.0
        ),
    })
}

fn detect_util_collapse(recent: &[SeriesSample]) -> Option<Alert> {
    let w = tail(recent, COLLAPSE_WINDOWS)?;
    let collapsed = w.iter().all(|s| {
        s.peak_gflops > 0.0
            && s.outstanding >= COLLAPSE_MIN_BACKLOG
            && s.completed > 0
            && s.achieved_gflops / s.peak_gflops < COLLAPSE_UTIL_FRAC
    });
    collapsed.then(|| {
        let last = w.last().unwrap();
        Alert {
            detector: Detector::UtilCollapse,
            reason: format!(
                "achieved {:.2} GFLOP/s is {:.2}% of the {:.0} GFLOP/s roofline for \
                 {COLLAPSE_WINDOWS} windows under sustained backlog",
                last.achieved_gflops,
                100.0 * last.achieved_gflops / last.peak_gflops,
                last.peak_gflops
            ),
        }
    })
}

fn detect_slo_burn(recent: &[SeriesSample], slo_p99_ms: f64) -> Option<Alert> {
    let w = tail(recent, BURN_WINDOWS)?;
    let burning = w.iter().all(|s| s.completed > 0 && s.percentile(99.0) > slo_p99_ms);
    burning.then(|| Alert {
        detector: Detector::SloBurn,
        reason: format!(
            "window p99 {:.2} ms over the {slo_p99_ms} ms target for {BURN_WINDOWS} windows",
            w.last().unwrap().percentile(99.0)
        ),
    })
}

// ---------------------------------------------------------------------------
// Health state (what /healthz serves)
// ---------------------------------------------------------------------------

/// Point-in-time health report: healthy/degraded plus per-detector
/// firing totals.
#[derive(Clone, Debug, PartialEq)]
pub struct HealthReport {
    /// `false` while any detector is active.
    pub healthy: bool,
    /// Diagnosis of the active detectors, `"ok"` when healthy.
    pub reason: String,
    /// Total windows each detector was active for, by
    /// [`Detector::as_str`] order.
    pub alerts_by_detector: [u64; 4],
}

impl HealthReport {
    /// Total detector-active windows across all detectors.
    pub fn alerts_total(&self) -> u64 {
        self.alerts_by_detector.iter().sum()
    }

    /// The `/healthz` JSON body.
    pub fn to_json(&self) -> String {
        let status = if self.healthy { "ok" } else { "degraded" };
        let mut o = format!("{{\"status\":\"{status}\",\"reason\":\"");
        for ch in self.reason.chars() {
            match ch {
                '"' => o.push_str("\\\""),
                '\\' => o.push_str("\\\\"),
                c if (c as u32) < 0x20 => o.push(' '),
                c => o.push(c),
            }
        }
        o.push_str("\",\"alerts\":{");
        for (i, d) in DETECTORS.iter().enumerate() {
            if i > 0 {
                o.push(',');
            }
            o.push_str(&format!("\"{}\":{}", d.as_str(), self.alerts_by_detector[i]));
        }
        o.push_str("}}");
        o
    }
}

#[derive(Debug, Default)]
struct HealthInner {
    active: [bool; 4],
    reason: String,
    totals: [u64; 4],
}

/// Shared health state: the watchdog writes it after every window, the
/// ingress `/healthz` handler and the Prometheus exposition read it.
#[derive(Debug, Default)]
pub struct Health {
    inner: Mutex<HealthInner>,
}

impl Health {
    pub fn new() -> Self {
        Health::default()
    }

    /// Fold one window's detector verdicts in. Returns only the
    /// **newly fired** alerts (inactive → active edges) — the caller's
    /// cue to log and dump a flight bundle; conditions that merely stay
    /// active return nothing. A window with no alerts restores health.
    pub fn observe(&self, alerts: &[Alert]) -> Vec<Alert> {
        let mut i = self.inner.lock().unwrap();
        let mut now = [false; 4];
        let mut edges = Vec::new();
        for a in alerts {
            let d = a.detector as usize;
            now[d] = true;
            i.totals[d] += 1;
            if !i.active[d] {
                edges.push(a.clone());
            }
        }
        i.active = now;
        i.reason = if alerts.is_empty() {
            String::new()
        } else {
            alerts
                .iter()
                .map(|a| format!("{}: {}", a.detector.as_str(), a.reason))
                .collect::<Vec<_>>()
                .join("; ")
        };
        edges
    }

    /// Current health, for `/healthz` and the exposition.
    pub fn report(&self) -> HealthReport {
        let i = self.inner.lock().unwrap();
        let healthy = !i.active.iter().any(|&a| a);
        HealthReport {
            healthy,
            reason: if healthy { "ok".to_string() } else { i.reason.clone() },
            alerts_by_detector: i.totals,
        }
    }
}

// ---------------------------------------------------------------------------
// Flight recorder
// ---------------------------------------------------------------------------

/// Bundles a dump may write before the recorder refuses (disk bound;
/// an alert storm must not fill the volume).
pub const MAX_BUNDLES: u64 = 8;

/// Dumps a timestamped evidence bundle on watchdog firing edges:
/// `trace.json` (the PR 8 span-ring export), `series.json` (recent
/// windows, [`crate::obs::timeseries::render_series_json`]) and
/// `snapshot.json` (the full cumulative `MetricsSnapshot`), under
/// `<dir>/flight-<unix-seconds>-<seq>-<detector>/`.
#[derive(Debug)]
pub struct FlightRecorder {
    dir: PathBuf,
    seq: AtomicU64,
}

impl FlightRecorder {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        FlightRecorder { dir: dir.into(), seq: AtomicU64::new(0) }
    }

    /// The configured bundle directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Bundles written so far.
    pub fn bundles(&self) -> u64 {
        self.seq.load(Ordering::Acquire)
    }

    /// Write one bundle tagged `tag` (the firing detector's label).
    /// Returns the bundle directory, or an error string (including
    /// when the [`MAX_BUNDLES`] bound is reached).
    pub fn dump(
        &self,
        tag: &str,
        series_json: &str,
        snapshot_json: &str,
    ) -> Result<PathBuf, String> {
        let seq = self.seq.fetch_add(1, Ordering::AcqRel);
        if seq >= MAX_BUNDLES {
            return Err(format!("flight recorder bundle limit ({MAX_BUNDLES}) reached"));
        }
        let stamp = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let bundle = self.dir.join(format!("flight-{stamp}-{seq}-{tag}"));
        std::fs::create_dir_all(&bundle).map_err(|e| format!("creating {bundle:?}: {e}"))?;
        let write = |name: &str, body: &str| {
            std::fs::write(bundle.join(name), body)
                .map_err(|e| format!("writing {name} in {bundle:?}: {e}"))
        };
        write("trace.json", &super::trace::export_chrome_json())?;
        write("series.json", series_json)?;
        write("snapshot.json", snapshot_json)?;
        Ok(bundle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn window(outstanding: u64, completed: u64, jobs: &[u64]) -> SeriesSample {
        SeriesSample {
            at_s: 1.0,
            window_s: 1.0,
            admitted: completed,
            completed,
            outstanding,
            worker_jobs: jobs.to_vec(),
            worker_busy: vec![0.0; jobs.len()],
            ..SeriesSample::default()
        }
    }

    #[test]
    fn stall_fires_only_after_n_flat_windows_with_backlog() {
        let stalled = window(4, 0, &[0, 0]);
        let busy = window(4, 3, &[2, 1]);
        // two windows: not yet
        assert!(detect(&[stalled.clone(), stalled.clone()], None).is_empty());
        // three stalled windows: fires and names the flat workers
        let run = [stalled.clone(), stalled.clone(), stalled.clone()];
        let alerts = detect(&run, None);
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].detector, Detector::WorkerStall);
        assert!(alerts[0].reason.contains("[0,1]"), "{}", alerts[0].reason);
        // a completion in the middle breaks the run
        assert!(detect(&[stalled.clone(), busy, stalled], None).is_empty());
        // idle server (no backlog): never a stall
        let idle = window(0, 0, &[0]);
        assert!(detect(&[idle.clone(), idle.clone(), idle], None).is_empty());
    }

    #[test]
    fn shed_spike_needs_volume_and_fraction() {
        let mut spike = window(0, 10, &[10]);
        spike.shed = [20, 0, 0, 0];
        let alerts = detect(&[spike.clone()], None);
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].detector, Detector::ShedSpike);

        // same fraction, tiny volume: quiet
        let mut tiny = window(0, 2, &[2]);
        tiny.shed = [4, 0, 0, 0];
        assert!(detect(&[tiny], None).is_empty());

        // high volume, low fraction: quiet
        let mut healthy = window(0, 100, &[100]);
        healthy.shed = [5, 0, 0, 0];
        assert!(detect(&[healthy], None).is_empty());
    }

    #[test]
    fn util_collapse_requires_sustained_backlog_and_a_declared_peak() {
        let mut collapsed = window(COLLAPSE_MIN_BACKLOG, 5, &[5]);
        collapsed.peak_gflops = 100.0;
        collapsed.achieved_gflops = 0.5; // 0.5% of peak
        let run = [collapsed.clone(), collapsed.clone(), collapsed.clone()];
        let alerts = detect(&run, None);
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].detector, Detector::UtilCollapse);

        // no declared peak: detector stays quiet
        let mut no_peak = collapsed.clone();
        no_peak.peak_gflops = 0.0;
        assert!(detect(&[no_peak.clone(), no_peak.clone(), no_peak], None).is_empty());

        // healthy utilization: quiet
        let mut healthy = collapsed.clone();
        healthy.achieved_gflops = 50.0;
        assert!(detect(&[healthy.clone(), healthy.clone(), healthy], None).is_empty());

        // no backlog (a drained queue is allowed to idle): quiet
        let mut idle = collapsed;
        idle.outstanding = 0;
        assert!(detect(&[idle.clone(), idle.clone(), idle], None).is_empty());
    }

    #[test]
    fn slo_burn_needs_a_target_and_sustained_overrun() {
        let mut slow = window(0, 10, &[10]);
        // all completions in the ~100ms bucket
        slow.hist = vec![(crate::obs::hist::Histogram::bucket_index(100.0) as u32, 10)];
        // unarmed: quiet no matter what
        let run = [slow.clone(), slow.clone(), slow.clone()];
        assert!(detect(&run, None).is_empty());
        // armed with a 10ms target: fires after 3 windows
        let alerts = detect(&run, Some(10.0));
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].detector, Detector::SloBurn);
        // a generous target stays quiet
        assert!(detect(&run, Some(10_000.0)).is_empty());
    }

    #[test]
    fn health_edges_fire_once_and_recover() {
        let h = Health::new();
        assert!(h.report().healthy);
        let stall =
            Alert { detector: Detector::WorkerStall, reason: "jam".to_string() };
        // first observation: an edge
        let edges = h.observe(std::slice::from_ref(&stall));
        assert_eq!(edges.len(), 1);
        let r = h.report();
        assert!(!r.healthy);
        assert!(r.reason.contains("worker_stall"), "{}", r.reason);
        // still active: no new edge, totals keep counting
        assert!(h.observe(std::slice::from_ref(&stall)).is_empty());
        assert_eq!(h.report().alerts_by_detector[0], 2);
        // clean window: recovered
        h.observe(&[]);
        let r = h.report();
        assert!(r.healthy);
        assert_eq!(r.reason, "ok");
        assert_eq!(r.alerts_total(), 2, "totals survive recovery");
        // refiring after recovery is an edge again
        assert_eq!(h.observe(&[stall]).len(), 1);
        // healthz JSON shape
        let json = h.report().to_json();
        assert!(json.contains("\"status\":\"degraded\""), "{json}");
        assert!(json.contains("\"worker_stall\":3"), "{json}");
    }

    #[test]
    fn flight_recorder_writes_bundles_and_respects_the_limit() {
        let dir = std::env::temp_dir().join(format!("bb_flight_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let fr = FlightRecorder::new(&dir);
        let series = crate::obs::timeseries::render_series_json(&[]);
        let bundle = fr.dump("worker_stall", &series, "{\"schema\":1}").unwrap();
        assert!(bundle.join("trace.json").is_file());
        assert!(bundle.join("series.json").is_file());
        assert!(bundle.join("snapshot.json").is_file());
        assert_eq!(fr.bundles(), 1);
        // the bound: dumps past MAX_BUNDLES are refused
        for _ in 1..MAX_BUNDLES {
            fr.dump("shed_spike", &series, "{}").unwrap();
        }
        assert!(fr.dump("shed_spike", &series, "{}").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
