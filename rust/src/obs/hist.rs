//! Fixed-boundary log-bucket latency histograms.
//!
//! Every histogram in the process shares one compile-time bucket
//! layout: bucket 0 catches everything below [`MIN_MS`] (1 µs), the
//! last bucket catches everything at or above the top boundary, and
//! the 126 buckets between them grow geometrically at
//! [`SUB_BUCKETS`] = 4 buckets per octave (ratio 2^(1/4) ≈ 1.19), so
//! the layout spans 1 µs … ~70 min of latency. Because the boundaries
//! are fixed, two histograms **merge exactly**: `merge` is a plain
//! elementwise add, associative and commutative, and a merged
//! histogram is bit-identical to the histogram of the concatenated
//! samples. That is what lets `MetricsSnapshot` report one set of
//! percentiles across workers (and per sequence bucket) with no
//! sampling noise — unlike the retired [`Reservoir`], identical runs
//! produce identical percentiles.
//!
//! [`percentile`] uses the nearest-rank convention
//! (`rank = ceil(p/100 · count)`) and reports the geometric midpoint
//! of the bucket holding that rank, so the reported value is within a
//! factor of 2^(1/8) ≈ 1.09 of the exact order statistic (the
//! property test in `tests/metrics_properties.rs` pins this bound
//! against a sorted-vector oracle).
//!
//! [`Reservoir`]: crate::util::stats::Reservoir
//! [`percentile`]: Histogram::percentile

/// Total number of buckets (including the two open-ended end buckets).
pub const BUCKETS: usize = 128;

/// Buckets per octave (power of two) of latency.
pub const SUB_BUCKETS: usize = 4;

/// Lower boundary of the geometric range, in milliseconds (1 µs).
pub const MIN_MS: f64 = 1e-3;

/// A latency histogram over the shared fixed bucket layout, plus an
/// exact running sum/count for the mean.
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Histogram { counts: [0; BUCKETS], count: 0, sum: 0.0 }
    }

    /// Bucket index for a value in ms. Negative and sub-µs values land
    /// in bucket 0; values past the top boundary land in the last
    /// bucket. Non-finite values are the caller's problem (record
    /// ignores them).
    pub fn bucket_index(ms: f64) -> usize {
        if ms < MIN_MS {
            return 0;
        }
        let octaves = (ms / MIN_MS).log2();
        let idx = 1 + (octaves * SUB_BUCKETS as f64).floor() as usize;
        idx.min(BUCKETS - 1)
    }

    /// `(lower, upper)` boundary of bucket `i` in ms. Bucket 0 is
    /// `[0, MIN_MS)`; the last bucket's upper bound is `f64::INFINITY`.
    pub fn bucket_bounds(i: usize) -> (f64, f64) {
        assert!(i < BUCKETS, "bucket index {i} out of range");
        let edge = |k: usize| MIN_MS * 2f64.powf(k as f64 / SUB_BUCKETS as f64);
        if i == 0 {
            (0.0, MIN_MS)
        } else if i == BUCKETS - 1 {
            (edge(i - 1), f64::INFINITY)
        } else {
            (edge(i - 1), edge(i))
        }
    }

    /// Deterministic representative value of bucket `i`: the geometric
    /// midpoint of its boundaries (arithmetic midpoint for bucket 0,
    /// lower bound for the open-ended last bucket).
    pub fn bucket_value(i: usize) -> f64 {
        let (lo, hi) = Self::bucket_bounds(i);
        if i == 0 {
            hi / 2.0
        } else if i == BUCKETS - 1 {
            lo
        } else {
            (lo * hi).sqrt()
        }
    }

    /// Record one sample in ms. Non-finite samples are ignored;
    /// negative samples clamp to 0.
    pub fn record(&mut self, ms: f64) {
        if !ms.is_finite() {
            return;
        }
        let ms = ms.max(0.0);
        self.counts[Self::bucket_index(ms)] += 1;
        self.count += 1;
        self.sum += ms;
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of recorded samples (ms).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean of recorded samples (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Nearest-rank percentile (`0 < p <= 100`): the representative
    /// value of the bucket containing the `ceil(p/100 · count)`-th
    /// smallest sample. Returns 0.0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().clamp(1.0, self.count as f64) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_value(i);
            }
        }
        Self::bucket_value(BUCKETS - 1)
    }

    /// Rebuild a histogram from raw per-bucket counts — e.g. the
    /// elementwise difference of two cumulative [`Histogram::counts`]
    /// snapshots, which is how the time-series sampler computes exact
    /// per-window percentiles. The count is exact; the sum is
    /// reconstructed from bucket representative values, so
    /// [`Histogram::mean`] is approximate (within the same 2^(1/8)
    /// bucket-resolution factor as [`Histogram::percentile`]).
    pub fn from_counts(counts: [u64; BUCKETS]) -> Histogram {
        let count = counts.iter().sum();
        let sum = counts
            .iter()
            .enumerate()
            .map(|(i, &c)| c as f64 * Self::bucket_value(i))
            .sum();
        Histogram { counts, count, sum }
    }

    /// Fold `other` into `self`. Exact: the result equals the
    /// histogram of the concatenated sample streams.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    /// Raw per-bucket counts (index with [`Histogram::bucket_bounds`]).
    pub fn counts(&self) -> &[u64; BUCKETS] {
        &self.counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_layout_is_monotonic_and_covering() {
        let mut prev_hi = 0.0;
        for i in 0..BUCKETS {
            let (lo, hi) = Histogram::bucket_bounds(i);
            assert!(lo >= prev_hi - 1e-12, "bucket {i} overlaps its predecessor");
            assert!(hi > lo, "bucket {i} is empty");
            prev_hi = lo.max(prev_hi);
        }
        // every boundary value indexes into the bucket it opens
        for i in 1..BUCKETS - 1 {
            let (lo, hi) = Histogram::bucket_bounds(i);
            assert_eq!(Histogram::bucket_index((lo * hi).sqrt()), i);
        }
        assert_eq!(Histogram::bucket_index(0.0), 0);
        assert_eq!(Histogram::bucket_index(-5.0), 0);
        assert_eq!(Histogram::bucket_index(f64::MAX), BUCKETS - 1);
    }

    #[test]
    fn percentile_is_deterministic_and_ordered() {
        let mut h = Histogram::new();
        for i in 0..1000 {
            h.record(i as f64 * 0.1);
        }
        let (p50, p95, p99) = (h.percentile(50.0), h.percentile(95.0), h.percentile(99.0));
        assert!(p50 <= p95 && p95 <= p99);
        // identical stream in reverse order: identical percentiles
        let mut r = Histogram::new();
        for i in (0..1000).rev() {
            r.record(i as f64 * 0.1);
        }
        assert_eq!(h, r);
        assert_eq!(r.percentile(50.0), p50);
    }

    #[test]
    fn merge_matches_concatenation() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut all = Histogram::new();
        for i in 0..500 {
            let v = (i as f64 * 7.3) % 250.0;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a, all);
    }

    #[test]
    fn from_counts_preserves_percentiles_of_a_window_delta() {
        // cumulative "before" and "after" snapshots of one stream
        let mut before = Histogram::new();
        let mut after = Histogram::new();
        for i in 0..300 {
            let v = (i as f64 * 3.7) % 90.0;
            before.record(v);
            after.record(v);
        }
        let mut window_oracle = Histogram::new();
        for i in 0..150 {
            let v = 5.0 + (i as f64 * 1.3) % 40.0;
            after.record(v);
            window_oracle.record(v);
        }
        let mut delta = [0u64; BUCKETS];
        for (d, (a, b)) in delta.iter_mut().zip(after.counts().iter().zip(before.counts())) {
            *d = a - b;
        }
        let window = Histogram::from_counts(delta);
        assert_eq!(window.count(), window_oracle.count());
        assert_eq!(window.counts(), window_oracle.counts());
        for p in [50.0, 95.0, 99.0] {
            assert_eq!(window.percentile(p), window_oracle.percentile(p), "p{p}");
        }
    }

    #[test]
    fn mean_is_exact_and_hostile_inputs_ignored() {
        let mut h = Histogram::new();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 0);
        h.record(2.0);
        h.record(4.0);
        h.record(-1.0); // clamps to 0
        assert_eq!(h.count(), 3);
        assert!((h.mean() - 2.0).abs() < 1e-12);
    }
}
