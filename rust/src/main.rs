//! BigBird leader binary: CLI entrypoint for serving, training, and
//! experiment reproduction.

use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match bigbird::cli::run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
