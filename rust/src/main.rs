//! BigBird leader binary: CLI entrypoint for serving, training, and
//! experiment reproduction.

use std::process::ExitCode;

use bigbird::obs::log::Level;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match bigbird::cli::run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            // the one fatal exit goes through the same facade as every
            // other line (rate limits don't matter for a single line,
            // the BB_LOG format and stderr stream do)
            bigbird::log!(Level::Error, "cli", "{e:#}");
            ExitCode::FAILURE
        }
    }
}
