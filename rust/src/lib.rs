//! # BigBird: Transformers for Longer Sequences — full-system reproduction
//!
//! This crate is the Layer-3 (coordinator) of a three-layer Rust + JAX +
//! Pallas stack reproducing Zaheer et al., *Big Bird: Transformers for
//! Longer Sequences* (NeurIPS 2020):
//!
//! * **Layer 1** — a Pallas block-sparse attention kernel
//!   (`python/compile/kernels/bigbird.py`) implementing the paper's
//!   blockified random + window + global attention (App. D).
//! * **Layer 2** — a JAX BigBird transformer (encoder, heads, seq2seq,
//!   Adam train step) lowered once to HLO text (`python/compile/aot.py`).
//! * **Layer 3** — this crate: a long-document serving and training
//!   coordinator that loads the AOT artifacts through PJRT (`xla` crate)
//!   and never touches Python on the request path.
//!
//! Since PR 3 the crate also carries a **native kernel subsystem**
//! (`kernel`): BigBird block-sparse attention computed in pure Rust —
//! block-CSR layout, streaming-softmax sparse kernel, threaded
//! multi-head driver, and a deterministic MLM forward pass — registered
//! as the `native` serving backend, so the coordinator serves real
//! forward passes with zero PJRT artifacts present.
//!
//! The crate additionally contains every substrate the paper depends on,
//! built from scratch: a BPE tokenizer, synthetic text / genome corpora,
//! random-graph theory tooling (Erdős–Rényi, Watts–Strogatz, the BigBird
//! attention graph), evaluation metrics (ROUGE, F1, AUC, bits-per-char),
//! and the experiment harnesses that regenerate every table and figure of
//! the paper's evaluation section (see `experiments`).

pub mod attention;
pub mod bench_check;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod graph;
pub mod kernel;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod tokenizer;
pub mod train;
pub mod util;

pub use config::ModelConfig;
