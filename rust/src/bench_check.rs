//! `bigbird bench-check` — the CI perf-regression gate.
//!
//! Compares the bench JSONs CI just produced (`BENCH_attention.json`
//! from `benches/attention_scaling.rs`, `BENCH_train.json` from
//! `benches/train_step.rs`) against **committed baselines**
//! (`rust/bench_baselines.json`) with a generous noise tolerance, so a
//! perf regression fails the smoke job instead of silently eroding the
//! trajectory the artifacts record. Three modes of output:
//!
//! * the gate itself: any gated metric worse than its baseline by more
//!   than the tolerance is an error listing every offender;
//! * `--summary <path>` appends a markdown report (the per-seq-len
//!   attention table, the train-step split, and the delta-vs-baseline
//!   table) — pointed at `$GITHUB_STEP_SUMMARY` in CI so perf is
//!   visible on every PR without downloading artifacts;
//! * `--update-baselines` rewrites the baselines file from the current
//!   JSONs (run the two benches locally, then commit the result — see
//!   rust/README.md "Refreshing the perf baselines").
//!
//! Both inputs and the baselines file are `util::BenchReport` JSON and
//! must carry the current `schema_version`; stale or foreign files are
//! rejected, and a baseline key missing from the fresh reports fails
//! the gate (it means the baselines no longer match the benches).

use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::BenchReport;

/// Tolerance used when the baselines file does not carry one: shared CI
/// runners are noisy, so the gate only fires on a >25% regression.
pub const DEFAULT_TOLERANCE: f64 = 0.25;

/// Reserved baselines key holding the relative noise tolerance.
const TOLERANCE_KEY: &str = "bench_check_tolerance";

/// The metrics the gate tracks — the absolute per-measurement
/// latencies only. Everything else stays informational (rendered in
/// the summary, never gated): scaling exponents and losses are too
/// noisy for the 25% tolerance, the fwd/bwd/opt split entries are
/// small slices of an already-gated step, tokens/sec keys are exact
/// reciprocals of gated latencies (the latency gate always fires
/// first), and the sparse-vs-dense speedup ratio would fail the gate
/// when the *dense reference* gets faster — a regression test must
/// never punish an improvement.
const GATED_KEYS: &[&str] = &[
    "attn_native_dense_n2048_ms",
    "attn_native_sparse_n256_ms",
    "attn_native_sparse_n512_ms",
    "attn_native_sparse_n1024_ms",
    "attn_native_sparse_n2048_ms",
    "train_native_step_ms",
];

/// Sequence lengths rendered in the attention summary table (must match
/// `benches/attention_scaling.rs::NATIVE_LENGTHS`).
const SUMMARY_LENGTHS: [usize; 4] = [256, 512, 1024, 2048];

/// Inputs of one `bench-check` run (wired from CLI flags).
#[derive(Debug)]
pub struct BenchCheck<'a> {
    /// Path of the attention-scaling bench JSON.
    pub attention: &'a str,
    /// Path of the train-step bench JSON.
    pub train: &'a str,
    /// Path of the pattern-ablation bench JSON (`experiment ablate`);
    /// a missing file skips the section silently — pattern metrics are
    /// informational and never gated.
    pub patterns: &'a str,
    /// Path of the committed baselines file.
    pub baselines: &'a str,
    /// Rewrite the baselines from the current JSONs instead of gating.
    pub update: bool,
    /// Append the markdown report to this path (`$GITHUB_STEP_SUMMARY`).
    pub summary: Option<&'a str>,
}

/// In which direction is a bigger value worse?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Direction {
    LowerIsBetter,
    HigherIsBetter,
}

/// Gating direction of a metric key, by naming convention: `*_ms` are
/// latencies (lower is better), `*_tokens_per_sec` throughputs (higher
/// is better). Ratios like `*_speedup_*` deliberately have no
/// direction: gating dense/sparse would fail on a dense-only
/// improvement.
fn direction(key: &str) -> Option<Direction> {
    if key.ends_with("_ms") {
        Some(Direction::LowerIsBetter)
    } else if key.ends_with("_tokens_per_sec") {
        Some(Direction::HigherIsBetter)
    } else {
        None
    }
}

/// Relative regression of `current` vs `baseline` (> 0 means worse).
fn regression(dir: Direction, baseline: f64, current: f64) -> f64 {
    match dir {
        Direction::LowerIsBetter => (current - baseline) / baseline,
        Direction::HigherIsBetter => (baseline - current) / baseline,
    }
}

fn load_report(path: &str) -> Result<BenchReport> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading bench JSON {path} (run the benches first)"))?;
    BenchReport::parse(&text).map_err(|e| anyhow!("{path}: {e}"))
}

/// Entry point: gate (default) or refresh (`--update-baselines`).
pub fn run(cfg: &BenchCheck<'_>) -> Result<()> {
    let attn = load_report(cfg.attention)?;
    let train = load_report(cfg.train)?;
    // optional + informational: the pattern ablation only runs in some
    // CI jobs, so absence is normal; a present-but-broken file is still
    // a loud error (silent misparses defeat the point of a report)
    let patterns = match std::fs::read_to_string(cfg.patterns) {
        Err(_) => None,
        Ok(text) => {
            Some(BenchReport::parse(&text).map_err(|e| anyhow!("{}: {e}", cfg.patterns))?)
        }
    };
    let mut merged = BenchReport::new();
    for (k, v) in attn.entries().iter().chain(train.entries()) {
        merged.push(k, *v);
    }
    if cfg.update {
        if cfg.summary.is_some() {
            eprintln!("note: --summary is ignored with --update-baselines (no gate ran)");
        }
        return update_baselines(cfg, &merged);
    }
    let base_text = std::fs::read_to_string(cfg.baselines).with_context(|| {
        format!(
            "reading perf baselines {} (seed them with `bench-check --update-baselines`)",
            cfg.baselines
        )
    })?;
    let base = BenchReport::parse(&base_text).map_err(|e| anyhow!("{}: {e}", cfg.baselines))?;
    let tol = base.get(TOLERANCE_KEY).unwrap_or(DEFAULT_TOLERANCE);
    if !(tol.is_finite() && tol > 0.0) {
        bail!("{}: {TOLERANCE_KEY} must be a positive number, got {tol}", cfg.baselines);
    }

    let mut rows = Vec::new();
    let mut failures = Vec::new();
    for (key, baseline) in base.entries() {
        let (key, baseline) = (key.as_str(), *baseline);
        if key == TOLERANCE_KEY {
            continue;
        }
        let Some(dir) = direction(key) else {
            continue; // informational baseline entry: nothing to gate
        };
        if !(baseline.is_finite() && baseline > 0.0) {
            bail!("{}: baseline for {key} must be positive, got {baseline}", cfg.baselines);
        }
        let Some(current) = merged.get(key) else {
            failures.push(format!(
                "{key}: present in baselines but missing from the bench JSONs (stale \
                 baselines? refresh with --update-baselines)"
            ));
            continue;
        };
        if !current.is_finite() {
            // fail closed: `NaN > tol` is false, so a NaN metric would
            // otherwise sail through the gate as "ok"
            failures.push(format!("{key}: non-finite bench value {current}"));
            rows.push((key.to_string(), baseline, current, f64::NAN, "INVALID"));
            continue;
        }
        let reg = regression(dir, baseline, current);
        let status = if reg > tol { "REGRESSED" } else { "ok" };
        if reg > tol {
            failures.push(format!(
                "{key}: {current:.3} vs baseline {baseline:.3} ({:+.1}% worse, tolerance \
                 {:.0}%)",
                reg * 100.0,
                tol * 100.0
            ));
        }
        rows.push((key.to_string(), baseline, current, reg, status));
    }

    // console table
    println!("bench-check vs {} (tolerance {:.0}%):\n", cfg.baselines, tol * 100.0);
    println!("{:<42}{:>12}{:>12}{:>9}  {}", "metric", "baseline", "current", "delta", "status");
    for (key, baseline, current, reg, status) in &rows {
        println!("{key:<42}{baseline:>12.3}{current:>12.3}{:>8.1}%  {status}", reg * 100.0);
    }

    if let Some(path) = cfg.summary {
        let md = render_summary(&attn, &train, patterns.as_ref(), &rows, tol);
        append_to(path, &md).with_context(|| format!("appending step summary to {path}"))?;
        println!("\n(markdown summary appended to {path})");
    }

    if !failures.is_empty() {
        bail!("bench-check: {} perf regression(s):\n  {}", failures.len(), failures.join("\n  "));
    }
    println!("\nbench-check: all {} gated metrics within tolerance", rows.len());
    Ok(())
}

/// Rewrite the baselines file from the freshly produced bench JSONs.
fn update_baselines(cfg: &BenchCheck<'_>, merged: &BenchReport) -> Result<()> {
    // preserve a hand-tuned tolerance across refreshes; a present but
    // unreadable file must not silently reset it to the default
    let tol = match std::fs::read_to_string(cfg.baselines) {
        Err(_) => DEFAULT_TOLERANCE, // no existing baselines: fresh seed
        Ok(text) => match BenchReport::parse(&text) {
            Ok(b) => b.get(TOLERANCE_KEY).unwrap_or(DEFAULT_TOLERANCE),
            Err(e) => {
                eprintln!(
                    "warning: existing {} is unreadable ({e}); any hand-tuned \
                     {TOLERANCE_KEY} is lost — resetting to {DEFAULT_TOLERANCE}",
                    cfg.baselines
                );
                DEFAULT_TOLERANCE
            }
        },
    };
    let mut out = BenchReport::new();
    out.push(TOLERANCE_KEY, tol);
    for &key in GATED_KEYS {
        let v = merged.get(key).with_context(|| {
            format!("gated metric {key} missing from the bench JSONs; rerun both benches")
        })?;
        out.push(key, v);
    }
    out.write(cfg.baselines).with_context(|| format!("writing {}", cfg.baselines))?;
    println!(
        "baselines refreshed from {} + {} → {} ({} gated metrics, tolerance {:.0}%); \
         commit the file to land the new floor",
        cfg.attention,
        cfg.train,
        cfg.baselines,
        GATED_KEYS.len(),
        tol * 100.0
    );
    Ok(())
}

/// Markdown report for `$GITHUB_STEP_SUMMARY`: attention scaling table
/// (tokens/sec + sparse-vs-dense speedup per sequence length), the
/// train-step split, the per-precision tokens/sec ablation (f32 / f16 /
/// int8, informational — these keys are never gated), and the
/// delta-vs-baseline gate table.
fn render_summary(
    attn: &BenchReport,
    train: &BenchReport,
    patterns: Option<&BenchReport>,
    rows: &[(String, f64, f64, f64, &str)],
    tol: f64,
) -> String {
    let mut md = String::new();
    let _ = writeln!(md, "## Native kernel perf\n");
    let _ = writeln!(md, "### Attention scaling (block-sparse vs dense, 1 head)\n");
    let _ = writeln!(md, "| seq len | dense ms | sparse ms | sparse tokens/sec | speedup |");
    let _ = writeln!(md, "|--------:|---------:|----------:|------------------:|--------:|");
    for n in SUMMARY_LENGTHS {
        let dense = attn.get(&format!("attn_native_dense_n{n}_ms"));
        let sparse = attn.get(&format!("attn_native_sparse_n{n}_ms"));
        let (Some(dense), Some(sparse)) = (dense, sparse) else {
            continue;
        };
        // prefer the tokens/sec the bench itself emitted; recompute
        // from the latency only as a fallback
        let tps = attn
            .get(&format!("attn_native_sparse_n{n}_tokens_per_sec"))
            .unwrap_or_else(|| if sparse > 0.0 { n as f64 / (sparse / 1000.0) } else { 0.0 });
        let speedup = if sparse > 0.0 { dense / sparse } else { 0.0 };
        let _ = writeln!(md, "| {n} | {dense:.2} | {sparse:.2} | {tps:.0} | {speedup:.1}× |");
    }
    let _ = writeln!(md, "\n### Train step (native, tiny config)\n");
    let _ = writeln!(md, "| tokens/sec | step ms | fwd ms | bwd ms | opt ms |");
    let _ = writeln!(md, "|-----------:|--------:|-------:|-------:|-------:|");
    let cell = |k: &str| train.get(k).map_or_else(|| "—".to_string(), |v| format!("{v:.1}"));
    let _ = writeln!(
        md,
        "| {} | {} | {} | {} | {} |",
        cell("train_native_tokens_per_sec"),
        cell("train_native_step_ms"),
        cell("train_native_fwd_ms"),
        cell("train_native_bwd_ms"),
        cell("train_native_opt_ms")
    );
    // per-precision ablation column: emitted by both benches when the
    // quantized tiers ran; "—" on older JSONs that predate them
    let _ = writeln!(md, "\n### Precision ablation (tokens/sec, informational)\n");
    let _ = writeln!(md, "| workload | f32 | f16 | int8 |");
    let _ = writeln!(md, "|:---------|----:|----:|-----:|");
    let tps = |r: &BenchReport, k: &str| {
        r.get(k).map_or_else(|| "—".to_string(), |v| format!("{v:.0}"))
    };
    for n in SUMMARY_LENGTHS {
        let _ = writeln!(
            md,
            "| serve forward n={n} | {} | {} | {} |",
            tps(attn, &format!("model_native_f32_n{n}_tokens_per_sec")),
            tps(attn, &format!("model_native_f16_n{n}_tokens_per_sec")),
            tps(attn, &format!("model_native_int8_n{n}_tokens_per_sec"))
        );
    }
    let _ = writeln!(
        md,
        "| train step | {} | {} | {} |",
        tps(train, "train_native_f32_tokens_per_sec"),
        tps(train, "train_native_f16_tokens_per_sec"),
        tps(train, "train_native_int8_tokens_per_sec")
    );
    // pattern-selection ablation (`experiment ablate`): quality vs
    // throughput per PatternSource kind, informational — never gated
    if let Some(pat) = patterns {
        let _ = writeln!(md, "\n### Pattern ablation (informational)\n");
        let _ = writeln!(
            md,
            "| pattern | spectral gap | MLM loss | tok/s n=1024 | tok/s n=2048 | vs static (n=2048) |"
        );
        let _ = writeln!(
            md,
            "|:--------|-------------:|---------:|-------------:|-------------:|-------------------:|"
        );
        let static_tps = pat.get("pattern_static_n2048_tokens_per_sec");
        for kind in ["static", "adaptive", "learned"] {
            let cell = |k: String, prec: usize| {
                pat.get(&k).map_or_else(|| "—".to_string(), |v| format!("{v:.prec$}"))
            };
            let vs = match (static_tps, pat.get(&format!("pattern_{kind}_n2048_tokens_per_sec"))) {
                (Some(st), Some(v)) if st > 0.0 => format!("{:+.1}%", 100.0 * (v - st) / st),
                _ => "—".to_string(),
            };
            let _ = writeln!(
                md,
                "| {kind} | {} | {} | {} | {} | {vs} |",
                cell(format!("pattern_{kind}_spectral_gap"), 4),
                cell(format!("pattern_{kind}_loss"), 4),
                cell(format!("pattern_{kind}_n1024_tokens_per_sec"), 0),
                cell(format!("pattern_{kind}_n2048_tokens_per_sec"), 0),
            );
        }
    }
    let _ = writeln!(md, "\n### Gate vs committed baselines (tolerance {:.0}%)\n", tol * 100.0);
    let _ = writeln!(md, "| metric | baseline | current | Δ | status |");
    let _ = writeln!(md, "|:-------|---------:|--------:|--:|:-------|");
    for (key, baseline, current, reg, status) in rows {
        let mark = if *status == "ok" { "✅ ok" } else { "❌ regressed" };
        let delta = reg * 100.0;
        let _ = writeln!(md, "| `{key}` | {baseline:.2} | {current:.2} | {delta:+.1}% | {mark} |");
    }
    md
}

/// Append `text` to `path`, creating the file when absent (the step
/// summary file already exists in CI; locally it may not).
fn append_to(path: &str, text: &str) -> std::io::Result<()> {
    use std::io::Write as _;
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    f.write_all(text.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn directions_follow_key_naming() {
        assert_eq!(direction("attn_native_sparse_n2048_ms"), Some(Direction::LowerIsBetter));
        assert_eq!(direction("train_native_tokens_per_sec"), Some(Direction::HigherIsBetter));
        // ratios are deliberately ungated: dense/sparse would fail the
        // gate on a dense-only improvement
        assert_eq!(direction("attn_native_sparse_speedup_n2048"), None);
        assert_eq!(direction("attn_native_sparse_exponent"), None);
        assert_eq!(direction(TOLERANCE_KEY), None);
    }

    #[test]
    fn regression_is_signed_worseness() {
        // latency: higher is worse
        assert!(regression(Direction::LowerIsBetter, 100.0, 130.0) > 0.25);
        assert!(regression(Direction::LowerIsBetter, 100.0, 90.0) < 0.0);
        // throughput: lower is worse
        assert!(regression(Direction::HigherIsBetter, 1000.0, 700.0) > 0.25);
        assert!(regression(Direction::HigherIsBetter, 1000.0, 1200.0) < 0.0);
    }

    #[test]
    fn every_gated_key_has_a_direction() {
        for key in GATED_KEYS {
            assert!(direction(key).is_some(), "{key} would never be compared");
        }
    }

    #[test]
    fn committed_baselines_cover_every_gated_key() {
        // the gate iterates the *baselines* entries, so a GATED_KEYS
        // addition that skips the `--update-baselines` + commit step
        // would silently never be compared — pin the committed file
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/bench_baselines.json");
        let text = std::fs::read_to_string(path).expect("committed bench_baselines.json");
        let base = BenchReport::parse(&text).expect("baselines must parse at current schema");
        for key in GATED_KEYS {
            let v = base.get(key);
            assert!(v.is_some(), "{key} is gated but missing from bench_baselines.json");
            let v = v.unwrap();
            assert!(v.is_finite() && v > 0.0, "{key} baseline must be positive, got {v}");
        }
        let tol = base.get(TOLERANCE_KEY).unwrap_or(DEFAULT_TOLERANCE);
        assert!(tol.is_finite() && tol > 0.0, "committed tolerance must be positive");
    }

    #[test]
    fn gate_passes_and_fails_end_to_end() {
        // pid-suffixed so concurrent `cargo test` runs on one machine
        // (worktrees, parallel CI jobs) cannot race on the files
        let dir = std::env::temp_dir().join(format!("bb_bench_check_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = |name: &str| dir.join(name).display().to_string();
        // leftovers from a previous test run would defeat the
        // missing-baselines assertion below
        for stale in ["baselines.json", "summary.md"] {
            let _ = std::fs::remove_file(dir.join(stale));
        }

        // synthesize bench JSONs covering every gated key
        let mut attn = BenchReport::new();
        for n in SUMMARY_LENGTHS {
            attn.push(&format!("attn_native_dense_n{n}_ms"), 80.0);
            attn.push(&format!("attn_native_sparse_n{n}_ms"), 10.0);
        }
        attn.push("attn_native_sparse_n2048_tokens_per_sec", 204_800.0);
        attn.push("attn_native_sparse_speedup_n2048", 8.0);
        let mut train = BenchReport::new();
        train.push("train_native_tokens_per_sec", 2000.0);
        train.push("train_native_step_ms", 256.0);
        train.push("train_native_fwd_ms", 100.0);
        attn.write(&p("attn.json")).unwrap();
        train.write(&p("train.json")).unwrap();

        let attention = p("attn.json");
        let train_p = p("train.json");
        let patterns_p = p("patterns.json");
        let baselines = p("baselines.json");
        let summary = p("summary.md");
        let _ = std::fs::remove_file(&patterns_p);
        let mk = |update: bool| BenchCheck {
            attention: &attention,
            train: &train_p,
            patterns: &patterns_p,
            baselines: &baselines,
            update,
            summary: Some(&summary),
        };

        // no baselines yet: the gate must ask for them descriptively
        let err = run(&mk(false)).unwrap_err();
        assert!(format!("{err:#}").contains("update-baselines"), "{err:#}");

        // seed baselines from the current numbers, then the gate passes
        run(&mk(true)).unwrap();
        run(&mk(false)).unwrap();
        let md = std::fs::read_to_string(&summary).unwrap();
        assert!(md.contains("Gate vs committed baselines"), "{md}");
        assert!(md.contains("✅"), "{md}");
        // the precision column renders even when the synthesized JSONs
        // carry no per-precision keys (em-dash fallback)
        assert!(md.contains("Precision ablation"), "{md}");
        assert!(md.contains("| train step | —"), "{md}");
        // no patterns JSON: the section is skipped silently
        assert!(!md.contains("Pattern ablation"), "{md}");

        // with a patterns JSON present, the informational section
        // renders (and its keys are never gated: the rerun still passes)
        let mut pats = BenchReport::new();
        for kind in ["static", "adaptive", "learned"] {
            pats.push(&format!("pattern_{kind}_spectral_gap"), 0.18);
            pats.push(&format!("pattern_{kind}_loss"), 5.5);
            pats.push(&format!("pattern_{kind}_n1024_tokens_per_sec"), 50_000.0);
            pats.push(&format!("pattern_{kind}_n2048_tokens_per_sec"), 40_000.0);
        }
        pats.write(&patterns_p).unwrap();
        let _ = std::fs::remove_file(&summary);
        run(&mk(false)).unwrap();
        let md = std::fs::read_to_string(&summary).unwrap();
        assert!(md.contains("Pattern ablation"), "{md}");
        assert!(md.contains("| adaptive |"), "{md}");
        assert!(md.contains("+0.0%"), "vs-static column missing: {md}");
        std::fs::remove_file(&patterns_p).unwrap();

        // a >tolerance regression fails the gate and names the metric
        let mut slow = BenchReport::new();
        for n in SUMMARY_LENGTHS {
            slow.push(&format!("attn_native_dense_n{n}_ms"), 80.0);
            slow.push(&format!("attn_native_sparse_n{n}_ms"), 10.0);
        }
        slow.push("attn_native_sparse_n2048_tokens_per_sec", 204_800.0);
        slow.push("attn_native_sparse_speedup_n2048", 8.0);
        let slow_sparse = 10.0 * (1.0 + DEFAULT_TOLERANCE) * 1.5;
        // overwrite the 2048 latency with a clear regression
        let mut slow_attn = BenchReport::new();
        for (k, v) in slow.entries() {
            let v = if k == "attn_native_sparse_n2048_ms" { slow_sparse } else { *v };
            slow_attn.push(k, v);
        }
        slow_attn.write(&p("attn.json")).unwrap();
        let err = run(&mk(false)).unwrap_err();
        assert!(
            format!("{err:#}").contains("attn_native_sparse_n2048_ms"),
            "regression must be named: {err:#}"
        );

        // a stale baseline key (missing from fresh JSONs) also fails
        let mut stale = BenchReport::new();
        stale.push(TOLERANCE_KEY, DEFAULT_TOLERANCE);
        stale.push("attn_native_retired_metric_ms", 1.0);
        stale.write(&baselines).unwrap();
        let err = run(&mk(false)).unwrap_err();
        assert!(format!("{err:#}").contains("retired_metric"), "{err:#}");
    }
}
