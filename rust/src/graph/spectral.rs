//! Spectral gap of the normalized adjacency via power iteration —
//! quantifies the expander/rapid-mixing claim of Sec. 2 ("its second
//! eigenvalue is quite far from the first").

use super::generators::Graph;
use crate::util::Rng;

/// Returns `1 − λ₂` of the **lazy symmetric normalized adjacency**
/// `M = ½(I + D^{-1/2} A D^{-1/2})`.
///
/// `M` is symmetric with eigenvalues in [0, 1]; its top eigenvector is
/// `v₁ ∝ √deg`. We estimate λ₂ by power iteration deflated against v₁.
/// Larger gap ⇒ faster random-walk mixing ⇒ better "information flows
/// fast between any pair of nodes" in the attention graph.
pub fn spectral_gap(g: &Graph, iters: usize) -> f64 {
    let n = g.len();
    if n == 0 {
        return 0.0;
    }
    let deg: Vec<f64> = g.adjacency.iter().map(|nb| nb.len().max(1) as f64).collect();
    let sqrt_deg: Vec<f64> = deg.iter().map(|d| d.sqrt()).collect();
    // v1 = sqrt(deg) normalised
    let v1_norm = sqrt_deg.iter().map(|v| v * v).sum::<f64>().sqrt();
    let v1: Vec<f64> = sqrt_deg.iter().map(|v| v / v1_norm).collect();

    // seeded random start, deflated against v1
    let mut rng = Rng::new(0x5EC7);
    let mut x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    deflate(&mut x, &v1);
    normalize(&mut x);

    let mut lambda = 0.0;
    for _ in 0..iters {
        // y = M x with M = 1/2 (I + D^-1/2 A D^-1/2)
        let mut y = vec![0.0; n];
        for (u, nb) in g.adjacency.iter().enumerate() {
            for &v in nb {
                y[v] += x[u] / (sqrt_deg[u] * sqrt_deg[v]);
            }
        }
        for i in 0..n {
            y[i] = 0.5 * (x[i] + y[i]);
        }
        deflate(&mut y, &v1);
        lambda = norm(&y);
        if lambda <= 1e-15 {
            break;
        }
        for (xi, yi) in x.iter_mut().zip(&y) {
            *xi = yi / lambda;
        }
    }
    (1.0 - lambda).clamp(0.0, 1.0)
}

fn deflate(x: &mut [f64], v1: &[f64]) {
    let c: f64 = x.iter().zip(v1).map(|(a, b)| a * b).sum();
    for (xi, v) in x.iter_mut().zip(v1) {
        *xi -= c * v;
    }
}

fn norm(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

fn normalize(x: &mut [f64]) {
    let n = norm(x);
    if n > 0.0 {
        for v in x.iter_mut() {
            *v /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::PatternSpec;
    use crate::config::AttnVariant;
    use crate::graph::{bigbird_graph, erdos_renyi, watts_strogatz};
    use crate::util::Rng;

    #[test]
    fn complete_graph_has_large_gap() {
        let n = 32;
        let mut edges = Vec::new();
        for u in 0..n {
            for v in (u + 1)..n {
                edges.push((u, v));
            }
        }
        let g = Graph::from_edges(n, edges);
        let gap = spectral_gap(&g, 300);
        // complete graph: λ2 of N is -1/(n-1); lazy λ2 ≈ 0.484 ⇒ gap ≈ 0.516
        assert!(gap > 0.4, "complete graph gap {gap}");
    }

    #[test]
    fn cycle_has_tiny_gap() {
        let n = 64;
        let g = Graph::from_edges(n, (0..n).map(|i| (i, (i + 1) % n)));
        let gap = spectral_gap(&g, 2000);
        // λ2 of the cycle = cos(2π/n) ≈ 1 − 2π²/n² ⇒ lazy gap ≈ π²/n² ≈ 0.0024
        assert!(gap < 0.02, "cycle gap {gap} should be ~0");
    }

    #[test]
    fn er_expands_better_than_ring() {
        let mut rng = Rng::new(11);
        let n = 128;
        let er = erdos_renyi(n, 8.0 / n as f64, &mut rng);
        let ring = watts_strogatz(n, 8, 0.0, false, &mut Rng::new(1));
        let g_er = spectral_gap(&er, 800);
        let g_ring = spectral_gap(&ring, 800);
        assert!(
            g_er > 2.0 * g_ring,
            "ER gap {g_er} should dominate ring gap {g_ring}"
        );
    }

    #[test]
    fn bigbird_gap_is_healthy() {
        let spec = PatternSpec {
            variant: AttnVariant::BigBirdItc,
            nb: 128,
            global_blocks: 2,
            window_blocks: 3,
            random_blocks: 3,
            seed: 0,
        };
        let g = bigbird_graph(&spec);
        let gap = spectral_gap(&g, 800);
        // window-only for contrast
        let w_spec = PatternSpec { variant: AttnVariant::Window, ..spec };
        let gw = spectral_gap(&bigbird_graph(&w_spec), 800);
        assert!(gap > 2.0 * gw, "bigbird {gap} vs window {gw}");
    }
}
