//! Graph generators: Erdős–Rényi, Watts–Strogatz, and the BigBird
//! attention graph viewed as an undirected graph.

use crate::attention::PatternSpec;
use crate::util::Rng;

/// Simple undirected graph as adjacency lists (no self-loops, no dups).
#[derive(Clone, Debug)]
pub struct Graph {
    /// adjacency[u] = sorted neighbours of u
    pub adjacency: Vec<Vec<usize>>,
}

impl Graph {
    /// Build from an edge iterator, deduping and dropping self-loops.
    pub fn from_edges(n: usize, edges: impl IntoIterator<Item = (usize, usize)>) -> Self {
        let mut adjacency = vec![Vec::new(); n];
        for (u, v) in edges {
            if u == v || u >= n || v >= n {
                continue;
            }
            adjacency[u].push(v);
            adjacency[v].push(u);
        }
        for nb in &mut adjacency {
            nb.sort_unstable();
            nb.dedup();
        }
        Graph { adjacency }
    }

    /// Number of vertices.
    pub fn len(&self) -> usize {
        self.adjacency.len()
    }

    /// True when the graph has no vertices.
    pub fn is_empty(&self) -> bool {
        self.adjacency.is_empty()
    }

    /// Number of undirected edges.
    pub fn edge_count(&self) -> usize {
        self.adjacency.iter().map(|nb| nb.len()).sum::<usize>() / 2
    }

    /// Average degree.
    pub fn avg_degree(&self) -> f64 {
        if self.adjacency.is_empty() {
            return 0.0;
        }
        2.0 * self.edge_count() as f64 / self.len() as f64
    }
}

/// G(n, p): every edge independently with probability p (Sec. 2: random
/// graphs as spectral approximators of the complete graph).
pub fn erdos_renyi(n: usize, p: f64, rng: &mut Rng) -> Graph {
    let mut edges = Vec::new();
    for u in 0..n {
        for v in (u + 1)..n {
            if rng.coin(p) {
                edges.push((u, v));
            }
        }
    }
    Graph::from_edges(n, edges)
}

/// Watts–Strogatz: ring lattice with w neighbours (w/2 each side), then a
/// fraction `beta` of edges rewired to random targets. The paper keeps the
/// local edges ("deleting random edges might be inefficient on modern
/// hardware, so we retain it"), which we reproduce with `rewire=false`.
pub fn watts_strogatz(n: usize, w: usize, beta: f64, rewire: bool, rng: &mut Rng) -> Graph {
    let half = w / 2;
    let mut edges = Vec::new();
    for u in 0..n {
        for o in 1..=half {
            let v = (u + o) % n;
            if rng.coin(beta) {
                // add a random long-range edge (replacing or retaining the
                // lattice edge per the `rewire` flag)
                let mut t = rng.below(n);
                while t == u {
                    t = rng.below(n);
                }
                edges.push((u, t));
                if !rewire {
                    edges.push((u, v));
                }
            } else {
                edges.push((u, v));
            }
        }
    }
    Graph::from_edges(n, edges)
}

/// The BigBird attention pattern as an undirected graph over blocks.
pub fn bigbird_graph(spec: &PatternSpec) -> Graph {
    let attend = crate::attention::build_pattern(spec);
    let mut edges = Vec::new();
    for (u, row) in attend.iter().enumerate() {
        for &v in row {
            if u != v {
                edges.push((u, v));
            }
        }
    }
    Graph::from_edges(spec.nb, edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AttnVariant;

    #[test]
    fn from_edges_dedups_and_drops_self_loops() {
        let g = Graph::from_edges(4, [(0, 1), (1, 0), (2, 2), (1, 3)]);
        assert_eq!(g.edge_count(), 2);
        assert_eq!(g.adjacency[1], vec![0, 3]);
    }

    #[test]
    fn er_edge_count_near_expectation() {
        let mut rng = Rng::new(1);
        let n = 200;
        let p = 0.05;
        let g = erdos_renyi(n, p, &mut rng);
        let expect = p * (n * (n - 1) / 2) as f64;
        let got = g.edge_count() as f64;
        assert!((got - expect).abs() < 0.2 * expect, "{got} vs {expect}");
    }

    #[test]
    fn ws_degree_without_rewiring() {
        let mut rng = Rng::new(2);
        let g = watts_strogatz(50, 4, 0.0, false, &mut rng);
        // pure ring lattice: every node has exactly w neighbours
        for nb in &g.adjacency {
            assert_eq!(nb.len(), 4);
        }
    }

    #[test]
    fn bigbird_graph_connects_globals_to_all() {
        let spec = PatternSpec {
            variant: AttnVariant::BigBirdItc,
            nb: 16,
            global_blocks: 1,
            window_blocks: 3,
            random_blocks: 1,
            seed: 0,
        };
        let g = bigbird_graph(&spec);
        assert_eq!(g.adjacency[0].len(), 15); // global sees everyone
    }
}
