//! Graph-theory substrate backing Sec. 2's motivation: sparse random
//! graphs are expanders (short paths, spectral gap), small-world graphs
//! add locality (clustering), and the BigBird pattern combines both.

mod generators;
mod metrics;
mod spectral;

pub use generators::{bigbird_graph, erdos_renyi, watts_strogatz, Graph};
pub use metrics::{avg_shortest_path, clustering_coefficient, connected};
pub use spectral::spectral_gap;
