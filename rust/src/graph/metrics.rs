//! Graph metrics: BFS shortest paths, clustering coefficient,
//! connectivity — the quantities Sec. 2 argues about.

use std::collections::VecDeque;

use super::generators::Graph;

/// BFS distances from `src` (usize::MAX when unreachable).
fn bfs(g: &Graph, src: usize) -> Vec<usize> {
    let mut dist = vec![usize::MAX; g.len()];
    dist[src] = 0;
    let mut q = VecDeque::from([src]);
    while let Some(u) = q.pop_front() {
        for &v in &g.adjacency[u] {
            if dist[v] == usize::MAX {
                dist[v] = dist[u] + 1;
                q.push_back(v);
            }
        }
    }
    dist
}

/// Is the graph connected?
pub fn connected(g: &Graph) -> bool {
    if g.len() == 0 {
        return true;
    }
    bfs(g, 0).iter().all(|&d| d != usize::MAX)
}

/// Average shortest-path length over connected pairs (exact all-pairs
/// BFS — fine at our graph sizes).
pub fn avg_shortest_path(g: &Graph) -> f64 {
    let n = g.len();
    let mut total = 0usize;
    let mut pairs = 0usize;
    for u in 0..n {
        for (v, &d) in bfs(g, u).iter().enumerate() {
            if v != u && d != usize::MAX {
                total += d;
                pairs += 1;
            }
        }
    }
    if pairs == 0 {
        return f64::INFINITY;
    }
    total as f64 / pairs as f64
}

/// Global clustering coefficient: mean over vertices of
/// (closed triangles at v) / (pairs of neighbours of v).
pub fn clustering_coefficient(g: &Graph) -> f64 {
    let n = g.len();
    if n == 0 {
        return 0.0;
    }
    let mut total = 0.0;
    for u in 0..n {
        let nb = &g.adjacency[u];
        let k = nb.len();
        if k < 2 {
            continue;
        }
        let mut closed = 0usize;
        for i in 0..k {
            for j in (i + 1)..k {
                if g.adjacency[nb[i]].binary_search(&nb[j]).is_ok() {
                    closed += 1;
                }
            }
        }
        total += closed as f64 / (k * (k - 1) / 2) as f64;
    }
    total / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::PatternSpec;
    use crate::config::AttnVariant;
    use crate::graph::{bigbird_graph, erdos_renyi, watts_strogatz};
    use crate::util::Rng;

    #[test]
    fn path_length_of_path_graph() {
        let g = Graph::from_edges(4, [(0, 1), (1, 2), (2, 3)]);
        // pairs: (0,1)=1 (0,2)=2 (0,3)=3 (1,2)=1 (1,3)=2 (2,3)=1 → avg 10/6, doubled pairs same
        assert!((avg_shortest_path(&g) - 10.0 / 6.0).abs() < 1e-12);
        assert!(connected(&g));
    }

    #[test]
    fn triangle_has_clustering_one() {
        let g = Graph::from_edges(3, [(0, 1), (1, 2), (0, 2)]);
        assert!((clustering_coefficient(&g) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn star_has_clustering_zero() {
        let g = Graph::from_edges(5, [(0, 1), (0, 2), (0, 3), (0, 4)]);
        assert_eq!(clustering_coefficient(&g), 0.0);
    }

    #[test]
    fn disconnected_detected() {
        let g = Graph::from_edges(4, [(0, 1), (2, 3)]);
        assert!(!connected(&g));
    }

    // ---- Sec. 2 claims, verified quantitatively ----

    #[test]
    fn er_paths_are_logarithmic() {
        // Θ~(n) edges ⇒ path length ~ log n (paper cites [17, 43])
        let mut rng = Rng::new(7);
        let n = 256;
        let g = erdos_renyi(n, 8.0 / n as f64, &mut rng); // avg degree 8
        assert!(connected(&g), "ER sample disconnected; reseed");
        let l = avg_shortest_path(&g);
        let logn = (n as f64).ln();
        assert!(l < 1.2 * logn, "avg path {l} not O(log n)={logn}");
        // ...but ER has (near-)zero clustering
        assert!(clustering_coefficient(&g) < 0.15);
    }

    #[test]
    fn ws_has_high_clustering_and_short_paths() {
        let mut rng = Rng::new(9);
        let n = 256;
        let g = watts_strogatz(n, 8, 0.1, false, &mut rng);
        let c = clustering_coefficient(&g);
        assert!(c > 0.3, "WS clustering {c} too low");
        let l = avg_shortest_path(&g);
        assert!(l < 8.0, "WS avg path {l} too long for small-world");
    }

    #[test]
    fn bigbird_graph_combines_both() {
        let spec = PatternSpec {
            variant: AttnVariant::BigBirdItc,
            nb: 128,
            global_blocks: 2,
            window_blocks: 3,
            random_blocks: 3,
            seed: 3,
        };
        let g = bigbird_graph(&spec);
        assert!(connected(&g));
        // global tokens give everyone a ≤2-hop route
        let l = avg_shortest_path(&g);
        assert!(l <= 2.5, "bigbird avg path {l}");
        let c = clustering_coefficient(&g);
        assert!(c > 0.1, "bigbird clustering {c}");
    }
}
