//! SIMD-tiled microkernels: the one hot inner loop every block-level
//! attention computation routes through.
//!
//! The scalar kernels left most of each core's FLOPs on the table: a
//! per-element `dot(q_row, k_row)` walks K row-major, so the compiler
//! sees a chain of dependent reductions and emits scalar code. These
//! microkernels restructure each block tile the way flash-style kernels
//! do on accelerators, but phrased for the **autovectorizer** — no
//! `unsafe`, no intrinsics, just fixed-lane-width accumulator arrays
//! the compiler maps straight onto vector registers:
//!
//! * [`pack_transposed`] — transpose a K/V block once per tile so the
//!   GEMM inner loop reads **contiguous** lanes instead of striding by
//!   `head_dim` (an O(b·d) pack amortised over O(b²·d) compute);
//! * [`qk_tile`] — the QKᵀ tile GEMM: [`MR`]×[`LANES`] register
//!   blocks of `f32` accumulators held across the whole `d` loop, with
//!   **fused scale + key-validity masking** in the epilogue (masked
//!   columns become `−inf`, ready for the softmax) and explicit scalar
//!   remainder handling for rows % [`MR`] and cols % [`LANES`];
//! * [`av_tile`] — the tiled AV accumulate `acc += W · V`: the output
//!   row is processed in [`LANES`]-wide chunks that stay in registers
//!   across the whole key loop (the backward reuses it for the
//!   dQ/dK/dV gathers — dKᵀ/dVᵀ scatters become `av_tile` calls on a
//!   transposed weight tile);
//! * [`row_dots`] — lane-partial row-wise dot products (the backward's
//!   `δ = dO · O` rowsums).
//!
//! Within one output element every accumulation runs in the same
//! ascending-index order as the scalar reference, so results match the
//! retired scalar path to well under the kernel-parity tolerance
//! (`tests/microkernel_parity.rs` pins this across remainder shapes).
//! Per-tile scratch (the packed transpose, score/probability tiles)
//! lives in [`SparseScratch`](super::sparse::SparseScratch) and
//! [`AttnGradScratch`](super::grad::AttnGradScratch), which the
//! [`KernelPool`](super::driver::KernelPool) hoists into per-thread
//! arenas — steady state allocates nothing.

/// Fixed vector-lane width: 8 × f32 (one AVX register, two SSE/NEON
/// registers — wide enough to saturate either without spilling the
/// [`MR`]-row accumulator block).
pub const LANES: usize = 8;

/// Register-block height: rows of the output tile accumulated
/// simultaneously, so each packed [`LANES`]-wide load of the B operand
/// is reused [`MR`] times. `MR × LANES` f32 accumulators fit in 8 SSE
/// (4 AVX) registers, leaving room for the operand vectors.
pub const MR: usize = 4;

/// Transpose `src` (`rows × cols`, row-major) into `dst`
/// (`cols × rows`, row-major): `dst[c·rows + r] = src[r·cols + c]`.
/// Packing K/V blocks this way once per tile lets the GEMM inner loops
/// read contiguous lanes. Every element of `dst` is written.
pub fn pack_transposed(src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    debug_assert_eq!(src.len(), rows * cols, "src must be [rows, cols]");
    debug_assert_eq!(dst.len(), rows * cols, "dst must be [cols, rows]");
    for (r, row) in src.chunks_exact(cols).enumerate() {
        for (c, &v) in row.iter().enumerate() {
            dst[c * rows + r] = v;
        }
    }
}

/// The QKᵀ tile GEMM with fused scale + mask:
/// `out[i·cols + j] = scale · Σ_t a[i·d + t] · bt[t·cols + j]`, or
/// `−inf` where `valid[j] ≤ 0`.
///
/// `a` is `[rows, d]` row-major (Q rows, or dO rows in the backward);
/// `bt` is the **packed transpose** of the `[cols, d]` B operand (from
/// [`pack_transposed`]), so the inner loop broadcasts one `a` element
/// against a contiguous [`LANES`]-wide slice of `bt`. The main path
/// computes [`MR`]`×`[`LANES`] register blocks; row and column
/// remainders fall back to narrower loops, so any tile shape is
/// handled. Every element of `out` is written.
#[allow(clippy::too_many_arguments)]
pub fn qk_tile(
    a: &[f32],
    bt: &[f32],
    rows: usize,
    cols: usize,
    d: usize,
    scale: f32,
    valid: Option<&[f32]>,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), rows * d, "a must be [rows, d]");
    debug_assert_eq!(bt.len(), d * cols, "bt must be [d, cols] (packed transpose)");
    debug_assert_eq!(out.len(), rows * cols, "out must be [rows, cols]");
    if let Some(v) = valid {
        debug_assert_eq!(v.len(), cols, "valid must be [cols]");
    }
    let mut i = 0;
    while i + MR <= rows {
        let a_rows: [&[f32]; MR] = std::array::from_fn(|m| &a[(i + m) * d..(i + m + 1) * d]);
        let mut j = 0;
        while j + LANES <= cols {
            let mut acc = [[0.0f32; LANES]; MR];
            for t in 0..d {
                let bv: [f32; LANES] =
                    bt[t * cols + j..t * cols + j + LANES].try_into().expect("lane slice");
                let av: [f32; MR] = std::array::from_fn(|m| a_rows[m][t]);
                for (lanes, &am) in acc.iter_mut().zip(&av) {
                    for (l, &bb) in lanes.iter_mut().zip(&bv) {
                        *l += am * bb;
                    }
                }
            }
            for (m, lanes) in acc.iter().enumerate() {
                let o = &mut out[(i + m) * cols + j..(i + m) * cols + j + LANES];
                scale_mask_lanes(lanes, scale, valid, j, o);
            }
            j += LANES;
        }
        for jr in j..cols {
            for m in 0..MR {
                out[(i + m) * cols + jr] = scalar_entry(a_rows[m], bt, cols, jr, scale, valid);
            }
        }
        i += MR;
    }
    while i < rows {
        let a_row = &a[i * d..(i + 1) * d];
        let mut j = 0;
        while j + LANES <= cols {
            let mut lanes = [0.0f32; LANES];
            for (t, &am) in a_row.iter().enumerate() {
                let bv: [f32; LANES] =
                    bt[t * cols + j..t * cols + j + LANES].try_into().expect("lane slice");
                for (l, &bb) in lanes.iter_mut().zip(&bv) {
                    *l += am * bb;
                }
            }
            let o = &mut out[i * cols + j..i * cols + j + LANES];
            scale_mask_lanes(&lanes, scale, valid, j, o);
            j += LANES;
        }
        for jr in j..cols {
            out[i * cols + jr] = scalar_entry(a_row, bt, cols, jr, scale, valid);
        }
        i += 1;
    }
}

/// Fused epilogue of one [`LANES`]-wide accumulator group: apply the
/// score scale and stamp masked columns to `−inf`.
#[inline]
fn scale_mask_lanes(
    lanes: &[f32; LANES],
    scale: f32,
    valid: Option<&[f32]>,
    j0: usize,
    out: &mut [f32],
) {
    match valid {
        None => {
            for (o, &s) in out.iter_mut().zip(lanes) {
                *o = s * scale;
            }
        }
        Some(v) => {
            let v = &v[j0..j0 + LANES];
            for ((o, &s), &ok) in out.iter_mut().zip(lanes).zip(v) {
                *o = if ok > 0.0 { s * scale } else { f32::NEG_INFINITY };
            }
        }
    }
}

/// Column-remainder path of [`qk_tile`]: one scaled, masked dot product
/// against the strided column `j` of the packed operand.
#[inline]
fn scalar_entry(
    a_row: &[f32],
    bt: &[f32],
    cols: usize,
    j: usize,
    scale: f32,
    valid: Option<&[f32]>,
) -> f32 {
    if let Some(v) = valid {
        if v[j] <= 0.0 {
            return f32::NEG_INFINITY;
        }
    }
    let mut s = 0.0f32;
    for (t, &am) in a_row.iter().enumerate() {
        s += am * bt[t * cols + j];
    }
    s * scale
}

/// The tiled AV accumulate: `acc[i·d + t] += Σ_j w[i·cols + j] · v[j·d + t]`.
///
/// `w` is a `[rows, cols]` weight tile (softmax weights in the forward,
/// probability / dS tiles — possibly transposed — in the backward), `v`
/// a `[cols, d]` value block, `acc` the `[rows, d]` running accumulator.
/// Each output row is processed in [`LANES`]-wide chunks held in
/// registers across the whole key loop, [`MR`] rows at a time so every
/// loaded `v` lane is reused; zero weights (masked keys, fully masked
/// rows) contribute exactly nothing. Row and `d` remainders take scalar
/// fallbacks.
pub fn av_tile(w: &[f32], v: &[f32], rows: usize, cols: usize, d: usize, acc: &mut [f32]) {
    debug_assert_eq!(w.len(), rows * cols, "w must be [rows, cols]");
    debug_assert_eq!(v.len(), cols * d, "v must be [cols, d]");
    debug_assert_eq!(acc.len(), rows * d, "acc must be [rows, d]");
    let mut i = 0;
    while i + MR <= rows {
        let w_rows: [&[f32]; MR] = std::array::from_fn(|m| &w[(i + m) * cols..(i + m + 1) * cols]);
        let mut t = 0;
        while t + LANES <= d {
            let mut lanes = [[0.0f32; LANES]; MR];
            for (m, la) in lanes.iter_mut().enumerate() {
                la.copy_from_slice(&acc[(i + m) * d + t..(i + m) * d + t + LANES]);
            }
            for j in 0..cols {
                let vv: [f32; LANES] =
                    v[j * d + t..j * d + t + LANES].try_into().expect("lane slice");
                for (la, wr) in lanes.iter_mut().zip(&w_rows) {
                    let wj = wr[j];
                    for (l, &x) in la.iter_mut().zip(&vv) {
                        *l += wj * x;
                    }
                }
            }
            for (m, la) in lanes.iter().enumerate() {
                acc[(i + m) * d + t..(i + m) * d + t + LANES].copy_from_slice(la);
            }
            t += LANES;
        }
        if t < d {
            for (m, wr) in w_rows.iter().enumerate() {
                av_row_tail(wr, v, d, t, &mut acc[(i + m) * d + t..(i + m + 1) * d]);
            }
        }
        i += MR;
    }
    while i < rows {
        let w_row = &w[i * cols..(i + 1) * cols];
        let acc_row = &mut acc[i * d..(i + 1) * d];
        let mut t = 0;
        while t + LANES <= d {
            let mut lanes: [f32; LANES] = acc_row[t..t + LANES].try_into().expect("lane slice");
            for (j, &wj) in w_row.iter().enumerate() {
                let vv: [f32; LANES] =
                    v[j * d + t..j * d + t + LANES].try_into().expect("lane slice");
                for (l, &x) in lanes.iter_mut().zip(&vv) {
                    *l += wj * x;
                }
            }
            acc_row[t..t + LANES].copy_from_slice(&lanes);
            t += LANES;
        }
        if t < d {
            av_row_tail(w_row, v, d, t, &mut acc_row[t..]);
        }
        i += 1;
    }
}

/// `d`-remainder of one [`av_tile`] output row: accumulate the last
/// `d − t0` columns of every value row.
#[inline]
fn av_row_tail(w_row: &[f32], v: &[f32], d: usize, t0: usize, acc_tail: &mut [f32]) {
    for (j, &wj) in w_row.iter().enumerate() {
        let v_tail = &v[j * d + t0..(j + 1) * d];
        for (a, &x) in acc_tail.iter_mut().zip(v_tail) {
            *a += wj * x;
        }
    }
}

/// Row-wise dot products: `out[i] = Σ_t a[i·d + t] · b[i·d + t]`, each
/// row reduced through [`LANES`] independent partial sums (so the
/// reduction vectorizes) with a scalar tail. The backward's
/// `δ_i = dO_i · O_i` rowsums.
pub fn row_dots(a: &[f32], b: &[f32], rows: usize, d: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), rows * d, "a must be [rows, d]");
    debug_assert_eq!(b.len(), rows * d, "b must be [rows, d]");
    debug_assert_eq!(out.len(), rows, "out must be [rows]");
    for (i, o) in out.iter_mut().enumerate() {
        let ar = &a[i * d..(i + 1) * d];
        let br = &b[i * d..(i + 1) * d];
        let mut lanes = [0.0f32; LANES];
        let mut ac = ar.chunks_exact(LANES);
        let mut bc = br.chunks_exact(LANES);
        for (ca, cb) in (&mut ac).zip(&mut bc) {
            for ((l, &x), &y) in lanes.iter_mut().zip(ca).zip(cb) {
                *l += x * y;
            }
        }
        let mut s: f32 = lanes.iter().sum();
        for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
            s += x * y;
        }
        *o = s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::dot;
    use crate::util::Rng;

    fn data(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn pack_transposed_is_an_involution() {
        let mut rng = Rng::new(1);
        for (rows, cols) in [(1usize, 1usize), (3, 7), (8, 8), (5, 16), (16, 5)] {
            let src = data(&mut rng, rows * cols);
            let mut t = vec![0.0f32; rows * cols];
            pack_transposed(&src, rows, cols, &mut t);
            let mut back = vec![0.0f32; rows * cols];
            pack_transposed(&t, cols, rows, &mut back);
            assert_eq!(src, back, "{rows}x{cols}");
        }
    }

    #[test]
    fn qk_tile_matches_scalar_dots_on_a_lane_aligned_shape() {
        let (rows, cols, d) = (MR * 2, LANES * 2, 16);
        let mut rng = Rng::new(2);
        let a = data(&mut rng, rows * d);
        let b = data(&mut rng, cols * d);
        let mut bt = vec![0.0f32; d * cols];
        pack_transposed(&b, cols, d, &mut bt);
        let mut got = vec![0.0f32; rows * cols];
        qk_tile(&a, &bt, rows, cols, d, 0.25, None, &mut got);
        for i in 0..rows {
            for j in 0..cols {
                let want = dot(&a[i * d..(i + 1) * d], &b[j * d..(j + 1) * d]) * 0.25;
                let g = got[i * cols + j];
                assert!((want - g).abs() <= 1e-5, "({i},{j}): {want} vs {g}");
            }
        }
    }

    #[test]
    fn qk_tile_masks_columns_to_neg_infinity() {
        let (rows, cols, d) = (3, LANES + 3, 8);
        let mut rng = Rng::new(3);
        let a = data(&mut rng, rows * d);
        let b = data(&mut rng, cols * d);
        let mut bt = vec![0.0f32; d * cols];
        pack_transposed(&b, cols, d, &mut bt);
        // mask a lane-interior column and the whole (non-aligned) tail
        let mut valid = vec![1.0f32; cols];
        valid[2] = 0.0;
        valid[LANES] = 0.0;
        valid[cols - 1] = 0.0;
        let mut got = vec![0.0f32; rows * cols];
        qk_tile(&a, &bt, rows, cols, d, 1.0, Some(&valid), &mut got);
        for i in 0..rows {
            for (j, &ok) in valid.iter().enumerate() {
                let g = got[i * cols + j];
                if ok > 0.0 {
                    assert!(g.is_finite(), "({i},{j}) should be live: {g}");
                } else {
                    assert_eq!(g, f32::NEG_INFINITY, "({i},{j}) should be masked");
                }
            }
        }
    }

    #[test]
    fn av_tile_accumulates_on_top_of_existing_values() {
        let (rows, cols, d) = (MR + 1, 5, LANES + 2);
        let mut rng = Rng::new(4);
        let w = data(&mut rng, rows * cols);
        let v = data(&mut rng, cols * d);
        let init = data(&mut rng, rows * d);
        let mut acc = init.clone();
        av_tile(&w, &v, rows, cols, d, &mut acc);
        for i in 0..rows {
            for t in 0..d {
                let mut want = init[i * d + t];
                for j in 0..cols {
                    want += w[i * cols + j] * v[j * d + t];
                }
                let g = acc[i * d + t];
                assert!((want - g).abs() <= 1e-4, "({i},{t}): {want} vs {g}");
            }
        }
    }

    #[test]
    fn row_dots_matches_scalar_dot() {
        let mut rng = Rng::new(5);
        for d in [1usize, 7, 8, 9, 31, 32] {
            let rows = 5;
            let a = data(&mut rng, rows * d);
            let b = data(&mut rng, rows * d);
            let mut got = vec![0.0f32; rows];
            row_dots(&a, &b, rows, d, &mut got);
            for (i, &g) in got.iter().enumerate() {
                let want = dot(&a[i * d..(i + 1) * d], &b[i * d..(i + 1) * d]);
                assert!((want - g).abs() <= 1e-4, "d={d} row {i}: {want} vs {g}");
            }
        }
    }
}
