//! SIMD-tiled microkernels: the one hot inner loop every block-level
//! attention computation routes through.
//!
//! The scalar kernels left most of each core's FLOPs on the table: a
//! per-element `dot(q_row, k_row)` walks K row-major, so the compiler
//! sees a chain of dependent reductions and emits scalar code. These
//! microkernels restructure each block tile the way flash-style kernels
//! do on accelerators, but phrased for the **autovectorizer** — no
//! `unsafe`, no intrinsics, just fixed-lane-width accumulator arrays
//! the compiler maps straight onto vector registers:
//!
//! * [`pack_transposed`] — transpose a K/V block once per tile so the
//!   GEMM inner loop reads **contiguous** lanes instead of striding by
//!   `head_dim` (an O(b·d) pack amortised over O(b²·d) compute);
//! * [`qk_tile`] — the QKᵀ tile GEMM: [`MR`]×[`LANES`] register
//!   blocks of `f32` accumulators held across the whole `d` loop, with
//!   **fused scale + key-validity masking** in the epilogue (masked
//!   columns become `−inf`, ready for the softmax) and explicit scalar
//!   remainder handling for rows % [`MR`] and cols % [`LANES`];
//! * [`av_tile`] — the tiled AV accumulate `acc += W · V`: the output
//!   row is processed in [`LANES`]-wide chunks that stay in registers
//!   across the whole key loop (the backward reuses it for the
//!   dQ/dK/dV gathers — dKᵀ/dVᵀ scatters become `av_tile` calls on a
//!   transposed weight tile);
//! * [`row_dots`] — lane-partial row-wise dot products (the backward's
//!   `δ = dO · O` rowsums).
//!
//! Within one output element every accumulation runs in the same
//! ascending-index order as the scalar reference, so results match the
//! retired scalar path to well under the kernel-parity tolerance
//! (`tests/microkernel_parity.rs` pins this across remainder shapes).
//! Per-tile scratch (the packed transpose, score/probability tiles)
//! lives in [`SparseScratch`](super::sparse::SparseScratch) and
//! [`AttnGradScratch`](super::grad::AttnGradScratch), which the
//! [`KernelPool`](super::driver::KernelPool) hoists into per-thread
//! arenas — steady state allocates nothing.
//!
//! # The multi-precision GEMM layer
//!
//! Beyond the attention tiles, this module is the **single routing
//! point for all model math**: the QKV/output projections, FFN, and
//! tied-logits GEMMs in `kernel::model` and the transposed matmuls in
//! `kernel::grad::ops` all go through [`gemm_packed`] over a
//! [`PackedMat`] weight operand. Three storage precisions
//! ([`Precision`]):
//!
//! * **f32** — plain packed rows; per-(i,j) accumulation runs over the
//!   contraction index ascending, exactly like the retired naive ikj
//!   matmul, so f32 results are **bit-identical** to the old path (and
//!   identical across every [`TileShape`], so the tuner never perturbs
//!   determinism);
//! * **f16** — weights stored as hand-rolled IEEE half bits
//!   ([`f32_to_f16`]/[`f16_to_f32`], round-to-nearest-even), widened
//!   lane-wise to f32 in registers: half the weight memory traffic,
//!   f32 compute;
//! * **int8** — symmetric quantization: per-column weight scales baked
//!   at pack time, per-row activation scales computed at call time
//!   (quantize-on-pack into [`GemmScratch`]), i8×i8→i32 dot tiles, f32
//!   dequant in the epilogue.
//!
//! Register-block shapes are **auto-tuned**: [`gemm_packed`] asks
//! `kernel::calibrate::tuned_tile` for the winning [`TileShape`] per
//! precision (probed once per process);
//! [`gemm_packed_with`] takes an explicit shape (the tuner itself, and
//! shape-sweeping tests, call this). `tests/precision_parity.rs` pins
//! every precision against the scalar references in
//! `kernel::reference`.

/// Fixed vector-lane width: 8 × f32 (one AVX register, two SSE/NEON
/// registers — wide enough to saturate either without spilling the
/// [`MR`]-row accumulator block).
pub const LANES: usize = 8;

/// Register-block height: rows of the output tile accumulated
/// simultaneously, so each packed [`LANES`]-wide load of the B operand
/// is reused [`MR`] times. `MR × LANES` f32 accumulators fit in 8 SSE
/// (4 AVX) registers, leaving room for the operand vectors.
pub const MR: usize = 4;

/// Transpose `src` (`rows × cols`, row-major) into `dst`
/// (`cols × rows`, row-major): `dst[c·rows + r] = src[r·cols + c]`.
/// Packing K/V blocks this way once per tile lets the GEMM inner loops
/// read contiguous lanes. Every element of `dst` is written.
pub fn pack_transposed(src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    debug_assert_eq!(src.len(), rows * cols, "src must be [rows, cols]");
    debug_assert_eq!(dst.len(), rows * cols, "dst must be [cols, rows]");
    for (r, row) in src.chunks_exact(cols).enumerate() {
        for (c, &v) in row.iter().enumerate() {
            dst[c * rows + r] = v;
        }
    }
}

/// The QKᵀ tile GEMM with fused scale + mask:
/// `out[i·cols + j] = scale · Σ_t a[i·d + t] · bt[t·cols + j]`, or
/// `−inf` where `valid[j] ≤ 0`.
///
/// `a` is `[rows, d]` row-major (Q rows, or dO rows in the backward);
/// `bt` is the **packed transpose** of the `[cols, d]` B operand (from
/// [`pack_transposed`]), so the inner loop broadcasts one `a` element
/// against a contiguous [`LANES`]-wide slice of `bt`. The main path
/// computes [`MR`]`×`[`LANES`] register blocks; row and column
/// remainders fall back to narrower loops, so any tile shape is
/// handled. Every element of `out` is written.
#[allow(clippy::too_many_arguments)]
pub fn qk_tile(
    a: &[f32],
    bt: &[f32],
    rows: usize,
    cols: usize,
    d: usize,
    scale: f32,
    valid: Option<&[f32]>,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), rows * d, "a must be [rows, d]");
    debug_assert_eq!(bt.len(), d * cols, "bt must be [d, cols] (packed transpose)");
    debug_assert_eq!(out.len(), rows * cols, "out must be [rows, cols]");
    if let Some(v) = valid {
        debug_assert_eq!(v.len(), cols, "valid must be [cols]");
    }
    let mut i = 0;
    while i + MR <= rows {
        let a_rows: [&[f32]; MR] = std::array::from_fn(|m| &a[(i + m) * d..(i + m + 1) * d]);
        let mut j = 0;
        while j + LANES <= cols {
            let mut acc = [[0.0f32; LANES]; MR];
            for t in 0..d {
                let bv: [f32; LANES] =
                    bt[t * cols + j..t * cols + j + LANES].try_into().expect("lane slice");
                let av: [f32; MR] = std::array::from_fn(|m| a_rows[m][t]);
                for (lanes, &am) in acc.iter_mut().zip(&av) {
                    for (l, &bb) in lanes.iter_mut().zip(&bv) {
                        *l += am * bb;
                    }
                }
            }
            for (m, lanes) in acc.iter().enumerate() {
                let o = &mut out[(i + m) * cols + j..(i + m) * cols + j + LANES];
                scale_mask_lanes(lanes, scale, valid, j, o);
            }
            j += LANES;
        }
        for jr in j..cols {
            for m in 0..MR {
                out[(i + m) * cols + jr] = scalar_entry(a_rows[m], bt, cols, jr, scale, valid);
            }
        }
        i += MR;
    }
    while i < rows {
        let a_row = &a[i * d..(i + 1) * d];
        let mut j = 0;
        while j + LANES <= cols {
            let mut lanes = [0.0f32; LANES];
            for (t, &am) in a_row.iter().enumerate() {
                let bv: [f32; LANES] =
                    bt[t * cols + j..t * cols + j + LANES].try_into().expect("lane slice");
                for (l, &bb) in lanes.iter_mut().zip(&bv) {
                    *l += am * bb;
                }
            }
            let o = &mut out[i * cols + j..i * cols + j + LANES];
            scale_mask_lanes(&lanes, scale, valid, j, o);
            j += LANES;
        }
        for jr in j..cols {
            out[i * cols + jr] = scalar_entry(a_row, bt, cols, jr, scale, valid);
        }
        i += 1;
    }
}

/// Fused epilogue of one [`LANES`]-wide accumulator group: apply the
/// score scale and stamp masked columns to `−inf`.
#[inline]
fn scale_mask_lanes(
    lanes: &[f32; LANES],
    scale: f32,
    valid: Option<&[f32]>,
    j0: usize,
    out: &mut [f32],
) {
    match valid {
        None => {
            for (o, &s) in out.iter_mut().zip(lanes) {
                *o = s * scale;
            }
        }
        Some(v) => {
            let v = &v[j0..j0 + LANES];
            for ((o, &s), &ok) in out.iter_mut().zip(lanes).zip(v) {
                *o = if ok > 0.0 { s * scale } else { f32::NEG_INFINITY };
            }
        }
    }
}

/// Column-remainder path of [`qk_tile`]: one scaled, masked dot product
/// against the strided column `j` of the packed operand.
#[inline]
fn scalar_entry(
    a_row: &[f32],
    bt: &[f32],
    cols: usize,
    j: usize,
    scale: f32,
    valid: Option<&[f32]>,
) -> f32 {
    if let Some(v) = valid {
        if v[j] <= 0.0 {
            return f32::NEG_INFINITY;
        }
    }
    let mut s = 0.0f32;
    for (t, &am) in a_row.iter().enumerate() {
        s += am * bt[t * cols + j];
    }
    s * scale
}

/// The tiled AV accumulate: `acc[i·d + t] += Σ_j w[i·cols + j] · v[j·d + t]`.
///
/// `w` is a `[rows, cols]` weight tile (softmax weights in the forward,
/// probability / dS tiles — possibly transposed — in the backward), `v`
/// a `[cols, d]` value block, `acc` the `[rows, d]` running accumulator.
/// Each output row is processed in [`LANES`]-wide chunks held in
/// registers across the whole key loop, [`MR`] rows at a time so every
/// loaded `v` lane is reused; zero weights (masked keys, fully masked
/// rows) contribute exactly nothing. Row and `d` remainders take scalar
/// fallbacks.
pub fn av_tile(w: &[f32], v: &[f32], rows: usize, cols: usize, d: usize, acc: &mut [f32]) {
    debug_assert_eq!(w.len(), rows * cols, "w must be [rows, cols]");
    debug_assert_eq!(v.len(), cols * d, "v must be [cols, d]");
    debug_assert_eq!(acc.len(), rows * d, "acc must be [rows, d]");
    let mut i = 0;
    while i + MR <= rows {
        let w_rows: [&[f32]; MR] = std::array::from_fn(|m| &w[(i + m) * cols..(i + m + 1) * cols]);
        let mut t = 0;
        while t + LANES <= d {
            let mut lanes = [[0.0f32; LANES]; MR];
            for (m, la) in lanes.iter_mut().enumerate() {
                la.copy_from_slice(&acc[(i + m) * d + t..(i + m) * d + t + LANES]);
            }
            for j in 0..cols {
                let vv: [f32; LANES] =
                    v[j * d + t..j * d + t + LANES].try_into().expect("lane slice");
                for (la, wr) in lanes.iter_mut().zip(&w_rows) {
                    let wj = wr[j];
                    for (l, &x) in la.iter_mut().zip(&vv) {
                        *l += wj * x;
                    }
                }
            }
            for (m, la) in lanes.iter().enumerate() {
                acc[(i + m) * d + t..(i + m) * d + t + LANES].copy_from_slice(la);
            }
            t += LANES;
        }
        if t < d {
            for (m, wr) in w_rows.iter().enumerate() {
                av_row_tail(wr, v, d, t, &mut acc[(i + m) * d + t..(i + m + 1) * d]);
            }
        }
        i += MR;
    }
    while i < rows {
        let w_row = &w[i * cols..(i + 1) * cols];
        let acc_row = &mut acc[i * d..(i + 1) * d];
        let mut t = 0;
        while t + LANES <= d {
            let mut lanes: [f32; LANES] = acc_row[t..t + LANES].try_into().expect("lane slice");
            for (j, &wj) in w_row.iter().enumerate() {
                let vv: [f32; LANES] =
                    v[j * d + t..j * d + t + LANES].try_into().expect("lane slice");
                for (l, &x) in lanes.iter_mut().zip(&vv) {
                    *l += wj * x;
                }
            }
            acc_row[t..t + LANES].copy_from_slice(&lanes);
            t += LANES;
        }
        if t < d {
            av_row_tail(w_row, v, d, t, &mut acc_row[t..]);
        }
        i += 1;
    }
}

/// `d`-remainder of one [`av_tile`] output row: accumulate the last
/// `d − t0` columns of every value row.
#[inline]
fn av_row_tail(w_row: &[f32], v: &[f32], d: usize, t0: usize, acc_tail: &mut [f32]) {
    for (j, &wj) in w_row.iter().enumerate() {
        let v_tail = &v[j * d + t0..(j + 1) * d];
        for (a, &x) in acc_tail.iter_mut().zip(v_tail) {
            *a += wj * x;
        }
    }
}

/// Row-wise dot products: `out[i] = Σ_t a[i·d + t] · b[i·d + t]`, each
/// row reduced through [`LANES`] independent partial sums (so the
/// reduction vectorizes) with a scalar tail. The backward's
/// `δ_i = dO_i · O_i` rowsums.
pub fn row_dots(a: &[f32], b: &[f32], rows: usize, d: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), rows * d, "a must be [rows, d]");
    debug_assert_eq!(b.len(), rows * d, "b must be [rows, d]");
    debug_assert_eq!(out.len(), rows, "out must be [rows]");
    for (i, o) in out.iter_mut().enumerate() {
        let ar = &a[i * d..(i + 1) * d];
        let br = &b[i * d..(i + 1) * d];
        let mut lanes = [0.0f32; LANES];
        let mut ac = ar.chunks_exact(LANES);
        let mut bc = br.chunks_exact(LANES);
        for (ca, cb) in (&mut ac).zip(&mut bc) {
            for ((l, &x), &y) in lanes.iter_mut().zip(ca).zip(cb) {
                *l += x * y;
            }
        }
        let mut s: f32 = lanes.iter().sum();
        for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
            s += x * y;
        }
        *o = s;
    }
}

// ---------------------------------------------------------------------
// the multi-precision GEMM layer (see the module docs)
// ---------------------------------------------------------------------

pub use crate::config::Precision;

/// Convert one f32 to IEEE 754 binary16 bits with round-to-nearest-even
/// (hand-rolled — no `half` crate in this offline environment).
/// Overflow saturates to ±inf; inputs below the subnormal range flush
/// to ±0; NaN payloads are preserved as quiet NaNs.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp32 = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp32 == 0xff {
        // inf / NaN: keep the top mantissa bits, force quiet on NaN
        let payload = (man >> 13) as u16;
        return if man == 0 { sign | 0x7c00 } else { sign | 0x7c00 | payload | 0x0200 };
    }
    let exp = exp32 - 127 + 15;
    if exp >= 31 {
        return sign | 0x7c00; // overflow → inf
    }
    if exp <= 0 {
        if exp < -10 {
            return sign; // underflow → signed zero
        }
        // subnormal half: shift the (implicit-bit) mantissa into place
        let man = man | 0x0080_0000;
        let shift = (14 - exp) as u32;
        let half = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let round_up = rem > halfway || (rem == halfway && (half & 1) == 1);
        return sign | (half + u32::from(round_up)) as u16;
    }
    let half = ((exp as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    let round_up = rem > 0x1000 || (rem == 0x1000 && (half & 1) == 1);
    // a mantissa carry out of rounding lands in the exponent field with
    // the correct encoding (including 0x7c00 = inf on max-normal)
    sign | (half + u32::from(round_up)) as u16
}

/// Convert IEEE 754 binary16 bits back to f32 (exact — every half value
/// is representable in f32).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // signed zero
        } else {
            // subnormal half: renormalize into an f32 exponent
            let mut e = 127 - 15 + 1;
            let mut m = man;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | ((e as u32) << 23) | ((m & 0x03ff) << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (man << 13) // inf / NaN
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Symmetric int8 quantization of one value against a positive scale.
#[inline]
fn quantize_i8(x: f32, scale: f32) -> i8 {
    (x / scale).round().clamp(-127.0, 127.0) as i8
}

/// The positive symmetric scale covering `[-maxabs, maxabs]` in 127
/// steps (1.0 for all-zero data, so dequantization stays exact).
#[inline]
fn symmetric_scale(maxabs: f32) -> f32 {
    if maxabs > 0.0 {
        maxabs / 127.0
    } else {
        1.0
    }
}

/// Quantize the rows of a `[rows, k]` f32 activation block into `q`
/// (i8, same layout) with one symmetric scale per row — the int8 GEMM's
/// quantize-on-pack step for the A operand, writing into reusable
/// per-thread scratch.
pub fn quantize_rows(a: &[f32], rows: usize, k: usize, q: &mut Vec<i8>, scale: &mut Vec<f32>) {
    debug_assert_eq!(a.len(), rows * k, "a must be [rows, k]");
    q.clear();
    q.resize(rows * k, 0);
    scale.clear();
    scale.resize(rows, 1.0);
    for ((row, qrow), s) in a.chunks_exact(k).zip(q.chunks_exact_mut(k)).zip(scale.iter_mut()) {
        let maxabs = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        *s = symmetric_scale(maxabs);
        for (qq, &v) in qrow.iter_mut().zip(row) {
            *qq = quantize_i8(v, *s);
        }
    }
}

/// Per-thread scratch of the packed GEMM entry points: the quantized A
/// operand (+ per-row scales) of the int8 path. Lives in the
/// [`ScratchArena`](super::driver::ScratchArena) per-thread arenas so
/// steady-state GEMM calls allocate nothing.
#[derive(Debug, Default)]
pub struct GemmScratch {
    aq: Vec<i8>,
    ascale: Vec<f32>,
}

/// Packed storage of one GEMM B operand (weights) at a chosen
/// [`Precision`].
#[derive(Clone, Debug)]
enum PackedData {
    /// Row-major `[k, n]` f32.
    F32(Vec<f32>),
    /// Row-major `[k, n]` IEEE binary16 bits.
    F16(Vec<u16>),
    /// Row-major `[k, n]` i8 with per-column symmetric scales `[n]`.
    Int8 { q: Vec<i8>, scale: Vec<f32> },
}

/// A GEMM weight operand packed (and, for int8/f16, quantized) once and
/// reused across forward passes: `C[m, n] (+)= A[m, k] · B[k, n]`.
/// Models pre-pack every weight at their configured precision
/// (quantize-on-pack — master weights stay f32 elsewhere).
#[derive(Clone, Debug)]
pub struct PackedMat {
    k: usize,
    n: usize,
    data: PackedData,
}

impl PackedMat {
    /// Pack a row-major `[k, n]` operand at `p`.
    pub fn pack(src: &[f32], k: usize, n: usize, p: Precision) -> Self {
        debug_assert_eq!(src.len(), k * n, "src must be [k, n]");
        let data = match p {
            Precision::F32 => PackedData::F32(src.to_vec()),
            Precision::F16 => PackedData::F16(src.iter().map(|&x| f32_to_f16(x)).collect()),
            Precision::Int8 => {
                let mut scale = vec![0.0f32; n];
                for row in src.chunks_exact(n) {
                    for (s, &x) in scale.iter_mut().zip(row) {
                        *s = s.max(x.abs());
                    }
                }
                for s in scale.iter_mut() {
                    *s = symmetric_scale(*s);
                }
                let mut q = vec![0i8; k * n];
                for (qrow, row) in q.chunks_exact_mut(n).zip(src.chunks_exact(n)) {
                    for ((qq, &x), &s) in qrow.iter_mut().zip(row).zip(scale.iter()) {
                        *qq = quantize_i8(x, s);
                    }
                }
                PackedData::Int8 { q, scale }
            }
        };
        PackedMat { k, n, data }
    }

    /// Pack the **transpose** of a row-major `[rows, cols]` operand:
    /// the result multiplies as a `[cols, rows]` B operand (`k = cols`,
    /// `n = rows`) — the `dX = dY · Wᵀ` backward shape.
    pub fn pack_transposed(src: &[f32], rows: usize, cols: usize, p: Precision) -> Self {
        let mut t = vec![0.0f32; rows * cols];
        pack_transposed(src, rows, cols, &mut t);
        Self::pack(&t, cols, rows, p)
    }

    /// Contraction length `k`.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output width `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The precision this operand was packed at.
    pub fn precision(&self) -> Precision {
        match &self.data {
            PackedData::F32(_) => Precision::F32,
            PackedData::F16(_) => Precision::F16,
            PackedData::Int8 { .. } => Precision::Int8,
        }
    }
}

/// Candidate register-block shapes of the packed GEMM kernels,
/// monomorphized via const generics. `kernel::calibrate` probes each
/// per precision at startup and records the winner; wider lanes win on
/// AVX-512-class machines, the narrow default elsewhere.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TileShape {
    /// 4 rows × 8 lanes — the attention tiles' [`MR`]×[`LANES`] shape.
    Mr4Nr8,
    /// 8 rows × 8 lanes — deeper B-operand reuse per loaded lane group.
    Mr8Nr8,
    /// 4 rows × 16 lanes — two vector registers wide per row.
    Mr4Nr16,
}

impl TileShape {
    /// Rows accumulated simultaneously.
    pub fn mr(self) -> usize {
        match self {
            TileShape::Mr4Nr8 => 4,
            TileShape::Mr8Nr8 => 8,
            TileShape::Mr4Nr16 => 4,
        }
    }

    /// Output-column lanes per register block.
    pub fn nr(self) -> usize {
        match self {
            TileShape::Mr4Nr8 => 8,
            TileShape::Mr8Nr8 => 8,
            TileShape::Mr4Nr16 => 16,
        }
    }

    /// Every candidate shape, in probe order.
    pub fn all() -> [TileShape; 3] {
        [TileShape::Mr4Nr8, TileShape::Mr8Nr8, TileShape::Mr4Nr16]
    }

    /// Display label (`MRxNR`).
    pub fn as_str(self) -> &'static str {
        match self {
            TileShape::Mr4Nr8 => "4x8",
            TileShape::Mr8Nr8 => "8x8",
            TileShape::Mr4Nr16 => "4x16",
        }
    }
}

/// `out[m, n] (+)= a[m, k] · b` through the packed tile kernels, using
/// the auto-tuned [`TileShape`] for `b`'s precision. `acc` selects
/// accumulate (`+=`, the `dW` shape) vs overwrite. Results at f32 are
/// bit-identical to the naive ikj reference for any tile shape; int8
/// quantizes `a`'s rows into `scratch` first (quantize-on-pack).
pub fn gemm_packed(
    a: &[f32],
    b: &PackedMat,
    m: usize,
    acc: bool,
    scratch: &mut GemmScratch,
    out: &mut [f32],
) {
    let shape = crate::kernel::calibrate::tuned_tile(b.precision());
    gemm_packed_with(shape, a, b, m, acc, scratch, out);
}

/// [`gemm_packed`] with an explicit register-block shape — the tuner's
/// probe entry point (it cannot ask itself for the winner) and the
/// shape-sweeping parity tests.
pub fn gemm_packed_with(
    shape: TileShape,
    a: &[f32],
    b: &PackedMat,
    m: usize,
    acc: bool,
    scratch: &mut GemmScratch,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * b.k, "a must be [m, k]");
    debug_assert_eq!(out.len(), m * b.n, "out must be [m, n]");
    match shape {
        TileShape::Mr4Nr8 => gemm_dispatch::<4, 8>(a, b, m, acc, scratch, out),
        TileShape::Mr8Nr8 => gemm_dispatch::<8, 8>(a, b, m, acc, scratch, out),
        TileShape::Mr4Nr16 => gemm_dispatch::<4, 16>(a, b, m, acc, scratch, out),
    }
}

/// Shape-monomorphized precision dispatch.
fn gemm_dispatch<const MRR: usize, const NR: usize>(
    a: &[f32],
    b: &PackedMat,
    m: usize,
    acc: bool,
    scratch: &mut GemmScratch,
    out: &mut [f32],
) {
    let (k, n) = (b.k, b.n);
    match (&b.data, acc) {
        (PackedData::F32(w), false) => gemm_f32::<MRR, NR, false>(a, w, m, k, n, out),
        (PackedData::F32(w), true) => gemm_f32::<MRR, NR, true>(a, w, m, k, n, out),
        (PackedData::F16(w), false) => gemm_f16::<MRR, NR, false>(a, w, m, k, n, out),
        (PackedData::F16(w), true) => gemm_f16::<MRR, NR, true>(a, w, m, k, n, out),
        (PackedData::Int8 { q, scale }, _) => {
            quantize_rows(a, m, k, &mut scratch.aq, &mut scratch.ascale);
            if acc {
                gemm_i8::<MRR, NR, true>(&scratch.aq, &scratch.ascale, q, scale, m, k, n, out);
            } else {
                gemm_i8::<MRR, NR, false>(&scratch.aq, &scratch.ascale, q, scale, m, k, n, out);
            }
        }
    }
}

/// Store or accumulate one finished register value.
#[inline(always)]
fn emit<const ACC: bool>(o: &mut f32, v: f32) {
    if ACC {
        *o += v;
    } else {
        *o = v;
    }
}

/// f32 packed GEMM: `MRR × NR` register blocks, contraction index
/// ascending inside each output element — the exact accumulation
/// sequence of the retired naive ikj matmul, so f32 results are
/// bit-identical to it.
fn gemm_f32<const MRR: usize, const NR: usize, const ACC: bool>(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    let mut i = 0;
    while i + MRR <= m {
        let a_rows: [&[f32]; MRR] = std::array::from_fn(|r| &a[(i + r) * k..(i + r + 1) * k]);
        let mut j = 0;
        while j + NR <= n {
            let mut acc = [[0.0f32; NR]; MRR];
            for t in 0..k {
                let bv: [f32; NR] = b[t * n + j..t * n + j + NR].try_into().expect("lane slice");
                for (lanes, ar) in acc.iter_mut().zip(&a_rows) {
                    let av = ar[t];
                    for (l, &bb) in lanes.iter_mut().zip(&bv) {
                        *l += av * bb;
                    }
                }
            }
            for (r, lanes) in acc.iter().enumerate() {
                let o = &mut out[(i + r) * n + j..(i + r) * n + j + NR];
                for (oo, &s) in o.iter_mut().zip(lanes) {
                    emit::<ACC>(oo, s);
                }
            }
            j += NR;
        }
        for jr in j..n {
            for (r, ar) in a_rows.iter().enumerate() {
                let mut s = 0.0f32;
                for (t, &av) in ar.iter().enumerate() {
                    s += av * b[t * n + jr];
                }
                emit::<ACC>(&mut out[(i + r) * n + jr], s);
            }
        }
        i += MRR;
    }
    while i < m {
        let a_row = &a[i * k..(i + 1) * k];
        let mut j = 0;
        while j + NR <= n {
            let mut lanes = [0.0f32; NR];
            for (t, &av) in a_row.iter().enumerate() {
                let bv: [f32; NR] = b[t * n + j..t * n + j + NR].try_into().expect("lane slice");
                for (l, &bb) in lanes.iter_mut().zip(&bv) {
                    *l += av * bb;
                }
            }
            for (oo, &s) in out[i * n + j..i * n + j + NR].iter_mut().zip(&lanes) {
                emit::<ACC>(oo, s);
            }
            j += NR;
        }
        for jr in j..n {
            let mut s = 0.0f32;
            for (t, &av) in a_row.iter().enumerate() {
                s += av * b[t * n + jr];
            }
            emit::<ACC>(&mut out[i * n + jr], s);
        }
        i += 1;
    }
}

/// f16-storage packed GEMM: B lanes widen to f32 in registers, then the
/// arithmetic is the f32 kernel's — accuracy is bounded purely by the
/// one-time weight rounding (≈2⁻¹⁰ relative per element).
fn gemm_f16<const MRR: usize, const NR: usize, const ACC: bool>(
    a: &[f32],
    b: &[u16],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    let mut i = 0;
    while i + MRR <= m {
        let a_rows: [&[f32]; MRR] = std::array::from_fn(|r| &a[(i + r) * k..(i + r + 1) * k]);
        let mut j = 0;
        while j + NR <= n {
            let mut acc = [[0.0f32; NR]; MRR];
            for t in 0..k {
                let brow = &b[t * n + j..t * n + j + NR];
                let bv: [f32; NR] = std::array::from_fn(|l| f16_to_f32(brow[l]));
                for (lanes, ar) in acc.iter_mut().zip(&a_rows) {
                    let av = ar[t];
                    for (l, &bb) in lanes.iter_mut().zip(&bv) {
                        *l += av * bb;
                    }
                }
            }
            for (r, lanes) in acc.iter().enumerate() {
                let o = &mut out[(i + r) * n + j..(i + r) * n + j + NR];
                for (oo, &s) in o.iter_mut().zip(lanes) {
                    emit::<ACC>(oo, s);
                }
            }
            j += NR;
        }
        for jr in j..n {
            for (r, ar) in a_rows.iter().enumerate() {
                let mut s = 0.0f32;
                for (t, &av) in ar.iter().enumerate() {
                    s += av * f16_to_f32(b[t * n + jr]);
                }
                emit::<ACC>(&mut out[(i + r) * n + jr], s);
            }
        }
        i += MRR;
    }
    while i < m {
        let a_row = &a[i * k..(i + 1) * k];
        let mut j = 0;
        while j + NR <= n {
            let mut lanes = [0.0f32; NR];
            for (t, &av) in a_row.iter().enumerate() {
                let brow = &b[t * n + j..t * n + j + NR];
                let bv: [f32; NR] = std::array::from_fn(|l| f16_to_f32(brow[l]));
                for (l, &bb) in lanes.iter_mut().zip(&bv) {
                    *l += av * bb;
                }
            }
            for (oo, &s) in out[i * n + j..i * n + j + NR].iter_mut().zip(&lanes) {
                emit::<ACC>(oo, s);
            }
            j += NR;
        }
        for jr in j..n {
            let mut s = 0.0f32;
            for (t, &av) in a_row.iter().enumerate() {
                s += av * f16_to_f32(b[t * n + jr]);
            }
            emit::<ACC>(&mut out[i * n + jr], s);
        }
        i += 1;
    }
}

/// int8 packed GEMM: i8×i8→i32 dot tiles (exact integer accumulation —
/// safe for k up to ~130k at |q| ≤ 127), dequantized in the f32
/// epilogue as `i32 · row_scale · col_scale`.
#[allow(clippy::too_many_arguments)]
fn gemm_i8<const MRR: usize, const NR: usize, const ACC: bool>(
    aq: &[i8],
    ascale: &[f32],
    bq: &[i8],
    bscale: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    let mut i = 0;
    while i + MRR <= m {
        let a_rows: [&[i8]; MRR] = std::array::from_fn(|r| &aq[(i + r) * k..(i + r + 1) * k]);
        let mut j = 0;
        while j + NR <= n {
            let mut acc = [[0i32; NR]; MRR];
            for t in 0..k {
                let brow = &bq[t * n + j..t * n + j + NR];
                let bv: [i32; NR] = std::array::from_fn(|l| brow[l] as i32);
                for (lanes, ar) in acc.iter_mut().zip(&a_rows) {
                    let av = ar[t] as i32;
                    for (l, &bb) in lanes.iter_mut().zip(&bv) {
                        *l += av * bb;
                    }
                }
            }
            for (r, lanes) in acc.iter().enumerate() {
                let sa = ascale[i + r];
                let o = &mut out[(i + r) * n + j..(i + r) * n + j + NR];
                for ((oo, &s), &sb) in o.iter_mut().zip(lanes).zip(&bscale[j..j + NR]) {
                    emit::<ACC>(oo, s as f32 * sa * sb);
                }
            }
            j += NR;
        }
        for jr in j..n {
            for (r, ar) in a_rows.iter().enumerate() {
                let mut s = 0i32;
                for (t, &av) in ar.iter().enumerate() {
                    s += av as i32 * bq[t * n + jr] as i32;
                }
                emit::<ACC>(&mut out[(i + r) * n + jr], s as f32 * ascale[i + r] * bscale[jr]);
            }
        }
        i += MRR;
    }
    while i < m {
        let a_row = &aq[i * k..(i + 1) * k];
        let sa = ascale[i];
        let mut j = 0;
        while j + NR <= n {
            let mut lanes = [0i32; NR];
            for (t, &av) in a_row.iter().enumerate() {
                let av = av as i32;
                let brow = &bq[t * n + j..t * n + j + NR];
                let bv: [i32; NR] = std::array::from_fn(|l| brow[l] as i32);
                for (l, &bb) in lanes.iter_mut().zip(&bv) {
                    *l += av * bb;
                }
            }
            let o = &mut out[i * n + j..i * n + j + NR];
            for ((oo, &s), &sb) in o.iter_mut().zip(&lanes).zip(&bscale[j..j + NR]) {
                emit::<ACC>(oo, s as f32 * sa * sb);
            }
            j += NR;
        }
        for jr in j..n {
            let mut s = 0i32;
            for (t, &av) in a_row.iter().enumerate() {
                s += av as i32 * bq[t * n + jr] as i32;
            }
            emit::<ACC>(&mut out[i * n + jr], s as f32 * sa * bscale[jr]);
        }
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::reference::dot;
    use crate::util::Rng;

    fn data(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn pack_transposed_is_an_involution() {
        let mut rng = Rng::new(1);
        for (rows, cols) in [(1usize, 1usize), (3, 7), (8, 8), (5, 16), (16, 5)] {
            let src = data(&mut rng, rows * cols);
            let mut t = vec![0.0f32; rows * cols];
            pack_transposed(&src, rows, cols, &mut t);
            let mut back = vec![0.0f32; rows * cols];
            pack_transposed(&t, cols, rows, &mut back);
            assert_eq!(src, back, "{rows}x{cols}");
        }
    }

    #[test]
    fn qk_tile_matches_scalar_dots_on_a_lane_aligned_shape() {
        let (rows, cols, d) = (MR * 2, LANES * 2, 16);
        let mut rng = Rng::new(2);
        let a = data(&mut rng, rows * d);
        let b = data(&mut rng, cols * d);
        let mut bt = vec![0.0f32; d * cols];
        pack_transposed(&b, cols, d, &mut bt);
        let mut got = vec![0.0f32; rows * cols];
        qk_tile(&a, &bt, rows, cols, d, 0.25, None, &mut got);
        for i in 0..rows {
            for j in 0..cols {
                let want = dot(&a[i * d..(i + 1) * d], &b[j * d..(j + 1) * d]) * 0.25;
                let g = got[i * cols + j];
                assert!((want - g).abs() <= 1e-5, "({i},{j}): {want} vs {g}");
            }
        }
    }

    #[test]
    fn qk_tile_masks_columns_to_neg_infinity() {
        let (rows, cols, d) = (3, LANES + 3, 8);
        let mut rng = Rng::new(3);
        let a = data(&mut rng, rows * d);
        let b = data(&mut rng, cols * d);
        let mut bt = vec![0.0f32; d * cols];
        pack_transposed(&b, cols, d, &mut bt);
        // mask a lane-interior column and the whole (non-aligned) tail
        let mut valid = vec![1.0f32; cols];
        valid[2] = 0.0;
        valid[LANES] = 0.0;
        valid[cols - 1] = 0.0;
        let mut got = vec![0.0f32; rows * cols];
        qk_tile(&a, &bt, rows, cols, d, 1.0, Some(&valid), &mut got);
        for i in 0..rows {
            for (j, &ok) in valid.iter().enumerate() {
                let g = got[i * cols + j];
                if ok > 0.0 {
                    assert!(g.is_finite(), "({i},{j}) should be live: {g}");
                } else {
                    assert_eq!(g, f32::NEG_INFINITY, "({i},{j}) should be masked");
                }
            }
        }
    }

    #[test]
    fn av_tile_accumulates_on_top_of_existing_values() {
        let (rows, cols, d) = (MR + 1, 5, LANES + 2);
        let mut rng = Rng::new(4);
        let w = data(&mut rng, rows * cols);
        let v = data(&mut rng, cols * d);
        let init = data(&mut rng, rows * d);
        let mut acc = init.clone();
        av_tile(&w, &v, rows, cols, d, &mut acc);
        for i in 0..rows {
            for t in 0..d {
                let mut want = init[i * d + t];
                for j in 0..cols {
                    want += w[i * cols + j] * v[j * d + t];
                }
                let g = acc[i * d + t];
                assert!((want - g).abs() <= 1e-4, "({i},{t}): {want} vs {g}");
            }
        }
    }

    #[test]
    fn row_dots_matches_scalar_dot() {
        let mut rng = Rng::new(5);
        for d in [1usize, 7, 8, 9, 31, 32] {
            let rows = 5;
            let a = data(&mut rng, rows * d);
            let b = data(&mut rng, rows * d);
            let mut got = vec![0.0f32; rows];
            row_dots(&a, &b, rows, d, &mut got);
            for (i, &g) in got.iter().enumerate() {
                let want = dot(&a[i * d..(i + 1) * d], &b[i * d..(i + 1) * d]);
                assert!((want - g).abs() <= 1e-4, "d={d} row {i}: {want} vs {g}");
            }
        }
    }

    #[test]
    fn f16_conversion_known_values_and_roundtrip() {
        // exact binary16 encodings
        for &(x, bits) in &[
            (0.0f32, 0x0000u16),
            (-0.0, 0x8000),
            (1.0, 0x3c00),
            (-1.0, 0xbc00),
            (2.0, 0x4000),
            (0.5, 0x3800),
            (65504.0, 0x7bff),          // max finite half
            (6.103_515_6e-5, 0x0400),   // smallest normal half
            (5.960_464_5e-8, 0x0001),   // smallest subnormal half
            (f32::INFINITY, 0x7c00),
            (f32::NEG_INFINITY, 0xfc00),
        ] {
            assert_eq!(f32_to_f16(x), bits, "encoding {x}");
            if x.is_finite() {
                assert_eq!(f16_to_f32(bits), x, "decoding {bits:#06x}");
            }
        }
        // overflow saturates to inf, deep underflow flushes to zero
        assert_eq!(f32_to_f16(1.0e6), 0x7c00);
        assert_eq!(f32_to_f16(-1.0e6), 0xfc00);
        assert_eq!(f32_to_f16(1.0e-9), 0x0000);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // every half value round-trips exactly through f32
        for h in 0..=0x7bffu16 {
            let x = f16_to_f32(h);
            assert_eq!(f32_to_f16(x), h, "half {h:#06x} must round-trip");
        }
        // representable-range f32s land within half a ULP (~2⁻¹⁰ rel)
        let mut rng = Rng::new(0xF16);
        for _ in 0..200 {
            let x = rng.normal() as f32;
            let back = f16_to_f32(f32_to_f16(x));
            assert!(
                (back - x).abs() <= x.abs() * 1.0e-3 + 1.0e-7,
                "{x} → {back} drifted past the f16 budget"
            );
        }
    }

    #[test]
    fn packed_gemm_f32_is_bit_identical_across_shapes_and_remainders() {
        let mut rng = Rng::new(0x6E99);
        // shapes straddling every MR/NR remainder boundary
        let shapes = [(1usize, 1usize, 1usize), (3, 5, 7), (4, 8, 8), (9, 17, 23), (16, 24, 33)];
        for &(m, k, n) in &shapes {
            let a = data(&mut rng, m * k);
            let b = data(&mut rng, k * n);
            let want = crate::kernel::reference::matmul(&a, &b, m, k, n);
            let packed = PackedMat::pack(&b, k, n, Precision::F32);
            assert_eq!(packed.precision(), Precision::F32);
            assert_eq!((packed.k(), packed.n()), (k, n));
            let mut scratch = GemmScratch::default();
            for shape in TileShape::all() {
                let mut got = vec![0.0f32; m * n];
                gemm_packed_with(shape, &a, &packed, m, false, &mut scratch, &mut got);
                assert_eq!(
                    got,
                    want,
                    "{}: f32 m={m} k={k} n={n} must be bit-identical to the naive reference",
                    shape.as_str()
                );
            }
        }
    }

    #[test]
    fn packed_gemm_f16_and_int8_match_their_precision_references_exactly() {
        let mut rng = Rng::new(0xAB5);
        for &(m, k, n) in &[(3usize, 5usize, 7usize), (9, 16, 23), (12, 33, 8)] {
            let a = data(&mut rng, m * k);
            let b = data(&mut rng, k * n);
            let mut scratch = GemmScratch::default();
            for p in [Precision::F16, Precision::Int8] {
                let want = crate::kernel::reference::matmul_prec(&a, &b, m, k, n, p);
                let packed = PackedMat::pack(&b, k, n, p);
                assert_eq!(packed.precision(), p);
                for shape in TileShape::all() {
                    let mut got = vec![0.0f32; m * n];
                    gemm_packed_with(shape, &a, &packed, m, false, &mut scratch, &mut got);
                    // int8 integer dots are order-free (exact); f16's
                    // f32 accumulation matches the reference's
                    // identical ordering bitwise too
                    for (idx, (&g, &w)) in got.iter().zip(&want).enumerate() {
                        assert_eq!(g, w, "{p:?} {}: m={m} k={k} n={n} idx={idx}", shape.as_str());
                    }
                }
            }
        }
    }

    #[test]
    fn packed_gemm_accumulate_adds_onto_existing_output() {
        let mut rng = Rng::new(0xACC);
        let (m, k, n) = (6usize, 11usize, 9usize);
        let a = data(&mut rng, m * k);
        let b = data(&mut rng, k * n);
        let init = data(&mut rng, m * n);
        let packed = PackedMat::pack(&b, k, n, Precision::F32);
        let mut scratch = GemmScratch::default();
        let want = crate::kernel::reference::matmul(&a, &b, m, k, n);
        for shape in TileShape::all() {
            let mut got = init.clone();
            gemm_packed_with(shape, &a, &packed, m, true, &mut scratch, &mut got);
            for idx in 0..m * n {
                assert_eq!(got[idx], init[idx] + want[idx], "{}: acc idx={idx}", shape.as_str());
            }
        }
    }

    #[test]
    fn pack_transposed_packs_the_transpose() {
        let (rows, cols) = (3usize, 4usize);
        let src: Vec<f32> = (0..rows * cols).map(|i| i as f32).collect();
        let p = PackedMat::pack_transposed(&src, rows, cols, Precision::F32);
        assert_eq!((p.k(), p.n()), (cols, rows));
        // multiplying the identity of width `cols` by the packed
        // transpose reads it back out
        let mut eye = vec![0.0f32; cols * cols];
        for i in 0..cols {
            eye[i * cols + i] = 1.0;
        }
        let mut out = vec![0.0f32; cols * rows];
        gemm_packed_with(
            TileShape::Mr4Nr8,
            &eye,
            &p,
            cols,
            false,
            &mut GemmScratch::default(),
            &mut out,
        );
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(out[c * rows + r], src[r * cols + c], "({r},{c})");
            }
        }
    }

    #[test]
    fn quantize_rows_scales_cover_the_row_maxima() {
        let a = vec![1.0f32, -2.0, 0.5, 0.0, 0.0, 0.0];
        let (mut q, mut s) = (Vec::new(), Vec::new());
        quantize_rows(&a, 2, 3, &mut q, &mut s);
        assert_eq!(q.len(), 6);
        assert_eq!(s.len(), 2);
        // row 0: maxabs 2.0 → scale 2/127; the max element hits ±127
        assert!((s[0] - 2.0 / 127.0).abs() < 1e-9);
        assert_eq!(q[1], -127);
        // all-zero row falls back to scale 1.0 and zero codes
        assert_eq!(s[1], 1.0);
        assert_eq!(&q[3..6], &[0, 0, 0]);
    }
}
