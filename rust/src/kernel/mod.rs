//! Native block-sparse attention kernels: BigBird compute in pure Rust.
//!
//! The rest of the stack *describes* the paper's band + global + random
//! pattern ([`crate::attention::build_pattern`]) and executes it through
//! opaque PJRT artifacts; this subsystem **computes** it, so the
//! linear-vs-quadratic claim is measurable — and servable — on any
//! machine with no AOT artifacts at all:
//!
//! * [`layout`] — [`BlockCsr`], the pattern compiled into a
//!   gather-friendly block-level CSR with per-entry provenance;
//! * [`dense`] — the blocked dense masked reference kernel (two-pass
//!   softmax), the correctness oracle;
//! * [`microkernel`] — the SIMD-tiled microkernels every block-level
//!   computation routes through: register-blocked QKᵀ tile GEMM with
//!   fused scale+mask, tiled AV accumulate, transpose packing, and
//!   lane-partial row dots (no unsafe, autovectorizer-friendly fixed
//!   lanes);
//! * [`sparse`] — the production kernel: gathered QKᵀ → streaming
//!   (flash-style) softmax → gathered AV accumulate, with reusable
//!   [`SparseScratch`] buffers;
//! * [`driver`] — the persistent [`KernelPool`] worker-thread pool
//!   (per-thread scratch arenas, shared by every caller) and the
//!   batch fan-out of `batch × heads` head problems over it, for both
//!   forward and backward;
//! * [`model`] — a deterministic scaled-down BigBird MLM forward pass
//!   ([`NativeModel`]) and the engine-worker wrapper
//!   ([`NativeEngine`]) behind `BackendKind::Native`;
//! * [`grad`] — reverse-mode gradients: flash-style sparse-attention
//!   backward, whole-model tape, [`grad::ParamGrads`], masked-LM loss,
//!   and the [`grad::AdamW`] optimizer powering `train --backends
//!   native`;
//! * [`calibrate`] — the self-calibration micro-probe that seeds the
//!   native backend's roofline from measurements instead of guesses.
//!
//! `tests/kernel_parity.rs` property-tests sparse-vs-dense agreement
//! (≤ 1e-5) across random [`crate::attention::PatternSpec`]s,
//! `tests/native_training.rs` gradient-checks the backward subsystem,
//! and `benches/attention_scaling.rs` measures the sub-quadratic
//! scaling.

pub mod calibrate;
pub mod dense;
pub mod driver;
pub mod grad;
pub mod layout;
pub mod microkernel;
pub mod model;
pub mod sparse;

pub use calibrate::native_roofline;
pub use dense::dense_reference;
pub use driver::{
    sparse_backward_batch, sparse_forward_batch, sparse_forward_batch_training, KernelPool,
    ScratchArena,
};
pub use layout::{BlockCsr, BlockProvenance};
pub use microkernel::{av_tile, pack_transposed, qk_tile, row_dots, LANES, MR};
pub use model::{
    config_fingerprint, is_native_artifact, native_artifact_name, native_buckets,
    param_count_for, parse_native_artifact, NativeEngine, NativeModel, NATIVE_PARAMS_ARTIFACT,
    NATIVE_PREFIX,
};
pub use sparse::{sparse_forward, sparse_forward_with_stats, SparseScratch};

/// Borrowed Q/K/V (+ optional key-validity mask) views for one kernel
/// invocation. Per-head entry points take `[n, head_dim]` slices; the
/// batch driver takes `[batch, heads, n, head_dim]` packs with a
/// `[batch, n]` mask shared across heads.
#[derive(Clone, Copy, Debug)]
pub struct HeadViews<'a> {
    /// Queries.
    pub q: &'a [f32],
    /// Keys.
    pub k: &'a [f32],
    /// Values.
    pub v: &'a [f32],
    /// Per-key validity (> 0.0 ⇒ admissible); `None` means all valid.
    pub key_valid: Option<&'a [f32]>,
}

impl HeadViews<'_> {
    /// Assert the per-head invariants for an `[n, head_dim]` problem.
    pub(crate) fn check(&self, n: usize, head_dim: usize) {
        assert_eq!(self.q.len(), n * head_dim, "q must be [n, head_dim]");
        assert_eq!(self.k.len(), n * head_dim, "k must be [n, head_dim]");
        assert_eq!(self.v.len(), n * head_dim, "v must be [n, head_dim]");
        if let Some(mask) = self.key_valid {
            assert_eq!(mask.len(), n, "key_valid must be [n]");
        }
    }
}

/// Dot product of two equal-length rows — retained **only** as the
/// test suite's scalar reference for the tiled [`microkernel`] layer;
/// production kernels no longer call it.
#[cfg(test)]
pub(crate) fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}
