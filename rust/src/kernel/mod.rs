//! Native block-sparse attention kernels: BigBird compute in pure Rust.
//!
//! The rest of the stack *describes* the paper's band + global + random
//! pattern ([`crate::attention::build_pattern`]) and executes it through
//! opaque PJRT artifacts; this subsystem **computes** it, so the
//! linear-vs-quadratic claim is measurable — and servable — on any
//! machine with no AOT artifacts at all:
//!
//! * [`layout`] — [`BlockCsr`], the pattern compiled into a
//!   gather-friendly block-level CSR with per-entry provenance;
//! * [`dense`] — the blocked dense masked reference kernel (two-pass
//!   softmax), the correctness oracle;
//! * [`microkernel`] — the SIMD-tiled microkernels every block-level
//!   computation routes through: register-blocked QKᵀ tile GEMM with
//!   fused scale+mask, tiled AV accumulate, transpose packing, and
//!   lane-partial row dots (no unsafe, autovectorizer-friendly fixed
//!   lanes);
//! * [`sparse`] — the production kernel: gathered QKᵀ → streaming
//!   (flash-style) softmax → gathered AV accumulate, with reusable
//!   [`SparseScratch`] buffers;
//! * [`driver`] — the persistent [`KernelPool`] worker-thread pool
//!   (per-thread scratch arenas, shared by every caller) and the
//!   batch fan-out of `batch × heads` head problems over it, for both
//!   forward and backward;
//! * [`model`] — a deterministic scaled-down BigBird MLM forward pass
//!   ([`NativeModel`]) and the engine-worker wrapper
//!   ([`NativeEngine`]) behind `BackendKind::Native`;
//! * [`grad`] — reverse-mode gradients: flash-style sparse-attention
//!   backward, whole-model tape, [`grad::ParamGrads`], masked-LM loss,
//!   and the [`grad::AdamW`] optimizer powering `train --backends
//!   native`;
//! * [`calibrate`] — the self-calibration micro-probes: the roofline
//!   that seeds the native backend's dispatch model, the per-precision
//!   GEMM tile-shape auto-tuner, and the SIMD-vectorization floor
//!   check behind `kernel-probe --assert-simd`;
//! * [`reference`] — always-compiled precision-generic scalar
//!   references (naive dot/matmul plus quantized variants), the
//!   oracles every parity test compares the tiles against.
//!
//! `tests/kernel_parity.rs` property-tests sparse-vs-dense agreement
//! (≤ 1e-5) across random [`crate::attention::PatternSpec`]s,
//! `tests/native_training.rs` gradient-checks the backward subsystem,
//! and `benches/attention_scaling.rs` measures the sub-quadratic
//! scaling.

pub mod calibrate;
pub mod dense;
pub mod driver;
pub mod grad;
pub mod layout;
pub mod microkernel;
pub mod model;
pub mod reference;
pub mod sparse;

pub use calibrate::{
    assert_simd_floor, native_roofline, simd_probe, tuned_tile, tuned_tiles, SimdProbe, TileChoice,
    TileTable, MIN_SIMD_RATIO,
};
pub use dense::dense_reference;
pub use driver::{
    model_gemm, model_gemm_acc, sparse_backward_batch, sparse_backward_batch_heads,
    sparse_forward_batch, sparse_forward_batch_heads, sparse_forward_batch_training,
    sparse_forward_batch_training_heads, with_select_cache, KernelPool, ScratchArena, SelectCache,
};
pub use layout::{BlockCsr, BlockProvenance};
pub use microkernel::{
    av_tile, f16_to_f32, f32_to_f16, gemm_packed, gemm_packed_with, pack_transposed, qk_tile,
    quantize_rows, row_dots, GemmScratch, PackedMat, TileShape, LANES, MR,
};
pub use model::{
    config_fingerprint, is_native_artifact, native_artifact_name, native_buckets,
    param_count_for, parse_native_artifact, NativeEngine, NativeModel, NATIVE_PARAMS_ARTIFACT,
    NATIVE_PREFIX,
};
pub use sparse::{sparse_forward, sparse_forward_with_stats, SparseScratch};

/// Borrowed Q/K/V (+ optional key-validity mask) views for one kernel
/// invocation. Per-head entry points take `[n, head_dim]` slices; the
/// batch driver takes `[batch, heads, n, head_dim]` packs with a
/// `[batch, n]` mask shared across heads.
#[derive(Clone, Copy, Debug)]
pub struct HeadViews<'a> {
    /// Queries.
    pub q: &'a [f32],
    /// Keys.
    pub k: &'a [f32],
    /// Values.
    pub v: &'a [f32],
    /// Per-key validity (> 0.0 ⇒ admissible); `None` means all valid.
    pub key_valid: Option<&'a [f32]>,
}

impl HeadViews<'_> {
    /// Assert the per-head invariants for an `[n, head_dim]` problem.
    pub(crate) fn check(&self, n: usize, head_dim: usize) {
        assert_eq!(self.q.len(), n * head_dim, "q must be [n, head_dim]");
        assert_eq!(self.k.len(), n * head_dim, "k must be [n, head_dim]");
        assert_eq!(self.v.len(), n * head_dim, "v must be [n, head_dim]");
        if let Some(mask) = self.key_valid {
            assert_eq!(mask.len(), n, "key_valid must be [n]");
        }
    }
}
