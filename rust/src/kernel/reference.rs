//! Precision-generic scalar references: the naive formulations every
//! tiled kernel is tested against. Deliberately the simplest possible
//! loops — they share no code with the microkernels, so agreement is
//! meaningful. Always compiled (not `#[cfg(test)]`) because the
//! integration tests in `tests/` link the library crate from outside
//! and could not see test-gated items; production code must still never
//! call these on a hot path (the acceptance gate greps for it).
//!
//! [`matmul_prec`] extends the f32 reference to the packed precisions:
//! it applies the **documented** quantization rules (per-column
//! symmetric weight scales + per-row symmetric activation scales for
//! int8; round-to-nearest-even storage rounding for f16) with
//! independent scalar code, then contracts in the same dequant order as
//! the tiled epilogue — so int8 parity tests can demand exact
//! agreement, not just a tolerance.

use crate::config::Precision;
use crate::kernel::microkernel::{f16_to_f32, f32_to_f16};

/// Scalar dot product of two equal-length rows — the test suite's
/// reference for the lane-partial tiled dots.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// Naive ikj matmul, `out[m, n] = a[m, k] · b[k, n]` — the retired
/// model matmul, kept verbatim as the f32 oracle. Its contraction
/// order (k ascending per output element) is the order the tiled f32
/// kernel reproduces bit-identically.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for t in 0..k {
            let av = a[i * k + t];
            let brow = &b[t * n..(t + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Naive matmul at a packed precision: quantizes/rounds the operands
/// with standalone scalar code implementing the documented scale rules,
/// then contracts naively. The tiled kernels must match this **exactly**
/// for int8 (integer accumulation is order-free) and to f32 rounding
/// noise for f16/f32.
pub fn matmul_prec(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, p: Precision) -> Vec<f32> {
    match p {
        Precision::F32 => matmul_f32_ordered(a, b, m, k, n),
        Precision::F16 => {
            let bh: Vec<f32> = b.iter().map(|&x| f16_to_f32(f32_to_f16(x))).collect();
            matmul_f32_ordered(a, &bh, m, k, n)
        }
        Precision::Int8 => {
            // per-column symmetric weight scales: maxabs/127, 1.0 on
            // all-zero columns
            let mut bscale = vec![0.0f32; n];
            for row in b.chunks_exact(n) {
                for (s, &x) in bscale.iter_mut().zip(row) {
                    *s = s.max(x.abs());
                }
            }
            for s in bscale.iter_mut() {
                *s = if *s > 0.0 { *s / 127.0 } else { 1.0 };
            }
            let bq: Vec<i8> = b
                .iter()
                .enumerate()
                .map(|(idx, &x)| (x / bscale[idx % n]).round().clamp(-127.0, 127.0) as i8)
                .collect();
            let mut out = vec![0.0f32; m * n];
            for i in 0..m {
                // per-row symmetric activation scale
                let row = &a[i * k..(i + 1) * k];
                let maxabs = row.iter().fold(0.0f32, |mx, &v| mx.max(v.abs()));
                let sa = if maxabs > 0.0 { maxabs / 127.0 } else { 1.0 };
                let aq: Vec<i8> =
                    row.iter().map(|&x| (x / sa).round().clamp(-127.0, 127.0) as i8).collect();
                for j in 0..n {
                    let mut acc = 0i32;
                    for (t, &qa) in aq.iter().enumerate() {
                        acc += qa as i32 * bq[t * n + j] as i32;
                    }
                    // dequant order must mirror the tiled epilogue:
                    // (acc as f32) · row_scale · col_scale
                    out[i * n + j] = acc as f32 * sa * bscale[j];
                }
            }
            out
        }
    }
}

/// f32 matmul with the per-output-element k-ascending accumulation
/// order (what the tiled kernels use), as the shared f32/f16 core.
fn matmul_f32_ordered(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0f32;
            for t in 0..k {
                s += a[i * k + t] * b[t * n + j];
            }
            out[i * n + j] = s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn ikj_and_ordered_f32_references_agree_bitwise() {
        // both accumulate each out[i][j] over t ascending in f32, so
        // they are the same sum in the same order
        let mut rng = Rng::new(0xF00D);
        let (m, k, n) = (7, 13, 9);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        assert_eq!(matmul(&a, &b, m, k, n), matmul_f32_ordered(&a, &b, m, k, n));
    }

    #[test]
    fn matmul_prec_f32_is_the_plain_reference() {
        let mut rng = Rng::new(0xBEAD);
        let (m, k, n) = (5, 11, 6);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        assert_eq!(matmul_prec(&a, &b, m, k, n, Precision::F32), matmul(&a, &b, m, k, n));
    }

    #[test]
    fn int8_reference_handles_zero_rows_and_columns() {
        // all-zero activation row and all-zero weight column must both
        // dequantize to exact zeros (scale falls back to 1.0)
        let (m, k, n) = (3, 4, 3);
        let mut a = vec![0.5f32; m * k];
        for t in 0..k {
            a[k + t] = 0.0; // row 1 all zero
        }
        let mut b = vec![0.25f32; k * n];
        for t in 0..k {
            b[t * n + 2] = 0.0; // column 2 all zero
        }
        let out = matmul_prec(&a, &b, m, k, n, Precision::Int8);
        for j in 0..n {
            assert_eq!(out[n + j], 0.0, "zero activation row stays zero");
        }
        for i in 0..m {
            assert_eq!(out[i * n + 2], 0.0, "zero weight column stays zero");
        }
    }
}
