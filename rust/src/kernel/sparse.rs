//! The sparse forward kernel: gathered QKᵀ → streaming softmax →
//! gathered AV accumulate.
//!
//! For each query block the kernel visits only the key blocks stored in
//! the [`BlockCsr`] row (band + global + random), so work is
//! O(n · attended_blocks · block · d) instead of O(n² · d). The softmax
//! is computed **online** (flash-attention style): per query row we
//! keep a running max `m`, running exponential sum `l`, and running
//! output accumulator, rescaling all three by `exp(m_old − m_new)` when
//! a new block raises the max — numerically equivalent to a full
//! softmax without ever materialising an n-length score row.
//!
//! All block-level math runs on the tiled
//! [`microkernel`](super::microkernel) layer — the QKᵀ tile is a
//! register-blocked GEMM against a packed-transposed key block with the
//! score scale and key-validity mask fused into its epilogue, and the
//! AV accumulate is lane-tiled — so the hot loops autovectorize instead
//! of retiring one scalar FLOP per cycle.
//!
//! All intermediate buffers (score tile, packed transpose, softmax
//! statistics, output accumulator) live in a reusable [`SparseScratch`]:
//! a caller that holds its scratch across calls pays no per-block
//! allocation. The batch driver runs on the persistent
//! [`super::driver::KernelPool`], whose worker threads each own a
//! process-lifetime scratch arena reused across every forward *and*
//! backward invocation.

use std::time::Instant;

use super::layout::BlockCsr;
use super::microkernel::{av_tile, pack_transposed, qk_tile};
use super::HeadViews;
use crate::obs::phase::{self, Phase};

/// Reusable per-thread scratch for [`sparse_forward`]: one score tile
/// (reused in place as the weight tile), the packed-transposed key
/// block, the running-softmax statistics, and the output accumulator
/// for a single query block. Grown on demand, never shrunk.
#[derive(Debug, Default)]
pub struct SparseScratch {
    /// `block × block` score tile for the current (qb, kb) pair; after
    /// the streaming-softmax update it holds the exp-weights the AV
    /// microkernel consumes.
    scores: Vec<f32>,
    /// Packed transpose of the current key block, `head_dim × block`.
    kt: Vec<f32>,
    /// Running max per query row of the block.
    m: Vec<f32>,
    /// Running sum of exponentials per query row of the block.
    l: Vec<f32>,
    /// Running (un-normalised) output accumulator, `block × head_dim`.
    acc: Vec<f32>,
}

impl SparseScratch {
    /// Fresh empty scratch (buffers grow on first use).
    pub fn new() -> Self {
        SparseScratch::default()
    }

    fn ensure(&mut self, block: usize, head_dim: usize) {
        self.scores.resize(block * block, 0.0);
        self.kt.resize(head_dim * block, 0.0);
        self.m.resize(block, 0.0);
        self.l.resize(block, 0.0);
        self.acc.resize(block * head_dim, 0.0);
    }
}

/// Block-sparse attention forward for one `[n, head_dim]` head over the
/// attended blocks of `layout`, writing `[n, head_dim]` into `out`.
/// Agrees with [`super::dense::dense_reference`] to ≤ 1e-5 (property
/// tested); rows with no admissible key produce zeros.
pub fn sparse_forward(
    x: &HeadViews<'_>,
    head_dim: usize,
    layout: &BlockCsr,
    scratch: &mut SparseScratch,
    out: &mut [f32],
) {
    forward_core(x, head_dim, layout, scratch, out, &mut [], &mut []);
}

/// Training-mode forward: identical compute (and bit-identical output)
/// to [`sparse_forward`], but additionally saves the **final**
/// streaming-softmax row statistics — the running max `m_out[i]` and
/// exponential sum `l_out[i]` of each query row, both `[n]` — that the
/// backward pass ([`super::grad::sparse_attention_backward`]) needs to
/// recompute the attention probabilities without materialising them.
/// Rows that never saw an admissible key are saved as
/// `(m, l) = (-inf, 0)`.
pub fn sparse_forward_with_stats(
    x: &HeadViews<'_>,
    head_dim: usize,
    layout: &BlockCsr,
    scratch: &mut SparseScratch,
    out: &mut [f32],
    m_out: &mut [f32],
    l_out: &mut [f32],
) {
    let n = layout.seq_len();
    assert_eq!(m_out.len(), n, "m_out must be [n]");
    assert_eq!(l_out.len(), n, "l_out must be [n]");
    forward_core(x, head_dim, layout, scratch, out, m_out, l_out);
}

/// Streaming-softmax update for one `(qb, kb)` score tile, per query
/// row of the block; the score tile becomes the weight tile in place.
#[inline]
fn softmax_update(scratch: &mut SparseScratch, b: usize, head_dim: usize) {
    for i in 0..b {
        let row = &mut scratch.scores[i * b..(i + 1) * b];
        let tile_max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        if tile_max == f32::NEG_INFINITY {
            // whole tile masked for this row: zero weights so the
            // AV microkernel adds nothing
            row.fill(0.0);
            continue;
        }
        let m_new = scratch.m[i].max(tile_max);
        // exp(-inf - finite) = 0: a row seeing its first live
        // tile rescales its (all-zero) statistics by zero
        let alpha = (scratch.m[i] - m_new).exp();
        scratch.l[i] *= alpha;
        let acc_row = &mut scratch.acc[i * head_dim..(i + 1) * head_dim];
        acc_row.iter_mut().for_each(|a| *a *= alpha);
        let mut row_sum = 0.0f32;
        for s in row.iter_mut() {
            // exp(-inf − m_new) = 0: masked keys drop out exactly
            let w = (*s - m_new).exp();
            row_sum += w;
            *s = w;
        }
        scratch.l[i] += row_sum;
        scratch.m[i] = m_new;
    }
}

/// Advance the lap clock: nanoseconds since `*t`, then reset `*t`.
#[inline]
fn lap(t: &mut Instant) -> u64 {
    let now = Instant::now();
    let dt = now.duration_since(*t).as_nanos() as u64;
    *t = now;
    dt
}

/// Shared kernel body: `m_out`/`l_out` are either both `[n]` (training
/// mode — final row statistics are saved) or both empty (serving mode).
///
/// When phase profiling is on, every 8th query block brackets its
/// pack/QKᵀ/softmax/AV microkernel calls with a clock; the sampled
/// busy time is scaled to the whole call by the exact
/// total-tiles / sampled-tiles ratio at flush, while flop/byte totals
/// are analytic over **all** tiles. Off, the cost is one branch per
/// tile.
fn forward_core(
    x: &HeadViews<'_>,
    head_dim: usize,
    layout: &BlockCsr,
    scratch: &mut SparseScratch,
    out: &mut [f32],
    m_out: &mut [f32],
    l_out: &mut [f32],
) {
    let n = layout.seq_len();
    let b = layout.block;
    x.check(n, head_dim);
    assert_eq!(out.len(), n * head_dim, "output must be [n, head_dim]");
    let scale = 1.0 / (head_dim as f32).sqrt();
    scratch.ensure(b, head_dim);
    let prof = phase::enabled();
    let (mut tiles_total, mut tiles_sampled) = (0u64, 0u64);
    let (mut t_pack, mut t_qk, mut t_soft, mut t_av) = (0u64, 0u64, 0u64, 0u64);
    for qb in 0..layout.nb {
        scratch.m.fill(f32::NEG_INFINITY);
        scratch.l.fill(0.0);
        scratch.acc.fill(0.0);
        let qs = layout.token_span(qb);
        let q_block = &x.q[qs.start * head_dim..qs.end * head_dim];
        let sampled = prof && (qb & 7) == 0;
        for &kb in layout.row(qb) {
            let ks = layout.token_span(kb);
            let k_block = &x.k[ks.start * head_dim..ks.end * head_dim];
            let v_block = &x.v[ks.start * head_dim..ks.end * head_dim];
            let valid = x.key_valid.map(|mask| &mask[ks.clone()]);
            // gathered QKᵀ tile for (qb, kb): pack Kᵀ once, then the
            // register-blocked GEMM with scale+mask fused (masked →
            // −inf), the streaming-softmax row pass, and the tiled AV
            // accumulate of the whole weight tile
            if sampled {
                let mut t = Instant::now();
                pack_transposed(k_block, b, head_dim, &mut scratch.kt);
                t_pack += lap(&mut t);
                qk_tile(q_block, &scratch.kt, b, b, head_dim, scale, valid, &mut scratch.scores);
                t_qk += lap(&mut t);
                softmax_update(scratch, b, head_dim);
                t_soft += lap(&mut t);
                av_tile(&scratch.scores, v_block, b, b, head_dim, &mut scratch.acc);
                t_av += lap(&mut t);
                tiles_sampled += 1;
            } else {
                pack_transposed(k_block, b, head_dim, &mut scratch.kt);
                qk_tile(q_block, &scratch.kt, b, b, head_dim, scale, valid, &mut scratch.scores);
                softmax_update(scratch, b, head_dim);
                av_tile(&scratch.scores, v_block, b, b, head_dim, &mut scratch.acc);
            }
        }
        if prof {
            tiles_total += layout.row(qb).len() as u64;
        }
        // normalise and write the block's output rows
        for i in 0..b {
            let o_row = &mut out[(qb * b + i) * head_dim..(qb * b + i + 1) * head_dim];
            let l = scratch.l[i];
            if l > 0.0 {
                let acc_row = &scratch.acc[i * head_dim..(i + 1) * head_dim];
                for (o, &a) in o_row.iter_mut().zip(acc_row) {
                    *o = a / l;
                }
            } else {
                o_row.fill(0.0);
            }
        }
        if !m_out.is_empty() {
            m_out[qb * b..(qb + 1) * b].copy_from_slice(&scratch.m[..b]);
            l_out[qb * b..(qb + 1) * b].copy_from_slice(&scratch.l[..b]);
        }
    }
    if prof && tiles_total > 0 {
        // one flush per kernel call keeps the atomics off the tile loop.
        // Analytic per-tile work: QKᵀ and AV are 2·b²·d flops; the
        // softmax row pass is ~5 flops per score (max, sub, exp, sum,
        // rescale); pack moves one b×d block through a transpose.
        let (bu, du) = (b as u64, head_dim as u64);
        let up = |t: u64| {
            if tiles_sampled > 0 {
                (t as f64 * tiles_total as f64 / tiles_sampled as f64) as u64
            } else {
                0
            }
        };
        phase::record(Phase::Pack, tiles_total, up(t_pack), 0, tiles_total * bu * du * 8);
        phase::record(
            Phase::QkT,
            tiles_total,
            up(t_qk),
            tiles_total * 2 * bu * bu * du,
            tiles_total * (2 * bu * du + bu * bu) * 4,
        );
        phase::record(
            Phase::Softmax,
            tiles_total,
            up(t_soft),
            tiles_total * 5 * bu * bu,
            tiles_total * bu * bu * 8,
        );
        phase::record(
            Phase::Av,
            tiles_total,
            up(t_av),
            tiles_total * 2 * bu * bu * du,
            tiles_total * (bu * bu + 2 * bu * du) * 4,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::PatternSpec;
    use crate::config::AttnVariant;
    use crate::kernel::dense::dense_reference;
    use crate::util::Rng;

    fn data(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn agrees_with_dense_reference_on_bigbird_pattern() {
        let spec = PatternSpec {
            variant: AttnVariant::BigBirdItc,
            nb: 8,
            global_blocks: 1,
            window_blocks: 3,
            random_blocks: 1,
            seed: 5,
        };
        let layout = BlockCsr::compile(&spec, 8);
        let (n, d) = (layout.seq_len(), 16);
        let mut rng = Rng::new(3);
        let q = data(&mut rng, n * d);
        let k = data(&mut rng, n * d);
        let v = data(&mut rng, n * d);
        let x = HeadViews { q: &q, k: &k, v: &v, key_valid: None };
        let mut want = vec![0.0f32; n * d];
        dense_reference(&x, d, &layout, &mut want);
        let mut got = vec![0.0f32; n * d];
        let mut scratch = SparseScratch::new();
        sparse_forward(&x, d, &layout, &mut scratch, &mut got);
        let diff = max_abs_diff(&want, &got);
        assert!(diff <= 1e-5, "max abs diff {diff}");
    }

    #[test]
    fn scratch_reuse_across_shapes_is_clean() {
        // run a big shape, then a smaller one with the same scratch:
        // stale buffer contents must not leak into the result
        let mut rng = Rng::new(9);
        let mut scratch = SparseScratch::new();
        for (nb, block, d) in [(8usize, 8usize, 16usize), (4, 4, 8)] {
            let spec = PatternSpec {
                variant: AttnVariant::BigBirdItc,
                nb,
                global_blocks: 1,
                window_blocks: 1,
                random_blocks: 1,
                seed: 2,
            };
            let layout = BlockCsr::compile(&spec, block);
            let n = layout.seq_len();
            let q = data(&mut rng, n * d);
            let k = data(&mut rng, n * d);
            let v = data(&mut rng, n * d);
            let x = HeadViews { q: &q, k: &k, v: &v, key_valid: None };
            let mut want = vec![0.0f32; n * d];
            dense_reference(&x, d, &layout, &mut want);
            let mut got = vec![0.0f32; n * d];
            sparse_forward(&x, d, &layout, &mut scratch, &mut got);
            assert!(max_abs_diff(&want, &got) <= 1e-5);
        }
    }

    #[test]
    fn stats_variant_matches_plain_forward_and_normalises() {
        let spec = PatternSpec {
            variant: AttnVariant::BigBirdItc,
            nb: 6,
            global_blocks: 1,
            window_blocks: 3,
            random_blocks: 1,
            seed: 13,
        };
        let layout = BlockCsr::compile(&spec, 4);
        let (n, d) = (layout.seq_len(), 8);
        let mut rng = Rng::new(6);
        let q = data(&mut rng, n * d);
        let k = data(&mut rng, n * d);
        let v = data(&mut rng, n * d);
        let x = HeadViews { q: &q, k: &k, v: &v, key_valid: None };
        let mut plain = vec![0.0f32; n * d];
        let mut scratch = SparseScratch::new();
        sparse_forward(&x, d, &layout, &mut scratch, &mut plain);
        let mut with = vec![0.0f32; n * d];
        let mut m = vec![0.0f32; n];
        let mut l = vec![0.0f32; n];
        sparse_forward_with_stats(&x, d, &layout, &mut scratch, &mut with, &mut m, &mut l);
        assert_eq!(plain, with, "stats variant must be bit-identical");
        for i in 0..n {
            // every row attends at least its own (band) block: l must be
            // a genuine softmax denominator and m a finite row max
            assert!(l[i] > 0.0, "row {i}: l = {}", l[i]);
            assert!(m[i].is_finite(), "row {i}: m = {}", m[i]);
            // softmax probabilities recomputed from (m, l) must sum to 1
            let qb = i / 4;
            let q_row = &q[i * d..(i + 1) * d];
            let scale = 1.0 / (d as f32).sqrt();
            let mut sum = 0.0f32;
            for &kb in layout.row(qb) {
                for jj in 0..4 {
                    let kj = kb * 4 + jj;
                    let s = crate::kernel::reference::dot(q_row, &k[kj * d..(kj + 1) * d]) * scale;
                    sum += (s - m[i]).exp() / l[i];
                }
            }
            assert!((sum - 1.0).abs() < 1e-4, "row {i}: probs sum to {sum}");
        }
    }

    #[test]
    fn masked_keys_are_excluded() {
        let spec = PatternSpec {
            variant: AttnVariant::Window,
            nb: 4,
            global_blocks: 0,
            window_blocks: 3,
            random_blocks: 0,
            seed: 0,
        };
        let layout = BlockCsr::compile(&spec, 4);
        let (n, d) = (layout.seq_len(), 8);
        let mut rng = Rng::new(4);
        let q = data(&mut rng, n * d);
        let k = data(&mut rng, n * d);
        // value rows encode their own index so the output reveals which
        // keys contributed
        let mut v = vec![0.0f32; n * d];
        for (kj, row) in v.chunks_mut(d).enumerate() {
            row.fill(kj as f32);
        }
        let mut key_valid = vec![1.0f32; n];
        // only key 5 stays valid: every row attending block 1 must
        // output exactly 5.0
        for (kj, kv) in key_valid.iter_mut().enumerate() {
            if kj != 5 {
                *kv = 0.0;
            }
        }
        let x = HeadViews { q: &q, k: &k, v: &v, key_valid: Some(&key_valid) };
        let mut got = vec![0.0f32; n * d];
        sparse_forward(&x, d, &layout, &mut SparseScratch::new(), &mut got);
        for qi in 0..n {
            let qb = qi / 4;
            let o = got[qi * d];
            if layout.is_attended(qb, 1) {
                assert!((o - 5.0).abs() < 1e-5, "row {qi}: {o}");
            } else {
                assert_eq!(o, 0.0, "row {qi} must be fully masked");
            }
        }
    }
}
