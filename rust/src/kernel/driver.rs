//! Multi-batch / multi-head driver for the sparse kernel.
//!
//! Fans the `batch × heads` independent head problems of one attention
//! layer out over OS threads (`std::thread::scope` fork-join — the
//! `rayon` crate is not vendored in this offline environment, so we
//! hand-roll the same contiguous-chunk work split). Each thread owns
//! one [`SparseScratch`] reused across all of its heads, so a forward
//! pass allocates O(threads) scratch, not O(batch × heads).

use super::layout::BlockCsr;
use super::sparse::{sparse_forward, SparseScratch};
use super::HeadViews;

/// Worker threads for `tasks` (≥ 1) independent head problems: all
/// available cores, capped by the task count (a single task runs
/// inline).
fn thread_count(tasks: usize) -> usize {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    cores.min(tasks)
}

/// Block-sparse attention forward over a `[batch, heads, n, head_dim]`
/// Q/K/V pack (with an optional `[batch, n]` key-validity mask shared
/// across heads), writing the same `[batch, heads, n, head_dim]` layout
/// into `out`. Heads are distributed over threads in contiguous chunks;
/// results are bit-identical to running [`sparse_forward`] per head
/// sequentially.
pub fn sparse_forward_batch(
    x: &HeadViews<'_>,
    batch: usize,
    heads: usize,
    head_dim: usize,
    layout: &BlockCsr,
    out: &mut [f32],
) {
    let n = layout.seq_len();
    let per = n * head_dim;
    let tasks = batch * heads;
    assert_eq!(x.q.len(), tasks * per, "q must be [batch, heads, n, head_dim]");
    assert_eq!(x.k.len(), tasks * per, "k must be [batch, heads, n, head_dim]");
    assert_eq!(x.v.len(), tasks * per, "v must be [batch, heads, n, head_dim]");
    assert_eq!(out.len(), tasks * per, "out must be [batch, heads, n, head_dim]");
    if let Some(mask) = x.key_valid {
        assert_eq!(mask.len(), batch * n, "key_valid must be [batch, n]");
    }
    if tasks == 0 {
        return;
    }

    let run_range = |first_task: usize, chunk: &mut [f32], scratch: &mut SparseScratch| {
        for (i, o) in chunk.chunks_mut(per).enumerate() {
            let task = first_task + i;
            let b = task / heads;
            let off = task * per;
            let hv = HeadViews {
                q: &x.q[off..off + per],
                k: &x.k[off..off + per],
                v: &x.v[off..off + per],
                key_valid: x.key_valid.map(|m| &m[b * n..(b + 1) * n]),
            };
            sparse_forward(&hv, head_dim, layout, scratch, o);
        }
    };

    let nt = thread_count(tasks);
    if nt == 1 {
        run_range(0, out, &mut SparseScratch::new());
        return;
    }
    let base = tasks / nt;
    let extra = tasks % nt;
    std::thread::scope(|s| {
        let mut remaining = out;
        let mut first_task = 0usize;
        for t in 0..nt {
            let count = base + usize::from(t < extra);
            let (chunk, rest) = remaining.split_at_mut(count * per);
            remaining = rest;
            let start = first_task;
            first_task += count;
            let run = &run_range;
            s.spawn(move || run(start, chunk, &mut SparseScratch::new()));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::PatternSpec;
    use crate::config::AttnVariant;
    use crate::util::Rng;

    #[test]
    fn batch_driver_matches_sequential_per_head_runs() {
        let spec = PatternSpec {
            variant: AttnVariant::BigBirdItc,
            nb: 6,
            global_blocks: 1,
            window_blocks: 3,
            random_blocks: 1,
            seed: 8,
        };
        let layout = BlockCsr::compile(&spec, 8);
        let (batch, heads, d) = (3usize, 4usize, 16usize);
        let n = layout.seq_len();
        let per = n * d;
        let mut rng = Rng::new(21);
        let vol = batch * heads * per;
        let q: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
        let key_valid: Vec<f32> =
            (0..batch * n).map(|_| if rng.coin(0.1) { 0.0 } else { 1.0 }).collect();

        let mut got = vec![0.0f32; vol];
        let x = HeadViews { q: &q, k: &k, v: &v, key_valid: Some(&key_valid) };
        sparse_forward_batch(&x, batch, heads, d, &layout, &mut got);

        let mut want = vec![0.0f32; vol];
        let mut scratch = SparseScratch::new();
        for task in 0..batch * heads {
            let b = task / heads;
            let off = task * per;
            let hv = HeadViews {
                q: &q[off..off + per],
                k: &k[off..off + per],
                v: &v[off..off + per],
                key_valid: Some(&key_valid[b * n..(b + 1) * n]),
            };
            sparse_forward(&hv, d, &layout, &mut scratch, &mut want[off..off + per]);
        }
        assert_eq!(got, want, "parallel driver must be bit-identical to sequential");
    }

    #[test]
    fn single_head_single_batch_runs_inline() {
        let spec = PatternSpec {
            variant: AttnVariant::Window,
            nb: 4,
            global_blocks: 0,
            window_blocks: 1,
            random_blocks: 0,
            seed: 0,
        };
        let layout = BlockCsr::compile(&spec, 4);
        let (n, d) = (layout.seq_len(), 8);
        let q = vec![0.5f32; n * d];
        let x = HeadViews { q: &q, k: &q, v: &q, key_valid: None };
        let mut out = vec![0.0f32; n * d];
        sparse_forward_batch(&x, 1, 1, d, &layout, &mut out);
        // constant V ⇒ every output element equals the constant
        assert!(out.iter().all(|&o| (o - 0.5).abs() < 1e-6));
    }
}
