//! Multi-batch / multi-head drivers for the sparse kernels, on a
//! **persistent worker-thread pool**.
//!
//! Earlier revisions spawned `std::thread::scope` threads — and fresh
//! scratch buffers — on *every* forward pass, so N concurrent native
//! engine workers could stand up N × cores short-lived threads at once
//! (core oversubscription) and re-pay the scratch allocations each
//! call. [`KernelPool`] fixes both: one process-wide pool of
//! `available_parallelism` threads, each owning a [`ScratchArena`]
//! (forward [`SparseScratch`] + backward
//! [`AttnGradScratch`](super::grad::AttnGradScratch), which carry the
//! tiled microkernels' per-(query-block, stored-block) pack and tile
//! buffers) that lives for the lifetime of the process and is reused
//! across every forward *and* backward invocation from every caller.
//!
//! Work submission keeps the fork-join shape: a batch call splits its
//! `batch × heads` independent head problems into contiguous chunks,
//! runs one chunk inline on the calling thread (which would otherwise
//! just block), queues the rest, and returns only when every chunk has
//! completed. Results are bit-identical to running the per-head kernel
//! sequentially — each task writes a disjoint output range and the
//! per-head math does not depend on scheduling.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

use super::grad::attention::{sparse_attention_backward, AttnGradScratch};
use super::layout::BlockCsr;
use super::microkernel::{gemm_packed, GemmScratch, PackedMat};
use super::sparse::{sparse_forward, sparse_forward_with_stats, SparseScratch};
use super::HeadViews;
use crate::attention::CompiledPattern;
use crate::obs::phase::{self, Phase};

/// Per-caller cache of the last compiled adaptive/learned pattern,
/// keyed by `PatternSource::fingerprint`: when consecutive forwards
/// select the same graph (the common serving case — unchanged content,
/// unchanged learned scores), the per-head `BlockCsr` compilation is
/// skipped entirely. Lives in the caller's [`ScratchArena`], so each
/// engine worker thread keeps its own hot entry with no locking.
#[derive(Debug, Default)]
pub struct SelectCache {
    key: u64,
    pattern: Option<CompiledPattern>,
}

impl SelectCache {
    /// The cached pattern for `key`, or `build` it and cache it. The
    /// returned value is a cheap clone (per-head `Arc`s).
    pub fn get_or_compile(
        &mut self,
        key: u64,
        build: impl FnOnce() -> CompiledPattern,
    ) -> CompiledPattern {
        if self.key != key || self.pattern.is_none() {
            self.pattern = Some(build());
            self.key = key;
        }
        self.pattern.clone().expect("just populated")
    }

    /// Is `key` the resident entry? (test/metrics hook)
    pub fn holds(&self, key: u64) -> bool {
        self.pattern.is_some() && self.key == key
    }
}

/// Per-thread scratch arena: every pool worker (and every caller
/// thread, for its inline chunk) owns one, reused across calls so the
/// hot path pays zero steady-state allocation.
#[derive(Debug, Default)]
pub struct ScratchArena {
    /// Forward-kernel scratch (score tile + streaming-softmax state).
    pub fwd: SparseScratch,
    /// Backward-kernel scratch (per-row δ values).
    pub bwd: AttnGradScratch,
    /// Packed-GEMM scratch (int8 quantize-on-pack row buffers).
    pub gemm: GemmScratch,
    /// Last compiled adaptive/learned pattern (layout-compile skip).
    pub select: SelectCache,
}

/// Run `f` against the calling thread's [`SelectCache`] (the same
/// arena the caller's inline kernel chunk uses) — the pattern-layout
/// cache hook for `NativeModel::select_pattern`.
pub fn with_select_cache<R>(f: impl FnOnce(&mut SelectCache) -> R) -> R {
    CALLER_ARENA.with(|a| f(&mut a.borrow_mut().select))
}

/// A type-erased unit of pool work.
type Job = Box<dyn FnOnce(&mut ScratchArena) + Send + 'static>;

/// Barrier state for one [`KernelPool::run`] call.
struct Pending {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

/// Decrements the pending counter when a task finishes — **including**
/// when it unwinds, so a panicking task can never deadlock the caller.
struct DoneGuard(Arc<Pending>);

impl Drop for DoneGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.panicked.store(true, Ordering::SeqCst);
        }
        let mut remaining = self.0.remaining.lock().unwrap_or_else(|e| e.into_inner());
        *remaining -= 1;
        if *remaining == 0 {
            self.0.done.notify_all();
        }
    }
}

thread_local! {
    /// Arena for the chunk a caller runs inline on its own thread.
    static CALLER_ARENA: RefCell<ScratchArena> = RefCell::new(ScratchArena::default());
}

/// The process-wide persistent kernel thread pool.
pub struct KernelPool {
    /// Job queue inlet. Behind a mutex so the pool is `Sync` on every
    /// supported toolchain (sends are a pointer handoff — the lock is
    /// never held for real work).
    tx: Mutex<Sender<Job>>,
    size: usize,
}

static POOL: OnceLock<KernelPool> = OnceLock::new();

impl KernelPool {
    /// The shared pool, spawned on first use with one worker per
    /// available core. All native engine workers funnel through it, so
    /// concurrent forwards/backwards share — rather than multiply — the
    /// machine's cores.
    pub fn global() -> &'static KernelPool {
        POOL.get_or_init(|| {
            let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            KernelPool::new(cores)
        })
    }

    fn new(size: usize) -> Self {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..size {
            let rx: Arc<Mutex<Receiver<Job>>> = rx.clone();
            std::thread::Builder::new()
                .name(format!("bigbird-kernel-{i}"))
                .spawn(move || {
                    let mut arena = ScratchArena::default();
                    loop {
                        // hold the lock only for the handoff; a worker
                        // executing a job never blocks its siblings
                        let job = {
                            let guard = match rx.lock() {
                                Ok(g) => g,
                                Err(_) => return,
                            };
                            match guard.recv() {
                                Ok(j) => j,
                                Err(_) => return, // pool dropped
                            }
                        };
                        // a panicking job must not kill the pool thread;
                        // the job's DoneGuard records the panic and the
                        // submitting `run` call re-raises it
                        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            job(&mut arena)
                        }));
                    }
                })
                .expect("spawning kernel pool worker");
        }
        KernelPool { tx: Mutex::new(tx), size }
    }

    /// Number of pool worker threads.
    pub fn threads(&self) -> usize {
        self.size
    }

    /// Run `tasks` to completion: the last task executes inline on the
    /// calling thread (with its thread-local arena), the rest on pool
    /// workers. Blocks until **all** tasks have finished, then
    /// propagates any task panic.
    #[allow(clippy::type_complexity)]
    pub fn run<'s>(&self, mut tasks: Vec<Box<dyn FnOnce(&mut ScratchArena) + Send + 's>>) {
        let Some(inline_task) = tasks.pop() else {
            return;
        };
        let pending = Arc::new(Pending {
            remaining: Mutex::new(tasks.len()),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        for task in tasks {
            let guard = DoneGuard(pending.clone());
            let job: Box<dyn FnOnce(&mut ScratchArena) + Send + 's> = Box::new(move |arena| {
                let _guard = guard;
                task(arena);
            });
            // SAFETY: `run` does not return until the pending counter
            // hits zero (and the inline task finishes), i.e. until every
            // queued job has been executed and dropped — the caller's
            // borrows captured in `job` strictly outlive all uses. This
            // lifetime erasure is the standard scoped-thread-pool
            // construction (the queue requires 'static, the barrier
            // restores the scoped guarantee).
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce(&mut ScratchArena) + Send + 's>, Job>(job)
            };
            self.tx
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .send(job)
                .expect("kernel pool workers live for the process lifetime");
        }
        let inline_result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            CALLER_ARENA.with(|a| inline_task(&mut a.borrow_mut()));
        }));
        let mut remaining = pending.remaining.lock().unwrap_or_else(|e| e.into_inner());
        while *remaining > 0 {
            remaining = pending.done.wait(remaining).unwrap_or_else(|e| e.into_inner());
        }
        drop(remaining);
        if let Err(p) = inline_result {
            std::panic::resume_unwind(p);
        }
        if pending.panicked.load(Ordering::SeqCst) {
            panic!("kernel pool task panicked");
        }
    }
}

/// Contiguous-chunk split of `tasks` over at most `threads` workers:
/// `(first_task, count)` per chunk, identical to the pre-pool fork-join
/// split so scheduling stays deterministic-shaped.
fn chunks(tasks: usize, threads: usize) -> Vec<(usize, usize)> {
    let nt = threads.min(tasks).max(1);
    let base = tasks / nt;
    let extra = tasks % nt;
    let mut out = Vec::with_capacity(nt);
    let mut first = 0usize;
    for t in 0..nt {
        let count = base + usize::from(t < extra);
        out.push((first, count));
        first += count;
    }
    out
}

/// Below this many multiply-accumulates a model GEMM runs inline on the
/// calling thread: the pool handoff (~µs) would cost more than the math
/// saves, and tiny GEMMs (per-step repacks, small ladders) stay cheap.
const INLINE_MACS: usize = 32_768;

/// Model GEMM `out[m, n] = a[m, k] · b` over the persistent pool: rows
/// are split into contiguous chunks, each computed independently through
/// [`gemm_packed`] with the worker's arena scratch. Row chunking never
/// changes results — every output element is one complete k-ascending
/// sum regardless of which thread computes it, so the parallel product
/// is bit-identical to the single-thread one (and, at f32, to the naive
/// reference). Small problems run inline (see [`INLINE_MACS`]).
pub fn model_gemm(a: &[f32], b: &PackedMat, m: usize, out: &mut [f32]) {
    model_gemm_core(a, b, m, false, out);
}

/// [`model_gemm`] accumulating into `out` (`+=`) — the `dW`-shaped
/// backward contractions.
pub fn model_gemm_acc(a: &[f32], b: &PackedMat, m: usize, out: &mut [f32]) {
    model_gemm_core(a, b, m, true, out);
}

fn model_gemm_core(a: &[f32], b: &PackedMat, m: usize, acc: bool, out: &mut [f32]) {
    let (k, n) = (b.k(), b.n());
    assert_eq!(a.len(), m * k, "a must be [m, k]");
    assert_eq!(out.len(), m * n, "out must be [m, n]");
    if m == 0 {
        return;
    }
    let prof = phase::enabled();
    let pool = KernelPool::global();
    if pool.threads() <= 1 || m * n * k < INLINE_MACS {
        let t0 = if prof { Some(Instant::now()) } else { None };
        CALLER_ARENA.with(|ar| gemm_packed(a, b, m, acc, &mut ar.borrow_mut().gemm, out));
        if let Some(t0) = t0 {
            record_gemm(m, k, n, t0.elapsed().as_nanos() as u64);
        }
        return;
    }
    let mut jobs: Vec<Box<dyn FnOnce(&mut ScratchArena) + Send + '_>> = Vec::new();
    let mut out_rest = out;
    for (first_row, count) in chunks(m, pool.threads()) {
        let (out_chunk, rest) = out_rest.split_at_mut(count * n);
        out_rest = rest;
        let a_chunk = &a[first_row * k..(first_row + count) * k];
        jobs.push(Box::new(move |arena: &mut ScratchArena| {
            // each chunk times itself, so the phase accumulator sums
            // per-thread busy time (comparable to a per-core roofline),
            // not the fork-join wall clock
            let t0 = if prof { Some(Instant::now()) } else { None };
            gemm_packed(a_chunk, b, count, acc, &mut arena.gemm, out_chunk);
            if let Some(t0) = t0 {
                record_gemm(count, k, n, t0.elapsed().as_nanos() as u64);
            }
        }));
    }
    pool.run(jobs);
}

/// Fold one executed `[m, k]·[k, n]` GEMM (or row chunk) into the
/// [`Phase::Gemm`] accumulator: 2·m·k·n flops; A, B, and C traffic at
/// f32 width (every row chunk reads all of B, so per-chunk B bytes are
/// real traffic, not double counting).
fn record_gemm(m: usize, k: usize, n: usize, nanos: u64) {
    let (m, k, n) = (m as u64, k as u64, n as u64);
    phase::record(Phase::Gemm, 1, nanos, 2 * m * k * n, (m * k + k * n + m * n) * 4);
}

/// Which `BlockCsr` each `batch × heads` task computes against: one
/// shared layout (the static pattern) or the per-head layouts of a
/// [`CompiledPattern`]. Keeps the fan-out logic below identical for
/// both shapes.
#[derive(Clone, Copy)]
enum LayoutSel<'a> {
    Shared(&'a BlockCsr),
    PerHead(&'a CompiledPattern),
}

impl<'a> LayoutSel<'a> {
    /// The layout of flat task index `task` (`task % heads` is the head).
    fn of(&self, task: usize, heads: usize) -> &'a BlockCsr {
        match self {
            LayoutSel::Shared(l) => l,
            LayoutSel::PerHead(p) => p.head(task % heads),
        }
    }

    /// Any layout — for shape facts (`nb`, `block`, `seq_len`) that the
    /// per-head constructor guarantees are uniform.
    fn any(&self) -> &'a BlockCsr {
        match self {
            LayoutSel::Shared(l) => l,
            LayoutSel::PerHead(p) => p.head(0),
        }
    }
}

/// Block-sparse attention forward over a `[batch, heads, n, head_dim]`
/// Q/K/V pack (with an optional `[batch, n]` key-validity mask shared
/// across heads), writing the same `[batch, heads, n, head_dim]` layout
/// into `out`. Head problems are distributed over the persistent
/// [`KernelPool`] in contiguous chunks; results are bit-identical to
/// running [`sparse_forward`] per head sequentially.
pub fn sparse_forward_batch(
    x: &HeadViews<'_>,
    batch: usize,
    heads: usize,
    head_dim: usize,
    layout: &BlockCsr,
    out: &mut [f32],
) {
    forward_batch_core(x, batch, heads, head_dim, LayoutSel::Shared(layout), out, &mut [], &mut []);
}

/// [`sparse_forward_batch`] over a [`CompiledPattern`]: each head runs
/// against its own layout (adaptive/learned sources); a shared pattern
/// degenerates to the single-layout path bit-for-bit.
pub fn sparse_forward_batch_heads(
    x: &HeadViews<'_>,
    batch: usize,
    heads: usize,
    head_dim: usize,
    pattern: &CompiledPattern,
    out: &mut [f32],
) {
    forward_batch_core(
        x,
        batch,
        heads,
        head_dim,
        LayoutSel::PerHead(pattern),
        out,
        &mut [],
        &mut [],
    );
}

/// Training-mode batch forward: like [`sparse_forward_batch`] but also
/// saves the per-row softmax statistics `m`/`l` (each
/// `[batch × heads × n]`, laid out task-major exactly like `out`'s
/// leading dims) for the backward pass. Output is bit-identical to the
/// serving forward.
#[allow(clippy::too_many_arguments)]
pub fn sparse_forward_batch_training(
    x: &HeadViews<'_>,
    batch: usize,
    heads: usize,
    head_dim: usize,
    layout: &BlockCsr,
    out: &mut [f32],
    m: &mut [f32],
    l: &mut [f32],
) {
    let n = layout.seq_len();
    assert_eq!(m.len(), batch * heads * n, "m must be [batch × heads × n]");
    assert_eq!(l.len(), batch * heads * n, "l must be [batch × heads × n]");
    forward_batch_core(x, batch, heads, head_dim, LayoutSel::Shared(layout), out, m, l);
}

/// [`sparse_forward_batch_training`] over a [`CompiledPattern`] (one
/// layout per head).
#[allow(clippy::too_many_arguments)]
pub fn sparse_forward_batch_training_heads(
    x: &HeadViews<'_>,
    batch: usize,
    heads: usize,
    head_dim: usize,
    pattern: &CompiledPattern,
    out: &mut [f32],
    m: &mut [f32],
    l: &mut [f32],
) {
    let n = pattern.seq_len();
    assert_eq!(m.len(), batch * heads * n, "m must be [batch × heads × n]");
    assert_eq!(l.len(), batch * heads * n, "l must be [batch × heads × n]");
    forward_batch_core(x, batch, heads, head_dim, LayoutSel::PerHead(pattern), out, m, l);
}

/// Shared forward fan-out; `m`/`l` are both `[batch × heads × n]`
/// (training) or both empty (serving).
#[allow(clippy::too_many_arguments)]
fn forward_batch_core(
    x: &HeadViews<'_>,
    batch: usize,
    heads: usize,
    head_dim: usize,
    sel: LayoutSel<'_>,
    out: &mut [f32],
    m: &mut [f32],
    l: &mut [f32],
) {
    let n = sel.any().seq_len();
    let per = n * head_dim;
    let tasks = batch * heads;
    assert_eq!(x.q.len(), tasks * per, "q must be [batch, heads, n, head_dim]");
    assert_eq!(x.k.len(), tasks * per, "k must be [batch, heads, n, head_dim]");
    assert_eq!(x.v.len(), tasks * per, "v must be [batch, heads, n, head_dim]");
    assert_eq!(out.len(), tasks * per, "out must be [batch, heads, n, head_dim]");
    if let Some(mask) = x.key_valid {
        assert_eq!(mask.len(), batch * n, "key_valid must be [batch, n]");
    }
    if tasks == 0 {
        return;
    }
    let with_stats = !m.is_empty();
    let pool = KernelPool::global();
    let mut jobs: Vec<Box<dyn FnOnce(&mut ScratchArena) + Send + '_>> = Vec::new();
    let mut out_rest = out;
    let mut m_rest = m;
    let mut l_rest = l;
    for (first_task, count) in chunks(tasks, pool.threads()) {
        let (out_chunk, rest) = out_rest.split_at_mut(count * per);
        out_rest = rest;
        let stat_len = if with_stats { count * n } else { 0 };
        let (m_chunk, rest) = m_rest.split_at_mut(stat_len);
        m_rest = rest;
        let (l_chunk, rest) = l_rest.split_at_mut(stat_len);
        l_rest = rest;
        jobs.push(Box::new(move |arena: &mut ScratchArena| {
            for (i, o) in out_chunk.chunks_mut(per).enumerate() {
                let task = first_task + i;
                let b = task / heads;
                let off = task * per;
                let layout = sel.of(task, heads);
                let hv = HeadViews {
                    q: &x.q[off..off + per],
                    k: &x.k[off..off + per],
                    v: &x.v[off..off + per],
                    key_valid: x.key_valid.map(|mm| &mm[b * n..(b + 1) * n]),
                };
                if with_stats {
                    sparse_forward_with_stats(
                        &hv,
                        head_dim,
                        layout,
                        &mut arena.fwd,
                        o,
                        &mut m_chunk[i * n..(i + 1) * n],
                        &mut l_chunk[i * n..(i + 1) * n],
                    );
                } else {
                    sparse_forward(&hv, head_dim, layout, &mut arena.fwd, o);
                }
            }
        }));
    }
    pool.run(jobs);
}

/// Backward of block-sparse attention over a full
/// `[batch, heads, n, head_dim]` pack: fans the per-head
/// [`sparse_attention_backward`] problems over the persistent pool.
/// `o`/`d_o` are the forward output and its upstream gradient (same
/// layout as `x`), `m`/`l` the saved statistics from
/// [`sparse_forward_batch_training`]. `dq`/`dk`/`dv` are fully
/// overwritten. Bit-identical to the sequential per-head backward.
#[allow(clippy::too_many_arguments)]
pub fn sparse_backward_batch(
    x: &HeadViews<'_>,
    o: &[f32],
    d_o: &[f32],
    m: &[f32],
    l: &[f32],
    batch: usize,
    heads: usize,
    head_dim: usize,
    layout: &BlockCsr,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    backward_batch_core(x, o, d_o, m, l, batch, heads, head_dim, LayoutSel::Shared(layout), dq, dk, dv);
}

/// [`sparse_backward_batch`] over a [`CompiledPattern`] (one layout per
/// head) — the training backward of adaptive/learned patterns.
#[allow(clippy::too_many_arguments)]
pub fn sparse_backward_batch_heads(
    x: &HeadViews<'_>,
    o: &[f32],
    d_o: &[f32],
    m: &[f32],
    l: &[f32],
    batch: usize,
    heads: usize,
    head_dim: usize,
    pattern: &CompiledPattern,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    backward_batch_core(
        x,
        o,
        d_o,
        m,
        l,
        batch,
        heads,
        head_dim,
        LayoutSel::PerHead(pattern),
        dq,
        dk,
        dv,
    );
}

#[allow(clippy::too_many_arguments)]
fn backward_batch_core(
    x: &HeadViews<'_>,
    o: &[f32],
    d_o: &[f32],
    m: &[f32],
    l: &[f32],
    batch: usize,
    heads: usize,
    head_dim: usize,
    sel: LayoutSel<'_>,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    let n = sel.any().seq_len();
    let per = n * head_dim;
    let tasks = batch * heads;
    assert_eq!(x.q.len(), tasks * per, "q must be [batch, heads, n, head_dim]");
    assert_eq!(o.len(), tasks * per, "o must be [batch, heads, n, head_dim]");
    assert_eq!(d_o.len(), tasks * per, "d_o must be [batch, heads, n, head_dim]");
    assert_eq!(m.len(), tasks * n, "m must be [batch × heads × n]");
    assert_eq!(l.len(), tasks * n, "l must be [batch × heads × n]");
    assert_eq!(dq.len(), tasks * per, "dq must be [batch, heads, n, head_dim]");
    assert_eq!(dk.len(), tasks * per, "dk must be [batch, heads, n, head_dim]");
    assert_eq!(dv.len(), tasks * per, "dv must be [batch, heads, n, head_dim]");
    if tasks == 0 {
        return;
    }
    let prof = phase::enabled();
    let pool = KernelPool::global();
    let mut jobs: Vec<Box<dyn FnOnce(&mut ScratchArena) + Send + '_>> = Vec::new();
    let mut dq_rest = dq;
    let mut dk_rest = dk;
    let mut dv_rest = dv;
    for (first_task, count) in chunks(tasks, pool.threads()) {
        let (dq_chunk, rest) = dq_rest.split_at_mut(count * per);
        dq_rest = rest;
        let (dk_chunk, rest) = dk_rest.split_at_mut(count * per);
        dk_rest = rest;
        let (dv_chunk, rest) = dv_rest.split_at_mut(count * per);
        dv_rest = rest;
        jobs.push(Box::new(move |arena: &mut ScratchArena| {
            let t0 = if prof { Some(Instant::now()) } else { None };
            // attended tiles across the chunk's tasks — the analytic
            // flop model below charges ~10·b²·d flops per tile (QKᵀ
            // recompute, dV, dP, dQ, dK contractions) and Q/K/V/O/dO
            // reads + dQ/dK/dV accumulator traffic
            let mut tiles = 0u64;
            for i in 0..count {
                let task = first_task + i;
                let b = task / heads;
                let off = task * per;
                let layout = sel.of(task, heads);
                if prof {
                    tiles += (0..layout.nb).map(|qb| layout.row(qb).len() as u64).sum::<u64>();
                }
                let hv = HeadViews {
                    q: &x.q[off..off + per],
                    k: &x.k[off..off + per],
                    v: &x.v[off..off + per],
                    key_valid: x.key_valid.map(|mm| &mm[b * n..(b + 1) * n]),
                };
                sparse_attention_backward(
                    &hv,
                    &o[off..off + per],
                    &d_o[off..off + per],
                    &m[task * n..(task + 1) * n],
                    &l[task * n..(task + 1) * n],
                    head_dim,
                    layout,
                    &mut arena.bwd,
                    &mut dq_chunk[i * per..(i + 1) * per],
                    &mut dk_chunk[i * per..(i + 1) * per],
                    &mut dv_chunk[i * per..(i + 1) * per],
                );
            }
            if let Some(t0) = t0 {
                let (bu, du) = (sel.any().block as u64, head_dim as u64);
                phase::record(
                    Phase::Backward,
                    count as u64,
                    t0.elapsed().as_nanos() as u64,
                    tiles * 10 * bu * bu * du,
                    tiles * (11 * bu * du + 2 * bu * bu) * 4,
                );
            }
        }));
    }
    pool.run(jobs);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::PatternSpec;
    use crate::config::AttnVariant;
    use crate::util::Rng;

    #[test]
    fn batch_driver_matches_sequential_per_head_runs() {
        let spec = PatternSpec {
            variant: AttnVariant::BigBirdItc,
            nb: 6,
            global_blocks: 1,
            window_blocks: 3,
            random_blocks: 1,
            seed: 8,
        };
        let layout = BlockCsr::compile(&spec, 8);
        let (batch, heads, d) = (3usize, 4usize, 16usize);
        let n = layout.seq_len();
        let per = n * d;
        let mut rng = Rng::new(21);
        let vol = batch * heads * per;
        let q: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
        let key_valid: Vec<f32> =
            (0..batch * n).map(|_| if rng.coin(0.1) { 0.0 } else { 1.0 }).collect();

        let mut got = vec![0.0f32; vol];
        let x = HeadViews { q: &q, k: &k, v: &v, key_valid: Some(&key_valid) };
        sparse_forward_batch(&x, batch, heads, d, &layout, &mut got);

        let mut want = vec![0.0f32; vol];
        let mut scratch = SparseScratch::new();
        for task in 0..batch * heads {
            let b = task / heads;
            let off = task * per;
            let hv = HeadViews {
                q: &q[off..off + per],
                k: &k[off..off + per],
                v: &v[off..off + per],
                key_valid: Some(&key_valid[b * n..(b + 1) * n]),
            };
            sparse_forward(&hv, d, &layout, &mut scratch, &mut want[off..off + per]);
        }
        assert_eq!(got, want, "pooled driver must be bit-identical to sequential");
    }

    #[test]
    fn training_forward_matches_serving_and_per_head_stats() {
        let spec = PatternSpec {
            variant: AttnVariant::BigBirdItc,
            nb: 4,
            global_blocks: 1,
            window_blocks: 1,
            random_blocks: 1,
            seed: 3,
        };
        let layout = BlockCsr::compile(&spec, 4);
        let (batch, heads, d) = (2usize, 3usize, 8usize);
        let n = layout.seq_len();
        let per = n * d;
        let vol = batch * heads * per;
        let mut rng = Rng::new(5);
        let q: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
        let x = HeadViews { q: &q, k: &k, v: &v, key_valid: None };

        let mut serving = vec![0.0f32; vol];
        sparse_forward_batch(&x, batch, heads, d, &layout, &mut serving);

        let mut training = vec![0.0f32; vol];
        let mut m = vec![0.0f32; batch * heads * n];
        let mut l = vec![0.0f32; batch * heads * n];
        sparse_forward_batch_training(&x, batch, heads, d, &layout, &mut training, &mut m, &mut l);
        assert_eq!(serving, training, "training forward must be bit-identical");

        // stats must agree with a sequential per-head stats run
        let mut scratch = SparseScratch::new();
        for task in 0..batch * heads {
            let off = task * per;
            let hv = HeadViews {
                q: &q[off..off + per],
                k: &k[off..off + per],
                v: &v[off..off + per],
                key_valid: None,
            };
            let mut o = vec![0.0f32; per];
            let mut mm = vec![0.0f32; n];
            let mut ll = vec![0.0f32; n];
            sparse_forward_with_stats(&hv, d, &layout, &mut scratch, &mut o, &mut mm, &mut ll);
            assert_eq!(&m[task * n..(task + 1) * n], mm.as_slice(), "task {task} m");
            assert_eq!(&l[task * n..(task + 1) * n], ll.as_slice(), "task {task} l");
        }
    }

    #[test]
    fn backward_batch_matches_sequential_per_head_runs() {
        let spec = PatternSpec {
            variant: AttnVariant::BigBirdItc,
            nb: 5,
            global_blocks: 1,
            window_blocks: 3,
            random_blocks: 1,
            seed: 9,
        };
        let layout = BlockCsr::compile(&spec, 4);
        let (batch, heads, d) = (2usize, 4usize, 8usize);
        let n = layout.seq_len();
        let per = n * d;
        let vol = batch * heads * per;
        let mut rng = Rng::new(77);
        let q: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
        let d_o: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
        let key_valid: Vec<f32> =
            (0..batch * n).map(|_| if rng.coin(0.15) { 0.0 } else { 1.0 }).collect();
        let x = HeadViews { q: &q, k: &k, v: &v, key_valid: Some(&key_valid) };

        let mut o = vec![0.0f32; vol];
        let mut m = vec![0.0f32; batch * heads * n];
        let mut l = vec![0.0f32; batch * heads * n];
        sparse_forward_batch_training(&x, batch, heads, d, &layout, &mut o, &mut m, &mut l);

        let mut dq = vec![0.0f32; vol];
        let mut dk = vec![0.0f32; vol];
        let mut dv = vec![0.0f32; vol];
        sparse_backward_batch(
            &x, &o, &d_o, &m, &l, batch, heads, d, &layout, &mut dq, &mut dk, &mut dv,
        );

        let mut scratch = AttnGradScratch::new();
        for task in 0..batch * heads {
            let b = task / heads;
            let off = task * per;
            let hv = HeadViews {
                q: &q[off..off + per],
                k: &k[off..off + per],
                v: &v[off..off + per],
                key_valid: Some(&key_valid[b * n..(b + 1) * n]),
            };
            let (mut sq, mut sk, mut sv) =
                (vec![0.0f32; per], vec![0.0f32; per], vec![0.0f32; per]);
            sparse_attention_backward(
                &hv,
                &o[off..off + per],
                &d_o[off..off + per],
                &m[task * n..(task + 1) * n],
                &l[task * n..(task + 1) * n],
                d,
                &layout,
                &mut scratch,
                &mut sq,
                &mut sk,
                &mut sv,
            );
            assert_eq!(&dq[off..off + per], sq.as_slice(), "task {task} dq");
            assert_eq!(&dk[off..off + per], sk.as_slice(), "task {task} dk");
            assert_eq!(&dv[off..off + per], sv.as_slice(), "task {task} dv");
        }
    }

    #[test]
    fn per_head_driver_matches_sequential_per_head_layouts() {
        use crate::attention::PatternSource;
        use std::sync::Arc;
        // two heads with *different* selected blocks: the _heads driver
        // must route each task to its head's layout, bit-identically to
        // a sequential per-head run
        let spec = PatternSpec {
            variant: AttnVariant::BigBirdItc,
            nb: 6,
            global_blocks: 1,
            window_blocks: 1,
            random_blocks: 1,
            seed: 2,
        };
        let nb = spec.nb;
        let mut s0 = vec![0.0f32; nb * nb];
        let mut s1 = vec![0.0f32; nb * nb];
        for j in 0..nb {
            s0[j * nb + (j + 2) % nb] = 1.0;
            s1[j * nb + (j + 3) % nb] = 1.0;
        }
        let src = PatternSource::Adaptive { spec, k: 1, scores: vec![s0, s1] };
        let pattern = src.compile(4);
        assert!(pattern.is_per_head());

        let (batch, heads, d) = (2usize, 2usize, 8usize);
        let n = pattern.seq_len();
        let per = n * d;
        let vol = batch * heads * per;
        let mut rng = Rng::new(31);
        let q: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
        let x = HeadViews { q: &q, k: &k, v: &v, key_valid: None };

        let mut got = vec![0.0f32; vol];
        sparse_forward_batch_heads(&x, batch, heads, d, &pattern, &mut got);

        let mut want = vec![0.0f32; vol];
        let mut scratch = SparseScratch::new();
        for task in 0..batch * heads {
            let off = task * per;
            let hv = HeadViews {
                q: &q[off..off + per],
                k: &k[off..off + per],
                v: &v[off..off + per],
                key_valid: None,
            };
            let layout = pattern.head(task % heads);
            sparse_forward(&hv, d, layout, &mut scratch, &mut want[off..off + per]);
        }
        assert_eq!(got, want, "per-head driver must match sequential per-head layouts");
        // the two heads genuinely differ, so routing matters
        assert_ne!(got[..per], got[per..2 * per], "distinct head layouts must differ");

        // a shared pattern through the _heads entry points is
        // bit-identical to the single-layout entry points
        let shared_layout = Arc::new(BlockCsr::compile(&spec, 4));
        let shared = crate::attention::CompiledPattern::shared(shared_layout.clone());
        let mut a = vec![0.0f32; vol];
        let mut b = vec![0.0f32; vol];
        sparse_forward_batch_heads(&x, batch, heads, d, &shared, &mut a);
        sparse_forward_batch(&x, batch, heads, d, &shared_layout, &mut b);
        assert_eq!(a, b);

        // training + backward _heads variants agree with the shared path
        let mut o1 = vec![0.0f32; vol];
        let mut m1 = vec![0.0f32; batch * heads * n];
        let mut l1 = vec![0.0f32; batch * heads * n];
        sparse_forward_batch_training_heads(&x, batch, heads, d, &shared, &mut o1, &mut m1, &mut l1);
        assert_eq!(o1, b);
        let d_o: Vec<f32> = (0..vol).map(|_| rng.normal() as f32).collect();
        let (mut dq_a, mut dk_a, mut dv_a) =
            (vec![0.0f32; vol], vec![0.0f32; vol], vec![0.0f32; vol]);
        sparse_backward_batch_heads(
            &x, &o1, &d_o, &m1, &l1, batch, heads, d, &shared, &mut dq_a, &mut dk_a, &mut dv_a,
        );
        let (mut dq_b, mut dk_b, mut dv_b) =
            (vec![0.0f32; vol], vec![0.0f32; vol], vec![0.0f32; vol]);
        sparse_backward_batch(
            &x, &o1, &d_o, &m1, &l1, batch, heads, d, &shared_layout, &mut dq_b, &mut dk_b,
            &mut dv_b,
        );
        assert_eq!((dq_a, dk_a, dv_a), (dq_b, dk_b, dv_b));
    }

    #[test]
    fn select_cache_compiles_once_per_key() {
        let mut cache = SelectCache::default();
        let spec = PatternSpec {
            variant: AttnVariant::BigBirdItc,
            nb: 4,
            global_blocks: 1,
            window_blocks: 1,
            random_blocks: 1,
            seed: 0,
        };
        let mut builds = 0usize;
        for _ in 0..3 {
            let p = cache.get_or_compile(99, || {
                builds += 1;
                crate::attention::CompiledPattern::shared(Arc::new(BlockCsr::compile(&spec, 4)))
            });
            assert_eq!(p.seq_len(), 16);
        }
        assert_eq!(builds, 1, "same key must reuse the compiled pattern");
        assert!(cache.holds(99));
        cache.get_or_compile(100, || {
            builds += 1;
            crate::attention::CompiledPattern::shared(Arc::new(BlockCsr::compile(&spec, 4)))
        });
        assert_eq!(builds, 2, "a new key must recompile");
        assert!(!cache.holds(99));
    }

    #[test]
    fn single_head_single_batch_runs_inline() {
        let spec = PatternSpec {
            variant: AttnVariant::Window,
            nb: 4,
            global_blocks: 0,
            window_blocks: 1,
            random_blocks: 0,
            seed: 0,
        };
        let layout = BlockCsr::compile(&spec, 4);
        let (n, d) = (layout.seq_len(), 8);
        let q = vec![0.5f32; n * d];
        let x = HeadViews { q: &q, k: &q, v: &q, key_valid: None };
        let mut out = vec![0.0f32; n * d];
        sparse_forward_batch(&x, 1, 1, d, &layout, &mut out);
        // constant V ⇒ every output element equals the constant
        assert!(out.iter().all(|&o| (o - 0.5).abs() < 1e-6));
    }

    #[test]
    fn model_gemm_is_bit_identical_to_naive_reference_at_f32() {
        use crate::config::Precision;
        use crate::kernel::reference;
        let mut rng = Rng::new(0x6E_33);
        // small (inline path) and large (pool fan-out path) shapes
        for &(m, k, n) in &[(5usize, 9usize, 7usize), (67, 48, 53)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let packed = PackedMat::pack(&b, k, n, Precision::F32);
            let mut got = vec![0.0f32; m * n];
            model_gemm(&a, &packed, m, &mut got);
            let want = reference::matmul(&a, &b, m, k, n);
            assert_eq!(got, want, "m={m} k={k} n={n}: f32 GEMM must be bit-identical");
            // accumulate variant: out += a·b on a non-zero out
            let init: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
            let mut acc = init.clone();
            model_gemm_acc(&a, &packed, m, &mut acc);
            let want_acc: Vec<f32> = init.iter().zip(&want).map(|(&i0, &w)| i0 + w).collect();
            let worst = acc
                .iter()
                .zip(&want_acc)
                .map(|(&g, &w)| (g - w).abs())
                .fold(0.0f32, f32::max);
            assert!(worst <= 1e-5, "m={m} k={k} n={n}: acc worst {worst}");
        }
    }

    #[test]
    fn pool_survives_concurrent_callers() {
        // several threads hammering the shared pool at once (the
        // "concurrent native engine workers" shape) must all complete
        // with correct results
        let spec = PatternSpec {
            variant: AttnVariant::Window,
            nb: 4,
            global_blocks: 0,
            window_blocks: 3,
            random_blocks: 0,
            seed: 0,
        };
        let layout = BlockCsr::compile(&spec, 4);
        let (n, d) = (layout.seq_len(), 8);
        std::thread::scope(|s| {
            for t in 0..4 {
                let layout = &layout;
                s.spawn(move || {
                    let c = 0.1 + t as f32 * 0.2;
                    let q = vec![c; 2 * 2 * n * d];
                    let x = HeadViews { q: &q, k: &q, v: &q, key_valid: None };
                    let mut out = vec![0.0f32; 2 * 2 * n * d];
                    for _ in 0..8 {
                        sparse_forward_batch(&x, 2, 2, d, layout, &mut out);
                        assert!(out.iter().all(|&o| (o - c).abs() < 1e-5));
                    }
                });
            }
        });
    }
}
