//! `BlockCsr`: the compiled block-sparse attention layout.
//!
//! [`crate::attention::build_pattern`] describes *which* key
//! blocks each query block attends; the kernels need that description in
//! a gather-friendly form. `BlockCsr` is a block-level CSR matrix —
//! per-row sorted key-block lists behind a row-pointer array — with a
//! provenance tag per stored block (band / global / random / full-row)
//! so reports and tests can attribute every gathered block to the paper
//! component that produced it ("Longer Attention Span"-style sparse
//! graph gathering, Sec. 2 of the BigBird paper).

use crate::attention::{build_pattern, components, window_blocks_of, PatternSpec};

/// Why a key block appears in a query block's attended list.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BlockProvenance {
    /// A global block (first `g` blocks, attended by every row).
    Global,
    /// A sliding-window (band) block — includes the diagonal.
    Band,
    /// A randomly sampled block (the Erdős–Rényi component).
    Random,
    /// Present only because the whole row attends everything (dense
    /// rows, and the global *query* rows of ITC/ETC patterns).
    Full,
}

impl BlockProvenance {
    /// Stable label for reports.
    pub fn as_str(self) -> &'static str {
        match self {
            BlockProvenance::Global => "global",
            BlockProvenance::Band => "band",
            BlockProvenance::Random => "random",
            BlockProvenance::Full => "full",
        }
    }
}

/// Block-level CSR layout of one attention pattern: for query block
/// `qb`, the attended key blocks are `cols[row_ptr[qb]..row_ptr[qb+1]]`
/// (sorted ascending, deduplicated), with a parallel provenance tag per
/// entry. Compiled once per `(PatternSpec, block)` and shared by every
/// kernel invocation over that shape.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlockCsr {
    /// Number of blocks per sequence side.
    pub nb: usize,
    /// Tokens per block.
    pub block: usize,
    /// Row pointers, length `nb + 1`.
    pub row_ptr: Vec<usize>,
    /// Concatenated sorted key-block indices.
    pub cols: Vec<usize>,
    /// Provenance of each entry of `cols`.
    pub prov: Vec<BlockProvenance>,
}

impl BlockCsr {
    /// Compile the layout for `spec` with `block` tokens per block.
    pub fn compile(spec: &PatternSpec, block: usize) -> Self {
        assert!(block > 0, "block size must be positive");
        let attend = build_pattern(spec);
        let (use_g, use_w, _) = components(spec.variant);
        let g_eff = if use_g { spec.global_blocks } else { 0 };
        let mut row_ptr = Vec::with_capacity(spec.nb + 1);
        let mut cols = Vec::new();
        let mut prov = Vec::new();
        row_ptr.push(0);
        for (j, row) in attend.iter().enumerate() {
            let full = row.len() == spec.nb;
            let win = if use_w {
                window_blocks_of(j, spec.nb, spec.window_blocks)
            } else {
                vec![j]
            };
            for &kb in row {
                let p = if win.contains(&kb) {
                    BlockProvenance::Band
                } else if kb < g_eff {
                    BlockProvenance::Global
                } else if full {
                    BlockProvenance::Full
                } else {
                    BlockProvenance::Random
                };
                cols.push(kb);
                prov.push(p);
            }
            row_ptr.push(cols.len());
        }
        BlockCsr { nb: spec.nb, block, row_ptr, cols, prov }
    }

    /// Token-level sequence length this layout covers.
    pub fn seq_len(&self) -> usize {
        self.nb * self.block
    }

    /// Sorted attended key blocks of query block `qb`.
    pub fn row(&self, qb: usize) -> &[usize] {
        &self.cols[self.row_ptr[qb]..self.row_ptr[qb + 1]]
    }

    /// Token index range covered by block `blk`
    /// (`blk·block .. (blk+1)·block`) — the gather span the kernels
    /// slice Q/K/V rows and key-validity masks with.
    pub fn token_span(&self, blk: usize) -> std::ops::Range<usize> {
        blk * self.block..(blk + 1) * self.block
    }

    /// Provenance tags parallel to [`BlockCsr::row`].
    pub fn row_prov(&self, qb: usize) -> &[BlockProvenance] {
        &self.prov[self.row_ptr[qb]..self.row_ptr[qb + 1]]
    }

    /// Stored (attended) block pairs — the paper's O(n) edge count.
    pub fn nnz_blocks(&self) -> usize {
        self.cols.len()
    }

    /// Fraction of the dense `nb × nb` block matrix that is stored.
    pub fn density(&self) -> f64 {
        if self.nb == 0 {
            return 0.0;
        }
        self.nnz_blocks() as f64 / (self.nb * self.nb) as f64
    }

    /// Is `(qb, kb)` an attended pair? Binary search over the sorted row.
    pub fn is_attended(&self, qb: usize, kb: usize) -> bool {
        self.row(qb).binary_search(&kb).is_ok()
    }

    /// Stored-block counts per provenance, in
    /// `[global, band, random, full]` order.
    pub fn provenance_counts(&self) -> [usize; 4] {
        let mut counts = [0usize; 4];
        for p in &self.prov {
            let i = match p {
                BlockProvenance::Global => 0,
                BlockProvenance::Band => 1,
                BlockProvenance::Random => 2,
                BlockProvenance::Full => 3,
            };
            counts[i] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AttnVariant;

    fn spec(variant: AttnVariant, nb: usize, g: usize, w: usize, r: usize, seed: u64) -> PatternSpec {
        PatternSpec { variant, nb, global_blocks: g, window_blocks: w, random_blocks: r, seed }
    }

    #[test]
    fn matches_build_pattern_rows() {
        let s = spec(AttnVariant::BigBirdItc, 16, 2, 3, 2, 11);
        let csr = BlockCsr::compile(&s, 8);
        let attend = build_pattern(&s);
        assert_eq!(csr.nb, 16);
        assert_eq!(csr.seq_len(), 128);
        for (j, row) in attend.iter().enumerate() {
            assert_eq!(csr.row(j), row.as_slice(), "row {j}");
            let mut sorted = csr.row(j).to_vec();
            sorted.sort_unstable();
            assert_eq!(sorted, csr.row(j), "row {j} not sorted");
        }
        assert_eq!(csr.nnz_blocks(), s.edge_count());
    }

    #[test]
    fn provenance_attributes_each_component() {
        let s = spec(AttnVariant::BigBirdItc, 16, 2, 3, 2, 7);
        let csr = BlockCsr::compile(&s, 4);
        let [g, band, rand, full] = csr.provenance_counts();
        // 14 non-global rows each carry 2 global + 2 random blocks and a
        // (possibly global-overlapping) 3-wide band; 2 global rows are full
        assert!(g > 0 && band > 0 && rand > 0 && full > 0, "{:?}", csr.provenance_counts());
        // every non-full row of BigBird-ITC has exactly r random blocks
        for qb in s.global_blocks..s.nb {
            let n_rand = csr
                .row_prov(qb)
                .iter()
                .filter(|p| **p == BlockProvenance::Random)
                .count();
            assert_eq!(n_rand, s.random_blocks, "row {qb}");
        }
        // diagonal is always band
        for qb in 0..s.nb {
            let pos = csr.row(qb).iter().position(|&kb| kb == qb).expect("diagonal attended");
            assert_eq!(csr.row_prov(qb)[pos], BlockProvenance::Band, "row {qb}");
        }
    }

    #[test]
    fn density_shrinks_linearly_for_sparse_patterns() {
        let d32 = BlockCsr::compile(&spec(AttnVariant::BigBirdItc, 32, 2, 3, 3, 0), 8).density();
        let d64 = BlockCsr::compile(&spec(AttnVariant::BigBirdItc, 64, 2, 3, 3, 0), 8).density();
        assert!(d64 < d32, "density must fall with nb: {d64} !< {d32}");
        let dense = BlockCsr::compile(&spec(AttnVariant::Dense, 16, 0, 1, 0, 0), 8);
        assert!((dense.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn token_span_tiles_the_sequence() {
        let csr = BlockCsr::compile(&spec(AttnVariant::Window, 5, 0, 1, 0, 0), 6);
        let mut covered = Vec::new();
        for blk in 0..csr.nb {
            let span = csr.token_span(blk);
            assert_eq!(span.len(), csr.block);
            covered.extend(span);
        }
        assert_eq!(covered, (0..csr.seq_len()).collect::<Vec<_>>());
    }

    #[test]
    fn is_attended_agrees_with_rows() {
        let s = spec(AttnVariant::Window, 12, 0, 3, 0, 0);
        let csr = BlockCsr::compile(&s, 4);
        for qb in 0..s.nb {
            for kb in 0..s.nb {
                assert_eq!(csr.is_attended(qb, kb), csr.row(qb).contains(&kb), "({qb},{kb})");
            }
        }
    }
}
