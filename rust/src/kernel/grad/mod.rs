//! Reverse-mode gradients for the native stack — the subsystem that
//! makes `train --backends native` real pretraining with **zero PJRT
//! artifacts**.
//!
//! * [`attention`] — flash-style backward for the block-sparse
//!   attention kernel: recomputes the streaming softmax from the saved
//!   row max/sum statistics and gathers/scatters dQ/dK/dV through the
//!   same [`BlockCsr`](crate::kernel::BlockCsr) layout as the forward;
//! * [`ops`] — backward (and stat-saving forward) variants of the dense
//!   ops: matmul transposes, pre-LN layer norm, tanh-GELU;
//! * [`tape`] — [`forward_tape`]/[`backward`]: the whole-model training
//!   forward (bit-identical logits to serving) and reverse walk;
//! * [`params`] — [`ParamGrads`], the gradient mirror of the parameter
//!   layout, flattening in the same canonical order as
//!   `NativeModel::flatten_params`;
//! * [`loss`] — [`masked_xent`], masked-LM softmax cross-entropy;
//! * [`optim`] — [`AdamW`] with linear warmup and global-norm clipping.
//!
//! `tests/native_training.rs` finite-difference-checks the attention
//! backward (≤ 1e-3 relative error against an f64 reference across
//! random `PatternSpec`s), directional-checks the whole-model gradient,
//! and property-tests that 20 optimizer steps reduce the MLM loss.

pub mod attention;
pub mod loss;
pub mod ops;
pub mod optim;
pub mod params;
pub mod tape;

pub use attention::{sparse_attention_backward, AttnGradScratch};
pub use loss::masked_xent;
pub use optim::{AdamW, AdamWConfig, StepInfo};
pub use params::{LayerGrads, ParamGrads};
pub use tape::{backward, forward_tape, Tape};
