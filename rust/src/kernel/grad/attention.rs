//! Flash-style backward pass for the block-sparse attention kernel.
//!
//! The forward kernel ([`crate::kernel::sparse_forward_with_stats`])
//! never materialises the `n × n` probability matrix; neither does the
//! backward. Instead it **recomputes** each probability from the saved
//! streaming-softmax row statistics — `p_ij = exp(q_i·k_j·scale − m_i) / l_i`
//! — while walking exactly the same [`BlockCsr`] gather structure as the
//! forward, and accumulates
//!
//! ```text
//! δ_i   = dO_i · O_i                      (the flash-attention rowsum trick)
//! dV_j += p_ij · dO_i
//! dS_ij = p_ij · (dO_i · v_j − δ_i)
//! dQ_i += dS_ij · scale · k_j
//! dK_j += dS_ij · scale · q_i
//! ```
//!
//! Work is O(n · attended_blocks · block · d), the same asymptotics as
//! the forward. Parallelism mirrors the forward driver: one task per
//! `(batch, head)` problem, so the dK/dV scatter never races — within a
//! head problem query blocks are processed sequentially.
//!
//! Like the forward, every block-level product runs on the tiled
//! [`microkernel`](crate::kernel::microkernel) layer: the recomputed
//! score tile and the dP = dO·Vᵀ tile are both [`qk_tile`] GEMMs
//! against packed transposes, and the dQ/dK/dV gathers are [`av_tile`]
//! accumulates (dK/dV on a transposed weight tile — a scatter becomes
//! a gather), so forward serving and training backward share one hot
//! inner loop.

use crate::kernel::layout::BlockCsr;
use crate::kernel::microkernel::{av_tile, pack_transposed, qk_tile, row_dots};
use crate::kernel::HeadViews;

/// Reusable per-thread scratch for [`sparse_attention_backward`]: the
/// per-row `δ = dO·O` values of the current query block plus the
/// per-tile pack/probability buffers. Grown on demand, never shrunk;
/// lives in the kernel pool's per-thread arena.
#[derive(Debug, Default)]
pub struct AttnGradScratch {
    /// `δ_i = dO_i · O_i` per query row of the block.
    delta: Vec<f32>,
    /// Packed transpose of the current key block, `head_dim × block`.
    kt: Vec<f32>,
    /// Packed transpose of the current value block, `head_dim × block`.
    vt: Vec<f32>,
    /// Score → probability tile, `block × block`.
    p: Vec<f32>,
    /// dP → dS tile, `block × block`.
    ds: Vec<f32>,
    /// Transposed weight tile (Pᵀ, then dSᵀ), `block × block`.
    tp: Vec<f32>,
}

impl AttnGradScratch {
    /// Fresh empty scratch (buffers grow on first use).
    pub fn new() -> Self {
        AttnGradScratch::default()
    }

    fn ensure(&mut self, block: usize, head_dim: usize) {
        self.delta.resize(block, 0.0);
        self.kt.resize(head_dim * block, 0.0);
        self.vt.resize(head_dim * block, 0.0);
        self.p.resize(block * block, 0.0);
        self.ds.resize(block * block, 0.0);
        self.tp.resize(block * block, 0.0);
    }
}

/// Backward of block-sparse attention for one `[n, head_dim]` head.
///
/// Inputs: the forward's Q/K/V views (with the same key-validity mask),
/// the forward output `o`, the upstream gradient `d_o` (both
/// `[n, head_dim]`), and the saved softmax row statistics `m`/`l`
/// (`[n]`, from [`crate::kernel::sparse_forward_with_stats`]). Writes
/// `dq`/`dk`/`dv` (`[n, head_dim]`, zeroed here first). Rows that saw
/// no admissible key (`l ≤ 0`) contribute nothing, matching the
/// forward's all-zero output for them.
#[allow(clippy::too_many_arguments)]
pub fn sparse_attention_backward(
    x: &HeadViews<'_>,
    o: &[f32],
    d_o: &[f32],
    m: &[f32],
    l: &[f32],
    head_dim: usize,
    layout: &BlockCsr,
    scratch: &mut AttnGradScratch,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    let n = layout.seq_len();
    let b = layout.block;
    x.check(n, head_dim);
    assert_eq!(o.len(), n * head_dim, "o must be [n, head_dim]");
    assert_eq!(d_o.len(), n * head_dim, "d_o must be [n, head_dim]");
    assert_eq!(m.len(), n, "m must be [n]");
    assert_eq!(l.len(), n, "l must be [n]");
    assert_eq!(dq.len(), n * head_dim, "dq must be [n, head_dim]");
    assert_eq!(dk.len(), n * head_dim, "dk must be [n, head_dim]");
    assert_eq!(dv.len(), n * head_dim, "dv must be [n, head_dim]");
    let scale = 1.0 / (head_dim as f32).sqrt();
    dq.fill(0.0);
    dk.fill(0.0);
    dv.fill(0.0);
    scratch.ensure(b, head_dim);
    for qb in 0..layout.nb {
        let qs = layout.token_span(qb);
        let q_range = qs.start * head_dim..qs.end * head_dim;
        let q_block = &x.q[q_range.clone()];
        let do_block = &d_o[q_range.clone()];
        // δ_i = dO_i · O_i (the flash-attention rowsum trick)
        row_dots(do_block, &o[q_range.clone()], b, head_dim, &mut scratch.delta);
        for &kb in layout.row(qb) {
            let ks = layout.token_span(kb);
            let k_range = ks.start * head_dim..ks.end * head_dim;
            let k_block = &x.k[k_range.clone()];
            let v_block = &x.v[k_range.clone()];
            let valid = x.key_valid.map(|mask| &mask[ks.clone()]);
            pack_transposed(k_block, b, head_dim, &mut scratch.kt);
            pack_transposed(v_block, b, head_dim, &mut scratch.vt);
            // recomputed score tile (masked → −inf), same GEMM as the
            // forward's QKᵀ
            qk_tile(q_block, &scratch.kt, b, b, head_dim, scale, valid, &mut scratch.p);
            // dP tile = dO · Vᵀ. Deliberately *unmasked*: p = 0 already
            // kills masked entries, while a −inf here would turn
            // p · (dp − δ) into 0 · ∞ = NaN.
            qk_tile(do_block, &scratch.vt, b, b, head_dim, 1.0, None, &mut scratch.ds);
            // scores → probabilities: p_ij = exp(s_ij − m_i) / l_i
            for i in 0..b {
                let qi = qs.start + i;
                let li = l[qi];
                let p_row = &mut scratch.p[i * b..(i + 1) * b];
                if li <= 0.0 {
                    // fully masked row: forward output was zero
                    p_row.fill(0.0);
                    continue;
                }
                let mi = m[qi];
                let inv_l = 1.0 / li;
                for s in p_row.iter_mut() {
                    // exp(-inf − m_i) = 0: masked keys contribute nothing
                    *s = (*s - mi).exp() * inv_l;
                }
            }
            // dS = P ∘ (dP − δ) · scale, in place over the dP tile
            for i in 0..b {
                let delta = scratch.delta[i];
                let p_row = &scratch.p[i * b..(i + 1) * b];
                let ds_row = &mut scratch.ds[i * b..(i + 1) * b];
                for (dsv, &pv) in ds_row.iter_mut().zip(p_row) {
                    *dsv = pv * (*dsv - delta) * scale;
                }
            }
            // dQ_block += dS · K (query-row gather)
            av_tile(&scratch.ds, k_block, b, b, head_dim, &mut dq[q_range.clone()]);
            // dV_block += Pᵀ · dO (the scatter becomes a gather on the
            // transposed tile)
            pack_transposed(&scratch.p, b, b, &mut scratch.tp);
            av_tile(&scratch.tp, do_block, b, b, head_dim, &mut dv[k_range.clone()]);
            // dK_block += dSᵀ · Q
            pack_transposed(&scratch.ds, b, b, &mut scratch.tp);
            av_tile(&scratch.tp, q_block, b, b, head_dim, &mut dk[k_range]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::PatternSpec;
    use crate::config::AttnVariant;
    use crate::kernel::sparse::{sparse_forward_with_stats, SparseScratch};
    use crate::util::Rng;

    /// With V constant and all keys valid, attention output is that
    /// constant for every row, independent of Q and K — so dQ and dK
    /// must vanish, and dV's per-key total weight must sum to the
    /// number of rows attending it.
    #[test]
    fn constant_values_zero_qk_gradients() {
        let spec = PatternSpec {
            variant: AttnVariant::BigBirdItc,
            nb: 4,
            global_blocks: 1,
            window_blocks: 1,
            random_blocks: 1,
            seed: 2,
        };
        let layout = BlockCsr::compile(&spec, 4);
        let (n, d) = (layout.seq_len(), 8);
        let mut rng = Rng::new(11);
        let q: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let v = vec![0.7f32; n * d];
        let x = HeadViews { q: &q, k: &k, v: &v, key_valid: None };
        let mut out = vec![0.0f32; n * d];
        let mut m = vec![0.0f32; n];
        let mut l = vec![0.0f32; n];
        sparse_forward_with_stats(&x, d, &layout, &mut SparseScratch::new(), &mut out, &mut m, &mut l);
        let d_o = vec![1.0f32; n * d];
        let (mut dq, mut dk, mut dv) = (vec![0.0f32; n * d], vec![0.0f32; n * d], vec![0.0f32; n * d]);
        sparse_attention_backward(
            &x,
            &out,
            &d_o,
            &m,
            &l,
            d,
            &layout,
            &mut AttnGradScratch::new(),
            &mut dq,
            &mut dk,
            &mut dv,
        );
        for (i, (&a, &b)) in dq.iter().zip(&dk).enumerate() {
            assert!(a.abs() < 1e-4, "dq[{i}] = {a}");
            assert!(b.abs() < 1e-4, "dk[{i}] = {b}");
        }
        // dV conservation: the total probability mass scattered into dV
        // equals one unit per live query row (d_o is all-ones).
        let total: f32 = dv.iter().sum();
        let live_rows = l.iter().filter(|&&x| x > 0.0).count();
        assert!(
            (total - (live_rows * d) as f32).abs() < 1e-2,
            "dv mass {total} vs {live_rows} rows × {d}"
        );
    }
}
