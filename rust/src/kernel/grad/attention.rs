//! Flash-style backward pass for the block-sparse attention kernel.
//!
//! The forward kernel ([`crate::kernel::sparse_forward_with_stats`])
//! never materialises the `n × n` probability matrix; neither does the
//! backward. Instead it **recomputes** each probability from the saved
//! streaming-softmax row statistics — `p_ij = exp(q_i·k_j·scale − m_i) / l_i`
//! — while walking exactly the same [`BlockCsr`] gather structure as the
//! forward, and accumulates
//!
//! ```text
//! δ_i   = dO_i · O_i                      (the flash-attention rowsum trick)
//! dV_j += p_ij · dO_i
//! dS_ij = p_ij · (dO_i · v_j − δ_i)
//! dQ_i += dS_ij · scale · k_j
//! dK_j += dS_ij · scale · q_i
//! ```
//!
//! Work is O(n · attended_blocks · block · d), the same asymptotics as
//! the forward. Parallelism mirrors the forward driver: one task per
//! `(batch, head)` problem, so the dK/dV scatter never races — within a
//! head problem query blocks are processed sequentially.

use crate::kernel::layout::BlockCsr;
use crate::kernel::{dot, HeadViews};

/// Reusable per-thread scratch for [`sparse_attention_backward`]: the
/// per-row `δ = dO·O` values of the current query block. Grown on
/// demand, never shrunk; lives in the kernel pool's per-thread arena.
#[derive(Debug, Default)]
pub struct AttnGradScratch {
    delta: Vec<f32>,
}

impl AttnGradScratch {
    /// Fresh empty scratch (buffers grow on first use).
    pub fn new() -> Self {
        AttnGradScratch::default()
    }
}

/// Backward of block-sparse attention for one `[n, head_dim]` head.
///
/// Inputs: the forward's Q/K/V views (with the same key-validity mask),
/// the forward output `o`, the upstream gradient `d_o` (both
/// `[n, head_dim]`), and the saved softmax row statistics `m`/`l`
/// (`[n]`, from [`crate::kernel::sparse_forward_with_stats`]). Writes
/// `dq`/`dk`/`dv` (`[n, head_dim]`, zeroed here first). Rows that saw
/// no admissible key (`l ≤ 0`) contribute nothing, matching the
/// forward's all-zero output for them.
#[allow(clippy::too_many_arguments)]
pub fn sparse_attention_backward(
    x: &HeadViews<'_>,
    o: &[f32],
    d_o: &[f32],
    m: &[f32],
    l: &[f32],
    head_dim: usize,
    layout: &BlockCsr,
    scratch: &mut AttnGradScratch,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    let n = layout.seq_len();
    let b = layout.block;
    x.check(n, head_dim);
    assert_eq!(o.len(), n * head_dim, "o must be [n, head_dim]");
    assert_eq!(d_o.len(), n * head_dim, "d_o must be [n, head_dim]");
    assert_eq!(m.len(), n, "m must be [n]");
    assert_eq!(l.len(), n, "l must be [n]");
    assert_eq!(dq.len(), n * head_dim, "dq must be [n, head_dim]");
    assert_eq!(dk.len(), n * head_dim, "dk must be [n, head_dim]");
    assert_eq!(dv.len(), n * head_dim, "dv must be [n, head_dim]");
    let scale = 1.0 / (head_dim as f32).sqrt();
    dq.fill(0.0);
    dk.fill(0.0);
    dv.fill(0.0);
    scratch.delta.resize(b, 0.0);
    for qb in 0..layout.nb {
        for i in 0..b {
            let qi = qb * b + i;
            let row = qi * head_dim..(qi + 1) * head_dim;
            scratch.delta[i] = dot(&d_o[row.clone()], &o[row]);
        }
        for &kb in layout.row(qb) {
            for i in 0..b {
                let qi = qb * b + i;
                let li = l[qi];
                if li <= 0.0 {
                    continue; // fully masked row: forward output was zero
                }
                let mi = m[qi];
                let delta = scratch.delta[i];
                let q_row = &x.q[qi * head_dim..(qi + 1) * head_dim];
                let do_row = &d_o[qi * head_dim..(qi + 1) * head_dim];
                for jj in 0..b {
                    let kj = kb * b + jj;
                    if let Some(mask) = x.key_valid {
                        if mask[kj] <= 0.0 {
                            continue;
                        }
                    }
                    let k_row = &x.k[kj * head_dim..(kj + 1) * head_dim];
                    let s = dot(q_row, k_row) * scale;
                    let p = (s - mi).exp() / li;
                    if p == 0.0 {
                        continue; // fully underflowed: no forward contribution
                    }
                    let v_row = &x.v[kj * head_dim..(kj + 1) * head_dim];
                    for (dvj, &g) in dv[kj * head_dim..(kj + 1) * head_dim].iter_mut().zip(do_row) {
                        *dvj += p * g;
                    }
                    let dp = dot(do_row, v_row);
                    let ds = p * (dp - delta) * scale;
                    for (dqi, &kv) in dq[qi * head_dim..(qi + 1) * head_dim].iter_mut().zip(k_row) {
                        *dqi += ds * kv;
                    }
                    for (dkj, &qv) in dk[kj * head_dim..(kj + 1) * head_dim].iter_mut().zip(q_row) {
                        *dkj += ds * qv;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::PatternSpec;
    use crate::config::AttnVariant;
    use crate::kernel::sparse::{sparse_forward_with_stats, SparseScratch};
    use crate::util::Rng;

    /// With V constant and all keys valid, attention output is that
    /// constant for every row, independent of Q and K — so dQ and dK
    /// must vanish, and dV's per-key total weight must sum to the
    /// number of rows attending it.
    #[test]
    fn constant_values_zero_qk_gradients() {
        let spec = PatternSpec {
            variant: AttnVariant::BigBirdItc,
            nb: 4,
            global_blocks: 1,
            window_blocks: 1,
            random_blocks: 1,
            seed: 2,
        };
        let layout = BlockCsr::compile(&spec, 4);
        let (n, d) = (layout.seq_len(), 8);
        let mut rng = Rng::new(11);
        let q: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let v = vec![0.7f32; n * d];
        let x = HeadViews { q: &q, k: &k, v: &v, key_valid: None };
        let mut out = vec![0.0f32; n * d];
        let mut m = vec![0.0f32; n];
        let mut l = vec![0.0f32; n];
        sparse_forward_with_stats(&x, d, &layout, &mut SparseScratch::new(), &mut out, &mut m, &mut l);
        let d_o = vec![1.0f32; n * d];
        let (mut dq, mut dk, mut dv) = (vec![0.0f32; n * d], vec![0.0f32; n * d], vec![0.0f32; n * d]);
        sparse_attention_backward(
            &x,
            &out,
            &d_o,
            &m,
            &l,
            d,
            &layout,
            &mut AttnGradScratch::new(),
            &mut dq,
            &mut dk,
            &mut dv,
        );
        for (i, (&a, &b)) in dq.iter().zip(&dk).enumerate() {
            assert!(a.abs() < 1e-4, "dq[{i}] = {a}");
            assert!(b.abs() < 1e-4, "dk[{i}] = {b}");
        }
        // dV conservation: the total probability mass scattered into dV
        // equals one unit per live query row (d_o is all-ones).
        let total: f32 = dv.iter().sum();
        let live_rows = l.iter().filter(|&&x| x > 0.0).count();
        assert!(
            (total - (live_rows * d) as f32).abs() < 1e-2,
            "dv mass {total} vs {live_rows} rows × {d}"
        );
    }
}
