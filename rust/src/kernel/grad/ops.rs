//! Forward-with-stats and backward implementations of the dense ops the
//! native model is built from: transposed matmul shapes for the
//! backward, pre-LN layer norm, tanh-GELU, and bias/column-sum
//! helpers. Training and serving forwards share **one implementation**
//! of each op ([`layernorm_fwd`] is the canonical layer norm, which
//! `kernel::model::layernorm` delegates to; [`gelu_fwd`] delegates to
//! the canonical `kernel::model::gelu`), so the training forward is
//! bit-identical to the serving forward by construction.
//!
//! The transposed matmuls route through the packed tiled GEMM layer
//! (`kernel::microkernel` via the pooled `kernel::driver::model_gemm`)
//! — **always at f32**: gradients keep full precision regardless of the
//! forward's `Precision` policy, so mixed-precision training still
//! updates f32 master weights with f32 gradients.

use crate::config::Precision;
use crate::kernel::driver::model_gemm_acc;
use crate::kernel::microkernel::{pack_transposed, PackedMat};
use crate::kernel::model::gemm_out;

/// `C[m,k] = A[m,n] · B[k,n]ᵀ` — the `dX = dY · Wᵀ` shape of a matmul
/// backward (row-major; `b`'s rows are the contraction axis). Packs
/// `Bᵀ` and runs the tiled f32 GEMM over the pool.
pub(crate) fn matmul_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    let bt = PackedMat::pack_transposed(b, k, n, Precision::F32);
    gemm_out(a, &bt, m)
}

/// `acc[k,n] += A[m,k]ᵀ · B[m,n]` — the `dW += Xᵀ · dY` shape of a
/// matmul backward, accumulating into `acc` through the tiled f32 GEMM
/// (transpose `A`, pack `B`, accumulate).
pub(crate) fn matmul_tn_acc(a: &[f32], b: &[f32], acc: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(acc.len(), k * n);
    let mut at = vec![0.0f32; k * m];
    pack_transposed(a, m, k, &mut at);
    let bp = PackedMat::pack(b, m, n, Precision::F32);
    model_gemm_acc(&at, &bp, k, acc);
}

/// `acc[j] += Σ_rows x[row, j]` — a bias gradient.
pub(crate) fn add_colsum(x: &[f32], acc: &mut [f32]) {
    for row in x.chunks(acc.len()) {
        for (o, &v) in acc.iter_mut().zip(row) {
            *o += v;
        }
    }
}

/// Per-row layer-norm statistics saved by the training forward: the
/// mean and inverse standard deviation of each `h`-wide row.
#[derive(Clone, Debug, Default)]
pub(crate) struct LnStats {
    pub mean: Vec<f32>,
    pub inv: Vec<f32>,
}

const LN_EPS: f32 = 1e-5;

/// Layer norm forward, saving per-row stats — the canonical layer-norm
/// implementation (`kernel::model::layernorm` delegates here and
/// discards the stats), so serving and training are bit-equal by
/// construction.
pub(crate) fn layernorm_fwd(x: &[f32], gamma: &[f32], beta: &[f32], h: usize) -> (Vec<f32>, LnStats) {
    let rows = x.len() / h;
    let mut out = vec![0.0f32; x.len()];
    let mut stats = LnStats { mean: vec![0.0; rows], inv: vec![0.0; rows] };
    for (r, (row, o_row)) in x.chunks(h).zip(out.chunks_mut(h)).enumerate() {
        let mean = row.iter().sum::<f32>() / h as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / h as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for (((o, &v), &g), &b) in o_row.iter_mut().zip(row).zip(gamma).zip(beta) {
            *o = (v - mean) * inv * g + b;
        }
        stats.mean[r] = mean;
        stats.inv[r] = inv;
    }
    (out, stats)
}

/// Layer-norm backward: returns `dx` and accumulates `dgamma`/`dbeta`.
/// Standard pre-LN formula per row, with `x̂ = (x − mean)·inv`:
///
/// ```text
/// dx̂ = dy · γ
/// dx  = inv · (dx̂ − mean_f(dx̂) − x̂ · mean_f(dx̂ · x̂))
/// dγ += dy · x̂,   dβ += dy
/// ```
pub(crate) fn layernorm_bwd(
    dy: &[f32],
    x: &[f32],
    stats: &LnStats,
    gamma: &[f32],
    h: usize,
    dgamma: &mut [f32],
    dbeta: &mut [f32],
) -> Vec<f32> {
    debug_assert_eq!(dy.len(), x.len());
    let mut dx = vec![0.0f32; x.len()];
    for (r, ((dy_row, x_row), dx_row)) in
        dy.chunks(h).zip(x.chunks(h)).zip(dx.chunks_mut(h)).enumerate()
    {
        let mean = stats.mean[r];
        let inv = stats.inv[r];
        let mut c1 = 0.0f32; // mean of dx̂
        let mut c2 = 0.0f32; // mean of dx̂ · x̂
        for j in 0..h {
            let xhat = (x_row[j] - mean) * inv;
            let dxhat = dy_row[j] * gamma[j];
            c1 += dxhat;
            c2 += dxhat * xhat;
            dgamma[j] += dy_row[j] * xhat;
            dbeta[j] += dy_row[j];
        }
        c1 /= h as f32;
        c2 /= h as f32;
        for j in 0..h {
            let xhat = (x_row[j] - mean) * inv;
            let dxhat = dy_row[j] * gamma[j];
            dx_row[j] = inv * (dxhat - c1 - xhat * c2);
        }
    }
    dx
}

/// Tanh-approximation GELU forward, out of place — delegates to the
/// serving `kernel::model::gelu` so the formula exists exactly once
/// (bit-parity by construction).
pub(crate) fn gelu_fwd(x: &[f32]) -> Vec<f32> {
    let mut out = x.to_vec();
    crate::kernel::model::gelu(&mut out);
    out
}

/// GELU backward: `d_pre = d_post · gelu'(pre)` with the tanh
/// approximation's exact derivative.
pub(crate) fn gelu_bwd(d_post: &[f32], pre: &[f32]) -> Vec<f32> {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    d_post
        .iter()
        .zip(pre)
        .map(|(&g, &u)| {
            let t = (c * (u + 0.044715 * u * u * u)).tanh();
            let sech2 = 1.0 - t * t;
            let d = 0.5 * (1.0 + t) + 0.5 * u * sech2 * c * (1.0 + 3.0 * 0.044715 * u * u);
            g * d
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Naive triple-loop references for the transposed matmuls.
    #[test]
    fn transposed_matmuls_match_naive_references() {
        let (m, n, k) = (5usize, 7usize, 4usize);
        let mut rng = Rng::new(1);
        let a = randn(&mut rng, m * n);
        let b = randn(&mut rng, k * n);
        let got = matmul_nt(&a, &b, m, n, k);
        for i in 0..m {
            for j in 0..k {
                let want: f32 = (0..n).map(|t| a[i * n + t] * b[j * n + t]).sum();
                assert!((got[i * k + j] - want).abs() < 1e-5, "nt ({i},{j})");
            }
        }
        let a2 = randn(&mut rng, m * k);
        let b2 = randn(&mut rng, m * n);
        let mut acc = vec![0.5f32; k * n];
        matmul_tn_acc(&a2, &b2, &mut acc, m, k, n);
        for p in 0..k {
            for q in 0..n {
                let want: f32 = 0.5 + (0..m).map(|i| a2[i * k + p] * b2[i * n + q]).sum::<f32>();
                assert!((acc[p * n + q] - want).abs() < 1e-5, "tn ({p},{q})");
            }
        }
    }

    /// Central-difference check of the layer-norm backward (f32, small
    /// shapes, generous-but-meaningful tolerance).
    #[test]
    fn layernorm_backward_matches_finite_differences() {
        let (rows, h) = (3usize, 8usize);
        let mut rng = Rng::new(2);
        let x = randn(&mut rng, rows * h);
        let gamma: Vec<f32> = (0..h).map(|_| 1.0 + 0.1 * rng.normal() as f32).collect();
        let beta = randn(&mut rng, h);
        let w = randn(&mut rng, rows * h); // loss = Σ w · y
        let loss = |x: &[f32]| -> f64 {
            let (y, _) = layernorm_fwd(x, &gamma, &beta, h);
            y.iter().zip(&w).map(|(&a, &b)| a as f64 * b as f64).sum()
        };
        let (_, stats) = layernorm_fwd(&x, &gamma, &beta, h);
        let mut dg = vec![0.0f32; h];
        let mut db = vec![0.0f32; h];
        let dx = layernorm_bwd(&w, &x, &stats, &gamma, h, &mut dg, &mut db);
        let eps = 1e-2f32;
        for i in 0..rows * h {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let num = ((loss(&xp) - loss(&xm)) / (2.0 * eps as f64)) as f32;
            let denom = dx[i].abs().max(num.abs()).max(0.05);
            assert!(
                (dx[i] - num).abs() / denom < 2e-2,
                "dx[{i}]: analytic {} vs numeric {num}",
                dx[i]
            );
        }
        // dbeta is exactly the column sum of w
        for j in 0..h {
            let want: f32 = (0..rows).map(|r| w[r * h + j]).sum();
            assert!((db[j] - want).abs() < 1e-4, "dbeta[{j}]");
        }
    }

    #[test]
    fn gelu_backward_matches_finite_differences() {
        let mut rng = Rng::new(3);
        let pre = randn(&mut rng, 64);
        let d_post = vec![1.0f32; 64];
        let grad = gelu_bwd(&d_post, &pre);
        let eps = 1e-2f32;
        for (i, &u) in pre.iter().enumerate() {
            let f = |u: f32| -> f64 {
                let c = (2.0f64 / std::f64::consts::PI).sqrt();
                let u = u as f64;
                0.5 * u * (1.0 + (c * (u + 0.044715 * u * u * u)).tanh())
            };
            let num = ((f(u + eps) - f(u - eps)) / (2.0 * eps as f64)) as f32;
            assert!(
                (grad[i] - num).abs() < 1e-3,
                "gelu'[{i}] at {u}: analytic {} vs numeric {num}",
                grad[i]
            );
        }
    }
}
