//! AdamW with linear learning-rate warmup and global-norm gradient
//! clipping — the optimizer behind `train --backends native`. Operates
//! on the flat parameter/gradient vectors produced by
//! `NativeModel::flatten_params` / [`super::ParamGrads::flatten_into`].

use anyhow::{ensure, Result};

/// Optimizer hyperparameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdamWConfig {
    /// Peak learning rate (after warmup).
    pub lr: f32,
    /// Steps of linear warmup from 0 → `lr` (0 disables warmup).
    pub warmup_steps: usize,
    /// Decoupled weight decay coefficient.
    pub weight_decay: f32,
    /// Global-L2-norm gradient clip (≤ 0 disables clipping).
    pub clip_norm: f32,
    /// First-moment decay.
    pub beta1: f32,
    /// Second-moment decay.
    pub beta2: f32,
    /// Denominator fuzz.
    pub eps: f32,
}

impl Default for AdamWConfig {
    fn default() -> Self {
        AdamWConfig {
            lr: 2e-3,
            warmup_steps: 10,
            weight_decay: 0.01,
            clip_norm: 1.0,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }
}

/// What one optimizer step did (for logging and the train-step bench).
#[derive(Clone, Copy, Debug)]
pub struct StepInfo {
    /// Learning rate actually applied (post-warmup schedule).
    pub lr: f32,
    /// Global gradient L2 norm *before* clipping.
    pub grad_norm: f64,
    /// True when the clip rescaled the gradient.
    pub clipped: bool,
}

/// AdamW state over one flat parameter vector.
pub struct AdamW {
    cfg: AdamWConfig,
    m: Vec<f32>,
    v: Vec<f32>,
    step: usize,
}

impl AdamW {
    /// Fresh state for `n` parameters.
    pub fn new(n: usize, cfg: AdamWConfig) -> Self {
        AdamW { cfg, m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }

    /// Hyperparameters.
    pub fn config(&self) -> &AdamWConfig {
        &self.cfg
    }

    /// Completed optimizer steps.
    pub fn step_count(&self) -> usize {
        self.step
    }

    /// First-moment state (for checkpointing).
    pub fn first_moment(&self) -> &[f32] {
        &self.m
    }

    /// Second-moment state (for checkpointing).
    pub fn second_moment(&self) -> &[f32] {
        &self.v
    }

    /// Restore state from a checkpoint.
    pub fn restore(&mut self, m: Vec<f32>, v: Vec<f32>, step: usize) -> Result<()> {
        ensure!(
            m.len() == self.m.len() && v.len() == self.v.len(),
            "optimizer state size mismatch: checkpoint has m={}, v={}, expected {}",
            m.len(),
            v.len(),
            self.m.len()
        );
        self.m = m;
        self.v = v;
        self.step = step;
        Ok(())
    }

    /// Clip `grads` to the configured global norm (in place), then apply
    /// one AdamW update to `params` with linear-warmup learning rate and
    /// bias-corrected moments.
    pub fn step(&mut self, params: &mut [f32], grads: &mut [f32]) -> StepInfo {
        assert_eq!(params.len(), self.m.len(), "params length changed under the optimizer");
        assert_eq!(grads.len(), self.m.len(), "grads length changed under the optimizer");
        let grad_norm = {
            let mut s = 0.0f64;
            for &g in grads.iter() {
                s += g as f64 * g as f64;
            }
            s.sqrt()
        };
        let mut clipped = false;
        if self.cfg.clip_norm > 0.0 && grad_norm > self.cfg.clip_norm as f64 {
            let scale = (self.cfg.clip_norm as f64 / grad_norm) as f32;
            for g in grads.iter_mut() {
                *g *= scale;
            }
            clipped = true;
        }
        self.step += 1;
        let t = self.step;
        let warm = if self.cfg.warmup_steps > 0 {
            (t as f32 / self.cfg.warmup_steps as f32).min(1.0)
        } else {
            1.0
        };
        let lr = self.cfg.lr * warm;
        let b1 = self.cfg.beta1;
        let b2 = self.cfg.beta2;
        let b1c = 1.0 - b1.powi(t as i32);
        let b2c = 1.0 - b2.powi(t as i32);
        let wd = self.cfg.weight_decay;
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            let m_hat = self.m[i] / b1c;
            let v_hat = self.v[i] / b2c;
            params[i] -= lr * (m_hat / (v_hat.sqrt() + self.cfg.eps) + wd * params[i]);
        }
        StepInfo { lr, grad_norm, clipped }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly_to_peak() {
        let cfg = AdamWConfig { lr: 1.0, warmup_steps: 4, weight_decay: 0.0, ..Default::default() };
        let mut opt = AdamW::new(1, cfg);
        let mut p = vec![0.0f32];
        let lrs: Vec<f32> = (0..6)
            .map(|_| {
                let mut g = vec![1.0f32];
                opt.step(&mut p, &mut g).lr
            })
            .collect();
        assert!((lrs[0] - 0.25).abs() < 1e-6, "{lrs:?}");
        assert!((lrs[1] - 0.5).abs() < 1e-6, "{lrs:?}");
        assert!((lrs[3] - 1.0).abs() < 1e-6, "{lrs:?}");
        assert!((lrs[5] - 1.0).abs() < 1e-6, "post-warmup lr must stay at peak: {lrs:?}");
    }

    #[test]
    fn clipping_caps_the_global_norm() {
        let cfg = AdamWConfig { clip_norm: 1.0, ..Default::default() };
        let mut opt = AdamW::new(2, cfg);
        let mut p = vec![0.0f32; 2];
        let mut g = vec![3.0f32, 4.0]; // norm 5
        let info = opt.step(&mut p, &mut g);
        assert!((info.grad_norm - 5.0).abs() < 1e-9, "{info:?}");
        assert!(info.clipped);
        let norm_after: f32 = g.iter().map(|&x| x * x).sum::<f32>().sqrt();
        assert!((norm_after - 1.0).abs() < 1e-5, "clipped norm {norm_after}");
        // small gradients pass through untouched
        let mut g = vec![0.1f32, 0.1];
        assert!(!opt.step(&mut p, &mut g).clipped);
    }

    #[test]
    fn steps_move_params_against_the_gradient_and_decay_weights() {
        let cfg = AdamWConfig {
            lr: 0.1,
            warmup_steps: 0,
            weight_decay: 0.0,
            clip_norm: 0.0,
            ..Default::default()
        };
        let mut opt = AdamW::new(1, cfg);
        let mut p = vec![1.0f32];
        for _ in 0..10 {
            let mut g = vec![2.0f32]; // constant positive gradient
            opt.step(&mut p, &mut g);
        }
        assert!(p[0] < 1.0 - 0.5, "param must descend: {}", p[0]);
        assert_eq!(opt.step_count(), 10);

        // pure decay: zero gradient shrinks weights toward zero
        let cfg = AdamWConfig {
            lr: 0.1,
            warmup_steps: 0,
            weight_decay: 0.5,
            clip_norm: 0.0,
            ..Default::default()
        };
        let mut opt = AdamW::new(1, cfg);
        let mut p = vec![1.0f32];
        let mut g = vec![0.0f32];
        opt.step(&mut p, &mut g);
        assert!((p[0] - 0.95).abs() < 1e-6, "decayed to {}", p[0]);
    }

    #[test]
    fn restore_rejects_mismatched_state() {
        let mut opt = AdamW::new(4, AdamWConfig::default());
        assert!(opt.restore(vec![0.0; 3], vec![0.0; 4], 1).is_err());
        assert!(opt.restore(vec![0.0; 4], vec![0.0; 4], 7).is_ok());
        assert_eq!(opt.step_count(), 7);
    }
}
