//! Masked-LM loss: softmax cross-entropy over the vocabulary at the
//! masked positions only (`weights > 0`), averaged by total mask
//! weight — the same objective the AOT `train_*` artifacts optimise.

/// Softmax cross-entropy with label masking.
///
/// `logits` is `[rows, vocab]`, `labels`/`weights` are `[rows]`
/// (weights are 1.0 at predicted positions, 0.0 elsewhere — padding and
/// unmasked tokens contribute nothing). Returns the mean loss over
/// weighted positions (in nats; `ln(vocab)` at uniform logits) and
/// `d_logits` scaled by `weight / Σweights`, so the gradient is of the
/// *mean* loss. A batch with zero mask weight yields loss 0 and zero
/// gradients.
pub fn masked_xent(logits: &[f32], labels: &[i32], weights: &[f32], vocab: usize) -> (f32, Vec<f32>) {
    let rows = labels.len();
    assert_eq!(logits.len(), rows * vocab, "logits must be [rows, vocab]");
    assert_eq!(weights.len(), rows, "weights must be [rows]");
    let mut d = vec![0.0f32; logits.len()];
    let total_w: f64 = weights.iter().map(|&w| w as f64).sum();
    if total_w <= 0.0 {
        return (0.0, d);
    }
    let inv_w = 1.0 / total_w;
    let mut loss = 0.0f64;
    for r in 0..rows {
        let w = weights[r];
        if w <= 0.0 {
            continue;
        }
        let row = &logits[r * vocab..(r + 1) * vocab];
        let maxv = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut lse = 0.0f64;
        for &x in row {
            lse += ((x - maxv) as f64).exp();
        }
        let log_z = lse.ln() + maxv as f64;
        let label = labels[r].rem_euclid(vocab as i32) as usize;
        loss += w as f64 * (log_z - row[label] as f64);
        let scale = w as f64 * inv_w;
        let d_row = &mut d[r * vocab..(r + 1) * vocab];
        for (j, dst) in d_row.iter_mut().enumerate() {
            let p = ((row[j] - maxv) as f64).exp() / lse;
            let target = if j == label { 1.0 } else { 0.0 };
            *dst = ((p - target) * scale) as f32;
        }
    }
    ((loss * inv_w) as f32, d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_logits_give_ln_vocab_and_centered_grads() {
        let (rows, vocab) = (4usize, 32usize);
        let logits = vec![0.25f32; rows * vocab];
        let labels = vec![3i32; rows];
        let weights = vec![1.0f32; rows];
        let (loss, d) = masked_xent(&logits, &labels, &weights, vocab);
        assert!((loss - (vocab as f32).ln()).abs() < 1e-4, "loss {loss}");
        // per-row gradients sum to zero (softmax minus one-hot)
        for r in 0..rows {
            let s: f32 = d[r * vocab..(r + 1) * vocab].iter().sum();
            assert!(s.abs() < 1e-5, "row {r} grad sum {s}");
            // the label coordinate is the only negative one
            for (j, &g) in d[r * vocab..(r + 1) * vocab].iter().enumerate() {
                if j == 3 {
                    assert!(g < 0.0, "label grad must be negative");
                } else {
                    assert!(g > 0.0, "non-label grad must be positive");
                }
            }
        }
    }

    #[test]
    fn zero_weights_are_ignored_entirely() {
        let (rows, vocab) = (3usize, 8usize);
        let logits: Vec<f32> = (0..rows * vocab).map(|i| i as f32 * 0.01).collect();
        let labels = vec![1i32; rows];
        let mut weights = vec![0.0f32; rows];
        let (loss, d) = masked_xent(&logits, &labels, &weights, vocab);
        assert_eq!(loss, 0.0);
        assert!(d.iter().all(|&g| g == 0.0));
        // one live row: loss equals that row's xent, other rows stay zero
        weights[1] = 1.0;
        let (_, d) = masked_xent(&logits, &labels, &weights, vocab);
        assert!(d[..vocab].iter().all(|&g| g == 0.0), "dead row 0 must have zero grads");
        assert!(d[vocab..2 * vocab].iter().any(|&g| g != 0.0), "live row must have grads");
    }

    #[test]
    fn confident_correct_prediction_has_low_loss() {
        let vocab = 16usize;
        let mut logits = vec![0.0f32; vocab];
        logits[5] = 12.0;
        let (loss, _) = masked_xent(&logits, &[5], &[1.0], vocab);
        assert!(loss < 0.01, "loss {loss}");
        let (wrong, _) = masked_xent(&logits, &[6], &[1.0], vocab);
        assert!(wrong > 5.0, "loss {wrong}");
    }
}
