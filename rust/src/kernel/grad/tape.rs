//! Whole-model reverse-mode differentiation for [`NativeModel`]: a
//! training forward that records an activation tape, and a backward
//! that walks it in reverse, accumulating into a [`ParamGrads`] mirror
//! of the parameter layout.
//!
//! The training forward performs **the same arithmetic in the same
//! order** as `NativeModel::forward` (it shares the serving helpers in
//! `kernel::model` and the batch attention driver), so its logits are
//! bit-identical to serving — a checkpoint trained here and a serving
//! forward agree exactly. The only additions are activation saves and
//! the streaming-softmax statistics from
//! [`sparse_forward_batch_training_heads`].
//!
//! Backward structure (per layer, in reverse):
//! tied-logits head → final LN → FFN (`w2`/GELU/`w1`/LN2, residual) →
//! attention (`wo`/merge → flash-style sparse backward → `wq,wk,wv`/LN1,
//! residual) → token embedding scatter. Positions are sinusoidal
//! constants and receive no gradient.

use anyhow::{ensure, Result};

use crate::attention::{CompiledPattern, LEARNED_SPAN};
use crate::config::Precision;
use crate::kernel::driver::{sparse_backward_batch_heads, sparse_forward_batch_training_heads};
use crate::kernel::microkernel::PackedMat;
use crate::kernel::model::{
    add_bias, add_in_place, gelu, gemm_out, merge_heads, split_heads, NativeModel,
};
use crate::kernel::HeadViews;

use super::ops::{
    add_colsum, gelu_bwd, gelu_fwd, layernorm_bwd, layernorm_fwd, matmul_nt, matmul_tn_acc,
    LnStats,
};
use super::params::ParamGrads;

/// Activations one layer saves for its backward pass.
struct LayerTape {
    /// Residual-stream input to the layer, `[rows, h]`.
    x_in: Vec<f32>,
    ln1: LnStats,
    /// Post-LN1 activations (input to the Q/K/V projections).
    xn1: Vec<f32>,
    /// Split-head projections, `[batch, heads, n, dh]`.
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Attention output `O`, `[batch, heads, n, dh]`.
    attn_out: Vec<f32>,
    /// Streaming-softmax row statistics, `[batch × heads × n]` each.
    stat_m: Vec<f32>,
    stat_l: Vec<f32>,
    /// Merged heads (input to the `wo` projection), `[rows, h]`.
    merged: Vec<f32>,
    /// Residual stream after the attention block (input to LN2).
    x_mid: Vec<f32>,
    ln2: LnStats,
    xn2: Vec<f32>,
    /// FFN pre-GELU activations, `[rows, ffn]`.
    ffn_pre: Vec<f32>,
}

/// The recorded forward pass: everything [`backward`] needs.
pub struct Tape {
    batch: usize,
    seq: usize,
    tokens: Vec<i32>,
    kv_valid: Option<Vec<f32>>,
    pattern: CompiledPattern,
    layers: Vec<LayerTape>,
    /// Residual stream entering the final LN.
    x_final: Vec<f32>,
    ln_f: LnStats,
    /// Post-final-LN activations (input to the tied logits head).
    xn_f: Vec<f32>,
}

/// Training forward: `[batch, seq]` token ids (+ optional key-validity
/// mask) → `[batch, seq, vocab]` logits plus the activation [`Tape`].
/// Logits are bit-identical to [`NativeModel::forward`] on the same
/// inputs.
pub fn forward_tape(
    model: &mut NativeModel,
    tokens: &[i32],
    kv_valid: Option<&[f32]>,
    batch: usize,
    seq_len: usize,
) -> Result<(Vec<f32>, Tape)> {
    let rows = batch * seq_len;
    ensure!(tokens.len() == rows, "tokens must be [batch={batch}, seq_len={seq_len}]");
    if let Some(mask) = kv_valid {
        ensure!(mask.len() == rows, "kv_valid must be [batch={batch}, seq_len={seq_len}]");
    }
    let pattern = model.select_pattern(Some((tokens, batch)), seq_len)?;
    let positions = model.positions(seq_len);
    model.ensure_packed();
    let packed = model.packed.as_ref().expect("ensure_packed just ran");
    let (h, heads) = (model.cfg.hidden, model.cfg.heads);
    let vocab = model.cfg.vocab;
    let dh = h / heads;

    // token embedding + sinusoidal positions (same loop as serving)
    let mut x = vec![0.0f32; rows * h];
    for (r, &tok) in tokens.iter().enumerate() {
        let t = tok.rem_euclid(vocab as i32) as usize;
        let dst = &mut x[r * h..(r + 1) * h];
        let emb = &model.embed[t * h..(t + 1) * h];
        let pos = &positions[(r % seq_len) * h..(r % seq_len + 1) * h];
        for ((d, &e), &p) in dst.iter_mut().zip(emb).zip(pos) {
            *d = e + p;
        }
    }

    let mut layer_tapes = Vec::with_capacity(model.cfg.layers);
    for (layer, pl) in model.layers.iter().zip(&packed.layers) {
        let x_in = x.clone();
        // pre-LN block-sparse attention, residual
        let (xn1, ln1) = layernorm_fwd(&x, &layer.ln1_g, &layer.ln1_b, h);
        let q = split_heads(&gemm_out(&xn1, &pl.wq, rows), batch, seq_len, heads, dh);
        let k = split_heads(&gemm_out(&xn1, &pl.wk, rows), batch, seq_len, heads, dh);
        let v = split_heads(&gemm_out(&xn1, &pl.wv, rows), batch, seq_len, heads, dh);
        let mut attn = vec![0.0f32; rows * h];
        let mut stat_m = vec![0.0f32; batch * heads * seq_len];
        let mut stat_l = vec![0.0f32; batch * heads * seq_len];
        let hv = HeadViews { q: &q, k: &k, v: &v, key_valid: kv_valid };
        sparse_forward_batch_training_heads(
            &hv, batch, heads, dh, &pattern, &mut attn, &mut stat_m, &mut stat_l,
        );
        let merged = merge_heads(&attn, batch, seq_len, heads, dh);
        let proj = gemm_out(&merged, &pl.wo, rows);
        add_in_place(&mut x, &proj);
        let x_mid = x.clone();

        // pre-LN GELU FFN, residual
        let (xn2, ln2) = layernorm_fwd(&x, &layer.ln2_g, &layer.ln2_b, h);
        let mut ffn_pre = gemm_out(&xn2, &pl.w1, rows);
        add_bias(&mut ffn_pre, &layer.b1);
        let mut mid = ffn_pre.clone();
        gelu(&mut mid);
        let mut down = gemm_out(&mid, &pl.w2, rows);
        add_bias(&mut down, &layer.b2);
        add_in_place(&mut x, &down);

        layer_tapes.push(LayerTape {
            x_in,
            ln1,
            xn1,
            q,
            k,
            v,
            attn_out: attn,
            stat_m,
            stat_l,
            merged,
            x_mid,
            ln2,
            xn2,
            ffn_pre,
        });
    }

    // final LN + tied-embedding logits
    let (xn_f, ln_f) = layernorm_fwd(&x, &model.ln_f_g, &model.ln_f_b, h);
    let logits = gemm_out(&xn_f, &packed.embed_t, rows);
    let tape = Tape {
        batch,
        seq: seq_len,
        tokens: tokens.to_vec(),
        kv_valid: kv_valid.map(|m| m.to_vec()),
        pattern,
        layers: layer_tapes,
        x_final: x,
        ln_f,
        xn_f,
    };
    Ok((logits, tape))
}

/// Backward over a recorded [`Tape`]: `d_logits` (`[rows, vocab]`, from
/// [`super::masked_xent`]) → parameter gradients. `grads` is zeroed
/// first, then every parameter's gradient — including both tied uses of
/// the embedding — is accumulated.
pub fn backward(model: &NativeModel, tape: &Tape, d_logits: &[f32], grads: &mut ParamGrads) {
    let (batch, seq) = (tape.batch, tape.seq);
    let rows = batch * seq;
    let (h, heads) = (model.cfg.hidden, model.cfg.heads);
    let (vocab, ffn) = (model.cfg.vocab, model.cfg.ffn);
    let dh = h / heads;
    assert_eq!(d_logits.len(), rows * vocab, "d_logits must be [rows, vocab]");
    assert_eq!(tape.layers.len(), model.layers.len(), "tape/model layer count mismatch");
    grads.zero();

    // tied logits head: logits = xn_f · embedᵀ
    //   d_xn_f = d_logits · embed            [rows, h]
    //   d_embed += d_logitsᵀ · xn_f          [vocab, h]
    // (backward GEMMs stay f32 — gradients never quantize)
    let embed_p = PackedMat::pack(&model.embed, vocab, h, Precision::F32);
    let d_xn_f = gemm_out(d_logits, &embed_p, rows);
    matmul_tn_acc(d_logits, &tape.xn_f, &mut grads.embed, rows, vocab, h);

    // final LN
    let mut d = layernorm_bwd(
        &d_xn_f,
        &tape.x_final,
        &tape.ln_f,
        &model.ln_f_g,
        h,
        &mut grads.ln_f_g,
        &mut grads.ln_f_b,
    );

    let kv_valid = tape.kv_valid.as_deref();
    for (l, lt) in tape.layers.iter().enumerate().rev() {
        let layer = &model.layers[l];
        let g = &mut grads.layers[l];

        // ---- FFN block: x_out = x_mid + (gelu(xn2·w1 + b1))·w2 + b2
        let post = gelu_fwd(&lt.ffn_pre);
        add_colsum(&d, &mut g.b2);
        matmul_tn_acc(&post, &d, &mut g.w2, rows, ffn, h);
        let d_post = matmul_nt(&d, &layer.w2, rows, h, ffn);
        let d_pre = gelu_bwd(&d_post, &lt.ffn_pre);
        add_colsum(&d_pre, &mut g.b1);
        matmul_tn_acc(&lt.xn2, &d_pre, &mut g.w1, rows, h, ffn);
        let d_xn2 = matmul_nt(&d_pre, &layer.w1, rows, ffn, h);
        let mut d_x_mid =
            layernorm_bwd(&d_xn2, &lt.x_mid, &lt.ln2, &layer.ln2_g, h, &mut g.ln2_g, &mut g.ln2_b);
        add_in_place(&mut d_x_mid, &d); // residual branch around the FFN

        // ---- attention block: x_mid = x_in + merge(attn)·wo
        matmul_tn_acc(&lt.merged, &d_x_mid, &mut g.wo, rows, h, h);
        let d_merged = matmul_nt(&d_x_mid, &layer.wo, rows, h, h);
        let d_attn = split_heads(&d_merged, batch, seq, heads, dh);
        let vol = batch * heads * seq * dh;
        let mut dq = vec![0.0f32; vol];
        let mut dk = vec![0.0f32; vol];
        let mut dv = vec![0.0f32; vol];
        let hv = HeadViews { q: &lt.q, k: &lt.k, v: &lt.v, key_valid: kv_valid };
        sparse_backward_batch_heads(
            &hv,
            &lt.attn_out,
            &d_attn,
            &lt.stat_m,
            &lt.stat_l,
            batch,
            heads,
            dh,
            &tape.pattern,
            &mut dq,
            &mut dk,
            &mut dv,
        );
        if !grads.sel.is_empty() {
            let nb = tape.pattern.head(0).nb;
            accumulate_selection_grads(&d_attn, &lt.v, batch, seq, heads, dh, nb, &mut grads.sel);
        }
        let d_qp = merge_heads(&dq, batch, seq, heads, dh);
        let d_kp = merge_heads(&dk, batch, seq, heads, dh);
        let d_vp = merge_heads(&dv, batch, seq, heads, dh);
        matmul_tn_acc(&lt.xn1, &d_qp, &mut g.wq, rows, h, h);
        matmul_tn_acc(&lt.xn1, &d_kp, &mut g.wk, rows, h, h);
        matmul_tn_acc(&lt.xn1, &d_vp, &mut g.wv, rows, h, h);
        let mut d_xn1 = matmul_nt(&d_qp, &layer.wq, rows, h, h);
        add_in_place(&mut d_xn1, &matmul_nt(&d_kp, &layer.wk, rows, h, h));
        add_in_place(&mut d_xn1, &matmul_nt(&d_vp, &layer.wv, rows, h, h));
        let mut d_x_in =
            layernorm_bwd(&d_xn1, &lt.x_in, &lt.ln1, &layer.ln1_g, h, &mut g.ln1_g, &mut g.ln1_b);
        add_in_place(&mut d_x_in, &d_x_mid); // residual branch around attention
        d = d_x_in;
    }

    // token embedding scatter (the input-side use of the tied embedding)
    for (r, &tok) in tape.tokens.iter().enumerate() {
        let t = tok.rem_euclid(vocab as i32) as usize;
        let dst = &mut grads.embed[t * h..(t + 1) * h];
        for (gd, &dd) in dst.iter_mut().zip(&d[r * h..(r + 1) * h]) {
            *gd += dd;
        }
    }
}

/// Straight-through gradient for the learned selection scores. The hard
/// top-k pick is non-differentiable, so — in the spirit of
/// straight-through estimators — each relative offset `o` is credited
/// with the alignment between the upstream attention gradient at query
/// block `j` and the values of key block `(j + o + 1) mod nb`,
/// block-mean-pooled per head and summed over query rows (and, via
/// repeated calls, over layers). An offset whose key blocks would have
/// pushed the output where the loss wants it to go gets a negative
/// loss-gradient (score should rise), and vice versa.
#[allow(clippy::too_many_arguments)]
fn accumulate_selection_grads(
    d_attn: &[f32], // [batch, heads, n, dh], upstream gradient of O
    v: &[f32],      // [batch, heads, n, dh]
    batch: usize,
    seq: usize,
    heads: usize,
    dh: usize,
    nb: usize,
    sel: &mut [f32], // [heads × LEARNED_SPAN]
) {
    let block = seq / nb;
    let inv = 1.0 / (batch * block) as f32;
    let span = LEARNED_SPAN.min(nb.saturating_sub(1));
    let mut pd = vec![0.0f32; nb * dh];
    let mut pv = vec![0.0f32; nb * dh];
    for h in 0..heads {
        pd.fill(0.0);
        pv.fill(0.0);
        for b in 0..batch {
            let base = (b * heads + h) * seq;
            for t in 0..seq {
                let j = t / block;
                for c in 0..dh {
                    pd[j * dh + c] += d_attn[(base + t) * dh + c];
                    pv[j * dh + c] += v[(base + t) * dh + c];
                }
            }
        }
        for o in 0..span {
            let mut g = 0.0f32;
            for j in 0..nb {
                let kb = (j + o + 1) % nb;
                for c in 0..dh {
                    g += pd[j * dh + c] * pv[kb * dh + c];
                }
            }
            // the proxy output moves *with* the selected values, so a
            // helpful offset has d_attn · v < 0 exactly when the loss
            // wants the output elsewhere — negate to make "select more
            // of this offset" reduce the loss under gradient descent
            sel[h * LEARNED_SPAN + o] -= g * inv * inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AttnVariant, ModelConfig};
    use crate::util::Rng;

    fn tiny_train_cfg() -> ModelConfig {
        ModelConfig {
            variant: AttnVariant::BigBirdItc,
            seq_len: 32,
            block: 8,
            global_blocks: 1,
            window_blocks: 1,
            random_blocks: 1,
            layers: 2,
            heads: 2,
            hidden: 16,
            ffn: 32,
            vocab: 64,
            batch: 2,
            attn_seed: 5,
            precision: Precision::F32,
            pattern: crate::config::PatternSelect::Static,
        }
    }

    #[test]
    fn training_forward_is_bit_identical_to_serving_forward() {
        let cfg = tiny_train_cfg();
        let (b, s) = (cfg.batch, cfg.seq_len);
        let mut rng = Rng::new(9);
        let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(cfg.vocab) as i32).collect();
        let kv: Vec<f32> = (0..b * s).map(|_| if rng.coin(0.1) { 0.0 } else { 1.0 }).collect();
        let mut model = NativeModel::new(cfg).unwrap();
        let serving = model.forward(&tokens, Some(&kv), b, s).unwrap();
        let (training, _tape) = forward_tape(&mut model, &tokens, Some(&kv), b, s).unwrap();
        assert_eq!(serving, training, "tape forward must match serving bit-for-bit");
    }

    #[test]
    fn backward_produces_finite_nonzero_grads_for_every_tensor() {
        let cfg = tiny_train_cfg();
        let (b, s) = (cfg.batch, cfg.seq_len);
        let vocab = cfg.vocab;
        let mut rng = Rng::new(4);
        let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(vocab) as i32).collect();
        let mut model = NativeModel::new(cfg).unwrap();
        let (logits, tape) = forward_tape(&mut model, &tokens, None, b, s).unwrap();
        let labels = tokens.clone();
        let weights: Vec<f32> = (0..b * s).map(|i| if i % 5 == 0 { 1.0 } else { 0.0 }).collect();
        let (loss, d_logits) = super::super::masked_xent(&logits, &labels, &weights, vocab);
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        let mut grads = ParamGrads::new(model.config());
        backward(&model, &tape, &d_logits, &mut grads);
        let mut flat = Vec::new();
        grads.flatten_into(&mut flat);
        assert!(flat.iter().all(|g| g.is_finite()), "gradients must be finite");
        assert!(grads.global_norm() > 0.0, "gradient must be nonzero");
        // spot-check: every per-layer tensor received some gradient
        for (l, g) in grads.layers.iter().enumerate() {
            for (name, t) in [
                ("wq", &g.wq),
                ("wk", &g.wk),
                ("wv", &g.wv),
                ("wo", &g.wo),
                ("w1", &g.w1),
                ("w2", &g.w2),
                ("ln1_g", &g.ln1_g),
                ("ln2_g", &g.ln2_g),
            ] {
                assert!(t.iter().any(|&x| x != 0.0), "layer {l} {name} got no gradient");
            }
        }
        assert!(grads.embed.iter().any(|&x| x != 0.0), "embed got no gradient");
        assert!(grads.ln_f_g.iter().any(|&x| x != 0.0), "ln_f_g got no gradient");
    }
}
