//! [`ParamGrads`]: gradient accumulators mirroring the
//! [`NativeModel`](crate::kernel::NativeModel) parameter layout
//! tensor-for-tensor, flattening to the **same canonical order** as
//! `NativeModel::flatten_params` (embed, then per layer
//! `ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w1, b1, w2, b2`, then
//! `ln_f_g, ln_f_b`, then — learned patterns only — the per-head
//! selection scores `sel`) so the optimizer and checkpoints see one
//! flat vector for both parameters and gradients.

use crate::attention::LEARNED_SPAN;
use crate::config::ModelConfig;

/// Per-layer gradient tensors (same shapes as the layer's parameters).
#[derive(Clone, Debug)]
pub struct LayerGrads {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

/// Whole-model gradient accumulator. The tied embedding receives both
/// the input-embedding and the output-head contributions in `embed`.
#[derive(Clone, Debug)]
pub struct ParamGrads {
    pub embed: Vec<f32>,
    pub layers: Vec<LayerGrads>,
    pub ln_f_g: Vec<f32>,
    pub ln_f_b: Vec<f32>,
    /// Straight-through gradient of the learned per-head selection
    /// scores, `[heads × LEARNED_SPAN]` — empty unless the config's
    /// pattern is `Learned`.
    pub sel: Vec<f32>,
}

impl ParamGrads {
    /// Zeroed gradients shaped for `cfg`.
    pub fn new(cfg: &ModelConfig) -> Self {
        let h = cfg.hidden;
        let layers = (0..cfg.layers)
            .map(|_| LayerGrads {
                ln1_g: vec![0.0; h],
                ln1_b: vec![0.0; h],
                wq: vec![0.0; h * h],
                wk: vec![0.0; h * h],
                wv: vec![0.0; h * h],
                wo: vec![0.0; h * h],
                ln2_g: vec![0.0; h],
                ln2_b: vec![0.0; h],
                w1: vec![0.0; h * cfg.ffn],
                b1: vec![0.0; cfg.ffn],
                w2: vec![0.0; cfg.ffn * h],
                b2: vec![0.0; h],
            })
            .collect();
        let sel =
            if cfg.pattern.is_learned() { vec![0.0; cfg.heads * LEARNED_SPAN] } else { Vec::new() };
        ParamGrads {
            embed: vec![0.0; cfg.vocab * h],
            layers,
            ln_f_g: vec![0.0; h],
            ln_f_b: vec![0.0; h],
            sel,
        }
    }

    /// Gradient tensors in the canonical flattening order.
    fn tensors(&self) -> Vec<&Vec<f32>> {
        let mut out = Vec::with_capacity(3 + 12 * self.layers.len() + 1);
        out.push(&self.embed);
        for l in &self.layers {
            out.push(&l.ln1_g);
            out.push(&l.ln1_b);
            out.push(&l.wq);
            out.push(&l.wk);
            out.push(&l.wv);
            out.push(&l.wo);
            out.push(&l.ln2_g);
            out.push(&l.ln2_b);
            out.push(&l.w1);
            out.push(&l.b1);
            out.push(&l.w2);
            out.push(&l.b2);
        }
        out.push(&self.ln_f_g);
        out.push(&self.ln_f_b);
        if !self.sel.is_empty() {
            out.push(&self.sel);
        }
        out
    }

    /// Reset every accumulator to zero (buffers are kept).
    pub fn zero(&mut self) {
        self.embed.fill(0.0);
        for l in &mut self.layers {
            l.ln1_g.fill(0.0);
            l.ln1_b.fill(0.0);
            l.wq.fill(0.0);
            l.wk.fill(0.0);
            l.wv.fill(0.0);
            l.wo.fill(0.0);
            l.ln2_g.fill(0.0);
            l.ln2_b.fill(0.0);
            l.w1.fill(0.0);
            l.b1.fill(0.0);
            l.w2.fill(0.0);
            l.b2.fill(0.0);
        }
        self.ln_f_g.fill(0.0);
        self.ln_f_b.fill(0.0);
        self.sel.fill(0.0);
    }

    /// Total gradient element count (equals the model's `param_count`).
    pub fn len(&self) -> usize {
        self.tensors().iter().map(|t| t.len()).sum()
    }

    /// True when the accumulator holds no tensors (never the case for a
    /// real config; provided for API completeness).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Flatten into `out` (cleared first) in the canonical order shared
    /// with `NativeModel::flatten_params`.
    pub fn flatten_into(&self, out: &mut Vec<f32>) {
        out.clear();
        for t in self.tensors() {
            out.extend_from_slice(t);
        }
    }

    /// Global L2 norm of the gradient (f64 accumulation).
    pub fn global_norm(&self) -> f64 {
        let mut sum = 0.0f64;
        for t in self.tensors() {
            for &g in t.iter() {
                sum += g as f64 * g as f64;
            }
        }
        sum.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn flat_length_matches_model_param_count() {
        let cfg = ModelConfig::tiny();
        let grads = ParamGrads::new(&cfg);
        assert_eq!(grads.len(), crate::kernel::model::param_count_for(&cfg));
        let mut flat = Vec::new();
        grads.flatten_into(&mut flat);
        assert_eq!(flat.len(), grads.len());
        assert!(!grads.is_empty());
        assert_eq!(grads.global_norm(), 0.0);
    }

    #[test]
    fn learned_pattern_adds_selection_grads() {
        let mut cfg = ModelConfig::tiny();
        cfg.pattern = crate::config::PatternSelect::Learned { k: 2 };
        let grads = ParamGrads::new(&cfg);
        assert_eq!(grads.sel.len(), cfg.heads * LEARNED_SPAN);
        assert_eq!(grads.len(), crate::kernel::model::param_count_for(&cfg));
        let static_len = ParamGrads::new(&ModelConfig::tiny()).len();
        assert_eq!(grads.len(), static_len + cfg.heads * LEARNED_SPAN);
    }
}
