//! The native serving model: a scaled-down BigBird MLM forward pass
//! computed entirely in Rust on top of the sparse kernel — no PJRT, no
//! AOT artifacts.
//!
//! Architecture (mirrors the JAX side's encoder at `ModelConfig::tiny`
//! scale): token embedding + sinusoidal positions → `layers ×`
//! (pre-LN block-sparse attention + pre-LN GELU FFN, both residual) →
//! final LN → logits through the tied embedding. Parameters are
//! initialised deterministically from `ModelConfig::attn_seed` (the
//! same convention as the AOT `init_*` artifacts), so every worker —
//! and every run — materialises identical weights and serving stays
//! reproducible.
//!
//! [`NativeEngine`] is the engine-worker-facing wrapper: it lazily
//! builds the model, maps pool jobs (tokens + kv_valid tensors) to
//! forward passes, and pre-warms per-bucket pattern layouts.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::attention::{
    block_mean_pool, proxy_scores, CompiledPattern, PatternSource, PatternSpec, LEARNED_SPAN,
};
use crate::config::{ModelConfig, PatternSelect, Precision};
use crate::runtime::{HostTensor, JobShape};
use crate::util::Rng;

use super::driver::{model_gemm, sparse_forward_batch_heads, with_select_cache};
use super::microkernel::PackedMat;
use super::HeadViews;

/// Name prefix of every native serving artifact (bucket).
pub const NATIVE_PREFIX: &str = "native_mlm_";

/// Is this artifact name served by the native kernel subsystem (rather
/// than a PJRT executable)?
pub fn is_native_artifact(name: &str) -> bool {
    name.starts_with(NATIVE_PREFIX)
}

/// Artifact name of the native bucket for `(seq_len, batch)`.
pub fn native_artifact_name(seq_len: usize, batch: usize) -> String {
    format!("{NATIVE_PREFIX}s{seq_len}_b{batch}")
}

/// Parse `(seq_len, batch)` back out of a native artifact name.
pub fn parse_native_artifact(name: &str) -> Option<(usize, usize)> {
    let rest = name.strip_prefix(NATIVE_PREFIX)?.strip_prefix('s')?;
    let (s, b) = rest.split_once("_b")?;
    Some((s.parse().ok()?, b.parse().ok()?))
}

/// The `(seq_len, batch)` serving buckets the native backend exposes —
/// the same length ladder as the AOT manifest, with batch sizes that
/// keep per-batch latency roughly flat.
pub fn native_buckets() -> [(usize, usize); 5] {
    [(128, 8), (256, 4), (512, 4), (1024, 2), (2048, 1)]
}

/// Artifact name under which the serving stack installs **trained
/// parameters** on native workers (`EnginePool::load_params` routing
/// key; carries the native prefix so it reaches the kernel engine).
pub const NATIVE_PARAMS_ARTIFACT: &str = "native_mlm_params";

/// One transformer layer's parameters. Fields are crate-visible so the
/// gradient subsystem ([`crate::kernel::grad`]) can read them during
/// the backward pass.
pub(crate) struct LayerParams {
    pub(crate) ln1_g: Vec<f32>,
    pub(crate) ln1_b: Vec<f32>,
    pub(crate) wq: Vec<f32>,
    pub(crate) wk: Vec<f32>,
    pub(crate) wv: Vec<f32>,
    pub(crate) wo: Vec<f32>,
    pub(crate) ln2_g: Vec<f32>,
    pub(crate) ln2_b: Vec<f32>,
    pub(crate) w1: Vec<f32>,
    pub(crate) b1: Vec<f32>,
    pub(crate) w2: Vec<f32>,
    pub(crate) b2: Vec<f32>,
}

/// The native BigBird MLM model: deterministic parameters + per-bucket
/// compiled pattern layouts and positional tables, cached across
/// forward passes. `ModelConfig::seq_len`/`batch` are treated as upper
/// bounds only — each forward pass brings its own `(batch, seq_len)`.
pub struct NativeModel {
    pub(crate) cfg: ModelConfig,
    /// Token embedding, `[vocab, hidden]`.
    pub(crate) embed: Vec<f32>,
    /// Transposed embedding, `[hidden, vocab]` — the tied output head.
    /// Derived from `embed`; rebuilt by [`NativeModel::load_flat_params`].
    pub(crate) embed_t: Vec<f32>,
    pub(crate) layers: Vec<LayerParams>,
    pub(crate) ln_f_g: Vec<f32>,
    pub(crate) ln_f_b: Vec<f32>,
    /// Learned per-head block-selection scores, `[heads × LEARNED_SPAN]`
    /// (offset-relative; see `attention::select`). Empty unless
    /// `cfg.pattern` is `Learned` — when present these are trainable
    /// parameters at the **end** of the canonical flat order.
    pub(crate) sel_scores: Vec<f32>,
    /// Compiled static patterns keyed by seq_len (adaptive/learned
    /// patterns are content-dependent and cache in the kernel driver's
    /// per-thread [`SelectCache`](super::driver::SelectCache) instead).
    layouts: HashMap<usize, CompiledPattern>,
    /// Sinusoidal position tables keyed by seq_len (`[seq_len, hidden]`).
    pos: HashMap<usize, Arc<Vec<f32>>>,
    /// Weights pre-packed (and, at f16/int8, quantized) for the tiled
    /// GEMM layer at `cfg.precision`. Master weights above stay f32
    /// (checkpoints remain `BBCKPT1`-compatible); this cache is rebuilt
    /// lazily after every [`NativeModel::load_flat_params`].
    pub(crate) packed: Option<PackedWeights>,
}

/// One layer's GEMM operands packed for the microkernel layer. LN
/// gains/biases and the FFN biases are element-wise (no GEMM) and stay
/// on the f32 tensors.
pub(crate) struct PackedLayer {
    pub(crate) wq: PackedMat,
    pub(crate) wk: PackedMat,
    pub(crate) wv: PackedMat,
    pub(crate) wo: PackedMat,
    pub(crate) w1: PackedMat,
    pub(crate) w2: PackedMat,
}

/// Every GEMM operand of the forward pass, packed once at a precision
/// and reused until the weights (or the precision) change.
pub(crate) struct PackedWeights {
    pub(crate) precision: Precision,
    pub(crate) layers: Vec<PackedLayer>,
    /// The tied output head `[hidden, vocab]`.
    pub(crate) embed_t: PackedMat,
}

const INIT_STD: f32 = 0.02;

fn init_normal(seed: u64, label: u64, len: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed).fold_in(label);
    (0..len).map(|_| rng.normal() as f32 * INIT_STD).collect()
}

impl NativeModel {
    /// Build the model with deterministic parameters derived from
    /// `cfg.attn_seed`.
    pub fn new(cfg: ModelConfig) -> Result<Self> {
        cfg.validate()?;
        let h = cfg.hidden;
        let seed = cfg.attn_seed;
        let embed = init_normal(seed, 1, cfg.vocab * h);
        let mut embed_t = vec![0.0f32; h * cfg.vocab];
        for t in 0..cfg.vocab {
            for i in 0..h {
                embed_t[i * cfg.vocab + t] = embed[t * h + i];
            }
        }
        let mut layers = Vec::with_capacity(cfg.layers);
        for l in 0..cfg.layers {
            let base = 16 * (l as u64 + 1);
            layers.push(LayerParams {
                ln1_g: vec![1.0; h],
                ln1_b: vec![0.0; h],
                wq: init_normal(seed, base + 1, h * h),
                wk: init_normal(seed, base + 2, h * h),
                wv: init_normal(seed, base + 3, h * h),
                wo: init_normal(seed, base + 4, h * h),
                ln2_g: vec![1.0; h],
                ln2_b: vec![0.0; h],
                w1: init_normal(seed, base + 5, h * cfg.ffn),
                b1: vec![0.0; cfg.ffn],
                w2: init_normal(seed, base + 6, cfg.ffn * h),
                b2: vec![0.0; h],
            });
        }
        let sel_scores = if cfg.pattern.is_learned() {
            init_normal(seed, 7, cfg.heads * LEARNED_SPAN)
        } else {
            Vec::new()
        };
        Ok(NativeModel {
            cfg,
            embed,
            embed_t,
            layers,
            ln_f_g: vec![1.0; h],
            ln_f_b: vec![0.0; h],
            sel_scores,
            layouts: HashMap::new(),
            pos: HashMap::new(),
            packed: None,
        })
    }

    /// Ensure the packed-weight cache exists at `cfg.precision`,
    /// repacking (quantize-on-pack) if it is missing, stale after a
    /// parameter load, or at the wrong precision.
    pub(crate) fn ensure_packed(&mut self) {
        let p = self.cfg.precision;
        if self.packed.as_ref().map(|pw| pw.precision == p).unwrap_or(false) {
            return;
        }
        let h = self.cfg.hidden;
        let (vocab, ffn) = (self.cfg.vocab, self.cfg.ffn);
        let layers = self
            .layers
            .iter()
            .map(|l| PackedLayer {
                wq: PackedMat::pack(&l.wq, h, h, p),
                wk: PackedMat::pack(&l.wk, h, h, p),
                wv: PackedMat::pack(&l.wv, h, h, p),
                wo: PackedMat::pack(&l.wo, h, h, p),
                w1: PackedMat::pack(&l.w1, h, ffn, p),
                w2: PackedMat::pack(&l.w2, ffn, h, p),
            })
            .collect();
        let embed_t = PackedMat::pack(&self.embed_t, h, vocab, p);
        self.packed = Some(PackedWeights { precision: p, layers, embed_t });
    }

    /// The model's hyperparameters.
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Total learned parameter count (for startup logging and the flat
    /// checkpoint layout).
    pub fn param_count(&self) -> usize {
        param_count_for(&self.cfg)
    }

    /// The pattern spec of this model family at `seq_len`.
    pub fn pattern_spec(&self, seq_len: usize) -> PatternSpec {
        PatternSpec {
            variant: self.cfg.variant,
            nb: seq_len / self.cfg.block,
            global_blocks: self.cfg.global_blocks,
            window_blocks: self.cfg.window_blocks,
            random_blocks: self.cfg.random_blocks,
            seed: self.cfg.attn_seed,
        }
    }

    /// The [`PatternSource`] this model compiles attention layouts from
    /// at `seq_len`: `cfg.pattern` decides the kind, `tokens` (when
    /// present) feeds the content-adaptive selector. With no content —
    /// warmup, or an adaptive model probed shape-only — the adaptive
    /// scores are zero and the selector falls back to its deterministic
    /// lowest-index tie-break.
    pub fn pattern_source(&self, tokens: Option<(&[i32], usize)>, seq_len: usize) -> PatternSource {
        let spec = self.pattern_spec(seq_len);
        let nb = spec.nb;
        match self.cfg.pattern {
            PatternSelect::Static => PatternSource::Static(spec),
            PatternSelect::Adaptive { .. } => {
                let k = self.cfg.pattern.budget(self.cfg.random_blocks);
                let (h, heads) = (self.cfg.hidden, self.cfg.heads);
                let scores = match tokens {
                    Some((toks, batch)) if batch > 0 => {
                        // block-mean-pool the raw token embeddings
                        // (positions are shared by every input, so they
                        // carry no content signal), then score through
                        // the first layer's Q/K projections
                        let mut x = vec![0.0f32; batch * seq_len * h];
                        for (r, &tok) in toks.iter().enumerate() {
                            let t = tok.rem_euclid(self.cfg.vocab as i32) as usize;
                            x[r * h..(r + 1) * h].copy_from_slice(&self.embed[t * h..(t + 1) * h]);
                        }
                        let pooled = block_mean_pool(&x, batch, seq_len, h, self.cfg.block);
                        let l0 = &self.layers[0];
                        proxy_scores(&pooled, &l0.wq, &l0.wk, h, heads, nb)
                    }
                    _ => vec![vec![0.0f32; nb * nb]; heads],
                };
                PatternSource::Adaptive { spec, k, scores }
            }
            PatternSelect::Learned { .. } => {
                let k = self.cfg.pattern.budget(self.cfg.random_blocks);
                let scores =
                    self.sel_scores.chunks(LEARNED_SPAN).map(|c| c.to_vec()).collect::<Vec<_>>();
                PatternSource::Learned { spec, k, scores }
            }
        }
    }

    /// Compiled attention pattern for one forward pass. Static patterns
    /// cache per `seq_len` in the model; adaptive/learned patterns are
    /// fingerprinted and cached in the calling thread's kernel-pool
    /// scratch ([`with_select_cache`]), so serving recompiles only when
    /// the selected graph actually changes.
    pub fn select_pattern(
        &mut self,
        tokens: Option<(&[i32], usize)>,
        seq_len: usize,
    ) -> Result<CompiledPattern> {
        ensure!(
            seq_len > 0 && seq_len % self.cfg.block == 0,
            "seq_len {} is not a positive multiple of block {}",
            seq_len,
            self.cfg.block
        );
        if self.cfg.pattern == PatternSelect::Static {
            let src = PatternSource::Static(self.pattern_spec(seq_len));
            let block = self.cfg.block;
            return Ok(self.layouts.entry(seq_len).or_insert_with(|| src.compile(block)).clone());
        }
        let src = self.pattern_source(tokens, seq_len);
        let key = src.fingerprint(self.cfg.block);
        let block = self.cfg.block;
        Ok(with_select_cache(|cache| cache.get_or_compile(key, || src.compile(block))))
    }

    /// Sinusoidal positional table for `seq_len` (cached).
    pub(crate) fn positions(&mut self, seq_len: usize) -> Arc<Vec<f32>> {
        let h = self.cfg.hidden;
        self.pos
            .entry(seq_len)
            .or_insert_with(|| {
                let mut table = vec![0.0f32; seq_len * h];
                for p in 0..seq_len {
                    for i in 0..h / 2 {
                        let freq = 1.0 / 10000f64.powf(2.0 * i as f64 / h as f64);
                        let angle = p as f64 * freq;
                        table[p * h + 2 * i] = angle.sin() as f32;
                        table[p * h + 2 * i + 1] = angle.cos() as f32;
                    }
                }
                Arc::new(table)
            })
            .clone()
    }

    /// Pre-build the layout and positional table for a bucket length
    /// (the warmup path, so first traffic pays no compile cost).
    pub fn prewarm(&mut self, seq_len: usize) -> Result<()> {
        self.select_pattern(None, seq_len)?;
        self.positions(seq_len);
        Ok(())
    }

    /// Full MLM forward: `[batch, seq_len]` token ids (+ optional
    /// `[batch, seq_len]` key-validity mask) → `[batch, seq_len, vocab]`
    /// logits, row-major.
    pub fn forward(
        &mut self,
        tokens: &[i32],
        kv_valid: Option<&[f32]>,
        batch: usize,
        seq_len: usize,
    ) -> Result<Vec<f32>> {
        let rows = batch * seq_len;
        ensure!(tokens.len() == rows, "tokens must be [batch={batch}, seq_len={seq_len}]");
        if let Some(mask) = kv_valid {
            ensure!(mask.len() == rows, "kv_valid must be [batch={batch}, seq_len={seq_len}]");
        }
        let pattern = self.select_pattern(Some((tokens, batch)), seq_len)?;
        let positions = self.positions(seq_len);
        self.ensure_packed();
        let packed = self.packed.as_ref().expect("ensure_packed just ran");
        let (h, heads) = (self.cfg.hidden, self.cfg.heads);
        let vocab = self.cfg.vocab;
        let dh = h / heads;

        // token embedding + sinusoidal positions
        let mut x = vec![0.0f32; rows * h];
        for (r, &tok) in tokens.iter().enumerate() {
            let t = tok.rem_euclid(vocab as i32) as usize;
            let dst = &mut x[r * h..(r + 1) * h];
            let emb = &self.embed[t * h..(t + 1) * h];
            let pos = &positions[(r % seq_len) * h..(r % seq_len + 1) * h];
            for ((d, &e), &p) in dst.iter_mut().zip(emb).zip(pos) {
                *d = e + p;
            }
        }

        for (layer, pl) in self.layers.iter().zip(&packed.layers) {
            // pre-LN block-sparse attention, residual
            let xn = layernorm(&x, &layer.ln1_g, &layer.ln1_b, h);
            let q = split_heads(&gemm_out(&xn, &pl.wq, rows), batch, seq_len, heads, dh);
            let k = split_heads(&gemm_out(&xn, &pl.wk, rows), batch, seq_len, heads, dh);
            let v = split_heads(&gemm_out(&xn, &pl.wv, rows), batch, seq_len, heads, dh);
            let mut attn = vec![0.0f32; rows * h];
            let hv = HeadViews { q: &q, k: &k, v: &v, key_valid: kv_valid };
            sparse_forward_batch_heads(&hv, batch, heads, dh, &pattern, &mut attn);
            let merged = merge_heads(&attn, batch, seq_len, heads, dh);
            let proj = gemm_out(&merged, &pl.wo, rows);
            add_in_place(&mut x, &proj);

            // pre-LN GELU FFN, residual
            let xn = layernorm(&x, &layer.ln2_g, &layer.ln2_b, h);
            let mut mid = gemm_out(&xn, &pl.w1, rows);
            add_bias(&mut mid, &layer.b1);
            gelu(&mut mid);
            let mut down = gemm_out(&mid, &pl.w2, rows);
            add_bias(&mut down, &layer.b2);
            add_in_place(&mut x, &down);
        }

        // final LN + tied-embedding logits
        let xn = layernorm(&x, &self.ln_f_g, &self.ln_f_b, h);
        Ok(gemm_out(&xn, &packed.embed_t, rows))
    }

    /// Learned parameter tensors in the **canonical flattening order**:
    /// `embed`, then per layer `ln1_g, ln1_b, wq, wk, wv, wo, ln2_g,
    /// ln2_b, w1, b1, w2, b2`, then `ln_f_g, ln_f_b`, then (learned
    /// patterns only) `sel_scores`. The derived `embed_t` is excluded
    /// (rebuilt after loads). This order is the contract shared with
    /// `grad::ParamGrads::flatten_into` and the `BBCKPT1` native
    /// checkpoint.
    fn param_tensors(&self) -> Vec<&Vec<f32>> {
        let mut out = Vec::with_capacity(4 + 12 * self.layers.len());
        out.push(&self.embed);
        for l in &self.layers {
            out.push(&l.ln1_g);
            out.push(&l.ln1_b);
            out.push(&l.wq);
            out.push(&l.wk);
            out.push(&l.wv);
            out.push(&l.wo);
            out.push(&l.ln2_g);
            out.push(&l.ln2_b);
            out.push(&l.w1);
            out.push(&l.b1);
            out.push(&l.w2);
            out.push(&l.b2);
        }
        out.push(&self.ln_f_g);
        out.push(&self.ln_f_b);
        if !self.sel_scores.is_empty() {
            out.push(&self.sel_scores);
        }
        out
    }

    /// Mutable view of [`NativeModel::param_tensors`] (same order).
    fn param_tensors_mut(&mut self) -> Vec<&mut Vec<f32>> {
        let mut out = Vec::with_capacity(4 + 12 * self.layers.len());
        out.push(&mut self.embed);
        for l in &mut self.layers {
            out.push(&mut l.ln1_g);
            out.push(&mut l.ln1_b);
            out.push(&mut l.wq);
            out.push(&mut l.wk);
            out.push(&mut l.wv);
            out.push(&mut l.wo);
            out.push(&mut l.ln2_g);
            out.push(&mut l.ln2_b);
            out.push(&mut l.w1);
            out.push(&mut l.b1);
            out.push(&mut l.w2);
            out.push(&mut l.b2);
        }
        out.push(&mut self.ln_f_g);
        out.push(&mut self.ln_f_b);
        if !self.sel_scores.is_empty() {
            out.push(&mut self.sel_scores);
        }
        out
    }

    /// Flatten every learned parameter into one `[param_count]` vector
    /// in the canonical order (see [`NativeModel::param_tensors`]).
    pub fn flatten_params(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.param_count());
        self.flatten_params_into(&mut out);
        out
    }

    /// [`NativeModel::flatten_params`] into a reusable buffer (cleared
    /// first) — the training step's allocation-free path.
    pub fn flatten_params_into(&self, out: &mut Vec<f32>) {
        out.clear();
        for t in self.param_tensors() {
            out.extend_from_slice(t);
        }
    }

    /// Install a flat parameter vector (the inverse of
    /// [`NativeModel::flatten_params`]) and rebuild the tied output
    /// head. Rejects — with a descriptive error and **without touching
    /// the current weights** — vectors of the wrong length or containing
    /// non-finite values, so a partial or mismatched checkpoint can
    /// never silently serve stale or garbage parameters.
    pub fn load_flat_params(&mut self, flat: &[f32]) -> Result<()> {
        let want = self.param_count();
        ensure!(
            flat.len() == want,
            "flat parameter vector has {} values but this model ({} layers, hidden {}, vocab {}) \
             expects {want} — checkpoint/model config mismatch",
            flat.len(),
            self.cfg.layers,
            self.cfg.hidden,
            self.cfg.vocab
        );
        if let Some(pos) = flat.iter().position(|v| !v.is_finite()) {
            bail!("flat parameter vector contains a non-finite value at index {pos}");
        }
        let mut off = 0usize;
        for t in self.param_tensors_mut() {
            let n = t.len();
            t.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        debug_assert_eq!(off, want);
        self.rebuild_tied_head();
        // new master weights ⇒ the packed/quantized operands are stale
        self.packed = None;
        Ok(())
    }

    /// Recompute `embed_t` (the `[hidden, vocab]` tied output head) from
    /// `embed` after a parameter update.
    pub(crate) fn rebuild_tied_head(&mut self) {
        let h = self.cfg.hidden;
        let vocab = self.cfg.vocab;
        for t in 0..vocab {
            for i in 0..h {
                self.embed_t[i * vocab + t] = self.embed[t * h + i];
            }
        }
    }
}

/// Parameter count of the native model family for `cfg` — the length of
/// the flat parameter/gradient/optimizer-state vectors.
pub fn param_count_for(cfg: &ModelConfig) -> usize {
    let h = cfg.hidden;
    let per_layer = 4 * h // layer norms
        + 4 * h * h // q, k, v, o
        + h * cfg.ffn + cfg.ffn // w1 + b1
        + cfg.ffn * h + h; // w2 + b2
    let sel = if cfg.pattern.is_learned() { cfg.heads * LEARNED_SPAN } else { 0 };
    cfg.vocab * h + cfg.layers * per_layer + 2 * h + sel
}

/// Architecture fingerprint stored inside native checkpoints: every
/// hyperparameter that changes the parameter layout or the attention
/// pattern. Serving refuses a checkpoint whose fingerprint disagrees
/// with its own config (seq_len/batch are deliberately excluded — they
/// are per-bucket runtime shapes, not parameters).
pub fn config_fingerprint(cfg: &ModelConfig) -> Vec<i32> {
    let variant_idx = crate::config::AttnVariant::all()
        .iter()
        .position(|v| *v == cfg.variant)
        .map(|i| i as i32)
        .unwrap_or(-1);
    vec![
        cfg.vocab as i32,
        cfg.hidden as i32,
        cfg.layers as i32,
        cfg.heads as i32,
        cfg.ffn as i32,
        cfg.block as i32,
        cfg.global_blocks as i32,
        cfg.window_blocks as i32,
        cfg.random_blocks as i32,
        variant_idx,
        cfg.attn_seed as u32 as i32,
        (cfg.attn_seed >> 32) as u32 as i32,
        // pattern selection kind + resolved budget: a learned model has
        // extra parameters; an adaptive one computes a different graph —
        // neither may silently load a static checkpoint's config
        cfg.pattern.kind_index() as i32,
        cfg.pattern.budget(cfg.random_blocks) as i32,
    ]
}

// ---------------------------------------------------------------------
// dense helpers — crate visible so the training forward
// (kernel::grad::tape) runs the exact same arithmetic and stays
// bit-identical to serving. The old naive ikj matmul lives on only as
// `kernel::reference::matmul`, the test oracle; every model GEMM now
// routes through the packed microkernel layer below.
// ---------------------------------------------------------------------

/// Allocate-and-run wrapper over the pooled packed GEMM:
/// `a[rows, w.k()] · w → [rows, w.n()]`.
pub(crate) fn gemm_out(a: &[f32], w: &PackedMat, rows: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * w.n()];
    model_gemm(a, w, rows, &mut out);
    out
}

pub(crate) fn layernorm(x: &[f32], gamma: &[f32], beta: &[f32], h: usize) -> Vec<f32> {
    // single implementation shared with training (bit-parity by
    // construction): the stats the backward needs are discarded here
    crate::kernel::grad::ops::layernorm_fwd(x, gamma, beta, h).0
}

pub(crate) fn gelu(x: &mut [f32]) {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    for v in x.iter_mut() {
        let u = *v;
        *v = 0.5 * u * (1.0 + (c * (u + 0.044715 * u * u * u)).tanh());
    }
}

pub(crate) fn add_in_place(x: &mut [f32], y: &[f32]) {
    for (a, &b) in x.iter_mut().zip(y) {
        *a += b;
    }
}

pub(crate) fn add_bias(x: &mut [f32], bias: &[f32]) {
    for row in x.chunks_mut(bias.len()) {
        for (a, &b) in row.iter_mut().zip(bias) {
            *a += b;
        }
    }
}

/// `[batch, seq, heads, dh]` (a projection's natural layout) →
/// `[batch, heads, seq, dh]` (the driver's layout).
pub(crate) fn split_heads(p: &[f32], batch: usize, seq: usize, heads: usize, dh: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; p.len()];
    for bi in 0..batch {
        for si in 0..seq {
            for hh in 0..heads {
                let src = ((bi * seq + si) * heads + hh) * dh;
                let dst = ((bi * heads + hh) * seq + si) * dh;
                out[dst..dst + dh].copy_from_slice(&p[src..src + dh]);
            }
        }
    }
    out
}

/// Inverse of [`split_heads`].
pub(crate) fn merge_heads(p: &[f32], batch: usize, seq: usize, heads: usize, dh: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; p.len()];
    for bi in 0..batch {
        for hh in 0..heads {
            for si in 0..seq {
                let src = ((bi * heads + hh) * seq + si) * dh;
                let dst = ((bi * seq + si) * heads + hh) * dh;
                out[dst..dst + dh].copy_from_slice(&p[src..src + dh]);
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// engine-facing wrapper
// ---------------------------------------------------------------------

/// The native execution engine owned by one pool worker: lazily builds
/// the [`NativeModel`] and serves pool jobs as real forward passes.
pub struct NativeEngine {
    cfg: ModelConfig,
    model: Option<NativeModel>,
    load_params_noted: bool,
}

impl NativeEngine {
    /// Engine for the given model family (`seq_len`/`batch` in `cfg`
    /// are defaults only; each job brings its own bucket shape).
    pub fn new(cfg: ModelConfig) -> Self {
        NativeEngine { cfg, model: None, load_params_noted: false }
    }

    fn ensure_model(&mut self) -> Result<&mut NativeModel> {
        if self.model.is_none() {
            let t0 = Instant::now();
            let model =
                NativeModel::new(self.cfg.clone()).context("building native serving model")?;
            crate::log!(
                crate::obs::log::Level::Info,
                "kernel",
                "built native model ({} params) in {:.2}s",
                model.param_count(),
                t0.elapsed().as_secs_f64()
            );
            self.model = Some(model);
        }
        Ok(self.model.as_mut().expect("just built"))
    }

    /// Execute one pool job: `(tokens i32[b,s], kv_valid f32[b,s])` →
    /// `logits f32[b,s,vocab]`.
    pub fn execute(&mut self, shape: JobShape, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        ensure!(
            inputs.len() == 2,
            "native engine expects (tokens, kv_valid) inputs, got {}",
            inputs.len()
        );
        let tokens = inputs[0].as_i32().context("native engine input #0 (tokens)")?;
        let kv_valid = inputs[1].as_f32().context("native engine input #1 (kv_valid)")?;
        let dims = inputs[0].shape();
        let [b, s] = dims else {
            bail!("tokens must be rank-2 [batch, seq_len], got shape {dims:?}");
        };
        let (b, s) = (*b, *s);
        ensure!(
            inputs[1].shape() == [b, s],
            "kv_valid shape {:?} must match tokens [{b}, {s}]",
            inputs[1].shape()
        );
        if shape.seq_len != 0 || shape.batch != 0 {
            ensure!(
                shape.seq_len == s && shape.batch == b,
                "job shape {shape:?} disagrees with tensor shape [{b}, {s}]"
            );
        }
        let vocab = self.cfg.vocab;
        let model = self.ensure_model()?;
        let logits = model.forward(tokens, Some(kv_valid), b, s)?;
        Ok(vec![HostTensor::F32 { shape: vec![b, s, vocab], data: logits }])
    }

    /// Warm a native bucket: build the model parameters and pre-compile
    /// the bucket's pattern layout and positional table.
    pub fn warm(&mut self, artifact: &str) -> Result<()> {
        let seq = parse_native_artifact(artifact).map(|(s, _)| s);
        let model = self.ensure_model()?;
        if let Some(s) = seq {
            model.prewarm(s)?;
        }
        Ok(())
    }

    /// Install trained parameters: a flat `[param_count]` f32 tensor in
    /// the canonical [`NativeModel::flatten_params`] order (the native
    /// checkpoint layout). A wrong dtype, wrong length, or non-finite
    /// payload returns a descriptive error and leaves the engine's
    /// current parameters untouched — a partial or mismatched checkpoint
    /// never serves stale weights silently.
    pub fn load_params(&mut self, artifact: &str, params: &HostTensor) -> Result<()> {
        let data = params
            .as_f32()
            .with_context(|| format!("native load_params for {artifact}: params tensor"))?;
        let model = self.ensure_model()?;
        let want = model.param_count();
        ensure!(
            data.len() == want,
            "native load_params for {artifact}: checkpoint carries {} parameters but this \
             engine's model expects {want} (model config mismatch?)",
            data.len()
        );
        model
            .load_flat_params(data)
            .with_context(|| format!("native load_params for {artifact}"))?;
        if !self.load_params_noted {
            self.load_params_noted = true;
            crate::log!(
                crate::obs::log::Level::Info,
                "kernel",
                "installed trained parameters ({want} values) for native serving"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn cfg() -> ModelConfig {
        ModelConfig::native_serving()
    }

    #[test]
    fn artifact_names_roundtrip() {
        for (s, b) in native_buckets() {
            let name = native_artifact_name(s, b);
            assert!(is_native_artifact(&name), "{name}");
            assert_eq!(parse_native_artifact(&name), Some((s, b)), "{name}");
        }
        assert!(!is_native_artifact("mlm_fwd_bigbird_itc_s512_b8"));
        assert!(parse_native_artifact("native_mlm_sx_b1").is_none());
    }

    #[test]
    fn forward_is_deterministic_and_shaped() {
        let (batch, seq) = (2usize, 128usize);
        let tokens: Vec<i32> = (0..batch * seq).map(|i| (i % 500) as i32).collect();
        let kv: Vec<f32> = vec![1.0; batch * seq];
        let mut m1 = NativeModel::new(cfg()).unwrap();
        let mut m2 = NativeModel::new(cfg()).unwrap();
        let l1 = m1.forward(&tokens, Some(&kv), batch, seq).unwrap();
        let l2 = m2.forward(&tokens, Some(&kv), batch, seq).unwrap();
        assert_eq!(l1.len(), batch * seq * cfg().vocab);
        assert_eq!(l1, l2, "identical configs must produce identical logits");
        assert!(l1.iter().all(|v| v.is_finite()), "logits must be finite");
        // logits must discriminate between tokens (not constant rows)
        let row = &l1[..cfg().vocab];
        let (lo, hi) = row
            .iter()
            .fold((f32::INFINITY, f32::NEG_INFINITY), |(lo, hi), &v| (lo.min(v), hi.max(v)));
        assert!(hi > lo, "first logits row is constant");
    }

    #[test]
    fn forward_rejects_bad_shapes() {
        let mut m = NativeModel::new(cfg()).unwrap();
        assert!(m.forward(&[1, 2, 3], None, 1, 128).is_err(), "token count mismatch");
        assert!(m.forward(&[1; 100], None, 1, 100).is_err(), "seq not multiple of block");
    }

    #[test]
    fn engine_executes_pool_job_tensors() {
        let mut eng = NativeEngine::new(cfg());
        let (b, s) = (1usize, 128usize);
        let tokens = HostTensor::i32(&[b, s], vec![7; b * s]).unwrap();
        let kv = HostTensor::f32(&[b, s], vec![1.0; b * s]).unwrap();
        let shape = JobShape { seq_len: s, batch: b };
        let out = eng.execute(shape, &[tokens.clone(), kv.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[b, s, cfg().vocab]);
        // wrong arity and disagreeing job shape both fail cleanly
        assert!(eng.execute(shape, &[tokens.clone()]).is_err());
        let bad = JobShape { seq_len: 64, batch: 2 };
        assert!(eng.execute(bad, &[tokens, kv]).is_err());
    }

    #[test]
    fn flat_params_roundtrip_and_rebuild_tied_head() {
        let mut m = NativeModel::new(cfg()).unwrap();
        let flat = m.flatten_params();
        assert_eq!(flat.len(), m.param_count());

        // perturb every parameter through the flat path and reload
        let shifted: Vec<f32> = flat.iter().map(|&v| v + 0.125).collect();
        m.load_flat_params(&shifted).unwrap();
        assert_eq!(m.flatten_params(), shifted, "flatten∘load must be identity");
        // the tied head must follow the new embedding
        let h = m.cfg.hidden;
        let vocab = m.cfg.vocab;
        for &(t, i) in &[(0usize, 0usize), (7, 3), (vocab - 1, h - 1)] {
            assert_eq!(m.embed_t[i * vocab + t], m.embed[t * h + i], "embed_t stale at ({t},{i})");
        }

        // wrong length and non-finite payloads are rejected without
        // touching the installed parameters
        let before = m.flatten_params();
        let err = m.load_flat_params(&shifted[..shifted.len() - 1]).unwrap_err();
        assert!(format!("{err:#}").contains("mismatch"), "{err:#}");
        let mut bad = before.clone();
        bad[42] = f32::NAN;
        assert!(m.load_flat_params(&bad).is_err());
        assert_eq!(m.flatten_params(), before, "failed loads must not corrupt params");
    }

    #[test]
    fn engine_load_params_imports_and_validates() {
        let mut eng = NativeEngine::new(cfg());
        let (b, s) = (1usize, 128usize);
        let tokens = HostTensor::i32(&[b, s], (0..(b * s) as i32).collect()).unwrap();
        let kv = HostTensor::f32(&[b, s], vec![1.0; b * s]).unwrap();
        let shape = JobShape { seq_len: s, batch: b };
        let seed_logits = eng.execute(shape, &[tokens.clone(), kv.clone()]).unwrap();

        // wrong-size params error; engine keeps serving the seed weights
        let bad = HostTensor::f32(&[3], vec![0.0; 3]).unwrap();
        assert!(eng.load_params(NATIVE_PARAMS_ARTIFACT, &bad).is_err());
        let still = eng.execute(shape, &[tokens.clone(), kv.clone()]).unwrap();
        assert_eq!(still[0].as_f32().unwrap(), seed_logits[0].as_f32().unwrap());

        // a genuine parameter install changes the served logits
        let n = eng.model.as_ref().unwrap().param_count();
        let mut flat = eng.model.as_ref().unwrap().flatten_params();
        for v in flat.iter_mut() {
            *v += 0.01;
        }
        let good = HostTensor::f32(&[n], flat).unwrap();
        eng.load_params(NATIVE_PARAMS_ARTIFACT, &good).unwrap();
        let trained = eng.execute(shape, &[tokens, kv]).unwrap();
        assert_ne!(
            trained[0].as_f32().unwrap(),
            seed_logits[0].as_f32().unwrap(),
            "loaded params must change the forward pass"
        );
    }

    #[test]
    fn config_fingerprint_tracks_architecture() {
        let a = config_fingerprint(&cfg());
        let b = config_fingerprint(&cfg());
        assert_eq!(a, b);
        let mut other = cfg();
        other.vocab += 1;
        assert_ne!(a, config_fingerprint(&other));
        let mut other = cfg();
        other.attn_seed = 0xDEAD_BEEF_0000_0001;
        assert_ne!(a, config_fingerprint(&other));
        assert_eq!(param_count_for(&cfg()), NativeModel::new(cfg()).unwrap().param_count());
    }

    #[test]
    fn warm_prebuilds_bucket_layout() {
        let mut eng = NativeEngine::new(cfg());
        eng.warm(&native_artifact_name(256, 4)).unwrap();
        let model = eng.model.as_mut().expect("warm builds the model");
        assert!(model.layouts.contains_key(&256));
        assert!(model.pos.contains_key(&256));
    }

    #[test]
    fn adaptive_forward_is_deterministic_and_content_dependent() {
        let mut c = cfg();
        c.pattern = PatternSelect::Adaptive { k: 0 };
        let (batch, seq) = (1usize, 128usize);
        let toks_a: Vec<i32> = (0..batch * seq).map(|i| (i % 97) as i32).collect();
        let kv = vec![1.0f32; batch * seq];
        let mut m1 = NativeModel::new(c.clone()).unwrap();
        let mut m2 = NativeModel::new(c.clone()).unwrap();
        let l1 = m1.forward(&toks_a, Some(&kv), batch, seq).unwrap();
        let l2 = m2.forward(&toks_a, Some(&kv), batch, seq).unwrap();
        assert_eq!(l1, l2, "adaptive forward must be deterministic per input");
        assert!(l1.iter().all(|v| v.is_finite()));
        // different content selects (in general) a different graph —
        // the pattern source fingerprints must differ for these inputs
        let toks_b: Vec<i32> = (0..batch * seq).map(|i| ((i * 31 + 5) % 409) as i32).collect();
        let fa = m1.pattern_source(Some((&toks_a, batch)), seq).fingerprint(c.block);
        let fb = m1.pattern_source(Some((&toks_b, batch)), seq).fingerprint(c.block);
        assert_ne!(fa, fb, "content must steer the adaptive selection");
        // equal budget: adaptive density stays at the static pattern's
        let pat = m1.select_pattern(Some((&toks_a, batch)), seq).unwrap();
        let stat = PatternSource::Static(m1.pattern_spec(seq)).compile(c.block);
        assert!((pat.density() - stat.density()).abs() < 0.02, "{} vs {}", pat.density(), stat.density());
    }

    #[test]
    fn learned_scores_live_in_flat_params() {
        let mut c = cfg();
        c.pattern = PatternSelect::Learned { k: 1 };
        let m = NativeModel::new(c.clone()).unwrap();
        let base = {
            let mut s = c.clone();
            s.pattern = PatternSelect::Static;
            param_count_for(&s)
        };
        assert_eq!(m.param_count(), base + c.heads * LEARNED_SPAN);
        let flat = m.flatten_params();
        assert_eq!(flat.len(), m.param_count());
        // the tail of the flat vector IS the selection scores
        assert_eq!(&flat[base..], &m.sel_scores[..]);
        // fingerprints separate pattern kinds (no silent cross-loads)
        let mut s = c.clone();
        s.pattern = PatternSelect::Static;
        assert_ne!(config_fingerprint(&c), config_fingerprint(&s));
        let mut a = c.clone();
        a.pattern = PatternSelect::Adaptive { k: 1 };
        assert_ne!(config_fingerprint(&c), config_fingerprint(&a));
    }

    #[test]
    fn learned_forward_depends_on_selection_scores() {
        let mut c = cfg();
        c.pattern = PatternSelect::Learned { k: 1 };
        let (batch, seq) = (1usize, 128usize);
        let tokens: Vec<i32> = (0..batch * seq).map(|i| (i % 211) as i32).collect();
        let mut m = NativeModel::new(c).unwrap();
        let before = m.forward(&tokens, None, batch, seq).unwrap();
        // flip the learned scores through the flat-params path: the
        // selected blocks change, so the logits must change too
        let mut flat = m.flatten_params();
        let tail = flat.len() - m.sel_scores.len();
        for v in flat[tail..].iter_mut() {
            *v = -*v;
        }
        m.load_flat_params(&flat).unwrap();
        let after = m.forward(&tokens, None, batch, seq).unwrap();
        assert_ne!(before, after, "selection scores must steer the forward pass");
    }
}
