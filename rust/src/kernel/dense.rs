//! Blocked dense reference kernel: the correctness oracle.
//!
//! Materialises each query row's full score vector (masked to the
//! pattern's attended blocks and the key-validity mask), applies a
//! classic two-pass softmax, and accumulates the value sum — the
//! textbook O(n²)-shaped computation the sparse kernel must agree with
//! to ≤ 1e-5 (see `tests/kernel_parity.rs`). Deliberately written with
//! a *different* algorithm than [`super::sparse`] (full-row two-pass
//! softmax vs per-block streaming softmax) so shared bugs can't cancel.

use super::layout::BlockCsr;
use super::{dot, HeadViews};

/// Masked dense attention forward for one `[n, head_dim]` head:
/// `out[i] = softmax(mask(Q Kᵀ / √d))[i] · V`, where the mask admits
/// key `j` iff its block is attended by `i`'s block in `layout` and
/// `key_valid[j] > 0` (when a mask is given). Rows with no admissible
/// key produce zeros.
pub fn dense_reference(x: &HeadViews<'_>, head_dim: usize, layout: &BlockCsr, out: &mut [f32]) {
    let n = layout.seq_len();
    let b = layout.block;
    x.check(n, head_dim);
    assert_eq!(out.len(), n * head_dim, "output must be [n, head_dim]");
    let scale = 1.0 / (head_dim as f32).sqrt();
    let mut scores = vec![f32::NEG_INFINITY; n];
    for qi in 0..n {
        let qb = qi / b;
        let q_row = &x.q[qi * head_dim..(qi + 1) * head_dim];
        scores.fill(f32::NEG_INFINITY);
        for &kb in layout.row(qb) {
            for kj in kb * b..(kb + 1) * b {
                let valid = match x.key_valid {
                    Some(mask) => mask[kj] > 0.0,
                    None => true,
                };
                if valid {
                    let k_row = &x.k[kj * head_dim..(kj + 1) * head_dim];
                    scores[kj] = dot(q_row, k_row) * scale;
                }
            }
        }
        let m = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let o_row = &mut out[qi * head_dim..(qi + 1) * head_dim];
        o_row.fill(0.0);
        if m == f32::NEG_INFINITY {
            continue; // no admissible key
        }
        let mut denom = 0.0f32;
        for (kj, &s) in scores.iter().enumerate() {
            if s == f32::NEG_INFINITY {
                continue;
            }
            let w = (s - m).exp();
            denom += w;
            let v_row = &x.v[kj * head_dim..(kj + 1) * head_dim];
            for (o, &vv) in o_row.iter_mut().zip(v_row) {
                *o += w * vv;
            }
        }
        if denom > 0.0 {
            o_row.iter_mut().for_each(|o| *o /= denom);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::PatternSpec;
    use crate::config::AttnVariant;
    use crate::util::Rng;

    fn data(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn dense_variant_rows_sum_softmax_weights_to_one() {
        // with the Dense variant and no validity mask, every key is
        // admissible: output rows are convex combinations of V rows
        let spec = PatternSpec {
            variant: AttnVariant::Dense,
            nb: 4,
            global_blocks: 0,
            window_blocks: 1,
            random_blocks: 0,
            seed: 0,
        };
        let layout = BlockCsr::compile(&spec, 4);
        let (n, d) = (layout.seq_len(), 8);
        let mut rng = Rng::new(1);
        let q = data(&mut rng, n * d);
        let k = data(&mut rng, n * d);
        let v = vec![1.0f32; n * d]; // constant V ⇒ output must be exactly 1
        let mut out = vec![0.0f32; n * d];
        dense_reference(&HeadViews { q: &q, k: &k, v: &v, key_valid: None }, d, &layout, &mut out);
        for (i, &o) in out.iter().enumerate() {
            assert!((o - 1.0).abs() < 1e-5, "out[{i}] = {o}");
        }
    }

    #[test]
    fn fully_masked_rows_produce_zeros() {
        let spec = PatternSpec {
            variant: AttnVariant::Window,
            nb: 4,
            global_blocks: 0,
            window_blocks: 1,
            random_blocks: 0,
            seed: 0,
        };
        let layout = BlockCsr::compile(&spec, 2);
        let (n, d) = (layout.seq_len(), 4);
        let mut rng = Rng::new(2);
        let q = data(&mut rng, n * d);
        let k = data(&mut rng, n * d);
        let v = data(&mut rng, n * d);
        let key_valid = vec![0.0f32; n]; // nothing admissible
        let mut out = vec![7.0f32; n * d];
        dense_reference(
            &HeadViews { q: &q, k: &k, v: &v, key_valid: Some(&key_valid) },
            d,
            &layout,
            &mut out,
        );
        assert!(out.iter().all(|&o| o == 0.0));
    }
}
