//! Blocked dense reference kernel: the correctness oracle.
//!
//! Materialises each query block's full score rows (masked to the
//! pattern's attended blocks and the key-validity mask), applies a
//! classic two-pass softmax per row, and accumulates the value sum —
//! the textbook O(n²)-shaped computation the sparse kernel must agree
//! with to ≤ 1e-5 (see `tests/kernel_parity.rs`). The block-level math
//! routes through the shared [`super::microkernel`] tiles, but the
//! *algorithm* stays different from [`super::sparse`] (full-row
//! two-pass softmax vs per-block streaming softmax), and the
//! microkernels themselves are pinned against plain scalar references
//! in `tests/microkernel_parity.rs` — so a shared-tile bug still can't
//! cancel silently.

use super::layout::BlockCsr;
use super::microkernel::{av_tile, pack_transposed, qk_tile};
use super::HeadViews;

/// Masked dense attention forward for one `[n, head_dim]` head:
/// `out[i] = softmax(mask(Q Kᵀ / √d))[i] · V`, where the mask admits
/// key `j` iff its block is attended by `i`'s block in `layout` and
/// `key_valid[j] > 0` (when a mask is given). Rows with no admissible
/// key produce zeros.
pub fn dense_reference(x: &HeadViews<'_>, head_dim: usize, layout: &BlockCsr, out: &mut [f32]) {
    let n = layout.seq_len();
    let b = layout.block;
    x.check(n, head_dim);
    assert_eq!(out.len(), n * head_dim, "output must be [n, head_dim]");
    let scale = 1.0 / (head_dim as f32).sqrt();
    // the oracle allocates per call (it is not on the serving path):
    // one full [block, n] score panel plus the per-tile pack buffers
    let mut scores = vec![f32::NEG_INFINITY; b * n];
    let mut tile = vec![0.0f32; b * b];
    let mut kt = vec![0.0f32; head_dim * b];
    let mut denoms = vec![0.0f32; b];
    for qb in 0..layout.nb {
        let qs = layout.token_span(qb);
        let q_block = &x.q[qs.start * head_dim..qs.end * head_dim];
        scores.fill(f32::NEG_INFINITY);
        for &kb in layout.row(qb) {
            let ks = layout.token_span(kb);
            let k_block = &x.k[ks.start * head_dim..ks.end * head_dim];
            let valid = x.key_valid.map(|mask| &mask[ks.clone()]);
            pack_transposed(k_block, b, head_dim, &mut kt);
            qk_tile(q_block, &kt, b, b, head_dim, scale, valid, &mut tile);
            for i in 0..b {
                scores[i * n + ks.start..i * n + ks.end]
                    .copy_from_slice(&tile[i * b..(i + 1) * b]);
            }
        }
        // two-pass softmax per row over the full score panel: max, then
        // exp-weights in place (non-attended / masked stay exactly zero)
        for i in 0..b {
            let row = &mut scores[i * n..(i + 1) * n];
            let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let o_row = &mut out[(qs.start + i) * head_dim..(qs.start + i + 1) * head_dim];
            o_row.fill(0.0);
            denoms[i] = 0.0;
            if m == f32::NEG_INFINITY {
                row.fill(0.0);
                continue; // no admissible key
            }
            let mut denom = 0.0f32;
            for s in row.iter_mut() {
                if *s == f32::NEG_INFINITY {
                    *s = 0.0;
                } else {
                    let w = (*s - m).exp();
                    denom += w;
                    *s = w;
                }
            }
            denoms[i] = denom;
        }
        // one tiled AV accumulate of the whole block over all n keys
        av_tile(&scores, x.v, b, n, head_dim, &mut out[qs.start * head_dim..qs.end * head_dim]);
        for (i, &denom) in denoms.iter().enumerate() {
            if denom > 0.0 {
                let o_row = &mut out[(qs.start + i) * head_dim..(qs.start + i + 1) * head_dim];
                o_row.iter_mut().for_each(|o| *o /= denom);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::PatternSpec;
    use crate::config::AttnVariant;
    use crate::util::Rng;

    fn data(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn dense_variant_rows_sum_softmax_weights_to_one() {
        // with the Dense variant and no validity mask, every key is
        // admissible: output rows are convex combinations of V rows
        let spec = PatternSpec {
            variant: AttnVariant::Dense,
            nb: 4,
            global_blocks: 0,
            window_blocks: 1,
            random_blocks: 0,
            seed: 0,
        };
        let layout = BlockCsr::compile(&spec, 4);
        let (n, d) = (layout.seq_len(), 8);
        let mut rng = Rng::new(1);
        let q = data(&mut rng, n * d);
        let k = data(&mut rng, n * d);
        let v = vec![1.0f32; n * d]; // constant V ⇒ output must be exactly 1
        let mut out = vec![0.0f32; n * d];
        dense_reference(&HeadViews { q: &q, k: &k, v: &v, key_valid: None }, d, &layout, &mut out);
        for (i, &o) in out.iter().enumerate() {
            assert!((o - 1.0).abs() < 1e-5, "out[{i}] = {o}");
        }
    }

    #[test]
    fn fully_masked_rows_produce_zeros() {
        let spec = PatternSpec {
            variant: AttnVariant::Window,
            nb: 4,
            global_blocks: 0,
            window_blocks: 1,
            random_blocks: 0,
            seed: 0,
        };
        let layout = BlockCsr::compile(&spec, 2);
        let (n, d) = (layout.seq_len(), 4);
        let mut rng = Rng::new(2);
        let q = data(&mut rng, n * d);
        let k = data(&mut rng, n * d);
        let v = data(&mut rng, n * d);
        let key_valid = vec![0.0f32; n]; // nothing admissible
        let mut out = vec![7.0f32; n * d];
        dense_reference(
            &HeadViews { q: &q, k: &k, v: &v, key_valid: Some(&key_valid) },
            d,
            &layout,
            &mut out,
        );
        assert!(out.iter().all(|&o| o == 0.0));
    }
}
