//! Self-calibration micro-probes for the native backend.
//!
//! Three probes, all measured **on the machine they run on** and cached
//! per process in `OnceLock`s:
//!
//! 1. **Roofline** ([`native_roofline`]) — a tiny GEMM probes sustained
//!    compute (GFLOP/s), a buffer copy probes memory bandwidth (GB/s),
//!    and a minimal sparse-kernel call probes fixed per-dispatch
//!    overhead, so `coordinator::dispatch` starts from real numbers
//!    instead of guesses. The compute leg takes the **max** of the
//!    attention-tile probe and the tuned model-GEMM probe — both run
//!    through this process's kernels, so the roofline reflects the best
//!    math the backend can actually route to.
//! 2. **Tile-shape auto-tuner** ([`tuned_tile`]) — probes each
//!    [`TileShape`] candidate per [`Precision`] through the packed GEMM
//!    entry points and records the GFLOP/s winner; `gemm_packed` then
//!    uses it for every model matmul. Wide lanes win on AVX-512-class
//!    machines, the narrow default elsewhere. The tuner never changes
//!    *results* (the f32 kernels are bit-identical across shapes — see
//!    `microkernel`), only speed, so a baseline refresh after a
//!    toolchain change captures tuner effects automatically.
//! 3. **SIMD floor** ([`simd_probe`] / [`assert_simd_floor`]) — the CI
//!    vectorization check: tiled-GEMM GFLOP/s vs a deliberately
//!    serial-dependency scalar baseline the autovectorizer cannot
//!    reorder. A healthy toolchain vectorizes the tiles several-fold
//!    past the scalar chain; falling under [`MIN_SIMD_RATIO`] fails
//!    `kernel-probe --assert-simd` loudly with remediation text.

use std::hint::black_box;
use std::sync::OnceLock;
use std::time::Instant;

use crate::attention::PatternSpec;
use crate::config::{AttnVariant, Precision};
use crate::runtime::Roofline;

use super::layout::BlockCsr;
use super::microkernel::{
    gemm_packed_with, pack_transposed, qk_tile, GemmScratch, PackedMat, TileShape,
};
use super::sparse::{sparse_forward, SparseScratch};
use super::HeadViews;

/// The calibrated roofline of the in-process native backend. Measured
/// on first call and cached for the process lifetime.
pub fn native_roofline() -> Roofline {
    static CACHE: OnceLock<Roofline> = OnceLock::new();
    *CACHE.get_or_init(probe)
}

fn probe() -> Roofline {
    Roofline {
        gflops: probe_gflops().max(0.05),
        gbps: probe_gbps().max(0.05),
        overhead_ms: probe_overhead_ms().max(1e-4),
    }
}

/// Sustained compute: a 96³ f32 GEMM **through the tiled microkernel
/// the kernels actually run** (transpose pack + register-blocked
/// [`qk_tile`]), measured on one thread and scaled by the core count —
/// the batch driver fans `batch × heads` head problems across all
/// cores, so single-thread numbers would overestimate native cost by a
/// core-count factor against the static PJRT seeds. Probing the
/// microkernel (not a hand-rolled loop) keeps roofline routing honest:
/// the measured GFLOP/s is what the sparse/dense/backward tiles see.
fn probe_gflops() -> f64 {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let attn = probe_single_thread_gflops();
    let gemm = tuned_tiles().f32.gflops;
    attn.max(gemm) * cores as f64
}

fn probe_single_thread_gflops() -> f64 {
    const M: usize = 96;
    const REPS: usize = 6;
    let a: Vec<f32> = (0..M * M).map(|i| ((i % 83) as f32) * 0.01).collect();
    let b: Vec<f32> = (0..M * M).map(|i| ((i % 89) as f32) * 0.01).collect();
    let mut bt = vec![0.0f32; M * M];
    let mut c = vec![0.0f32; M * M];
    let t0 = Instant::now();
    for _ in 0..REPS {
        // the pack is part of the measured path: every (qb, kb) tile
        // the real kernels execute pays it too
        pack_transposed(&b, M, M, &mut bt);
        qk_tile(&a, &bt, M, M, M, 1.0, None, &mut c);
        black_box(&c);
    }
    let secs = t0.elapsed().as_secs_f64();
    let flops = (2 * M * M * M * REPS) as f64;
    flops / secs / 1e9
}

/// Effective host memory bandwidth: a 4 MiB f32 buffer copy.
fn probe_gbps() -> f64 {
    const LEN: usize = 1 << 20; // 1M f32 = 4 MiB
    const REPS: usize = 6;
    let src: Vec<f32> = (0..LEN).map(|i| i as f32).collect();
    let mut dst = vec![0.0f32; LEN];
    let t0 = Instant::now();
    for _ in 0..REPS {
        dst.copy_from_slice(black_box(&src));
        black_box(&dst);
    }
    let secs = t0.elapsed().as_secs_f64();
    // read + write per element per rep
    let bytes = (2 * 4 * LEN * REPS) as f64;
    bytes / secs / 1e9
}

/// Fixed per-dispatch overhead: the wall time of a minimal sparse
/// kernel call (one tiny head problem), which bounds the constant cost
/// every native batch pays regardless of size.
fn probe_overhead_ms() -> f64 {
    const REPS: usize = 32;
    let spec = PatternSpec {
        variant: AttnVariant::Window,
        nb: 4,
        global_blocks: 0,
        window_blocks: 1,
        random_blocks: 0,
        seed: 0,
    };
    let layout = BlockCsr::compile(&spec, 8);
    let (n, d) = (layout.seq_len(), 16);
    let q: Vec<f32> = (0..n * d).map(|i| ((i % 31) as f32) * 0.1).collect();
    let x = HeadViews { q: &q, k: &q, v: &q, key_valid: None };
    let mut out = vec![0.0f32; n * d];
    let mut scratch = SparseScratch::new();
    let t0 = Instant::now();
    for _ in 0..REPS {
        sparse_forward(&x, d, &layout, &mut scratch, &mut out);
        black_box(&out);
    }
    t0.elapsed().as_secs_f64() * 1e3 / REPS as f64
}

// ---------------------------------------------------------------------
// tile-shape auto-tuner
// ---------------------------------------------------------------------

/// One tuner verdict: the winning register-block shape for a precision
/// and the GFLOP/s it sustained in the probe.
#[derive(Clone, Copy, Debug)]
pub struct TileChoice {
    /// The fastest probed shape.
    pub shape: TileShape,
    /// Single-thread GFLOP/s the winner sustained.
    pub gflops: f64,
}

/// The per-precision tuner verdicts.
#[derive(Clone, Copy, Debug)]
pub struct TileTable {
    /// Winner for [`Precision::F32`].
    pub f32: TileChoice,
    /// Winner for [`Precision::F16`].
    pub f16: TileChoice,
    /// Winner for [`Precision::Int8`] (int ops counted as FLOPs for
    /// comparability).
    pub int8: TileChoice,
}

impl TileTable {
    /// The verdict for `p`.
    pub fn choice(&self, p: Precision) -> TileChoice {
        match p {
            Precision::F32 => self.f32,
            Precision::F16 => self.f16,
            Precision::Int8 => self.int8,
        }
    }
}

/// The auto-tuned tile table: probed once per process, cached.
pub fn tuned_tiles() -> &'static TileTable {
    static CACHE: OnceLock<TileTable> = OnceLock::new();
    CACHE.get_or_init(|| TileTable {
        f32: tune_precision(Precision::F32),
        f16: tune_precision(Precision::F16),
        int8: tune_precision(Precision::Int8),
    })
}

/// The auto-tuned register-block shape for `p` — what `gemm_packed`
/// routes through. `gemm_packed_with` exists so the tuner (and the
/// shape-sweeping parity tests) can bypass this.
pub fn tuned_tile(p: Precision) -> TileShape {
    tuned_tiles().choice(p).shape
}

/// Probe every candidate shape at `p` on a model-sized GEMM and keep
/// the fastest. Results are identical across shapes by construction, so
/// this is purely a speed decision.
fn tune_precision(p: Precision) -> TileChoice {
    const M: usize = 96;
    const REPS: usize = 4;
    let a: Vec<f32> = (0..M * M).map(|i| ((i % 83) as f32) * 0.01 - 0.4).collect();
    let b: Vec<f32> = (0..M * M).map(|i| ((i % 89) as f32) * 0.01 - 0.45).collect();
    let packed = PackedMat::pack(&b, M, M, p);
    let mut scratch = GemmScratch::default();
    let mut out = vec![0.0f32; M * M];
    let mut best: Option<TileChoice> = None;
    for shape in TileShape::all() {
        // one warm-up pays the lazy page faults / branch training
        gemm_packed_with(shape, &a, &packed, M, false, &mut scratch, &mut out);
        let t0 = Instant::now();
        for _ in 0..REPS {
            gemm_packed_with(shape, &a, &packed, M, false, &mut scratch, &mut out);
            black_box(&out);
        }
        let secs = t0.elapsed().as_secs_f64();
        let gflops = (2 * M * M * M * REPS) as f64 / secs / 1e9;
        if best.map(|c| gflops > c.gflops).unwrap_or(true) {
            best = Some(TileChoice { shape, gflops });
        }
    }
    best.expect("TileShape::all() is non-empty")
}

// ---------------------------------------------------------------------
// SIMD vectorization floor
// ---------------------------------------------------------------------

/// Minimum tiled-vs-scalar speed ratio a healthy vectorizing toolchain
/// must clear. The scalar baseline is a serial dependency chain the
/// autovectorizer cannot reorder (f32 addition is not associative), so
/// a vectorized tile beats it several-fold; a build that lost
/// vectorization (wrong opt-level, codegen regression) lands near 1×.
pub const MIN_SIMD_RATIO: f64 = 2.0;

/// Measured SIMD health: tuned tiled GEMM GFLOP/s vs the serial scalar
/// chain, per precision.
#[derive(Clone, Copy, Debug)]
pub struct SimdProbe {
    /// Serial-dependency scalar-chain GFLOP/s (the "no SIMD" floor).
    pub scalar_gflops: f64,
    /// Tuned f32 tiled-GEMM GFLOP/s.
    pub f32_gflops: f64,
    /// Tuned f16-storage tiled-GEMM GFLOP/s.
    pub f16_gflops: f64,
    /// Tuned int8 tiled-GEMM GFLOP/s (int ops counted as FLOPs).
    pub int8_gflops: f64,
}

impl SimdProbe {
    /// Tiled-vs-scalar ratio of the f32 path — the gated number.
    pub fn ratio(&self) -> f64 {
        self.f32_gflops / self.scalar_gflops.max(1e-9)
    }
}

/// Run the SIMD health probe (uses the cached tuner verdicts for the
/// tiled legs, measures the scalar chain fresh).
pub fn simd_probe() -> SimdProbe {
    let tiles = tuned_tiles();
    SimdProbe {
        scalar_gflops: probe_scalar_chain_gflops(),
        f32_gflops: tiles.f32.gflops,
        f16_gflops: tiles.f16.gflops,
        int8_gflops: tiles.int8.gflops,
    }
}

/// Assert the vectorization floor, returning the probe on success and a
/// loud remediation message on failure — the backend of `kernel-probe
/// --assert-simd` in CI.
pub fn assert_simd_floor() -> Result<SimdProbe, String> {
    let p = simd_probe();
    if p.ratio() >= MIN_SIMD_RATIO {
        Ok(p)
    } else {
        Err(format!(
            "microkernel lanes did NOT vectorize: tiled f32 GEMM sustained {:.2} GFLOP/s vs \
             {:.2} GFLOP/s for the serial scalar chain (ratio {:.2}x < required {MIN_SIMD_RATIO}x).\n\
             Remediation: build with `--release` (opt-level 3); do not override RUSTFLAGS with \
             `-C opt-level=0/1` or `-C no-vectorize-loops`; if cross-compiling, set `-C \
             target-cpu` to a SIMD-capable target; re-run `cargo run --release -- kernel-probe \
             --assert-simd` to confirm.",
            p.f32_gflops, p.scalar_gflops
        ))
    }
}

/// The scalar floor: one long dot product accumulated into a single
/// f32 — every add depends on the previous one, so the autovectorizer
/// cannot widen it without changing results. This is what "no SIMD"
/// throughput looks like on this machine.
fn probe_scalar_chain_gflops() -> f64 {
    const K: usize = 96 * 96;
    const REPS: usize = 64;
    let a: Vec<f32> = (0..K).map(|i| ((i % 83) as f32) * 0.001 - 0.04).collect();
    let b: Vec<f32> = (0..K).map(|i| ((i % 89) as f32) * 0.001 - 0.045).collect();
    let mut sink = 0.0f32;
    let t0 = Instant::now();
    for _ in 0..REPS {
        let mut s = 0.0f32;
        for (&x, &y) in a.iter().zip(black_box(&b)) {
            s += x * y;
        }
        sink += s;
    }
    black_box(sink);
    let secs = t0.elapsed().as_secs_f64();
    (2 * K * REPS) as f64 / secs / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_yields_finite_positive_roofline() {
        let r = native_roofline();
        assert!(r.gflops.is_finite() && r.gflops > 0.0, "{r:?}");
        assert!(r.gbps.is_finite() && r.gbps > 0.0, "{r:?}");
        assert!(r.overhead_ms.is_finite() && r.overhead_ms > 0.0, "{r:?}");
    }

    #[test]
    fn probe_is_cached_per_process() {
        let a = native_roofline();
        let b = native_roofline();
        assert_eq!(a, b, "second call must return the cached measurement");
    }

    #[test]
    fn tuner_yields_finite_positive_winners_for_every_precision() {
        let t = tuned_tiles();
        for p in Precision::all() {
            let c = t.choice(p);
            assert!(c.gflops.is_finite() && c.gflops > 0.0, "{p:?}: {c:?}");
            assert!(
                TileShape::all().contains(&c.shape),
                "{p:?}: winner {:?} must be a candidate",
                c.shape
            );
            assert_eq!(tuned_tile(p), c.shape, "tuned_tile must mirror the table");
        }
    }

    #[test]
    fn simd_probe_reports_finite_throughputs() {
        let p = simd_probe();
        assert!(p.scalar_gflops.is_finite() && p.scalar_gflops > 0.0, "{p:?}");
        assert!(p.f32_gflops.is_finite() && p.f32_gflops > 0.0, "{p:?}");
        assert!(p.f16_gflops.is_finite() && p.f16_gflops > 0.0, "{p:?}");
        assert!(p.int8_gflops.is_finite() && p.int8_gflops > 0.0, "{p:?}");
        assert!(p.ratio().is_finite() && p.ratio() > 0.0, "{p:?}");
        // NOTE: no ratio assertion here — debug-profile test builds do
        // not vectorize. The floor is enforced by `kernel-probe
        // --assert-simd` on the release binary in CI.
    }
}
