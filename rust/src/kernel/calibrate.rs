//! Self-calibration micro-probe for the native backend's roofline.
//!
//! The PJRT backends ship hand-seeded roofline constants; the native
//! backend's cost model is instead **measured on the machine it runs
//! on**: a tiny matmul probes sustained compute (GFLOP/s), a buffer
//! copy probes memory bandwidth (GB/s), and a minimal sparse-kernel
//! call probes fixed per-dispatch overhead. The probe runs once per
//! process (~10–20 ms, cached in a `OnceLock`) the first time a native
//! worker spawns, so dispatch starts from real numbers instead of
//! guesses — and the exec-time EWMAs refine from there as usual.

use std::hint::black_box;
use std::sync::OnceLock;
use std::time::Instant;

use crate::attention::PatternSpec;
use crate::config::AttnVariant;
use crate::runtime::Roofline;

use super::layout::BlockCsr;
use super::microkernel::{pack_transposed, qk_tile};
use super::sparse::{sparse_forward, SparseScratch};
use super::HeadViews;

/// The calibrated roofline of the in-process native backend. Measured
/// on first call and cached for the process lifetime.
pub fn native_roofline() -> Roofline {
    static CACHE: OnceLock<Roofline> = OnceLock::new();
    *CACHE.get_or_init(probe)
}

fn probe() -> Roofline {
    Roofline {
        gflops: probe_gflops().max(0.05),
        gbps: probe_gbps().max(0.05),
        overhead_ms: probe_overhead_ms().max(1e-4),
    }
}

/// Sustained compute: a 96³ f32 GEMM **through the tiled microkernel
/// the kernels actually run** (transpose pack + register-blocked
/// [`qk_tile`]), measured on one thread and scaled by the core count —
/// the batch driver fans `batch × heads` head problems across all
/// cores, so single-thread numbers would overestimate native cost by a
/// core-count factor against the static PJRT seeds. Probing the
/// microkernel (not a hand-rolled loop) keeps roofline routing honest:
/// the measured GFLOP/s is what the sparse/dense/backward tiles see.
fn probe_gflops() -> f64 {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    probe_single_thread_gflops() * cores as f64
}

fn probe_single_thread_gflops() -> f64 {
    const M: usize = 96;
    const REPS: usize = 6;
    let a: Vec<f32> = (0..M * M).map(|i| ((i % 83) as f32) * 0.01).collect();
    let b: Vec<f32> = (0..M * M).map(|i| ((i % 89) as f32) * 0.01).collect();
    let mut bt = vec![0.0f32; M * M];
    let mut c = vec![0.0f32; M * M];
    let t0 = Instant::now();
    for _ in 0..REPS {
        // the pack is part of the measured path: every (qb, kb) tile
        // the real kernels execute pays it too
        pack_transposed(&b, M, M, &mut bt);
        qk_tile(&a, &bt, M, M, M, 1.0, None, &mut c);
        black_box(&c);
    }
    let secs = t0.elapsed().as_secs_f64();
    let flops = (2 * M * M * M * REPS) as f64;
    flops / secs / 1e9
}

/// Effective host memory bandwidth: a 4 MiB f32 buffer copy.
fn probe_gbps() -> f64 {
    const LEN: usize = 1 << 20; // 1M f32 = 4 MiB
    const REPS: usize = 6;
    let src: Vec<f32> = (0..LEN).map(|i| i as f32).collect();
    let mut dst = vec![0.0f32; LEN];
    let t0 = Instant::now();
    for _ in 0..REPS {
        dst.copy_from_slice(black_box(&src));
        black_box(&dst);
    }
    let secs = t0.elapsed().as_secs_f64();
    // read + write per element per rep
    let bytes = (2 * 4 * LEN * REPS) as f64;
    bytes / secs / 1e9
}

/// Fixed per-dispatch overhead: the wall time of a minimal sparse
/// kernel call (one tiny head problem), which bounds the constant cost
/// every native batch pays regardless of size.
fn probe_overhead_ms() -> f64 {
    const REPS: usize = 32;
    let spec = PatternSpec {
        variant: AttnVariant::Window,
        nb: 4,
        global_blocks: 0,
        window_blocks: 1,
        random_blocks: 0,
        seed: 0,
    };
    let layout = BlockCsr::compile(&spec, 8);
    let (n, d) = (layout.seq_len(), 16);
    let q: Vec<f32> = (0..n * d).map(|i| ((i % 31) as f32) * 0.1).collect();
    let x = HeadViews { q: &q, k: &q, v: &q, key_valid: None };
    let mut out = vec![0.0f32; n * d];
    let mut scratch = SparseScratch::new();
    let t0 = Instant::now();
    for _ in 0..REPS {
        sparse_forward(&x, d, &layout, &mut scratch, &mut out);
        black_box(&out);
    }
    t0.elapsed().as_secs_f64() * 1e3 / REPS as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_yields_finite_positive_roofline() {
        let r = native_roofline();
        assert!(r.gflops.is_finite() && r.gflops > 0.0, "{r:?}");
        assert!(r.gbps.is_finite() && r.gbps > 0.0, "{r:?}");
        assert!(r.overhead_ms.is_finite() && r.overhead_ms > 0.0, "{r:?}");
    }

    #[test]
    fn probe_is_cached_per_process() {
        let a = native_roofline();
        let b = native_roofline();
        assert_eq!(a, b, "second call must return the cached measurement");
    }
}
