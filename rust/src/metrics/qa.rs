//! Span-QA metrics: token-level F1 and exact match (Tab. 2/3 style).

/// Exact match: predicted span equals the gold span.
pub fn exact_match(pred: (usize, usize), gold: (usize, usize)) -> bool {
    pred == gold
}

/// Token-overlap F1 between two half-open spans `[start, end)`.
pub fn span_f1(pred: (usize, usize), gold: (usize, usize)) -> f64 {
    let (ps, pe) = pred;
    let (gs, ge) = gold;
    if ps >= pe || gs >= ge {
        return 0.0;
    }
    let inter = pe.min(ge).saturating_sub(ps.max(gs));
    if inter == 0 {
        return 0.0;
    }
    let p = inter as f64 / (pe - ps) as f64;
    let r = inter as f64 / (ge - gs) as f64;
    2.0 * p * r / (p + r)
}

/// Greedy span decode from start/end logits: best (s, e) with s ≤ e and
/// e − s < max_len (the paper bounds span length per dataset, App. E.2).
pub fn decode_span(start_logits: &[f32], end_logits: &[f32], max_len: usize) -> (usize, usize) {
    let n = start_logits.len();
    assert_eq!(n, end_logits.len());
    let mut best = (0usize, 1usize);
    let mut best_score = f32::NEG_INFINITY;
    for s in 0..n {
        let e_hi = (s + max_len).min(n);
        for e in s..e_hi {
            let score = start_logits[s] + end_logits[e];
            if score > best_score {
                best_score = score;
                best = (s, e + 1);
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_match_works() {
        assert!(exact_match((3, 7), (3, 7)));
        assert!(!exact_match((3, 7), (3, 8)));
    }

    #[test]
    fn f1_full_partial_none() {
        assert!((span_f1((2, 6), (2, 6)) - 1.0).abs() < 1e-12);
        assert_eq!(span_f1((0, 2), (5, 8)), 0.0);
        // pred [0,4), gold [2,6): inter 2, p=.5, r=.5 → f1=.5
        assert!((span_f1((0, 4), (2, 6)) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn degenerate_spans_zero() {
        assert_eq!(span_f1((3, 3), (0, 5)), 0.0);
        assert_eq!(span_f1((0, 5), (4, 4)), 0.0);
    }

    #[test]
    fn decode_span_picks_peak() {
        let mut s = vec![0.0f32; 10];
        let mut e = vec![0.0f32; 10];
        s[4] = 5.0;
        e[6] = 5.0;
        assert_eq!(decode_span(&s, &e, 16), (4, 7));
    }

    #[test]
    fn decode_span_respects_max_len() {
        let mut s = vec![0.0f32; 10];
        let mut e = vec![0.0f32; 10];
        s[0] = 5.0;
        e[9] = 5.0;
        e[2] = 1.0;
        assert_eq!(decode_span(&s, &e, 4), (0, 3));
    }
}
