//! ROC-AUC for the chromatin-profile experiment (Tab. 7).

/// Area under the ROC curve by the rank-sum (Mann–Whitney U) method,
/// with tie handling via midranks.
pub fn roc_auc(scores: &[f32], labels: &[bool]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let pos = labels.iter().filter(|&&l| l).count();
    let neg = labels.len() - pos;
    if pos == 0 || neg == 0 {
        return 0.5; // undefined; convention
    }
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    // midranks
    let mut ranks = vec![0.0f64; scores.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let mid = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[idx[k]] = mid;
        }
        i = j + 1;
    }
    let rank_sum_pos: f64 = ranks
        .iter()
        .zip(labels)
        .filter(|(_, &l)| l)
        .map(|(&r, _)| r)
        .sum();
    let u = rank_sum_pos - (pos as f64) * (pos as f64 + 1.0) / 2.0;
    u / (pos as f64 * neg as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_separation_is_one() {
        let scores = [0.1, 0.2, 0.8, 0.9];
        let labels = [false, false, true, true];
        assert!((roc_auc(&scores, &labels) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn inverted_is_zero() {
        let scores = [0.9, 0.8, 0.1, 0.2];
        let labels = [false, false, true, true];
        assert!(roc_auc(&scores, &labels) < 1e-12);
    }

    #[test]
    fn random_is_half() {
        // all scores tied → AUC 0.5 by midranks
        let scores = [0.5f32; 10];
        let labels = [true, false, true, false, true, false, true, false, true, false];
        assert!((roc_auc(&scores, &labels) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn degenerate_labels_half() {
        assert_eq!(roc_auc(&[0.1, 0.2], &[true, true]), 0.5);
    }
}
