//! Evaluation metrics used by the experiment harnesses: ROUGE (Tab. 4/20),
//! span F1/EM (Tab. 2/3), ROC-AUC (Tab. 7), bits-per-token (Tab. 5/10),
//! classification accuracy/F1 (Tab. 15/16, Tab. 6).

mod auc;
mod qa;
mod rouge;

pub use auc::roc_auc;
pub use qa::{decode_span, exact_match, span_f1};
pub use rouge::{rouge_l, rouge_n, RougeScore};

/// Bits-per-token from a mean negative log-likelihood in nats.
///
/// The paper reports bits per character; with a tokenizer averaging
/// `chars_per_token` characters per token, `bpc = bits_per_token /
/// chars_per_token` — the harnesses do that division where relevant.
pub fn bits_per_token(mean_nll_nats: f64) -> f64 {
    mean_nll_nats / std::f64::consts::LN_2
}

/// Token-level MLM accuracy: argmax(logits) == label over weighted
/// positions. `logits` laid out (B, S, V) row-major.
pub fn mlm_accuracy(logits: &[f32], labels: &[i32], weights: &[f32], vocab: usize) -> f64 {
    assert_eq!(labels.len(), weights.len());
    assert_eq!(logits.len(), labels.len() * vocab);
    let mut hit = 0.0;
    let mut total = 0.0;
    for (i, (&lab, &w)) in labels.iter().zip(weights).enumerate() {
        if w <= 0.0 {
            continue;
        }
        let row = &logits[i * vocab..(i + 1) * vocab];
        let mut best = 0usize;
        for (j, &x) in row.iter().enumerate() {
            if x > row[best] {
                best = j;
            }
        }
        if best as i32 == lab {
            hit += f64::from(w);
        }
        total += f64::from(w);
    }
    if total == 0.0 {
        0.0
    } else {
        hit / total
    }
}

/// Mean weighted cross-entropy (nats) from logits — mirrors
/// `layers.softmax_xent` so Rust-side eval agrees with the training loss.
pub fn softmax_xent(logits: &[f32], labels: &[i32], weights: &[f32], vocab: usize) -> f64 {
    assert_eq!(logits.len(), labels.len() * vocab);
    let mut nll = 0.0;
    let mut total = 0.0;
    for (i, (&lab, &w)) in labels.iter().zip(weights).enumerate() {
        if w <= 0.0 {
            continue;
        }
        let row = &logits[i * vocab..(i + 1) * vocab];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let logz = mx + row.iter().map(|&x| (x - mx).exp()).sum::<f32>().ln();
        nll += f64::from((logz - row[lab as usize]) * w);
        total += f64::from(w);
    }
    if total == 0.0 {
        0.0
    } else {
        nll / total
    }
}

/// Multi-class accuracy from (B, C) logits.
pub fn cls_accuracy(logits: &[f32], labels: &[i32], classes: usize) -> f64 {
    assert_eq!(logits.len(), labels.len() * classes);
    let mut hit = 0;
    for (i, &lab) in labels.iter().enumerate() {
        let row = &logits[i * classes..(i + 1) * classes];
        let mut best = 0usize;
        for (j, &x) in row.iter().enumerate() {
            if x > row[best] {
                best = j;
            }
        }
        if best as i32 == lab {
            hit += 1;
        }
    }
    hit as f64 / labels.len().max(1) as f64
}

/// Binary F1 from predictions and gold labels.
pub fn binary_f1(pred: &[bool], gold: &[bool]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    let mut tp = 0.0;
    let mut fp = 0.0;
    let mut fnn = 0.0;
    for (&p, &g) in pred.iter().zip(gold) {
        match (p, g) {
            (true, true) => tp += 1.0,
            (true, false) => fp += 1.0,
            (false, true) => fnn += 1.0,
            _ => {}
        }
    }
    if tp == 0.0 {
        return 0.0;
    }
    let prec = tp / (tp + fp);
    let rec = tp / (tp + fnn);
    2.0 * prec * rec / (prec + rec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_per_token_of_uniform() {
        // uniform over 256 symbols = 8 bits
        let nll = (256f64).ln();
        assert!((bits_per_token(nll) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn mlm_accuracy_counts_weighted_hits() {
        // vocab 3, two positions; logits argmax = [2, 0]; labels [2, 1]
        let logits = [0.0, 0.1, 0.9, 0.8, 0.1, 0.0];
        let labels = [2, 1];
        let w = [1.0, 1.0];
        assert!((mlm_accuracy(&logits, &labels, &w, 3) - 0.5).abs() < 1e-12);
        // zero-weighted miss is ignored
        let w = [1.0, 0.0];
        assert!((mlm_accuracy(&logits, &labels, &w, 3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn xent_matches_hand_computation() {
        let logits = [0.0, 0.0]; // uniform over 2
        let labels = [0];
        let w = [1.0];
        assert!((softmax_xent(&logits, &labels, &w, 2) - (2f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn cls_accuracy_basic() {
        let logits = [1.0, 0.0, 0.0, 1.0];
        assert!((cls_accuracy(&logits, &[0, 1], 2) - 1.0).abs() < 1e-12);
        assert!((cls_accuracy(&logits, &[1, 1], 2) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn binary_f1_perfect_and_empty() {
        assert!((binary_f1(&[true, false], &[true, false]) - 1.0).abs() < 1e-12);
        assert_eq!(binary_f1(&[false, false], &[true, false]), 0.0);
    }
}
