//! ROUGE-N and ROUGE-L over token-id sequences (Tab. 4 / Tab. 20).

use std::collections::HashMap;

/// Precision / recall / F1 triple.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RougeScore {
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
}

impl RougeScore {
    fn from_counts(overlap: f64, pred: f64, gold: f64) -> Self {
        if overlap == 0.0 || pred == 0.0 || gold == 0.0 {
            return RougeScore::default();
        }
        let p = overlap / pred;
        let r = overlap / gold;
        RougeScore { precision: p, recall: r, f1: 2.0 * p * r / (p + r) }
    }
}

fn ngram_counts(xs: &[i32], n: usize) -> HashMap<&[i32], usize> {
    let mut m = HashMap::new();
    if xs.len() >= n {
        for w in xs.windows(n) {
            *m.entry(w).or_insert(0) += 1;
        }
    }
    m
}

/// ROUGE-N: clipped n-gram overlap.
pub fn rouge_n(pred: &[i32], gold: &[i32], n: usize) -> RougeScore {
    let pc = ngram_counts(pred, n);
    let gc = ngram_counts(gold, n);
    let overlap: usize = gc
        .iter()
        .map(|(g, &c)| c.min(pc.get(g).copied().unwrap_or(0)))
        .sum();
    let np = pred.len().saturating_sub(n - 1);
    let ng = gold.len().saturating_sub(n - 1);
    RougeScore::from_counts(overlap as f64, np as f64, ng as f64)
}

/// ROUGE-L: longest common subsequence based F-measure.
pub fn rouge_l(pred: &[i32], gold: &[i32]) -> RougeScore {
    let lcs = lcs_len(pred, gold) as f64;
    RougeScore::from_counts(lcs, pred.len() as f64, gold.len() as f64)
}

fn lcs_len(a: &[i32], b: &[i32]) -> usize {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    let mut prev = vec![0usize; b.len() + 1];
    let mut cur = vec![0usize; b.len() + 1];
    for &x in a {
        for (j, &y) in b.iter().enumerate() {
            cur[j + 1] = if x == y { prev[j] + 1 } else { cur[j].max(prev[j + 1]) };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_sequences_score_one() {
        let x = [1, 2, 3, 4, 5];
        assert!((rouge_n(&x, &x, 1).f1 - 1.0).abs() < 1e-12);
        assert!((rouge_n(&x, &x, 2).f1 - 1.0).abs() < 1e-12);
        assert!((rouge_l(&x, &x).f1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_sequences_score_zero() {
        assert_eq!(rouge_n(&[1, 2], &[3, 4], 1).f1, 0.0);
        assert_eq!(rouge_l(&[1, 2], &[3, 4]).f1, 0.0);
    }

    #[test]
    fn rouge1_partial_overlap() {
        // pred {1,2,3}, gold {2,3,4}: overlap 2, p=2/3, r=2/3
        let s = rouge_n(&[1, 2, 3], &[2, 3, 4], 1);
        assert!((s.precision - 2.0 / 3.0).abs() < 1e-12);
        assert!((s.f1 - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn rouge2_clipping() {
        // repeated bigram in pred must be clipped by gold count
        let s = rouge_n(&[1, 2, 1, 2, 1, 2], &[1, 2, 9, 9], 2);
        // gold has one (1,2); pred has three → overlap 1, p=1/5, r=1/3
        assert!((s.precision - 0.2).abs() < 1e-12);
        assert!((s.recall - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn lcs_respects_order() {
        assert_eq!(lcs_len(&[1, 3, 2], &[1, 2, 3]), 2);
        assert_eq!(lcs_len(&[1, 2, 3, 4], &[2, 4]), 2);
        assert_eq!(lcs_len(&[], &[1]), 0);
    }
}
