//! Admission control: the one gate every request passes on its way into
//! the batcher, shared by the TCP ingress and the in-process client.
//!
//! Three policies, checked in order at submit time:
//!
//! 1. **Hard queue bound** (`max_queue`): outstanding
//!    (admitted-but-unanswered) requests across all clients may never
//!    exceed it — past the bound new arrivals are shed [`QueueFull`]
//!    regardless of priority, so queue memory stays flat no matter the
//!    offered load.
//! 2. **Per-client inflight cap** (`max_client_inflight`): a greedy
//!    pipelining client is shed [`ClientLimit`] instead of consuming
//!    the shared queue budget other clients need (fairness isolation).
//! 3. **Soft latency budget** (`latency_budget_ms`): once the observed
//!    request queue-wait EWMA blows the budget — and at least
//!    `pressure_floor` requests are outstanding, so a stale post-spike
//!    EWMA cannot shed on an idle server — `Normal`/`Low` priority
//!    requests are shed [`Overloaded`]. A request whose own deadline is
//!    already smaller than the EWMA is shed the same way (admitting it
//!    would only queue a guaranteed miss).
//!
//! The router feeds the EWMA with each completed request's observed
//! queue wait (total latency minus execute time) and releases the
//! outstanding slots as requests are answered — every answer path,
//! including batch failures and expiry sheds, releases exactly once.
//!
//! The gate also keeps a lock-free per-client [`ClientRate`]
//! sliding-window submission counter (every submit ticks it, admitted
//! or shed), surfaced as the `req_per_s` gauge in the per-client
//! metrics ledger.
//!
//! [`QueueFull`]: ShedReason::QueueFull
//! [`ClientLimit`]: ShedReason::ClientLimit
//! [`Overloaded`]: ShedReason::Overloaded

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use super::api::{Priority, ShedReason};
use crate::config::AdmissionConfig;

/// EWMA smoothing factor for the observed queue wait (per completed
/// request). 0.2 reacts within a handful of batches without flapping on
/// a single slow outlier.
const EWMA_ALPHA: f64 = 0.2;

/// Shared admission state. Lock-free: the counters are atomics and the
/// queue-wait EWMA is an f64 carried in an `AtomicU64`, so the submit
/// hot path never takes the metrics mutex.
#[derive(Debug)]
pub struct AdmissionState {
    cfg: AdmissionConfig,
    /// Admitted-but-unanswered requests across all clients.
    outstanding: AtomicUsize,
    /// High-water mark of `outstanding` (the bounded-memory witness).
    peak_outstanding: AtomicUsize,
    /// Queue-wait EWMA in ms, stored as f64 bits. 0.0 = no signal yet.
    ewma_bits: AtomicU64,
    /// Total sheds at the admission door (dispatch-time `Expired` sheds
    /// are counted by metrics, not here).
    shed: AtomicUsize,
}

impl AdmissionState {
    pub fn new(cfg: AdmissionConfig) -> Self {
        AdmissionState {
            cfg,
            outstanding: AtomicUsize::new(0),
            peak_outstanding: AtomicUsize::new(0),
            ewma_bits: AtomicU64::new(0.0f64.to_bits()),
            shed: AtomicUsize::new(0),
        }
    }

    /// The configured policy.
    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Try to admit one request from a client currently holding
    /// `client_inflight` slots. On success both the global and the
    /// client counters are incremented and must be released exactly
    /// once via [`AdmissionState::release`] when the request is
    /// answered. On shed, no state is held.
    pub fn try_admit(
        &self,
        priority: Priority,
        deadline: Option<Duration>,
        client_inflight: &AtomicUsize,
    ) -> Result<(), ShedReason> {
        // hard queue bound: exact under concurrency (CAS increment)
        let before = match bounded_increment(&self.outstanding, self.cfg.max_queue) {
            Some(prev) => prev,
            None => return Err(self.reject(ShedReason::QueueFull)),
        };
        // per-client cap, undoing the global slot on shed
        if bounded_increment(client_inflight, self.cfg.max_client_inflight).is_none() {
            self.outstanding.fetch_sub(1, Ordering::AcqRel);
            return Err(self.reject(ShedReason::ClientLimit));
        }
        // soft budget/deadline shed, gated on real queue pressure
        if before >= self.cfg.pressure_floor {
            let ewma = self.ewma_wait_ms();
            let over_budget = matches!(self.cfg.latency_budget_ms, Some(b) if ewma > b)
                && priority != Priority::High;
            let misses_deadline =
                matches!(deadline, Some(d) if ewma > d.as_secs_f64() * 1e3);
            if over_budget || misses_deadline {
                client_inflight.fetch_sub(1, Ordering::AcqRel);
                self.outstanding.fetch_sub(1, Ordering::AcqRel);
                return Err(self.reject(ShedReason::Overloaded));
            }
        }
        self.peak_outstanding.fetch_max(before + 1, Ordering::AcqRel);
        Ok(())
    }

    /// Release the slots held by one admitted request (call exactly
    /// once per answered request, on every answer path).
    pub fn release(&self, client_inflight: &AtomicUsize) {
        self.outstanding.fetch_sub(1, Ordering::AcqRel);
        client_inflight.fetch_sub(1, Ordering::AcqRel);
    }

    /// Fold one completed request's observed queue wait into the EWMA.
    pub fn observe_wait(&self, ms: f64) {
        if !ms.is_finite() {
            return;
        }
        let ms = ms.max(0.0);
        let mut cur = self.ewma_bits.load(Ordering::Acquire);
        loop {
            let prev = f64::from_bits(cur);
            // first sample seeds the EWMA directly (0.0 = no signal)
            let next = if prev == 0.0 { ms } else { (1.0 - EWMA_ALPHA) * prev + EWMA_ALPHA * ms };
            match self.ewma_bits.compare_exchange_weak(
                cur,
                next.to_bits(),
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return,
                Err(observed) => cur = observed,
            }
        }
    }

    /// Current queue-wait EWMA in ms (0.0 before any completion).
    pub fn ewma_wait_ms(&self) -> f64 {
        f64::from_bits(self.ewma_bits.load(Ordering::Acquire))
    }

    /// Admitted-but-unanswered requests right now.
    pub fn outstanding(&self) -> usize {
        self.outstanding.load(Ordering::Acquire)
    }

    /// High-water mark of [`AdmissionState::outstanding`].
    pub fn peak_outstanding(&self) -> usize {
        self.peak_outstanding.load(Ordering::Acquire)
    }

    /// Total admission-door sheds so far.
    pub fn shed_count(&self) -> usize {
        self.shed.load(Ordering::Acquire)
    }

    fn reject(&self, reason: ShedReason) -> ShedReason {
        self.shed.fetch_add(1, Ordering::AcqRel);
        reason
    }
}

/// Slots in the [`ClientRate`] sliding window.
pub const RATE_SLOTS: usize = 8;
/// Width of one window slot in milliseconds (8 × 250 ms = a 2 s
/// window: wide enough to smooth request bursts, narrow enough that a
/// client going quiet decays to 0 within two seconds).
pub const RATE_SLOT_MS: u64 = 250;

/// Lock-free sliding-window request-rate counter, one per client.
///
/// Eight 250 ms slots cover a rolling 2 s window. Each slot packs
/// `(generation << 32) | count` into one `AtomicU64`: a submit CAS-es
/// either a count bump (same generation) or a fresh `(gen, 1)` cell
/// (slot recycled from a past window), so ticks from concurrent
/// connection threads never lose counts and never take a lock. Reads
/// sum only slots whose generation falls inside the current window —
/// stale slots are skipped, not cleaned, so there is no maintenance
/// path.
///
/// The deterministic `*_at_ms` entry points take the clock as an
/// argument (milliseconds since the counter was created) so tests can
/// drive the window exactly; `observe`/`req_per_s` wrap them with the
/// real elapsed clock.
#[derive(Debug)]
pub struct ClientRate {
    started: Instant,
    slots: [AtomicU64; RATE_SLOTS],
}

impl Default for ClientRate {
    fn default() -> Self {
        Self::new()
    }
}

impl ClientRate {
    pub fn new() -> Self {
        ClientRate {
            started: Instant::now(),
            slots: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Count one submission now.
    pub fn observe(&self) {
        self.observe_at_ms(self.started.elapsed().as_millis() as u64);
    }

    /// Current rate over the trailing window, requests per second.
    /// Averages over the full 2 s window, so a freshly connected
    /// client's gauge ramps up over its first window rather than
    /// spiking.
    pub fn req_per_s(&self) -> f64 {
        self.rate_at_ms(self.started.elapsed().as_millis() as u64)
    }

    /// Count one submission at `now_ms` milliseconds on this counter's
    /// clock.
    pub fn observe_at_ms(&self, now_ms: u64) {
        let gen = now_ms / RATE_SLOT_MS;
        let tag = (gen as u32 as u64) << 32;
        let slot = &self.slots[(gen as usize) % RATE_SLOTS];
        let mut cur = slot.load(Ordering::Acquire);
        loop {
            let next = if cur & 0xFFFF_FFFF_0000_0000 == tag {
                if cur & 0xFFFF_FFFF == 0xFFFF_FFFF {
                    return; // saturated (4e9 submits in 250ms: not real)
                }
                cur + 1
            } else {
                tag | 1
            };
            match slot.compare_exchange_weak(cur, next, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => return,
                Err(observed) => cur = observed,
            }
        }
    }

    /// Rate over the window ending at `now_ms`, requests per second.
    pub fn rate_at_ms(&self, now_ms: u64) -> f64 {
        let gen = now_ms / RATE_SLOT_MS;
        let oldest = gen.saturating_sub(RATE_SLOTS as u64 - 1);
        let mut total = 0u64;
        for g in oldest..=gen {
            let v = self.slots[(g as usize) % RATE_SLOTS].load(Ordering::Acquire);
            if v >> 32 == g as u32 as u64 {
                total += v & 0xFFFF_FFFF;
            }
        }
        total as f64 * 1000.0 / (RATE_SLOTS as u64 * RATE_SLOT_MS) as f64
    }
}

/// Increment `counter` only while it stays below `bound`; returns the
/// pre-increment value, or `None` (no change) when the bound is hit.
fn bounded_increment(counter: &AtomicUsize, bound: usize) -> Option<usize> {
    let mut cur = counter.load(Ordering::Acquire);
    loop {
        if cur >= bound {
            return None;
        }
        match counter.compare_exchange_weak(cur, cur + 1, Ordering::AcqRel, Ordering::Acquire) {
            Ok(prev) => return Some(prev),
            Err(observed) => cur = observed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AdmissionConfig {
        AdmissionConfig {
            latency_budget_ms: Some(10.0),
            max_queue: 4,
            max_client_inflight: 2,
            pressure_floor: 0,
        }
    }

    #[test]
    fn hard_queue_bound_sheds_queue_full() {
        let st = AdmissionState::new(AdmissionConfig { max_queue: 2, ..cfg() });
        let a = AtomicUsize::new(0);
        let b = AtomicUsize::new(0);
        st.try_admit(Priority::Normal, None, &a).unwrap();
        st.try_admit(Priority::Normal, None, &b).unwrap();
        // queue full: even High priority is refused
        assert_eq!(st.try_admit(Priority::High, None, &a), Err(ShedReason::QueueFull));
        assert_eq!(st.outstanding(), 2);
        assert_eq!(st.peak_outstanding(), 2);
        assert_eq!(st.shed_count(), 1);
        st.release(&a);
        st.try_admit(Priority::High, None, &a).unwrap();
        assert_eq!(st.outstanding(), 2);
    }

    #[test]
    fn client_cap_sheds_without_leaking_global_slots() {
        let st = AdmissionState::new(cfg());
        let greedy = AtomicUsize::new(0);
        st.try_admit(Priority::Normal, None, &greedy).unwrap();
        st.try_admit(Priority::Normal, None, &greedy).unwrap();
        assert_eq!(st.try_admit(Priority::Normal, None, &greedy), Err(ShedReason::ClientLimit));
        // the shed must not consume a global slot
        assert_eq!(st.outstanding(), 2);
        // other clients still fit
        let polite = AtomicUsize::new(0);
        st.try_admit(Priority::Normal, None, &polite).unwrap();
        assert_eq!(st.outstanding(), 3);
    }

    #[test]
    fn budget_shed_spares_high_priority_and_idle_servers() {
        let st = AdmissionState::new(AdmissionConfig { pressure_floor: 1, ..cfg() });
        let c = AtomicUsize::new(0);
        // blow the budget (EWMA seeds at 50ms > 10ms budget)
        st.observe_wait(50.0);
        // no pressure (0 outstanding < floor 1): still admitted
        st.try_admit(Priority::Normal, None, &c).unwrap();
        // pressured now: Normal is shed, High passes
        assert_eq!(st.try_admit(Priority::Normal, None, &c), Err(ShedReason::Overloaded));
        assert_eq!(st.try_admit(Priority::High, None, &c), Ok(()));
        assert_eq!(st.outstanding(), 2);
        // a deadline below the EWMA sheds even High priority (fresh
        // client cell, so the per-client cap stays out of the way —
        // `c` is already at its cap of 2 and would shed ClientLimit)
        let c2 = AtomicUsize::new(0);
        let d = Some(Duration::from_millis(5));
        assert_eq!(st.try_admit(Priority::High, d, &c2), Err(ShedReason::Overloaded));
        // a deadline the EWMA can meet is admitted
        assert_eq!(
            st.try_admit(Priority::High, Some(Duration::from_secs(1)), &c2),
            Ok(())
        );
    }

    #[test]
    fn ewma_converges_and_release_restores_capacity() {
        let st = AdmissionState::new(cfg());
        assert_eq!(st.ewma_wait_ms(), 0.0);
        st.observe_wait(100.0);
        assert!((st.ewma_wait_ms() - 100.0).abs() < 1e-12, "first sample seeds");
        for _ in 0..60 {
            st.observe_wait(1.0);
        }
        assert!(st.ewma_wait_ms() < 2.0, "EWMA must converge toward recent waits");
        st.observe_wait(f64::NAN); // ignored, never poisons the gauge
        assert!(st.ewma_wait_ms().is_finite());

        let c = AtomicUsize::new(0);
        for _ in 0..4 {
            // budget is blown? no: ewma ~1ms < 10ms budget, so all admit
            // up to max_queue with the client cap raised via fresh cells
            let cell = AtomicUsize::new(0);
            st.try_admit(Priority::Normal, None, &cell).unwrap();
        }
        assert_eq!(st.try_admit(Priority::Normal, None, &c), Err(ShedReason::QueueFull));
        assert_eq!(st.peak_outstanding(), 4);
    }

    #[test]
    fn client_rate_window_counts_and_expires() {
        let r = ClientRate::new();
        assert_eq!(r.rate_at_ms(0), 0.0);
        // 10 submits inside the first slot → 10 req over the 2s window
        for _ in 0..10 {
            r.observe_at_ms(100);
        }
        assert_eq!(r.rate_at_ms(100), 5.0);
        // spread across slots: still summed while inside the window
        r.observe_at_ms(600);
        r.observe_at_ms(1900);
        assert_eq!(r.rate_at_ms(1900), 6.0);
        // 2s later the first slot's generation has left the window
        // (and its slot index is being reused by a fresh generation)
        assert_eq!(r.rate_at_ms(2100), 1.0, "only the 600ms+1900ms ticks remain");
        // far future: everything expired without any cleanup pass
        assert_eq!(r.rate_at_ms(60_000), 0.0);
    }

    #[test]
    fn client_rate_slot_reuse_resets_counts() {
        let r = ClientRate::new();
        r.observe_at_ms(0);
        r.observe_at_ms(0);
        // same slot index (gen 0 and gen 8 both map to slot 0), one
        // full window later: the old count must not bleed through
        r.observe_at_ms(8 * RATE_SLOT_MS);
        assert_eq!(r.rate_at_ms(8 * RATE_SLOT_MS), 0.5);
    }
}
