//! The serving coordinator: BigBird's systems payoff is "serve 8× longer
//! documents on the same hardware", so L3 is a long-document inference
//! server shaped as a **sharded, pipelined dispatch loop**:
//!
//! ```text
//!                          ┌────────────────── router thread ─────────────────┐
//!  clients ──req──▶ submit │ accept ─▶ batcher ─▶ dispatch ──job──▶ worker 0  │ (owns PJRT, cpu)
//!     ▲      (bounded      │  (per-bucket FIFO,   (min expected  ─▶ worker 1  │ (owns PJRT, cpu)
//!     │       queue)       │   inflight caps)      completion)   ─▶ worker N  │ (owns PJRT, gpu)
//!     │                    │ complete ◀──────── shared completion channel ◀───┘
//!     └── per-request response channel (decode: argmax at mask positions)
//! ```
//!
//! **Stages.** The router overlaps the three hot-path stages that
//! `experiments/hotpath.rs` times: (1) *accept/assemble* — submissions
//! land in the length-bucketing [`Batcher`]; (2) *execute* — every
//! formable batch is dispatched to the [`EnginePool`] worker with the
//! minimum expected completion time under the per-backend roofline cost
//! model ([`WeightedPolicy`]; the pool may mix CPU/GPU/TPU workers, and
//! on a homogeneous pool under uniform single-bucket traffic the policy
//! reduces exactly to least-loaded), each
//! worker a thread owning its own PJRT `Runtime` + `ExecutablePool`
//! (PJRT objects are not `Send`, so only plain
//! [`crate::runtime::HostTensor`]s and control messages cross threads)
//! — or, for `native` workers, the in-process block-sparse kernel
//! engine ([`crate::kernel::NativeEngine`]): real Rust compute with no
//! PJRT client and no AOT artifacts, so a `--backends native:2` pool
//! serves real forward passes on a bare checkout;
//! (3) *decode/complete* — finished batches come back on one shared
//! completion channel and are decoded while other batches are still
//! executing; their observed execution times refine the cost model's
//! per-(bucket, backend) EWMAs, so long-sequence buckets migrate to the
//! backend whose roofline actually fits them. The manifest is parsed
//! once and shared `Arc`-style with all workers.
//!
//! **Backpressure.** Three bounds, outermost first: the client
//! submission queue (`ServerConfig::queue_depth`) blocks producers when
//! the router falls behind; per-bucket inflight caps
//! (`ServingConfig::max_inflight`, enforced by [`Batcher::poll`]) stop a
//! slow long-sequence bucket from monopolising the pool while short
//! buckets starve; and each worker's bounded job queue blocks the
//! dispatcher if a worker stalls.
//!
//! **Shutdown order.** `Server::shutdown` (or `Drop`) flips the stop
//! flag and joins the router; the router drops the [`EnginePool`], whose
//! `Drop` closes every worker's job queue and then joins each worker —
//! no detached threads (the old `EngineHandle` detach-on-drop leak is
//! gone; the handle is now a thin wrapper over a 1-worker pool).
//!
//! **Request surface.** Every caller — the TCP [`Ingress`] and the
//! in-process path alike — submits a typed [`Request`] and receives a
//! typed [`Response`] whose [`Outcome`] is `Completed`, `Shed` (the
//! typed graceful-degradation answer from admission control:
//! queue-full, over-budget, per-client cap, expired deadline), or
//! `Error`. Admission runs synchronously in [`Client::submit_with`] —
//! one gate, one accounting path — with bounded queues everywhere, so
//! memory stays flat under overload. [`wire`] defines the
//! length-prefixed versioned frame codec the ingress speaks, and
//! metrics expose streaming latency percentiles, shed counters, and
//! per-client accounting over the same wire (`metrics` frame → JSON
//! [`MetricsSnapshot`]).

mod admission;
pub mod api;
mod batcher;
mod dispatch;
mod engine;
mod ingress;
mod metrics;
mod server;
pub mod trace;
pub mod wire;

pub use admission::{AdmissionState, ClientRate};
pub use api::{Outcome, Priority, Request, Response, ShedReason};
pub use batcher::{Batcher, BatcherConfig, Bucket, FormedBatch, PendingRequest};
pub use dispatch::{replay, WeightedPolicy};
pub use engine::{EngineHandle, EnginePool, PoolCompletion, PoolJob};
pub use ingress::Ingress;
pub use metrics::{
    json_num_field, BackendRoofline, BucketLatency, ClientStats, MetricsSnapshot, ServingMetrics,
};
pub use server::{Client, Server, ServerConfig, SubmitTicket};
pub use wire::WireClient;
