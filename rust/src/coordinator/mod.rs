//! The serving coordinator: BigBird's systems payoff is "serve 8× longer
//! documents on the same hardware", so L3 is a long-document inference
//! server in the vLLM-router shape:
//!
//! ```text
//!  clients ──req──▶ router thread ──job──▶ engine thread (owns PJRT)
//!     ▲                 │  length-bucketing dynamic batcher
//!     └───── per-request response channel ◀──────┘
//! ```
//!
//! PJRT objects are not `Send`, so the engine thread constructs and owns
//! the [`ExecutablePool`]; everything crossing threads is a plain
//! [`HostTensor`] or a control message. The batcher buckets requests by
//! padded sequence length (artifact shapes are fixed at AOT time), fills
//! batches up to the artifact batch size, and flushes on a deadline.

mod batcher;
mod engine;
mod metrics;
mod server;
pub mod trace;

pub use batcher::{Batcher, BatcherConfig, Bucket, PendingRequest};
pub use engine::{EngineHandle, EngineJob};
pub use batcher::FormedBatch;
pub use metrics::{MetricsSnapshot, ServingMetrics};
pub use server::{Response, Server, ServerConfig};
