//! Device-aware weighted dispatch: minimum expected-completion-time
//! worker selection over a heterogeneous backend pool.
//!
//! Pure logic (no PJRT): the policy sees only each worker's [`Backend`]
//! descriptor, the estimated cost of the work already queued on it, and
//! the [`JobShape`] of the batch being placed. The per-(bucket, backend)
//! cost starts from the static [`Roofline`](crate::runtime::Roofline)
//! seed and is refined online by an EWMA of observed execution times, so
//! mis-seeded rooflines converge to reality after a few batches.
//!
//! With identical backends and a uniform trace this degrades exactly to
//! PR 1's least-loaded policy: every job carries the same cost estimate,
//! so `argmin(queued + estimate) == argmin(outstanding count)`, with the
//! same lowest-index tie-break.

use std::collections::{HashMap, VecDeque};

use crate::runtime::{Backend, BackendKind, JobShape};

/// EWMA smoothing factor for observed execution times (weight on the
/// newest observation).
const EWMA_ALPHA: f64 = 0.3;

/// Expected-completion-time dispatch over per-worker backends.
#[derive(Debug)]
pub struct WeightedPolicy {
    backends: Vec<Backend>,
    /// Per-worker FIFO ledger of the shapes dispatched and not yet
    /// completed (workers drain their bounded queues in order). Queued
    /// work is costed from the ledger with the *current* estimates at
    /// pick time — never accumulated — so estimates refine retroactively
    /// as EWMAs learn, an idle worker's queue is exactly zero, and two
    /// same-backend workers holding equal ledgers always compare
    /// identically, which is what makes the homogeneous case degrade
    /// bit-exactly to the least-loaded policy.
    charges: Vec<VecDeque<JobShape>>,
    /// Observed exec-time EWMA per (bucket seq_len, realized backend).
    ewma_ms: HashMap<(usize, BackendKind), f64>,
}

impl WeightedPolicy {
    /// Policy over one [`Backend`] descriptor per worker.
    pub fn new(backends: Vec<Backend>) -> Self {
        assert!(!backends.is_empty(), "dispatch policy needs at least one worker");
        let n = backends.len();
        WeightedPolicy {
            backends,
            charges: vec![VecDeque::new(); n],
            ewma_ms: HashMap::new(),
        }
    }

    /// Number of workers the policy scores.
    pub fn size(&self) -> usize {
        self.backends.len()
    }

    /// The worker backends, indexed by worker id.
    pub fn backends(&self) -> &[Backend] {
        &self.backends
    }

    /// Estimated execution cost of `shape` on `worker`, in ms: the
    /// observed EWMA for (bucket, backend) when one exists, else the
    /// static roofline seed.
    pub fn estimate_ms(&self, worker: usize, shape: JobShape) -> f64 {
        let b = &self.backends[worker];
        self.ewma_ms
            .get(&(shape.seq_len, b.kind))
            .copied()
            .unwrap_or_else(|| b.roofline.cost_ms(shape))
    }

    /// Pick the worker with the minimum expected completion time for a
    /// batch of `shape`: queued work plus this batch's estimated cost on
    /// that worker's backend. Ties break to the lowest index (the
    /// least-loaded policy's behaviour).
    pub fn pick(&self, shape: JobShape) -> usize {
        let mut best = 0usize;
        let mut best_eta = f64::INFINITY;
        for w in 0..self.backends.len() {
            let eta = self.queued_ms(w) + self.estimate_ms(w, shape);
            if eta < best_eta {
                best = w;
                best_eta = eta;
            }
        }
        best
    }

    /// Charge `worker` for a dispatched batch of `shape`. Must be paired
    /// with [`WeightedPolicy::completed`] when the batch finishes.
    pub fn dispatched(&mut self, worker: usize, shape: JobShape) {
        self.charges[worker].push_back(shape);
    }

    /// A batch finished on `worker`: release the oldest outstanding
    /// charge, and — when the batch *succeeded* and `observed_ms` is
    /// `Some` — fold its execution time into the (bucket, backend)
    /// EWMA. Callers pass `None` for failed batches: an error that
    /// returns in microseconds must not make its backend look cheap, or
    /// the policy would route the whole bucket into the broken worker
    /// (a failure black hole).
    pub fn completed(&mut self, worker: usize, shape: JobShape, observed_ms: Option<f64>) {
        self.charges[worker].pop_front();
        if let Some(ms) = observed_ms {
            if ms.is_finite() && ms >= 0.0 {
                let key = (shape.seq_len, self.backends[worker].kind);
                let e = self.ewma_ms.entry(key).or_insert(ms);
                *e = EWMA_ALPHA * ms + (1.0 - EWMA_ALPHA) * *e;
            }
        }
    }

    /// Estimated queued work on `worker`, in ms: its outstanding shapes
    /// costed with the current estimates (the pool's inflight caps keep
    /// the ledger short).
    pub fn queued_ms(&self, worker: usize) -> f64 {
        self.charges[worker].iter().map(|&s| self.estimate_ms(worker, s)).sum()
    }

    /// Current (bucket seq_len, backend, ewma ms) table, sorted for
    /// deterministic reporting.
    pub fn ewma_table(&self) -> Vec<(usize, BackendKind, f64)> {
        let mut t: Vec<(usize, BackendKind, f64)> =
            self.ewma_ms.iter().map(|(&(s, k), &v)| (s, k, v)).collect();
        t.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        t
    }
}

/// Replay `shapes` through a policy with at most `window` batches in
/// flight, completing the oldest dispatched batch (with its simulated
/// true cost from `true_cost(worker, shape)`) whenever the window
/// fills. Returns the worker index chosen for each batch.
///
/// This is the shared simulation harness behind the dispatch-policy
/// contract tests (`tests/dispatch_policy.rs`) and the
/// heterogeneous-pool bench (`benches/coordinator.rs`), so both
/// exercise the exact pick/dispatched/completed protocol the engine
/// pool runs.
pub fn replay(
    policy: &mut WeightedPolicy,
    shapes: &[JobShape],
    window: usize,
    true_cost: impl Fn(usize, JobShape) -> f64,
) -> Vec<usize> {
    let mut picks = Vec::with_capacity(shapes.len());
    let mut inflight: VecDeque<(usize, JobShape)> = VecDeque::new();
    for &shape in shapes {
        if inflight.len() >= window {
            let (w, s) = inflight.pop_front().expect("window > 0");
            policy.completed(w, s, Some(true_cost(w, s)));
        }
        let w = policy.pick(shape);
        policy.dispatched(w, shape);
        inflight.push_back((w, shape));
        picks.push(w);
    }
    while let Some((w, s)) = inflight.pop_front() {
        policy.completed(w, s, Some(true_cost(w, s)));
    }
    picks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Roofline;

    fn sim(kind: BackendKind, gflops: f64, overhead_ms: f64) -> Backend {
        Backend::simulated(kind, Roofline { gflops, gbps: 1000.0, overhead_ms })
    }

    #[test]
    fn identical_backends_degrade_to_least_loaded() {
        // three identical workers, uniform shapes: picks must match the
        // least-loaded-by-count policy, lowest index on ties
        let b = sim(BackendKind::Cpu, 100.0, 0.1);
        let mut p = WeightedPolicy::new(vec![b.clone(), b.clone(), b]);
        let shape = JobShape { seq_len: 512, batch: 8 };
        let mut counts = [0usize; 3];
        let mut picks = Vec::new();
        for _ in 0..9 {
            let w = p.pick(shape);
            let least =
                counts.iter().enumerate().min_by_key(|&(_, c)| *c).map(|(i, _)| i).unwrap();
            assert_eq!(w, least, "diverged from least-loaded");
            p.dispatched(w, shape);
            counts[w] += 1;
            picks.push(w);
        }
        // round-robin across the identical pool
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0, 1, 2]);
        // completions free load symmetrically
        p.completed(0, shape, Some(5.0));
        assert_eq!(p.pick(shape), 0);
    }

    #[test]
    fn skewed_costs_route_long_buckets_to_the_cheap_backend() {
        // worker 0: low-latency but slow; worker 1: high-throughput
        let slow = sim(BackendKind::Cpu, 50.0, 0.05);
        let fast = sim(BackendKind::Gpu, 5000.0, 1.0);
        let mut p = WeightedPolicy::new(vec![slow, fast]);
        let long = JobShape { seq_len: 2048, batch: 4 };
        // with no queue, the long bucket must go to the throughput backend
        assert_eq!(p.pick(long), 1);
        // ...until its queue is long enough that the slow worker's ETA wins
        for _ in 0..200 {
            let w = p.pick(long);
            p.dispatched(w, long);
        }
        assert!(p.queued_ms(0) > 0.0, "slow worker must absorb overflow eventually");
    }

    #[test]
    fn ewma_overrides_a_bad_seed() {
        // seed says worker 1 (gpu) is far cheaper for this bucket...
        let cpu = sim(BackendKind::Cpu, 50.0, 0.05);
        let gpu = sim(BackendKind::Gpu, 5000.0, 1.0);
        let mut p = WeightedPolicy::new(vec![cpu, gpu]);
        let shape = JobShape { seq_len: 1024, batch: 4 };
        assert_eq!(p.pick(shape), 1);
        // ...but observations say the cpu actually executes it in 1ms and
        // the gpu in 100ms; after a few completions the policy flips
        for _ in 0..20 {
            p.dispatched(0, shape);
            p.completed(0, shape, Some(1.0));
            p.dispatched(1, shape);
            p.completed(1, shape, Some(100.0));
        }
        assert!(p.estimate_ms(0, shape) < p.estimate_ms(1, shape));
        assert_eq!(p.pick(shape), 0, "EWMA must override the static seed");
        // the ewma table surfaces both (bucket, backend) pairs
        let t = p.ewma_table();
        assert_eq!(t.len(), 2);
        assert!(t.iter().any(|&(s, k, v)| s == 1024 && k == BackendKind::Cpu && v < 2.0));
    }

    #[test]
    fn charges_settle_back_to_zero() {
        let b = sim(BackendKind::Cpu, 100.0, 0.1);
        let mut p = WeightedPolicy::new(vec![b]);
        let a = JobShape { seq_len: 128, batch: 8 };
        let c = JobShape { seq_len: 2048, batch: 2 };
        p.dispatched(0, a);
        p.dispatched(0, c);
        assert!(p.queued_ms(0) > 0.0);
        // completions observe times different from the charges — the
        // FIFO charge ledger still settles to exactly zero (queued work
        // is summed from the ledger, never accumulated)
        // a None (failed batch) still pops its charge but never touches
        // the EWMA — failures must not make a backend look cheap
        p.completed(0, a, None);
        p.completed(0, c, Some(0.5));
        assert!(p.ewma_table().iter().all(|&(s, _, _)| s != 128));
        assert_eq!(p.queued_ms(0), 0.0);
    }
}
