//! Length-prefixed, versioned wire protocol for the TCP ingress — a
//! byte-level encoding of the typed [`api`](super::api) surface, never a
//! parallel API.
//!
//! ## Frame format
//!
//! ```text
//! ┌─────────┬──────┬────────────┬──────────────┐
//! │ version │ type │ len u32 LE │ payload      │
//! │  1 byte │ 1 B  │  4 bytes   │ `len` bytes  │
//! └─────────┴──────┴────────────┴──────────────┘
//! ```
//!
//! All integers little-endian. `len` is validated against
//! [`MAX_FRAME`] **before** any allocation, so a hostile length prefix
//! cannot balloon memory. Frame types:
//!
//! | type | name             | payload |
//! |------|------------------|---------|
//! | 1    | infer request    | `id u64, priority u8, deadline_ms u32 (0 = none), n u32, tokens i32×n` |
//! | 2    | infer response   | `id u64, latency_ms f64, tag u8, tag-specific body` |
//! | 3    | metrics request  | empty |
//! | 4    | metrics response | UTF-8 JSON ([`MetricsSnapshot::to_json`](super::metrics::MetricsSnapshot::to_json)) |
//! | 5    | trace request    | empty |
//! | 6    | trace response   | UTF-8 Chrome trace-event JSON ([`crate::obs::trace::export_chrome_json`]) |
//! | 7    | prometheus request | empty |
//! | 8    | prometheus response | UTF-8 Prometheus text exposition ([`crate::obs::export::render_validated`]) |
//!
//! Infer-response tags: `0` completed (`truncated u8, n u32,
//! (pos u32, token i32)×n`), `1` shed (`reason u8`), `2` error
//! (`len u32, UTF-8 message`).
//!
//! Decoding is strict: truncated bodies, trailing garbage, unknown
//! version/type/tag bytes, and non-UTF-8 messages are all
//! [`WireError::Malformed`] — the connection is dropped, the process
//! never panics, and (because admission bookkeeping lives server-side
//! in the router's reply table) no inflight slot can leak.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use super::api::{Outcome, Priority, Request, Response, ShedReason};

/// Protocol version carried in every frame header.
pub const WIRE_VERSION: u8 = 1;
/// Hard bound on a frame payload; checked before allocating.
pub const MAX_FRAME: usize = 16 << 20;

/// Frame type: client → server inference request.
pub const FRAME_INFER_REQUEST: u8 = 1;
/// Frame type: server → client inference response.
pub const FRAME_INFER_RESPONSE: u8 = 2;
/// Frame type: client → server metrics scrape (empty payload).
pub const FRAME_METRICS_REQUEST: u8 = 3;
/// Frame type: server → client metrics JSON.
pub const FRAME_METRICS_RESPONSE: u8 = 4;
/// Frame type: client → server trace export (empty payload).
pub const FRAME_TRACE_REQUEST: u8 = 5;
/// Frame type: server → client Chrome trace-event JSON.
pub const FRAME_TRACE_RESPONSE: u8 = 6;
/// Frame type: client → server Prometheus scrape (empty payload).
pub const FRAME_PROM_REQUEST: u8 = 7;
/// Frame type: server → client Prometheus text exposition.
pub const FRAME_PROM_RESPONSE: u8 = 8;

const HEADER_LEN: usize = 6;

/// Codec-level failure.
#[derive(Debug)]
pub enum WireError {
    /// Clean EOF at a frame boundary (the peer closed; not an error).
    Closed,
    /// Transport failure mid-frame (reset, mid-frame disconnect, ...).
    Io(std::io::Error),
    /// Protocol violation: drop the connection, keep the process.
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Closed => write!(f, "connection closed"),
            WireError::Io(e) => write!(f, "io error: {e}"),
            WireError::Malformed(m) => write!(f, "malformed frame: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

fn malformed(msg: impl Into<String>) -> WireError {
    WireError::Malformed(msg.into())
}

/// One decoded frame (header validated, payload length-checked).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    pub ty: u8,
    pub payload: Vec<u8>,
}

/// Read one frame. Clean EOF before the first header byte is
/// [`WireError::Closed`]; EOF anywhere later is a mid-frame disconnect
/// and reports [`WireError::Malformed`].
pub fn read_frame(r: &mut impl Read) -> Result<Frame, WireError> {
    let mut header = [0u8; HEADER_LEN];
    // first byte separately: EOF here is a clean close, not truncation
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Err(WireError::Closed),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    header[0] = first[0];
    r.read_exact(&mut header[1..])
        .map_err(|e| eof_as_truncation(e, "truncated frame header"))?;
    if header[0] != WIRE_VERSION {
        return Err(malformed(format!(
            "unsupported wire version {} (expected {WIRE_VERSION})",
            header[0]
        )));
    }
    let ty = header[1];
    let len = u32::from_le_bytes([header[2], header[3], header[4], header[5]]) as usize;
    if len > MAX_FRAME {
        return Err(malformed(format!("frame length {len} exceeds cap {MAX_FRAME}")));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .map_err(|e| eof_as_truncation(e, "truncated frame payload"))?;
    Ok(Frame { ty, payload })
}

fn eof_as_truncation(e: std::io::Error, what: &str) -> WireError {
    if e.kind() == std::io::ErrorKind::UnexpectedEof {
        malformed(what)
    } else {
        WireError::Io(e)
    }
}

/// Write one frame (header + payload in a single buffered write, so a
/// concurrent writer on the same socket can never interleave bytes
/// inside a frame as long as each frame is written under one lock).
pub fn write_frame(w: &mut impl Write, ty: u8, payload: &[u8]) -> std::io::Result<()> {
    assert!(payload.len() <= MAX_FRAME, "refusing to emit an oversized frame");
    let mut buf = Vec::with_capacity(HEADER_LEN + payload.len());
    buf.push(WIRE_VERSION);
    buf.push(ty);
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(payload);
    w.write_all(&buf)?;
    w.flush()
}

/// Encode an inference request payload.
pub fn encode_request(req: &Request) -> Vec<u8> {
    let deadline_ms: u32 = req
        .deadline
        .map(|d| d.as_millis().min(u32::MAX as u128) as u32)
        .unwrap_or(0);
    let mut p = Vec::with_capacity(17 + 4 * req.tokens.len());
    p.extend_from_slice(&req.id.to_le_bytes());
    p.push(req.priority.code());
    p.extend_from_slice(&deadline_ms.to_le_bytes());
    p.extend_from_slice(&(req.tokens.len() as u32).to_le_bytes());
    for t in &req.tokens {
        p.extend_from_slice(&t.to_le_bytes());
    }
    p
}

/// Decode an inference request payload (strict: exact length, valid
/// priority code).
pub fn decode_request(p: &[u8]) -> Result<Request, WireError> {
    let mut c = Cursor::new(p);
    let id = c.u64()?;
    let priority = Priority::from_code(c.u8()?)
        .map_err(|e| malformed(format!("{e}")))?;
    let deadline_ms = c.u32()?;
    let n = c.u32()? as usize;
    // byte math in u64 so a hostile count cannot overflow the check
    if (c.remaining() as u64) != (n as u64) * 4 {
        return Err(malformed(format!(
            "token count {n} disagrees with {} payload bytes",
            c.remaining()
        )));
    }
    let mut tokens = Vec::with_capacity(n);
    for _ in 0..n {
        tokens.push(c.i32()?);
    }
    c.done()?;
    Ok(Request {
        id,
        tokens,
        deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms as u64)),
        priority,
    })
}

const TAG_COMPLETED: u8 = 0;
const TAG_SHED: u8 = 1;
const TAG_ERROR: u8 = 2;

/// Encode an inference response payload.
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut p = Vec::with_capacity(32);
    p.extend_from_slice(&resp.id.to_le_bytes());
    p.extend_from_slice(&resp.latency_ms.to_le_bytes());
    match &resp.outcome {
        Outcome::Completed { predictions, truncated } => {
            p.push(TAG_COMPLETED);
            p.push(*truncated as u8);
            p.extend_from_slice(&(predictions.len() as u32).to_le_bytes());
            for &(pos, tok) in predictions {
                p.extend_from_slice(&(pos as u32).to_le_bytes());
                p.extend_from_slice(&tok.to_le_bytes());
            }
        }
        Outcome::Shed { reason } => {
            p.push(TAG_SHED);
            p.push(reason.code());
        }
        Outcome::Error { message } => {
            p.push(TAG_ERROR);
            let msg = message.as_bytes();
            p.extend_from_slice(&(msg.len() as u32).to_le_bytes());
            p.extend_from_slice(msg);
        }
    }
    p
}

/// Decode an inference response payload (strict, like
/// [`decode_request`]).
pub fn decode_response(p: &[u8]) -> Result<Response, WireError> {
    let mut c = Cursor::new(p);
    let id = c.u64()?;
    let latency_ms = c.f64()?;
    let outcome = match c.u8()? {
        TAG_COMPLETED => {
            let truncated = match c.u8()? {
                0 => false,
                1 => true,
                other => return Err(malformed(format!("bad truncated flag {other}"))),
            };
            let n = c.u32()? as usize;
            if (c.remaining() as u64) != (n as u64) * 8 {
                return Err(malformed(format!(
                    "prediction count {n} disagrees with {} payload bytes",
                    c.remaining()
                )));
            }
            let mut predictions = Vec::with_capacity(n);
            for _ in 0..n {
                let pos = c.u32()? as usize;
                let tok = c.i32()?;
                predictions.push((pos, tok));
            }
            Outcome::Completed { predictions, truncated }
        }
        TAG_SHED => {
            let reason = ShedReason::from_code(c.u8()?)
                .map_err(|e| malformed(format!("{e}")))?;
            Outcome::Shed { reason }
        }
        TAG_ERROR => {
            let len = c.u32()? as usize;
            let bytes = c.bytes(len)?;
            let message = std::str::from_utf8(bytes)
                .map_err(|_| malformed("error message is not UTF-8"))?
                .to_string();
            Outcome::Error { message }
        }
        other => return Err(malformed(format!("unknown outcome tag {other}"))),
    };
    c.done()?;
    Ok(Response { id, outcome, latency_ms })
}

/// Strict little-endian payload reader: every read is bounds-checked,
/// and [`Cursor::done`] rejects trailing garbage.
struct Cursor<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, off: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.off
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(malformed(format!(
                "payload truncated: wanted {n} bytes, {} left",
                self.remaining()
            )));
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn i32(&mut self) -> Result<i32, WireError> {
        Ok(i32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn done(&self) -> Result<(), WireError> {
        if self.remaining() != 0 {
            return Err(malformed(format!("{} trailing bytes", self.remaining())));
        }
        Ok(())
    }
}

/// Minimal blocking client for the ingress protocol — what the demo,
/// the soak test, and the README example use. One response arrives per
/// request; requests may be pipelined ([`WireClient::send`] many, then
/// [`WireClient::recv`] as many). Fetch metrics on a connection with no
/// inference responses pending (the server may answer a metrics scrape
/// ahead of queued inference answers).
pub struct WireClient {
    stream: TcpStream,
}

impl WireClient {
    /// Connect to a running ingress.
    pub fn connect(addr: &std::net::SocketAddr) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(WireClient { stream })
    }

    /// Send one inference request without waiting for the answer.
    pub fn send(&mut self, req: &Request) -> std::io::Result<()> {
        write_frame(&mut self.stream, FRAME_INFER_REQUEST, &encode_request(req))
    }

    /// Receive the next inference response.
    pub fn recv(&mut self) -> Result<Response, WireError> {
        let f = read_frame(&mut self.stream)?;
        if f.ty != FRAME_INFER_RESPONSE {
            return Err(malformed(format!(
                "expected infer response frame, got type {}",
                f.ty
            )));
        }
        decode_response(&f.payload)
    }

    /// Send one request and block for its response.
    pub fn infer(&mut self, req: &Request) -> Result<Response, WireError> {
        self.send(req)?;
        self.recv()
    }

    /// Scrape the server's metrics snapshot as JSON.
    pub fn metrics(&mut self) -> Result<String, WireError> {
        write_frame(&mut self.stream, FRAME_METRICS_REQUEST, &[])?;
        let f = read_frame(&mut self.stream)?;
        if f.ty != FRAME_METRICS_RESPONSE {
            return Err(malformed(format!(
                "expected metrics response frame, got type {}",
                f.ty
            )));
        }
        String::from_utf8(f.payload).map_err(|_| malformed("metrics JSON is not UTF-8"))
    }

    /// Fetch the server's recorded spans as Chrome trace-event JSON
    /// (Perfetto-loadable; empty `traceEvents` while tracing is off).
    /// Like [`WireClient::metrics`], call with no inference responses
    /// pending.
    pub fn trace(&mut self) -> Result<String, WireError> {
        write_frame(&mut self.stream, FRAME_TRACE_REQUEST, &[])?;
        let f = read_frame(&mut self.stream)?;
        if f.ty != FRAME_TRACE_RESPONSE {
            return Err(malformed(format!(
                "expected trace response frame, got type {}",
                f.ty
            )));
        }
        String::from_utf8(f.payload).map_err(|_| malformed("trace JSON is not UTF-8"))
    }

    /// Scrape the server's Prometheus text exposition — the same
    /// document the ingress serves on HTTP `GET /metrics`, already
    /// validated by the strict self-parser server-side. Like
    /// [`WireClient::metrics`], call with no inference responses
    /// pending.
    pub fn prometheus(&mut self) -> Result<String, WireError> {
        write_frame(&mut self.stream, FRAME_PROM_REQUEST, &[])?;
        let f = read_frame(&mut self.stream)?;
        if f.ty != FRAME_PROM_RESPONSE {
            return Err(malformed(format!(
                "expected prometheus response frame, got type {}",
                f.ty
            )));
        }
        String::from_utf8(f.payload).map_err(|_| malformed("prometheus text is not UTF-8"))
    }

    /// The underlying stream (tests use this to simulate abrupt,
    /// mid-frame disconnects).
    pub fn stream(&mut self) -> &mut TcpStream {
        &mut self.stream
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        Request::new(vec![5, -3, 7])
            .with_id(42)
            .with_deadline(Duration::from_millis(250))
            .with_priority(Priority::High)
    }

    #[test]
    fn request_round_trips() {
        let r = req();
        assert_eq!(decode_request(&encode_request(&r)).unwrap(), r);
        // no deadline encodes as 0 and survives
        let r = Request::new(vec![]);
        assert_eq!(decode_request(&encode_request(&r)).unwrap(), r);
    }

    #[test]
    fn responses_round_trip() {
        for resp in [
            Response {
                id: 7,
                outcome: Outcome::Completed {
                    predictions: vec![(3, 11), (9, -2)],
                    truncated: true,
                },
                latency_ms: 12.25,
            },
            Response {
                id: 8,
                outcome: Outcome::Shed { reason: ShedReason::Overloaded },
                latency_ms: 0.0,
            },
            Response {
                id: 9,
                outcome: Outcome::Error { message: "boom × utf8".into() },
                latency_ms: 1.5,
            },
        ] {
            assert_eq!(decode_response(&encode_response(&resp)).unwrap(), resp);
        }
    }

    #[test]
    fn frames_round_trip_through_io() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FRAME_INFER_REQUEST, &encode_request(&req())).unwrap();
        write_frame(&mut buf, FRAME_METRICS_REQUEST, &[]).unwrap();
        let mut r = &buf[..];
        let f1 = read_frame(&mut r).unwrap();
        assert_eq!(f1.ty, FRAME_INFER_REQUEST);
        assert_eq!(decode_request(&f1.payload).unwrap(), req());
        let f2 = read_frame(&mut r).unwrap();
        assert_eq!(f2, Frame { ty: FRAME_METRICS_REQUEST, payload: vec![] });
        // clean EOF at the boundary
        assert!(matches!(read_frame(&mut r), Err(WireError::Closed)));
    }

    #[test]
    fn trace_frames_round_trip_and_types_are_distinct() {
        let mut buf = Vec::new();
        write_frame(&mut buf, FRAME_TRACE_REQUEST, &[]).unwrap();
        let body = br#"{"traceEvents":[],"displayTimeUnit":"ms"}"#;
        write_frame(&mut buf, FRAME_TRACE_RESPONSE, body).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), Frame { ty: FRAME_TRACE_REQUEST, payload: vec![] });
        let f = read_frame(&mut r).unwrap();
        assert_eq!(f.ty, FRAME_TRACE_RESPONSE);
        assert_eq!(f.payload, body);
        // the frame-type namespace stays collision-free
        let types = [
            FRAME_INFER_REQUEST,
            FRAME_INFER_RESPONSE,
            FRAME_METRICS_REQUEST,
            FRAME_METRICS_RESPONSE,
            FRAME_TRACE_REQUEST,
            FRAME_TRACE_RESPONSE,
            FRAME_PROM_REQUEST,
            FRAME_PROM_RESPONSE,
        ];
        for (i, a) in types.iter().enumerate() {
            for b in &types[i + 1..] {
                assert_ne!(a, b);
            }
        }
    }

    #[test]
    fn hostile_headers_are_rejected_before_allocation() {
        // oversized length prefix
        let mut h = vec![WIRE_VERSION, FRAME_INFER_REQUEST];
        h.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(matches!(read_frame(&mut &h[..]), Err(WireError::Malformed(_))));
        // wrong version
        let mut h = vec![9, FRAME_INFER_REQUEST];
        h.extend_from_slice(&0u32.to_le_bytes());
        assert!(matches!(read_frame(&mut &h[..]), Err(WireError::Malformed(_))));
        // truncated header
        let h = [WIRE_VERSION, FRAME_INFER_REQUEST, 1];
        assert!(matches!(read_frame(&mut &h[..]), Err(WireError::Malformed(_))));
    }

    #[test]
    fn hostile_payloads_are_rejected() {
        // token count disagreeing with payload size
        let mut p = encode_request(&Request::new(vec![1, 2, 3]));
        let n_at = 8 + 1 + 4;
        p[n_at..n_at + 4].copy_from_slice(&1_000_000u32.to_le_bytes());
        assert!(matches!(decode_request(&p), Err(WireError::Malformed(_))));
        // trailing garbage
        let mut p = encode_request(&Request::new(vec![1]));
        p.push(0);
        assert!(matches!(decode_request(&p), Err(WireError::Malformed(_))));
        // bad priority / shed / tag codes
        let mut p = encode_request(&req());
        p[8] = 77;
        assert!(matches!(decode_request(&p), Err(WireError::Malformed(_))));
        let shed =
            Response { id: 1, outcome: Outcome::Shed { reason: ShedReason::Expired }, latency_ms: 0.0 };
        let mut p = encode_response(&shed);
        *p.last_mut().unwrap() = 200;
        assert!(matches!(decode_response(&p), Err(WireError::Malformed(_))));
        let mut p = encode_response(&shed);
        p[16] = 9; // outcome tag
        assert!(matches!(decode_response(&p), Err(WireError::Malformed(_))));
        // truncated response body
        let done = Response {
            id: 2,
            outcome: Outcome::Completed { predictions: vec![(1, 2)], truncated: false },
            latency_ms: 3.0,
        };
        let p = encode_response(&done);
        for cut in 0..p.len() {
            assert!(
                decode_response(&p[..cut]).is_err(),
                "truncation at {cut} must not decode"
            );
        }
    }
}
