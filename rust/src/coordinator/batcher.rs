//! Length-bucketing dynamic batcher — pure logic, fully unit-testable
//! without PJRT.
//!
//! AOT artifacts have fixed (batch, seq_len) shapes, so the batcher's job
//! is: route each request to the smallest bucket whose seq_len fits,
//! batch up to the bucket's capacity, and flush a partial batch when its
//! oldest request has waited `max_wait`. Requests longer than the largest
//! bucket are truncated to it (the dense-baseline behaviour the paper
//! ridicules — but somebody has to serve those requests too).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// One artifact-backed shape bucket.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bucket {
    /// artifact name to execute for this bucket
    pub artifact: String,
    /// padded sequence length
    pub seq_len: usize,
    /// batch capacity baked into the artifact
    pub batch: usize,
}

/// Batcher tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// flush a partial batch when its oldest member waited this long
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_wait: Duration::from_millis(10) }
    }
}

/// A queued request (token ids + bookkeeping).
#[derive(Clone, Debug)]
pub struct PendingRequest {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub enqueued: Instant,
}

/// A formed batch ready for the engine.
#[derive(Clone, Debug)]
pub struct FormedBatch {
    pub bucket: Bucket,
    pub requests: Vec<PendingRequest>,
}

/// The batcher: per-bucket FIFO queues.
#[derive(Debug)]
pub struct Batcher {
    buckets: Vec<Bucket>, // sorted by seq_len ascending
    queues: Vec<VecDeque<PendingRequest>>,
    cfg: BatcherConfig,
}

impl Batcher {
    /// `buckets` may arrive unsorted; they are sorted by seq_len.
    pub fn new(mut buckets: Vec<Bucket>, cfg: BatcherConfig) -> Self {
        assert!(!buckets.is_empty(), "batcher needs at least one bucket");
        buckets.sort_by_key(|b| b.seq_len);
        let queues = buckets.iter().map(|_| VecDeque::new()).collect();
        Batcher { buckets, queues, cfg }
    }

    /// Bucket index for a request of `len` tokens: smallest bucket with
    /// seq_len ≥ len, else the largest (truncation).
    pub fn route(&self, len: usize) -> usize {
        self.buckets
            .iter()
            .position(|b| b.seq_len >= len)
            .unwrap_or(self.buckets.len() - 1)
    }

    /// Enqueue a request; returns the chosen bucket index.
    pub fn push(&mut self, req: PendingRequest) -> usize {
        let i = self.route(req.tokens.len());
        self.queues[i].push_back(req);
        i
    }

    /// Total queued requests.
    pub fn pending(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Form at most one batch: a full bucket first, else the bucket whose
    /// head has exceeded `max_wait`.
    pub fn poll(&mut self, now: Instant) -> Option<FormedBatch> {
        // full batches first (throughput)
        for (i, b) in self.buckets.iter().enumerate() {
            if self.queues[i].len() >= b.batch {
                return Some(self.take(i, b.batch));
            }
        }
        // deadline flush (latency)
        for (i, _) in self.buckets.iter().enumerate() {
            if let Some(head) = self.queues[i].front() {
                if now.duration_since(head.enqueued) >= self.cfg.max_wait {
                    let n = self.queues[i].len().min(self.buckets[i].batch);
                    return Some(self.take(i, n));
                }
            }
        }
        None
    }

    fn take(&mut self, i: usize, n: usize) -> FormedBatch {
        let requests = self.queues[i].drain(..n).collect();
        FormedBatch { bucket: self.buckets[i].clone(), requests }
    }

    /// The configured buckets (sorted by seq_len).
    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_res;
    use std::time::Duration;

    fn buckets() -> Vec<Bucket> {
        vec![
            Bucket { artifact: "fwd_s512".into(), seq_len: 512, batch: 4 },
            Bucket { artifact: "fwd_s128".into(), seq_len: 128, batch: 8 },
            Bucket { artifact: "fwd_s2048".into(), seq_len: 2048, batch: 2 },
        ]
    }

    fn req(id: u64, len: usize, t: Instant) -> PendingRequest {
        PendingRequest { id, tokens: vec![7; len], enqueued: t }
    }

    #[test]
    fn routes_to_smallest_fitting_bucket() {
        let b = Batcher::new(buckets(), BatcherConfig::default());
        assert_eq!(b.buckets()[b.route(100)].seq_len, 128);
        assert_eq!(b.buckets()[b.route(128)].seq_len, 128);
        assert_eq!(b.buckets()[b.route(129)].seq_len, 512);
        assert_eq!(b.buckets()[b.route(2048)].seq_len, 2048);
        // oversized → largest bucket (truncation)
        assert_eq!(b.buckets()[b.route(9999)].seq_len, 2048);
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let mut b = Batcher::new(buckets(), BatcherConfig::default());
        let t = Instant::now();
        for i in 0..8 {
            b.push(req(i, 100, t));
        }
        let fb = b.poll(t).expect("full batch");
        assert_eq!(fb.bucket.seq_len, 128);
        assert_eq!(fb.requests.len(), 8);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let cfg = BatcherConfig { max_wait: Duration::from_millis(10) };
        let mut b = Batcher::new(buckets(), cfg);
        let t0 = Instant::now();
        b.push(req(1, 400, t0));
        assert!(b.poll(t0).is_none(), "must not flush early");
        let later = t0 + Duration::from_millis(11);
        let fb = b.poll(later).expect("deadline flush");
        assert_eq!(fb.requests.len(), 1);
        assert_eq!(fb.bucket.seq_len, 512);
    }

    #[test]
    fn fifo_within_bucket() {
        let mut b = Batcher::new(buckets(), BatcherConfig::default());
        let t = Instant::now();
        for i in 0..4 {
            b.push(req(i, 300, t));
        }
        let fb = b.poll(t).unwrap();
        let ids: Vec<u64> = fb.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn prop_no_request_lost_or_duplicated() {
        check_res(
            7,
            100,
            |rng| {
                let n = rng.range(1, 60);
                (0..n)
                    .map(|i| (i as u64, rng.range(1, 3000)))
                    .collect::<Vec<_>>()
            },
            |reqs| {
                let mut b = Batcher::new(buckets(), BatcherConfig { max_wait: Duration::ZERO });
                let t = Instant::now();
                for &(id, len) in reqs {
                    b.push(PendingRequest { id, tokens: vec![1; len], enqueued: t });
                }
                let mut seen = std::collections::HashSet::new();
                while let Some(fb) = b.poll(t + Duration::from_millis(1)) {
                    for r in fb.requests {
                        if !seen.insert(r.id) {
                            return Err(format!("request {} duplicated", r.id));
                        }
                        if fb.bucket.seq_len < r.tokens.len()
                            && fb.bucket.seq_len != 2048
                        {
                            return Err(format!(
                                "request {} (len {}) under-bucketed to {}",
                                r.id,
                                r.tokens.len(),
                                fb.bucket.seq_len
                            ));
                        }
                    }
                }
                if seen.len() != reqs.len() {
                    return Err(format!("{} of {} requests drained", seen.len(), reqs.len()));
                }
                if b.pending() != 0 {
                    return Err("queue not empty".into());
                }
                Ok(())
            },
        );
    }
}
