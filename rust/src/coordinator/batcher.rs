//! Length-bucketing dynamic batcher — pure logic, fully unit-testable
//! without PJRT.
//!
//! AOT artifacts have fixed (batch, seq_len) shapes, so the batcher's job
//! is: route each request to the smallest bucket whose seq_len fits,
//! batch up to the bucket's capacity, and flush a partial batch when its
//! oldest request has waited `max_wait`. Requests longer than the largest
//! bucket are truncated to it (the dense-baseline behaviour the paper
//! ridicules — but somebody has to serve those requests too).
//!
//! For the pipelined dispatcher the batcher also carries per-bucket
//! **inflight accounting**: [`Batcher::poll`] marks the formed batch's
//! bucket as having one more batch in flight and skips buckets that are
//! saturated (≥ `max_inflight` dispatched-but-incomplete batches), so a
//! slow long-sequence bucket cannot monopolise the engine pool while
//! short buckets starve. The dispatcher reports completions back via
//! [`Batcher::complete`].

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// One artifact-backed shape bucket.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bucket {
    /// artifact name to execute for this bucket
    pub artifact: String,
    /// padded sequence length
    pub seq_len: usize,
    /// batch capacity baked into the artifact
    pub batch: usize,
}

/// Batcher tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// flush a partial batch when its oldest member waited this long
    pub max_wait: Duration,
    /// per-bucket cap on dispatched-but-incomplete batches; `poll` skips
    /// saturated buckets. `usize::MAX` (the pure-queueing default) means
    /// uncapped — the serving coordinator always overrides this from
    /// [`crate::config::ServingConfig::max_inflight`].
    pub max_inflight: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_wait: Duration::from_millis(10), max_inflight: usize::MAX }
    }
}

/// A queued request (token ids + bookkeeping).
#[derive(Clone, Debug)]
pub struct PendingRequest {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub enqueued: Instant,
    /// Absolute expiry: the router sheds the request `Expired` instead
    /// of dispatching it once this instant has passed (the batcher
    /// itself stays pure FIFO and never inspects it).
    pub deadline: Option<Instant>,
}

/// A formed batch ready for the engine.
#[derive(Clone, Debug)]
pub struct FormedBatch {
    pub bucket: Bucket,
    /// Index of `bucket` in [`Batcher::buckets`] — hand it back to
    /// [`Batcher::complete`] when the batch finishes.
    pub bucket_idx: usize,
    pub requests: Vec<PendingRequest>,
}

/// The batcher: per-bucket FIFO queues + per-bucket inflight counts.
#[derive(Debug)]
pub struct Batcher {
    buckets: Vec<Bucket>, // sorted by seq_len ascending
    queues: Vec<VecDeque<PendingRequest>>,
    inflight: Vec<usize>, // batches dispatched but not yet completed
    cfg: BatcherConfig,
}

impl Batcher {
    /// `buckets` may arrive unsorted; they are sorted by seq_len.
    pub fn new(mut buckets: Vec<Bucket>, cfg: BatcherConfig) -> Self {
        assert!(!buckets.is_empty(), "batcher needs at least one bucket");
        buckets.sort_by_key(|b| b.seq_len);
        let queues = buckets.iter().map(|_| VecDeque::new()).collect();
        let inflight = vec![0; buckets.len()];
        Batcher { buckets, queues, inflight, cfg }
    }

    /// Bucket index for a request of `len` tokens: smallest bucket with
    /// seq_len ≥ len, else the largest (truncation). Binary search over
    /// the sorted bucket bounds — `route` runs once per request, so the
    /// old linear scan was O(buckets) on the accept hot path.
    pub fn route(&self, len: usize) -> usize {
        let i = self.buckets.partition_point(|b| b.seq_len < len);
        i.min(self.buckets.len() - 1)
    }

    /// Enqueue a request; returns the chosen bucket index.
    pub fn push(&mut self, req: PendingRequest) -> usize {
        let i = self.route(req.tokens.len());
        self.queues[i].push_back(req);
        i
    }

    /// Total queued requests.
    pub fn pending(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Form at most one batch from a non-saturated bucket: a full bucket
    /// first, else the bucket whose head has exceeded `max_wait`. The
    /// returned batch counts against its bucket's inflight budget until
    /// [`Batcher::complete`] is called with its `bucket_idx`.
    pub fn poll(&mut self, now: Instant) -> Option<FormedBatch> {
        // full batches first (throughput)
        for (i, b) in self.buckets.iter().enumerate() {
            if self.inflight[i] < self.cfg.max_inflight && self.queues[i].len() >= b.batch {
                return Some(self.take(i, b.batch));
            }
        }
        // deadline flush (latency)
        for i in 0..self.buckets.len() {
            if self.inflight[i] >= self.cfg.max_inflight {
                continue;
            }
            if let Some(head) = self.queues[i].front() {
                if now.duration_since(head.enqueued) >= self.cfg.max_wait {
                    let n = self.queues[i].len().min(self.buckets[i].batch);
                    return Some(self.take(i, n));
                }
            }
        }
        None
    }

    /// A batch formed from bucket `bucket_idx` finished (successfully or
    /// not): release its inflight slot so `poll` may dispatch the next.
    pub fn complete(&mut self, bucket_idx: usize) {
        self.inflight[bucket_idx] = self.inflight[bucket_idx].saturating_sub(1);
    }

    /// Batches currently dispatched-but-incomplete for bucket `i`.
    pub fn bucket_inflight(&self, i: usize) -> usize {
        self.inflight[i]
    }

    /// Total batches currently dispatched-but-incomplete.
    pub fn inflight(&self) -> usize {
        self.inflight.iter().sum()
    }

    fn take(&mut self, i: usize, n: usize) -> FormedBatch {
        let requests = self.queues[i].drain(..n).collect();
        self.inflight[i] += 1;
        FormedBatch { bucket: self.buckets[i].clone(), bucket_idx: i, requests }
    }

    /// The configured buckets (sorted by seq_len).
    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check_res;
    use std::time::Duration;

    fn buckets() -> Vec<Bucket> {
        vec![
            Bucket { artifact: "fwd_s512".into(), seq_len: 512, batch: 4 },
            Bucket { artifact: "fwd_s128".into(), seq_len: 128, batch: 8 },
            Bucket { artifact: "fwd_s2048".into(), seq_len: 2048, batch: 2 },
        ]
    }

    fn req(id: u64, len: usize, t: Instant) -> PendingRequest {
        PendingRequest { id, tokens: vec![7; len], enqueued: t, deadline: None }
    }

    #[test]
    fn routes_to_smallest_fitting_bucket() {
        let b = Batcher::new(buckets(), BatcherConfig::default());
        assert_eq!(b.buckets()[b.route(100)].seq_len, 128);
        assert_eq!(b.buckets()[b.route(128)].seq_len, 128);
        assert_eq!(b.buckets()[b.route(129)].seq_len, 512);
        assert_eq!(b.buckets()[b.route(2048)].seq_len, 2048);
        // oversized → largest bucket (truncation)
        assert_eq!(b.buckets()[b.route(9999)].seq_len, 2048);
    }

    #[test]
    fn route_boundaries_match_linear_scan() {
        // pin the boundary behaviour of the binary search: exact bucket
        // bounds, bound±1, zero-length, and beyond-largest all agree
        // with the reference linear scan
        let b = Batcher::new(buckets(), BatcherConfig::default());
        let linear = |len: usize| {
            b.buckets().iter().position(|bk| bk.seq_len >= len).unwrap_or(b.buckets().len() - 1)
        };
        for len in [0, 1, 127, 128, 129, 511, 512, 513, 2047, 2048, 2049, 9999] {
            assert_eq!(b.route(len), linear(len), "len {len}");
        }
        // explicit pins so a regression in *both* paths still fails
        assert_eq!(b.route(0), 0);
        assert_eq!(b.route(128), 0);
        assert_eq!(b.route(129), 1);
        assert_eq!(b.route(2048), 2);
        assert_eq!(b.route(2049), 2); // truncation bucket
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let mut b = Batcher::new(buckets(), BatcherConfig::default());
        let t = Instant::now();
        for i in 0..8 {
            b.push(req(i, 100, t));
        }
        let fb = b.poll(t).expect("full batch");
        assert_eq!(fb.bucket.seq_len, 128);
        assert_eq!(fb.requests.len(), 8);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let cfg = BatcherConfig { max_wait: Duration::from_millis(10), ..Default::default() };
        let mut b = Batcher::new(buckets(), cfg);
        let t0 = Instant::now();
        b.push(req(1, 400, t0));
        assert!(b.poll(t0).is_none(), "must not flush early");
        let later = t0 + Duration::from_millis(11);
        let fb = b.poll(later).expect("deadline flush");
        assert_eq!(fb.requests.len(), 1);
        assert_eq!(fb.bucket.seq_len, 512);
    }

    #[test]
    fn fifo_within_bucket() {
        let mut b = Batcher::new(buckets(), BatcherConfig::default());
        let t = Instant::now();
        for i in 0..4 {
            b.push(req(i, 300, t));
        }
        let fb = b.poll(t).unwrap();
        let ids: Vec<u64> = fb.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn prop_no_request_lost_or_duplicated() {
        check_res(
            7,
            100,
            |rng| {
                let n = rng.range(1, 60);
                (0..n)
                    .map(|i| (i as u64, rng.range(1, 3000)))
                    .collect::<Vec<_>>()
            },
            |reqs| {
                let mut b = Batcher::new(
                    buckets(),
                    BatcherConfig { max_wait: Duration::ZERO, ..Default::default() },
                );
                // the truncation bucket is whatever bucket is largest —
                // derived, so the property survives bucket-set changes
                let largest = b.buckets().last().expect("nonempty").seq_len;
                let t = Instant::now();
                for &(id, len) in reqs {
                    b.push(PendingRequest { id, tokens: vec![1; len], enqueued: t, deadline: None });
                }
                let mut seen = std::collections::HashSet::new();
                while let Some(fb) = b.poll(t + Duration::from_millis(1)) {
                    for r in fb.requests {
                        if !seen.insert(r.id) {
                            return Err(format!("request {} duplicated", r.id));
                        }
                        if fb.bucket.seq_len < r.tokens.len()
                            && fb.bucket.seq_len != largest
                        {
                            return Err(format!(
                                "request {} (len {}) under-bucketed to {}",
                                r.id,
                                r.tokens.len(),
                                fb.bucket.seq_len
                            ));
                        }
                    }
                }
                if seen.len() != reqs.len() {
                    return Err(format!("{} of {} requests drained", seen.len(), reqs.len()));
                }
                if b.pending() != 0 {
                    return Err("queue not empty".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn saturated_bucket_is_skipped_until_completion() {
        let cfg = BatcherConfig { max_wait: Duration::ZERO, max_inflight: 1 };
        let mut b = Batcher::new(buckets(), cfg);
        let t = Instant::now();
        for i in 0..16 {
            b.push(req(i, 100, t)); // bucket 128, batch 8
        }
        let later = t + Duration::from_millis(1);
        let fb1 = b.poll(later).expect("first full batch");
        assert_eq!(fb1.bucket.seq_len, 128);
        assert_eq!(b.bucket_inflight(fb1.bucket_idx), 1);
        // bucket saturated: 8 more queued requests must wait
        assert!(b.poll(later).is_none(), "saturated bucket must be skipped");
        assert_eq!(b.pending(), 8);
        // ...but other buckets still dispatch while 128 is saturated
        b.push(req(100, 400, t)); // bucket 512
        let fb2 = b.poll(later).expect("other bucket dispatches");
        assert_eq!(fb2.bucket.seq_len, 512);
        // completing the first batch frees the slot, FIFO preserved
        b.complete(fb1.bucket_idx);
        let fb3 = b.poll(later).expect("slot freed");
        assert_eq!(fb3.bucket.seq_len, 128);
        let ids: Vec<u64> = fb3.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, (8..16).collect::<Vec<u64>>());
        assert_eq!(b.inflight(), 2);
        assert_eq!(b.pending(), 0);
    }
}
