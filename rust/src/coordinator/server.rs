//! The serving front-end: ties the router/batcher loop to the engine.
//!
//! Single-inflight design (the vLLM engine-step loop): the router forms a
//! batch, executes it on the engine, distributes responses, repeats.
//! Requests keep accumulating in the batcher while a batch is in flight,
//! so throughput comes from batching, and latency from the flush
//! deadline.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::{Batcher, BatcherConfig, Bucket, PendingRequest};
use super::engine::EngineHandle;
use super::metrics::{MetricsSnapshot, ServingMetrics};
use crate::runtime::HostTensor;
use crate::tokenizer::special;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// artifact directory
    pub artifacts: String,
    /// manifest metadata filters selecting the serving buckets
    /// (e.g. `kind=fwd`, `task=mlm`, `attn=bigbird_itc`)
    pub bucket_filters: Vec<(String, String)>,
    pub batcher: BatcherConfig,
    /// submission queue depth (backpressure bound)
    pub queue_depth: usize,
}

impl ServerConfig {
    /// Serve MLM fill-mask with the BigBird variant (the demo workload).
    pub fn mlm_default(artifacts: &str) -> Self {
        ServerConfig {
            artifacts: artifacts.to_string(),
            bucket_filters: vec![
                ("kind".into(), "fwd".into()),
                ("task".into(), "mlm".into()),
                ("attn".into(), "bigbird_itc".into()),
                ("impl".into(), "jnp".into()),
            ],
            batcher: BatcherConfig::default(),
            queue_depth: 256,
        }
    }
}

/// A completed fill-mask response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// (position, predicted token id) at each `<mask>` position
    pub predictions: Vec<(usize, i32)>,
    pub latency_ms: f64,
    /// true if the request was truncated to the largest bucket
    pub truncated: bool,
}

struct Submission {
    req: PendingRequest,
    reply: Sender<Response>,
}

/// Running server handle.
pub struct Server {
    tx: SyncSender<Submission>,
    next_id: AtomicU64,
    metrics: Arc<ServingMetrics>,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl Server {
    /// Start the engine + router threads. Blocks until the engine has
    /// compiled nothing yet (lazy) but has loaded the manifest.
    pub fn start(cfg: ServerConfig) -> Result<Self> {
        let engine = EngineHandle::spawn(cfg.artifacts.clone(), cfg.queue_depth)?;
        // discover buckets from the manifest (router side reads it too)
        let manifest = crate::runtime::Manifest::load(&cfg.artifacts)?;
        let filters: Vec<(&str, &str)> = cfg
            .bucket_filters
            .iter()
            .map(|(k, v)| (k.as_str(), v.as_str()))
            .collect();
        let buckets: Vec<Bucket> = manifest
            .select(&filters)
            .into_iter()
            .map(|e| {
                let seq_len = e.meta_usize("seq_len").unwrap_or(0);
                let batch = e.meta_usize("batch").unwrap_or(1);
                Bucket { artifact: e.name.clone(), seq_len, batch }
            })
            .collect();
        if buckets.is_empty() {
            anyhow::bail!("no artifacts match the bucket filters {filters:?}");
        }
        // vocab for logits decoding, from the first bucket's fwd output
        let vocab = manifest
            .get(&buckets[0].artifact)?
            .io
            .outputs
            .first()
            .map(|o| *o.dims.last().unwrap_or(&0))
            .context("fwd artifact has no output")?;

        let (tx, rx): (SyncSender<Submission>, Receiver<Submission>) =
            sync_channel(cfg.queue_depth);
        let metrics = Arc::new(ServingMetrics::default());
        let stop = Arc::new(AtomicBool::new(false));
        let m2 = metrics.clone();
        let stop2 = stop.clone();
        let batcher_cfg = cfg.batcher;
        let join = std::thread::Builder::new()
            .name("bigbird-router".into())
            .spawn(move || {
                router_loop(rx, engine, buckets, batcher_cfg, vocab, m2, stop2);
            })
            .context("spawning router")?;
        Ok(Server { tx, next_id: AtomicU64::new(1), metrics, stop, join: Some(join) })
    }

    /// Submit a fill-mask request. Returns the response channel.
    pub fn submit(&self, tokens: Vec<i32>) -> Result<Receiver<Response>> {
        let (reply, rx) = std::sync::mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Submission {
                req: PendingRequest { id, tokens, enqueued: Instant::now() },
                reply,
            })
            .context("server stopped")?;
        Ok(rx)
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Warm up: submit one dummy request per length (compiling each
    /// bucket's artifact + initialising params), wait for completion,
    /// then reset metrics so measurements exclude compilation.
    pub fn warmup(&self, lens: &[usize]) -> Result<()> {
        let mut rxs = Vec::new();
        for &len in lens {
            rxs.push(self.submit(vec![crate::tokenizer::special::CLS; len.max(1)])?);
        }
        for rx in rxs {
            rx.recv().map_err(|_| anyhow::anyhow!("warmup request dropped"))?;
        }
        self.metrics.reset();
        Ok(())
    }

    /// Stop the router (drains nothing; pending requests get dropped).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        drop(self.tx.clone()); // router wakes on channel activity or timeout
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn router_loop(
    rx: Receiver<Submission>,
    engine: EngineHandle,
    buckets: Vec<Bucket>,
    batcher_cfg: BatcherConfig,
    vocab: usize,
    metrics: Arc<ServingMetrics>,
    stop: Arc<AtomicBool>,
) {
    let mut batcher = Batcher::new(buckets, batcher_cfg);
    let mut replies: std::collections::HashMap<u64, Sender<Response>> =
        std::collections::HashMap::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        // drain the submission channel without blocking too long
        let deadline = Duration::from_millis(2);
        match rx.recv_timeout(deadline) {
            Ok(sub) => {
                replies.insert(sub.req.id, sub.reply);
                batcher.push(sub.req);
                // opportunistically drain more
                loop {
                    match rx.try_recv() {
                        Ok(s) => {
                            replies.insert(s.req.id, s.reply);
                            batcher.push(s.req);
                        }
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => break,
                    }
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                if batcher.pending() == 0 {
                    return;
                }
            }
        }
        while let Some(fb) = batcher.poll(Instant::now()) {
            run_batch(&engine, fb, vocab, &metrics, &mut replies);
        }
    }
}

fn run_batch(
    engine: &EngineHandle,
    fb: super::batcher::FormedBatch,
    vocab: usize,
    metrics: &ServingMetrics,
    replies: &mut std::collections::HashMap<u64, Sender<Response>>,
) {
    let b = fb.bucket.batch;
    let s = fb.bucket.seq_len;
    let mut tokens = vec![special::PAD; b * s];
    let mut kv_valid = vec![0f32; b * s];
    let mut truncated = vec![false; fb.requests.len()];
    for (row, req) in fb.requests.iter().enumerate() {
        let n = req.tokens.len().min(s);
        truncated[row] = req.tokens.len() > s;
        tokens[row * s..row * s + n].copy_from_slice(&req.tokens[..n]);
        for v in kv_valid[row * s..row * s + n].iter_mut() {
            *v = 1.0;
        }
    }
    metrics.record_batch(fb.requests.len(), b);
    let inputs = vec![
        HostTensor::I32 { shape: vec![b, s], data: tokens.clone() },
        HostTensor::F32 { shape: vec![b, s], data: kv_valid },
    ];
    // the fwd artifact signature is (params, tokens, kv_valid) — the
    // engine owns the params; serving artifacts are wrapped to take
    // (tokens, kv_valid) only when params are baked... our fwd artifacts
    // take params explicitly, so the server keeps a parameter store.
    let result = engine.execute_with_params(&fb.bucket.artifact, inputs);
    match result {
        Ok(outs) => {
            let logits = match &outs[0] {
                HostTensor::F32 { data, .. } => data,
                _ => {
                    metrics.record_error();
                    return;
                }
            };
            for (row, req) in fb.requests.iter().enumerate() {
                let mut preds = Vec::new();
                for (pos, &t) in req.tokens.iter().take(s).enumerate() {
                    if t == special::MASK {
                        let base = (row * s + pos) * vocab;
                        let row_logits = &logits[base..base + vocab];
                        let mut best = 0usize;
                        for (j, &x) in row_logits.iter().enumerate() {
                            if x > row_logits[best] {
                                best = j;
                            }
                        }
                        preds.push((pos, best as i32));
                    }
                }
                let lat = req.enqueued.elapsed().as_secs_f64() * 1000.0;
                metrics.record_latency(lat);
                if truncated[row] {
                    metrics.record_truncated();
                }
                if let Some(tx) = replies.remove(&req.id) {
                    let _ = tx.send(Response {
                        id: req.id,
                        predictions: preds,
                        latency_ms: lat,
                        truncated: truncated[row],
                    });
                }
            }
        }
        Err(e) => {
            eprintln!("[server] batch failed: {e:#}");
            metrics.record_error();
            for req in &fb.requests {
                replies.remove(&req.id);
            }
        }
    }
}

// Per-thread parameter store for fwd artifacts. The router thread is the
// only user in practice; tests drive it from their own thread, which gets
// an independent (but equally valid) cache.
thread_local! {
    static PARAMS_CACHE: std::cell::RefCell<std::collections::HashMap<String, HostTensor>> =
        std::cell::RefCell::new(std::collections::HashMap::new());
}

impl EngineHandle {
    /// Execute a fwd artifact, prepending its cached parameters
    /// (initialised from the matching `init_*` artifact on first use, or
    /// whatever [`EngineHandle::load_params`] installed).
    pub fn execute_with_params(
        &self,
        fwd_artifact: &str,
        mut inputs: Vec<HostTensor>,
    ) -> Result<Vec<HostTensor>> {
        let params = self.params_for(fwd_artifact)?;
        let mut all = Vec::with_capacity(1 + inputs.len());
        all.push(params);
        all.append(&mut inputs);
        self.execute(fwd_artifact, all)
    }

    fn params_for(&self, fwd_artifact: &str) -> Result<HostTensor> {
        if let Some(p) =
            PARAMS_CACHE.with(|c| c.borrow().get(fwd_artifact).cloned())
        {
            return Ok(p);
        }
        let init_name = fwd_artifact.replacen("fwd_", "init_", 1);
        let mut out = self.execute(&init_name, vec![])?;
        let p = out.remove(0);
        PARAMS_CACHE.with(|c| {
            c.borrow_mut().insert(fwd_artifact.to_string(), p.clone());
        });
        Ok(p)
    }

    /// Install trained parameters for a fwd artifact (e.g. from a
    /// checkpoint) so subsequent batches serve the trained model.
    /// Thread-local: call from the thread that will execute batches.
    pub fn load_params(&self, fwd_artifact: &str, params: HostTensor) {
        PARAMS_CACHE.with(|c| {
            c.borrow_mut().insert(fwd_artifact.to_string(), params);
        });
    }
}
