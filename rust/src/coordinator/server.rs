//! The serving front-end: a pipelined dispatch/completion state machine
//! over the engine pool, fronted by one admission-controlled submission
//! path shared by every caller.
//!
//! The router thread runs three overlapped stages (the ones
//! `experiments/hotpath.rs` times): it **accepts** submissions into the
//! length-bucketing batcher, **dispatches** every formable batch to the
//! engine worker with the minimum expected completion time under the
//! per-backend roofline cost model (bounded per bucket by
//! `ServingConfig::max_inflight`), and **completes** finished batches —
//! decoding logits and answering each request's reply channel — while
//! other batches are still executing. On a homogeneous pool the cost
//! model scores every worker identically, so dispatch weighs queued
//! *work* instead of queued batch counts — on uniform single-bucket
//! traffic that is exactly PR 1's least-loaded policy (mixed bucket
//! sizes may place batches differently, with identical responses); with
//! one CPU worker and `max_inflight: 1` it degenerates to the original
//! single-inflight loop (same responses, FIFO within bucket).
//!
//! **Admission is synchronous and caller-side**: [`Client::submit_with`]
//! runs [`AdmissionState::try_admit`] before anything reaches the
//! router, so a shed request is answered with a typed
//! [`Outcome::Shed`] immediately — no queue entry, no router hop — and
//! the TCP ingress and the in-process path exercise the exact same gate
//! and the exact same accounting. Every admitted request is answered
//! exactly once through the router's single `finish` path (completion,
//! execution error, or dispatch-time expiry), which is also the only
//! place admission slots are released.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError,
};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::admission::{AdmissionState, ClientRate};
use super::api::{Outcome, Request, Response, ShedReason};
use super::batcher::{Batcher, BatcherConfig, Bucket, FormedBatch, PendingRequest};
use super::engine::{EnginePool, PoolCompletion, PoolJob};
use super::metrics::{MetricsSnapshot, ServingMetrics};
use crate::config::{AdmissionConfig, ModelConfig, ObsConfig, ServingConfig};
use crate::kernel;
use crate::obs::export::{self, ExportMeta};
use crate::obs::log::Level;
use crate::obs::timeseries::{render_series_json, SamplerState, SeriesRing, SeriesSample};
use crate::obs::trace::{self, SpanKind};
use crate::obs::watchdog::{self, FlightRecorder, Health, HealthReport};
use crate::runtime::{BackendKind, HostTensor, JobShape, Manifest};
use crate::tokenizer::special;
use crate::util::decode;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// artifact directory
    pub artifacts: String,
    /// manifest metadata filters selecting the serving buckets
    /// (e.g. `kind=fwd`, `task=mlm`, `attn=bigbird_itc`)
    pub bucket_filters: Vec<(String, String)>,
    pub batcher: BatcherConfig,
    /// submission queue depth (backpressure bound)
    pub queue_depth: usize,
    /// engine-pool shape: worker count + per-bucket inflight cap
    pub serving: ServingConfig,
    /// admission-control policy (queue bound, latency budget, client cap)
    pub admission: AdmissionConfig,
    /// model family the native kernel backend serves when the pool
    /// contains `native` workers (seq_len/batch are per-bucket)
    pub native: ModelConfig,
    /// optional `BBCKPT1` checkpoint written by `train --backends
    /// native`: loaded, fingerprint-validated against `native`, and
    /// installed on every worker at startup so the pool serves the
    /// trained weights (requires a native worker in the pool)
    pub native_checkpoint: Option<String>,
    /// observability switches: request tracing ring + kernel-phase
    /// profiling (both off by default — the hot paths then pay one
    /// relaxed atomic load per site)
    pub obs: ObsConfig,
}

impl ServerConfig {
    /// Serve MLM fill-mask with the BigBird variant (the demo workload).
    pub fn mlm_default(artifacts: &str) -> Self {
        ServerConfig {
            artifacts: artifacts.to_string(),
            bucket_filters: vec![
                ("kind".into(), "fwd".into()),
                ("task".into(), "mlm".into()),
                ("attn".into(), "bigbird_itc".into()),
                ("impl".into(), "jnp".into()),
            ],
            batcher: BatcherConfig::default(),
            queue_depth: 256,
            serving: ServingConfig::default(),
            admission: AdmissionConfig::default(),
            native: ModelConfig::native_serving(),
            native_checkpoint: None,
            obs: ObsConfig::default(),
        }
    }
}

enum Submission {
    Request {
        req: PendingRequest,
        entry: ReplyEntry,
    },
    /// Warm the given artifacts on every pool worker; each worker acks
    /// once on `done`.
    Warmup {
        artifacts: Vec<String>,
        done: Sender<std::result::Result<(), String>>,
    },
}

/// Everything the router needs to answer one admitted request: the
/// caller-facing id, the reply channel, and the client bookkeeping
/// (label for metrics, inflight cell for admission release).
struct ReplyEntry {
    wire_id: u64,
    reply: Sender<Response>,
    label: Arc<String>,
    inflight: Arc<AtomicUsize>,
    /// When the request entered the serving stack (frame-decode start
    /// for wire submissions) — the root span's anchor.
    t0: Instant,
}

/// State shared between the server handle, its clients, and the router.
struct Shared {
    tx: SyncSender<Submission>,
    next_id: AtomicU64,
    admission: Arc<AdmissionState>,
    metrics: Arc<ServingMetrics>,
}

/// Continuous-telemetry state shared between the server handle, the
/// sampler thread, and the ingress scrape paths. The ring and health
/// ledger exist even with the sampler off (scrapes then see an empty
/// series and a healthy report) so the scrape surfaces never change
/// shape with configuration.
struct ObsShared {
    ring: SeriesRing,
    health: Health,
    /// Server start — the anchor for `uptime_seconds` and sample
    /// timestamps.
    started: Instant,
    sampler_interval_s: f64,
    /// Dotted [`kernel::config_fingerprint`] of the native model config
    /// (the `bigbird_model_info` label).
    fingerprint: String,
    slo_p99_ms: Option<f64>,
}

/// Running server handle.
pub struct Server {
    shared: Arc<Shared>,
    /// The in-process submission identity ([`Server::submit`] routes
    /// through it), labelled `local` in per-client metrics.
    local: Client,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
    sampler_join: Option<JoinHandle<()>>,
    obs: Arc<ObsShared>,
    /// serving buckets, sorted by seq_len (for warmup routing)
    buckets: Vec<Bucket>,
    workers: usize,
}

/// A submission identity: one admission bookkeeping unit (its own
/// inflight count against `max_client_inflight`, its own metrics rows).
/// The TCP ingress creates one per connection; in-process callers get
/// one from [`Server::client`]. Clones share the identity.
#[derive(Clone)]
pub struct Client {
    shared: Arc<Shared>,
    label: Arc<String>,
    inflight: Arc<AtomicUsize>,
    /// Sliding-window submission rate (ticked on every submit,
    /// admitted or shed); surfaced as the `req_per_s` metrics gauge.
    rate: Arc<ClientRate>,
}

/// What [`Client::submit_traced`] hands back: the id the response will
/// carry, plus the internal trace id its spans are recorded under.
#[derive(Clone, Copy, Debug)]
pub struct SubmitTicket {
    /// Caller-facing response id (the request's own id when nonzero).
    pub wire_id: u64,
    /// Trace id of this request's span tree (the internal request id).
    pub trace_id: u64,
}

impl Client {
    /// Submit a typed request; the response arrives on the returned
    /// channel (exactly one [`Response`] per request — completed, shed,
    /// or error).
    pub fn submit(&self, req: Request) -> Result<Receiver<Response>> {
        let (reply, rx) = channel();
        self.submit_with(req, reply)?;
        Ok(rx)
    }

    /// Submit with a caller-owned reply channel (the ingress funnels
    /// every response of a connection into one writer this way).
    /// Returns the id the response will carry. Admission runs *here*,
    /// synchronously: a shed request is answered on `reply` before this
    /// returns and never reaches the router.
    pub fn submit_with(&self, req: Request, reply: Sender<Response>) -> Result<u64> {
        Ok(self.submit_traced(req, reply, Instant::now())?.wire_id)
    }

    /// [`Client::submit_with`] with an explicit trace anchor: `t0` is
    /// when the request entered the stack (the ingress passes its
    /// frame-decode start, so the root span covers decode + admission
    /// + everything after). Records the admission span here — and, on
    /// a shed, the whole (two-span) trace — under the internal request
    /// id returned in the ticket.
    pub fn submit_traced(
        &self,
        req: Request,
        reply: Sender<Response>,
        t0: Instant,
    ) -> Result<SubmitTicket> {
        let internal = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        let wire_id = if req.id != 0 { req.id } else { internal };
        self.rate.observe();
        self.shared.metrics.record_client_rate(&self.label, self.rate.req_per_s());
        let verdict = self.shared.admission.try_admit(req.priority, req.deadline, &self.inflight);
        if trace::enabled() {
            trace::span(SpanKind::Admission, internal, t0, Instant::now(), verdict.is_err() as u64);
        }
        if let Err(reason) = verdict {
            self.shared.metrics.record_shed(&self.label, reason);
            let _ = reply.send(Response {
                id: wire_id,
                outcome: Outcome::Shed { reason },
                latency_ms: 0.0,
            });
            if trace::enabled() {
                trace::span(SpanKind::Request, internal, t0, Instant::now(), wire_id);
            }
            return Ok(SubmitTicket { wire_id, trace_id: internal });
        }
        self.shared.metrics.record_admitted(&self.label);
        let enqueued = Instant::now();
        let pending = PendingRequest {
            id: internal,
            tokens: req.tokens,
            enqueued,
            deadline: req.deadline.map(|d| enqueued + d),
        };
        let entry = ReplyEntry {
            wire_id,
            reply,
            label: self.label.clone(),
            inflight: self.inflight.clone(),
            t0,
        };
        if self.shared.tx.send(Submission::Request { req: pending, entry }).is_err() {
            // router gone: undo the admission so counters stay balanced
            self.shared.admission.release(&self.inflight);
            if trace::enabled() {
                // close the trace so the admission span is never orphaned
                trace::span(SpanKind::Request, internal, t0, Instant::now(), wire_id);
            }
            anyhow::bail!("server stopped");
        }
        Ok(SubmitTicket { wire_id, trace_id: internal })
    }

    /// This client's label in per-client metrics.
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Requests this client has admitted-but-unanswered right now.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }
}

impl Server {
    /// Start the engine pool + router thread.
    ///
    /// Bucket selection depends on the pool shape: when the pool
    /// contains any `native` worker the server serves the **native
    /// kernel pipeline** — buckets synthesized from
    /// `ServerConfig::native` (every worker, PJRT or native, can
    /// execute them in-process), and the artifact manifest is optional
    /// (an absent `manifest.txt` degrades to an empty manifest instead
    /// of an error, so `--backends native:2` works on a bare checkout
    /// with zero PJRT artifacts). Pure-PJRT pools keep the original
    /// behaviour: buckets from the manifest's metadata filters, parsed
    /// once and shared with every worker; artifacts compile lazily on
    /// first use (or eagerly via [`Server::warmup`]).
    pub fn start(cfg: ServerConfig) -> Result<Self> {
        cfg.serving.validate()?;
        cfg.admission.validate()?;
        cfg.obs.validate()?;
        // process-wide switches: sticky across servers in one process
        // (tests that start tracing servers isolate by trace-id range)
        if cfg.obs.trace {
            trace::enable(cfg.obs.trace_ring);
        }
        if cfg.obs.phase_profile {
            crate::obs::phase::set_enabled(true);
        }
        let any_native = cfg.serving.backends.iter().any(|b| b.kind == BackendKind::Native);
        let manifest_present = std::path::Path::new(&cfg.artifacts).join("manifest.txt").exists();
        let (manifest, mut buckets, vocab) = if any_native {
            let manifest = if manifest_present {
                Arc::new(Manifest::load(&cfg.artifacts)?)
            } else {
                Arc::new(Manifest::default())
            };
            let buckets: Vec<Bucket> = kernel::native_buckets()
                .into_iter()
                .map(|(seq_len, batch)| Bucket {
                    artifact: kernel::native_artifact_name(seq_len, batch),
                    seq_len,
                    batch,
                })
                .collect();
            (manifest, buckets, cfg.native.vocab)
        } else {
            let manifest = Arc::new(Manifest::load(&cfg.artifacts)?);
            let filters: Vec<(&str, &str)> = cfg
                .bucket_filters
                .iter()
                .map(|(k, v)| (k.as_str(), v.as_str()))
                .collect();
            let buckets: Vec<Bucket> = manifest
                .select(&filters)
                .into_iter()
                .map(|e| {
                    let seq_len = e.meta_usize("seq_len").unwrap_or(0);
                    let batch = e.meta_usize("batch").unwrap_or(1);
                    Bucket { artifact: e.name.clone(), seq_len, batch }
                })
                .collect();
            if buckets.is_empty() {
                anyhow::bail!("no artifacts match the bucket filters {filters:?}");
            }
            // vocab for logits decoding, from the first fwd output
            let first = buckets.iter().min_by_key(|b| b.seq_len).expect("nonempty buckets");
            let vocab = manifest
                .get(&first.artifact)?
                .io
                .outputs
                .first()
                .map(|o| *o.dims.last().unwrap_or(&0))
                .context("fwd artifact has no output")?;
            (manifest, buckets, vocab)
        };
        buckets.sort_by_key(|b| b.seq_len);

        let pool = EnginePool::spawn_with_native(
            manifest.clone(),
            &cfg.serving.backends,
            cfg.queue_depth,
            cfg.native.clone(),
        )?;
        // install trained native parameters before any traffic: a bad
        // checkpoint fails startup loudly instead of serving seed (or
        // worse, stale) weights
        if let Some(ckpt_path) = &cfg.native_checkpoint {
            anyhow::ensure!(
                any_native,
                "native checkpoint {ckpt_path:?} requires a native worker in the pool \
                 (use --backends native:N)"
            );
            let ckpt = crate::train::load_native_checkpoint(
                std::path::Path::new(ckpt_path),
                &cfg.native,
            )
            .with_context(|| format!("loading native checkpoint {ckpt_path:?}"))?;
            let n = ckpt.params.len();
            let tensor = HostTensor::f32(&[n], ckpt.params)?;
            pool.load_params(kernel::NATIVE_PARAMS_ARTIFACT, &tensor)
                .with_context(|| format!("installing native checkpoint {ckpt_path:?}"))?;
            crate::log!(
                Level::Info,
                "server",
                "serving trained native checkpoint {ckpt_path} ({n} params, step {})",
                ckpt.step
            );
        }
        let (tx, rx): (SyncSender<Submission>, Receiver<Submission>) =
            sync_channel(cfg.queue_depth);
        let metrics = Arc::new(ServingMetrics::default());
        let worker_labels: Vec<String> = pool.backends().iter().map(|b| b.label()).collect();
        metrics.set_worker_backends(&worker_labels);
        let worker_kinds: Vec<BackendKind> = pool.backends().iter().map(|b| b.kind).collect();
        if cfg.obs.phase_profile {
            // declare the roofline denominator for instrumented (native)
            // backends: phase busy time sums across kernel threads, so
            // the comparable peak is the machine roofline per core
            if let Some(label) = worker_labels
                .iter()
                .zip(worker_kinds.iter())
                .find(|(_, &k)| k == BackendKind::Native)
                .map(|(l, _)| l)
            {
                let threads = kernel::KernelPool::global().threads().max(1);
                let peak = kernel::native_roofline().gflops / threads as f64;
                metrics.set_backend_peak(label, peak);
            }
        }
        let admission = Arc::new(AdmissionState::new(cfg.admission));
        let stop = Arc::new(AtomicBool::new(false));
        let sampler_interval_s = cfg.obs.sampler_interval_ms as f64 * 1e-3;
        let fingerprint: String = kernel::config_fingerprint(&cfg.native)
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join(".");
        metrics.set_scrape_identity(sampler_interval_s, fingerprint.clone());
        let obs = Arc::new(ObsShared {
            ring: SeriesRing::new(cfg.obs.series_capacity),
            health: Health::new(),
            started: Instant::now(),
            sampler_interval_s,
            fingerprint,
            slo_p99_ms: cfg.obs.slo_p99_ms,
        });
        let m2 = metrics.clone();
        let adm2 = admission.clone();
        let stop2 = stop.clone();
        let mut batcher_cfg = cfg.batcher;
        batcher_cfg.max_inflight = cfg.serving.max_inflight;
        let router_buckets = buckets.clone();
        // fault injection for the watchdog's stall path: accept and
        // admit but never dispatch, so outstanding grows while
        // completions and worker jobs stay at zero
        let fault_stall = cfg.obs.fault_stall;
        if fault_stall {
            crate::log!(
                Level::Warn,
                "server",
                "fault injection active: dispatch disabled (--fault stall)"
            );
        }
        let join = std::thread::Builder::new()
            .name("bigbird-router".into())
            .spawn(move || {
                let st = RouterState::new(
                    pool,
                    router_buckets,
                    worker_kinds,
                    batcher_cfg,
                    vocab,
                    m2,
                    adm2,
                );
                router_loop(rx, st, stop2, fault_stall);
            })
            .context("spawning router")?;
        let sampler_join = if cfg.obs.sampler_interval_ms > 0 {
            let obs2 = obs.clone();
            let m3 = metrics.clone();
            let adm3 = admission.clone();
            let stop3 = stop.clone();
            let recorder = cfg.obs.flight_dir.as_ref().map(|d| FlightRecorder::new(d.as_str()));
            let interval = Duration::from_millis(cfg.obs.sampler_interval_ms);
            Some(
                std::thread::Builder::new()
                    .name("bigbird-sampler".into())
                    .spawn(move || sampler_loop(obs2, m3, adm3, recorder, interval, stop3))
                    .context("spawning sampler")?,
            )
        } else {
            None
        };
        let shared =
            Arc::new(Shared { tx, next_id: AtomicU64::new(1), admission, metrics });
        let local = Client {
            shared: shared.clone(),
            label: Arc::new("local".to_string()),
            inflight: Arc::new(AtomicUsize::new(0)),
            rate: Arc::new(ClientRate::new()),
        };
        Ok(Server {
            shared,
            local,
            stop,
            join: Some(join),
            sampler_join,
            obs,
            buckets,
            workers: cfg.serving.n_workers(),
        })
    }

    /// Submit a typed request through the in-process `local` client.
    pub fn submit(&self, req: Request) -> Result<Receiver<Response>> {
        self.local.submit(req)
    }

    /// Create a new submission identity (per-client admission cap and
    /// metrics rows). The TCP ingress makes one per connection, labelled
    /// by peer address.
    pub fn client(&self, label: &str) -> Client {
        Client {
            shared: self.shared.clone(),
            label: Arc::new(label.to_string()),
            inflight: Arc::new(AtomicUsize::new(0)),
            rate: Arc::new(ClientRate::new()),
        }
    }

    /// Metrics snapshot (admission gauges and the kernel-phase profile
    /// refreshed first, so `queue_ewma_ms` / `peak_outstanding` /
    /// `kernel_phases` / `backend_roofline` are current).
    pub fn metrics(&self) -> MetricsSnapshot {
        let adm = &self.shared.admission;
        self.shared.metrics.set_admission_gauges(adm.ewma_wait_ms(), adm.peak_outstanding());
        if crate::obs::phase::enabled() {
            self.shared.metrics.set_kernel_phases(crate::obs::phase::snapshot());
        }
        self.shared.metrics.snapshot()
    }

    /// Chrome trace-event JSON of every span recorded so far (an empty
    /// `traceEvents` array while tracing is disabled) — the payload of
    /// the wire `trace` request and of `serve --trace-out`.
    pub fn trace_json(&self) -> String {
        trace::export_chrome_json()
    }

    /// The serialized metrics snapshot — the payload the wire `metrics`
    /// request returns and `serve_demo` prints.
    pub fn metrics_json(&self) -> String {
        self.metrics().to_json()
    }

    /// Prometheus text exposition of the full metric surface, validated
    /// by the strict self-parser before it is returned — a malformed
    /// export is an `Err` here (the ingress turns it into a 500), never
    /// a quietly-broken 200. This is the payload of wire frame 7 and of
    /// HTTP `GET /metrics`.
    pub fn prometheus_text(&self) -> std::result::Result<String, String> {
        let adm = &self.shared.admission;
        self.shared.metrics.set_admission_gauges(adm.ewma_wait_ms(), adm.peak_outstanding());
        if crate::obs::phase::enabled() {
            self.shared.metrics.set_kernel_phases(crate::obs::phase::snapshot());
        }
        let cum = self.shared.metrics.cumulative();
        let snap = self.shared.metrics.snapshot();
        let meta = ExportMeta {
            uptime_s: self.obs.started.elapsed().as_secs_f64(),
            sampler_interval_s: self.obs.sampler_interval_s,
            fingerprint: self.obs.fingerprint.clone(),
            outstanding: adm.outstanding() as u64,
            queue_ewma_ms: adm.ewma_wait_ms(),
            batches: snap.batches as u64,
            backend_roofline: snap
                .backend_roofline
                .iter()
                .map(|r| (r.backend.clone(), r.achieved_gflops, r.peak_gflops))
                .collect(),
            samples_total: self.obs.ring.total_pushed(),
        };
        let last = self.obs.ring.last(1);
        export::render_validated(&cum, &meta, last.first(), &self.obs.health.report())
    }

    /// The watchdog's current health verdict — the `/healthz` payload
    /// (`healthy == false` maps to HTTP 503).
    pub fn health_report(&self) -> HealthReport {
        self.obs.health.report()
    }

    /// The freshest `k` sampler windows, oldest first (empty while the
    /// sampler is off or has not completed a window yet).
    pub fn series(&self, k: usize) -> Vec<SeriesSample> {
        self.obs.ring.last(k)
    }

    /// Strict-schema series JSON of the freshest `k` sampler windows —
    /// the `series.json` member of flight-recorder bundles.
    pub fn series_json(&self, k: usize) -> String {
        render_series_json(&self.series(k))
    }

    /// Admitted-but-unanswered requests across all clients (live gauge).
    pub fn outstanding(&self) -> usize {
        self.shared.admission.outstanding()
    }

    /// Warm up: compile the bucket artifact for each length and
    /// initialise its parameters on **every** pool worker (so measured
    /// traffic never hits a cold compile on any worker), then reset
    /// metrics so measurements exclude compilation.
    pub fn warmup(&self, lens: &[usize]) -> Result<()> {
        let mut artifacts: Vec<String> = Vec::new();
        for &len in lens {
            let b = self
                .buckets
                .iter()
                .find(|b| b.seq_len >= len)
                .unwrap_or(self.buckets.last().expect("server has buckets"));
            if !artifacts.contains(&b.artifact) {
                artifacts.push(b.artifact.clone());
            }
        }
        let (done_tx, done_rx) = channel();
        self.shared
            .tx
            .send(Submission::Warmup { artifacts, done: done_tx })
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        for _ in 0..self.workers {
            done_rx
                .recv()
                .context("server stopped during warmup")?
                .map_err(|e| anyhow::anyhow!("warmup failed: {e}"))?;
        }
        self.shared.metrics.reset();
        Ok(())
    }

    /// Stop the router and the engine pool (drains nothing; pending
    /// requests get dropped). Shutdown order: router exits first, then
    /// the pool's `Drop` closes each worker queue and joins the workers.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.sampler_join.take() {
            let _ = j.join();
        }
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Dropping without shutdown() must not leak the router or the
        // engine workers.
        self.stop_and_join();
    }
}

/// A batch that has been dispatched to the pool and not completed yet.
struct InflightBatch {
    bucket_idx: usize,
    seq_len: usize,
    requests: Vec<PendingRequest>,
    truncated: Vec<bool>,
    /// When the batch was handed to the pool (anchors the worker-queue
    /// and kernel spans, split by the completion's timing breakdown).
    submitted: Instant,
}

/// Everything the dispatch/completion handlers touch, so the stage
/// functions stay small.
struct RouterState {
    batcher: Batcher,
    pool: EnginePool,
    replies: HashMap<u64, ReplyEntry>,
    inflight: HashMap<u64, InflightBatch>,
    next_batch_id: u64,
    vocab: usize,
    metrics: Arc<ServingMetrics>,
    admission: Arc<AdmissionState>,
    /// Realized backend kind of each pool worker, indexed by worker id.
    /// Realized — not requested — so two physically identical workers
    /// (e.g. a `gpu` spec that fell back to CPU next to a `cpu` worker)
    /// never register migrations between each other.
    worker_kinds: Vec<BackendKind>,
    /// Realized backend kind that served each bucket's previous batch,
    /// indexed by bucket — a change is a bucket migration (counted in
    /// metrics).
    bucket_backend: Vec<Option<BackendKind>>,
}

impl RouterState {
    #[allow(clippy::too_many_arguments)]
    fn new(
        pool: EnginePool,
        buckets: Vec<Bucket>,
        worker_kinds: Vec<BackendKind>,
        batcher_cfg: BatcherConfig,
        vocab: usize,
        metrics: Arc<ServingMetrics>,
        admission: Arc<AdmissionState>,
    ) -> Self {
        let n_buckets = buckets.len();
        RouterState {
            batcher: Batcher::new(buckets, batcher_cfg),
            pool,
            replies: HashMap::new(),
            inflight: HashMap::new(),
            next_batch_id: 1,
            vocab,
            metrics,
            admission,
            worker_kinds,
            bucket_backend: vec![None; n_buckets],
        }
    }
}

fn router_loop(
    rx: Receiver<Submission>,
    mut st: RouterState,
    stop: Arc<AtomicBool>,
    fault_stall: bool,
) {
    let wait = Duration::from_millis(1);
    // The loop exits only via the stop flag: the Server owns the sole
    // submission sender and always sets stop + joins this thread before
    // dropping it, so a disconnected channel implies stop is (about to
    // be) set.
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        // stage 3: collect completions first — frees bucket inflight
        // slots and answers waiting clients
        while let Some(c) = st.pool.try_completion() {
            complete_batch(&mut st, c);
        }
        // stage 1: accept new submissions without blocking
        loop {
            match rx.try_recv() {
                Ok(sub) => accept(&mut st, sub),
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        // stage 2: dispatch every formable batch (poll skips buckets at
        // their inflight cap, so long buckets can't starve short ones).
        // Under stall fault injection this stage is skipped entirely:
        // admitted requests pile up in the batcher, which is exactly the
        // outstanding>0 / completed==0 / jobs==0 shape the watchdog's
        // stall detector keys on.
        if !fault_stall {
            let now = Instant::now();
            while let Some(fb) = st.batcher.poll(now) {
                dispatch_batch(&mut st, fb);
            }
        }
        // idle: block briefly on the event that can make progress next
        if !st.inflight.is_empty() {
            if let Some(c) = st.pool.completion_timeout(wait) {
                complete_batch(&mut st, c);
            }
        } else {
            match rx.recv_timeout(wait) {
                Ok(sub) => accept(&mut st, sub),
                Err(RecvTimeoutError::Timeout) => {}
                // see loop header — pace the spin until stop lands
                Err(RecvTimeoutError::Disconnected) => std::thread::sleep(wait),
            }
        }
    }
}

/// The sampler thread: every interval, refresh the gauges the scrape
/// surfaces read, fold the cumulative counters into one window sample
/// pushed onto the ring, and run the watchdog over the freshest
/// windows. Only alert *edges* (a detector flipping quiet→firing) are
/// logged — and, when a flight recorder is configured, dump a
/// timestamped bundle — so a wedged server cannot flood its own logs
/// or disk while the condition persists.
fn sampler_loop(
    obs: Arc<ObsShared>,
    metrics: Arc<ServingMetrics>,
    admission: Arc<AdmissionState>,
    recorder: Option<FlightRecorder>,
    interval: Duration,
    stop: Arc<AtomicBool>,
) {
    // the deepest window lookback any detector needs
    let lookback = watchdog::STALL_WINDOWS
        .max(watchdog::COLLAPSE_WINDOWS)
        .max(watchdog::BURN_WINDOWS);
    let mut sampler = SamplerState::new();
    // sleep in short slices so shutdown never waits out a full interval
    let slice = Duration::from_millis(10).min(interval);
    'run: loop {
        let mut slept = Duration::ZERO;
        while slept < interval {
            if stop.load(Ordering::SeqCst) {
                break 'run;
            }
            std::thread::sleep(slice);
            slept += slice;
        }
        metrics.set_admission_gauges(admission.ewma_wait_ms(), admission.peak_outstanding());
        if crate::obs::phase::enabled() {
            metrics.set_kernel_phases(crate::obs::phase::snapshot());
        }
        let sample = sampler.sample(
            obs.started.elapsed().as_secs_f64(),
            metrics.cumulative(),
            admission.outstanding() as u64,
            admission.ewma_wait_ms(),
        );
        obs.ring.push(sample);
        let alerts = watchdog::detect(&obs.ring.last(lookback), obs.slo_p99_ms);
        let edges = obs.health.observe(&alerts);
        for alert in &edges {
            crate::log!(
                Level::Warn,
                "watchdog",
                "{} alert: {}",
                alert.detector.as_str(),
                alert.reason
            );
        }
        if let (Some(rec), Some(alert)) = (recorder.as_ref(), edges.first()) {
            let series = render_series_json(&obs.ring.last(obs.ring.capacity()));
            let snapshot = metrics.snapshot().to_json();
            match rec.dump(alert.detector.as_str(), &series, &snapshot) {
                Ok(path) => crate::log!(
                    Level::Warn,
                    "watchdog",
                    "flight bundle dumped to {}",
                    path.display()
                ),
                Err(e) => crate::log!(Level::Error, "watchdog", "flight dump failed: {e}"),
            }
        }
    }
}

fn accept(st: &mut RouterState, sub: Submission) {
    match sub {
        Submission::Request { req, entry } => {
            st.replies.insert(req.id, entry);
            st.batcher.push(req);
        }
        Submission::Warmup { artifacts, done } => {
            st.pool.warm(&artifacts, &done);
        }
    }
}

/// Answer one admitted request (by internal id) exactly once: send the
/// typed response, record the outcome against the owning client, and
/// release its admission slots. Every post-admission path — completion,
/// expiry shed, dispatch failure, batch error — funnels through here,
/// so a request can neither leak its slot nor be double-released.
fn finish(
    st: &mut RouterState,
    internal_id: u64,
    outcome: Outcome,
    latency_ms: f64,
    bucket: Option<usize>,
) {
    let Some(entry) = st.replies.remove(&internal_id) else {
        // unknown id (e.g. duplicate pool completion): never poison the
        // loop, but do surface it in the error count
        st.metrics.record_error();
        return;
    };
    match &outcome {
        Outcome::Completed { .. } => st.metrics.record_completed(&entry.label, latency_ms, bucket),
        Outcome::Shed { reason } => st.metrics.record_shed(&entry.label, *reason),
        Outcome::Error { .. } => st.metrics.record_request_error(&entry.label),
    }
    st.admission.release(&entry.inflight);
    let write_start = if trace::enabled() { Some(Instant::now()) } else { None };
    // a dropped receiver (disconnected wire client) is fine: the send
    // fails, the accounting above already happened
    let _ = entry.reply.send(Response { id: entry.wire_id, outcome, latency_ms });
    if let Some(ws) = write_start {
        // close the trace: the response-write span, then the root
        // request span stretching from the submission anchor to now
        let end = Instant::now();
        trace::span(SpanKind::Write, internal_id, ws, end, 0);
        trace::span(SpanKind::Request, internal_id, entry.t0, end, entry.wire_id);
    }
}

/// Pad/stack a formed batch and hand it to the worker with the minimum
/// expected completion time for its bucket. Requests whose deadline
/// passed while they queued are shed `Expired` here instead of burning
/// a forward pass on an answer nobody is waiting for.
fn dispatch_batch(st: &mut RouterState, fb: FormedBatch) {
    let bucket = fb.bucket;
    let bucket_idx = fb.bucket_idx;
    let now = Instant::now();
    let mut requests = Vec::with_capacity(fb.requests.len());
    for req in fb.requests {
        if matches!(req.deadline, Some(d) if now >= d) {
            let age = now.duration_since(req.enqueued).as_secs_f64() * 1e3;
            if trace::enabled() {
                // the request died waiting: its queue span is its story
                trace::span(SpanKind::Queue, req.id, req.enqueued, now, bucket.seq_len as u64);
            }
            finish(st, req.id, Outcome::Shed { reason: ShedReason::Expired }, age, None);
        } else {
            requests.push(req);
        }
    }
    if requests.is_empty() {
        st.batcher.complete(bucket_idx);
        return;
    }
    let b = bucket.batch;
    let s = bucket.seq_len;
    let mut tokens = vec![special::PAD; b * s];
    let mut kv_valid = vec![0f32; b * s];
    let mut truncated = vec![false; requests.len()];
    for (row, req) in requests.iter().enumerate() {
        let n = req.tokens.len().min(s);
        truncated[row] = req.tokens.len() > s;
        tokens[row * s..row * s + n].copy_from_slice(&req.tokens[..n]);
        for v in kv_valid[row * s..row * s + n].iter_mut() {
            *v = 1.0;
        }
    }
    let batch_id = st.next_batch_id;
    st.next_batch_id += 1;
    let submitted = Instant::now();
    let job = PoolJob {
        batch_id,
        artifact: bucket.artifact.clone(),
        shape: JobShape { seq_len: s, batch: b },
        inputs: vec![
            HostTensor::I32 { shape: vec![b, s], data: tokens },
            HostTensor::F32 { shape: vec![b, s], data: kv_valid },
        ],
        // the fwd artifact signature is (params, tokens, kv_valid); each
        // worker owns its params (deterministic init, so all agree)
        with_params: true,
        submitted,
    };
    // padded-vs-real token accounting for the padding-waste metric
    let real_tokens: usize = requests.iter().map(|r| r.tokens.len().min(s)).sum();
    match st.pool.submit(job) {
        Ok(worker) => {
            if trace::enabled() {
                // per request: batcher-queue span up to the dispatch
                // decision, then the dispatch span around pool submit
                let end = Instant::now();
                for req in &requests {
                    trace::span(SpanKind::Queue, req.id, req.enqueued, now, s as u64);
                    trace::span(SpanKind::Dispatch, req.id, now, end, worker as u64);
                }
            }
            // counted only once actually dispatched, so batch-fill and
            // the per-worker job totals stay consistent
            st.metrics.record_batch(requests.len(), b);
            st.metrics.record_padding(s, real_tokens, b * s);
            // a bucket changing (realized) backends is a migration —
            // the roofline/EWMA policy moving it to a better-fitting
            // device, never churn between identical workers
            if let Some(&kind) = st.worker_kinds.get(worker) {
                let prev = st.bucket_backend[bucket_idx].replace(kind);
                if matches!(prev, Some(p) if p != kind) {
                    st.metrics.record_migration();
                }
            }
            st.inflight.insert(
                batch_id,
                InflightBatch { bucket_idx, seq_len: s, requests, truncated, submitted },
            );
            st.metrics.record_dispatch(st.pool.inflight());
        }
        Err(e) => {
            crate::log!(Level::Error, "server", "dispatch failed: {e:#}");
            st.batcher.complete(bucket_idx);
            let msg = format!("dispatch failed: {e:#}");
            for req in requests {
                let age = req.enqueued.elapsed().as_secs_f64() * 1e3;
                finish(st, req.id, Outcome::Error { message: msg.clone() }, age, None);
            }
        }
    }
}

/// Decode one completed batch and answer its requests.
fn complete_batch(st: &mut RouterState, c: PoolCompletion) {
    let Some(ib) = st.inflight.remove(&c.batch_id) else {
        // unknown id: should not happen, but never poison the loop
        st.metrics.record_error();
        return;
    };
    st.batcher.complete(ib.bucket_idx);
    let exec_ms = c.exec.as_secs_f64() * 1e3;
    st.metrics.record_job(c.worker, c.queue_wait.as_secs_f64() * 1e3, exec_ms);
    if trace::enabled() {
        // reconstruct the worker timeline from the completion's split:
        // [submitted, picked] in the worker queue, [picked, +exec] on
        // the kernel — recorded per request so every trace tree is
        // complete on its own
        let picked = ib.submitted + c.queue_wait;
        let kernel_end = picked + c.exec;
        for req in &ib.requests {
            trace::span(SpanKind::WorkerQueue, req.id, ib.submitted, picked, c.worker as u64);
            trace::span(SpanKind::Kernel, req.id, picked, kernel_end, ib.seq_len as u64);
        }
    }
    // mirror the dispatch policy's refreshed cost table (the pool folds
    // successful exec times into it as completions are collected) so
    // metrics report exactly the EWMAs routing runs on
    let ewma = st
        .pool
        .ewma_table()
        .into_iter()
        .map(|(s, k, v)| (s, k.as_str().to_string(), v))
        .collect();
    st.metrics.set_exec_ewma(ewma);
    let outs = match c.result {
        Ok(outs) => outs,
        Err(e) => {
            crate::log!(
                Level::Error,
                "server",
                "batch {} failed on worker {}: {e}",
                c.batch_id,
                c.worker
            );
            fail_batch(st, ib, &format!("batch execution failed: {e}"));
            return;
        }
    };
    let logits = match outs.first().map(|t| t.as_f32()) {
        Some(Ok(l)) => l,
        _ => {
            fail_batch(st, ib, "batch produced no decodable logits");
            return;
        }
    };
    for (row, req) in ib.requests.iter().enumerate() {
        let preds = decode::mask_predictions(
            logits,
            row,
            ib.seq_len,
            st.vocab,
            &req.tokens,
            special::MASK,
        );
        let lat = req.enqueued.elapsed().as_secs_f64() * 1000.0;
        // feed the admission EWMA the non-execute share of the latency
        // (time spent queued in the batcher and the worker queue)
        st.admission.observe_wait((lat - exec_ms).max(0.0));
        if ib.truncated[row] {
            st.metrics.record_truncated();
        }
        finish(
            st,
            req.id,
            Outcome::Completed { predictions: preds, truncated: ib.truncated[row] },
            lat,
            Some(ib.seq_len),
        );
    }
}

/// Answer every request of a failed batch with a typed error (releasing
/// their admission slots) — an execution failure must degrade into N
/// error responses, never into silently dropped replies.
fn fail_batch(st: &mut RouterState, ib: InflightBatch, msg: &str) {
    for req in &ib.requests {
        let age = req.enqueued.elapsed().as_secs_f64() * 1e3;
        finish(st, req.id, Outcome::Error { message: msg.to_string() }, age, None);
    }
}
