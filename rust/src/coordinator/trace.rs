//! Workload traces for serving benchmarks: arrival processes + length
//! distributions, replayable against the server.
//!
//! The paper's serving story ("handle sequences up to 8× longer on
//! similar hardware") needs a workload whose *length distribution* is
//! long-tailed, like the document-length statistics of its datasets
//! (App. E.2 Tab. 11: NQ median 3258, max 77962). The trace generator
//! reproduces that shape: log-normal body + Pareto tail.

use crate::util::Rng;

/// Arrival process for a trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrival {
    /// Poisson with rate λ req/s.
    Poisson { rate: f64 },
    /// On/off bursts: `burst` back-to-back requests every `period_s`.
    Bursty { burst: usize, period_s: f64 },
    /// All requests at t = 0 (offline/batch evaluation).
    Closed,
}

/// One trace event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    /// arrival time in seconds from trace start
    pub at_s: f64,
    /// request sequence length in tokens
    pub len: usize,
    /// number of masked positions to predict
    pub masks: usize,
}

/// Length distribution matching long-document QA statistics: log-normal
/// body with a Pareto tail, clamped to [16, max_len].
pub fn sample_length(rng: &mut Rng, median: usize, max_len: usize) -> usize {
    let body = (median as f64) * (0.6 * rng.normal()).exp();
    let len = if rng.coin(0.1) {
        // Pareto tail: P(X > x) = (x_m / x)^α, α = 1.5
        let u = rng.f64().max(1e-9);
        body * u.powf(-1.0 / 1.5)
    } else {
        body
    };
    (len as usize).clamp(16, max_len)
}

/// Generate a trace of `n` events.
pub fn generate(
    n: usize,
    arrival: Arrival,
    median_len: usize,
    max_len: usize,
    seed: u64,
) -> Vec<TraceEvent> {
    let mut rng = Rng::new(seed).fold_in(0x7124CE);
    let mut events = Vec::with_capacity(n);
    let mut t = 0.0f64;
    for i in 0..n {
        match arrival {
            Arrival::Poisson { rate } => {
                // exponential inter-arrival
                t += -(1.0 - rng.f64()).ln() / rate;
            }
            Arrival::Bursty { burst, period_s } => {
                if i % burst == 0 && i > 0 {
                    t += period_s;
                }
            }
            Arrival::Closed => {}
        }
        events.push(TraceEvent {
            at_s: t,
            len: sample_length(&mut rng, median_len, max_len),
            masks: 1 + rng.below(4),
        });
    }
    events
}

/// Two-point length mixture: each request is `long` tokens with
/// probability `frac_long`, else `short`. This is the mixed
/// 512/2048-style traffic the engine-pool scaling bench uses to show
/// that long-sequence buckets no longer head-of-line-block short ones.
pub fn bimodal(
    n: usize,
    arrival: Arrival,
    short: usize,
    long: usize,
    frac_long: f64,
    seed: u64,
) -> Vec<TraceEvent> {
    let mut rng = Rng::new(seed).fold_in(0xB1D0);
    let mut events = Vec::with_capacity(n);
    let mut t = 0.0f64;
    for i in 0..n {
        match arrival {
            Arrival::Poisson { rate } => {
                t += -(1.0 - rng.f64()).ln() / rate;
            }
            Arrival::Bursty { burst, period_s } => {
                if i % burst == 0 && i > 0 {
                    t += period_s;
                }
            }
            Arrival::Closed => {}
        }
        let len = if rng.coin(frac_long) { long } else { short };
        events.push(TraceEvent { at_s: t, len, masks: 1 + rng.below(4) });
    }
    events
}

/// Summary statistics of a trace (for reporting).
pub fn summarize(events: &[TraceEvent]) -> (f64, usize, usize) {
    let lens: Vec<f64> = events.iter().map(|e| e.len as f64).collect();
    let median = crate::util::stats::median(&lens) as usize;
    let max = events.iter().map(|e| e.len).max().unwrap_or(0);
    let duration = events.last().map(|e| e.at_s).unwrap_or(0.0);
    (duration, median, max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_interarrivals_match_rate() {
        let tr = generate(2000, Arrival::Poisson { rate: 100.0 }, 512, 4096, 1);
        let (duration, _, _) = summarize(&tr);
        // 2000 events at 100/s ≈ 20 s
        assert!((duration - 20.0).abs() < 3.0, "duration {duration}");
        // arrivals strictly non-decreasing
        for w in tr.windows(2) {
            assert!(w[1].at_s >= w[0].at_s);
        }
    }

    #[test]
    fn lengths_are_long_tailed() {
        let tr = generate(5000, Arrival::Closed, 512, 8192, 2);
        let (_, median, max) = summarize(&tr);
        assert!((300..900).contains(&median), "median {median}");
        assert!(max > 2000, "no tail: max {max}");
        assert!(tr.iter().all(|e| (16..=8192).contains(&e.len)));
    }

    #[test]
    fn bursty_spacing() {
        let tr = generate(30, Arrival::Bursty { burst: 10, period_s: 1.0 }, 256, 1024, 3);
        assert_eq!(tr[9].at_s, tr[0].at_s);
        assert!(tr[10].at_s >= tr[9].at_s + 1.0);
    }

    #[test]
    fn bimodal_lengths_are_two_point() {
        let tr = bimodal(1000, Arrival::Closed, 400, 1800, 0.4, 9);
        assert!(tr.iter().all(|e| e.len == 400 || e.len == 1800));
        let longs = tr.iter().filter(|e| e.len == 1800).count();
        assert!((250..550).contains(&longs), "long fraction off: {longs}/1000");
        assert!(tr.iter().all(|e| (1..=4).contains(&e.masks)));
        // deterministic
        assert_eq!(tr, bimodal(1000, Arrival::Closed, 400, 1800, 0.4, 9));
    }

    #[test]
    fn deterministic() {
        let a = generate(50, Arrival::Poisson { rate: 10.0 }, 512, 4096, 7);
        let b = generate(50, Arrival::Poisson { rate: 10.0 }, 512, 4096, 7);
        assert_eq!(a, b);
    }
}
