//! Serving metrics: request counts, latency distribution, batch fill,
//! per-bucket padding waste (real vs padded tokens), and — for the
//! pipelined engine pool — the queue-wait vs execute-wait split,
//! per-worker and per-backend utilization, per-(bucket, backend)
//! exec-time EWMAs, bucket migration counts, and inflight-depth
//! tracking.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::stats;

/// Shared metrics sink (cheap Mutex; the hot path appends one f64).
#[derive(Debug, Default)]
pub struct ServingMetrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    latencies_ms: Vec<f64>,
    batches: usize,
    batched_requests: usize,
    batch_capacity: usize,
    truncated: usize,
    errors: usize,
    // pipeline split (one sample per completed batch job)
    queue_wait_ms: Vec<f64>,
    exec_ms: Vec<f64>,
    // per-worker accounting, indexed by worker id; pre-sized to the
    // pool via set_workers so idle workers still appear in reports
    workers: usize,
    worker_jobs: Vec<usize>,
    worker_busy_ms: Vec<f64>,
    // realized backend label per worker (from the engine pool), parallel
    // to worker_jobs; empty label for undeclared workers
    worker_backend: Vec<String>,
    // per-(bucket seq_len, backend) exec-time EWMA table, mirrored
    // wholesale from the dispatch policy (the authoritative copy that
    // routing actually uses) — never computed here, so the two can't
    // drift
    exec_ewma_ms: Vec<(usize, String, f64)>,
    // batches whose bucket moved to a different backend than the
    // previous batch of the same bucket
    migrations: usize,
    // (real tokens, padded tokens) dispatched per bucket seq_len: the
    // bucket ladder's padding waste (padded − real is compute burned on
    // PAD positions)
    padding: BTreeMap<usize, (u64, u64)>,
    // inflight depth sampled at each dispatch
    dispatches: usize,
    inflight_sum: usize,
    inflight_peak: usize,
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub requests: usize,
    pub batches: usize,
    pub errors: usize,
    pub truncated: usize,
    /// mean requests per formed batch / batch capacity
    pub fill_ratio: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    /// mean time a dispatched batch sat in a worker queue
    pub mean_queue_wait_ms: f64,
    /// mean time a batch spent executing on a worker
    pub mean_exec_ms: f64,
    /// mean pool-wide inflight depth observed at dispatch time
    pub mean_inflight: f64,
    /// peak pool-wide inflight depth observed at dispatch time
    pub peak_inflight: usize,
    /// completed batch jobs per worker, indexed by worker id
    pub worker_jobs: Vec<usize>,
    /// total execute time per worker (ms), indexed by worker id
    pub worker_busy_ms: Vec<f64>,
    /// realized backend label per worker, indexed by worker id (empty
    /// when the pool never declared backends)
    pub worker_backend: Vec<String>,
    /// observed exec-time EWMA per (bucket seq_len, backend), ms,
    /// sorted by bucket then backend — a mirror of the dispatch
    /// policy's authoritative routing table
    pub exec_ewma_ms: Vec<(usize, String, f64)>,
    /// batches whose bucket was served by a different backend than that
    /// bucket's previous batch
    pub migrations: usize,
    /// (bucket seq_len, real tokens, padded tokens) dispatched per
    /// bucket, sorted by seq_len — the padding-waste breakdown
    pub padding_by_bucket: Vec<(usize, u64, u64)>,
    /// overall fraction of dispatched (padded) tokens that were padding,
    /// `1 − Σreal / Σpadded` (0.0 before any dispatch)
    pub padding_waste: f64,
}

impl MetricsSnapshot {
    /// Per-worker utilization (busy time / wall time) over a measurement
    /// window of `wall_s` seconds.
    pub fn worker_utilization(&self, wall_s: f64) -> Vec<f64> {
        if wall_s <= 0.0 {
            return vec![0.0; self.worker_busy_ms.len()];
        }
        self.worker_busy_ms.iter().map(|&ms| ms / 1000.0 / wall_s).collect()
    }

    /// Per-backend utilization over a `wall_s`-second window: worker
    /// busy time aggregated by backend label, normalised by wall time ×
    /// the number of workers of that backend. Sorted by label.
    pub fn backend_utilization(&self, wall_s: f64) -> Vec<(String, f64)> {
        let mut busy: BTreeMap<&str, (f64, usize)> = BTreeMap::new();
        for (w, label) in self.worker_backend.iter().enumerate() {
            let ms = self.worker_busy_ms.get(w).copied().unwrap_or(0.0);
            let e = busy.entry(label.as_str()).or_insert((0.0, 0));
            e.0 += ms;
            e.1 += 1;
        }
        busy.into_iter()
            .map(|(label, (ms, n))| {
                let denom = wall_s * n as f64;
                let util = if denom > 0.0 { ms / 1000.0 / denom } else { 0.0 };
                (label.to_string(), util)
            })
            .collect()
    }
}

impl ServingMetrics {
    pub fn record_latency(&self, ms: f64) {
        self.inner.lock().unwrap().latencies_ms.push(ms);
    }

    pub fn record_batch(&self, requests: usize, capacity: usize) {
        let mut i = self.inner.lock().unwrap();
        i.batches += 1;
        i.batched_requests += requests;
        i.batch_capacity += capacity;
    }

    /// A batch was handed to the engine pool with `inflight_now` total
    /// batches (including this one) in flight.
    pub fn record_dispatch(&self, inflight_now: usize) {
        let mut i = self.inner.lock().unwrap();
        i.dispatches += 1;
        i.inflight_sum += inflight_now;
        i.inflight_peak = i.inflight_peak.max(inflight_now);
    }

    /// Declare the engine-pool size so per-worker vectors cover every
    /// worker (including ones that never complete a job) and report
    /// denominators are right. Survives [`ServingMetrics::reset`].
    pub fn set_workers(&self, n: usize) {
        let mut i = self.inner.lock().unwrap();
        i.workers = n;
        let len = n.max(i.worker_jobs.len());
        i.worker_jobs.resize(len, 0);
        i.worker_busy_ms.resize(len, 0.0);
        i.worker_backend.resize(len, String::new());
    }

    /// Declare the realized backend label of every pool worker (from
    /// `EnginePool::backends`), sizing the per-worker vectors like
    /// [`ServingMetrics::set_workers`]. Survives
    /// [`ServingMetrics::reset`].
    pub fn set_worker_backends(&self, labels: &[String]) {
        {
            let mut i = self.inner.lock().unwrap();
            i.worker_backend = labels.to_vec();
        }
        self.set_workers(labels.len());
    }

    /// A batch job completed on `worker` after waiting `queue_wait_ms`
    /// in its queue and executing for `exec_ms`.
    pub fn record_job(&self, worker: usize, queue_wait_ms: f64, exec_ms: f64) {
        let mut i = self.inner.lock().unwrap();
        if worker >= i.worker_jobs.len() {
            i.worker_jobs.resize(worker + 1, 0);
            i.worker_busy_ms.resize(worker + 1, 0.0);
            i.worker_backend.resize(worker + 1, String::new());
        }
        i.worker_jobs[worker] += 1;
        i.worker_busy_ms[worker] += exec_ms;
        i.queue_wait_ms.push(queue_wait_ms);
        i.exec_ms.push(exec_ms);
    }

    /// Install the dispatch policy's current per-(bucket seq_len,
    /// backend) exec-time EWMA table (from `EnginePool::ewma_table`),
    /// replacing the previous copy. The router pushes this on every
    /// completion so snapshots report exactly what routing runs on.
    pub fn set_exec_ewma(&self, table: Vec<(usize, String, f64)>) {
        self.inner.lock().unwrap().exec_ewma_ms = table;
    }

    /// A bucket's batch was dispatched to a different backend than the
    /// bucket's previous batch.
    pub fn record_migration(&self) {
        self.inner.lock().unwrap().migrations += 1;
    }

    /// A batch of bucket `seq_len` was dispatched carrying `real`
    /// request tokens inside `padded` total (batch × seq_len) padded
    /// tokens.
    pub fn record_padding(&self, seq_len: usize, real: usize, padded: usize) {
        let mut i = self.inner.lock().unwrap();
        let e = i.padding.entry(seq_len).or_insert((0, 0));
        e.0 += real as u64;
        e.1 += padded as u64;
    }

    pub fn record_truncated(&self) {
        self.inner.lock().unwrap().truncated += 1;
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// Clear all recordings (used after serving warmup, so measured
    /// latencies exclude one-off artifact compilation). Keeps the
    /// declared pool size.
    pub fn reset(&self) {
        let mut i = self.inner.lock().unwrap();
        let workers = i.workers;
        let backends = std::mem::take(&mut i.worker_backend);
        *i = Inner::default();
        i.workers = workers;
        i.worker_jobs.resize(workers, 0);
        i.worker_busy_ms.resize(workers, 0.0);
        i.worker_backend = backends;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let i = self.inner.lock().unwrap();
        MetricsSnapshot {
            requests: i.latencies_ms.len(),
            batches: i.batches,
            errors: i.errors,
            truncated: i.truncated,
            fill_ratio: if i.batch_capacity == 0 {
                0.0
            } else {
                i.batched_requests as f64 / i.batch_capacity as f64
            },
            p50_ms: stats::percentile(&i.latencies_ms, 50.0),
            p95_ms: stats::percentile(&i.latencies_ms, 95.0),
            p99_ms: stats::percentile(&i.latencies_ms, 99.0),
            mean_ms: stats::mean(&i.latencies_ms),
            mean_queue_wait_ms: stats::mean(&i.queue_wait_ms),
            mean_exec_ms: stats::mean(&i.exec_ms),
            mean_inflight: if i.dispatches == 0 {
                0.0
            } else {
                i.inflight_sum as f64 / i.dispatches as f64
            },
            peak_inflight: i.inflight_peak,
            worker_jobs: i.worker_jobs.clone(),
            worker_busy_ms: i.worker_busy_ms.clone(),
            worker_backend: i.worker_backend.clone(),
            exec_ewma_ms: i.exec_ewma_ms.clone(),
            migrations: i.migrations,
            padding_by_bucket: i
                .padding
                .iter()
                .map(|(&seq_len, &(real, padded))| (seq_len, real, padded))
                .collect(),
            padding_waste: {
                let real: u64 = i.padding.values().map(|&(r, _)| r).sum();
                let padded: u64 = i.padding.values().map(|&(_, p)| p).sum();
                if padded == 0 {
                    0.0
                } else {
                    1.0 - real as f64 / padded as f64
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_recordings() {
        let m = ServingMetrics::default();
        for i in 0..100 {
            m.record_latency(i as f64);
        }
        m.record_batch(3, 4);
        m.record_batch(4, 4);
        m.record_truncated();
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 2);
        assert_eq!(s.truncated, 1);
        assert!((s.fill_ratio - 7.0 / 8.0).abs() < 1e-12);
        assert!((s.p50_ms - 49.5).abs() < 1.0);
        assert!(s.p99_ms >= s.p95_ms && s.p95_ms >= s.p50_ms);
    }

    #[test]
    fn pipeline_metrics_split_by_worker() {
        let m = ServingMetrics::default();
        m.set_workers(4);
        m.record_dispatch(1);
        m.record_dispatch(3);
        m.record_job(0, 2.0, 10.0);
        m.record_job(2, 4.0, 30.0);
        let s = m.snapshot();
        // idle workers 1 and 3 still appear (pool-sized vectors)
        assert_eq!(s.worker_jobs, vec![1, 0, 1, 0]);
        assert_eq!(s.worker_busy_ms, vec![10.0, 0.0, 30.0, 0.0]);
        assert!((s.mean_queue_wait_ms - 3.0).abs() < 1e-12);
        assert!((s.mean_exec_ms - 20.0).abs() < 1e-12);
        assert!((s.mean_inflight - 2.0).abs() < 1e-12);
        assert_eq!(s.peak_inflight, 3);
        // utilization: worker 0 busy 10ms over a 1s window
        let u = s.worker_utilization(1.0);
        assert!((u[0] - 0.01).abs() < 1e-12);
        // reset clears counts but keeps the pool sizing
        m.reset();
        let s = m.snapshot();
        assert_eq!(s.peak_inflight, 0);
        assert_eq!(s.worker_jobs, vec![0; 4]);
    }

    #[test]
    fn padding_waste_aggregates_per_bucket() {
        let m = ServingMetrics::default();
        let s = m.snapshot();
        assert!(s.padding_by_bucket.is_empty());
        assert_eq!(s.padding_waste, 0.0, "no dispatches → no waste");
        // 512-bucket: 300+400 real of 2×512 padded; 2048-bucket: full
        m.record_padding(512, 300, 512);
        m.record_padding(512, 400, 512);
        m.record_padding(2048, 2048, 2048);
        let s = m.snapshot();
        assert_eq!(
            s.padding_by_bucket,
            vec![(512, 700, 1024), (2048, 2048, 2048)],
            "sorted by bucket, summed within"
        );
        let want = 1.0 - (700.0 + 2048.0) / (1024.0 + 2048.0);
        assert!((s.padding_waste - want).abs() < 1e-12, "{}", s.padding_waste);
        // reset clears the accumulation
        m.reset();
        assert!(m.snapshot().padding_by_bucket.is_empty());
    }

    #[test]
    fn backend_metrics_aggregate_by_label() {
        let m = ServingMetrics::default();
        m.set_worker_backends(&["cpu".into(), "cpu".into(), "gpu".into()]);
        // two cpu workers split 512-bucket work; the gpu takes 2048s
        m.record_job(0, 0.0, 10.0);
        m.record_job(1, 0.0, 30.0);
        m.record_job(2, 0.0, 40.0);
        m.record_job(2, 0.0, 20.0);
        m.record_migration();
        // the router mirrors the dispatch policy's EWMA table verbatim
        m.set_exec_ewma(vec![(512, "cpu".into(), 20.0), (2048, "gpu".into(), 34.0)]);
        let s = m.snapshot();
        assert_eq!(s.worker_backend, vec!["cpu", "cpu", "gpu"]);
        assert_eq!(s.migrations, 1);
        // per-backend utilization over a 1s window: cpu (10+30)ms over
        // 2 workers = 2%, gpu (40+20)ms over 1 worker = 6%
        let u = s.backend_utilization(1.0);
        assert_eq!(u.len(), 2);
        assert_eq!(u[0].0, "cpu");
        assert!((u[0].1 - 0.02).abs() < 1e-12);
        assert_eq!(u[1].0, "gpu");
        assert!((u[1].1 - 0.06).abs() < 1e-12);
        assert_eq!(s.exec_ewma_ms.len(), 2);
        assert_eq!(s.exec_ewma_ms[1], (2048, "gpu".to_string(), 34.0));
        // reset keeps the backend declaration, drops the mirrored table
        // (the router re-pushes it on the next completion)
        m.reset();
        let s = m.snapshot();
        assert_eq!(s.worker_backend.len(), 3);
        assert_eq!(s.migrations, 0);
        assert!(s.exec_ewma_ms.is_empty());
    }
}
