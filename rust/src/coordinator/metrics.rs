//! Serving metrics: request counts, latency distribution, batch fill.

use std::sync::Mutex;

use crate::util::stats;

/// Shared metrics sink (cheap Mutex; the hot path appends one f64).
#[derive(Debug, Default)]
pub struct ServingMetrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    latencies_ms: Vec<f64>,
    batches: usize,
    batched_requests: usize,
    batch_capacity: usize,
    truncated: usize,
    errors: usize,
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub requests: usize,
    pub batches: usize,
    pub errors: usize,
    pub truncated: usize,
    /// mean requests per formed batch / batch capacity
    pub fill_ratio: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
}

impl ServingMetrics {
    pub fn record_latency(&self, ms: f64) {
        self.inner.lock().unwrap().latencies_ms.push(ms);
    }

    pub fn record_batch(&self, requests: usize, capacity: usize) {
        let mut i = self.inner.lock().unwrap();
        i.batches += 1;
        i.batched_requests += requests;
        i.batch_capacity += capacity;
    }

    pub fn record_truncated(&self) {
        self.inner.lock().unwrap().truncated += 1;
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// Clear all recordings (used after serving warmup, so measured
    /// latencies exclude one-off artifact compilation).
    pub fn reset(&self) {
        *self.inner.lock().unwrap() = Inner::default();
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let i = self.inner.lock().unwrap();
        MetricsSnapshot {
            requests: i.latencies_ms.len(),
            batches: i.batches,
            errors: i.errors,
            truncated: i.truncated,
            fill_ratio: if i.batch_capacity == 0 {
                0.0
            } else {
                i.batched_requests as f64 / i.batch_capacity as f64
            },
            p50_ms: stats::percentile(&i.latencies_ms, 50.0),
            p95_ms: stats::percentile(&i.latencies_ms, 95.0),
            p99_ms: stats::percentile(&i.latencies_ms, 99.0),
            mean_ms: stats::mean(&i.latencies_ms),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_recordings() {
        let m = ServingMetrics::default();
        for i in 0..100 {
            m.record_latency(i as f64);
        }
        m.record_batch(3, 4);
        m.record_batch(4, 4);
        m.record_truncated();
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 2);
        assert_eq!(s.truncated, 1);
        assert!((s.fill_ratio - 7.0 / 8.0).abs() < 1e-12);
        assert!((s.p50_ms - 49.5).abs() < 1.0);
        assert!(s.p99_ms >= s.p95_ms && s.p95_ms >= s.p50_ms);
    }
}
