//! Serving metrics: request counts, streaming latency percentiles,
//! batch fill, per-bucket padding waste (real vs padded tokens), and —
//! for the pipelined engine pool — the queue-wait vs execute-wait
//! split, per-worker and per-backend utilization, per-(bucket, backend)
//! exec-time EWMAs, bucket migration counts, and inflight-depth
//! tracking. The admission-control era adds shed counters (total and
//! per [`ShedReason`]), per-client accounting, and the queue-wait EWMA
//! / peak-outstanding gauges.
//!
//! Latency distributions are kept in fixed-boundary log-bucket
//! [`Histogram`]s, not growing vectors or samplers: a server that runs
//! for days under load must have flat metrics memory, same as its
//! request queues — and because every histogram shares one bucket
//! layout, distributions **merge exactly** across workers and slice
//! per sequence bucket, so the SLO percentiles (p50/p95/p99 overall
//! and per `native_mlm_s{n}` ladder rung) are deterministic: identical
//! runs report identical numbers, unlike the retired sampling
//! reservoir. The snapshot also carries the kernel-phase profile and
//! per-backend achieved-vs-roofline utilization pushed by the server
//! (see [`crate::obs::phase`]). The snapshot is serializable
//! ([`MetricsSnapshot::to_json`]) and is exactly what the wire
//! `metrics` request returns, so operators scrape the same numbers
//! `serve_demo` prints.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use super::api::ShedReason;
use crate::obs::hist::Histogram;
use crate::obs::phase::PhaseStat;
use crate::obs::timeseries::CumulativeStats;

/// Shared metrics sink (cheap Mutex; the hot path pushes one f64).
#[derive(Debug, Default)]
pub struct ServingMetrics {
    inner: Mutex<Inner>,
}

#[derive(Debug)]
struct Inner {
    started: Instant,
    latencies: Histogram,
    // per sequence-bucket latency histograms (same fixed boundaries,
    // so the overall histogram is exactly their merge plus any
    // completions without a bucket attribution)
    latency_by_bucket: BTreeMap<usize, Histogram>,
    admitted: usize,
    shed: [usize; 4], // indexed by ShedReason::code()
    clients: BTreeMap<String, ClientCounters>,
    // admission gauges, pushed by the server before each snapshot
    queue_ewma_ms: f64,
    peak_outstanding: usize,
    batches: usize,
    batched_requests: usize,
    batch_capacity: usize,
    truncated: usize,
    errors: usize,
    // pipeline split (one sample per completed batch job)
    queue_wait: Histogram,
    exec: Histogram,
    // per-worker accounting, indexed by worker id; pre-sized to the
    // pool via set_workers so idle workers still appear in reports
    workers: usize,
    worker_jobs: Vec<usize>,
    worker_busy_ms: Vec<f64>,
    // realized backend label per worker (from the engine pool), parallel
    // to worker_jobs; empty label for undeclared workers
    worker_backend: Vec<String>,
    // per-(bucket seq_len, backend) exec-time EWMA table, mirrored
    // wholesale from the dispatch policy (the authoritative copy that
    // routing actually uses) — never computed here, so the two can't
    // drift
    exec_ewma_ms: Vec<(usize, String, f64)>,
    // batches whose bucket moved to a different backend than the
    // previous batch of the same bucket
    migrations: usize,
    // (real tokens, padded tokens) dispatched per bucket seq_len: the
    // bucket ladder's padding waste (padded − real is compute burned on
    // PAD positions)
    padding: BTreeMap<usize, (u64, u64)>,
    // inflight depth sampled at each dispatch
    dispatches: usize,
    inflight_sum: usize,
    inflight_peak: usize,
    // kernel-phase profile, mirrored from the global obs::phase
    // accumulators by the server right before each snapshot
    kernel_phases: Vec<PhaseStat>,
    // per-backend-label single-core roofline peak (GFLOP/s), declared
    // once at server start; survives reset like the worker backends
    backend_peak_gflops: BTreeMap<String, f64>,
    // scrape identity, declared once at server start so every snapshot
    // is self-describing; survives reset like the worker backends
    sampler_interval_s: f64,
    config_fingerprint: String,
}

impl Default for Inner {
    fn default() -> Self {
        Inner {
            started: Instant::now(),
            latencies: Histogram::new(),
            latency_by_bucket: BTreeMap::new(),
            admitted: 0,
            shed: [0; 4],
            clients: BTreeMap::new(),
            queue_ewma_ms: 0.0,
            peak_outstanding: 0,
            batches: 0,
            batched_requests: 0,
            batch_capacity: 0,
            truncated: 0,
            errors: 0,
            queue_wait: Histogram::new(),
            exec: Histogram::new(),
            workers: 0,
            worker_jobs: Vec::new(),
            worker_busy_ms: Vec::new(),
            worker_backend: Vec::new(),
            exec_ewma_ms: Vec::new(),
            migrations: 0,
            padding: BTreeMap::new(),
            dispatches: 0,
            inflight_sum: 0,
            inflight_peak: 0,
            kernel_phases: Vec::new(),
            backend_peak_gflops: BTreeMap::new(),
            sampler_interval_s: 0.0,
            config_fingerprint: String::new(),
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct ClientCounters {
    admitted: usize,
    completed: usize,
    shed: usize,
    errors: usize,
    req_per_s: f64,
}

/// Per-client accounting row in a snapshot.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClientStats {
    /// Client label (peer address for wire clients, `local` in-process).
    pub client: String,
    /// Requests that passed admission.
    pub admitted: usize,
    /// Requests answered with predictions.
    pub completed: usize,
    /// Requests answered with a typed shed.
    pub shed: usize,
    /// Requests answered with an execution error.
    pub errors: usize,
    /// Sliding-window submission rate (admitted + shed), requests per
    /// second — the admission ledger's rate gauge, updated at every
    /// submit (see `coordinator::admission::ClientRate`).
    pub req_per_s: f64,
}

/// One sequence bucket's SLO row: exact histogram-derived percentiles
/// over the requests completed in that `native_mlm_s{seq_len}` rung.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BucketLatency {
    /// Bucket sequence length (the ladder rung).
    pub seq_len: usize,
    /// Completed requests attributed to this bucket.
    pub count: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

/// Per-backend achieved-vs-roofline utilization, derived from the
/// kernel-phase profile: how close the backend's kernels run to the
/// calibrated single-core peak while busy.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BackendRoofline {
    /// Backend label (as in `MetricsSnapshot::worker_backend`).
    pub backend: String,
    /// Achieved GFLOP/s while busy (phase flops / phase busy time,
    /// summed across kernel threads — a per-thread rate).
    pub achieved_gflops: f64,
    /// Calibrated single-core roofline peak (GFLOP/s).
    pub peak_gflops: f64,
    /// `achieved / peak` (0 when idle or undeclared).
    pub utilization: f64,
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    /// Completed requests (the population of the latency percentiles).
    pub requests: usize,
    /// Requests that passed admission (completed + still inflight +
    /// errored + expired-after-admission).
    pub admitted: usize,
    /// Requests shed with a typed reason (door sheds + dispatch expiry).
    pub shed: usize,
    /// Shed counts per reason label, in wire-code order (zeros kept).
    pub shed_by_reason: Vec<(String, usize)>,
    /// Per-client accounting, sorted by client label.
    pub clients: Vec<ClientStats>,
    /// The admission controller's queue-wait EWMA gauge (ms).
    pub queue_ewma_ms: f64,
    /// High-water mark of admitted-but-unanswered requests — the
    /// bounded-queue witness (≤ configured `max_queue` by construction).
    pub peak_outstanding: usize,
    /// Seconds since the metrics window started (construction or the
    /// last [`ServingMetrics::reset`]).
    pub uptime_s: f64,
    pub batches: usize,
    pub errors: usize,
    pub truncated: usize,
    /// mean requests per formed batch / batch capacity
    pub fill_ratio: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    /// mean time a dispatched batch sat in a worker queue
    pub mean_queue_wait_ms: f64,
    /// mean time a batch spent executing on a worker
    pub mean_exec_ms: f64,
    /// mean pool-wide inflight depth observed at dispatch time
    pub mean_inflight: f64,
    /// peak pool-wide inflight depth observed at dispatch time
    pub peak_inflight: usize,
    /// completed batch jobs per worker, indexed by worker id
    pub worker_jobs: Vec<usize>,
    /// total execute time per worker (ms), indexed by worker id
    pub worker_busy_ms: Vec<f64>,
    /// realized backend label per worker, indexed by worker id (empty
    /// when the pool never declared backends)
    pub worker_backend: Vec<String>,
    /// observed exec-time EWMA per (bucket seq_len, backend), ms,
    /// sorted by bucket then backend — a mirror of the dispatch
    /// policy's authoritative routing table
    pub exec_ewma_ms: Vec<(usize, String, f64)>,
    /// batches whose bucket was served by a different backend than that
    /// bucket's previous batch
    pub migrations: usize,
    /// (bucket seq_len, real tokens, padded tokens) dispatched per
    /// bucket, sorted by seq_len — the padding-waste breakdown
    pub padding_by_bucket: Vec<(usize, u64, u64)>,
    /// overall fraction of dispatched (padded) tokens that were padding,
    /// `1 − Σreal / Σpadded` (0.0 before any dispatch)
    pub padding_waste: f64,
    /// exact histogram-derived latency percentiles per sequence bucket,
    /// sorted by bucket seq_len — the SLO ladder
    pub latency_by_bucket: Vec<BucketLatency>,
    /// kernel-phase profile (pack, QKᵀ, softmax, AV, backward, GEMM),
    /// mirrored from [`crate::obs::phase::snapshot`] by the server
    pub kernel_phases: Vec<PhaseStat>,
    /// per-backend achieved-vs-roofline utilization, sorted by label
    pub backend_roofline: Vec<BackendRoofline>,
    /// telemetry sampler interval in seconds (0 when the sampler is
    /// off) — declared once at server start
    pub sampler_interval_s: f64,
    /// serving `ModelConfig` fingerprint (dotted integers, from
    /// [`crate::kernel::model::config_fingerprint`]); empty when the
    /// server never declared one
    pub config_fingerprint: String,
}

impl MetricsSnapshot {
    /// Per-worker utilization (busy time / wall time) over a measurement
    /// window of `wall_s` seconds.
    pub fn worker_utilization(&self, wall_s: f64) -> Vec<f64> {
        if wall_s <= 0.0 {
            return vec![0.0; self.worker_busy_ms.len()];
        }
        self.worker_busy_ms.iter().map(|&ms| ms / 1000.0 / wall_s).collect()
    }

    /// Per-backend utilization over a `wall_s`-second window: worker
    /// busy time aggregated by backend label, normalised by wall time ×
    /// the number of workers of that backend. Sorted by label.
    pub fn backend_utilization(&self, wall_s: f64) -> Vec<(String, f64)> {
        let mut busy: BTreeMap<&str, (f64, usize)> = BTreeMap::new();
        for (w, label) in self.worker_backend.iter().enumerate() {
            let ms = self.worker_busy_ms.get(w).copied().unwrap_or(0.0);
            let e = busy.entry(label.as_str()).or_insert((0.0, 0));
            e.0 += ms;
            e.1 += 1;
        }
        busy.into_iter()
            .map(|(label, (ms, n))| {
                let denom = wall_s * n as f64;
                let util = if denom > 0.0 { ms / 1000.0 / denom } else { 0.0 };
                (label.to_string(), util)
            })
            .collect()
    }

    /// Serialize as a single JSON object — the payload of the wire
    /// `metrics` request, and what `serve_demo` prints. Hand-rolled like
    /// [`crate::util::report::BenchReport`] (the crate carries no JSON
    /// dependency); non-finite floats become `null`.
    pub fn to_json(&self) -> String {
        let mut o = String::with_capacity(1024);
        o.push('{');
        o.push_str("\"schema\":1");
        push_num(&mut o, "uptime_s", self.uptime_s);
        // self-describing scrape identity: spelled-out uptime alias for
        // external tooling, the sampler cadence, and the model identity
        push_num(&mut o, "uptime_seconds", self.uptime_s);
        push_num(&mut o, "sampler_interval_s", self.sampler_interval_s);
        o.push_str(&format!(",\"config_fingerprint\":{}", json_str(&self.config_fingerprint)));
        push_int(&mut o, "requests", self.requests);
        push_int(&mut o, "admitted", self.admitted);
        push_int(&mut o, "shed", self.shed);
        push_int(&mut o, "errors", self.errors);
        push_int(&mut o, "truncated", self.truncated);
        push_int(&mut o, "batches", self.batches);
        push_num(&mut o, "fill_ratio", self.fill_ratio);
        push_num(&mut o, "p50_ms", self.p50_ms);
        push_num(&mut o, "p95_ms", self.p95_ms);
        push_num(&mut o, "p99_ms", self.p99_ms);
        push_num(&mut o, "mean_ms", self.mean_ms);
        push_num(&mut o, "mean_queue_wait_ms", self.mean_queue_wait_ms);
        push_num(&mut o, "mean_exec_ms", self.mean_exec_ms);
        push_num(&mut o, "queue_ewma_ms", self.queue_ewma_ms);
        push_int(&mut o, "peak_outstanding", self.peak_outstanding);
        push_num(&mut o, "mean_inflight", self.mean_inflight);
        push_int(&mut o, "peak_inflight", self.peak_inflight);
        push_int(&mut o, "migrations", self.migrations);
        push_num(&mut o, "padding_waste", self.padding_waste);
        // shed reasons as an object with every label present
        o.push_str(",\"shed_by_reason\":{");
        for (k, (label, n)) in self.shed_by_reason.iter().enumerate() {
            if k > 0 {
                o.push(',');
            }
            o.push_str(&format!("{}:{}", json_str(label), n));
        }
        o.push('}');
        // per-client rows
        o.push_str(",\"clients\":[");
        for (k, c) in self.clients.iter().enumerate() {
            if k > 0 {
                o.push(',');
            }
            o.push_str(&format!(
                "{{\"client\":{},\"admitted\":{},\"completed\":{},\"shed\":{},\"errors\":{},\"req_per_s\":{}}}",
                json_str(&c.client),
                c.admitted,
                c.completed,
                c.shed,
                c.errors,
                json_num(c.req_per_s)
            ));
        }
        o.push(']');
        // per-worker rows; utilization over the metrics window
        o.push_str(",\"workers\":[");
        let util = self.worker_utilization(self.uptime_s);
        for w in 0..self.worker_jobs.len() {
            if w > 0 {
                o.push(',');
            }
            let backend = self.worker_backend.get(w).map(String::as_str).unwrap_or("");
            o.push_str(&format!(
                "{{\"worker\":{},\"backend\":{},\"jobs\":{},\"busy_ms\":{},\"utilization\":{}}}",
                w,
                json_str(backend),
                self.worker_jobs[w],
                json_num(self.worker_busy_ms.get(w).copied().unwrap_or(0.0)),
                json_num(util.get(w).copied().unwrap_or(0.0)),
            ));
        }
        o.push(']');
        o.push_str(",\"backend_utilization\":[");
        for (k, (label, u)) in self.backend_utilization(self.uptime_s).iter().enumerate() {
            if k > 0 {
                o.push(',');
            }
            o.push_str(&format!("{{\"backend\":{},\"utilization\":{}}}", json_str(label), json_num(*u)));
        }
        o.push(']');
        o.push_str(",\"padding_by_bucket\":[");
        for (k, &(bucket, real, padded)) in self.padding_by_bucket.iter().enumerate() {
            if k > 0 {
                o.push(',');
            }
            o.push_str(&format!(
                "{{\"bucket\":{bucket},\"real_tokens\":{real},\"padded_tokens\":{padded}}}"
            ));
        }
        o.push(']');
        o.push_str(",\"exec_ewma_ms\":[");
        for (k, (bucket, backend, ewma)) in self.exec_ewma_ms.iter().enumerate() {
            if k > 0 {
                o.push(',');
            }
            o.push_str(&format!(
                "{{\"bucket\":{},\"backend\":{},\"ewma_ms\":{}}}",
                bucket,
                json_str(backend),
                json_num(*ewma)
            ));
        }
        o.push(']');
        // exact per-rung SLO percentiles from the shared histogram layout
        o.push_str(",\"latency_by_bucket\":[");
        for (k, b) in self.latency_by_bucket.iter().enumerate() {
            if k > 0 {
                o.push(',');
            }
            o.push_str(&format!(
                "{{\"bucket\":{},\"count\":{},\"p50_ms\":{},\"p95_ms\":{},\"p99_ms\":{}}}",
                b.seq_len,
                b.count,
                json_num(b.p50_ms),
                json_num(b.p95_ms),
                json_num(b.p99_ms)
            ));
        }
        o.push(']');
        o.push_str(",\"kernel_phases\":[");
        for (k, p) in self.kernel_phases.iter().enumerate() {
            if k > 0 {
                o.push(',');
            }
            o.push_str(&format!(
                "{{\"phase\":{},\"calls\":{},\"busy_ms\":{},\"gflop\":{},\"gbyte\":{},\"achieved_gflops\":{},\"achieved_gbps\":{}}}",
                json_str(p.phase),
                p.calls,
                json_num(p.busy_ms),
                json_num(p.gflop),
                json_num(p.gbyte),
                json_num(p.achieved_gflops()),
                json_num(p.achieved_gbps())
            ));
        }
        o.push(']');
        o.push_str(",\"backend_roofline\":[");
        for (k, r) in self.backend_roofline.iter().enumerate() {
            if k > 0 {
                o.push(',');
            }
            o.push_str(&format!(
                "{{\"backend\":{},\"achieved_gflops\":{},\"peak_gflops\":{},\"utilization\":{}}}",
                json_str(&r.backend),
                json_num(r.achieved_gflops),
                json_num(r.peak_gflops),
                json_num(r.utilization)
            ));
        }
        o.push_str("]}");
        o
    }
}

fn push_num(out: &mut String, key: &str, v: f64) {
    out.push_str(&format!(",\"{key}\":{}", json_num(v)));
}

fn push_int(out: &mut String, key: &str, v: usize) {
    out.push_str(&format!(",\"{key}\":{v}"));
}

fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

fn json_str(s: &str) -> String {
    let mut o = String::with_capacity(s.len() + 2);
    o.push('"');
    for c in s.chars() {
        match c {
            '"' => o.push_str("\\\""),
            '\\' => o.push_str("\\\\"),
            '\n' => o.push_str("\\n"),
            '\r' => o.push_str("\\r"),
            '\t' => o.push_str("\\t"),
            c if (c as u32) < 0x20 => o.push_str(&format!("\\u{:04x}", c as u32)),
            c => o.push(c),
        }
    }
    o.push('"');
    o
}

/// Extract a top-level numeric field from a flat JSON object produced by
/// [`MetricsSnapshot::to_json`] — enough for tests and demo printing to
/// assert on wire-fetched metrics without a JSON dependency.
pub fn json_num_field(json: &str, key: &str) -> Option<f64> {
    let needle = format!("\"{key}\":");
    let at = json.find(&needle)? + needle.len();
    let rest = &json[at..];
    let end = rest
        .char_indices()
        .find(|(_, c)| !matches!(c, '0'..='9' | '-' | '+' | '.' | 'e' | 'E'))
        .map(|(i, _)| i)
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

impl ServingMetrics {
    /// A request passed admission for `client`.
    pub fn record_admitted(&self, client: &str) {
        let mut i = self.inner.lock().unwrap();
        i.admitted += 1;
        i.clients.entry(client.to_string()).or_default().admitted += 1;
    }

    /// A request from `client` completed with predictions after
    /// `latency_ms` end to end, served by the `bucket` sequence rung
    /// (when the batch that carried it is known — `None` attributes the
    /// sample only to the overall distribution).
    pub fn record_completed(&self, client: &str, latency_ms: f64, bucket: Option<usize>) {
        let mut i = self.inner.lock().unwrap();
        i.latencies.record(latency_ms);
        if let Some(seq_len) = bucket {
            i.latency_by_bucket.entry(seq_len).or_default().record(latency_ms);
        }
        i.clients.entry(client.to_string()).or_default().completed += 1;
    }

    /// Push `client`'s sliding-window submission rate gauge (req/s, from
    /// the admission ledger) so the next snapshot reports it.
    pub fn record_client_rate(&self, client: &str, req_per_s: f64) {
        let mut i = self.inner.lock().unwrap();
        i.clients.entry(client.to_string()).or_default().req_per_s = req_per_s;
    }

    /// A request from `client` was answered with a typed shed.
    pub fn record_shed(&self, client: &str, reason: ShedReason) {
        let mut i = self.inner.lock().unwrap();
        i.shed[reason.code() as usize] += 1;
        i.clients.entry(client.to_string()).or_default().shed += 1;
    }

    /// An error not attributable to a single client request (unknown
    /// batch id, duplicate completion).
    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// An admitted request from `client` failed in execution.
    pub fn record_request_error(&self, client: &str) {
        let mut i = self.inner.lock().unwrap();
        i.errors += 1;
        i.clients.entry(client.to_string()).or_default().errors += 1;
    }

    /// Push the admission controller's live gauges so the next snapshot
    /// reports them (called by the server right before snapshotting).
    pub fn set_admission_gauges(&self, queue_ewma_ms: f64, peak_outstanding: usize) {
        let mut i = self.inner.lock().unwrap();
        i.queue_ewma_ms = queue_ewma_ms;
        i.peak_outstanding = peak_outstanding;
    }

    pub fn record_batch(&self, requests: usize, capacity: usize) {
        let mut i = self.inner.lock().unwrap();
        i.batches += 1;
        i.batched_requests += requests;
        i.batch_capacity += capacity;
    }

    /// A batch was handed to the engine pool with `inflight_now` total
    /// batches (including this one) in flight.
    pub fn record_dispatch(&self, inflight_now: usize) {
        let mut i = self.inner.lock().unwrap();
        i.dispatches += 1;
        i.inflight_sum += inflight_now;
        i.inflight_peak = i.inflight_peak.max(inflight_now);
    }

    /// Declare the engine-pool size so per-worker vectors cover every
    /// worker (including ones that never complete a job) and report
    /// denominators are right. Survives [`ServingMetrics::reset`].
    pub fn set_workers(&self, n: usize) {
        let mut i = self.inner.lock().unwrap();
        i.workers = n;
        let len = n.max(i.worker_jobs.len());
        i.worker_jobs.resize(len, 0);
        i.worker_busy_ms.resize(len, 0.0);
        i.worker_backend.resize(len, String::new());
    }

    /// Declare the realized backend label of every pool worker (from
    /// `EnginePool::backends`), sizing the per-worker vectors like
    /// [`ServingMetrics::set_workers`]. Survives
    /// [`ServingMetrics::reset`].
    pub fn set_worker_backends(&self, labels: &[String]) {
        {
            let mut i = self.inner.lock().unwrap();
            i.worker_backend = labels.to_vec();
        }
        self.set_workers(labels.len());
    }

    /// A batch job completed on `worker` after waiting `queue_wait_ms`
    /// in its queue and executing for `exec_ms`.
    pub fn record_job(&self, worker: usize, queue_wait_ms: f64, exec_ms: f64) {
        let mut i = self.inner.lock().unwrap();
        if worker >= i.worker_jobs.len() {
            i.worker_jobs.resize(worker + 1, 0);
            i.worker_busy_ms.resize(worker + 1, 0.0);
            i.worker_backend.resize(worker + 1, String::new());
        }
        i.worker_jobs[worker] += 1;
        i.worker_busy_ms[worker] += exec_ms;
        i.queue_wait.record(queue_wait_ms);
        i.exec.record(exec_ms);
    }

    /// Mirror the global kernel-phase accumulators
    /// ([`crate::obs::phase::snapshot`]) so the next metrics snapshot
    /// carries the profile (called by the server before snapshotting).
    pub fn set_kernel_phases(&self, phases: Vec<PhaseStat>) {
        self.inner.lock().unwrap().kernel_phases = phases;
    }

    /// Declare a backend label's calibrated single-core roofline peak
    /// (GFLOP/s), the denominator of its utilization row. Survives
    /// [`ServingMetrics::reset`] like the worker backends.
    pub fn set_backend_peak(&self, backend: &str, peak_gflops: f64) {
        let mut i = self.inner.lock().unwrap();
        i.backend_peak_gflops.insert(backend.to_string(), peak_gflops);
    }

    /// Declare the scrape identity — the telemetry sampler interval
    /// (seconds, 0 = off) and the serving model's config fingerprint —
    /// so every snapshot and exposition is self-describing. Survives
    /// [`ServingMetrics::reset`] like the worker backends.
    pub fn set_scrape_identity(&self, sampler_interval_s: f64, config_fingerprint: String) {
        let mut i = self.inner.lock().unwrap();
        i.sampler_interval_s = sampler_interval_s;
        i.config_fingerprint = config_fingerprint;
    }

    /// Install the dispatch policy's current per-(bucket seq_len,
    /// backend) exec-time EWMA table (from `EnginePool::ewma_table`),
    /// replacing the previous copy. The router pushes this on every
    /// completion so snapshots report exactly what routing runs on.
    pub fn set_exec_ewma(&self, table: Vec<(usize, String, f64)>) {
        self.inner.lock().unwrap().exec_ewma_ms = table;
    }

    /// A bucket's batch was dispatched to a different backend than the
    /// bucket's previous batch.
    pub fn record_migration(&self) {
        self.inner.lock().unwrap().migrations += 1;
    }

    /// A batch of bucket `seq_len` was dispatched carrying `real`
    /// request tokens inside `padded` total (batch × seq_len) padded
    /// tokens.
    pub fn record_padding(&self, seq_len: usize, real: usize, padded: usize) {
        let mut i = self.inner.lock().unwrap();
        let e = i.padding.entry(seq_len).or_insert((0, 0));
        e.0 += real as u64;
        e.1 += padded as u64;
    }

    pub fn record_truncated(&self) {
        self.inner.lock().unwrap().truncated += 1;
    }

    /// Clear all recordings (used after serving warmup, so measured
    /// latencies exclude one-off artifact compilation). Keeps the
    /// declared pool size and restarts the metrics window clock.
    pub fn reset(&self) {
        let mut i = self.inner.lock().unwrap();
        let workers = i.workers;
        let backends = std::mem::take(&mut i.worker_backend);
        let peaks = std::mem::take(&mut i.backend_peak_gflops);
        let sampler_interval_s = i.sampler_interval_s;
        let fingerprint = std::mem::take(&mut i.config_fingerprint);
        *i = Inner::default();
        i.workers = workers;
        i.worker_jobs.resize(workers, 0);
        i.worker_busy_ms.resize(workers, 0.0);
        i.worker_backend = backends;
        i.backend_peak_gflops = peaks;
        i.sampler_interval_s = sampler_interval_s;
        i.config_fingerprint = fingerprint;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let i = self.inner.lock().unwrap();
        MetricsSnapshot {
            requests: i.latencies.count() as usize,
            admitted: i.admitted,
            shed: i.shed.iter().sum(),
            shed_by_reason: ShedReason::all()
                .iter()
                .map(|r| (r.as_str().to_string(), i.shed[r.code() as usize]))
                .collect(),
            clients: i
                .clients
                .iter()
                .map(|(label, c)| ClientStats {
                    client: label.clone(),
                    admitted: c.admitted,
                    completed: c.completed,
                    shed: c.shed,
                    errors: c.errors,
                    req_per_s: c.req_per_s,
                })
                .collect(),
            queue_ewma_ms: i.queue_ewma_ms,
            peak_outstanding: i.peak_outstanding,
            uptime_s: i.started.elapsed().as_secs_f64(),
            batches: i.batches,
            errors: i.errors,
            truncated: i.truncated,
            fill_ratio: if i.batch_capacity == 0 {
                0.0
            } else {
                i.batched_requests as f64 / i.batch_capacity as f64
            },
            p50_ms: i.latencies.percentile(50.0),
            p95_ms: i.latencies.percentile(95.0),
            p99_ms: i.latencies.percentile(99.0),
            mean_ms: i.latencies.mean(),
            mean_queue_wait_ms: i.queue_wait.mean(),
            mean_exec_ms: i.exec.mean(),
            mean_inflight: if i.dispatches == 0 {
                0.0
            } else {
                i.inflight_sum as f64 / i.dispatches as f64
            },
            peak_inflight: i.inflight_peak,
            worker_jobs: i.worker_jobs.clone(),
            worker_busy_ms: i.worker_busy_ms.clone(),
            worker_backend: i.worker_backend.clone(),
            exec_ewma_ms: i.exec_ewma_ms.clone(),
            migrations: i.migrations,
            padding_by_bucket: i
                .padding
                .iter()
                .map(|(&seq_len, &(real, padded))| (seq_len, real, padded))
                .collect(),
            padding_waste: {
                let real: u64 = i.padding.values().map(|&(r, _)| r).sum();
                let padded: u64 = i.padding.values().map(|&(_, p)| p).sum();
                if padded == 0 {
                    0.0
                } else {
                    1.0 - real as f64 / padded as f64
                }
            },
            latency_by_bucket: i
                .latency_by_bucket
                .iter()
                .map(|(&seq_len, h)| BucketLatency {
                    seq_len,
                    count: h.count(),
                    p50_ms: h.percentile(50.0),
                    p95_ms: h.percentile(95.0),
                    p99_ms: h.percentile(99.0),
                })
                .collect(),
            kernel_phases: i.kernel_phases.clone(),
            backend_roofline: {
                // one profile feeds every instrumented backend: the
                // phase accumulators are global, so the achieved rate is
                // the pool-wide per-thread number; only labels with a
                // declared peak get a row
                let busy_s: f64 = i.kernel_phases.iter().map(|p| p.busy_ms).sum::<f64>() / 1000.0;
                let gflop: f64 = i.kernel_phases.iter().map(|p| p.gflop).sum();
                let achieved = if busy_s > 0.0 { gflop / busy_s } else { 0.0 };
                i.backend_peak_gflops
                    .iter()
                    .map(|(label, &peak)| BackendRoofline {
                        backend: label.clone(),
                        achieved_gflops: achieved,
                        peak_gflops: peak,
                        utilization: if peak > 0.0 { achieved / peak } else { 0.0 },
                    })
                    .collect()
            },
            sampler_interval_s: i.sampler_interval_s,
            config_fingerprint: i.config_fingerprint.clone(),
        }
    }

    /// Raw cumulative counters and **histograms** (not derived
    /// percentiles) — the input the time-series sampler differences to
    /// get exact per-window distributions
    /// ([`crate::obs::timeseries::SamplerState::sample`]). Completions
    /// equal `latency.count()`; the pool roofline peak is the sum over
    /// workers of their backend's declared single-core peak.
    pub fn cumulative(&self) -> CumulativeStats {
        let i = self.inner.lock().unwrap();
        let mut shed = [0u64; 4];
        for (d, &s) in shed.iter_mut().zip(i.shed.iter()) {
            *d = s as u64;
        }
        let peak_gflops: f64 = i
            .worker_backend
            .iter()
            .map(|label| i.backend_peak_gflops.get(label).copied().unwrap_or(0.0))
            .sum();
        CumulativeStats {
            admitted: i.admitted as u64,
            shed,
            errors: i.errors as u64,
            latency: i.latencies.clone(),
            bucket_latency: i
                .latency_by_bucket
                .iter()
                .map(|(&seq_len, h)| (seq_len, h.clone()))
                .collect(),
            queue_wait: i.queue_wait.clone(),
            exec: i.exec.clone(),
            worker_jobs: i.worker_jobs.iter().map(|&j| j as u64).collect(),
            worker_busy_ms: i.worker_busy_ms.clone(),
            phase_gflop: i.kernel_phases.iter().map(|p| p.gflop).sum(),
            peak_gflops,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_recordings() {
        let m = ServingMetrics::default();
        // reference histograms built the same way the sink builds its
        // own — percentiles must now be EXACTLY reproducible, not
        // sample-dependent like the retired reservoir
        let mut all = Histogram::new();
        let mut short = Histogram::new();
        for i in 0..100 {
            let bucket = if i < 50 { 512 } else { 2048 };
            m.record_completed("local", i as f64, Some(bucket));
            all.record(i as f64);
            if bucket == 512 {
                short.record(i as f64);
            }
        }
        m.record_batch(3, 4);
        m.record_batch(4, 4);
        m.record_truncated();
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 2);
        assert_eq!(s.truncated, 1);
        assert!((s.fill_ratio - 7.0 / 8.0).abs() < 1e-12);
        assert_eq!(s.p50_ms, all.percentile(50.0));
        assert_eq!(s.p95_ms, all.percentile(95.0));
        assert_eq!(s.p99_ms, all.percentile(99.0));
        assert_eq!(s.mean_ms, all.mean());
        assert!(s.p99_ms >= s.p95_ms && s.p95_ms >= s.p50_ms);
        // per-rung SLO rows: sorted by bucket, exact per-slice percentiles
        assert_eq!(s.latency_by_bucket.len(), 2);
        assert_eq!(s.latency_by_bucket[0].seq_len, 512);
        assert_eq!(s.latency_by_bucket[0].count, 50);
        assert_eq!(s.latency_by_bucket[0].p50_ms, short.percentile(50.0));
        assert_eq!(s.latency_by_bucket[1].seq_len, 2048);
        assert_eq!(s.latency_by_bucket[1].count, 50);
        assert!(s.uptime_s >= 0.0);
    }

    #[test]
    fn roofline_rows_derive_from_phase_profile() {
        let m = ServingMetrics::default();
        // no peak declared → no rows even with a profile present
        m.set_kernel_phases(vec![PhaseStat {
            phase: "qk_t",
            calls: 4,
            busy_ms: 500.0,
            gflop: 10.0,
            gbyte: 1.0,
        }]);
        assert!(m.snapshot().backend_roofline.is_empty());
        // declared peak 80 GFLOP/s; achieved = 10 GFLOP / 0.5 s = 20
        m.set_backend_peak("native", 80.0);
        let s = m.snapshot();
        assert_eq!(s.backend_roofline.len(), 1);
        let r = &s.backend_roofline[0];
        assert_eq!(r.backend, "native");
        assert!((r.achieved_gflops - 20.0).abs() < 1e-12);
        assert!((r.utilization - 0.25).abs() < 1e-12);
        // reset keeps the declared peak (like worker backends) but
        // drops the mirrored profile → idle row with utilization 0
        m.reset();
        let s = m.snapshot();
        assert_eq!(s.backend_roofline.len(), 1);
        assert_eq!(s.backend_roofline[0].utilization, 0.0);
    }

    #[test]
    fn pipeline_metrics_split_by_worker() {
        let m = ServingMetrics::default();
        m.set_workers(4);
        m.record_dispatch(1);
        m.record_dispatch(3);
        m.record_job(0, 2.0, 10.0);
        m.record_job(2, 4.0, 30.0);
        let s = m.snapshot();
        // idle workers 1 and 3 still appear (pool-sized vectors)
        assert_eq!(s.worker_jobs, vec![1, 0, 1, 0]);
        assert_eq!(s.worker_busy_ms, vec![10.0, 0.0, 30.0, 0.0]);
        assert!((s.mean_queue_wait_ms - 3.0).abs() < 1e-12);
        assert!((s.mean_exec_ms - 20.0).abs() < 1e-12);
        assert!((s.mean_inflight - 2.0).abs() < 1e-12);
        assert_eq!(s.peak_inflight, 3);
        // utilization: worker 0 busy 10ms over a 1s window
        let u = s.worker_utilization(1.0);
        assert!((u[0] - 0.01).abs() < 1e-12);
        // reset clears counts but keeps the pool sizing
        m.reset();
        let s = m.snapshot();
        assert_eq!(s.peak_inflight, 0);
        assert_eq!(s.worker_jobs, vec![0; 4]);
    }

    #[test]
    fn padding_waste_aggregates_per_bucket() {
        let m = ServingMetrics::default();
        let s = m.snapshot();
        assert!(s.padding_by_bucket.is_empty());
        assert_eq!(s.padding_waste, 0.0, "no dispatches → no waste");
        // 512-bucket: 300+400 real of 2×512 padded; 2048-bucket: full
        m.record_padding(512, 300, 512);
        m.record_padding(512, 400, 512);
        m.record_padding(2048, 2048, 2048);
        let s = m.snapshot();
        assert_eq!(
            s.padding_by_bucket,
            vec![(512, 700, 1024), (2048, 2048, 2048)],
            "sorted by bucket, summed within"
        );
        let want = 1.0 - (700.0 + 2048.0) / (1024.0 + 2048.0);
        assert!((s.padding_waste - want).abs() < 1e-12, "{}", s.padding_waste);
        // reset clears the accumulation
        m.reset();
        assert!(m.snapshot().padding_by_bucket.is_empty());
    }

    #[test]
    fn backend_metrics_aggregate_by_label() {
        let m = ServingMetrics::default();
        m.set_worker_backends(&["cpu".into(), "cpu".into(), "gpu".into()]);
        // two cpu workers split 512-bucket work; the gpu takes 2048s
        m.record_job(0, 0.0, 10.0);
        m.record_job(1, 0.0, 30.0);
        m.record_job(2, 0.0, 40.0);
        m.record_job(2, 0.0, 20.0);
        m.record_migration();
        // the router mirrors the dispatch policy's EWMA table verbatim
        m.set_exec_ewma(vec![(512, "cpu".into(), 20.0), (2048, "gpu".into(), 34.0)]);
        let s = m.snapshot();
        assert_eq!(s.worker_backend, vec!["cpu", "cpu", "gpu"]);
        assert_eq!(s.migrations, 1);
        // per-backend utilization over a 1s window: cpu (10+30)ms over
        // 2 workers = 2%, gpu (40+20)ms over 1 worker = 6%
        let u = s.backend_utilization(1.0);
        assert_eq!(u.len(), 2);
        assert_eq!(u[0].0, "cpu");
        assert!((u[0].1 - 0.02).abs() < 1e-12);
        assert_eq!(u[1].0, "gpu");
        assert!((u[1].1 - 0.06).abs() < 1e-12);
        assert_eq!(s.exec_ewma_ms.len(), 2);
        assert_eq!(s.exec_ewma_ms[1], (2048, "gpu".to_string(), 34.0));
        // reset keeps the backend declaration, drops the mirrored table
        // (the router re-pushes it on the next completion)
        m.reset();
        let s = m.snapshot();
        assert_eq!(s.worker_backend.len(), 3);
        assert_eq!(s.migrations, 0);
        assert!(s.exec_ewma_ms.is_empty());
    }

    #[test]
    fn admission_accounting_and_shed_reasons() {
        let m = ServingMetrics::default();
        m.record_admitted("10.0.0.1:9");
        m.record_admitted("10.0.0.1:9");
        m.record_admitted("local");
        m.record_completed("10.0.0.1:9", 5.0, None);
        m.record_completed("10.0.0.1:9", 7.0, None);
        m.record_client_rate("10.0.0.1:9", 3.5);
        m.record_request_error("local");
        m.record_shed("10.0.0.2:7", ShedReason::QueueFull);
        m.record_shed("10.0.0.2:7", ShedReason::Overloaded);
        m.record_shed("10.0.0.2:7", ShedReason::Overloaded);
        m.set_admission_gauges(12.5, 42);
        let s = m.snapshot();
        assert_eq!(s.admitted, 3);
        assert_eq!(s.requests, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.shed, 3);
        assert_eq!(
            s.shed_by_reason,
            vec![
                ("queue_full".to_string(), 1),
                ("overloaded".to_string(), 2),
                ("client_limit".to_string(), 0),
                ("expired".to_string(), 0),
            ]
        );
        assert_eq!(s.queue_ewma_ms, 12.5);
        assert_eq!(s.peak_outstanding, 42);
        // clients sorted by label, each fully accounted
        assert_eq!(s.clients.len(), 3);
        assert_eq!(
            s.clients[0],
            ClientStats {
                client: "10.0.0.1:9".into(),
                admitted: 2,
                completed: 2,
                shed: 0,
                errors: 0,
                req_per_s: 3.5
            }
        );
        assert_eq!(s.clients[1].shed, 3);
        assert_eq!(s.clients[2].errors, 1);
        // unbucketed completions produce no SLO rows
        assert!(s.latency_by_bucket.is_empty());
    }

    #[test]
    fn snapshot_serializes_to_json() {
        let m = ServingMetrics::default();
        m.set_worker_backends(&["native".into(), "native".into()]);
        m.record_admitted("a\"b"); // label needing escape
        m.record_completed("a\"b", 3.0, Some(512));
        m.record_shed("a\"b", ShedReason::Overloaded);
        m.record_job(0, 1.0, 2.0);
        m.record_padding(512, 300, 512);
        m.set_admission_gauges(4.5, 7);
        m.set_kernel_phases(vec![PhaseStat {
            phase: "softmax",
            calls: 2,
            busy_ms: 1.0,
            gflop: 0.5,
            gbyte: 0.25,
        }]);
        m.set_backend_peak("native", 50.0);
        let j = m.snapshot().to_json();
        assert!(j.starts_with('{') && j.ends_with('}'), "{j}");
        assert!(j.contains("\"schema\":1"));
        assert!(j.contains("\"client\":\"a\\\"b\""), "escaped label: {j}");
        assert!(j.contains("\"req_per_s\":0"), "rate gauge serialized: {j}");
        assert!(j.contains("\"shed_by_reason\":{\"queue_full\":0,\"overloaded\":1"));
        assert!(j.contains("\"backend\":\"native\""));
        assert!(j.contains("\"padding_by_bucket\":[{\"bucket\":512"));
        assert!(j.contains("\"latency_by_bucket\":[{\"bucket\":512,\"count\":1"), "{j}");
        assert!(j.contains("\"kernel_phases\":[{\"phase\":\"softmax\",\"calls\":2"), "{j}");
        assert!(j.contains("\"backend_roofline\":[{\"backend\":\"native\""), "{j}");
        // numeric fields extractable by the helper; the p50 is the
        // 3.0ms sample's bucket representative, bit-for-bit
        let mut want = Histogram::new();
        want.record(3.0);
        assert_eq!(json_num_field(&j, "p50_ms"), Some(want.percentile(50.0)));
        assert_eq!(json_num_field(&j, "queue_ewma_ms"), Some(4.5));
        assert_eq!(json_num_field(&j, "peak_outstanding"), Some(7.0));
        assert_eq!(json_num_field(&j, "shed"), Some(1.0));
        assert_eq!(json_num_field(&j, "no_such_key"), None);
        // braces balance (cheap structural sanity without a parser)
        let open = j.matches('{').count();
        let close = j.matches('}').count();
        assert_eq!(open, close, "{j}");
    }
}
