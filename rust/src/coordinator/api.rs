//! The typed request/response surface of the serving coordinator.
//!
//! Exactly one request shape enters the server — [`Request`] — and
//! exactly one answer shape leaves it — [`Response`] with a typed
//! [`Outcome`] — whether the caller is an in-process client
//! (`serve_demo`, tests, benches) or a TCP connection through
//! [`crate::coordinator::Ingress`]. The wire codec
//! ([`crate::coordinator::wire`]) is a byte-level encoding of these
//! types, not a parallel API: both paths share the same admission and
//! accounting code in `Server`.

use std::time::Duration;

use anyhow::{bail, Result};

/// Scheduling class of a request. Priority affects **admission** under
/// pressure, not execution order: `High` requests bypass the soft
/// latency-budget shed (only the hard queue bound can reject them),
/// `Normal` and `Low` are shed once the queue-wait EWMA blows the
/// budget. Within the batcher everything stays FIFO per bucket.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Priority {
    /// Shed first under pressure (background / best-effort traffic).
    Low,
    /// The default class.
    #[default]
    Normal,
    /// Latency-critical: only hard bounds (queue full, per-client cap)
    /// may shed it.
    High,
}

impl Priority {
    /// Stable wire code (also the CLI string order).
    pub fn code(self) -> u8 {
        match self {
            Priority::Low => 0,
            Priority::Normal => 1,
            Priority::High => 2,
        }
    }

    /// Parse a wire code.
    pub fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => Priority::Low,
            1 => Priority::Normal,
            2 => Priority::High,
            other => bail!("unknown priority code {other}"),
        })
    }

    /// Human/CLI string.
    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }
}

/// A fill-mask inference request: the one submission type both the wire
/// path and the in-process path use.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    /// Caller's correlation id, echoed verbatim in [`Response::id`].
    /// `0` means "let the server assign one" (the server's internal
    /// sequence number, which is FIFO within a submission stream).
    pub id: u64,
    /// Token ids; `<mask>` positions produce predictions.
    pub tokens: Vec<i32>,
    /// Optional end-to-end deadline, relative to submission. A request
    /// the admission EWMA already predicts will miss it is shed
    /// `Overloaded` at the door; one that expires while queued is shed
    /// `Expired` at dispatch instead of burning a forward pass.
    pub deadline: Option<Duration>,
    /// Admission class (see [`Priority`]).
    pub priority: Priority,
}

impl Request {
    /// A default-class request with server-assigned id and no deadline.
    pub fn new(tokens: Vec<i32>) -> Self {
        Request { id: 0, tokens, deadline: None, priority: Priority::Normal }
    }

    /// Set the caller correlation id.
    pub fn with_id(mut self, id: u64) -> Self {
        self.id = id;
        self
    }

    /// Set a relative deadline.
    pub fn with_deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// Set the admission class.
    pub fn with_priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }
}

/// Why admission control refused (or abandoned) a request. Every
/// variant is a *normal, typed* answer — the overloaded server's
/// graceful-degradation contract — never a transport error.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ShedReason {
    /// The hard `max_queue` bound on outstanding requests was hit.
    QueueFull,
    /// The queue-wait EWMA exceeds the latency budget (or the request's
    /// own deadline) — admitting it would just queue a miss.
    Overloaded,
    /// This client already has `max_client_inflight` requests
    /// outstanding.
    ClientLimit,
    /// Admitted, but its deadline passed before dispatch.
    Expired,
}

impl ShedReason {
    /// Stable wire code.
    pub fn code(self) -> u8 {
        match self {
            ShedReason::QueueFull => 0,
            ShedReason::Overloaded => 1,
            ShedReason::ClientLimit => 2,
            ShedReason::Expired => 3,
        }
    }

    /// Parse a wire code.
    pub fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => ShedReason::QueueFull,
            1 => ShedReason::Overloaded,
            2 => ShedReason::ClientLimit,
            3 => ShedReason::Expired,
            other => bail!("unknown shed-reason code {other}"),
        })
    }

    /// Metrics / JSON label.
    pub fn as_str(self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue_full",
            ShedReason::Overloaded => "overloaded",
            ShedReason::ClientLimit => "client_limit",
            ShedReason::Expired => "expired",
        }
    }

    /// All reasons, in wire-code order (for metrics tables).
    pub fn all() -> [ShedReason; 4] {
        [ShedReason::QueueFull, ShedReason::Overloaded, ShedReason::ClientLimit, ShedReason::Expired]
    }
}

/// How a request ended.
#[derive(Clone, Debug, PartialEq)]
pub enum Outcome {
    /// A forward pass ran and produced predictions.
    Completed {
        /// (position, predicted token id) at each `<mask>` position.
        predictions: Vec<(usize, i32)>,
        /// True if the request was truncated to the largest bucket.
        truncated: bool,
    },
    /// Admission control refused or abandoned the request (typed
    /// overload answer, not an error).
    Shed { reason: ShedReason },
    /// The request was admitted but execution failed (worker error,
    /// malformed batch result). The message is operator-facing.
    Error { message: String },
}

/// A completed answer to one [`Request`].
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    /// Echo of [`Request::id`] (or the server-assigned id when 0).
    pub id: u64,
    pub outcome: Outcome,
    /// Submission-to-answer latency. For sheds this is the admission
    /// decision time (effectively zero at the door, queue-age for
    /// `Expired`).
    pub latency_ms: f64,
}

impl Response {
    /// Predictions of a completed outcome (empty for shed/error).
    pub fn predictions(&self) -> &[(usize, i32)] {
        match &self.outcome {
            Outcome::Completed { predictions, .. } => predictions,
            _ => &[],
        }
    }

    /// True if completed after truncation to the largest bucket.
    pub fn truncated(&self) -> bool {
        matches!(self.outcome, Outcome::Completed { truncated: true, .. })
    }

    /// True for any completed outcome.
    pub fn is_completed(&self) -> bool {
        matches!(self.outcome, Outcome::Completed { .. })
    }

    /// The shed reason, if this request was shed.
    pub fn shed_reason(&self) -> Option<ShedReason> {
        match self.outcome {
            Outcome::Shed { reason } => Some(reason),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_and_shed_reason_codes_round_trip() {
        for p in [Priority::Low, Priority::Normal, Priority::High] {
            assert_eq!(Priority::from_code(p.code()).unwrap(), p);
        }
        assert!(Priority::from_code(9).is_err());
        for r in ShedReason::all() {
            assert_eq!(ShedReason::from_code(r.code()).unwrap(), r);
        }
        assert!(ShedReason::from_code(9).is_err());
        assert_eq!(Priority::default(), Priority::Normal);
    }

    #[test]
    fn request_builder_and_response_accessors() {
        let r = Request::new(vec![1, 2, 3])
            .with_id(7)
            .with_deadline(Duration::from_millis(50))
            .with_priority(Priority::High);
        assert_eq!(r.id, 7);
        assert_eq!(r.deadline, Some(Duration::from_millis(50)));
        assert_eq!(r.priority, Priority::High);

        let done = Response {
            id: 7,
            outcome: Outcome::Completed { predictions: vec![(3, 11)], truncated: true },
            latency_ms: 1.0,
        };
        assert!(done.is_completed());
        assert!(done.truncated());
        assert_eq!(done.predictions(), &[(3, 11)]);
        assert_eq!(done.shed_reason(), None);

        let shed = Response {
            id: 8,
            outcome: Outcome::Shed { reason: ShedReason::QueueFull },
            latency_ms: 0.0,
        };
        assert!(!shed.is_completed());
        assert!(!shed.truncated());
        assert!(shed.predictions().is_empty());
        assert_eq!(shed.shed_reason(), Some(ShedReason::QueueFull));
    }
}
