//! The engine thread: sole owner of PJRT state.
//!
//! Jobs cross the thread boundary as `HostTensor`s; results return on a
//! per-job reply channel. `ExecutablePool` (not `Send`) is constructed
//! *inside* the engine thread.

use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::runtime::{ExecutablePool, HostTensor, Manifest, Runtime};

/// One unit of engine work.
pub struct EngineJob {
    /// artifact name to execute
    pub artifact: String,
    /// positional inputs
    pub inputs: Vec<HostTensor>,
    /// where the outputs go (stringified error on failure — keeps the
    /// channel payload `Send` without dragging non-Send context along)
    pub reply: Sender<std::result::Result<Vec<HostTensor>, String>>,
}

/// Handle to a running engine thread.
pub struct EngineHandle {
    tx: SyncSender<EngineJob>,
    join: Option<JoinHandle<()>>,
}

impl EngineHandle {
    /// Spawn the engine on `artifact_dir`, with a bounded queue of
    /// `queue_depth` jobs (backpressure: senders block when full).
    pub fn spawn(artifact_dir: String, queue_depth: usize) -> Result<Self> {
        let (tx, rx): (SyncSender<EngineJob>, Receiver<EngineJob>) =
            sync_channel(queue_depth);
        let (ready_tx, ready_rx) = sync_channel::<std::result::Result<(), String>>(1);
        let join = std::thread::Builder::new()
            .name("bigbird-engine".into())
            .spawn(move || {
                let pool = match Runtime::cpu()
                    .and_then(|rt| Ok(ExecutablePool::new(rt, Manifest::load(&artifact_dir)?)))
                {
                    Ok(p) => {
                        let _ = ready_tx.send(Ok(()));
                        p
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    let result = pool
                        .get(&job.artifact)
                        .and_then(|exe| exe.run(&job.inputs))
                        .map_err(|e| format!("{e:#}"));
                    let _ = job.reply.send(result);
                }
            })
            .context("spawning engine thread")?;
        ready_rx
            .recv()
            .context("engine thread died during startup")?
            .map_err(|e| anyhow::anyhow!("engine startup failed: {e}"))?;
        Ok(EngineHandle { tx, join: Some(join) })
    }

    /// Submit a job (blocks when the queue is full — backpressure).
    pub fn submit(&self, job: EngineJob) -> Result<()> {
        self.tx.send(job).context("engine thread gone")
    }

    /// Convenience: execute synchronously.
    pub fn execute(&self, artifact: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (reply, rx) = std::sync::mpsc::channel();
        self.submit(EngineJob { artifact: artifact.to_string(), inputs, reply })?;
        rx.recv()
            .context("engine dropped reply")?
            .map_err(|e| anyhow::anyhow!(e))
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        // Closing the channel stops the engine loop.
        // (tx is dropped as part of self; join afterwards.)
        if let Some(join) = self.join.take() {
            // replace tx with a dummy by dropping self.tx — can't move out;
            // the loop exits when all senders are gone, which happens when
            // self is fully dropped. Detach instead of joining to avoid
            // deadlock on self-referential drop order.
            let _ = join; // detach
        }
    }
}
